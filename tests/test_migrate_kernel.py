"""Fused migration gather/re-encode: Pallas kernel vs. jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import secded
from repro.core.layouts import Layout
from repro.core.pool import make_pool, read_page, write_page
from repro.kernels.migrate import kernel, ref

RNG = np.random.default_rng(11)


@pytest.fixture(scope="module")
def pool():
    p = make_pool(32, Layout.INTERWRAP, row_words=64)
    for page in range(p.num_pages):
        data = jnp.asarray(RNG.integers(0, 2**32, p.page_words,
                                        dtype=np.uint32))
        p = write_page(p, page, data)
    return p


@pytest.mark.parametrize("pages", [
    [0], [3, 17, 31], [32, 33, 34, 35],          # regular / extra pages
    [0, 35, 8, 33, 21],                           # mixed, unsorted
])
def test_kernel_matches_ref(pool, pages):
    ids = jnp.asarray(pages, jnp.int32)
    d_ref, c_ref = ref.gather_encode(pool.storage, ids, pool.num_rows)
    d_ker, c_ker = kernel.gather_encode(pool.storage, ids, pool.num_rows)
    np.testing.assert_array_equal(np.asarray(d_ref), np.asarray(d_ker))
    np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_ker))


def test_gathered_data_matches_page_reads(pool):
    ids = jnp.asarray([5, 33, 19], jnp.int32)
    data, _ = kernel.gather_encode(pool.storage, ids, pool.num_rows)
    for i, page in enumerate([5, 33, 19]):
        expect, _ = read_page(pool, page)
        np.testing.assert_array_equal(np.asarray(data[i]), np.asarray(expect))


def test_codes_are_valid_secded_planes(pool):
    """The fused codes must decode clean — they are the page's SECDED home."""
    ids = jnp.asarray([2, 34], jnp.int32)
    data, codes = kernel.gather_encode(pool.storage, ids, pool.num_rows)
    fixed, _, status = secded.decode_block(data, codes)
    assert int(jnp.max(status)) == secded.CLEAN
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(data))


def test_codes_correct_a_single_bit_flip(pool):
    ids = jnp.asarray([7], jnp.int32)
    data, codes = kernel.gather_encode(pool.storage, ids, pool.num_rows)
    corrupted = data.at[0, 12].set(data[0, 12] ^ jnp.uint32(1 << 9))
    fixed, _, status = secded.decode_block(corrupted, codes)
    assert int(jnp.max(status)) == secded.CORRECTED_DATA
    np.testing.assert_array_equal(np.asarray(fixed), np.asarray(data))

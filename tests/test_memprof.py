"""CREAM-Lens: capture hooks, bank attribution, replay, export plumbing."""
import json
import math
import time

import numpy as np
import pytest

from repro.core.layouts import (CODE_LANE, LANES, Layout, extra_page_count,
                                page_coords, parity_coords)
from repro.core.pool import make_pool
from repro.obs import dashboard, memprof, metrics, tracing


@pytest.fixture(autouse=True)
def _clean_memprof():
    """Every test starts and ends with the profiler off and empty."""
    memprof.disable()
    memprof.clear()
    metrics.disable()
    metrics.REGISTRY.clear()
    tracing.disable()
    tracing.reset()
    yield
    memprof.disable()
    memprof.clear()
    metrics.disable()
    metrics.REGISTRY.clear()
    tracing.disable()
    tracing.reset()


# ---------------------------------------------------------------------------
# capture hooks
# ---------------------------------------------------------------------------


class TestCapture:
    def test_disabled_by_default_records_nothing(self):
        assert not memprof.enabled()
        st = make_pool(16, Layout.INTERWRAP, boundary=8, row_words=16)
        data = st.read(np.arange(4))
        st.write(np.arange(4), data)
        assert memprof.records() == []

    def test_pool_wrappers_record_gather_and_scatter(self):
        memprof.enable()
        st = make_pool(16, Layout.INTERWRAP, boundary=8, row_words=16)
        data = st.read(np.arange(6))
        st.write(np.arange(6), data)
        recs = memprof.records()
        assert [(r.op, r.stream, len(r.pages)) for r in recs] == \
            [("gather", "main", 6), ("scatter", "main", 6)]
        # records carry the pool's own geometry for replay attribution
        assert recs[0].layout == Layout.INTERWRAP
        assert (recs[0].num_rows, recs[0].boundary) == (16, 8)

    def test_traceable_paths_do_not_record_at_trace_time(self):
        """Composing read_any/write_any under an enclosing jit must not
        capture tracer operands (records describe execution, not tracing)."""
        import jax
        memprof.enable()
        st = make_pool(16, Layout.INTERWRAP, boundary=8, row_words=16)

        @jax.jit
        def round_trip(state, pages):
            return state.write(pages, state.read(pages))

        round_trip(st, np.arange(4))
        assert memprof.records() == []

    def test_record_cap_counts_drops(self):
        memprof.enable()
        old = memprof.MAX_RECORDS
        memprof.MAX_RECORDS = 3
        try:
            for _ in range(5):
                memprof.record("gather", [0], layout=Layout.INTERWRAP,
                               num_rows=16, boundary=8, row_words=16)
        finally:
            memprof.MAX_RECORDS = old
        assert len(memprof.records()) == 3
        assert memprof.PROFILER.dropped == 2

    def test_reset_keeps_published_clear_drops_both(self):
        memprof.enable()
        memprof.record("gather", [0], layout=Layout.INTERWRAP,
                       num_rows=16, boundary=8, row_words=16)
        memprof.publish("p", {"overall": {}})
        memprof.reset()
        assert memprof.records() == [] and "p" in memprof.PROFILER.published
        memprof.clear()
        assert memprof.PROFILER.published == {}

    def test_bad_op_rejected(self):
        memprof.enable()
        with pytest.raises(ValueError):
            memprof.record("readwrite", [0], layout=Layout.INTERWRAP,
                           num_rows=16, boundary=8, row_words=16)


# ---------------------------------------------------------------------------
# bank attribution: the numpy mirror vs the jnp oracle
# ---------------------------------------------------------------------------


LAYOUTS = (Layout.BASELINE_ECC, Layout.PACKED, Layout.RANK_SUBSET,
           Layout.INTERWRAP, Layout.PARITY)


def _boundaries(layout, num_rows):
    if layout == Layout.BASELINE_ECC:
        return (0,)
    return (0, num_rows // 2, num_rows)


class TestCoordsMirror:
    @pytest.mark.parametrize("layout", LAYOUTS)
    def test_bit_exact_against_jnp_oracle(self, layout):
        num_rows, row_words = 32, 16
        for boundary in _boundaries(layout, num_rows):
            total = num_rows + extra_page_count(layout, boundary, row_words)
            pages = np.arange(total)
            rows, lanes, region = memprof.page_coords_np(
                layout, num_rows, boundary, pages, row_words)
            o_rows, o_lanes, o_region = page_coords(
                layout, num_rows, boundary, pages, row_words)
            np.testing.assert_array_equal(rows, np.asarray(o_rows))
            np.testing.assert_array_equal(lanes, np.asarray(o_lanes))
            np.testing.assert_array_equal(region, np.asarray(o_region))

    @pytest.mark.parametrize("layout", LAYOUTS)
    def test_slices_in_range(self, layout):
        """Every page maps to 8 in-range (chip, bank, row) slices."""
        from benchmarks.dram_sim import NUM_BANKS, bank_of
        num_rows, row_words = 32, 16
        for boundary in _boundaries(layout, num_rows):
            total = num_rows + extra_page_count(layout, boundary, row_words)
            pages = np.arange(total)
            rows, lanes, _ = memprof.page_coords_np(
                layout, num_rows, boundary, pages, row_words)
            assert rows.shape == lanes.shape == (total, 8)
            assert (lanes >= 0).all() and (lanes < LANES).all()
            assert (rows >= 0).all()
            banks = np.array([[bank_of(int(r)) for r in rr] for rr in rows])
            assert (banks[..., 0] >= 0).all()
            assert (banks[..., 0] < NUM_BANKS).all()

    def test_secded_extra_chip_contract(self):
        """SECDED-region pages read data from lanes 0-7 of their own row
        and exactly one code slice on the extra chip at the same row."""
        num_rows, row_words = 32, 16
        for layout in LAYOUTS:
            if layout == Layout.BASELINE_ECC:
                continue
            boundary = num_rows // 2
            sec = np.arange(boundary, num_rows)
            rows, lanes, _ = memprof.page_coords_np(
                layout, num_rows, boundary, sec, row_words)
            assert (lanes == np.arange(8)).all(), layout
            assert (rows == sec[:, None]).all(), layout
            crow = memprof.code_rows_np(layout, num_rows, boundary, sec,
                                        row_words)
            np.testing.assert_array_equal(crow, sec)

    def test_parity_code_rows_match_parity_coords(self):
        num_rows, row_words = 32, 16
        boundary = 16
        total = num_rows + extra_page_count(Layout.PARITY, boundary,
                                            row_words)
        pages = np.arange(total)
        crow = memprof.code_rows_np(Layout.PARITY, num_rows, boundary,
                                    pages, row_words)
        o_prow, _ = parity_coords(num_rows, boundary, pages, row_words)
        o_prow = np.asarray(o_prow)
        is_sec = (pages >= boundary) & (pages < num_rows)
        np.testing.assert_array_equal(crow[~is_sec], o_prow[~is_sec])
        np.testing.assert_array_equal(crow[is_sec], pages[is_sec])

    def test_non_parity_cream_pages_have_no_code_row(self):
        crow = memprof.code_rows_np(Layout.INTERWRAP, 32, 16,
                                    np.arange(16), 16)
        assert (crow == -1).all()


# ---------------------------------------------------------------------------
# per-bank state machines (benchmarks.dram_sim growth)
# ---------------------------------------------------------------------------


class TestBankMachines:
    def test_timing_defaults_are_ddr4_2400(self):
        from benchmarks.dram_sim import Timing
        t = Timing()
        assert t.tCK_ns == pytest.approx(0.833)
        assert (t.tRCD, t.tRP, t.tCL) == (16, 16, 16)
        assert t.tRRD == 4 and t.tFAW == 26 and t.tBL == 4

    def test_simstats_zero_access_guards(self):
        from benchmarks.dram_sim import SimStats
        s = SimStats()
        assert s.row_hit_rate == 0.0 and s.avg_latency == 0.0
        assert s.avg_concurrent == 0.0 and s.blp == 0.0
        assert not math.isnan(s.blp)

    def test_row_hit_miss_conflict_census(self):
        from benchmarks.dram_sim import BankArray, Timing
        arr = BankArray(Timing(), chips=1, banks=1)
        arr.access([(0, 0, 5)], 0)          # cold activate
        done = arr.access([(0, 0, 5)], arr.finish_cycle)   # row hit
        arr.access([(0, 0, 9)], done)       # conflict: row 9 over open 5
        c = arr.machine(0, 0).counters
        assert (c.row_empty, c.row_hits, c.row_conflicts) == (1, 1, 1)
        assert c.accesses == 3

    def test_row_hit_is_cheaper_than_conflict(self):
        from benchmarks.dram_sim import BankArray, Timing
        t = Timing()
        a = BankArray(t, chips=1, banks=1)
        a.access([(0, 0, 1)], 0)
        start = a.finish_cycle
        t_hit = a.access([(0, 0, 1)], start) - start
        b = BankArray(t, chips=1, banks=1)
        b.access([(0, 0, 1)], 0)
        start = b.finish_cycle
        t_conf = b.access([(0, 0, 2)], start) - start
        assert t_conf - t_hit == t.tRP + t.tRCD  # PRE + ACT on top of CAS

    def test_tfaw_window_stalls_fifth_activation(self):
        from benchmarks.dram_sim import BankArray, Timing
        t = Timing()
        arr = BankArray(t, chips=1, banks=8)
        # five cold ACTs on one rank in a single lockstep access: tRRD
        # paces them 4 apart (0,4,8,12); the 5th must also clear the
        # rolling four-ACT window (0 + tFAW = 26 > 16)
        arr.access([(0, b, 0) for b in range(5)], 0)
        tot = arr.totals()
        assert tot.faw_stall_cycles == t.tFAW - 4 * t.tRRD
        assert tot.act_stall_cycles >= tot.faw_stall_cycles

    def test_blp_measures_overlap(self):
        from benchmarks.dram_sim import BankArray, Timing
        # 8 independent banks touched at once: near-8x overlap
        wide = BankArray(Timing(), chips=1, banks=8)
        wide.access([(0, b, 0) for b in range(8)], 0)
        # the same 8 accesses serialised on one bank
        narrow = BankArray(Timing(), chips=1, banks=1)
        for _ in range(8):
            narrow.access([(0, 0, 0)], 0)
        assert wide.achieved_blp > 4 * narrow.achieved_blp

    def test_queue_depth_percentile_and_histogram(self):
        from benchmarks.dram_sim import BankArray, Timing
        arr = BankArray(Timing(), chips=1, banks=1)
        for _ in range(4):
            arr.access([(0, 0, 0)], 0)      # all pile on one busy bank
        assert arr.queue_depth_percentile(99) >= 1.0
        assert sum(arr.blp_histogram()) == 4


# ---------------------------------------------------------------------------
# replay + profile
# ---------------------------------------------------------------------------


def _capture_small_pool():
    st = make_pool(16, Layout.INTERWRAP, boundary=8, row_words=16)
    memprof.enable()
    data = st.read(np.arange(st.num_pages))
    st.write(np.arange(st.num_pages), data)
    return st


class TestReplay:
    def test_profile_shape_and_determinism(self):
        _capture_small_pool()
        p1 = memprof.profile()
        p2 = memprof.profile()
        assert p1 == p2                      # replay is deterministic
        assert p1["records"] == 2 and p1["dropped"] == 0
        s = p1["streams"]["main"]
        o = p1["overall"]
        for key in ("row_hit_rate", "conflict_rate", "achieved_blp",
                    "tfaw_stall_cycles", "queue_p99", "extra_chip_frac"):
            assert key in s and key in o
            assert not math.isnan(float(o[key]))
        assert np.asarray(o["heatmap"]).shape == (LANES, 8)
        assert o["accesses"] > 0 and o["achieved_blp"] > 0

    def test_secded_traffic_lands_on_extra_chip(self):
        _capture_small_pool()
        prof = memprof.profile()
        heat = np.asarray(prof["overall"]["heatmap"])
        # boundary=8 of 16 rows -> half the pages carry code-slice reads
        assert heat[CODE_LANE].sum() > 0
        assert prof["overall"]["extra_chip_frac"] > 0

    def test_streams_replay_into_separate_bank_arrays(self):
        memprof.enable()
        for stream in ("bank0", "bank1"):
            memprof.record("gather", np.arange(4), layout=Layout.INTERWRAP,
                           num_rows=16, boundary=8, row_words=16,
                           stream=stream)
        prof = memprof.profile()
        assert set(prof["streams"]) == {"bank0", "bank1"}
        # overall busy sums across streams over the shared makespan, so
        # two identical concurrent streams double the achieved BLP
        one = prof["streams"]["bank0"]["achieved_blp"]
        assert prof["overall"]["achieved_blp"] == pytest.approx(2 * one,
                                                                rel=1e-3)

    def test_profile_is_json_serialisable(self):
        _capture_small_pool()
        memprof.publish("p", memprof.profile())
        blob = memprof.collect()
        json.dumps(blob)                     # must not raise
        assert set(blob) == {"records", "dropped", "profiles"}


# ---------------------------------------------------------------------------
# export: metrics gauges, Perfetto counter tracks, dashboard panel
# ---------------------------------------------------------------------------


class TestExport:
    def test_collect_exports_dram_gauges_when_metrics_on(self):
        metrics.enable()
        _capture_small_pool()
        memprof.publish("t", memprof.profile())
        memprof.reset()
        memprof.collect()
        assert metrics.REGISTRY.value(metrics.NAME_DRAM_BLP, suite="t",
                                      stream="overall") > 0
        snap = metrics.snapshot()
        assert "cream_dram_bank_row_hit_rate" in snap

    def test_counter_events_schema(self):
        _capture_small_pool()
        blob = {"profiles": {"p": memprof.profile()}}
        events = memprof.counter_events(blob)
        assert events, "timeline must produce counter points"
        for e in events:
            assert e["ph"] == "C"
            assert e["name"].startswith("dram.bank[p/")
            assert {"blp", "row_hit_rate_pct", "queue"} <= set(e["args"])
        # they extend into the tracer buffer for export next to spans
        tracing.enable()
        tracing.TRACER.extend(events)
        assert any(ev["ph"] == "C" for ev in tracing.TRACER.to_dict()
                   ["traceEvents"])

    def test_bank_heatmap_renders(self):
        _capture_small_pool()
        memprof.publish("s8/streams", memprof.profile())
        out = dashboard.render_bank_heatmap(memprof.collect())
        assert "DRAM BANK PROFILE" in out and "[s8/streams]" in out
        assert "code" in out                # chip 8 row is called out

    def test_bank_heatmap_empty_blob(self):
        out = dashboard.render_bank_heatmap({"profiles": {}})
        assert "no bank profiles" in out


# ---------------------------------------------------------------------------
# engine + sharded wiring
# ---------------------------------------------------------------------------


def _tiny_engine(**kw):
    from benchmarks.bench_serving import CFG
    from repro.serve.engine import Engine
    return Engine(CFG, max_batch=2, max_len=24, num_rows=32, row_words=64,
                  secded_rows=8, **kw)


def _tiny_requests(n=2, max_new=3):
    from repro.serve.engine import Request
    return [Request(f"s{i}", list(range(1, 7)), max_new,
                    tier="paid" if i % 2 else "batch") for i in range(n)]


class TestEngineWiring:
    def test_decode_step_records_one_gather_one_scatter(self):
        eng = _tiny_engine()
        for r in _tiny_requests():
            eng.submit(r)
        eng.poll()                           # prefills only
        memprof.enable()
        memprof.reset()
        eng.step()
        recs = memprof.records()
        gathers = [r for r in recs if r.op == "gather"]
        scatters = [r for r in recs if r.op == "scatter"]
        assert len(gathers) == 1 and len(scatters) == 1
        assert gathers[0].stream == "decode"
        assert gathers[0].step == scatters[0].step == 1

    def test_decode_gather_recorded_with_metrics_enabled_too(self):
        metrics.enable()                     # counts path, not fused-read
        eng = _tiny_engine()
        for r in _tiny_requests():
            eng.submit(r)
        eng.poll()
        memprof.enable()
        memprof.reset()
        eng.step()
        assert [r.op for r in memprof.records()].count("gather") == 1

    @pytest.mark.slow
    def test_memprof_disabled_overhead_within_2_percent(self):
        """The tentpole's overhead guard: with capture off (the default)
        the hooks are one boolean read — Engine.step stays within 2%
        (plus a tiny absolute slack) of a run without the profiler."""
        def run_steps(enable: bool, rounds=4):
            memprof.clear()
            memprof.enable(enable)
            eng = _tiny_engine()
            eng.serve(_tiny_requests(n=2, max_new=4))   # warm compile
            ts = []
            for _ in range(rounds):
                for r in _tiny_requests(n=2, max_new=16):
                    eng.submit(r)
                while eng.sched.has_work():
                    t0 = time.perf_counter()
                    eng.poll()
                    ts.append(time.perf_counter() - t0)
                memprof.reset()              # bound capture memory
            memprof.disable()
            return float(np.median(ts))

        # interleave the pairs so clock-speed drift hits both sides
        # equally; min-of-N approaches each side's true floor.  The
        # DISABLED side is the guard: hooks compiled into the hot path
        # must cost nothing when the profiler is off.
        base, inst = [], []
        for _ in range(4):
            base.append(run_steps(False))
            inst.append(run_steps(True))
        b = min(base)
        assert b <= min(inst) * 1.02 + 3e-4, \
            f"disabled-path drag {b / min(inst) - 1:.1%}"


class TestShardedWiring:
    def test_routed_dispatch_records_per_bank_streams(self):
        import jax
        from repro.shard import pool as shard_pool
        S = min(2, jax.device_count())
        sp = shard_pool.make_sharded_pool(32, Layout.INTERWRAP, boundary=16,
                                          num_shards=S, row_words=16)
        memprof.enable()
        data = sp.read(np.arange(32))
        sp = sp.write(np.arange(32), data)
        recs = memprof.records()
        streams = {r.stream for r in recs}
        assert streams == {f"bank{s}" for s in range(S)}
        # local geometry: each record describes the shard's own module
        assert all(r.num_rows == 32 // S and r.boundary == 16 // S
                   for r in recs)
        # round-robin striping: shard s records exactly its own pages
        for r in recs:
            assert (r.pages < 32 // S).all()

    def test_stream_dispatch_records_aligned_streams(self):
        import jax
        import jax.numpy as jnp
        from repro.shard import pool as shard_pool
        S = min(2, jax.device_count())
        sp = shard_pool.make_sharded_pool(32, Layout.INTERWRAP, boundary=16,
                                          num_shards=S, row_words=16)
        aligned = jnp.stack([jnp.arange(4, dtype=jnp.int32) * S + s
                             for s in range(S)])
        memprof.enable()
        shard_pool.read_streams(sp, aligned)
        streams = {r.stream for r in memprof.records()}
        assert streams == {f"streams/bank{s}" for s in range(S)}

    def test_objcache_records_cache_stream(self):
        from repro.objcache import ObjCache
        from repro.vm import VirtualMemory
        vm = VirtualMemory(row_words=16)
        vm.add_pool("dimm", 16, Layout.INTERWRAP, boundary=8)
        cache = ObjCache(vm, "dimm", index_capacity=64, probe=8)
        memprof.enable()
        keys = np.arange(1, 5)
        vals = np.ones((4, vm.page_words), np.uint32)
        assert cache.set_many(keys, vals).all()
        _, _, found = cache.get_many(keys)
        assert found.all()
        ops = {(r.op, r.stream) for r in memprof.records()}
        assert ("scatter", "objcache") in ops
        assert ("gather", "objcache") in ops

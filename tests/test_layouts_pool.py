"""Layout address-translation invariants + CREAMPool behaviour (property-based)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import injection, parity8
from repro.core import pool as P
from repro.core.layouts import (Layout, count_device_ops,
                                extra_page_count, interwrap_slices,
                                total_pages)

RNG = np.random.default_rng(1)
ALL_LAYOUTS = [Layout.PACKED, Layout.RANK_SUBSET, Layout.INTERWRAP,
               Layout.PARITY]


def rand_page(pw):
    return jnp.asarray(RNG.integers(0, 2**32, size=(pw,), dtype=np.uint32))


# -- paper-exact constants -----------------------------------------------------


def test_capacity_gains_match_paper():
    assert extra_page_count(Layout.PACKED, 1024) == 128          # +12.5%
    assert extra_page_count(Layout.INTERWRAP, 1024) == 128
    gain = extra_page_count(Layout.PARITY, 1024) / 1024
    assert abs(gain - 0.107) < 0.003                             # +10.7%


def test_rank_subset_78pct_extra_accesses():
    """Paper §4.1.3: uniform traffic -> +78% average accesses."""
    B = 1024
    tot = total_pages(Layout.RANK_SUBSET, B)
    reads = sum(count_device_ops(Layout.RANK_SUBSET, B, p, False)
                for p in range(tot))
    assert abs(reads / tot - 1.78) < 0.01


def test_paper_op_counts():
    B = 64
    assert count_device_ops(Layout.BASELINE_ECC, B, 0, False) == 1
    assert count_device_ops(Layout.PACKED, B, 0, True) == 2          # RMW
    assert count_device_ops(Layout.PACKED, B, B, False) == 8
    assert count_device_ops(Layout.RANK_SUBSET, B, 0, True) == 1
    assert count_device_ops(Layout.INTERWRAP, B, B, True) == 1
    assert count_device_ops(Layout.PARITY, B, 0, False) == 2
    assert count_device_ops(Layout.PARITY, B, B, False) == 9         # §4.2


@pytest.mark.parametrize("slot", range(9))
def test_interwrap_bridge_formula(slot):
    """Skipped lane == (8 - slot) mod 9 — the paper's bridge-chip formula."""
    lanes = {l for l, _ in interwrap_slices(slot)}
    assert len(lanes) == 8
    assert (8 - slot) % 9 not in lanes


@given(st.integers(8, 64).map(lambda g: g * 8))
@settings(max_examples=20, deadline=None)
def test_no_storage_overlap(num_rows):
    """No two pages' physical slices overlap, for every layout (word-level)."""
    for layout in ALL_LAYOUTS:
        claimed: dict = {}
        tot = total_pages(layout, num_rows)
        for page in (0, 1, 7, 8, 9, num_rows - 1, num_rows,
                     tot - 1):
            if page >= tot:
                continue
            from repro.core.layouts import place_page
            pl = place_page(layout, num_rows, page)
            if pl.kind == "rows":
                cells = {(pl.row0, lane) for lane in range(8)}
            elif pl.kind == "codelane":
                cells = {(pl.row0 + k, 8) for k in range(8)}
            else:
                cells = {(row, lane) for lane, row in pl.slices}
            for c in cells:
                assert c not in claimed, (layout, page, c, claimed[c])
                claimed[c] = page


# -- pool roundtrips -------------------------------------------------------------


@pytest.mark.parametrize("layout", ALL_LAYOUTS)
def test_pool_roundtrip_mixed_regions(layout):
    pool = P.make_pool(64, layout, boundary=32)
    pages = {}
    for pid in [0, 31, 32, 63, pool.num_rows, pool.num_pages - 1]:
        d = rand_page(pool.page_words)
        pages[pid] = d
        pool = P.write_page(pool, pid, d)
    for pid, d in pages.items():
        got, status = P.read_page(pool, pid)
        assert (got == d).all() and int(status) == 0


def test_pool_secded_corrects_and_parity_detects():
    pool = P.make_pool(16, Layout.INTERWRAP, boundary=8)
    d = rand_page(pool.page_words)
    pool = P.write_page(pool, 12, d)
    stor, _ = injection.inject_flips(pool.storage, RNG, 1, row_range=(12, 13),
                                     lanes=tuple(range(8)))
    got, status = P.read_page(
        dataclasses.replace(pool, storage=stor), 12)
    assert (got == d).all() and int(status) in (1, 2)

    pp = P.make_pool(16, Layout.PARITY)
    d2 = rand_page(pp.page_words)
    pp = P.write_page(pp, 3, d2)
    arr = np.asarray(pp.storage).copy()
    arr[3, 2, 50] ^= 1 << 3
    got, status = P.read_page(dataclasses.replace(
        pp, storage=jnp.asarray(arr)), 3)
    assert int(status) == 3


@given(st.integers(0, 10**9))
@settings(max_examples=15, deadline=None)
def test_repartition_preserves_contents(seed):
    rng = np.random.default_rng(seed)
    pool = P.make_pool(32, Layout.INTERWRAP, boundary=16)
    keep = {}
    for pid in [0, 5, 18, 31]:
        d = jnp.asarray(rng.integers(0, 2**32, size=(pool.page_words,),
                                     dtype=np.uint32))
        keep[pid] = d
        pool = P.write_page(pool, pid, d)
    grown, info = P.repartition(pool, 32)
    assert grown.num_pages == 36
    shrunk, info2 = P.repartition(grown, 8)
    assert len(info2["evicted_extra_pages"]) == 3
    for st_ in (grown, shrunk):
        for pid, d in keep.items():
            got, status = P.read_page(st_, pid)
            assert (got == d).all() and int(status) == 0


def test_batched_matches_scalar_path():
    pool = P.make_pool(64, Layout.INTERWRAP)
    idx = jnp.asarray([0, 7, 8, 63, 64, 71], jnp.int32)
    data = jnp.asarray(RNG.integers(0, 2**32, size=(6, pool.page_words),
                                    dtype=np.uint32))
    pool = P.write_pages_batch(pool, idx, data)
    got = P.read_pages_batch(pool, idx)
    assert (got == data).all()
    for i, pid in enumerate(idx.tolist()):
        one, _ = P.read_page(pool, pid)
        assert (one == got[i]).all()


@given(st.lists(st.integers(0, 2**32 - 1), min_size=16, max_size=16))
@settings(max_examples=50, deadline=None)
def test_parity_detects_any_single_flip(words):
    data = jnp.asarray(np.asarray(words, np.uint32))[None, :]
    par = parity8.encode_lines(data)
    w = int(RNG.integers(0, 16))
    b = int(RNG.integers(0, 32))
    arr = np.asarray(data).copy()
    arr[0, w] ^= np.uint32(1 << b)
    assert int(parity8.check_lines(jnp.asarray(arr), par)[0, 0]) == 1

"""End-to-end behaviour: training loop, fault tolerance, serving, adaptation.

These are the paper's claims as executable assertions:
  * capacity: CREAM pools expose +12.5% (correction-free) / +10.7% (parity);
  * reliability: injected single-bit flips are repaired (SECDED) or detected
    (parity) end-to-end through trainer scrub and checkpoint restore;
  * adaptation: the monitor upgrades sick regions and downgrades healthy
    ones, moving real capacity;
  * serving: CREAM mode serves the same workload with fewer host fetches.
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import injection
from repro.core.layouts import Layout
from repro.core.monitor import MonitorConfig
from repro.core.pool import make_pool
from repro.core.protection import Protection, RegionSpec
from repro.core.regions import RegionManager

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                   num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                   head_dim=16, dtype="float32")


def test_capacity_claims():
    cream = make_pool(64, Layout.INTERWRAP)
    secded = make_pool(64, Layout.INTERWRAP, boundary=0)
    parity = make_pool(1024, Layout.PARITY)   # gain quantises in small pools
    assert cream.num_pages == 72 and secded.num_pages == 64
    assert abs(cream.capacity_gain() - 0.125) < 1e-9
    assert abs(parity.capacity_gain() - 0.107) < 0.005


@pytest.fixture(scope="module")
def trained():
    from repro.train.trainer import make_trainer
    tmp = tempfile.mkdtemp()
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=5, total_steps=60,
                       scrub_every=10, checkpoint_every=10, microbatch=2)
    tr = make_trainer(TINY, tcfg, ckpt_dir=tmp, seq_len=64, global_batch=8)
    log = tr.run(22)
    return tr, log, tmp


def test_training_learns(trained):
    _, log, _ = trained
    assert log[-1]["loss"] < log[0]["loss"]


def test_checkpoint_restart_resumes_exactly(trained):
    from repro.train.trainer import make_trainer
    tr, _, tmp = trained
    tcfg = tr.tcfg
    tr2 = make_trainer(TINY, tcfg, ckpt_dir=tmp, seq_len=64, global_batch=8)
    assert tr2.restore()
    assert tr2.step == 20
    # deterministic data => the next batch is identical to the original run
    b1 = tr.data.batch(20)
    b2 = tr2.data.batch(20)
    assert (np.asarray(b1["tokens"]) == np.asarray(b2["tokens"])).all()


def test_scrub_repairs_moment_pool_and_warm_restore(trained):
    tr, _, _ = trained
    rng = np.random.default_rng(3)
    before = {"m": tr.opt_state.m, "v": tr.opt_state.v}
    tr.snapshot_moments()
    stor, recs = injection.inject_flips(tr.moment_pool.storage, rng, 9)
    tr.moment_pool = dataclasses.replace(tr.moment_pool, storage=stor)
    s = tr.scrub_pools()
    assert s["corrected"] == 9 and s["uncorrectable"] == 0
    worst = tr.warm_restore()
    assert worst == 0
    for a, b in zip(jax.tree.leaves(before),
                    jax.tree.leaves({"m": tr.opt_state.m,
                                     "v": tr.opt_state.v})):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_checkpoint_detects_and_corrects_disk_corruption(trained):
    import glob
    import os

    tr, _, tmp = trained
    step = tr.checkpointer.latest_step()
    # flip one bit in one shard on disk
    shard = sorted(glob.glob(os.path.join(
        tr.checkpointer.step_dir(step), "*.npz")))[0]
    z = dict(np.load(shard))
    z["data"] = z["data"].copy()
    z["data"][len(z["data"]) // 2] ^= np.uint32(1 << 9)
    np.savez(shard, **z)
    tree, report = tr.checkpointer.restore(step, like=tr._ckpt_tree())
    assert len(report.corrected_leaves) == 1
    assert not report.corrupt_leaves


def test_adaptive_region_manager_moves_capacity():
    mgr = RegionManager(MonitorConfig(window=2, upgrade_threshold=1e-7,
                                      downgrade_threshold=1e-9,
                                      downgrade_patience=2))
    mgr.add_region(RegionSpec.make("kv", Protection.SECDED, 32,
                                   min_protection=Protection.NONE))
    mgr.add_region(RegionSpec.make("wt", Protection.PARITY, 32,
                                   min_protection=Protection.PARITY))
    before = mgr.total_capacity_pages()
    for _ in range(3):
        mgr.scrub_all()
    trans = mgr.adapt()
    assert ("kv", Protection.SECDED, Protection.PARITY) in trans
    assert mgr.total_capacity_pages() > before
    # sicken 'wt' -> upgrade to SECDED
    rng = np.random.default_rng(0)
    r = mgr.regions["wt"]
    stor, _ = injection.inject_flips(r.pool.storage, rng, 200)
    r.pool = dataclasses.replace(r.pool, storage=stor)
    mgr.scrub_all()
    trans = mgr.adapt()
    assert ("wt", Protection.PARITY, Protection.SECDED) in trans


def test_serving_cream_vs_secded_capacity():
    from benchmarks.bench_serving import run
    r = run(num_rows=32, n_turns=12)
    assert r["cream"]["device_pages"] > r["secded"]["device_pages"]
    # +12.5% device pages => no more host round-trips than the baseline
    assert r["cream"]["restores"] <= r["secded"]["restores"]
    assert r["cream"]["tokens"] == r["secded"]["tokens"]


def test_grad_compression_roundtrip():
    from repro.optim.adamw import compress_int8, decompress_int8
    g = jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)),
                    jnp.float32)
    q, s = compress_int8(g)
    err = jnp.abs(decompress_int8(q, s) - g).max() / jnp.abs(g).max()
    assert float(err) < 1.0 / 127 + 1e-6

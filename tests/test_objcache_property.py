"""Property test: the hash index and cache against a Python-dict oracle.

Random insert/get/delete/evict-pressure sequences across the three
reliability classes, with a repartition (protection upgrade) forced
mid-sequence — after which every key the oracle knows must still be
readable bit-for-bit (the zero-loss acceptance criterion), and absent keys
must still miss.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.layouts import Layout
from repro.core.protection import Protection
from repro.objcache import ObjCache, hash_index as hix
from repro.vm import MigrationEngine, VirtualMemory

ROW_WORDS = 32
KEYS = list(range(1, 13))          # small keyspace; capacity never binds
CLASSES = [Protection.NONE, Protection.PARITY, Protection.SECDED]


# ---------------------------------------------------------------------------
# Index-only state machine (pure jnp, fast)
# ---------------------------------------------------------------------------

_index_op = st.one_of(
    st.tuples(st.just("insert"),
              st.lists(st.sampled_from(KEYS), min_size=1, max_size=4,
                       unique=True)),
    st.tuples(st.just("delete"),
              st.lists(st.sampled_from(KEYS), min_size=1, max_size=3,
                       unique=True)),
    st.tuples(st.just("lookup"),
              st.lists(st.sampled_from(KEYS + [999]), min_size=1,
                       max_size=4)),
)


@settings(max_examples=25, deadline=None)
@given(st.lists(_index_op, min_size=1, max_size=12))
def test_hash_index_matches_dict(ops):
    index = hix.make_index(32, probe=8)
    oracle: dict[int, tuple[int, int, int]] = {}
    serial = 0
    for op, keys in ops:
        q = jnp.asarray(keys, jnp.uint32)
        if op == "insert":
            n = len(keys)
            meta = [(serial + i, (serial + i) % 7, 1 + (serial + i) % 5)
                    for i in range(n)]
            serial += n
            pages = jnp.asarray([m[0] for m in meta], jnp.int32)
            offs = jnp.asarray([m[1] for m in meta], jnp.int32)
            lens = jnp.asarray([m[2] for m in meta], jnp.int32)
            index, _, ok = hix.insert(index, q, pages, offs, lens)
            assert np.asarray(ok).all()
            for k, m in zip(keys, meta):
                oracle[k] = m
        elif op == "delete":
            index, found = hix.delete(index, q)
            for k, f in zip(keys, np.asarray(found)):
                assert bool(f) == (k in oracle)
                oracle.pop(k, None)
        else:
            page, off, length, _, found = hix.lookup(index, q)
            for j, k in enumerate(keys):
                assert bool(np.asarray(found)[j]) == (k in oracle)
                if k in oracle:
                    assert (int(np.asarray(page)[j]),
                            int(np.asarray(off)[j]),
                            int(np.asarray(length)[j])) == oracle[k]


# ---------------------------------------------------------------------------
# Full-cache state machine (data plane + classes + repartition)
# ---------------------------------------------------------------------------

_cache_op = st.one_of(
    st.tuples(st.just("set"),
              st.lists(st.sampled_from(KEYS), min_size=1, max_size=3,
                       unique=True),
              st.sampled_from(range(len(CLASSES)))),
    st.tuples(st.just("get"),
              st.lists(st.sampled_from(KEYS + [777]), min_size=1,
                       max_size=4),
              st.just(0)),
    st.tuples(st.just("delete"),
              st.lists(st.sampled_from(KEYS), min_size=1, max_size=2,
                       unique=True),
              st.just(0)),
)


def _value(key: int, version: int, span: int) -> np.ndarray:
    return (np.uint32(key * 1000 + version)
            * np.arange(1, span + 1, dtype=np.uint32))


def _check_against_oracle(cache, oracle, keys):
    got, lens, found = cache.get_many(keys)
    for j, k in enumerate(keys):
        assert bool(found[j]) == (k in oracle), f"membership wrong for {k}"
        if k in oracle:
            version, span = oracle[k]
            assert int(lens[j]) == span
            np.testing.assert_array_equal(got[j, :span],
                                          _value(k, version, span))
            assert (got[j, span:] == 0).all()


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(st.lists(_cache_op, min_size=2, max_size=8),
       st.integers(0, 2**31 - 1))
def test_cache_matches_dict_across_repartition(ops, seed):
    rng = np.random.default_rng(seed)
    vm = VirtualMemory(row_words=ROW_WORDS)
    # mixed pool: every reliability class is placeable before AND after the
    # upgrade (over-protection is always allowed)
    vm.add_pool("dimm", 24, Layout.INTERWRAP, boundary=16)
    cache = ObjCache(vm, "dimm", index_capacity=64, probe=8)
    engine = MigrationEngine(vm)
    oracle: dict[int, tuple[int, int]] = {}
    version = 0
    spans = [ROW_WORDS, 2 * ROW_WORDS, 8 * ROW_WORDS]
    mid = max(1, len(ops) // 2)
    for step, (op, keys, relidx) in enumerate(ops):
        if step == mid:
            # protection upgrade mid-sequence: zero loss required
            engine.repartition_with_migration("dimm", 0)
            cache.refresh_translation()
            _check_against_oracle(cache, oracle, list(oracle) or [777])
        if op == "set":
            version += 1
            span = spans[int(rng.integers(len(spans)))]
            vals = np.stack([_value(k, version, span) for k in keys])
            stored = cache.set_many(keys, vals,
                                    reliability=CLASSES[relidx])
            assert stored.all()              # capacity never binds here
            for k in keys:
                oracle[k] = (version, span)
        elif op == "delete":
            found = cache.delete_many(keys)
            for k, f in zip(keys, found):
                assert bool(f) == (k in oracle)
                oracle.pop(k, None)
        else:
            _check_against_oracle(cache, oracle, keys)
    _check_against_oracle(cache, oracle, KEYS + [777])

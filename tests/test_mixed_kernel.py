"""Fused mixed-pool read: Pallas kernel vs. jnp oracle vs. per-page reads.

Runs in interpret mode on CPU; the kernel must match the oracle bit-exactly
for every layout and boundary, including SECDED correction fused into the
gather.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pool as P
from repro.core.layouts import Layout
from repro.kernels.mixed import kernel, ops, ref

RNG = np.random.default_rng(23)
ROW_WORDS = 64
ALL_LAYOUTS = [Layout.PACKED, Layout.RANK_SUBSET, Layout.INTERWRAP,
               Layout.PARITY]


def _filled_pool(layout, boundary):
    pool = P.make_pool(16, layout, boundary=boundary, row_words=ROW_WORDS)
    for page in range(pool.num_pages):
        pool = P.write_page(pool, page, jnp.asarray(
            RNG.integers(0, 2**32, pool.page_words, dtype=np.uint32)))
    return pool


@pytest.mark.parametrize("layout", ALL_LAYOUTS)
@pytest.mark.parametrize("boundary", [0, 8, 16])
def test_kernel_matches_ref_all_modes(layout, boundary):
    pool = _filled_pool(layout, boundary)
    ids = jnp.asarray(list(RNG.permutation(pool.num_pages)[:7]), jnp.int32)
    d_ref = ref.read_correct(pool.storage, ids, layout, pool.num_rows,
                             boundary)
    d_ker = kernel.read_correct(pool.storage, ids, layout, pool.num_rows,
                                boundary)
    np.testing.assert_array_equal(np.asarray(d_ref), np.asarray(d_ker))


def test_kernel_matches_page_reads_mixed_ids():
    pool = _filled_pool(Layout.INTERWRAP, 8)
    ids = [0, 7, 8, 15, pool.num_pages - 1]      # CREAM, SECDED, extra
    data = kernel.read_correct(pool.storage, jnp.asarray(ids, jnp.int32),
                               Layout.INTERWRAP, pool.num_rows, 8)
    for j, page in enumerate(ids):
        expect, _ = P.read_page(pool, page)
        np.testing.assert_array_equal(np.asarray(data[j]), np.asarray(expect))


def test_kernel_corrects_secded_flip_in_fused_pass():
    pool = _filled_pool(Layout.INTERWRAP, 8)
    clean, _ = P.read_page(pool, 12)
    arr = np.asarray(pool.storage).copy()
    arr[12, 4, 20] ^= np.uint32(1 << 11)         # data-lane flip, SECDED row
    flipped = dataclasses.replace(pool, storage=jnp.asarray(arr))
    out = kernel.read_correct(flipped.storage, jnp.asarray([12, 0], jnp.int32),
                              Layout.INTERWRAP, pool.num_rows, 8)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(clean))


def test_kernel_leaves_unprotected_pages_raw():
    """A flip in a CREAM page must pass through undisturbed (no protection)."""
    pool = _filled_pool(Layout.INTERWRAP, 8)
    arr = np.asarray(pool.storage).copy()
    arr[1, 1, 0] ^= np.uint32(1)                 # inside the CREAM span
    flipped = jnp.asarray(arr)
    d_ref = ref.read_correct(flipped, jnp.asarray([0, 1, 2], jnp.int32),
                             Layout.INTERWRAP, pool.num_rows, 8)
    d_ker = kernel.read_correct(flipped, jnp.asarray([0, 1, 2], jnp.int32),
                                Layout.INTERWRAP, pool.num_rows, 8)
    np.testing.assert_array_equal(np.asarray(d_ref), np.asarray(d_ker))


def test_ops_dispatch_agrees_with_engine():
    pool = _filled_pool(Layout.PARITY, 8)
    ids = jnp.asarray([0, 9, 15], jnp.int32)
    via_ops = ops.read_pool(pool, ids)                   # auto dispatch
    via_engine = P.read_pages_any(pool, ids)
    np.testing.assert_array_equal(np.asarray(via_ops), np.asarray(via_engine))

"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

RNG = np.random.default_rng(2)


@pytest.mark.parametrize("n,d", [(8, 512), (16, 1024), (48, 2048), (64, 4096)])
def test_secded_kernel_sweep(n, d):
    from repro.kernels.secded import kernel, ref
    data = jnp.asarray(RNG.integers(0, 2**32, size=(n, d), dtype=np.uint32))
    ck, cr = kernel.encode(data), ref.encode(data)
    assert (ck == cr).all()
    arr = np.asarray(data).copy()
    arr[n // 2, d // 3] ^= 1 << 11
    d2 = jnp.asarray(arr)
    for a, b in zip(kernel.decode(d2, ck), ref.decode(d2, cr)):
        assert (a == b).all()
    fixed, _, status = kernel.decode(d2, ck)
    assert (fixed == data).all() and int(status.sum()) == 1


@pytest.mark.parametrize("n,d", [(8, 1024), (32, 2048)])
def test_parity_kernel_sweep(n, d):
    from repro.kernels.parity8 import kernel, ref
    data = jnp.asarray(RNG.integers(0, 2**32, size=(n, d), dtype=np.uint32))
    assert (kernel.encode(data) == ref.encode(data)).all()
    par = kernel.encode(data)
    assert (kernel.check(data, par) == ref.check(data, par)).all()
    assert int(kernel.check(data, par).sum()) == 0


@pytest.mark.parametrize("rows,W", [(16, 128), (64, 256), (32, 512)])
def test_interwrap_kernel_sweep(rows, W):
    from repro.kernels.interwrap import kernel, ref
    storage = jnp.asarray(RNG.integers(0, 2**32, size=(rows, 9, W),
                                       dtype=np.uint32))
    extra = rows // 8
    pages = jnp.asarray([0, 7, 8, rows - 1, rows, rows + extra - 1],
                        jnp.int32)
    gk = kernel.gather(storage, pages, rows)
    gr = ref.gather(storage, pages, rows)
    assert (gk == gr).all()
    data = jnp.asarray(RNG.integers(0, 2**32, size=(len(pages), 8 * W),
                                    dtype=np.uint32))
    sk = kernel.scatter(storage.copy(), pages, data, rows)
    sr = ref.scatter(storage, pages, data, rows)
    assert (sk == sr).all()


@pytest.mark.parametrize("rows", [16, 48])
def test_scrub_kernel_sweep(rows):
    from repro.core import secded
    from repro.core.injection import inject_flips
    from repro.kernels.scrub import kernel, ref
    storage = jnp.asarray(RNG.integers(0, 2**32, size=(rows, 9, 256),
                                       dtype=np.uint32))
    data = storage[:, :8, :].reshape(rows, -1)
    storage = storage.at[:, 8, :].set(secded.encode_block(data))
    storage, recs = inject_flips(storage, RNG, 7)
    outk, outr = kernel.scrub_rows(storage), ref.scrub_rows(storage)
    assert (outk[0] == outr[0]).all() and (outk[1] == outr[1]).all()
    # scrubbing the scrubbed pool is a fixpoint
    again, status = kernel.scrub_rows(outk[0])
    assert (status == 0).all() and (again == outk[0]).all()


@pytest.mark.parametrize("m,k,n", [(64, 128, 64), (128, 256, 128),
                                   (256, 512, 128)])
def test_ecc_matmul_sweep(m, k, n):
    from repro.kernels.ecc_matmul import kernel, ref
    a = jnp.asarray(RNG.standard_normal((m, k)), jnp.bfloat16)
    b = jnp.asarray(RNG.standard_normal((k, n)), jnp.bfloat16)
    bits, codes = ref.protect(a)
    assert (ref.unprotect(bits) == a).all()
    arr = np.asarray(bits).copy()
    arr[m // 3, k // 8] ^= 1 << 21     # corrupt a weight bit
    bits2 = jnp.asarray(arr)
    yk = kernel.ecc_matmul(bits2, codes, b)
    yr = ref.ecc_matmul(bits2, codes, b)
    y_truth = jnp.dot(a, b, preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(y_truth),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,hq,hkv,s,d,dtype", [
    (2, 4, 2, 128, 64, jnp.float32),
    (1, 8, 1, 256, 32, jnp.float32),
    (1, 2, 2, 64, 128, jnp.float32),
    (2, 4, 4, 128, 64, jnp.bfloat16),
])
def test_flash_attention_sweep(b, hq, hkv, s, d, dtype):
    from repro.kernels.flash_attention import kernel, ref
    q = jnp.asarray(RNG.standard_normal((b, hq, s, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), dtype)
    for causal in (True, False):
        yk = kernel.attention(q, k, v, causal=causal)
        yr = ref.attention(q, k, v, causal=causal)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(yk, np.float32),
                                   np.asarray(yr, np.float32),
                                   rtol=tol, atol=tol)

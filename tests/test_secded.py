"""SECDED(72,64) code properties: exhaustive single-bit, random double-bit."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import secded as s

RNG = np.random.default_rng(0)


@pytest.fixture(scope="module")
def beats():
    lo = jnp.asarray(RNG.integers(0, 2**32, size=(512,), dtype=np.uint32))
    hi = jnp.asarray(RNG.integers(0, 2**32, size=(512,), dtype=np.uint32))
    return lo, hi, s.encode_words(lo, hi)


def test_clean_decode(beats):
    lo, hi, code = beats
    lo2, hi2, c2, st_ = s.decode_words(lo, hi, code)
    assert (st_ == s.CLEAN).all()
    assert (lo2 == lo).all() and (hi2 == hi).all() and (c2 == code).all()


@pytest.mark.parametrize("bit", list(range(72)))
def test_single_bit_corrected_exhaustive(beats, bit):
    lo, hi, code = beats
    l, h, c = lo, hi, code
    if bit < 32:
        l = l ^ jnp.uint32(1 << bit)
    elif bit < 64:
        h = h ^ jnp.uint32(1 << (bit - 32))
    else:
        c = c ^ jnp.uint32(1 << (bit - 64))
    l2, h2, c2, st_ = s.decode_words(l, h, c)
    expected = s.CORRECTED_CODE if bit >= 64 else s.CORRECTED_DATA
    assert (st_ == expected).all()
    assert (l2 == lo).all() and (h2 == hi).all() and (c2 == code).all()


@given(st.integers(0, 71), st.integers(0, 71), st.integers(0, 2**64 - 1))
@settings(max_examples=200, deadline=None)
def test_double_bit_always_detected(b1, b2, data):
    """Hsiao guarantee: any 2-bit error is detected, never miscorrected."""
    if b1 == b2:
        return
    lo = jnp.uint32(data & 0xFFFFFFFF)[None]
    hi = jnp.uint32(data >> 32)[None]
    code = s.encode_words(lo, hi)
    l, h, c = lo, hi, code
    for bit in (b1, b2):
        if bit < 32:
            l = l ^ jnp.uint32(1 << bit)
        elif bit < 64:
            h = h ^ jnp.uint32(1 << (bit - 32))
        else:
            c = c ^ jnp.uint32(1 << (bit - 64))
    _, _, _, st_ = s.decode_words(l, h, c)
    assert int(st_[0]) == s.DETECTED_UNCORRECTABLE


@given(st.lists(st.integers(0, 2**32 - 1), min_size=8, max_size=8))
@settings(max_examples=100, deadline=None)
def test_block_roundtrip(words):
    data = jnp.asarray(np.asarray(words, np.uint32))[None, :]
    codes = s.encode_block(data)
    d2, c2, st_ = s.decode_block(data, codes)
    assert (st_ == 0).all() and (d2 == data).all()


def test_pack_unpack_inverse():
    codes = jnp.asarray(RNG.integers(0, 256, size=(4, 64), dtype=np.uint32))
    assert (s.unpack_codes(s.pack_codes(codes)) == codes).all()


def test_hsiao_columns_odd_weight_distinct():
    cols = np.asarray(s._COLUMNS)
    assert len(set(cols.tolist())) == 64
    assert all(bin(int(c)).count("1") % 2 == 1 for c in cols)

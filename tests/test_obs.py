"""CREAM-Scope telemetry plane: registry, tracing, SLOs, engine wiring."""
import dataclasses
import json
import time

import numpy as np
import pytest

from repro.core import secded
from repro.core.injection import inject_flips
from repro.core.layouts import Layout
from repro.core.monitor import ErrorMonitor, MonitorConfig
from repro.core.pool import make_pool
from repro.core.scrubber import scrub
from repro.obs import dashboard, metrics, slo, tracing


@pytest.fixture(autouse=True)
def _clean_telemetry():
    """Every test starts and ends with the global plane off and empty."""
    metrics.disable()
    metrics.REGISTRY.clear()
    tracing.disable()
    tracing.reset()
    slo.TRACKER.reset()
    yield
    metrics.disable()
    metrics.REGISTRY.clear()
    tracing.disable()
    tracing.reset()
    slo.TRACKER.reset()


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_counter_labels_are_distinct_series(self):
        metrics.enable()
        c = metrics.counter("t_reads", "reads", labels=("pool", "cls"))
        c.labels(pool="kv", cls="secded").inc()
        c.labels(pool="kv", cls="none").inc(3)
        assert metrics.REGISTRY.value("t_reads", pool="kv",
                                      cls="secded") == 1
        assert metrics.REGISTRY.value("t_reads", pool="kv", cls="none") == 3

    def test_disabled_registry_records_nothing(self):
        c = metrics.counter("t_off", "off")
        c.inc(5)
        assert metrics.REGISTRY.value("t_off") == 0.0

    def test_label_mismatch_raises(self):
        metrics.enable()
        c = metrics.counter("t_lbl", "x", labels=("a",))
        with pytest.raises(ValueError):
            c.labels(b="1")

    def test_redeclare_with_other_kind_raises(self):
        metrics.counter("t_kind", "x")
        with pytest.raises(ValueError):
            metrics.gauge("t_kind", "x")

    def test_counter_never_decreases(self):
        metrics.enable()
        with pytest.raises(ValueError):
            metrics.counter("t_neg", "x").inc(-1)

    def test_reset_zeroes_but_keeps_series(self):
        metrics.enable()
        c = metrics.counter("t_rst", "x", labels=("k",))
        c.labels(k="a").inc(7)
        metrics.reset()
        assert metrics.REGISTRY.value("t_rst", k="a") == 0.0
        # the series (and registration) survive: snapshot still exposes it
        assert 't_rst{k="a"} 0' in metrics.snapshot()

    def test_histogram_buckets_and_exposition(self):
        metrics.enable()
        h = metrics.histogram("t_lat", "us", buckets=(10.0, 100.0,
                                                      float("inf")))
        for v in (5.0, 50.0, 500.0):
            h.observe(v)
        snap = metrics.snapshot()
        assert 't_lat_bucket{le="10"} 1' in snap
        assert 't_lat_bucket{le="100"} 2' in snap
        assert 't_lat_bucket{le="+Inf"} 3' in snap
        assert "t_lat_count 3" in snap

    def test_collect_roundtrips_through_json(self):
        metrics.enable()
        metrics.counter("t_json", "x", labels=("k",)).labels(k="v").inc()
        snap = json.loads(json.dumps(metrics.collect()))
        assert snap["t_json"]["series"][0] == {"labels": {"k": "v"},
                                               "value": 1.0}

    def test_fold_read_status(self):
        metrics.enable()
        metrics.touch_read_status()
        # shape derives from the Protection ladder — never a literal
        counts = np.zeros((len(metrics.FOLD_CLASSES), 2), np.int32)
        counts[metrics.FOLD_CLASSES.index("secded"), 0] = 4
        counts[metrics.FOLD_CLASSES.index("none"), 1] = 2
        metrics.fold_read_status(counts)
        assert metrics.REGISTRY.value(metrics.NAME_READ_STATUS,
                                      cls="secded", status="corrected") == 4
        assert metrics.REGISTRY.value(metrics.NAME_READ_STATUS, cls="none",
                                      status="uncorrectable") == 2
        # touched-but-untouched series exist at zero (snapshot completeness)
        assert metrics.REGISTRY.value(metrics.NAME_READ_STATUS,
                                      cls="parity", status="corrected") == 0


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------


class TestTracing:
    def test_span_nesting_depth_recorded(self):
        tracing.enable()
        with tracing.span("outer"):
            with tracing.span("inner"):
                pass
        ev = {e["name"]: e for e in tracing.TRACER.events}
        assert ev["inner"]["args"]["depth"] == 1
        assert ev["outer"]["args"]["depth"] == 0
        # containment: outer starts before and ends after inner
        assert ev["outer"]["ts"] <= ev["inner"]["ts"]
        assert (ev["outer"]["ts"] + ev["outer"]["dur"]
                >= ev["inner"]["ts"] + ev["inner"]["dur"])

    def test_perfetto_schema(self):
        tracing.enable()
        with tracing.span("a", pages=3):
            pass
        tracing.instant("marker", x=1)
        d = json.loads(tracing.TRACER.to_json())
        assert d["displayTimeUnit"] == "ms"
        assert isinstance(d["traceEvents"], list)
        for e in d["traceEvents"]:
            assert {"name", "ph", "ts", "pid", "tid", "cat"} <= set(e)
            assert e["ph"] in ("X", "i")
            if e["ph"] == "X":
                assert e["dur"] >= 0

    def test_disabled_span_is_shared_null(self):
        assert tracing.span("x") is tracing.span("y")
        with tracing.span("x"):
            pass
        assert tracing.TRACER.events == []

    def test_blocked_span_records_duration(self):
        tracing.enable()
        with tracing.blocked_span("b") as hold:
            hold(np.arange(4))
        assert tracing.TRACER.span_names() == {"b"}

    def test_export(self, tmp_path):
        tracing.enable()
        with tracing.span("e"):
            pass
        p = tmp_path / "trace.json"
        tracing.export(str(p))
        assert json.loads(p.read_text())["traceEvents"]


# ---------------------------------------------------------------------------
# SLO tracking + scrub/monitor feed
# ---------------------------------------------------------------------------


class TestSLO:
    def test_secded_uncorrectable_breaches(self):
        slo.TRACKER.record_read_status("secded", uncorrectable=1)
        breached = slo.TRACKER.breached()
        assert [s.scope for s in breached] == ["class/secded"]

    def test_batch_tier_errors_tolerated(self):
        slo.TRACKER.record_read_status("none", uncorrectable=10)
        assert slo.TRACKER.breached() == []

    def test_injected_uncorrectable_reaches_slo_via_scrub(self):
        """A multi-bit SECDED error seen by scrub must go red on the
        dashboard — the reliability contract's enforcement path."""
        import jax.numpy as jnp
        state = make_pool(16, Layout.INTERWRAP, boundary=8, row_words=16)
        # two flips in the same beat of a SECDED row -> uncorrectable
        storage = np.asarray(state.storage).copy()
        storage[12, 0, 0] ^= 0b11     # two bit flips, one word
        state = dataclasses.replace(state, storage=jnp.asarray(storage))
        mon = ErrorMonitor()
        new_state, stats = scrub(state)
        mon.record("kv", stats)
        assert stats.detected_uncorrectable >= 1
        breaches = [s for s in slo.TRACKER.report()
                    if s.scope == "region/kv"]
        assert breaches and breaches[0].detail.startswith("sweeps=1")
        # rendering never crashes and shows the census
        out = dashboard.render()
        assert "region/kv" in out

    def test_capacity_slo_rides_boundary(self):
        state = make_pool(16, Layout.INTERWRAP, boundary=16, row_words=16)
        slo.TRACKER.record_capacity("kv", state, min_gain=0.12)
        ok = [s for s in slo.TRACKER.report() if s.scope == "pool/kv"]
        assert ok[0].ok and ok[0].value == pytest.approx(0.125)
        slo.TRACKER.set_capacity_target("kv", 0.5)
        assert [s.scope for s in slo.TRACKER.breached()] == ["pool/kv"]

    def test_corrected_errors_do_not_breach_secded(self):
        slo.TRACKER.record_read_status("secded", corrected=100)
        assert slo.TRACKER.breached() == []


class TestMonitor:
    def test_window_larger_than_64_is_not_truncated(self):
        """Regression: RegionHealth used a fixed deque(maxlen=64), silently
        truncating estimates for MonitorConfig.window > 64."""
        from repro.core.scrubber import ScrubStats
        mon = ErrorMonitor(MonitorConfig(window=128))
        # 64 clean sweeps after 64 noisy ones: with the fixed maxlen the
        # noisy half would have been evicted and the rate would read 0
        noisy = ScrubStats(beats_checked=100, corrected_data=10)
        clean = ScrubStats(beats_checked=100)
        for _ in range(64):
            mon.record("r", noisy)
        for _ in range(64):
            mon.record("r", clean)
        assert mon.rate("r") == pytest.approx(0.05)
        assert len(mon._health["r"].rates) == 128

    def test_scrub_feed_emits_metrics(self):
        from repro.core.scrubber import ScrubStats
        metrics.enable()
        mon = ErrorMonitor()
        mon.record("kv", ScrubStats(beats_checked=10, corrected_data=2,
                                    detected_uncorrectable=1))
        assert metrics.REGISTRY.value(metrics.NAME_SCRUB_SWEEPS,
                                      region="kv") == 1
        assert metrics.REGISTRY.value(metrics.NAME_SCRUB_CORRECTED,
                                      region="kv", kind="data") == 2
        assert metrics.REGISTRY.value(metrics.NAME_SCRUB_UNCORRECTABLE,
                                      region="kv") == 1


# ---------------------------------------------------------------------------
# scrub span + pool capacity gauges
# ---------------------------------------------------------------------------


def test_scrub_emits_span():
    tracing.enable()
    state = make_pool(16, Layout.INTERWRAP, boundary=8, row_words=16)
    scrub(state)
    assert "scrub.sweep" in tracing.TRACER.span_names()


def test_record_pool_capacity_gauges():
    metrics.enable()
    state = make_pool(16, Layout.INTERWRAP, boundary=8, row_words=16)
    metrics.record_pool_capacity("kv", state)
    assert metrics.REGISTRY.value(metrics.NAME_CAPACITY_PAGES, pool="kv",
                                  cls="secded") == 8
    assert metrics.REGISTRY.value(metrics.NAME_CAPACITY_PAGES, pool="kv",
                                  cls="none") == 8 + state.num_extra_pages
    assert metrics.REGISTRY.value(metrics.NAME_CAPACITY_RECLAIMED,
                                  pool="kv") == state.num_extra_pages


# ---------------------------------------------------------------------------
# engine wiring (span presence + read-status fold + overhead guard)
# ---------------------------------------------------------------------------


def _tiny_engine(**kw):
    from benchmarks.bench_serving import CFG
    from repro.serve.engine import Engine
    return Engine(CFG, max_batch=2, max_len=24, num_rows=32, row_words=64,
                  secded_rows=8, **kw)


def _tiny_requests(n=2, max_new=3):
    from repro.serve.engine import Request
    return [Request(f"s{i}", list(range(1, 7)), max_new,
                    tier="paid" if i % 2 else "batch") for i in range(n)]


class TestEngineWiring:
    def test_profile_run_has_phase_spans_and_status_series(self):
        metrics.enable()
        tracing.enable()
        eng = _tiny_engine()
        eng.serve(_tiny_requests())
        names = tracing.TRACER.span_names()
        assert {"engine.step.gather", "engine.step.compute",
                "engine.step.scatter", "serve.router.dispatch"} <= names
        snap = metrics.collect()
        rs = {(r["labels"]["cls"], r["labels"]["status"])
              for r in snap[metrics.NAME_READ_STATUS]["series"]}
        assert rs == {(c, s) for c in metrics.FOLD_CLASSES
                      for s in ("corrected", "uncorrectable")}
        assert metrics.REGISTRY.value(metrics.NAME_DECODE_STEPS) > 0
        assert metrics.REGISTRY.value(metrics.NAME_TOKENS_DECODED,
                                      tier="paid") > 0
        # capacity gauges ride along (acceptance: reclaimed per class)
        assert metrics.NAME_CAPACITY_RECLAIMED in snap

    def test_injected_secded_error_counted_and_corrected(self):
        metrics.enable()
        eng = _tiny_engine()
        import jax.numpy as jnp
        pool = eng.pool
        rng = np.random.default_rng(3)
        storage, _ = inject_flips(pool.storage, rng, n_flips=2,
                                  row_range=(pool.boundary, pool.num_rows))
        eng.vm.pools[eng.pool_name] = dataclasses.replace(
            pool, storage=jnp.asarray(storage))
        eng.serve(_tiny_requests(n=2, max_new=8))
        corrected = metrics.REGISTRY.value(metrics.NAME_READ_STATUS,
                                           cls="secded", status="corrected")
        unc = metrics.REGISTRY.value(metrics.NAME_READ_STATUS, cls="secded",
                                     status="uncorrectable")
        # the decode path saw and repaired (or at least detected) the flips
        assert corrected + unc >= 0   # series exist; value depends on
        # whether a served page hosts the flip — the strong assertion:
        snap = metrics.snapshot()
        assert 'cream_read_status_total{cls="secded",status="corrected"}' \
            in snap

    @pytest.mark.slow
    def test_metrics_overhead_within_5_percent(self):
        """The tentpole's overhead guard: Engine.step with metrics enabled
        stays within 5% (plus a tiny absolute slack) of disabled."""
        def run_steps(enable: bool, rounds=4):
            metrics.REGISTRY.clear()
            metrics.enable(enable)
            eng = _tiny_engine()
            eng.serve(_tiny_requests(n=2, max_new=4))   # warm compile
            ts = []
            for _ in range(rounds):
                for r in _tiny_requests(n=2, max_new=16):
                    eng.submit(r)
                while eng.sched.has_work():
                    t0 = time.perf_counter()
                    eng.poll()
                    ts.append(time.perf_counter() - t0)
            metrics.disable()
            return float(np.median(ts))

        # interleave the pairs so clock-speed drift hits both sides
        # equally; min-of-N approaches each side's true floor
        base, inst = [], []
        for _ in range(4):
            base.append(run_steps(False))
            inst.append(run_steps(True))
        b, i = min(base), min(inst)
        assert i <= b * 1.05 + 3e-4, \
            f"metrics overhead {i / b - 1:.1%} (base {b * 1e6:.0f}us)"


# ---------------------------------------------------------------------------
# dashboard rendering
# ---------------------------------------------------------------------------


def test_dashboard_renders_from_snapshot_dict():
    metrics.enable()
    metrics.touch_read_status()
    metrics.counter(metrics.NAME_TOKENS_DECODED, "t",
                    labels=("tier",)).labels(tier="paid").inc(5)
    out = dashboard.render(snap=metrics.collect(), statuses=[])
    assert "METRICS" in out and "cream_tokens_decoded_total" in out

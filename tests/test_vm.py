"""CREAM-VM: page tables, reliability classes, live migration, policy loop."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.layouts import Layout
from repro.core.monitor import MonitorConfig
from repro.core.protection import Protection
from repro.vm import MigrationEngine, VirtualMemory, VMPolicy
from repro.vm.policy import PoolPolicy

RNG = np.random.default_rng(7)
ROW_WORDS = 64


def make_vm(**pools):
    vm = VirtualMemory(row_words=ROW_WORDS)
    for name, (rows, layout, boundary) in pools.items():
        vm.add_pool(name, rows, layout, boundary=boundary)
    return vm


def blob(n, pw):
    return jnp.asarray(RNG.integers(0, 2**32, (n, pw), dtype=np.uint32))


# ---------------------------------------------------------------------------
# Allocation & reliability classes
# ---------------------------------------------------------------------------


def test_alloc_respects_reliability_classes():
    vm = make_vm(p0=(16, Layout.INTERWRAP, 8))   # 8 CREAM + 8 SECDED + 1 extra
    vm.create_tenant("a", default_reliability=Protection.SECDED)
    vm.create_tenant("b", default_reliability=Protection.NONE)
    sec = vm.alloc("a", 3)
    assert all(vm.effective_protection("a", v) == Protection.SECDED
               for v in sec)
    bulk = vm.alloc("b", 3)
    # bulk lands on CREAM frames first (exact class before stronger)
    assert all(vm.effective_protection("b", v) == Protection.NONE
               for v in bulk)


def test_alloc_falls_back_to_stronger_class_then_host():
    vm = make_vm(p0=(16, Layout.INTERWRAP, 8))
    vm.create_tenant("b", default_reliability=Protection.NONE)
    # 8 CREAM + 1 extra = 9 NONE frames, then 8 SECDED, then host
    vpns = vm.alloc("b", 19)
    classes = [vm.effective_protection("b", v) for v in vpns]
    assert classes.count(Protection.NONE) == 9
    assert classes.count(Protection.SECDED) == 8
    assert classes.count(None) == 2              # host swap tier
    assert vm.residency("b", vpns) == "mixed"


def test_alloc_never_underprotects():
    vm = make_vm(p0=(16, Layout.INTERWRAP, None))   # whole-CREAM: no SECDED
    vm.create_tenant("a", default_reliability=Protection.SECDED)
    vpns = vm.alloc("a", 2)                      # only host can honour SECDED
    assert all(vm.translate("a", v).pool is None for v in vpns)
    assert vm.alloc("a", 1, allow_host=False) is None


def test_rejected_alloc_leaks_no_frames():
    vm = make_vm(p0=(16, Layout.INTERWRAP, 0))
    vm.create_tenant("a", default_reliability=Protection.SECDED)
    free_before = sum(len(l) for l in vm.allocators["p0"].free.values())
    assert vm.alloc("a", 17, allow_host=False) is None
    assert sum(len(l) for l in vm.allocators["p0"].free.values()) == free_before


# ---------------------------------------------------------------------------
# Data plane
# ---------------------------------------------------------------------------


def test_read_write_roundtrip_across_pools_and_host():
    vm = make_vm(p0=(16, Layout.INTERWRAP, 8),
                 p1=(8, Layout.INTERWRAP, 0))
    vm.create_tenant("t", default_reliability=Protection.NONE)
    vpns = vm.alloc("t", 30)                     # spans both pools + host
    data = blob(30, vm.page_words)
    vm.write("t", vpns, data)
    assert (vm.read("t", vpns) == data).all()
    assert vm.stats.host_reads > 0               # host tier was exercised


def test_freed_frames_never_leak_across_tenants():
    vm = make_vm(p0=(16, Layout.INTERWRAP, 8))
    vm.create_tenant("a", default_reliability=Protection.NONE)
    vm.create_tenant("b", default_reliability=Protection.NONE)
    va = vm.alloc("a", 4, allow_host=False)
    vm.write("a", va, jnp.full((4, vm.page_words), 0xDEADBEEF, jnp.uint32))
    vm.free("a", va)
    vb = vm.alloc("b", 4, allow_host=False)   # reuses a's frames
    assert not np.asarray(vm.read("b", vb)).any()   # zeroed, not a's bits


def test_batch_access_rejects_out_of_range_pages():
    from repro.core import pool as pool_lib
    state = pool_lib.make_pool(16, Layout.INTERWRAP, row_words=ROW_WORDS)
    with pytest.raises(ValueError, match="out of range"):
        pool_lib.read_pages_any(state, [99])
    with pytest.raises(ValueError, match="out of range"):
        pool_lib.write_pages_any(
            state, [99], jnp.zeros((1, state.page_words), jnp.uint32))
    # empty batches are no-ops, not crashes
    assert pool_lib.read_pages_any(state, []).shape == (0, state.page_words)
    assert pool_lib.write_pages_any(
        state, [], jnp.zeros((0, state.page_words), jnp.uint32)) is state


def test_free_returns_frames():
    vm = make_vm(p0=(16, Layout.INTERWRAP, 8))
    vm.create_tenant("t", default_reliability=Protection.NONE)
    vpns = vm.alloc("t", 9, allow_host=False)
    assert vm.used_device_pages() == 9
    vm.free("t", vpns)
    assert vm.used_device_pages() == 0
    assert vm.alloc("t", 9, allow_host=False) is not None


def test_swap_out_and_in_preserves_contents():
    vm = make_vm(p0=(16, Layout.INTERWRAP, 8))
    vm.create_tenant("t", default_reliability=Protection.NONE)
    vpns = vm.alloc("t", 4, allow_host=False)
    data = blob(4, vm.page_words)
    vm.write("t", vpns, data)
    assert vm.swap_out("t", vpns) == 4
    assert vm.residency("t", vpns) == "host"
    assert (vm.read("t", vpns) == data).all()
    assert vm.swap_in("t", vpns) == 4
    assert vm.residency("t", vpns) == "device"
    assert (vm.read("t", vpns) == data).all()


# ---------------------------------------------------------------------------
# Live migration
# ---------------------------------------------------------------------------


def test_relocate_moves_pages_off_a_pool():
    vm = make_vm(src=(16, Layout.INTERWRAP, None),
                 dst=(16, Layout.INTERWRAP, 0))
    vm.create_tenant("t", default_reliability=Protection.NONE)
    vpns = vm.alloc("t", vm.pools["src"].num_pages, allow_host=False)
    data = blob(len(vpns), vm.page_words)
    vm.write("t", vpns, data)
    eng = MigrationEngine(vm)
    assert eng.relocate("t", vpns, avoid_pool="src") == len(vpns)
    assert vm.used_device_pages("src") == 0
    assert (vm.read("t", vpns) == data).all()
    # 18 pages into 16 SECDED frames: 2 overflowed to the host tier
    assert eng.stats.to_host == 2


def test_upgrade_migrates_instead_of_evicting():
    vm = make_vm(p0=(32, Layout.INTERWRAP, None))   # 36 pages, 4 extras
    vm.create_tenant("t", default_reliability=Protection.NONE)
    vpns = vm.alloc("t", 36, allow_host=False)
    data = blob(36, vm.page_words)
    vm.write("t", vpns, data)
    eng = MigrationEngine(vm)
    info = eng.repartition_with_migration("p0", 0)
    assert info["migrated"] == 4                 # the doomed extra pages
    assert (vm.read("t", vpns) == data).all()    # zero lost pages
    assert vm.pools["p0"].boundary == 0


def test_downgrade_relocates_strict_tenants():
    vm = make_vm(p0=(16, Layout.INTERWRAP, 0),
                 p1=(8, Layout.INTERWRAP, 0))
    vm.create_tenant("a", default_reliability=Protection.SECDED)
    vm.create_tenant("b", default_reliability=Protection.NONE)
    sa = vm.alloc("a", 4, allow_host=False)
    sb = vm.alloc("b", 4, allow_host=False)
    da, db = blob(4, vm.page_words), blob(4, vm.page_words)
    vm.write("a", sa, da)
    vm.write("b", sb, db)
    eng = MigrationEngine(vm)
    info = eng.repartition_with_migration("p0", 16)   # p0 -> whole-CREAM
    # only the SECDED-contracted pages that lived on p0 had to move
    assert info["migrated"] == sum(
        1 for v in sa if vm.translate("a", v).pool != "p0")
    for v in sa:    # contract still honoured: SECDED or host
        assert vm.effective_protection("a", v) in (Protection.SECDED, None)
    assert (vm.read("a", sa) == da).all()
    assert (vm.read("b", sb) == db).all()
    # capacity was reclaimed: p0 now exposes extra pages
    assert vm.pools["p0"].num_extra_pages == 2


def test_rebuild_refuses_to_lose_mapped_frames():
    from repro.core import pool as pool_lib
    vm = make_vm(p0=(32, Layout.INTERWRAP, None))
    vm.create_tenant("t", default_reliability=Protection.NONE)
    vm.alloc("t", 36, allow_host=False)          # extras are mapped
    new_state, _ = pool_lib.repartition(vm.pools["p0"], 0)
    with pytest.raises(RuntimeError, match="relocate them before"):
        vm.allocators["p0"].rebuild(new_state)


# ---------------------------------------------------------------------------
# End-to-end acceptance: two tenants, monitor-driven upgrade, zero loss
# ---------------------------------------------------------------------------


def test_multitenant_monitor_driven_upgrade_zero_loss():
    rng = np.random.default_rng(3)
    vm = make_vm(p0=(32, Layout.INTERWRAP, 16),   # mixed pool, 2 extras
                 spare=(16, Layout.INTERWRAP, 0))
    vm.create_tenant("secure", default_reliability=Protection.SECDED)
    vm.create_tenant("bulk", default_reliability=Protection.NONE)
    eng = MigrationEngine(vm, use_kernel=True)
    policy = VMPolicy(vm, eng, MonitorConfig(window=2, upgrade_threshold=1e-9),
                      pool_policies={"spare": PoolPolicy(
                          floor=Protection.SECDED)})

    sec = vm.alloc("secure", 6, allow_host=False)
    bulk = vm.alloc("bulk", 18, allow_host=False)   # all 16 CREAM + 2 extras
    dsec, dbulk = blob(6, vm.page_words), blob(18, vm.page_words)
    vm.write("secure", sec, dsec)
    vm.write("bulk", bulk, dbulk)
    assert any(vm.translate("bulk", v).phys >= 32 for v in bulk)  # extras used

    # healthy epoch: no transition
    stats, performed = policy.step()
    assert performed == []

    # inject uncorrectable damage into an *unmapped* SECDED row (a weakening
    # DIMM region) -> the monitor upgrades the whole pool
    storage = vm.pools["p0"].storage
    storage = storage.at[30, 0, 0].set(storage[30, 0, 0] ^ jnp.uint32(0b11))
    vm.pools["p0"] = dataclasses.replace(vm.pools["p0"], storage=storage)
    snapshot = np.asarray(vm.read("bulk", bulk))   # pre-upgrade contents
    stats, performed = policy.step()
    assert len(performed) == 1 and performed[0]["pool"] == "p0"
    assert vm.pools["p0"].boundary == 0            # fully SECDED now

    # zero lost pages: every mapped page survived the repartition+migration
    assert (np.asarray(vm.read("bulk", bulk)) == snapshot).all()
    assert (np.asarray(vm.read("secure", sec)) == np.asarray(dsec)).all()
    assert eng.stats.pages_moved >= 2              # the two mapped extras
    # bulk pages now enjoy >= their contracted protection (or host tier)
    for v in bulk:
        assert vm.effective_protection("bulk", v) in (
            Protection.SECDED, Protection.NONE, None)


def test_policy_downgrade_reclaims_capacity_when_quiet():
    vm = make_vm(p0=(16, Layout.INTERWRAP, 0))
    vm.create_tenant("t", default_reliability=Protection.NONE)
    policy = VMPolicy(vm, MigrationEngine(vm),
                      MonitorConfig(window=2, downgrade_patience=2))
    pages_before = vm.device_capacity_pages()
    for _ in range(3):
        _, performed = policy.step()
    assert vm.pools["p0"].boundary == 16           # downgraded to CREAM
    assert vm.device_capacity_pages() > pages_before   # +12.5% reclaimed


# ---------------------------------------------------------------------------
# Serving integration
# ---------------------------------------------------------------------------


def test_sequence_cache_allocates_through_vm():
    from repro.serve.kv_cache import SequenceCache
    cache = SequenceCache(num_rows=16, mode="cream", row_words=ROW_WORDS)
    blobs = {}
    for i in range(10):
        sid = f"s{i}"
        blobs[sid] = RNG.integers(0, 256, size=2500, dtype=np.uint8)
        cache.park(sid, blobs[sid])
    for sid, b in blobs.items():
        assert (cache.resume(sid) == b).all()
    assert cache.vm.used_device_pages() > 0
    assert cache.device_capacity_pages == 18       # 16 rows + 2 extras


def test_sequence_cache_survives_pool_upgrade():
    from repro.serve.kv_cache import SequenceCache
    cache = SequenceCache(num_rows=16, mode="cream", row_words=ROW_WORDS)
    blobs = {}
    for i in range(9):
        sid = f"s{i}"
        blobs[sid] = RNG.integers(0, 256, size=2000, dtype=np.uint8)
        cache.park(sid, blobs[sid])
    eng = MigrationEngine(cache.vm)
    eng.repartition_with_migration(SequenceCache.POOL, 0)   # upgrade
    for sid, b in blobs.items():
        assert (cache.resume(sid) == b).all()      # nothing lost


def test_sequence_cache_resume_many_batches_tiers():
    """One engine dispatch resumes a device+host mix; unknowns miss cleanly."""
    from repro.serve.kv_cache import SequenceCache
    cache = SequenceCache(num_rows=16, mode="cream", row_words=ROW_WORDS)
    blobs = {}
    for i in range(6):
        sid = f"s{i}"
        blobs[sid] = RNG.integers(0, 256, size=2500, dtype=np.uint8)
        cache.park(sid, blobs[sid])
    for i in range(14):                     # overflow -> LRU demotions to host
        sid = f"x{i}"
        blobs[sid] = RNG.integers(0, 256, size=2500, dtype=np.uint8)
        cache.park(sid, blobs[sid])
    got = cache.resume_many(list(blobs) + ["unknown"])
    assert got["unknown"] is None and cache.stats.misses == 1
    for sid, b in blobs.items():
        assert got[sid] is not None and (got[sid] == b).all()
    assert cache.stats.host_hits > 0        # the batch really spanned tiers
    assert (cache.resume("s0") == blobs["s0"]).all()   # singles still agree

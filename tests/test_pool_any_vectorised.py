"""Mixed-pool access engine vs. the per-page oracle (property-style).

The vectorised ``read_pages_any`` / ``write_pages_any`` / batched
``repartition`` must agree *bit-exactly* with the per-page
``read_page`` / ``write_page`` reference across all four layouts, any
boundary, and any page-id mix (CREAM regular / SECDED / extra) — and must
trace with dynamic page-id arrays.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import injection
from repro.core import pool as P
from repro.core.layouts import GROUP_ROWS, Layout, extra_page_count

RNG = np.random.default_rng(3)
ROW_WORDS = 64
ALL_LAYOUTS = [Layout.PACKED, Layout.RANK_SUBSET, Layout.INTERWRAP,
               Layout.PARITY]
BOUNDARIES = [0, GROUP_ROWS, 16, 32]


def rand_pages(n, pw):
    return jnp.asarray(RNG.integers(0, 2**32, (n, pw), dtype=np.uint32))


def mixed_ids(pool, n=12):
    """A shuffled id sample covering CREAM, SECDED, and extra pages."""
    ids = list(RNG.permutation(pool.num_pages)[:n])
    for anchor in (0, pool.boundary, pool.num_rows - 1, pool.num_pages - 1):
        if 0 <= anchor < pool.num_pages and anchor not in ids:
            ids.append(anchor)
    return [int(i) for i in ids]


@pytest.mark.parametrize("layout", ALL_LAYOUTS)
@pytest.mark.parametrize("boundary", BOUNDARIES)
def test_write_any_read_page_roundtrip(layout, boundary):
    pool = P.make_pool(32, layout, boundary=boundary, row_words=ROW_WORDS)
    ids = mixed_ids(pool)
    data = rand_pages(len(ids), pool.page_words)
    pool = P.write_pages_any(pool, ids, data)
    for j, pid in enumerate(ids):
        got, status = P.read_page(pool, pid)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(data[j]))
        assert int(status) == 0


@pytest.mark.parametrize("layout", ALL_LAYOUTS)
@pytest.mark.parametrize("boundary", BOUNDARIES)
def test_write_page_read_any_roundtrip(layout, boundary):
    pool = P.make_pool(32, layout, boundary=boundary, row_words=ROW_WORDS)
    ids = mixed_ids(pool)
    data = rand_pages(len(ids), pool.page_words)
    for j, pid in enumerate(ids):
        pool = P.write_page(pool, pid, data[j])
    got, status = P.read_pages_any_status(pool, ids)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(data))
    assert not np.asarray(status).any()


@pytest.mark.parametrize("layout", ALL_LAYOUTS)
def test_engine_is_jittable_with_traced_ids(layout):
    """read/write_pages_any must trace with *dynamic* page-id arrays."""
    pool = P.make_pool(32, layout, boundary=16, row_words=ROW_WORDS)
    ids = jnp.asarray(mixed_ids(pool, 8), jnp.int32)
    data = rand_pages(ids.shape[0], pool.page_words)

    write = jax.jit(P.write_pages_any)
    read = jax.jit(P.read_pages_any)
    pool = write(pool, ids, data)
    np.testing.assert_array_equal(np.asarray(read(pool, ids)),
                                  np.asarray(data))
    # same trace serves a different id vector of the same length
    ids2 = jnp.flip(ids)
    got = read(pool, ids2)
    for j, pid in enumerate(ids2.tolist()):
        exp, _ = P.read_page(pool, pid)
        np.testing.assert_array_equal(np.asarray(got[j]), np.asarray(exp))


def test_engine_status_flags_secded_and_parity_errors():
    pool = P.make_pool(16, Layout.INTERWRAP, boundary=8, row_words=ROW_WORDS)
    d = rand_pages(1, pool.page_words)[0]
    pool = P.write_page(pool, 12, d)
    stor, _ = injection.inject_flips(pool.storage, RNG, 1, row_range=(12, 13),
                                     lanes=tuple(range(8)))
    got, status = P.read_pages_any_status(
        dataclasses.replace(pool, storage=stor), [12, 0])
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(d))
    assert int(status[0]) in (1, 2) and int(status[1]) == 0

    pp = P.make_pool(16, Layout.PARITY, row_words=ROW_WORDS)
    d2 = rand_pages(1, pp.page_words)[0]
    pp = P.write_page(pp, 3, d2)
    arr = np.asarray(pp.storage).copy()
    arr[3, 2, 5] ^= np.uint32(1 << 3)
    _, status = P.read_pages_any_status(
        dataclasses.replace(pp, storage=jnp.asarray(arr)), [3, 4])
    assert int(status[0]) == 3 and int(status[1]) == 0


@pytest.mark.parametrize("layout", ALL_LAYOUTS)
def test_batched_repartition_matches_per_page_oracle(layout):
    """Boundary moves re-encode exactly like the per-page reference would.

    Regular pages survive both directions under every layout; surviving
    extra pages do too — PARITY extras are re-homed above the resized
    parity tables, the other layouts' extras never move.
    """
    pool = P.make_pool(32, layout, boundary=16, row_words=ROW_WORDS)
    pids = [0, 5, 15, 16, 30, 31]
    if pool.num_pages > 32:
        pids.append(32)
    keep = {}
    for pid in pids:
        d = rand_pages(1, pool.page_words)[0]
        keep[pid] = d
        pool = P.write_page(pool, pid, d)
    grown, info = P.repartition(pool, 32)
    assert info["pages_reencoded"] == 16
    for pid, d in keep.items():
        got, status = P.read_page(grown, pid)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(d))
        assert int(status) == 0
    shrunk, info2 = P.repartition(grown, 8)
    assert info2["pages_reencoded"] == 24
    lim = 32 + extra_page_count(layout, 8, ROW_WORDS)
    for pid, d in keep.items():
        if pid >= lim:
            continue
        got, status = P.read_page(shrunk, pid)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(d))
        assert int(status) == 0


def test_parity_extra_pages_survive_boundary_moves_mapped_in_vm():
    """A mapped PARITY extra page keeps its contents across a downgrade
    (its storage is re-homed above the grown parity tables) and is
    live-migrated on the upgrade that dooms it — zero loss either way."""
    from repro.core.protection import Protection
    from repro.vm import MigrationEngine, VirtualMemory
    vm = VirtualMemory(row_words=ROW_WORDS)
    vm.add_pool("p", 32, Layout.PARITY, boundary=16)
    vm.create_tenant("t", default_reliability=Protection.NONE)
    vpns = vm.alloc("t", vm.pools["p"].num_pages, allow_host=False)
    data = rand_pages(len(vpns), vm.page_words)
    vm.write("t", vpns, data)
    eng = MigrationEngine(vm)
    eng.repartition_with_migration("p", 32)          # downgrade: tables grow
    np.testing.assert_array_equal(np.asarray(vm.read("t", vpns)),
                                  np.asarray(data))
    info = eng.repartition_with_migration("p", 8)    # upgrade: extras doomed
    assert info["migrated"] >= 1
    np.testing.assert_array_equal(np.asarray(vm.read("t", vpns)),
                                  np.asarray(data))


def test_batch_status_contract_shapes():
    """Both read_pages_batch_status branches return ((n, pw), (n,)) int32."""
    for layout, boundary in [(Layout.INTERWRAP, None), (Layout.INTERWRAP, 0)]:
        pool = P.make_pool(16, layout, boundary=boundary, row_words=ROW_WORDS)
        ids = jnp.asarray([0, 3, 9], jnp.int32)
        data, status = P.read_pages_batch_status(pool, ids)
        assert data.shape == (3, pool.page_words) and data.dtype == jnp.uint32
        assert status.shape == (3,) and status.dtype == jnp.int32


def test_migrate_pages_single_dispatch():
    src = P.make_pool(16, Layout.INTERWRAP, row_words=ROW_WORDS)
    dst = P.make_pool(16, Layout.INTERWRAP, boundary=0, row_words=ROW_WORDS)
    ids = jnp.asarray([0, 9, 17], jnp.int32)   # includes an extra page
    data = rand_pages(3, src.page_words)
    src = P.write_pages_any(src, ids, data)
    dst_ids = jnp.asarray([2, 3, 4], jnp.int32)
    dst = P.migrate_pages(src, ids, dst, dst_ids)
    got, status = P.read_pages_any_status(dst, dst_ids)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(data))
    assert not np.asarray(status).any()


# -- hypothesis property sweep (optional dep, heavier => slow marker) --------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @pytest.mark.slow
    @given(st.integers(0, 10**9),
           st.sampled_from(ALL_LAYOUTS),
           st.integers(0, 4).map(lambda g: g * GROUP_ROWS))
    @settings(max_examples=12, deadline=None)
    def test_any_engine_agrees_with_oracle_property(seed, layout, boundary):
        rng = np.random.default_rng(seed)
        pool = P.make_pool(32, layout, boundary=boundary, row_words=ROW_WORDS)
        n = int(rng.integers(1, 10))
        ids = [int(p) for p in rng.integers(0, pool.num_pages, n)]
        ids = list(dict.fromkeys(ids))             # dedup, keep order
        data = jnp.asarray(rng.integers(0, 2**32, (len(ids), pool.page_words),
                                        dtype=np.uint32))
        batched = P.write_pages_any(pool, ids, data)
        looped = pool
        for j, pid in enumerate(ids):
            looped = P.write_page(looped, pid, data[j])
        np.testing.assert_array_equal(np.asarray(batched.storage),
                                      np.asarray(looped.storage))
        got = P.read_pages_any(batched, ids)
        for j, pid in enumerate(ids):
            exp, _ = P.read_page(batched, pid)
            np.testing.assert_array_equal(np.asarray(got[j]), np.asarray(exp))

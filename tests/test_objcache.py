"""CREAM-Cache acceptance: batched hot path, reliability classes, the
capacity bridge (demotion growth / zero-loss upgrade migration)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layouts import Layout
from repro.core.protection import Protection
from repro.objcache import ObjCache
from repro.objcache.cache import _get_batch
from repro.vm import MigrationEngine, VirtualMemory
from repro.vm.address_space import frame_class
from repro.vm.policy import VMPolicy
from repro.core.monitor import MonitorConfig

ROW_WORDS = 32


def value_for(keys, span):
    keys = np.asarray(keys, np.uint32)
    return keys[:, None] * np.arange(1, span + 1, dtype=np.uint32)


def make_cache(rows=16, layout=Layout.INTERWRAP, boundary=8, **kw):
    vm = VirtualMemory(row_words=ROW_WORDS)
    vm.add_pool("dimm", rows, layout, boundary=boundary)
    cache = ObjCache(vm, "dimm", index_capacity=128, probe=8, **kw)
    return vm, cache


# ---------------------------------------------------------------------------
# Batched get/set hot path
# ---------------------------------------------------------------------------


def test_set_get_roundtrip_and_misses():
    _, cache = make_cache()
    pw = cache.vm.page_words
    keys = np.arange(1, 6)
    assert cache.set_many(keys, value_for(keys, pw)).all()
    got, lens, found = cache.get_many([3, 1, 99, 5])
    assert found.tolist() == [True, True, False, True]
    np.testing.assert_array_equal(got[0], value_for([3], pw)[0])
    np.testing.assert_array_equal(got[3], value_for([5], pw)[0])
    assert (got[2] == 0).all() and lens[2] == 0
    assert cache.stats.hits == 3 and cache.stats.misses == 1


def test_variable_value_lengths_share_pages():
    """Sub-page values land in chunks; several share one pool page."""
    _, cache = make_cache()
    pw = cache.vm.page_words
    span = pw // 8
    keys = np.arange(10, 26)                 # 16 eighth-page values
    assert cache.set_many(keys, value_for(keys, span)).all()
    assert cache.capacity_report()["pages_claimed"] <= 2
    got, lens, found = cache.get_many(keys)
    assert found.all() and (lens == span).all()
    np.testing.assert_array_equal(got[:, :span], value_for(keys, span))
    assert (got[:, span:] == 0).all()


def test_update_overwrites_and_delete():
    _, cache = make_cache()
    pw = cache.vm.page_words
    cache.set_many([7], value_for([7], pw))
    cache.set_many([7], value_for([777], pw))
    np.testing.assert_array_equal(cache.get_many([7])[0][0],
                                  value_for([777], pw)[0])
    assert cache.stats.updates == 1
    assert cache.delete_many([7, 8]).tolist() == [True, False]
    assert not cache.get_many([7])[2][0]


def test_duplicate_keys_in_batch_last_wins():
    _, cache = make_cache()
    pw = cache.vm.page_words
    keys = np.asarray([5, 9, 5])
    vals = np.stack([value_for([1], pw)[0], value_for([2], pw)[0],
                     value_for([3], pw)[0]])
    assert cache.set_many(keys, vals).all()
    np.testing.assert_array_equal(cache.get_many([5])[0][0],
                                  value_for([3], pw)[0])


def test_get_set_trace_with_dynamic_key_batches():
    """The jitted hot path is traced once per shape, not per key batch."""
    _, cache = make_cache()
    pw = cache.vm.page_words
    keys = np.arange(1, 9)
    cache.set_many(keys, value_for(keys, pw))
    with jax.checking_leaks():
        for batch in ([1, 2, 3, 4], [8, 7, 99, 1]):
            got, _, found = cache.get_many(batch)
            for i, k in enumerate(batch):
                if found[i]:
                    np.testing.assert_array_equal(got[i],
                                                  value_for([k], pw)[0])
    # the underlying engine traces with abstract key arrays
    jax.eval_shape(lambda q: _get_batch(cache.pool, cache.index, q,
                                        cache.max_value_words, None),
                   jax.ShapeDtypeStruct((4,), jnp.uint32))


# ---------------------------------------------------------------------------
# Reliability classes
# ---------------------------------------------------------------------------


def test_reliability_classes_map_to_frame_classes():
    vm, cache = make_cache()
    pw = cache.vm.page_words
    assert cache.set_many([1], value_for([1], pw),
                          reliability=Protection.SECDED).all()
    assert cache.set_many([2], value_for([2], pw),
                          reliability=Protection.NONE).all()
    for key, want in ((1, Protection.SECDED), (2, Protection.NONE)):
        slot = int(np.asarray(
            jax.device_get(_get_batch(cache.pool, cache.index,
                                      jnp.asarray([key], jnp.uint32),
                                      pw, None)[2]))[0])
        pte = vm.tenants[cache.tenant].entries[int(cache._vpn[slot])]
        assert frame_class(vm.pools[pte.pool], pte.phys) == want


def test_secded_items_rejected_when_no_secded_frames():
    _, cache = make_cache(boundary=16)       # whole pool correction-free
    pw = cache.vm.page_words
    stored = cache.set_many([1], value_for([1], pw),
                            reliability=Protection.SECDED)
    assert not stored.any()
    assert cache.stats.rejected == 1


def test_flip_in_secded_item_corrected_on_get():
    vm, cache = make_cache()
    pw = cache.vm.page_words
    assert cache.set_many([42], value_for([42], pw),
                          reliability=Protection.SECDED).all()
    state = vm.pools["dimm"]
    slot = int(np.flatnonzero(cache._live)[0])
    pte = vm.tenants[cache.tenant].entries[int(cache._vpn[slot])]
    arr = np.asarray(state.storage).copy()
    arr[pte.phys, 2, 5] ^= np.uint32(1 << 13)
    vm.pools["dimm"] = dataclasses.replace(state, storage=jnp.asarray(arr))
    got, _, found = cache.get_many([42])
    assert found[0]
    np.testing.assert_array_equal(got[0], value_for([42], pw)[0])


# ---------------------------------------------------------------------------
# Eviction / 2Q
# ---------------------------------------------------------------------------


def test_eviction_under_pressure_prefers_cold_probation():
    _, cache = make_cache()
    pw = cache.vm.page_words
    first = np.arange(1, 9)
    cache.set_many(first, value_for(first, pw))
    cache.get_many(first[:4])                # promote 1..4 to the main queue
    over = np.arange(100, 130)
    stored = cache.set_many(over, value_for(over, pw))
    assert cache.stats.evictions > 0 and stored.any()
    # the promoted hot items outlive the cold probation ones
    hot_alive = cache.get_many(first[:4])[2]
    cold_alive = cache.get_many(first[4:])[2]
    assert hot_alive.sum() >= cold_alive.sum()


def test_oversized_batch_admits_what_fits():
    _, cache = make_cache()
    pw = cache.vm.page_words
    cap = cache.vm.device_capacity_pages()
    huge = np.arange(1000, 1000 + 3 * cap)
    stored = cache.set_many(huge, value_for(huge, pw))
    assert 0 < stored.sum() <= cap
    got, _, found = cache.get_many(huge[stored][:4])
    assert found.all()


# ---------------------------------------------------------------------------
# The capacity bridge
# ---------------------------------------------------------------------------


def _fill(cache, lo, hi):
    keys = np.arange(lo, hi)
    stored = cache.set_many(keys, value_for(keys, cache.vm.page_words))
    return keys[stored]


def test_demotion_grows_capacity_online():
    vm, cache = make_cache(boundary=0)       # all-SECDED start
    pw = cache.vm.page_words
    kept = _fill(cache, 1, 100)              # fill to the brim
    assert len(kept) == 16                   # baseline capacity
    ev0 = cache.stats.evictions
    MigrationEngine(vm).repartition_with_migration("dimm", 16)
    cache.refresh_translation()
    assert vm.device_capacity_pages() == 18  # +2 reclaimed extra pages
    more = np.arange(200, 202)
    assert cache.set_many(more, value_for(more, pw)).all()
    # the reclaimed extra pages absorbed the new values: no eviction needed
    assert cache.stats.evictions == ev0
    got, _, found = cache.get_many(np.concatenate([kept, more]))
    assert found.all()


def test_upgrade_migration_loses_zero_values():
    """Acceptance: every key readable before the boundary move is readable
    after, bit-for-bit — including values bumped to the host swap tier."""
    vm, cache = make_cache(boundary=16)      # whole pool correction-free
    pw = cache.vm.page_words
    kept = _fill(cache, 1, 60)
    before = {int(k): cache.get_many([int(k)])[0][0].copy() for k in kept}
    info = MigrationEngine(vm).repartition_with_migration("dimm", 0)
    assert info["migrated"] > 0
    cache.refresh_translation()
    got, lens, found = cache.get_many(kept)
    assert found.all(), "cached values lost in protection upgrade"
    for i, k in enumerate(kept):
        np.testing.assert_array_equal(got[i], before[int(k)])
    assert cache.stats.host_hits > 0         # some rode the patch path


def test_policy_driven_upgrade_keeps_cache_intact():
    """The scrub->monitor->adapt loop upgrades the pool; the cache follows."""
    vm, cache = make_cache(boundary=8)       # mixed pool: scrub sees SECDED
    kept = _fill(cache, 1, 40)
    policy = VMPolicy(vm, MigrationEngine(vm),
                      MonitorConfig(window=1, upgrade_threshold=1e-9))
    # an uncorrectable pattern in a SECDED row trips the monitor
    state = vm.pools["dimm"]
    arr = np.asarray(state.storage).copy()
    arr[12, 1, 2] ^= np.uint32(0b11)
    vm.pools["dimm"] = dataclasses.replace(state, storage=jnp.asarray(arr))
    policy.step()
    assert vm.pools["dimm"].boundary == 0    # upgraded to full SECDED
    cache.refresh_translation()
    got, _, found = cache.get_many(kept)
    assert found.all()

"""The VM stack runs unchanged on a sharded pool (PoolLike acceptance).

`vm/address_space.py`, `vm/migration.py`, `vm/policy.py`,
`objcache/cache.py` and `serve/kv_cache.py` were written against the
`PoolLike` surface; these tests run their existing flows with the backing
pool sharded over a `banks` mesh and assert nothing observable changes:
allocation, data plane, zero-loss repartition+migration, the object cache,
and sequence parking.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.layouts import Layout  # noqa: E402
from repro.core.protection import Protection  # noqa: E402
from repro.shard import ShardedPool  # noqa: E402
from repro.vm import MigrationEngine, VirtualMemory, VMPolicy  # noqa: E402

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4+ devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8; the repo conftest sets it)")

ROW_WORDS = 32


def _vm(shards=4, rows=128, boundary=64, layout=Layout.INTERWRAP):
    vm = VirtualMemory(row_words=ROW_WORDS)
    state = vm.add_pool("main", rows, layout, boundary=boundary,
                        shards=shards)
    assert isinstance(state, ShardedPool)
    return vm


def test_vm_alloc_write_read_free_on_sharded_pool():
    vm = _vm()
    rng = np.random.default_rng(0)
    t = vm.create_tenant("t", default_reliability=Protection.NONE)
    vpns = vm.alloc("t", 24)
    blob = rng.integers(0, 2**32, (24, vm.page_words), dtype=np.uint32)
    vm.write("t", vpns, blob)
    np.testing.assert_array_equal(np.asarray(vm.read("t", vpns)), blob)
    # frames really live on the sharded pool
    assert all(t.entries[v].pool == "main" for v in vpns)
    vm.free("t", vpns)
    assert vm.used_device_pages() == 0


def test_vm_swap_roundtrip_on_sharded_pool():
    vm = _vm()
    rng = np.random.default_rng(1)
    vm.create_tenant("t")
    vpns = vm.alloc("t", 8)
    blob = rng.integers(0, 2**32, (8, vm.page_words), dtype=np.uint32)
    vm.write("t", vpns, blob)
    assert vm.swap_out("t", vpns) == 8
    assert vm.residency("t", vpns) == "host"
    np.testing.assert_array_equal(np.asarray(vm.read("t", vpns)), blob)
    assert vm.swap_in("t", vpns) == 8
    assert vm.residency("t", vpns) == "device"
    np.testing.assert_array_equal(np.asarray(vm.read("t", vpns)), blob)


def test_repartition_with_migration_zero_loss_on_sharded_pool():
    vm = _vm(shards=4, rows=128, boundary=128)
    rng = np.random.default_rng(2)
    engine = MigrationEngine(vm)
    vm.create_tenant("bulk", default_reliability=Protection.NONE)
    state = vm.pools["main"]
    # map every page (incl. all extras), then upgrade protection fully:
    # every extra page is doomed and must be relocated, not dropped
    vpns = vm.alloc("bulk", state.num_pages)
    blob = rng.integers(0, 2**32, (len(vpns), vm.page_words), dtype=np.uint32)
    vm.write("bulk", vpns, blob)
    info = engine.repartition_with_migration("main", 0)
    assert info["migrated"] == state.num_extra_pages
    assert vm.pools["main"].boundary == 0
    np.testing.assert_array_equal(np.asarray(vm.read("bulk", vpns)), blob)

    # boundary steps must respect the shard lockstep granularity
    with pytest.raises(ValueError):
        engine.repartition_with_migration("main", 8)   # < 4 shards * 8 rows


def test_policy_scrub_and_adapt_on_sharded_pool():
    vm = _vm(shards=4, rows=128, boundary=128)
    policy = VMPolicy(vm)
    stats = policy.scrub_all()
    assert stats["main"].error_rate == 0.0
    # force an upgrade recommendation by recording a hot error census
    from repro.core.scrubber import ScrubStats
    for _ in range(4):
        policy.monitor.record("main", ScrubStats(
            beats_checked=1000, corrected_data=50))
    infos = policy.adapt()
    assert infos and vm.pools["main"].boundary == 0


def test_objcache_on_sharded_pool():
    from repro.objcache.cache import ObjCache
    vm = _vm(shards=4, rows=128, boundary=128)
    cache = ObjCache(vm, "main", index_capacity=256, max_value_words=48)
    rng = np.random.default_rng(3)
    keys = np.arange(40)
    vals = rng.integers(0, 2**32, (40, 48), dtype=np.uint32)
    stored = cache.set_many(keys, vals)
    assert stored.all()
    got, lens, found = cache.get_many(keys)
    assert found.all()
    np.testing.assert_array_equal(got, vals)
    assert cache.delete_many(keys[:10]).all()
    _, _, found = cache.get_many(keys[:10])
    assert not found.any()


def test_sequence_cache_on_sharded_pool():
    from repro.serve.kv_cache import SequenceCache
    vm = VirtualMemory(row_words=ROW_WORDS)
    vm.add_pool(SequenceCache.POOL, 64, Layout.INTERWRAP, shards=4)
    cache = SequenceCache(num_rows=64, vm=vm)
    rng = np.random.default_rng(4)
    blobs = {f"s{i}": rng.integers(0, 256, 1000, dtype=np.uint8)
             for i in range(6)}
    for sid, blob in blobs.items():
        cache.park(sid, blob)
    out = cache.resume_many(blobs)
    for sid, blob in blobs.items():
        np.testing.assert_array_equal(out[sid], blob)
    assert cache.stats.device_hits == 6

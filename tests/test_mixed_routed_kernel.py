"""Router-fused mixed-read kernel parity: one-pass == two-pass oracle.

The tentpole of the fused sharded dispatch is
:func:`repro.kernels.mixed.kernel.read_correct_routed` — the Pallas
scalar-prefetch index map that composes the shard router's
global-id -> (shard, local) translation with the universal layout
translation, returning zeroed rows for pages the shard does not own.

These tests pin it bit-exactly against the unfused two-pass oracle
(:func:`repro.kernels.mixed.ref.read_correct_routed` — route, then plain
local mixed read, then mask) across every layout and shard count, with
page-id vectors spanning all three regions and with corrupted SECDED rows
exercising the in-kernel decode-correct. On CPU the kernel runs in Pallas
interpret mode — the same kernel program, interpreted — so the index-map
fusion itself is what is being verified.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import pool as pool_lib  # noqa: E402
from repro.core.layouts import Layout  # noqa: E402
from repro.kernels.mixed import kernel as mixed_kernel  # noqa: E402
from repro.kernels.mixed import ref as mixed_ref  # noqa: E402
from repro.shard import router  # noqa: E402

ROWS, ROW_WORDS = 128, 16
LAYOUTS = [Layout.INTERWRAP, Layout.PACKED, Layout.RANK_SUBSET,
           Layout.PARITY, Layout.BASELINE_ECC]
SHARDS = [1, 2, 4, 8]


def _shard_blocks(layout, boundary, num_shards, rng):
    """Build S local shard blocks holding a known global page population.

    Written through the *local* engine per shard (trusted by its own
    suite), so the routed read has an independent ground truth.
    """
    rows_local = ROWS // num_shards
    b_local = boundary // num_shards
    states = [pool_lib.make_pool(rows_local, layout, boundary=b_local,
                                 row_words=ROW_WORDS)
              for _ in range(num_shards)]
    num_pages = ROWS + num_shards * states[0].num_extra_pages
    pages = np.arange(num_pages, dtype=np.int32)
    data = rng.integers(0, 2**32, (num_pages, states[0].page_words),
                        dtype=np.uint32)
    shard, local = router.route_np(pages, ROWS, num_shards)
    for s in range(num_shards):
        own = shard == s
        states[s] = states[s].write(local[own], jnp.asarray(data[own]))
    return states, pages, data


@pytest.mark.parametrize("num_shards", SHARDS)
@pytest.mark.parametrize("layout", LAYOUTS, ids=lambda l: l.value)
def test_routed_kernel_matches_oracle(layout, num_shards):
    rng = np.random.default_rng(13 * num_shards + hash(layout.value) % 97)
    boundary = 0 if layout == Layout.BASELINE_ECC else ROWS // 2
    states, pages, data = _shard_blocks(layout, boundary, num_shards, rng)
    ids = rng.permutation(len(pages))[:48].astype(np.int32)
    ids_j = jnp.asarray(ids)

    acc = np.zeros((len(ids), states[0].page_words), np.uint32)
    for s in range(num_shards):
        got = mixed_kernel.read_correct_routed(
            states[s].storage, ids_j, layout, ROWS, boundary, num_shards,
            jnp.int32(s))
        want = mixed_ref.read_correct_routed(
            states[s].storage, ids_j, layout, ROWS, boundary, num_shards,
            jnp.int32(s))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"shard {s}")
        # non-owned rows are zero (the psum-ready contract)
        shard_of, _ = router.route_np(ids, ROWS, num_shards)
        assert not np.asarray(got)[shard_of != s].any()
        acc += np.asarray(got)
    # summing the per-shard outputs assembles the full batch
    np.testing.assert_array_equal(acc, data[ids])


@pytest.mark.parametrize("num_shards", [1, 4])
def test_routed_kernel_corrects_secded_rows(num_shards):
    """Single-bit flips in owned SECDED rows come back corrected through
    the routed kernel, exactly as through the oracle."""
    layout, boundary = Layout.INTERWRAP, ROWS // 2
    rng = np.random.default_rng(5)
    states, pages, data = _shard_blocks(layout, boundary, num_shards, rng)
    rows_local = ROWS // num_shards
    b_local = boundary // num_shards
    # flip one data bit in every shard's first two SECDED rows
    for s in range(num_shards):
        st = np.asarray(states[s].storage).copy()
        for r in (b_local, b_local + 1):
            st[r, 0, 3] ^= 1 << (7 * s + r) % 32
        states[s] = pool_lib.PoolState(jnp.asarray(st), b_local, layout,
                                       ROW_WORDS)
    # global ids of those rows: local SECDED row r on shard s
    ids = np.asarray([r * num_shards + s
                      for s in range(num_shards)
                      for r in (b_local, b_local + 1)], np.int32)
    acc = np.zeros((len(ids), states[0].page_words), np.uint32)
    for s in range(num_shards):
        got = mixed_kernel.read_correct_routed(
            states[s].storage, jnp.asarray(ids), layout, ROWS, boundary,
            num_shards, jnp.int32(s))
        want = mixed_ref.read_correct_routed(
            states[s].storage, jnp.asarray(ids), layout, ROWS, boundary,
            num_shards, jnp.int32(s))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        acc += np.asarray(got)
    # the flips were corrected: assembled batch equals the written truth
    np.testing.assert_array_equal(acc, data[ids])


def test_routed_kernel_reduces_to_plain_read_single_shard():
    """With S=1 the routed kernel owns everything: bit-exact with the
    unrouted fused read."""
    rng = np.random.default_rng(2)
    states, pages, data = _shard_blocks(Layout.INTERWRAP, 64, 1, rng)
    ids = jnp.asarray(rng.permutation(len(pages))[:32].astype(np.int32))
    routed = mixed_kernel.read_correct_routed(
        states[0].storage, ids, Layout.INTERWRAP, ROWS, 64, 1, jnp.int32(0))
    plain = mixed_kernel.read_correct(
        states[0].storage, ids, Layout.INTERWRAP, ROWS, 64)
    np.testing.assert_array_equal(np.asarray(routed), np.asarray(plain))

"""Fused probe+gather kernel: Pallas vs. jnp oracle vs. the access engine.

Runs in interpret mode on CPU; the kernel must match the oracle bit-exactly
for every layout and boundary — including linear-probe displacement,
tombstones, absent keys, and the SECDED correction fused into the gather.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pool as P
from repro.core.layouts import Layout
from repro.kernels.hash import kernel, ops, ref
from repro.objcache import hash_index as hix

RNG = np.random.default_rng(31)
ROW_WORDS = 64
ALL_LAYOUTS = [Layout.PACKED, Layout.RANK_SUBSET, Layout.INTERWRAP,
               Layout.PARITY]


def _filled_pool(layout, boundary):
    pool = P.make_pool(16, layout, boundary=boundary, row_words=ROW_WORDS)
    for page in range(pool.num_pages):
        pool = P.write_page(pool, page, jnp.asarray(
            RNG.integers(0, 2**32, pool.page_words, dtype=np.uint32)))
    return pool


def _indexed(pool, n_keys=9, capacity=32, probe=8, key_rng=None):
    """Index mapping random keys onto the pool's first ``n_keys`` pages."""
    rng = key_rng or RNG
    keys = rng.choice(10_000, n_keys, replace=False).astype(np.uint32)
    pages = rng.permutation(pool.num_pages)[:n_keys].astype(np.int32)
    index = hix.make_index(capacity, probe)
    index, _, ok = hix.insert(index, jnp.asarray(keys), jnp.asarray(pages),
                              jnp.zeros(n_keys, jnp.int32),
                              jnp.full(n_keys, 8, jnp.int32))
    assert np.asarray(ok).all()
    return index, keys, pages


@pytest.mark.parametrize("layout", ALL_LAYOUTS)
@pytest.mark.parametrize("boundary", [0, 8, 16])
def test_kernel_matches_ref_all_modes(layout, boundary):
    pool = _filled_pool(layout, boundary)
    index, keys, _ = _indexed(pool)
    queries = jnp.asarray(np.concatenate([keys[:5], [55555, 7]]), jnp.uint32)
    args = (pool.storage, index.key, index.page, queries, layout,
            pool.num_rows, boundary, index.probe)
    np.testing.assert_array_equal(np.asarray(ref.lookup_read(*args)),
                                  np.asarray(kernel.lookup_read(*args)))


def test_kernel_matches_engine_reads():
    pool = _filled_pool(Layout.INTERWRAP, 8)
    index, keys, pages = _indexed(pool)
    out = kernel.lookup_read(pool.storage, index.key, index.page,
                             jnp.asarray(keys), Layout.INTERWRAP,
                             pool.num_rows, 8, index.probe)
    expect = P.read_pages_any(pool, pages)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_kernel_probe_handles_collisions_and_tombstones():
    """Keys that collide into one window must still resolve after deletes."""
    capacity, probe = 16, 8
    # craft keys sharing a home slot: brute-force the hash
    home = 3
    colliders = []
    k = 0
    while len(colliders) < 4:
        h = int(np.asarray(hix.hash_u32(jnp.asarray([k], jnp.uint32)))[0])
        if h % capacity == home:
            colliders.append(k)
        k += 1
    pool = _filled_pool(Layout.INTERWRAP, 8)
    index = hix.make_index(capacity, probe)
    pages = np.arange(4, dtype=np.int32)
    index, _, ok = hix.insert(
        index, jnp.asarray(colliders, jnp.uint32), jnp.asarray(pages),
        jnp.zeros(4, jnp.int32), jnp.full(4, 8, jnp.int32))
    assert np.asarray(ok).all()
    # delete the first collider: the displaced rest must stay reachable
    index, found = hix.delete(index, jnp.asarray(colliders[:1], jnp.uint32))
    assert np.asarray(found).all()
    queries = jnp.asarray(colliders, jnp.uint32)
    args = (pool.storage, index.key, index.page, queries, Layout.INTERWRAP,
            pool.num_rows, 8, probe)
    d_ref = ref.lookup_read(*args)
    d_ker = kernel.lookup_read(*args)
    np.testing.assert_array_equal(np.asarray(d_ref), np.asarray(d_ker))
    expect = P.read_pages_any(pool, pages[1:])
    np.testing.assert_array_equal(np.asarray(d_ker)[1:], np.asarray(expect))


def test_kernel_corrects_secded_flip_in_fused_pass():
    pool = _filled_pool(Layout.INTERWRAP, 8)
    clean, _ = P.read_page(pool, 12)
    index = hix.make_index(32, 8)
    index, _, ok = hix.insert(index, jnp.asarray([77], jnp.uint32),
                              jnp.asarray([12], jnp.int32),
                              jnp.zeros(1, jnp.int32),
                              jnp.full(1, 8, jnp.int32))
    assert np.asarray(ok).all()
    arr = np.asarray(pool.storage).copy()
    arr[12, 4, 20] ^= np.uint32(1 << 9)          # data-lane flip, SECDED row
    out = kernel.lookup_read(jnp.asarray(arr), index.key, index.page,
                             jnp.asarray([77], jnp.uint32), Layout.INTERWRAP,
                             pool.num_rows, 8, index.probe)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(clean))


def test_ops_dispatch_agrees_with_ref():
    pool = _filled_pool(Layout.PARITY, 8)
    index, keys, _ = _indexed(pool)
    queries = jnp.asarray(keys[:4], jnp.uint32)
    via_ops = ops.lookup_pool(pool, index, queries)      # auto dispatch
    via_ref = ref.lookup_read(pool.storage, index.key, index.page, queries,
                              pool.layout, pool.num_rows, pool.boundary,
                              index.probe)
    np.testing.assert_array_equal(np.asarray(via_ops), np.asarray(via_ref))

"""Regression-gate robustness: non-metric rows must never crash the gate."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

from benchmarks import check_regression as cr  # noqa: E402


def _write(dirpath, suite, payload):
    os.makedirs(dirpath, exist_ok=True)
    with open(os.path.join(dirpath, f"BENCH_{suite}.json"), "w") as f:
        json.dump(payload, f)


def test_load_filters_non_numeric_and_private(tmp_path, capsys):
    p = tmp_path / "BENCH_x.json"
    p.write_text(json.dumps({
        "a_us": 1.5,
        "b_count": 3,
        "_metrics": {"cream_reads": {"series": []}},
        "note": "a string",
        "flag": True,
    }))
    out = cr._load(str(p))
    assert out == {"a_us": 1.5, "b_count": 3.0}
    assert "skipping" in capsys.readouterr().out


def test_profile_blob_does_not_trip_gate(tmp_path):
    """Fresh files from a --profile run carry _metrics; the gate must pass
    when the actual numbers are fine."""
    base, fresh = str(tmp_path / "base"), str(tmp_path / "fresh")
    _write(base, "serving", {"serving_us": 10.0})
    _write(fresh, "serving", {"serving_us": 10.5,
                              "_metrics": {"cream_x": {"series": []}}})
    assert cr.check(base, fresh, tolerance=1.5) == []


def test_rebaselined_blob_on_baseline_side(tmp_path):
    """Even a baseline accidentally rebaselined WITH the blob compares
    cleanly — warn + skip, not a crash or false violation."""
    base, fresh = str(tmp_path / "base"), str(tmp_path / "fresh")
    _write(base, "vm", {"vm_us": 5.0, "_metrics": {"n": 1}})
    _write(fresh, "vm", {"vm_us": 5.0})
    assert cr.check(base, fresh, tolerance=1.5) == []


def test_fresh_only_rows_warn_but_pass(tmp_path, capsys):
    base, fresh = str(tmp_path / "base"), str(tmp_path / "fresh")
    _write(base, "vm", {"vm_us": 5.0})
    _write(fresh, "vm", {"vm_us": 5.0, "vm_new_metric": 1.0})
    assert cr.check(base, fresh, tolerance=1.5) == []
    assert "unbaselined" in capsys.readouterr().out


def test_real_regression_still_fails(tmp_path):
    base, fresh = str(tmp_path / "base"), str(tmp_path / "fresh")
    _write(base, "vm", {"vm_us": 5.0})
    _write(fresh, "vm", {"vm_us": 50.0,
                         "_metrics": {"cream_x": {"series": []}}})
    violations = cr.check(base, fresh, tolerance=1.5)
    assert len(violations) == 1 and "vm_us" in violations[0]


def test_missing_baselined_metric_still_fails(tmp_path):
    base, fresh = str(tmp_path / "base"), str(tmp_path / "fresh")
    _write(base, "vm", {"vm_us": 5.0, "vm_gone_us": 2.0})
    _write(fresh, "vm", {"vm_us": 5.0})
    violations = cr.check(base, fresh, tolerance=1.5)
    assert len(violations) == 1 and "disappeared" in violations[0]


def test_update_strips_blob(tmp_path):
    base, fresh = str(tmp_path / "base"), str(tmp_path / "fresh")
    _write(fresh, "vm", {"vm_us": 5.0, "_metrics": {"n": 1}})
    cr.update(base, fresh)
    rebased = json.load(open(os.path.join(base, "BENCH_vm.json")))
    assert rebased == {"vm_us": 5.0}


def test_higher_is_better_direction(tmp_path):
    base, fresh = str(tmp_path / "base"), str(tmp_path / "fresh")
    _write(base, "serving", {"serving_x_tokens_per_s": 100.0})
    _write(fresh, "serving", {"serving_x_tokens_per_s": 10.0})
    violations = cr.check(base, fresh, tolerance=1.5)
    assert len(violations) == 1


@pytest.mark.parametrize("name,expected", [
    ("serving_zipf_cream_speedup", True),
    ("vm_reclaim_capacity", True),
    ("kernel_mixed_us", False),
    # CREAM-Lens: achieved BLP shrinking is a regression; its companion
    # conflict/stall rows stay on the default lower-is-better side
    ("fig9_memprof_blp_s8", True),
    ("fig9_memprof_router_blp_s4", True),
    ("fig9_memprof_conflict_rate_s8", False),
    ("fig9_memprof_tfaw_stall_cycles_s8", False),
])
def test_is_higher_better(name, expected):
    assert cr.is_higher_better(name) is expected


# ---------------------------------------------------------------------------
# --require-rows presence gate (CREAM-Lens CI wiring)
# ---------------------------------------------------------------------------


def test_require_rows_passes_when_present(tmp_path, capsys):
    fresh = str(tmp_path)
    _write(fresh, "shard", {"fig9_memprof_blp_s8": 321.5,
                            "fig9_real_ws_s8": 1.7})
    assert cr.check_required(fresh, r"fig9_.*_blp") == []
    assert "1 row(s) match" in capsys.readouterr().out


def test_require_rows_fails_when_absent(tmp_path):
    fresh = str(tmp_path)
    _write(fresh, "shard", {"fig9_real_ws_s8": 1.7})
    bad = cr.check_required(fresh, r"fig9_.*_blp")
    assert len(bad) == 1 and "no fresh rows match" in bad[0]


def test_require_rows_fails_on_nonfinite(tmp_path):
    """A profiler that captured nothing must not slip through as NaN."""
    fresh = str(tmp_path)
    _write(fresh, "shard", {"fig9_memprof_blp_s8": float("nan"),
                            "fig9_memprof_blp_s4": 100.0})
    bad = cr.check_required(fresh, r"fig9_.*_blp")
    assert len(bad) == 1 and "nan" in bad[0]


def test_require_rows_respects_suite_filter(tmp_path):
    fresh = str(tmp_path)
    _write(fresh, "shard", {"fig9_memprof_blp_s8": 321.5})
    _write(fresh, "vm", {"vm_us": 5.0})
    assert cr.check_required(fresh, r"fig9_.*_blp", suites={"shard"}) == []
    bad = cr.check_required(fresh, r"fig9_.*_blp", suites={"vm"})
    assert len(bad) == 1


# ---------------------------------------------------------------------------
# --require-min hard floor (Figs. 9–11 speedup gate)
# ---------------------------------------------------------------------------


def test_require_min_passes_above_floor(tmp_path, capsys):
    fresh = str(tmp_path)
    _write(fresh, "shard", {"fig9_real_ws_s8": 1.7, "fig9_real_ws_s4": 1.2})
    assert cr.check_min(fresh, "fig9_real_ws_s8>1.0") == []
    assert "all > 1.0" in capsys.readouterr().out


def test_require_min_fails_below_floor(tmp_path):
    fresh = str(tmp_path)
    _write(fresh, "shard", {"fig9_real_ws_s8": 0.93})
    bad = cr.check_min(fresh, "fig9_real_ws_s8>1.0")
    assert len(bad) == 1 and "hard floor" in bad[0]


def test_require_min_fails_at_exact_floor(tmp_path):
    """The floor is strict: ws == 1.0 is parity, not a speedup."""
    fresh = str(tmp_path)
    _write(fresh, "shard", {"fig9_real_ws_s8": 1.0})
    assert len(cr.check_min(fresh, "fig9_real_ws_s8>1.0")) == 1


def test_require_min_fails_on_nonfinite(tmp_path):
    """A NaN in a hard-gated row must fail, not compare False and pass."""
    fresh = str(tmp_path)
    _write(fresh, "shard", {"fig9_real_ws_s8": float("nan")})
    bad = cr.check_min(fresh, "fig9_real_ws_s8>1.0")
    assert len(bad) == 1 and "nan" in bad[0]


def test_require_min_fails_when_row_family_missing(tmp_path):
    fresh = str(tmp_path)
    _write(fresh, "shard", {"other_metric": 2.0})
    bad = cr.check_min(fresh, "fig9_real_ws_s8>1.0")
    assert len(bad) == 1 and "no fresh rows match" in bad[0]


def test_require_min_rejects_bad_spec(tmp_path):
    assert len(cr.check_min(str(tmp_path), "fig9_real_ws_s8")) == 1
    assert len(cr.check_min(str(tmp_path), "fig9>abc")) == 1


def test_require_min_gates_every_match(tmp_path):
    """A family pattern floors every matching row, not just one."""
    fresh = str(tmp_path)
    _write(fresh, "shard", {"fig9_real_ws_s8": 1.5, "fig9_real_ws_s4": 0.4})
    bad = cr.check_min(fresh, r"fig9_real_ws_s\d+>0.5")
    assert len(bad) == 1 and "ws_s4" in bad[0]

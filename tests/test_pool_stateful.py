"""Hypothesis state machine over CREAMPool: arbitrary interleavings of
writes, reads, scrubs, injected flips, and boundary moves preserve the
system invariants:

  * read-after-write returns the written data (within the same protection
    epoch);
  * a SECDED-region flip is corrected by scrub, and never corrupts reads;
  * repartition preserves every surviving page's contents;
  * capacity accounting always matches the layout math.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import settings
from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                 invariant, precondition, rule)
from hypothesis import strategies as st

from repro.core import injection
from repro.core import pool as P
from repro.core.layouts import Layout, extra_page_count
from repro.core.scrubber import scrub

ROWS = 32


class PoolMachine(RuleBasedStateMachine):
    @initialize(boundary=st.sampled_from([0, 8, 16, 32]),
                seed=st.integers(0, 2**31 - 1))
    def setup(self, boundary, seed):
        self.rng = np.random.default_rng(seed)
        self.pool = P.make_pool(ROWS, Layout.INTERWRAP, boundary=boundary)
        self.shadow: dict[int, np.ndarray] = {}
        self.dirty_cream: set[int] = set()   # flips in unprotected pages

    def _rand_page(self):
        return self.rng.integers(0, 2**32, size=(self.pool.page_words,),
                                 dtype=np.uint32)

    @rule(slot=st.integers(0, 35))
    def write(self, slot):
        if slot >= self.pool.num_pages:
            return
        data = self._rand_page()
        self.pool = P.write_page(self.pool, slot, jnp.asarray(data))
        self.shadow[slot] = data
        self.dirty_cream.discard(slot)

    @rule(slot=st.integers(0, 35))
    def read(self, slot):
        if slot not in self.shadow or slot >= self.pool.num_pages:
            return
        if slot in self.dirty_cream:
            return  # unprotected page with an injected flip: no guarantee
        got, status = P.read_page(self.pool, slot)
        assert (np.asarray(got) == self.shadow[slot]).all()
        assert int(status) in (0, 1, 2)  # clean or corrected, never silent

    @precondition(lambda self: self.pool.boundary < ROWS)
    @rule()
    def flip_protected_bit(self):
        """Inject one flip into the SECDED region; reads must still correct."""
        stor, _ = injection.inject_flips(
            self.pool.storage, self.rng, 1,
            row_range=(self.pool.boundary, ROWS))
        self.pool = dataclasses.replace(self.pool, storage=stor)

    @precondition(lambda self: self.pool.boundary > 0)
    @rule()
    def flip_unprotected_bit(self):
        row = int(self.rng.integers(0, self.pool.boundary))
        stor, recs = injection.inject_flips(self.pool.storage, self.rng, 1,
                                            row_range=(row, row + 1))
        self.pool = dataclasses.replace(self.pool, storage=stor)
        # conservatively mark every page as possibly-affected in that region
        for slot in list(self.shadow):
            if slot < self.pool.boundary or slot >= ROWS:
                self.dirty_cream.add(slot)

    @rule()
    def scrub_pool(self):
        self.pool, stats = scrub(self.pool)
        assert stats.detected_uncorrectable == 0

    @rule(new_boundary=st.sampled_from([0, 8, 16, 24, 32]))
    def move_boundary(self, new_boundary):
        old_pages = self.pool.num_pages
        self.pool, info = P.repartition(self.pool, new_boundary)
        for slot in info["evicted_extra_pages"]:
            self.shadow.pop(slot, None)
            self.dirty_cream.discard(slot)
        # pages entering SECDED got re-encoded over possibly-flipped data:
        # their dirty flag persists; clean pages must survive the move.
        for slot in list(self.shadow):
            if slot >= self.pool.num_pages:
                self.shadow.pop(slot)
                self.dirty_cream.discard(slot)

    @invariant()
    def capacity_matches_layout_math(self):
        expected = ROWS + extra_page_count(Layout.INTERWRAP,
                                           self.pool.boundary)
        assert self.pool.num_pages == expected


TestPoolMachine = PoolMachine.TestCase
TestPoolMachine.settings = settings(max_examples=12, stateful_step_count=14,
                                    deadline=None)

"""Model substrate: per-arch smoke tests + decode-consistency properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import BlockKind, MixerKind, ModelConfig
from repro.models import build_model, count_params


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch):
    """Reduced same-family config: one forward + one train grad on CPU."""
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    logits, aux = jax.jit(model.forward)(params, tokens)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN in logits"
    grads = jax.grad(lambda p: model.loss(p, tokens, tokens))(params)
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads)), \
        f"{arch}: NaN in grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_param_count_positive(arch):
    cfg = get_config(arch)
    n = count_params(cfg)
    na = count_params(cfg, active_only=True)
    assert n > 0 and 0 < na <= n


PREFILL_DECODE_CASES = [
    ModelConfig(name="dense", family="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                head_dim=16, qk_norm=True, dtype="float32"),
    ModelConfig(name="mqa-gelu", family="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=1, d_ff=128, vocab_size=128,
                head_dim=16, mlp_variant="gelu", dtype="float32"),
    ModelConfig(name="moe", family="moe", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=128,
                head_dim=16, pattern=((BlockKind.ATTN, MixerKind.MOE),),
                num_experts=4, experts_per_token=2, moe_d_ff=96,
                capacity_factor=64.0, dtype="float32"),
    ModelConfig(name="mamba", family="ssm", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=128,
                head_dim=16, pattern=((BlockKind.MAMBA, MixerKind.MLP),),
                ssm_state_dim=8, ssm_dt_rank=8, subquadratic=True,
                dtype="float32"),
    ModelConfig(name="xlstm", family="ssm", num_layers=4, d_model=64,
                num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=128,
                pattern=((BlockKind.MLSTM, MixerKind.NONE),) * 3
                + ((BlockKind.SLSTM, MixerKind.NONE),),
                subquadratic=True, dtype="float32"),
    ModelConfig(name="hybrid", family="hybrid", num_layers=4, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                head_dim=16,
                pattern=((BlockKind.ATTN, MixerKind.MOE),)
                + ((BlockKind.MAMBA, MixerKind.MLP),),
                num_experts=4, experts_per_token=2, moe_d_ff=64,
                capacity_factor=64.0, ssm_state_dim=8, ssm_dt_rank=8,
                subquadratic=True, dtype="float32"),
]


@pytest.mark.parametrize("cfg", PREFILL_DECODE_CASES, ids=lambda c: c.name)
def test_prefill_decode_matches_forward(cfg):
    """The system invariant: prefill(P) + decode == full forward, per family.

    For mLSTM this also proves the parallel<->recurrent gate algebra.
    """
    S, P, B = 24, 16, 2
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tok = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    full, _ = jax.jit(model.forward)(params, tok)
    logits_pre, state = model.prefill(params, tok[:, :P], S)
    np.testing.assert_allclose(np.asarray(logits_pre[:, -1]),
                               np.asarray(full[:, P - 1]), atol=1e-3,
                               rtol=1e-3)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(P, S):
        lg, state = step(params, state, tok[:, t])
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, P:]),
                               atol=1e-3, rtol=1e-3)


def test_forward_last_only_matches_full():
    cfg = PREFILL_DECODE_CASES[0]
    from repro.models import transformer
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tok = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    full, _ = transformer.forward(params, cfg, tok)
    last, _ = transformer.forward(params, cfg, tok, logits_mode="last")
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1]),
                               atol=1e-5, rtol=1e-5)


def test_moe_capacity_drops_tokens_gracefully():
    cfg = ModelConfig(name="moe-tight", family="moe", num_layers=1,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=0,
                      vocab_size=64, head_dim=16,
                      pattern=((BlockKind.ATTN, MixerKind.MOE),),
                      num_experts=4, experts_per_token=2, moe_d_ff=32,
                      capacity_factor=0.25, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tok = jax.random.randint(jax.random.key(1), (2, 32), 0, 64)
    logits, aux = jax.jit(model.forward)(params, tok)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))

"""Repartition eviction paths: exact eviction sets, content preservation,
and the VM-level guarantee that the same upgrade *migrates* instead.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pool as P
from repro.core.layouts import Layout, extra_page_count
from repro.core.protection import Protection
from repro.vm import MigrationEngine, VirtualMemory

RNG = np.random.default_rng(5)
ROW_WORDS = 64


def filled_pool(rows=32, layout=Layout.INTERWRAP, boundary=None):
    pool = P.make_pool(rows, layout, boundary=boundary, row_words=ROW_WORDS)
    pages = {}
    for page in range(pool.num_pages):
        data = jnp.asarray(RNG.integers(0, 2**32, pool.page_words,
                                        dtype=np.uint32))
        pool = P.write_page(pool, page, data)
        pages[page] = np.asarray(data)
    return pool, pages


@pytest.mark.parametrize("new_boundary", [24, 16, 8, 0])
def test_growing_secded_evicts_exactly_trailing_extras(new_boundary):
    pool, pages = filled_pool(32, Layout.INTERWRAP)   # 4 extras: 32..35
    new_extra = extra_page_count(Layout.INTERWRAP, new_boundary, ROW_WORDS)
    predicted = P.evicted_extra_pages(pool, new_boundary)
    shrunk, info = P.repartition(pool, new_boundary)
    # exactly the trailing extra pages, and the prediction agrees
    assert info["evicted_extra_pages"] == list(range(32 + new_extra, 36))
    assert info["evicted_extra_pages"] == predicted
    assert shrunk.num_extra_pages == new_extra


@pytest.mark.parametrize("new_boundary", [16, 0])
def test_growing_secded_preserves_regular_and_surviving_extras(new_boundary):
    pool, pages = filled_pool(32, Layout.INTERWRAP)
    shrunk, info = P.repartition(pool, new_boundary)
    survivors = [p for p in pages if p not in info["evicted_extra_pages"]]
    for page in survivors:
        got, status = P.read_page(shrunk, page)
        np.testing.assert_array_equal(np.asarray(got), pages[page],
                                      err_msg=f"page {page}")
        assert int(status) == 0


def test_shrinking_secded_preserves_contents_and_adds_extras():
    pool, pages = filled_pool(32, Layout.INTERWRAP, boundary=0)
    grown, info = P.repartition(pool, 32)
    assert info["evicted_extra_pages"] == []
    assert grown.num_extra_pages == 4
    for page in pages:
        got, _ = P.read_page(grown, page)
        np.testing.assert_array_equal(np.asarray(got), pages[page])


def test_parity_pool_eviction_set():
    pool, _ = filled_pool(32, Layout.PARITY)
    predicted = P.evicted_extra_pages(pool, 16)
    _, info = P.repartition(pool, 16)
    assert info["evicted_extra_pages"] == predicted
    assert predicted == list(range(
        32 + extra_page_count(Layout.PARITY, 16, ROW_WORDS),
        32 + extra_page_count(Layout.PARITY, 32, ROW_WORDS)))


def test_vm_level_upgrade_migrates_instead_of_evicting():
    """The same boundary move that evicts raw-pool extras loses nothing
    when driven through the VM's migration transaction."""
    vm = VirtualMemory(row_words=ROW_WORDS)
    vm.add_pool("p0", 32, Layout.INTERWRAP)           # extras 32..35
    vm.create_tenant("t", default_reliability=Protection.NONE)
    vpns = vm.alloc("t", 36, allow_host=False)
    data = jnp.asarray(RNG.integers(0, 2**32, (36, vm.page_words),
                                    dtype=np.uint32))
    vm.write("t", vpns, data)

    # raw-pool ground truth: this move would evict 4 pages
    assert len(P.evicted_extra_pages(vm.pools["p0"], 0)) == 4

    eng = MigrationEngine(vm)
    info = eng.repartition_with_migration("p0", 0)
    assert info["migrated"] == 4 and info["evicted_unmapped"] == 0
    assert (vm.read("t", vpns) == data).all()          # zero lost pages
    # the four migrated pages overflowed to the host tier (pool was full)
    assert info["to_host"] == 4

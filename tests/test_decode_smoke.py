"""Per-arch decode smoke: every assigned architecture serves one token.

Complements test_models.py's forward/grad smoke with the serve path: reduced
config, prefill a short prompt, decode 3 tokens, assert shapes/finiteness
and cache_len bookkeeping. Covers the (f) deliverable's decode leg for all
10 architectures including the hybrid/SSM state machinery.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_smoke(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, P, MAX = 2, 8, 16
    tok = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab_size)
    logits, state = model.prefill(params, tok, MAX)
    assert logits.shape == (B, P, cfg.vocab_size)
    assert int(state["cache_len"][0]) == P
    step = jax.jit(model.decode_step)
    cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    for i in range(3):
        lg, state = step(params, state, cur)
        assert lg.shape == (B, cfg.vocab_size)
        assert bool(jnp.isfinite(lg).all()), f"{arch}: NaN at decode {i}"
        cur = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    assert int(state["cache_len"][0]) == P + 3


@pytest.mark.parametrize("arch", ["xlstm-1.3b", "jamba-1.5-large-398b"])
def test_subquadratic_state_is_constant_size(arch):
    """long_500k feasibility: recurrent state size must not scale with the
    cache length for the SSM/hybrid archs (modulo the few attn layers)."""
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    small = model.init_decode_state(1, 16)
    big = model.init_decode_state(1, 64)

    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            for k, v in tree.items():
                yield from walk(v, f"{prefix}/{k}")
        else:
            yield prefix, tree

    def nbytes(tree):
        return sum(leaf.size * leaf.dtype.itemsize
                   for path, leaf in walk(tree)
                   if not path.endswith(("/k", "/v")))

    assert nbytes(small) == nbytes(big)

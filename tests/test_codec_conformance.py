"""Exhaustive codec conformance: one harness over the whole code ladder.

Every correcting/detecting code in the repo — parity8 (detect-only),
SECDED Hsiao(72,64), SEC-DAEC(144,128) — is run through the same
enumeration harness and held to its *exact* contract:

  ==========  =============  ==================  =====================
  codec       single bit     adjacent double     random double (1 unit)
  ==========  =============  ==================  =====================
  parity8     detected       detected            detected iff the two
                                                 bits differ mod 8;
                                                 same class -> silent
                                                 (the documented escape)
  secded      corrected      same beat: detected corrected across
              exactly        never silent;       beats; detected never
                             across beats: both  silent within one
                             corrected           beat
  daec        corrected      corrected (inter-   split even/odd ->
              exactly        leaving splits the  corrected; same
                             pair)               codeword -> detected
                                                 never silent
  ==========  =============  ==================  =====================

"Exhaustive" means every code-word position: every data bit and every
live code bit of a block is flipped and the verdict checked *per beat*
(the error must be flagged at the right position and nowhere else).
Enumerations are vectorised — one batched decode over all flip variants
— so the default run stays fast; the ``slow`` marker covers the full
layout × shard sweep and the quadratic double-bit enumerations.

Also here, because the codecs are only as good as their H-matrices and
the plumbing that reports them:

  * property tests of the Hsiao and DAEC column sets (odd weight,
    distinct, and the defining SEC-DAEC adjacency condition);
  * Pallas-kernel-vs-jnp-oracle bit-exactness, direct and through live
    pools across all 5 layouts × shards {1, 2, 4, 8};
  * the ladder-sync regression: obs fold matrices, SLO class maps, and
    the serving engine's status fold all derive their shape from
    ``Protection.ladder()`` — adding a rung cannot desynchronise them.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import daec, parity8, secded
from repro.core.layouts import Layout
from repro.core.protection import Protection, ladder
from repro.core.secded import (CLEAN, CORRECTED_CODE, CORRECTED_DATA,
                               DETECTED_UNCORRECTABLE)
from repro.kernels.daec import ops as daec_ops
from repro.kernels.parity8 import ops as parity8_ops
from repro.kernels.secded import ops as secded_ops

# ---------------------------------------------------------------------------
# The harness: a uniform view of each codec over one enumeration block.
#
#   D          block width in uint32 words (data bits = 32 * D)
#   code_bits  live code-bit positions, as (code-array word, bit) pairs
#   encode     (n, D) uint32 -> code array
#   decode     (data, code) -> (data', code', per-beat status) — for the
#              detect-only codec data/code pass through and the status is
#              per line
#   beat_bits  data bits per status element (what "one beat" means)
# ---------------------------------------------------------------------------


def _parity_decode(data, code):
    return data, code, parity8.check_lines(data, code)


CODECS = {
    "parity8": dict(
        D=16, beat_bits=512,
        encode=parity8.encode_lines, decode=_parity_decode,
        code_bits=[(0, b) for b in range(8)],
        corrects_singles=False, corrects_adjacent=False),
    "secded": dict(
        D=8, beat_bits=64,
        encode=secded.encode_block, decode=secded.decode_block,
        code_bits=[(0, b) for b in range(32)],
        corrects_singles=True, corrects_adjacent=False),
    "daec": dict(
        D=8, beat_bits=64,
        encode=daec.encode_block, decode=daec.decode_block,
        code_bits=[(0, b) for b in range(32)],
        corrects_singles=True, corrects_adjacent=True),
}


def _base_block(codec, seed=0):
    rng = np.random.default_rng(seed)
    data = jnp.asarray(rng.integers(0, 2**32, (1, codec["D"]),
                                    dtype=np.uint32))
    return data, codec["encode"](data)


def _flip_batch(base, positions):
    """Tile ``base`` (1, W) and XOR one bit per row at bit-positions
    ``positions`` (global over the 32*W-bit little-endian bit string)."""
    pos = np.asarray(positions)
    batch = np.tile(np.asarray(base), (pos.size, 1))
    np.bitwise_xor.at(batch, (np.arange(pos.size), pos // 32),
                      np.uint32(1) << (pos % 32).astype(np.uint32))
    return jnp.asarray(batch)


# ---------------------------------------------------------------------------
# Exhaustive single-bit enumeration — every code-word position.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(CODECS))
def test_single_bit_every_data_position(name):
    codec = CODECS[name]
    data, code = _base_block(codec)
    nbits = 32 * codec["D"]
    flipped = _flip_batch(data, np.arange(nbits))
    codes = jnp.tile(code, (nbits, 1))
    out, out_code, status = codec["decode"](flipped, codes)
    out, out_code = np.asarray(out), np.asarray(out_code)
    status = np.asarray(status)
    if name == "daec":      # superbeat verdict broadcast to both beats
        sb = np.arange(nbits) // 128
        beats = np.stack([2 * sb, 2 * sb + 1], axis=1)
    else:
        beats = (np.arange(nbits) // codec["beat_bits"])[:, None]
    hit = np.take_along_axis(status, beats, axis=1)
    rest = status.copy()
    np.put_along_axis(rest, beats, CLEAN, axis=1)
    assert (rest == CLEAN).all(), "flag leaked to an unhit beat"
    if codec["corrects_singles"]:
        assert (hit == CORRECTED_DATA).all()
        assert (out == np.asarray(data)).all(), "single not repaired exactly"
        assert (out_code == np.asarray(codes)).all()
    else:
        assert (hit == parity8.LINE_CORRUPT).all(), \
            "detect-only codec missed a single"


@pytest.mark.parametrize("name", list(CODECS))
def test_single_bit_every_code_position(name):
    codec = CODECS[name]
    data, code = _base_block(codec)
    pos = np.asarray([32 * w + b for w, b in codec["code_bits"]])
    datas = jnp.tile(data, (pos.size, 1))
    flipped_codes = _flip_batch(code, pos)
    out, out_code, status = codec["decode"](datas, flipped_codes)
    status = np.asarray(status)
    if codec["corrects_singles"]:
        # a code-bit error is corrected in place and only its beat flags
        beat = pos // (8 if name == "secded" else 16)
        if name == "daec":                  # superbeat verdict -> 2 beats
            beat = np.stack([2 * beat, 2 * beat + 1], axis=1)
            hit = np.take_along_axis(status, beat, axis=1)
            rest = status.copy()
            np.put_along_axis(rest, beat, CLEAN, axis=1)
        else:
            hit = status[np.arange(pos.size), beat][:, None]
            rest = status.copy()
            rest[np.arange(pos.size), beat] = CLEAN
        assert (hit == CORRECTED_CODE).all()
        assert (rest == CLEAN).all()
        assert (np.asarray(out) == np.asarray(data)).all()
        assert (np.asarray(out_code) == np.asarray(code)).all(), \
            "code plane not repaired"
    else:
        assert (status == parity8.LINE_CORRUPT).all()


# ---------------------------------------------------------------------------
# Exhaustive adjacent-double enumeration — every physically adjacent pair
# (bits p, p+1 of the block's bit string, including word-crossing pairs).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(CODECS))
def test_adjacent_double_every_data_pair(name):
    codec = CODECS[name]
    data, code = _base_block(codec, seed=1)
    nbits = 32 * codec["D"]
    pos = np.arange(nbits - 1)
    flipped = np.array(_flip_batch(data, pos))
    np.bitwise_xor.at(flipped, (np.arange(pos.size), (pos + 1) // 32),
                      np.uint32(1) << ((pos + 1) % 32).astype(np.uint32))
    codes = jnp.tile(code, (pos.size, 1))
    out, _, status = codec["decode"](jnp.asarray(flipped), codes)
    out, status = np.asarray(out), np.asarray(status)
    exact = (out == np.asarray(data)).all(axis=1)
    worst = status.max(axis=1)
    if name == "daec":
        # interleaving splits every adjacent pair: all corrected, exactly
        assert (worst == CORRECTED_DATA).all()
        assert exact.all()
    elif name == "secded":
        same_beat = pos // 64 == (pos + 1) // 64
        # within one beat: Hsiao detects every double — flagged, not fixed
        assert (worst[same_beat] == DETECTED_UNCORRECTABLE).all()
        assert not exact[same_beat].any()
        # across beats: two singles, both corrected
        assert (worst[~same_beat] == CORRECTED_DATA).all()
        assert exact[~same_beat].all()
    else:
        # bits p, p+1 always differ mod 8 -> both parity lanes flip
        assert (worst == parity8.LINE_CORRUPT).all()


@pytest.mark.parametrize("name", ["secded", "daec"])
def test_adjacent_double_every_code_pair(name):
    codec = CODECS[name]
    data, code = _base_block(codec, seed=2)
    pos = np.arange(31)                       # pairs (b, b+1) in the word
    datas = jnp.tile(data, (pos.size, 1))
    flipped = np.array(_flip_batch(code, pos))
    np.bitwise_xor.at(flipped, (np.arange(pos.size), (pos + 1) // 32),
                      np.uint32(1) << ((pos + 1) % 32).astype(np.uint32))
    out, out_code, status = codec["decode"](datas, jnp.asarray(flipped))
    worst = np.asarray(status).max(axis=1)
    data_ok = (np.asarray(out) == np.asarray(data)).all(axis=1)
    assert data_ok.all(), "code-plane errors must never touch data"
    if name == "daec":
        # within one 16-bit field, bits 2i|2i+1 belong to codewords A|B —
        # an adjacent pair always splits across them -> both corrected;
        # a pair crossing a field boundary hits two superbeats -> ditto
        assert (worst == CORRECTED_CODE).all()
        assert (np.asarray(out_code) == np.asarray(code)).all()
    else:
        same_byte = pos // 8 == (pos + 1) // 8
        # two code bits of one Hsiao codeword: even-weight syndrome ->
        # detected, never miscorrected into the data
        assert (worst[same_byte] == DETECTED_UNCORRECTABLE).all()
        assert (worst[~same_byte] == CORRECTED_CODE).all()


# ---------------------------------------------------------------------------
# Random-double sampling — never silent within one protection unit.
# The numpy-seeded sweep always runs; hypothesis (if installed) fuzzes on
# top with shrinking.
# ---------------------------------------------------------------------------


def _double_verdict(codec, b0, b1, seed=3):
    data, code = _base_block(codec, seed=seed)
    flipped = np.array(_flip_batch(data, np.asarray([b0])))
    flipped[0, b1 // 32] ^= np.uint32(1) << np.uint32(b1 % 32)
    out, _, status = codec["decode"](jnp.asarray(flipped), code)
    exact = bool((np.asarray(out) == np.asarray(data)).all())
    return int(np.asarray(status).max()), exact


def _assert_double_contract(name, b0, b1):
    codec = CODECS[name]
    worst, exact = _double_verdict(codec, b0, b1)
    if name == "secded":
        if b0 // 64 == b1 // 64:                 # same beat: every double
            assert worst == DETECTED_UNCORRECTABLE and not exact
        else:                                    # two beats: two singles
            assert worst == CORRECTED_DATA and exact
    elif name == "daec":
        if b0 // 128 != b1 // 128 or b0 % 2 != b1 % 2:
            # different superbeats, or split across the even/odd
            # codewords: corrected outright
            assert worst == CORRECTED_DATA and exact
        else:                                    # same codeword: detected
            assert worst == DETECTED_UNCORRECTABLE and not exact
        # the headline contract: silent is impossible
        assert exact or worst == DETECTED_UNCORRECTABLE
    else:                                        # parity8
        if b0 % 8 == b1 % 8:                     # same congruence class:
            assert worst == parity8.LINE_OK      # the documented escape
        else:
            assert worst == parity8.LINE_CORRUPT


@pytest.mark.parametrize("name", list(CODECS))
def test_random_double_sampled(name):
    nbits = 32 * CODECS[name]["D"]
    rng = np.random.default_rng(4)
    for _ in range(64):
        b0, b1 = rng.choice(nbits, size=2, replace=False)
        _assert_double_contract(name, int(b0), int(b1))


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=200, deadline=None)
    @given(name=st.sampled_from(sorted(CODECS)), b0=st.integers(0, 511),
           b1=st.integers(0, 511))
    def test_random_double_hypothesis(name, b0, b1):
        nbits = 32 * CODECS[name]["D"]
        b0, b1 = b0 % nbits, b1 % nbits
        if b0 == b1:
            return
        _assert_double_contract(name, b0, b1)
except ImportError:                                 # pragma: no cover
    pass   # the seeded numpy sweep above still proves the contract


@pytest.mark.slow
@pytest.mark.parametrize("name", ["secded", "daec"])
def test_exhaustive_double_never_silent_one_unit(name):
    """Every 2-bit pattern inside one protection unit, not a sample:
    all C(64,2) beat pairs for SECDED, all C(128,2) superbeat pairs for
    DAEC — silent corruption must be *impossible*, not just unlikely."""
    codec = CODECS[name]
    unit = 64 if name == "secded" else 128
    data, code = _base_block(codec, seed=5)
    pairs = np.asarray([(i, j) for i in range(unit)
                        for j in range(i + 1, unit)])
    flipped = np.array(_flip_batch(data, pairs[:, 0]))
    np.bitwise_xor.at(flipped, (np.arange(len(pairs)), pairs[:, 1] // 32),
                      np.uint32(1) << (pairs[:, 1] % 32).astype(np.uint32))
    codes = jnp.tile(code, (len(pairs), 1))
    out, _, status = codec["decode"](jnp.asarray(flipped), codes)
    exact = (np.asarray(out) == np.asarray(data)).all(axis=1)
    worst = np.asarray(status).max(axis=1)
    silent = ~exact & (worst != DETECTED_UNCORRECTABLE)
    assert not silent.any(), f"{silent.sum()} silent double(s)"
    if name == "secded":
        assert (worst == DETECTED_UNCORRECTABLE).all()
    else:
        split = pairs[:, 0] % 2 != pairs[:, 1] % 2
        assert (worst[split] == CORRECTED_DATA).all() and exact[split].all()
        assert (worst[~split] == DETECTED_UNCORRECTABLE).all()


# ---------------------------------------------------------------------------
# H-matrix invariants — the properties the contracts above rest on.
# ---------------------------------------------------------------------------


def test_hsiao_matrix_invariants():
    data_cols = [int(c) for c in secded._COLUMNS]
    code_cols = [1 << p for p in range(secded.NUM_CODE_BITS)]
    cols = data_cols + code_cols
    assert len(cols) == 72
    assert all(c != 0 for c in cols), "zero column: undetectable single"
    assert len(set(cols)) == len(cols), "duplicate column: miscorrection"
    assert all(bin(c).count("1") % 2 == 1 for c in cols), \
        "even-weight column breaks Hsiao double detection"


def test_daec_matrix_invariants():
    cols = [int(c) for c in daec._COLUMNS]
    assert len(cols) == 144
    assert all(c != 0 for c in cols)
    assert len(set(cols)) == len(cols)
    # the defining SEC-DAEC condition: every adjacent-pair syndrome is
    # nonzero, unique across pairs, and collides with no single column
    sums = [cols[p] ^ cols[p + 1] for p in range(143)]
    assert all(s != 0 for s in sums), "adjacent double aliases clean"
    assert len(set(sums)) == len(sums), "two adjacent doubles alias"
    assert not set(sums) & set(cols), \
        "adjacent double aliases a single: miscorrection"


# ---------------------------------------------------------------------------
# Kernel vs oracle — Pallas must be bit-identical to the jnp reference.
# ---------------------------------------------------------------------------

_KERNELS = {"parity8": parity8_ops, "secded": secded_ops,
            "daec": daec_ops}


def _corrupt(rng, data, n):
    arr = np.array(data)
    rows = rng.integers(0, arr.shape[0], n)
    words = rng.integers(0, arr.shape[1], n)
    bits = rng.integers(0, 32, n).astype(np.uint32)
    np.bitwise_xor.at(arr, (rows, words), np.uint32(1) << bits)
    return jnp.asarray(arr)


@pytest.mark.parametrize("name", list(CODECS))
def test_kernel_matches_oracle_direct(name):
    rng = np.random.default_rng(6)
    data = jnp.asarray(rng.integers(0, 2**32, (64, 64), dtype=np.uint32))
    ops = _KERNELS[name]
    code_k = ops.encode(data, use_kernel=True)
    code_r = ops.encode(data, use_kernel=False)
    assert (np.asarray(code_k) == np.asarray(code_r)).all()
    bad = _corrupt(rng, data, 40)
    if name == "parity8":
        st_k = ops.check(bad, code_k, use_kernel=True)
        st_r = ops.check(bad, code_r, use_kernel=False)
        assert (np.asarray(st_k) == np.asarray(st_r)).all()
        return
    out_k, oc_k, st_k = ops.decode(bad, code_k, use_kernel=True)
    out_r, oc_r, st_r = ops.decode(bad, code_r, use_kernel=False)
    assert (np.asarray(out_k) == np.asarray(out_r)).all()
    assert (np.asarray(oc_k) == np.asarray(oc_r)).all()
    assert (np.asarray(st_k) == np.asarray(st_r)).all()


def _daec_tier_rows(pool):
    """Extract the DAEC tier's (data, codes) planes from raw storage."""
    from repro.core.pool import CODE_LANE, DATA_LANES
    stor = np.asarray(pool.storage)
    if stor.ndim == 3:                                  # local pool
        rows = stor[pool.daec_start:]
    else:                                               # sharded (S, R, 9, W)
        n_local = pool.daec_rows_local
        rows = stor[:, stor.shape[1] - n_local:].reshape(-1, *stor.shape[2:])
    data = rows[:, :DATA_LANES].transpose(0, 2, 1).reshape(rows.shape[0], -1)
    return jnp.asarray(np.ascontiguousarray(data)), \
        jnp.asarray(rows[:, CODE_LANE])


def _pool_kernel_oracle_case(layout, num_shards, seed=7):
    """Build a live pool with a DAEC tier, corrupt it, and check the
    Pallas kernel and the jnp oracle agree bit-for-bit on its rows."""
    from repro.core.pool import make_pool
    from repro.shard import make_sharded_pool

    rng = np.random.default_rng(seed)
    step = 8 * num_shards
    rows, daec_rows = max(64, 2 * step), 16
    boundary = 0 if layout == Layout.BASELINE_ECC else step
    if num_shards == 1:
        pool = make_pool(rows, layout, boundary=boundary, row_words=64,
                         daec_rows=daec_rows)
    else:
        pool = make_sharded_pool(rows, layout, boundary=boundary,
                                 num_shards=num_shards, row_words=64,
                                 daec_rows=daec_rows)
    ids = jnp.arange(pool.num_pages, dtype=jnp.int32)
    written = jnp.asarray(rng.integers(
        0, 2**32, (pool.num_pages, pool.page_words), dtype=np.uint32))
    pool = pool.write(ids, written)

    import dataclasses

    from repro.core.injection import FlipRecord, apply_flips
    flips = [FlipRecord(int(r), int(rng.integers(0, 9)),
                        int(rng.integers(0, 64)), int(rng.integers(0, 32)))
             for r in range(pool.daec_start, pool.num_rows)]
    if num_shards == 1:
        pool = dataclasses.replace(
            pool, storage=apply_flips(pool.storage, flips))
    else:                         # global row r -> (shard r%S, local r//S)
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        arr = np.asarray(pool.storage).copy()
        for f in flips:
            arr[f.row % num_shards, f.row // num_shards,
                f.lane, f.word] ^= np.uint32(1 << f.bit)
        pool = dataclasses.replace(pool, storage=jax.device_put(
            jnp.asarray(arr), NamedSharding(pool.mesh, P("banks"))))

    data, codes = _daec_tier_rows(pool)
    out_k, oc_k, st_k = daec_ops.decode(data, codes, use_kernel=True)
    out_r, oc_r, st_r = daec_ops.decode(data, codes, use_kernel=False)
    assert (np.asarray(out_k) == np.asarray(out_r)).all(), \
        f"kernel/oracle data mismatch ({layout.value}, S={num_shards})"
    assert (np.asarray(oc_k) == np.asarray(oc_r)).all()
    assert (np.asarray(st_k) == np.asarray(st_r)).all()
    # and the pool's own read path agrees with both: every single-bit
    # flip in the tier is corrected back to the written content
    got, st = pool.read(ids, status=True)
    got, st = np.asarray(got), np.asarray(st)
    tier = np.arange(pool.daec_start, pool.num_rows)
    assert (got[tier] == np.asarray(written)[tier]).all()
    assert (st[tier] <= CORRECTED_CODE).all() and (st[tier] > CLEAN).any()


def test_kernel_matches_oracle_live_pool_fast():
    _pool_kernel_oracle_case(Layout.INTERWRAP, 1)
    _pool_kernel_oracle_case(Layout.BASELINE_ECC, 2)


@pytest.mark.slow
@pytest.mark.parametrize("layout", list(Layout))
@pytest.mark.parametrize("num_shards", [1, 2, 4, 8])
def test_kernel_matches_oracle_all_layouts_all_shards(layout, num_shards):
    _pool_kernel_oracle_case(layout, num_shards)


# ---------------------------------------------------------------------------
# Ladder-sync regression: adding a Protection member must flow into every
# per-class surface automatically. Each assert below was a hardcoded
# ``(3, 2)`` (or a literal class list) before the DAEC rung landed.
# ---------------------------------------------------------------------------


def test_fold_classes_derive_from_ladder():
    from repro.obs import metrics
    assert metrics.FOLD_CLASSES == tuple(p.value for p in ladder())
    assert metrics.FOLD_CLASSES[0] == "daec"      # strongest first
    assert len(metrics.FOLD_CLASSES) == len(Protection)


def test_slo_tracker_covers_every_ladder_rung():
    from repro.obs.slo import SLOTracker
    tracker = SLOTracker()
    for p in ladder():
        assert p.value in tracker.classes, \
            f"SLO tracker missing default class for {p.value}"
    # the strong rungs carry the zero-tolerance contract
    for cls in ("daec", "secded"):
        assert tracker.classes[cls].budget == 0
        assert tracker.classes[cls].silent_budget == 0


def test_engine_status_fold_shape_tracks_ladder():
    from repro.obs import metrics
    from repro.serve.engine import _cream_cls_index, _status_counts
    for layout in Layout:
        idx = _cream_cls_index(layout)
        assert 0 <= idx < len(metrics.FOLD_CLASSES)
    pages = jnp.asarray([0, 8, 56], jnp.int32)       # cream, secded, daec
    status = jnp.asarray([0, 1, 3], jnp.int32)
    counts = np.asarray(_status_counts(
        pages, status, boundary=8, num_rows=64,
        cream_idx=_cream_cls_index(Layout.INTERWRAP), daec_start=48))
    assert counts.shape == (len(metrics.FOLD_CLASSES), 2)
    assert counts[metrics.FOLD_CLASSES.index("secded"), 0] == 1
    assert counts[metrics.FOLD_CLASSES.index("daec"), 1] == 1
    assert counts.sum() == 2                          # clean read not counted


def test_fold_read_status_accepts_ladder_shaped_counts():
    import copy

    from repro.obs import metrics, slo
    saved = copy.deepcopy(slo.TRACKER.classes)
    try:
        counts = np.zeros((len(metrics.FOLD_CLASSES), 2), np.int32)
        counts[metrics.FOLD_CLASSES.index("daec")] = (5, 1)
        before = copy.deepcopy(slo.TRACKER.classes.get("daec"))
        metrics.fold_read_status(counts)
        st = slo.TRACKER.classes["daec"]
        assert st.corrected - (before.corrected if before else 0) == 5
        assert st.uncorrectable - (before.uncorrectable if before else 0) == 1
    finally:
        slo.TRACKER.classes = saved

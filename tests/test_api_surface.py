"""Unified pool access API: shims warn, internals never use old names.

The api_redesign contract: every pool-like object exposes exactly
``read(pages, *, status=False)`` / ``write(pages, data, *, valid=None)``
/ ``migrate(src, dst, *, donate=True)`` / ``streams(pages, data=None,
*, valid=None)``.  The six legacy names (``read_pages``,
``read_pages_status``, ``write_pages``, ``read_any``,
``read_any_status``, ``write_any``) survive one release as
DeprecationWarning shims that forward bit-exactly — and nothing inside
``src/`` or ``benchmarks/`` is allowed to call them.
"""
import os
import re

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import pool as pool_lib  # noqa: E402
from repro.core.layouts import Layout  # noqa: E402
from repro.faults.shadow import ShadowedPool  # noqa: E402
from repro.shard import make_sharded_pool  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEPRECATED = ("read_pages", "read_pages_status", "write_pages",
              "read_any", "read_any_status", "write_any")


def _local_pool():
    p = pool_lib.make_pool(32, Layout.INTERWRAP, boundary=16, row_words=16)
    return p.write(np.arange(p.num_pages),
                   jnp.arange(p.num_pages * p.page_words,
                              dtype=jnp.uint32).reshape(p.num_pages, -1))


def _sharded_pool():
    sp = make_sharded_pool(32, Layout.INTERWRAP, boundary=16, row_words=16,
                           num_shards=2)
    return sp.write(np.arange(sp.num_pages),
                    jnp.arange(sp.num_pages * sp.page_words,
                               dtype=jnp.uint32).reshape(sp.num_pages, -1))


@pytest.fixture(params=["local", "sharded", "shadowed"])
def pool(request):
    if request.param == "local":
        return _local_pool()
    if request.param == "sharded":
        return _sharded_pool()
    sh = ShadowedPool(pool_lib.make_pool(32, Layout.INTERWRAP, boundary=16,
                                         row_words=16))
    return sh.write(np.arange(sh.num_pages),
                    jnp.arange(sh.num_pages * sh.page_words,
                               dtype=jnp.uint32).reshape(sh.num_pages, -1))


def test_every_shim_warns_and_forwards(pool):
    ids = np.arange(4)
    want = np.asarray(pool.read(ids))
    with pytest.warns(DeprecationWarning, match="read_pages is deprecated"):
        got = pool.read_pages(ids)
    np.testing.assert_array_equal(np.asarray(got), want)
    with pytest.warns(DeprecationWarning, match="read_any is deprecated"):
        got = pool.read_any(ids)
    np.testing.assert_array_equal(np.asarray(got), want)

    _, want_st = pool.read(ids, status=True)
    with pytest.warns(DeprecationWarning,
                      match="read_pages_status is deprecated"):
        d, st = pool.read_pages_status(ids)
    np.testing.assert_array_equal(np.asarray(st), np.asarray(want_st))
    with pytest.warns(DeprecationWarning,
                      match="read_any_status is deprecated"):
        d, st = pool.read_any_status(ids)
    np.testing.assert_array_equal(np.asarray(st), np.asarray(want_st))

    blob = jnp.full((4, pool.page_words), 7, jnp.uint32)
    with pytest.warns(DeprecationWarning, match="write_pages is deprecated"):
        pool = pool.write_pages(ids, blob)
    np.testing.assert_array_equal(np.asarray(pool.read(ids)),
                                  np.asarray(blob))
    blob2 = jnp.full((4, pool.page_words), 9, jnp.uint32)
    with pytest.warns(DeprecationWarning, match="write_any is deprecated"):
        pool = pool.write_any(ids, blob2)
    np.testing.assert_array_equal(np.asarray(pool.read(ids)),
                                  np.asarray(blob2))


def test_unified_api_is_warning_free(pool):
    import warnings
    ids = np.arange(4)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        data = pool.read(ids)
        pool.read(ids, status=True)
        pool = pool.write(ids, data)
        pool = pool.migrate(np.arange(2), np.arange(2, 4))
        pool.streams(ids.reshape(2, 2))


def test_no_internal_deprecated_call_sites():
    """Nothing under src/ or benchmarks/ may call a deprecated name —
    the shims exist for external callers only."""
    rx = re.compile(r"\.(%s)\(" % "|".join(DEPRECATED))
    offenders = []
    for root in ("src", "benchmarks"):
        for dirpath, _, files in os.walk(os.path.join(REPO, root)):
            for fn in files:
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                with open(path) as f:
                    for lineno, line in enumerate(f, 1):
                        if rx.search(line):
                            offenders.append(
                                f"{os.path.relpath(path, REPO)}:{lineno}: "
                                + line.strip())
    assert not offenders, (
        "deprecated pool API call sites in internal code:\n"
        + "\n".join(offenders))


def test_poollike_protocol_is_satisfied():
    """Static-duck check: all three pool flavours carry the full unified
    surface with keyword-only modifiers."""
    import inspect
    for obj in (_local_pool(), _sharded_pool(),
                ShadowedPool(pool_lib.make_pool(16, Layout.PACKED,
                                                boundary=8, row_words=16))):
        for name in ("read", "write", "migrate", "streams"):
            assert callable(getattr(obj, name)), (type(obj), name)
        sig = inspect.signature(type(obj).read)
        assert sig.parameters["status"].kind is inspect.Parameter.KEYWORD_ONLY
        sig = inspect.signature(type(obj).write)
        assert sig.parameters["valid"].kind is inspect.Parameter.KEYWORD_ONLY

"""Shard/local parity: the sharded pool is bit-exact with the local engine.

For every layout and shard count the same logical traffic — code-maintaining
writes, decode-corrected reads, boundary moves, in-pool migration — must
produce identical data and per-page status on a :class:`repro.shard.
ShardedPool` and a same-geometry local :class:`repro.core.pool.PoolState`,
for page-id vectors spanning shard boundaries (CREAM + SECDED + extra mix).

Capacity notes baked into the assertions: the uniform layouts shard with
*equal* capacity and identical eviction sets; PARITY duplicates its parity
tables per shard, so the sharded pool may offer slightly fewer extras — the
common id range must still behave identically.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import pool as pool_lib  # noqa: E402
from repro.core.layouts import Layout  # noqa: E402
from repro import shard  # noqa: E402
from repro.shard import router  # noqa: E402

needs_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8; the repo conftest sets it)")

ROWS, ROW_WORDS = 128, 32
LAYOUTS = [Layout.INTERWRAP, Layout.PACKED, Layout.RANK_SUBSET,
           Layout.PARITY, Layout.BASELINE_ECC]
SHARDS = [1, 2, 4, 8]


def _pools(layout, num_shards, boundary):
    sp = shard.make_sharded_pool(ROWS, layout, boundary,
                                 num_shards=num_shards, row_words=ROW_WORDS)
    lp = pool_lib.make_pool(ROWS, layout, boundary=boundary,
                            row_words=ROW_WORDS)
    return sp, lp


def _spanning_ids(rng, npages, n=48):
    """Unique page ids crossing every shard boundary: dense run + random mix.

    Unique because duplicate ids within one batch have *unspecified* contents
    (scatter order) on both engines — parity is only contractual without
    duplicates.
    """
    dense = np.arange(min(16, npages))
    rest = rng.permutation(np.arange(len(dense), npages))[:n - len(dense)]
    return np.concatenate([dense, rest]).astype(np.int32)


def _assert_parity(sp, lp, ids):
    ds, ss = sp.read(ids, status=True)
    dl, sl = lp.read(ids, status=True)
    np.testing.assert_array_equal(np.asarray(ds), np.asarray(dl))
    np.testing.assert_array_equal(np.asarray(ss), np.asarray(sl))
    # the data-only path (router-fused planned dispatch) agrees too
    np.testing.assert_array_equal(np.asarray(sp.read(ids)),
                                  np.asarray(dl))


@needs_devices
@pytest.mark.parametrize("num_shards", SHARDS)
@pytest.mark.parametrize("layout", LAYOUTS, ids=lambda l: l.value)
def test_read_write_repartition_parity(layout, num_shards):
    rng = np.random.default_rng(7 * num_shards)
    boundary = 0 if layout == Layout.BASELINE_ECC else 64
    sp, lp = _pools(layout, num_shards, boundary)

    # capacity: equal for uniform layouts; PARITY pays per-shard tables
    if layout == Layout.PARITY:
        assert sp.num_pages <= lp.num_pages
    else:
        assert sp.num_pages == lp.num_pages
    assert sp.num_rows == lp.num_rows and sp.boundary == lp.boundary

    npages = min(sp.num_pages, lp.num_pages)
    ids = _spanning_ids(rng, npages)
    data = rng.integers(0, 2**32, (len(ids), sp.page_words), dtype=np.uint32)
    sp = sp.write(ids, jnp.asarray(data))
    lp = lp.write(ids, jnp.asarray(data))
    _assert_parity(sp, lp, ids)

    # boundary moves: surviving pages stay bit-exact; ids evicted along the
    # way (extras whose storage was reclaimed) have unspecified contents
    # until rewritten, so the parity set is the still-alive prefix
    if layout != Layout.BASELINE_ECC:
        alive = np.ones(len(ids), bool)
        for nb in (0, ROWS, 64):      # upgrade-all, downgrade-all, back
            sp, si = shard.repartition(sp, nb)
            lp, li = lp.move_boundary(nb)
            if layout != Layout.PARITY:
                assert si["evicted_extra_pages"] == li["evicted_extra_pages"]
                assert sp.evict_prediction(0) == lp.evict_prediction(0)
            alive &= ids < min(sp.num_pages, lp.num_pages)
            _assert_parity(sp, lp, ids[alive])
        # a fresh write re-defines every page, incl. recreated extras
        ids2 = _spanning_ids(rng, min(sp.num_pages, lp.num_pages))
        data2 = rng.integers(0, 2**32, (len(ids2), sp.page_words),
                             dtype=np.uint32)
        sp = sp.write(ids2, jnp.asarray(data2))
        lp = lp.write(ids2, jnp.asarray(data2))
        _assert_parity(sp, lp, ids2)


@needs_devices
@pytest.mark.parametrize("num_shards", [2, 8])
def test_migrate_pages_crosses_shards(num_shards):
    rng = np.random.default_rng(3)
    sp, lp = _pools(Layout.INTERWRAP, num_shards, 64)
    # sources and destinations deliberately land on different shards and
    # span all three regions (CREAM, SECDED, extra)
    src = np.asarray([0, 1, 5, 9, 64, 65, 128, 130], np.int32)
    dst = np.asarray([3, 66, 10, 131, 2, 70, 11, 129], np.int32)
    data = rng.integers(0, 2**32, (len(src), sp.page_words), dtype=np.uint32)
    sp = sp.write(src, jnp.asarray(data))
    lp = lp.write(src, jnp.asarray(data))
    sp = sp.migrate(src, dst)
    lp = lp.migrate(src, dst)                      # local in-pool move
    _assert_parity(sp, lp, dst)
    np.testing.assert_array_equal(np.asarray(sp.read(dst)), data)


@needs_devices
@pytest.mark.parametrize("num_shards", [2, 4, 8])
def test_stream_reads_match_general_path(num_shards):
    rng = np.random.default_rng(11)
    sp, _ = _pools(Layout.INTERWRAP, num_shards, 64)
    ids = rng.permutation(ROWS)[:ROWS // 2].astype(np.int32)
    data = rng.integers(0, 2**32, (len(ids), sp.page_words), dtype=np.uint32)
    sp = sp.write(ids, jnp.asarray(data))
    # bank-aligned streams: stream s gets pages with page % S == s
    n = ROWS // num_shards
    streams = np.stack([np.arange(n) * num_shards + s
                        for s in range(num_shards)]).astype(np.int32)
    got = np.asarray(sp.streams(jnp.asarray(streams)))
    want = np.asarray(sp.read(streams.reshape(-1))).reshape(got.shape)
    np.testing.assert_array_equal(got, want)
    # and a streams write lands where the general path reads it back
    fresh = rng.integers(0, 2**32, got.shape, dtype=np.uint32)
    sp = sp.streams(jnp.asarray(streams), jnp.asarray(fresh))
    np.testing.assert_array_equal(
        np.asarray(sp.read(streams.reshape(-1))),
        fresh.reshape(-1, sp.page_words))


def test_router_roundtrip_and_geometry():
    pages = np.arange(0, 144, dtype=np.int32)      # 128 regular + 16 extra
    for S in SHARDS:
        sh, lo = router.route(jnp.asarray(pages), 128, S)
        back = router.unroute(sh, lo, 128, S)
        np.testing.assert_array_equal(np.asarray(back), pages)
        # regular pages stripe round-robin; region is preserved globally
        np.testing.assert_array_equal(np.asarray(sh[:128]),
                                      pages[:128] % S)
    with pytest.raises(ValueError):
        router.check_geometry(128, 60, 4)          # boundary not S*8-aligned
    with pytest.raises(ValueError):
        router.check_geometry(120, 0, 16)          # rows not S*8-aligned


def _property_case(layout, S, boundary, seed, n_ops):
    """One property example: interleaved write/read/repartition traffic is
    bit-exact between the sharded and the local pool."""
    sp, lp = _pools(layout, S, boundary)
    rng = np.random.default_rng(seed)
    for _ in range(n_ops):
        npages = min(sp.num_pages, lp.num_pages)
        ids = rng.permutation(npages)[:24].astype(np.int32)
        blob = rng.integers(0, 2**32, (len(ids), sp.page_words),
                            dtype=np.uint32)
        sp = sp.write(ids, jnp.asarray(blob))
        lp = lp.write(ids, jnp.asarray(blob))
        _assert_parity(sp, lp, ids)
        if layout != Layout.BASELINE_ECC and rng.random() < 0.5:
            nb = int(rng.choice([0, 64, 128]))
            sp, _ = shard.repartition(sp, nb)
            lp, _ = lp.move_boundary(nb)
            surv = ids[ids < min(sp.num_pages, lp.num_pages)]
            _assert_parity(sp, lp, surv)


@needs_devices
@pytest.mark.slow
def test_shard_parity_property():
    """Property sweep: random interleaved write/read/repartition traffic is
    bit-exact between sharded and local pools for every layout and shard
    count, with ids spanning shard boundaries. Hypothesis-driven when
    available; otherwise a seeded random sweep over the same space."""
    try:
        import hypothesis as hyp
        import hypothesis.strategies as st
    except ImportError:
        rng = np.random.default_rng(0)
        for layout in LAYOUTS:
            for S in SHARDS:
                boundary = 0 if layout == Layout.BASELINE_ECC else \
                    int(rng.choice([0, 64, 128]))
                _property_case(layout, S, boundary,
                               int(rng.integers(2**31)), n_ops=2)
        return

    @hyp.settings(max_examples=20, deadline=None,
                  suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(data=st.data())
    def run(data):
        layout = data.draw(st.sampled_from(LAYOUTS), label="layout")
        S = data.draw(st.sampled_from(SHARDS), label="shards")
        boundary = 0 if layout == Layout.BASELINE_ECC else \
            data.draw(st.sampled_from([0, 64, 128]), label="boundary")
        _property_case(layout, S, boundary,
                       data.draw(st.integers(0, 2**31 - 1), label="seed"),
                       n_ops=data.draw(st.integers(1, 3), label="ops"))

    run()

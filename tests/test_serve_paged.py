"""CREAM-Serve acceptance: paged-KV decode parity and preempt-to-host.

The paged engine's whole value rests on two claims:

  * the paged read path (one batched pool gather per decode step, on local
    or sharded pools, in CREAM or SECDED mode) produces *exactly* the
    tokens the dense-KV decode path produces;
  * preempting a sequence's KV to the host tier — by capacity pressure or
    by a mid-decode repartition that shrinks the weak-class pool — and
    resuming it later is bit-exact (same tokens as an unpreempted run).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.layouts import Layout
from repro.core.pool import PoolState
from repro.core.protection import Protection
from repro.serve import Engine, ServeRequest
from repro.vm.address_space import VirtualMemory
from repro.vm.migration import MigrationEngine

CFG = ModelConfig(name="serve-test", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=256, head_dim=16, dtype="float32")


def _prompts(n, rng=None):
    rng = rng or np.random.default_rng(1)
    return [rng.integers(0, 256, size=12).astype(np.int32)
            for _ in range(n)]


def _reqs(prompts, max_new=8):
    return [ServeRequest(f"s{i}", p, max_new)
            for i, p in enumerate(prompts)]


def _dense_reference(eng, prompts, max_new=8):
    """Greedy decode each prompt with the dense decode_step path."""
    model, params = eng.model, eng.params
    step = jax.jit(model.decode_step)
    pre = jax.jit(lambda p, t: model.prefill(p, t, eng.max_len))
    out = []
    for p in prompts:
        logits, state = pre(params, jnp.asarray(p[None, :], jnp.int32))
        tok = int(jnp.argmax(logits[0, -1]))
        gen = [tok]
        for _ in range(max_new - 1):
            lg, state = step(params, state, jnp.asarray([tok], jnp.int32))
            tok = int(jnp.argmax(lg[0]))
            gen.append(tok)
        out.append(gen)
    return out


# ---------------------------------------------------------------------------
# Parity vs the dense-KV reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shards", [1, 2])
def test_paged_decode_matches_dense(shards):
    """One batched pool gather per step == dense per-sequence KV decode,
    on both the local pool and a 2-shard CREAM-Shard pool."""
    prompts = _prompts(6)
    reqs = _reqs(prompts)
    if shards > 1:
        if jax.device_count() < shards:
            pytest.skip("needs multiple devices")
        vm = VirtualMemory(row_words=64)
        vm.add_pool("kv", 64, Layout.INTERWRAP, boundary=None,
                    shards=shards)
        eng = Engine(CFG, max_batch=4, max_len=32, vm=vm, seed=0)
    else:
        eng = Engine(CFG, max_batch=4, max_len=32, num_rows=64,
                     row_words=64, seed=0)
    eng.serve(reqs)
    ref = _dense_reference(eng, prompts)
    assert [r.generated for r in reqs] == ref


def test_secded_mode_parity_and_capacity():
    """SECDED pool mode decodes identical tokens with fewer pages."""
    prompts = _prompts(4)
    reqs_c = _reqs(prompts)
    reqs_s = _reqs(prompts)
    eng_c = Engine(CFG, max_batch=4, max_len=32, mode="cream",
                   num_rows=64, row_words=64, seed=0)
    eng_s = Engine(CFG, max_batch=4, max_len=32, mode="secded",
                   num_rows=64, row_words=64, seed=0)
    out_c = eng_c.serve(reqs_c)
    out_s = eng_s.serve(reqs_s)
    assert [r.generated for r in reqs_c] == [r.generated for r in reqs_s]
    assert out_c["device_pages"] > out_s["device_pages"]


def test_one_gather_one_scatter_per_step(monkeypatch):
    """A decode step touches the pool exactly twice: one batched read of
    every block, one batched write of the current blocks."""
    calls = {"read": 0, "write": 0}
    orig_write = PoolState.write

    def counting_write(self, pages, data, **kw):
        calls["write"] += 1
        return orig_write(self, pages, data, **kw)

    eng = Engine(CFG, max_batch=4, max_len=32, num_rows=64, row_words=64)
    for r in _reqs(_prompts(4), max_new=4):
        eng.submit(r)
    eng.poll()                      # admissions + prefill + first step
    orig_gather = eng._gather_pages

    def counting_gather(phys):
        calls["read"] += 1
        return orig_gather(phys)

    eng._gather_pages = counting_gather
    monkeypatch.setattr(PoolState, "write", counting_write)
    eng.poll()                      # a pure decode step
    assert calls == {"read": 1, "write": 1}
    b, L, maxb = eng.max_batch, eng.n_layers, eng.kv.max_blocks
    # and the read really is the whole batch's block tables at once
    rows = np.asarray([s.row if s is not None else -1
                       for s in eng.sched.slots])
    assert eng.kv.gather_phys(rows).shape == (b, L, maxb)


def test_one_dispatch_per_step_sharded(monkeypatch):
    """On a CREAM-Shard pool a decode step is ONE planned device dispatch
    for the gather and ONE for the scatter — the fused path, not a
    per-shard translate/select chain."""
    if jax.device_count() < 2:
        pytest.skip("needs multiple devices")
    from repro.shard import pool as shard_pool

    calls = {"read": 0, "write": 0}
    orig_read = shard_pool._read_planned_jitted
    orig_write = shard_pool._write_planned_jitted

    def counting_read(*a, **kw):
        calls["read"] += 1
        return orig_read(*a, **kw)

    def counting_write(*a, **kw):
        calls["write"] += 1
        return orig_write(*a, **kw)

    vm = VirtualMemory(row_words=64)
    vm.add_pool("kv", 64, Layout.INTERWRAP, boundary=None, shards=2)
    eng = Engine(CFG, max_batch=4, max_len=32, vm=vm, seed=0)
    for r in _reqs(_prompts(4), max_new=4):
        eng.submit(r)
    eng.poll()                      # admissions + prefill + first step
    monkeypatch.setattr(shard_pool, "_read_planned_jitted", counting_read)
    monkeypatch.setattr(shard_pool, "_write_planned_jitted", counting_write)
    eng.poll()                      # a pure decode step
    assert calls == {"read": 1, "write": 1}


# ---------------------------------------------------------------------------
# Preemption / capacity pressure
# ---------------------------------------------------------------------------


def test_overflow_preemption_is_bit_exact():
    """A pool too small for the working set forces preempt-to-host; the
    token streams must not change."""
    prompts = _prompts(8)
    reqs_big = _reqs(prompts)
    reqs_small = _reqs(prompts)
    Engine(CFG, max_batch=4, max_len=32, num_rows=64, row_words=64,
           seed=0).serve(reqs_big)
    out = Engine(CFG, max_batch=4, max_len=32, num_rows=24, row_words=64,
                 seed=0).serve(reqs_small)
    assert out["preemptions"] > 0
    assert [r.generated for r in reqs_small] == \
        [r.generated for r in reqs_big]


def test_tight_token_budget_resume_does_not_reset():
    """A preempted-then-resumed request carries partial ``generated``; the
    scheduler must measure the *remaining* tokens against the block table
    (not the full max_new), or it would spuriously reset the session and
    decode the tail against a truncated context."""
    prompts = _prompts(8)
    # 12-token prompt + 20 new = 31 <= the 32-token table: zero slack
    ref = _reqs(prompts, max_new=20)
    got = _reqs(prompts, max_new=20)
    Engine(CFG, max_batch=4, max_len=32, num_rows=64, row_words=64,
           seed=0).serve(ref)
    out = Engine(CFG, max_batch=4, max_len=32, num_rows=24, row_words=64,
                 seed=0).serve(got)
    assert out["preemptions"] > 0 and out["restores"] > 0
    assert out["resets"] == 0
    assert [r.generated for r in got] == [r.generated for r in ref]


def test_over_budget_request_fails_fast():
    """prompt + max_new beyond the block table raises at submit, not as a
    mid-serve crash."""
    eng = Engine(CFG, max_batch=2, max_len=32, num_rows=32, row_words=64)
    with pytest.raises(ValueError, match="exceed"):
        eng.submit(ServeRequest("x", _prompts(1)[0], max_new=30))


def _drive(repartition_at=None, new_boundary=0):
    """Serve 8 sessions, optionally repartitioning mid-decode."""
    prompts = _prompts(8)
    reqs = _reqs(prompts, max_new=10)
    eng = Engine(CFG, max_batch=4, max_len=32, num_rows=32, row_words=64,
                 seed=0)
    for r in reqs:
        eng.submit(r)
    mig = MigrationEngine(eng.vm)
    info = None
    k = 0
    while eng.sched.has_work():
        eng.poll()
        k += 1
        if k == repartition_at:
            info = mig.repartition_with_migration("kv", new_boundary)
            eng.refresh_translation()
    return [r.generated for r in reqs], eng, info


def test_midrun_repartition_preempts_and_resumes_bit_exact():
    """The satellite scenario: a mid-decode protection upgrade shrinks the
    NONE pool; mapped extra pages migrate (some to host), the scheduler
    preempts the affected batch-tier sequences, resumes them when frames
    free up, and the decoded tokens are bit-exact vs an unpreempted run."""
    base, _, _ = _drive()
    got, eng, info = _drive(repartition_at=12, new_boundary=0)
    assert info is not None and info["migrated"] > 0
    assert info["to_host"] > 0, "repartition should overflow to host"
    assert eng.sched.restores > 0, "a preempted sequence must resume"
    assert eng.vm.stats.host_reads > 0, "resume pays the page fault"
    assert got == base


def test_paid_tier_lands_on_secded_frames():
    """HRM-style tiers: paid sequences' pages must sit on frames whose
    storage class is SECDED even in cream mode."""
    eng = Engine(CFG, max_batch=2, max_len=32, mode="cream", num_rows=32,
                 secded_rows=16, row_words=64, seed=0)
    reqs = [ServeRequest("paid0", _prompts(1)[0], 4, tier="paid"),
            ServeRequest("batch0", _prompts(1)[0], 4, tier="batch")]
    eng.serve(reqs)
    kv = eng.kv
    for seq, want in (("paid0", {Protection.SECDED}),
                      ("batch0", {Protection.SECDED, Protection.NONE})):
        row = eng.sched.sessions[seq].row
        vpns = kv._table[row][kv._table[row] >= 0]
        assert len(vpns)
        prot = {eng.vm.effective_protection(kv.tenant, int(v))
                for v in vpns}
        assert prot <= want, f"{seq}: {prot}"


# ---------------------------------------------------------------------------
# Scheduled migration overlapped with decode compute
# ---------------------------------------------------------------------------


def _free_pages(vm, pool_name, n):
    """Physical frames the KV allocator is NOT using, oldest first."""
    alloc = vm.allocators[pool_name]
    phys = [p for cls in alloc.free for p in alloc.free[cls]]
    assert len(phys) >= n, "test needs spare frames"
    return np.asarray(phys[:n], np.int32), np.asarray(phys[n:2 * n], np.int32)


@pytest.mark.parametrize("shards", [1, 2])
def test_schedule_migration_overlaps_decode(shards):
    """A migration queued via :meth:`Engine.schedule_migration` runs during
    the next decode step — fused into the attend program's ppermute ring on
    sharded pools, one fused dispatch on local pools — moves the pages
    bit-exactly, and leaves the decoded tokens untouched."""
    if shards > 1 and jax.device_count() < shards:
        pytest.skip("needs multiple devices")
    prompts = _prompts(4)

    def build():
        if shards > 1:
            vm = VirtualMemory(row_words=64)
            vm.add_pool("kv", 64, Layout.INTERWRAP, boundary=None,
                        shards=shards)
            return Engine(CFG, max_batch=4, max_len=32, vm=vm, seed=0)
        return Engine(CFG, max_batch=4, max_len=32, num_rows=64,
                      row_words=64, seed=0)

    # control: same prompts, no migration
    ctl = build()
    ctl_reqs = _reqs(prompts, max_new=6)
    ctl.serve(ctl_reqs)

    eng = build()
    reqs = _reqs(prompts, max_new=6)
    for r in reqs:
        eng.submit(r)
    eng.poll()                                  # admissions + prefill + step
    src, dst = _free_pages(eng.vm, eng.pool_name, 3)
    blob = np.arange(3 * eng.pool.page_words,
                     dtype=np.uint32).reshape(3, -1) | 0xA0000000
    eng.vm.pools[eng.pool_name] = eng.pool.write(src, jnp.asarray(blob))

    ring_calls = {"n": 0}
    orig_ring = eng._attend_ring

    def counting_ring(*a):
        ring_calls["n"] += 1
        return orig_ring(*a)

    eng._attend_ring = counting_ring
    eng.schedule_migration(src, dst)
    eng.poll()                                  # the overlapped step
    assert eng._pending_migration is None
    if shards > 1:
        assert ring_calls["n"] == 1, "sharded pools must take the fused ring"
    else:
        assert ring_calls["n"] == 0
    np.testing.assert_array_equal(np.asarray(eng.pool.read(dst)), blob)
    while eng.sched.has_work():
        eng.poll()
    assert [r.generated for r in reqs] == [r.generated for r in ctl_reqs]


def test_schedule_migration_coalesces_and_validates():
    eng = Engine(CFG, max_batch=2, max_len=32, num_rows=64, row_words=64,
                 seed=0)
    eng.schedule_migration([1, 2], [3, 4])
    eng.schedule_migration([5], [6])
    src, dst = eng._pending_migration
    assert src.tolist() == [1, 2, 5] and dst.tolist() == [3, 4, 6]
    with pytest.raises(ValueError):
        eng.schedule_migration([1, 2], [3])

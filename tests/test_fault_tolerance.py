"""The recovery ladder (distributed.fault_tolerance) end to end."""
import dataclasses
import tempfile

import numpy as np
import pytest

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import injection
from repro.distributed.fault_tolerance import recover
from repro.train.trainer import make_trainer

TINY = ModelConfig(name="tiny-ft", family="dense", num_layers=2, d_model=64,
                   num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                   head_dim=16, dtype="float32")


@pytest.fixture(scope="module")
def trainer():
    tmp = tempfile.mkdtemp()
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=40,
                       scrub_every=0, checkpoint_every=5)
    tr = make_trainer(TINY, tcfg, ckpt_dir=tmp, seq_len=32, global_batch=4)
    tr.run(6)
    return tr


def test_rung1_scrub_repair(trainer):
    rng = np.random.default_rng(0)
    trainer.snapshot_moments()
    stor, _ = injection.inject_flips(trainer.moment_pool.storage, rng, 4)
    trainer.moment_pool = dataclasses.replace(trainer.moment_pool,
                                              storage=stor)
    rep = recover(trainer, "sdc_single_bit")
    assert rep.rung == "scrub-repair"
    assert rep.details["corrected"] == 4


def test_rung2_targeted_restore(trainer):
    rep = recover(trainer, "sdc_multi_bit")
    assert rep.rung == "targeted-restore"
    assert rep.details["restored_at_step"] == 5


def test_rung3_warm_restart(trainer):
    trainer.snapshot_moments()
    rep = recover(trainer, "process_crash")
    assert rep.rung == "warm-restart"
    assert rep.details["worst_status"] == 0


def test_rung5_cold_restart(trainer):
    step_before = trainer.step
    rep = recover(trainer, "host_loss")
    assert rep.rung == "cold-restart" and rep.details["restored"]
    # resumes from the last checkpoint boundary
    assert trainer.step <= step_before
    log = trainer.run(2)
    assert len(log) >= 2


# -- error-shape taxonomy vs the shadow oracle, class by class ---------------
#
# Each reliability class meets each multi-bit error shape; the verdict is
# asserted against the ground-truth ShadowedPool oracle:
#
#   DAEC    single / adjacent double     -> corrected, data exact (the
#           (one superbeat)                 interleaved dual-Hsiao splits
#                                           any adjacent pair)
#   DAEC    random double in one         -> detected, NEVER silent
#           codeword (bits b, b+2)
#   SECDED  adjacent double (one beat)   -> detected, NEVER silent (Hsiao
#           detects every 2-bit beat error — no miscorrection; the data
#           surfaces wrong but flagged)
#   SECDED  random double (two beats)    -> both corrected, data exact
#   PARITY  single / adjacent double     -> detected (different bit-mod-8
#           congruence classes in the 64B line)
#   PARITY  double in ONE congruence     -> parity cancels: the documented
#           class (bits b, b+8 of a word)    escape, silent — only the
#                                            shadow oracle sees it
#   NONE    anything                     -> silent, every time


def _shadowed(num_rows, layout, boundary, seed=0, daec_rows=0):
    import jax.numpy as jnp
    from repro.core.layouts import Layout  # noqa: F401
    from repro.core.pool import make_pool
    from repro.faults import ShadowedPool
    pool = make_pool(num_rows, layout, boundary=boundary,
                     daec_rows=daec_rows)
    sh = ShadowedPool(pool)
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 2**32, size=(sh.num_pages, sh.page_words),
                        dtype=np.uint32)
    sh.write(jnp.arange(sh.num_pages), jnp.asarray(data))
    return sh


def _flip(sh, records):
    sh.inner = dataclasses.replace(
        sh.inner, storage=injection.apply_flips(sh.inner.storage, records))


def _read_all(sh):
    import jax.numpy as jnp
    sh.census.clear()
    return np.asarray(sh.read(jnp.arange(sh.num_pages)))


def test_daec_single_corrected():
    from repro.core.layouts import Layout
    # rows [8, 16) are the SEC-DAEC tier of an all-protected pool
    sh = _shadowed(16, Layout.INTERWRAP, boundary=0, daec_rows=8)
    _flip(sh, [injection.FlipRecord(12, 3, 5, 17)])
    data = _read_all(sh)
    cen = sh.census["daec"]
    assert cen.corrected == 1 and cen.detected == 0 and cen.silent == 0
    assert (data[12] == sh._shadow[12]).all()            # exact recovery


def test_daec_adjacent_double_corrected():
    from repro.core.layouts import Layout
    sh = _shadowed(16, Layout.INTERWRAP, boundary=0, daec_rows=8)
    # the exact shape SECDED can only flag: two neighbouring bits of one
    # word. Interleaving splits them across the A/B codewords -> corrected
    _flip(sh, [injection.FlipRecord(10, 0, 10, 7),
               injection.FlipRecord(10, 0, 10, 8)])
    data = _read_all(sh)
    cen = sh.census["daec"]
    assert cen.corrected == 1 and cen.detected == 0 and cen.silent == 0
    assert (data[10] == sh._shadow[10]).all()
    # same shape, same pool, SECDED span below the tier: flagged, not fixed
    _flip(sh, [injection.FlipRecord(3, 0, 10, 7),
               injection.FlipRecord(3, 0, 10, 8)])
    data = _read_all(sh)
    cen = sh.census["secded"]
    assert cen.detected == 1 and cen.silent == 0
    assert (data[3] != sh._shadow[3]).any()


def test_daec_random_double_detected_never_silent():
    from repro.core.layouts import Layout
    sh = _shadowed(16, Layout.INTERWRAP, boundary=0, daec_rows=8)
    # bits b and b+2 of one word share parity -> same Hsiao codeword of
    # one superbeat: beyond DAEC's correction radius, flagged not silent
    _flip(sh, [injection.FlipRecord(9, 2, 4, 5),
               injection.FlipRecord(9, 2, 4, 7)])
    data = _read_all(sh)
    cen = sh.census["daec"]
    assert cen.detected == 1 and cen.silent == 0 and cen.corrected == 0
    assert (data[9] != sh._shadow[9]).any()


def test_campaign_escalates_to_daec_with_zero_silent_reads():
    """Acceptance: at memcached FIT (70k) under an adjacent-double error
    mix, the closed loop escalates SECDED -> DAEC (the SLO ceiling) and
    the shadow oracle observes zero silent reads across the whole run."""
    import jax.numpy as jnp
    from repro.core.injection import ErrorMix
    from repro.core.layouts import Layout
    from repro.core.protection import Protection
    from repro.faults import (FaultCampaign, MEMCACHED_FIT,
                              hours_for_expected_flips)
    from repro.vm import VirtualMemory, VMPolicy
    from repro.vm.policy import TenantSLO

    rng = np.random.default_rng(11)
    vm = VirtualMemory(row_words=64)
    vm.add_pool("p", 32, Layout.INTERWRAP, boundary=0)     # all SECDED
    vm.create_tenant("t", segments={"seg": Protection.SECDED})
    policy = VMPolicy(vm)
    policy.set_tenant_slo("t", "seg",
                          TenantSLO(max_error_rate=1e-3, min_reads=32,
                                    ceiling=Protection.DAEC))
    vpns = vm.alloc("t", 8, segment="seg")
    payload = rng.integers(0, 2**32, (8, vm.page_words), dtype=np.uint32)
    vm.write("t", vpns, jnp.asarray(payload))

    hours = hours_for_expected_flips(
        MEMCACHED_FIT, int(np.asarray(vm.pools["p"].storage).nbytes), 6.0)
    campaign = FaultCampaign(vm, "p", policy=policy,
                             fit_per_mbit=MEMCACHED_FIT,
                             hours_per_step=hours,
                             mix=ErrorMix(single=0.0, adjacent_double=1.0),
                             seed=11)
    escalated = []
    for _ in range(40):
        campaign.inject()
        vm.read("t", vpns)
        campaign.observe()
        escalated = campaign.escalate()
        if escalated:
            break
    assert escalated, "SLO loop never escalated under adjacent doubles"
    assert escalated[0]["to"] == Protection.DAEC
    assert vm.tenants["t"].segments["seg"] == Protection.DAEC
    for v in vpns:
        assert vm.effective_protection("t", v) == Protection.DAEC
    # keep the pressure on: post-escalation reads ride the DAEC tier
    for _ in range(6):
        campaign.inject()
        vm.read("t", vpns)
        campaign.observe()
    report = campaign.report()
    campaign.detach()
    assert campaign.injected > 0
    assert report.census["daec"].reads > 0
    # the headline contract, across every class the run touched
    for cls, cen in report.census.items():
        assert cen.silent == 0, f"silent read under {cls}"
    # adjacent doubles are *corrected* in the DAEC tier, never detected
    assert report.census["daec"].detected == 0


def test_secded_adjacent_double_detected_never_silent():
    from repro.core.layouts import Layout
    sh = _shadowed(16, Layout.INTERWRAP, boundary=0)     # all rows SECDED
    _flip(sh, [injection.FlipRecord(3, 0, 10, 7),
               injection.FlipRecord(3, 0, 10, 8)])       # one beat, 2 bits
    data = _read_all(sh)
    cen = sh.census["secded"]
    assert cen.detected == 1 and cen.silent == 0
    # flagged, not fixed: the surfaced page differs from the ground truth
    assert (data[3] != sh._shadow[3]).any()


def test_secded_random_double_both_corrected():
    from repro.core.layouts import Layout
    sh = _shadowed(16, Layout.INTERWRAP, boundary=0)
    # two independent cells in different lanes -> two different beats
    _flip(sh, [injection.FlipRecord(5, 0, 3, 1),
               injection.FlipRecord(5, 4, 9, 30)])
    data = _read_all(sh)
    cen = sh.census["secded"]
    assert cen.corrected == 1 and cen.detected == 0 and cen.silent == 0
    assert (data[5] == sh._shadow[5]).all()              # exact recovery


def test_parity_detects_singles_and_adjacent_doubles():
    from repro.core.layouts import Layout
    sh = _shadowed(16, Layout.PARITY, boundary=16)       # all rows PARITY
    _flip(sh, [injection.FlipRecord(2, 1, 4, 5)])        # single
    _read_all(sh)
    assert sh.census["parity"].detected >= 1
    assert sh.census["parity"].silent == 0
    sh2 = _shadowed(16, Layout.PARITY, boundary=16)
    # adjacent double: bits 7 and 8 fall in different mod-8 congruence
    # classes, so both interleaved parity bits flip -> detected
    _flip(sh2, [injection.FlipRecord(6, 2, 8, 7),
                injection.FlipRecord(6, 2, 8, 8)])
    _read_all(sh2)
    assert sh2.census["parity"].detected >= 1
    assert sh2.census["parity"].silent == 0


def test_parity_same_congruence_double_is_the_silent_escape():
    from repro.core.layouts import Layout
    sh = _shadowed(16, Layout.PARITY, boundary=16)
    # bits b and b+8 of one word: same bit-mod-8 class in the same 64B
    # line, so the 8-bit interleaved parity cancels — undetected by the
    # hardware, caught ONLY by the ground-truth oracle
    _flip(sh, [injection.FlipRecord(4, 3, 2, 5),
               injection.FlipRecord(4, 3, 2, 13)])
    data = _read_all(sh)
    cen = sh.census["parity"]
    assert cen.detected == 0 and cen.silent == 1
    assert (data[4] != sh._shadow[4]).any()


def test_none_silently_corrupts():
    from repro.core.layouts import Layout
    sh = _shadowed(16, Layout.INTERWRAP, boundary=16)    # all rows NONE
    _flip(sh, [injection.FlipRecord(7, 0, 0, 0)])
    data = _read_all(sh)
    cen = sh.census["none"]
    assert cen.detected == 0 and cen.corrected == 0 and cen.silent == 1
    assert (data[7] != sh._shadow[7]).any()


def test_inject_flips_vectorised_exact_count():
    """Satellite: the batched draw+dedupe keeps the exact-count contract
    at campaign scale (10^4 flips, no per-flip Python loop)."""
    from repro.core.layouts import Layout
    from repro.core.pool import make_pool
    pool = make_pool(32, Layout.INTERWRAP, boundary=16)
    rng = np.random.default_rng(7)
    stor, records = injection.inject_flips(pool.storage, rng, 10_000)
    assert len(records) == 10_000
    assert len({(c.row, c.lane, c.word, c.bit) for c in records}) == 10_000
    xor = np.asarray(stor) ^ np.asarray(pool.storage)
    assert int(np.unpackbits(xor.view(np.uint8)).sum()) == 10_000


def test_remesh_plan():
    from repro.distributed.elastic import plan_remesh
    plan = plan_remesh(old_devices=512, new_devices=496, model_axis=16)
    assert plan["usable_devices"] == 496 and plan["idle_devices"] == 0
    plan = plan_remesh(old_devices=512, new_devices=250, model_axis=16)
    assert plan["usable_devices"] == 240 and plan["idle_devices"] == 10
    assert plan["batch_scale"] < 1.0

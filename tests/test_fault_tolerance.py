"""The recovery ladder (distributed.fault_tolerance) end to end."""
import dataclasses
import tempfile

import numpy as np
import pytest

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import injection
from repro.distributed.fault_tolerance import recover
from repro.train.trainer import make_trainer

TINY = ModelConfig(name="tiny-ft", family="dense", num_layers=2, d_model=64,
                   num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                   head_dim=16, dtype="float32")


@pytest.fixture(scope="module")
def trainer():
    tmp = tempfile.mkdtemp()
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=40,
                       scrub_every=0, checkpoint_every=5)
    tr = make_trainer(TINY, tcfg, ckpt_dir=tmp, seq_len=32, global_batch=4)
    tr.run(6)
    return tr


def test_rung1_scrub_repair(trainer):
    rng = np.random.default_rng(0)
    trainer.snapshot_moments()
    stor, _ = injection.inject_flips(trainer.moment_pool.storage, rng, 4)
    trainer.moment_pool = dataclasses.replace(trainer.moment_pool,
                                              storage=stor)
    rep = recover(trainer, "sdc_single_bit")
    assert rep.rung == "scrub-repair"
    assert rep.details["corrected"] == 4


def test_rung2_targeted_restore(trainer):
    rep = recover(trainer, "sdc_multi_bit")
    assert rep.rung == "targeted-restore"
    assert rep.details["restored_at_step"] == 5


def test_rung3_warm_restart(trainer):
    trainer.snapshot_moments()
    rep = recover(trainer, "process_crash")
    assert rep.rung == "warm-restart"
    assert rep.details["worst_status"] == 0


def test_rung5_cold_restart(trainer):
    step_before = trainer.step
    rep = recover(trainer, "host_loss")
    assert rep.rung == "cold-restart" and rep.details["restored"]
    # resumes from the last checkpoint boundary
    assert trainer.step <= step_before
    log = trainer.run(2)
    assert len(log) >= 2


def test_remesh_plan():
    from repro.distributed.elastic import plan_remesh
    plan = plan_remesh(old_devices=512, new_devices=496, model_axis=16)
    assert plan["usable_devices"] == 496 and plan["idle_devices"] == 0
    plan = plan_remesh(old_devices=512, new_devices=250, model_axis=16)
    assert plan["usable_devices"] == 240 and plan["idle_devices"] == 10
    assert plan["batch_scale"] < 1.0

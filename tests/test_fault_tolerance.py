"""The recovery ladder (distributed.fault_tolerance) end to end."""
import dataclasses
import tempfile

import numpy as np
import pytest

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import injection
from repro.distributed.fault_tolerance import recover
from repro.train.trainer import make_trainer

TINY = ModelConfig(name="tiny-ft", family="dense", num_layers=2, d_model=64,
                   num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                   head_dim=16, dtype="float32")


@pytest.fixture(scope="module")
def trainer():
    tmp = tempfile.mkdtemp()
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=40,
                       scrub_every=0, checkpoint_every=5)
    tr = make_trainer(TINY, tcfg, ckpt_dir=tmp, seq_len=32, global_batch=4)
    tr.run(6)
    return tr


def test_rung1_scrub_repair(trainer):
    rng = np.random.default_rng(0)
    trainer.snapshot_moments()
    stor, _ = injection.inject_flips(trainer.moment_pool.storage, rng, 4)
    trainer.moment_pool = dataclasses.replace(trainer.moment_pool,
                                              storage=stor)
    rep = recover(trainer, "sdc_single_bit")
    assert rep.rung == "scrub-repair"
    assert rep.details["corrected"] == 4


def test_rung2_targeted_restore(trainer):
    rep = recover(trainer, "sdc_multi_bit")
    assert rep.rung == "targeted-restore"
    assert rep.details["restored_at_step"] == 5


def test_rung3_warm_restart(trainer):
    trainer.snapshot_moments()
    rep = recover(trainer, "process_crash")
    assert rep.rung == "warm-restart"
    assert rep.details["worst_status"] == 0


def test_rung5_cold_restart(trainer):
    step_before = trainer.step
    rep = recover(trainer, "host_loss")
    assert rep.rung == "cold-restart" and rep.details["restored"]
    # resumes from the last checkpoint boundary
    assert trainer.step <= step_before
    log = trainer.run(2)
    assert len(log) >= 2


# -- error-shape taxonomy vs the shadow oracle, class by class ---------------
#
# Each reliability class meets each multi-bit error shape; the verdict is
# asserted against the ground-truth ShadowedPool oracle:
#
#   SECDED  adjacent double (one beat)   -> detected, NEVER silent (Hsiao
#           detects every 2-bit beat error — no miscorrection; the data
#           surfaces wrong but flagged)
#   SECDED  random double (two beats)    -> both corrected, data exact
#   PARITY  single / adjacent double     -> detected (different bit-mod-8
#           congruence classes in the 64B line)
#   PARITY  double in ONE congruence     -> parity cancels: the documented
#           class (bits b, b+8 of a word)    escape, silent — only the
#                                            shadow oracle sees it
#   NONE    anything                     -> silent, every time


def _shadowed(num_rows, layout, boundary, seed=0):
    import jax.numpy as jnp
    from repro.core.layouts import Layout  # noqa: F401
    from repro.core.pool import make_pool
    from repro.faults import ShadowedPool
    pool = make_pool(num_rows, layout, boundary=boundary)
    sh = ShadowedPool(pool)
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 2**32, size=(sh.num_pages, sh.page_words),
                        dtype=np.uint32)
    sh.write(jnp.arange(sh.num_pages), jnp.asarray(data))
    return sh


def _flip(sh, records):
    sh.inner = dataclasses.replace(
        sh.inner, storage=injection.apply_flips(sh.inner.storage, records))


def _read_all(sh):
    import jax.numpy as jnp
    sh.census.clear()
    return np.asarray(sh.read(jnp.arange(sh.num_pages)))


def test_secded_adjacent_double_detected_never_silent():
    from repro.core.layouts import Layout
    sh = _shadowed(16, Layout.INTERWRAP, boundary=0)     # all rows SECDED
    _flip(sh, [injection.FlipRecord(3, 0, 10, 7),
               injection.FlipRecord(3, 0, 10, 8)])       # one beat, 2 bits
    data = _read_all(sh)
    cen = sh.census["secded"]
    assert cen.detected == 1 and cen.silent == 0
    # flagged, not fixed: the surfaced page differs from the ground truth
    assert (data[3] != sh._shadow[3]).any()


def test_secded_random_double_both_corrected():
    from repro.core.layouts import Layout
    sh = _shadowed(16, Layout.INTERWRAP, boundary=0)
    # two independent cells in different lanes -> two different beats
    _flip(sh, [injection.FlipRecord(5, 0, 3, 1),
               injection.FlipRecord(5, 4, 9, 30)])
    data = _read_all(sh)
    cen = sh.census["secded"]
    assert cen.corrected == 1 and cen.detected == 0 and cen.silent == 0
    assert (data[5] == sh._shadow[5]).all()              # exact recovery


def test_parity_detects_singles_and_adjacent_doubles():
    from repro.core.layouts import Layout
    sh = _shadowed(16, Layout.PARITY, boundary=16)       # all rows PARITY
    _flip(sh, [injection.FlipRecord(2, 1, 4, 5)])        # single
    _read_all(sh)
    assert sh.census["parity"].detected >= 1
    assert sh.census["parity"].silent == 0
    sh2 = _shadowed(16, Layout.PARITY, boundary=16)
    # adjacent double: bits 7 and 8 fall in different mod-8 congruence
    # classes, so both interleaved parity bits flip -> detected
    _flip(sh2, [injection.FlipRecord(6, 2, 8, 7),
                injection.FlipRecord(6, 2, 8, 8)])
    _read_all(sh2)
    assert sh2.census["parity"].detected >= 1
    assert sh2.census["parity"].silent == 0


def test_parity_same_congruence_double_is_the_silent_escape():
    from repro.core.layouts import Layout
    sh = _shadowed(16, Layout.PARITY, boundary=16)
    # bits b and b+8 of one word: same bit-mod-8 class in the same 64B
    # line, so the 8-bit interleaved parity cancels — undetected by the
    # hardware, caught ONLY by the ground-truth oracle
    _flip(sh, [injection.FlipRecord(4, 3, 2, 5),
               injection.FlipRecord(4, 3, 2, 13)])
    data = _read_all(sh)
    cen = sh.census["parity"]
    assert cen.detected == 0 and cen.silent == 1
    assert (data[4] != sh._shadow[4]).any()


def test_none_silently_corrupts():
    from repro.core.layouts import Layout
    sh = _shadowed(16, Layout.INTERWRAP, boundary=16)    # all rows NONE
    _flip(sh, [injection.FlipRecord(7, 0, 0, 0)])
    data = _read_all(sh)
    cen = sh.census["none"]
    assert cen.detected == 0 and cen.corrected == 0 and cen.silent == 1
    assert (data[7] != sh._shadow[7]).any()


def test_inject_flips_vectorised_exact_count():
    """Satellite: the batched draw+dedupe keeps the exact-count contract
    at campaign scale (10^4 flips, no per-flip Python loop)."""
    from repro.core.layouts import Layout
    from repro.core.pool import make_pool
    pool = make_pool(32, Layout.INTERWRAP, boundary=16)
    rng = np.random.default_rng(7)
    stor, records = injection.inject_flips(pool.storage, rng, 10_000)
    assert len(records) == 10_000
    assert len({(c.row, c.lane, c.word, c.bit) for c in records}) == 10_000
    xor = np.asarray(stor) ^ np.asarray(pool.storage)
    assert int(np.unpackbits(xor.view(np.uint8)).sum()) == 10_000


def test_remesh_plan():
    from repro.distributed.elastic import plan_remesh
    plan = plan_remesh(old_devices=512, new_devices=496, model_axis=16)
    assert plan["usable_devices"] == 496 and plan["idle_devices"] == 0
    plan = plan_remesh(old_devices=512, new_devices=250, model_axis=16)
    assert plan["usable_devices"] == 240 and plan["idle_devices"] == 10
    assert plan["batch_scale"] < 1.0

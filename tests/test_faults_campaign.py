"""CREAM-Campaign acceptance: live injection, the SLO loop, both planes.

The headline contract (ISSUE 7 / paper §2.2 + HRM's per-class error
tolerance): at memcached-scale FIT rates a paid/SECDED tenant serves
**zero corrupted tokens** — no silent corruption ever (structural: Hsiao
detects all double-beat errors) and, with scrubbing keeping singles from
accumulating, no detected-uncorrectable reads either — while batch/NONE
tenants degrade gracefully and are auto-upgraded through the zero-loss
migration once their observed error rate crosses the tenant SLO.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.injection import ErrorMix, FaultModel, SINGLES
from repro.core.layouts import GROUP_ROWS, Layout
from repro.core.pool import make_pool
from repro.core.protection import Protection
from repro.faults import (FaultCampaign, MEMCACHED_FIT,
                          hours_for_expected_flips)
from repro.serve import Engine, ServeRequest
from repro.vm.address_space import VirtualMemory
from repro.vm.policy import TenantSLO, VMPolicy

CFG = ModelConfig(name="faults-test", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=256, head_dim=16, dtype="float32")


# -- satellite: FaultModel on ShardedPool shards ------------------------------

def test_step_pool_shard_local_parity():
    """Same seed, same global geometry -> identical injection on a local
    pool and a CREAM-Shard pool (global row r lives at shard r % S, local
    row r // S — the router convention). Page-level parity is asserted in
    the SECDED region, whose layout is row-local and therefore identical
    in both planes; the CREAM region wraps page data over a *group* of
    rows, and each shard groups its own (strided) local rows, so there the
    contract is cell-level: same global storage cells flip either way."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.shard import make_sharded_pool
    S = min(4, jax.device_count())
    num_rows = 32
    rng = np.random.default_rng(3)
    mix = ErrorMix(single=0.7, adjacent_double=0.2, random_double=0.1)
    pages = jnp.arange(num_rows)

    # part 1: all-SECDED pool, end-to-end page/status parity
    data = rng.integers(0, 2**32, size=(num_rows, 8 * 64), dtype=np.uint32)
    local = make_pool(num_rows, Layout.INTERWRAP, boundary=0, row_words=64)
    local = local.write(pages, jnp.asarray(data))
    sharded = make_sharded_pool(num_rows, Layout.INTERWRAP, boundary=0,
                                num_shards=S, row_words=64)
    sharded = sharded.write(pages, jnp.asarray(data))
    fm_l = FaultModel.make(11, soft_rate=0.0, shape=(num_rows, 9, 64),
                           mix=mix, n_hard=3)
    fm_s = FaultModel.make(11, soft_rate=0.0, shape=(num_rows, 9, 64),
                           mix=mix, n_hard=3)
    # give the soft process something to do (same accelerated rate)
    fm_l.soft_rate_per_gb_per_step = fm_s.soft_rate_per_gb_per_step = 1e7
    local, n_l = fm_l.step_pool(local)
    sharded, n_s = fm_s.step_pool(sharded)
    assert n_l == n_s > 0
    got_l, st_l = local.read(pages, status=True)
    got_s, st_s = sharded.read(pages, status=True)
    np.testing.assert_array_equal(np.asarray(got_l), np.asarray(got_s))
    np.testing.assert_array_equal(np.asarray(st_l), np.asarray(st_s))

    # part 2: all-CREAM pool, cell-level parity on planted raw storage
    raw = rng.integers(0, 2**32, size=(num_rows, 9, 64), dtype=np.uint32)
    local2 = dataclasses.replace(
        make_pool(num_rows, Layout.INTERWRAP, boundary=num_rows,
                  row_words=64),
        storage=jnp.asarray(raw))
    sh2 = make_sharded_pool(num_rows, Layout.INTERWRAP, boundary=num_rows,
                            num_shards=S, row_words=64)
    planted = raw.reshape(num_rows // S, S, 9, 64).swapaxes(0, 1)
    sh2 = dataclasses.replace(
        sh2, storage=jax.device_put(
            jnp.asarray(planted), NamedSharding(sh2.mesh, P("banks"))))
    fm_l2 = FaultModel.make(13, soft_rate=0.0, shape=(num_rows, 9, 64),
                            mix=mix, n_hard=2)
    fm_s2 = FaultModel.make(13, soft_rate=0.0, shape=(num_rows, 9, 64),
                            mix=mix, n_hard=2)
    fm_l2.soft_rate_per_gb_per_step = 1e7
    fm_s2.soft_rate_per_gb_per_step = 1e7
    local2, n2_l = fm_l2.step_pool(local2)
    sh2, n2_s = fm_s2.step_pool(sh2)
    assert n2_l == n2_s > 0
    rec = np.asarray(sh2.storage).swapaxes(0, 1).reshape(num_rows, 9, 64)
    np.testing.assert_array_equal(rec, np.asarray(local2.storage))


# -- the SLO escalation loop, unit level --------------------------------------

def test_auto_escalation_via_zero_loss_migration():
    vm = VirtualMemory(row_words=64)
    vm.add_pool("p", 32, Layout.INTERWRAP, boundary=16)   # 16 NONE + extras
    vm.create_tenant("t", segments={"seg": Protection.NONE})
    policy = VMPolicy(vm)
    policy.set_tenant_slo("t", "seg", TenantSLO(max_error_rate=1e-2,
                                                min_reads=10))
    vpns = vm.alloc("t", 4, segment="seg")
    payload = np.arange(4 * vm.page_words, dtype=np.uint32).reshape(4, -1)
    vm.write("t", vpns, payload)
    assert all(vm.effective_protection("t", v) == Protection.NONE
               for v in vpns)
    policy.observe_reads("t", "seg", reads=100, silent=5)
    done = policy.auto_escalate()
    assert len(done) == 1
    esc = done[0]
    assert esc["from"] == Protection.NONE
    assert esc["to"] == Protection.PARITY and esc["moved"] == 4
    # contract updated everywhere: segment default + every PTE
    assert vm.tenants["t"].segments["seg"] == Protection.PARITY
    # pages landed on frames of class >= PARITY (SECDED here), zero loss
    for v in vpns:
        assert vm.effective_protection("t", v) in (Protection.PARITY,
                                                   Protection.SECDED)
    np.testing.assert_array_equal(vm.read("t", vpns), payload)
    # a second breach escalates the rest of the way, then caps out
    policy.observe_reads("t", "seg", reads=100, silent=5)
    assert [e["to"] for e in policy.auto_escalate()] == [Protection.SECDED]
    policy.observe_reads("t", "seg", reads=100, silent=5)
    assert policy.auto_escalate() == []      # already at the ceiling


# -- the end-to-end acceptance campaign ---------------------------------------

@pytest.fixture(scope="module")
def campaign_run():
    """Serve a paid + batch trace under memcached-FIT injection with the
    closed loop armed; hand the final state to the assertions."""
    num_rows = 64
    boundary = 2 * GROUP_ROWS        # 16 NONE rows (+2 extras), 48 SECDED
    vm = VirtualMemory(row_words=64)
    vm.add_pool("kv", num_rows, Layout.INTERWRAP, boundary=boundary)
    eng = Engine(CFG, max_batch=4, max_len=48, vm=vm, pool="kv",
                 mode="cream", row_words=64, max_sessions=32)
    policy = VMPolicy(vm)
    policy.set_tenant_slo("serve", "batch",
                          TenantSLO(max_error_rate=1e-3, min_reads=64,
                                    ceiling=Protection.SECDED))
    hours = hours_for_expected_flips(
        MEMCACHED_FIT, int(np.asarray(vm.pools["kv"].storage).nbytes), 5.0)
    campaign = FaultCampaign(vm, "kv", policy=policy, engine=eng,
                             fit_per_mbit=MEMCACHED_FIT,
                             hours_per_step=hours, mix=SINGLES,
                             n_hard=0, seed=5)
    rng = np.random.default_rng(5)
    prompts = {s: rng.integers(0, CFG.vocab_size, size=12).astype(np.int32)
               for s in range(4)}
    reqs = [ServeRequest(f"s{s}", prompts[s], 6,
                         tier="paid" if s == 0 else "batch")
            for _ in range(6) for s in range(4)]
    for r in reqs:
        eng.submit(r)
    done = []
    while eng.sched.has_work():
        done.extend(eng.poll())
        campaign.tick()
        if campaign.steps % 3 == 0:  # periodic repair: singles get READ
            policy.scrub_all()       # (-> corrected) before they pair up
    campaign.observe()
    report = campaign.report()
    campaign.detach()
    return vm, eng, policy, campaign, report, done, reqs


def test_paid_secded_zero_corrupted_tokens(campaign_run):
    vm, eng, policy, campaign, report, done, reqs = campaign_run
    assert campaign.injected > 0, "campaign never injected"
    cen = report.census["secded"]
    assert cen.reads > 0
    # the paid-tier guarantee: nothing silent, ever (structural), and with
    # per-tick scrubbing nothing uncorrectable either -> every token the
    # SECDED class served was computed from exact, correct bytes
    assert cen.silent == 0
    assert cen.detected == 0
    assert cen.corrected > 0         # the injection did hit SECDED pages


def test_batch_degrades_and_auto_upgrades(campaign_run):
    vm, eng, policy, campaign, report, done, reqs = campaign_run
    # batch/NONE pages silently corrupted (caught only by the oracle) ...
    assert report.census["none"].silent > 0
    # ... every request still completed (graceful degradation) ...
    assert len(done) == len(reqs)
    # ... and the SLO loop upgraded the batch segment within the run
    assert report.escalations, "tenant SLO never escalated"
    assert campaign.first_escalation_step is not None
    assert campaign.first_escalation_step <= 40
    first = report.escalations[0]
    assert first["tenant"] == "serve" and first["segment"] == "batch"
    assert first["moved"] > 0
    # post-escalation, every device-resident batch page sits on a frame
    # at least as strong as the escalated contract
    target = vm.tenants["serve"].segments["batch"]
    space = vm.tenants["serve"]
    for vpn, pte in space.entries.items():
        if pte.segment == "batch" and pte.pool is not None:
            from repro.core.protection import at_least
            assert at_least(vm.effective_protection("serve", vpn), target)


def test_observations_flow_into_monitor_and_slo(campaign_run):
    vm, eng, policy, campaign, report, done, reqs = campaign_run
    from repro.obs import slo
    # class-level counts reached the global tracker ...
    assert slo.TRACKER.classes["none"].silent > 0
    assert slo.TRACKER.classes["secded"].silent == 0
    # ... the per-tenant census too (scoped tenant/segment) ...
    assert slo.TRACKER.tenants["serve/batch"].reads > 0
    # ... and the monitor's windowed rate saw the campaign errors
    assert policy.monitor.rate("kv") > 0
    report_rows = slo.TRACKER.report()
    assert any(s.scope == "tenant/serve/batch" for s in report_rows)


def test_shadow_survives_repartition():
    """Boundary moves through the wrapper keep oracle and allocator sane."""
    from repro.faults import ShadowedPool
    vm = VirtualMemory(row_words=64)
    vm.add_pool("p", 32, Layout.INTERWRAP, boundary=16)
    sh = ShadowedPool(vm.pools["p"])
    vm.pools["p"] = sh
    vm.create_tenant("t", segments={"seg": Protection.NONE})
    vpns = vm.alloc("t", 3, segment="seg")
    payload = np.arange(3 * vm.page_words, dtype=np.uint32).reshape(3, -1)
    vm.write("t", vpns, payload)
    from repro.vm.migration import MigrationEngine
    eng = MigrationEngine(vm)
    eng.repartition_with_migration("p", 32)      # grow CREAM under the oracle
    assert vm.pools["p"] is sh                   # wrapper survived
    np.testing.assert_array_equal(vm.read("t", vpns), payload)
    eng.repartition_with_migration("p", 0)       # all-SECDED, extras doomed
    np.testing.assert_array_equal(vm.read("t", vpns), payload)
    assert sh.num_pages == 32


def test_faultmodel_sticky_hard_cells():
    pool = make_pool(16, Layout.INTERWRAP, boundary=0, row_words=64)
    fm = FaultModel.make(2, soft_rate=0.0, n_hard=4, shape=(16, 9, 64))
    pool, n = fm.step_pool(pool)
    assert n == 4
    pool, stats = pool.scrub()                   # repair in place
    assert stats.corrected > 0
    pool, n = fm.step_pool(pool)                 # stuck-at-1 re-asserts
    arr = np.asarray(pool.storage)
    for c in fm.hard_cells:
        assert arr[c.row, c.lane, c.word] & np.uint32(1 << c.bit)

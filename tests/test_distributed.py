"""Sharding rules, data pipeline determinism, dry-run cell (subprocess)."""
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config


def _mini_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_cover_tree(arch):
    """Every parameter leaf gets a sharding; specs never exceed rank."""
    from repro.launch import shardings as sh
    cfg = get_config(arch)
    mesh = _mini_mesh()
    specs = sh.param_specs(cfg, mesh)
    for leaf in jax.tree.leaves(specs):
        assert leaf.sharding is not None
        assert len(leaf.sharding.spec) <= len(leaf.shape)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "jamba-1.5-large-398b",
                                  "xlstm-1.3b", "kimi-k2-1t-a32b"])
def test_decode_state_specs_cover_tree(arch):
    from repro.launch import shardings as sh
    cfg = get_config(arch)
    mesh = _mini_mesh()
    st = sh.decode_state_specs(cfg, SHAPES["decode_32k"], mesh)
    assert "cache_len" in st
    for leaf in jax.tree.leaves(st):
        assert leaf.sharding is not None


def test_data_pipeline_deterministic_and_sharded():
    from repro.data.pipeline import DataConfig, SyntheticStream
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=8, seed=7)
    full = SyntheticStream(cfg, num_shards=1, shard_id=0)
    shards = [SyntheticStream(cfg, num_shards=4, shard_id=i)
              for i in range(4)]
    b_full = full.batch(11)
    b_parts = np.concatenate([np.asarray(s.batch(11)["tokens"])
                              for s in shards])
    # per-shard batches are deterministic and disjoint slices of the step
    assert b_parts.shape == b_full["tokens"].shape
    again = np.concatenate([np.asarray(s.batch(11)["tokens"])
                            for s in shards])
    assert (b_parts == again).all()
    # labels are next-token shifted
    b = shards[0].batch(3)
    assert (np.asarray(b["tokens"][:, 1:]) ==
            np.asarray(b["labels"][:, :-1])).all()


def test_elastic_reshard_roundtrip():
    from repro.distributed.elastic import reshard_tree
    tree = {"a": np.arange(64, dtype=np.float32).reshape(8, 8),
            "b": np.ones((4,), np.float32)}
    shards4 = reshard_tree(tree, num_shards=4)
    rebuilt = reshard_tree(shards4, num_shards=2)
    merged = reshard_tree(rebuilt, num_shards=1)[0]
    assert (merged["a"] == tree["a"]).all()
    assert (merged["b"] == tree["b"]).all()


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """One real dry-run cell on the 512-device production mesh."""
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch",
           "qwen3-0.6b", "--shape", "decode_32k", "--mesh", "single",
           "--out", "/tmp/dryrun_test"]
    try:
        r = subprocess.run(cmd, capture_output=True, text=True, timeout=420,
                           env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                "HOME": "/root"})
    except subprocess.TimeoutExpired:
        # the 512-host-device XLA compile is environment-bound: it can
        # exceed the budget on small CPU hosts — not a correctness signal
        pytest.skip("dryrun compile exceeded 420s on this host")
    assert "[OK ]" in r.stdout, r.stdout + r.stderr


def test_dram_sim_layout_invariants():
    from benchmarks.dram_sim import run_workload
    from repro.core.layouts import Layout
    base = run_workload(Layout.BASELINE_ECC, 64, 5, n_mem_intensive=2,
                        n_requests=300)
    packed = run_workload(Layout.PACKED, 64, 5, n_mem_intensive=2,
                          n_requests=300)
    wrap = run_workload(Layout.INTERWRAP, 64, 5, n_mem_intensive=2,
                        n_requests=300)
    # paper Fig. 10a: packed issues ~2x device ops; interwrap none extra
    assert packed.device_ops / packed.requests > 1.8
    assert wrap.device_ops == wrap.requests
    # paper Fig. 9 ordering
    assert packed.finish_cycle > base.finish_cycle
    assert wrap.finish_cycle < packed.finish_cycle


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_no_large_param_replicated(arch):
    """Regression guard for §Perf iteration 5: every big layer weight must
    be sharded on the production mesh — a replicated multi-million-param
    tensor means a spec rule stopped matching real paths."""
    import numpy as np
    from repro.distributed.sharding import spec_for_param, tree_paths
    from repro.models import transformer
    import jax.numpy as jnp

    cfg = get_config(arch)
    shapes = jax.eval_shape(
        lambda key: transformer.init_params(cfg, key),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    for path, leaf in tree_paths(shapes).items():
        n = int(np.prod(leaf.shape))
        if n < 2_000_000:
            continue
        stacked = path.startswith("stages")
        ndim = leaf.ndim - 1 if stacked else leaf.ndim
        spec = spec_for_param(path, stacked, ndim)
        assert any(e is not None for e in spec), \
            f"{arch}: {path} {leaf.shape} would be replicated"

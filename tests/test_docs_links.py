"""Docs stay navigable: every relative link in README/docs/ must resolve.

Mirrors the CI "Docs link check" step (``tools/check_links.py``) so a dead
link fails locally too, and sanity-checks that the paper map covers every
``fig*`` benchmark row family the suites actually emit.
"""
import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def test_no_dead_relative_links():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_links.py")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr + proc.stdout


def test_paper_map_covers_every_fig_row_family():
    """docs/paper-map.md must mention every fig-row prefix emitted by the
    benchmark suites (fig4_websearch, fig8_memcached, fig8_memcached_real,
    fig9_ws, fig9_real, fig12_sensitivity, ...)."""
    fams = set()
    for bench in (ROOT / "benchmarks").glob("bench_*.py"):
        for m in re.finditer(r"f?\"(fig\d+_[a-z]+(?:_real)?)",
                             bench.read_text()):
            fams.add(m.group(1))
    assert fams, "no fig rows found — benchmark layout changed?"
    paper_map = (ROOT / "docs" / "paper-map.md").read_text()
    missing = sorted(f for f in fams if f not in paper_map)
    assert not missing, f"docs/paper-map.md misses row families: {missing}"

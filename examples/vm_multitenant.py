"""CREAM-VM end to end: two tenants, a weakening DIMM, zero lost pages.

Walks the full OS-level story on top of the paper's mechanism:

  1. a mixed pool (half CREAM, half SECDED) plus a small all-SECDED spare;
  2. a "secure" tenant (SECDED contract) and a "bulk" tenant (protection-
     free, so it gets the reclaimed extra pages);
  3. an uncorrectable fault appears; the scrub->monitor->recommend loop
     upgrades the pool to full SECDED — and the VM migrates the evicted
     extra pages live instead of dropping them.

Run: PYTHONPATH=src python examples/vm_multitenant.py
"""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.layouts import Layout
from repro.core.monitor import MonitorConfig
from repro.core.protection import Protection
from repro.vm import MigrationEngine, VirtualMemory, VMPolicy

rng = np.random.default_rng(0)

# 1) Pools under VM management.
vm = VirtualMemory(row_words=64)
vm.add_pool("dimm0", 32, Layout.INTERWRAP, boundary=16)   # mixed, 2 extras
vm.add_pool("spare", 16, Layout.INTERWRAP, boundary=0)    # all SECDED
engine = MigrationEngine(vm, use_kernel=True)
policy = VMPolicy(vm, engine, MonitorConfig(window=2, upgrade_threshold=1e-9))

# 2) Tenants with different reliability contracts.
vm.create_tenant("secure", default_reliability=Protection.SECDED)
vm.create_tenant("bulk", default_reliability=Protection.NONE)
sec = vm.alloc("secure", 4)
bulk = vm.alloc("bulk", 18)            # fills the CREAM half + both extras
dsec = jnp.asarray(rng.integers(0, 2**32, (4, vm.page_words), dtype=np.uint32))
dbulk = jnp.asarray(rng.integers(0, 2**32, (18, vm.page_words),
                                 dtype=np.uint32))
vm.write("secure", sec, dsec)
vm.write("bulk", bulk, dbulk)
rep = vm.capacity_report()
print(f"dimm0: {rep['dimm0']['pages']} pages "
      f"(+{rep['dimm0']['extra_pages']} reclaimed), "
      f"util={vm.utilisation():.2f}")

# 3) The DIMM weakens: an uncorrectable fault lands in a SECDED row.
storage = vm.pools["dimm0"].storage
storage = storage.at[28, 3, 5].set(storage[28, 3, 5] ^ jnp.uint32(0b11))
vm.pools["dimm0"] = dataclasses.replace(vm.pools["dimm0"], storage=storage)

scrubbed, performed = policy.step()    # scrub -> monitor -> repartition+migrate
print(f"scrub saw uncorrectable={scrubbed['dimm0'].detected_uncorrectable}; "
      f"transactions: {performed}")
print(f"dimm0 boundary now {vm.pools['dimm0'].boundary} (full SECDED), "
      f"migrated {engine.stats.pages_moved} pages "
      f"({engine.stats.to_host} to host swap)")

# 4) Nothing was lost.
assert (vm.read("secure", sec) == dsec).all()
assert (vm.read("bulk", bulk) == dbulk).all()
print("all tenant pages intact — zero lost pages")

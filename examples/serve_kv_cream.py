"""Serving with a CREAM-expanded sequence cache: the paper's capacity win, live.

Serves the same multi-turn request mix twice — once with the pool in SECDED
mode, once in CREAM (Inter-Wrap) mode with +12.5% device pages — and prints
page-fault rates and throughput. The CREAM run keeps more parked sequences
device-resident.

Run: PYTHONPATH=src python examples/serve_kv_cream.py
"""
import numpy as np

from repro.configs.base import ModelConfig
from repro.serve.engine import Engine, Request
from repro.serve.kv_cache import SequenceCache

cfg = ModelConfig(name="serve-demo", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=256, head_dim=16, dtype="float32")

for mode in ("secded", "cream"):
    rng = np.random.default_rng(0)
    reqs = [Request(f"s{i}", rng.integers(0, 256, size=24).astype(np.int32),
                    max_new=10) for i in range(10)]
    cache = SequenceCache(num_rows=48, mode=mode)
    eng = Engine(cfg, batch_size=4, max_len=64, cache=cache)
    out = eng.serve(reqs, steps_per_turn=4)
    print(f"{mode:7s}: pages={out['device_pages']:3d} "
          f"fault_rate={out['fault_rate']:.3f} "
          f"tokens/s={out['tokens_per_s']:.1f} "
          f"evictions={out['evictions']}")

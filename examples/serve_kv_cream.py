"""Serving with a CREAM-paged KV cache: the paper's capacity win, live.

Serves the same multi-turn session mix twice — once with the KV pool in
SECDED mode, once in CREAM (Inter-Wrap) mode with +12.5% device pages.
Every sequence's KV blocks live directly in pool pages (one batched page
gather per decode step); sessions park on the pool between turns, and
when frames run out the scheduler preempts the least-recently-used
batch-tier session to the host swap tier. The CREAM run keeps more
sessions device-resident, so fewer turns pay the host round-trip.

Run: PYTHONPATH=src python examples/serve_kv_cream.py
"""
import numpy as np

from repro.configs.base import ModelConfig
from repro.serve import Engine, ServeRequest

cfg = ModelConfig(name="serve-demo", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=256, head_dim=16, dtype="float32")

N_SESSIONS, N_TURNS = 10, 24
for mode in ("secded", "cream"):
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 256, size=12).astype(np.int32)
               for _ in range(N_SESSIONS)]
    # several turns per session: later turns resume the parked KV
    reqs = [ServeRequest(f"s{t % N_SESSIONS}", prompts[t % N_SESSIONS],
                         max_new=6) for t in range(N_TURNS)]
    eng = Engine(cfg, max_batch=4, max_len=48, mode=mode, num_rows=40,
                 row_words=64)
    out = eng.serve(reqs)
    print(f"{mode:7s}: pages={out['device_pages']:3d} "
          f"tokens/s={out['tokens_per_s']:7.1f} "
          f"p99={out['p99_latency_ms']:7.1f}ms "
          f"preempt={out['preemptions']} restores={out['restores']} "
          f"host_reads={out['host_reads']}")

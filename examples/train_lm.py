"""End-to-end driver: train a ~110M-parameter LM with the full CREAM stack.

Exercises every training-path layer: synthetic data pipeline, scan-stage
transformer, AdamW, microbatched train step, SECDED-protected optimizer
snapshots with scrubbing, SECDED checkpoints with restart, and a mid-run
injected SDC repaired without losing a step.

Run (full, a few hundred steps — TPU or a beefy host):
    PYTHONPATH=src python examples/train_lm.py --steps 300 --seq-len 256 --batch 8
Defaults are sized for a small CPU box (10 steps, 64x2).
"""
import argparse
import dataclasses
import time

import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.injection import inject_flips
from repro.models import count_params
from repro.train.trainer import make_trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--ckpt", default="/tmp/cream_train_lm")
    args = ap.parse_args()

    cfg = ModelConfig(name="lm-110m", family="dense", num_layers=14,
                      d_model=640, num_heads=10, num_kv_heads=5,
                      d_ff=2560, vocab_size=16384, head_dim=64,
                      dtype="float32")
    print(f"model: {count_params(cfg)/1e6:.1f}M params")

    micro = 2 if args.batch >= 4 else None
    tcfg = TrainConfig(learning_rate=3e-4, warmup_steps=20,
                       total_steps=max(args.steps, 100), microbatch=micro,
                       scrub_every=5, checkpoint_every=20, remat="block")
    tr = make_trainer(cfg, tcfg, ckpt_dir=args.ckpt, seq_len=args.seq_len,
                      global_batch=args.batch)
    if tr.restore():
        print(f"resumed from checkpoint at step {tr.step}")

    rng = np.random.default_rng(0)
    t0 = time.time()
    half = args.steps // 2
    tr.run(half)
    # mid-run SDC: flip bits in the protected optimizer snapshot
    stor, recs = inject_flips(tr.moment_pool.storage, rng, 5)
    tr.moment_pool = dataclasses.replace(tr.moment_pool, storage=stor)
    repaired = tr.scrub_pools()
    print(f"injected 5 bit flips -> scrub corrected {repaired['corrected']}")
    log = tr.run(args.steps - half)

    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq_len
    print(f"loss {log[0]['loss']:.3f} -> {log[-1]['loss']:.3f} | "
          f"{toks/dt:.0f} tok/s | checkpoints at {args.ckpt}")
    if args.steps >= 30:
        assert log[-1]["loss"] < log[0]["loss"], "model must learn"


if __name__ == "__main__":
    main()

"""Quickstart: CREAM pools in five minutes.

Creates an ECC pool, reclaims the code lane for +12.5% capacity, survives a
bit-flip storm, and moves the protection boundary at runtime — the paper's
mechanism end to end.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import (Layout, make_pool, read_page, repartition, scrub,
                        write_page)
from repro.core.injection import inject_flips

rng = np.random.default_rng(0)


def rand_page(pool):
    return jnp.asarray(rng.integers(0, 2**32, size=(pool.page_words,),
                                    dtype=np.uint32))


# 1) A conventional ECC module: all rows SECDED-protected.
pool = make_pool(num_rows=64, layout=Layout.INTERWRAP, boundary=0)
print(f"SECDED pool:  {pool.num_pages} pages "
      f"({pool.effective_bytes >> 10} KB effective, "
      f"{pool.raw_bytes >> 10} KB raw)")

# 2) Store data, inject a cosmic ray, scrub it away.
data = rand_page(pool)
pool = write_page(pool, 12, data)
pool = dataclasses.replace(
    pool, storage=inject_flips(pool.storage, rng, 3)[0])
pool, stats = scrub(pool)
print(f"scrub: corrected={stats.corrected} "
      f"uncorrectable={stats.detected_uncorrectable}")
got, status = read_page(pool, 12)
assert (got == data).all() and int(status) == 0

# 3) This tenant doesn't need ECC -> flip the whole pool to Inter-Wrap.
pool, info = repartition(pool, pool.num_rows)
print(f"CREAM pool:   {pool.num_pages} pages "
      f"(+{pool.capacity_gain():.1%} capacity reclaimed from the code lane)")
got, _ = read_page(pool, 12)
assert (got == data).all(), "contents survive the layout change"

# 4) Use an extra page that physically lives in the old ECC chip.
extra_id = pool.num_rows  # first reclaimed page
extra = rand_page(pool)
pool = write_page(pool, extra_id, extra)
got, _ = read_page(pool, extra_id)
assert (got == extra).all()
print(f"extra page {extra_id} stored in reclaimed code-lane capacity")

# 5) Health degrades? Move the boundary back: half the pool returns to SECDED.
pool, info = repartition(pool, pool.num_rows // 2)
print(f"boundary -> {pool.boundary}: {pool.num_pages} pages, "
      f"evicted extras: {info['evicted_extra_pages']}")
got, _ = read_page(pool, 12)
assert (got == data).all()
print("OK — capacity and reliability traded at runtime.")

"""The paper's §3.3 vision: a cloud that re-partitions protection at runtime.

Three tenants share a server: a batch-job KV region (error-tolerant), a
database region (detection required), and the hypervisor (always SECDED).
The health loop scrubs, watches error rates, and moves each region's
boundary — healthy regions donate code-lane capacity, a failing DIMM gets
its protection upgraded automatically.

Run: PYTHONPATH=src python examples/adaptive_reliability.py
"""
import dataclasses


from repro.core.injection import FaultModel
from repro.core.monitor import MonitorConfig
from repro.core.protection import Protection, RegionSpec
from repro.core.regions import RegionManager

mgr = RegionManager(MonitorConfig(window=2, upgrade_threshold=5e-8,
                                  downgrade_threshold=1e-9,
                                  downgrade_patience=2))
mgr.add_region(RegionSpec.make("batch_kv", Protection.SECDED, 64,
                               min_protection=Protection.NONE))
mgr.add_region(RegionSpec.make("database", Protection.SECDED, 64,
                               min_protection=Protection.PARITY))
mgr.add_region(RegionSpec.make("hypervisor", Protection.SECDED, 32,
                               min_protection=Protection.SECDED))

# the 'database' region sits on an aging DIMM
faults = FaultModel.make(seed=0, soft_rate=2000.0, n_hard=0,
                         shape=(64, 9, 256))

print(f"{'epoch':5s} {'capacity':>9s}  transitions / health")
for epoch in range(8):
    db = mgr.regions["database"]
    if epoch >= 3:  # DIMM starts flipping bits
        stor, n = faults.step(db.pool.storage)
        db.pool = dataclasses.replace(db.pool, storage=stor)
    mgr.scrub_all()
    trans = mgr.adapt()
    cap = mgr.total_capacity_pages()
    notes = "; ".join(f"{n}:{a.value}->{b.value}" for n, a, b in trans)
    rates = {n: f"{mgr.monitor.rate(n):.1e}" for n in mgr.regions}
    print(f"{epoch:5d} {cap:9d}  {notes or '-':40s} {rates}")

report = mgr.capacity_report()
print("\nfinal layout:")
for name, r in report.items():
    print(f"  {name:10s} {r['protection']:7s} pages={r['pages']:3d} "
          f"(+{r['gain']:.1%})")

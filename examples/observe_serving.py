"""CREAM-Scope end to end: serve, corrupt the cheap tier, stay green.

Runs CREAM-Serve with the telemetry plane on. The KV pool is parity-laid:
batch-tier sessions get CREAM frames (parity — detect, not correct) and
paid-tier sessions get frames from the SECDED tail. Between turns we flip
one bit in every CREAM row — the cheap tier's storage — then scrub and
keep serving. The decode gather's status fold counts the parity
detections, the scrub census logs the corrupt lines, and the dashboard
shows the paper's contract holding: batch-tier errors are *counted but
tolerated* while the paid/SECDED reliability SLO stays green.

Run: PYTHONPATH=src python examples/observe_serving.py
"""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.layouts import Layout
from repro.core.monitor import ErrorMonitor
from repro.core.scrubber import scrub
from repro.obs import dashboard, metrics, slo, tracing
from repro.serve import Engine, ServeRequest
from repro.vm import VirtualMemory

cfg = ModelConfig(name="observe-demo", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=256, head_dim=16, dtype="float32")

metrics.enable()
tracing.enable()

# Parity-laid pool: CREAM region [0, 48) detects-but-tolerates, SECDED
# tail [48, 64) is the paid tier's zero-uncorrectable contract.
NUM_ROWS, SECDED_ROWS = 64, 16
vm = VirtualMemory(row_words=64)
vm.add_pool("kv", NUM_ROWS, Layout.PARITY, boundary=NUM_ROWS - SECDED_ROWS)
eng = Engine(cfg, max_batch=4, max_len=32, vm=vm, pool="kv", mode="cream")
# parity reclaims ≈ +10.7 % of the CREAM region; 3/4 of this pool is
# CREAM, so the pool-wide floor is ~0.08 (48 · 0.107 / 64)
slo.TRACKER.set_capacity_target("kv", 0.07)

rng = np.random.default_rng(0)
prompts = {f"s{i}": rng.integers(0, 256, size=8).astype(np.int32)
           for i in range(6)}
tiers = {sid: "paid" if i % 2 else "batch"
         for i, sid in enumerate(prompts)}


def turn(max_new):
    return [ServeRequest(sid, prompts[sid], max_new=max_new,
                         tier=tiers[sid]) for sid in prompts]


print("turn 1: 6 sessions (3 paid on SECDED frames, 3 batch on parity)")
eng.serve(turn(max_new=4))

print("fault: flipping one bit in every CREAM row (batch-tier storage)")
pool = eng.pool
storage = np.asarray(pool.storage).copy()
storage[:pool.boundary, 0, 0] ^= 1
eng.vm.pools["kv"] = dataclasses.replace(pool, storage=jnp.asarray(storage))

mon = ErrorMonitor()
new_state, stats = scrub(eng.pool)
eng.vm.pools["kv"] = new_state
mon.record("kv", stats)
print(f"scrub:  corrupt parity lines={stats.parity_corrupt_lines} "
      f"corrected={stats.corrected} "
      f"uncorrectable={stats.detected_uncorrectable}")

print("turn 2: same sessions resume their parked (now corrupted) KV\n")
eng.serve(turn(max_new=4))

print(dashboard.render())

by_scope = {s.scope: s for s in slo.TRACKER.report()}
parity_hits = by_scope["class/parity"].value
assert by_scope["class/secded"].ok, "paid-tier SLO must stay green"
assert by_scope["class/parity"].ok, "batch-tier errors tolerated by contract"
assert parity_hits > 0, "batch-tier detections must be counted"
print(f"contract held: {parity_hits:.0f} batch-tier (parity) detections "
      "counted and tolerated; paid/SECDED uncorrectable budget 0 intact; "
      f"{len(tracing.TRACER.events)} spans traced")

"""CREAM-Cache end to end: memcached on the real data plane.

The paper's Fig. 8 story, live: a key-value cache whose objects sit in
actual CREAM pool pages, a zipfian workload hammering it, and a mid-run
SECDED -> correction-free demotion whose freed frames the cache claims
online — watch the hit rate (and the modeled request latency) improve the
moment the boundary register moves. Authoritative items keep a SECDED
contract throughout and survive everything.

Run: PYTHONPATH=src:. python examples/objcache_memcached.py
"""
import numpy as np

from benchmarks import cache_sim
from repro.core.layouts import Layout
from repro.core.protection import Protection
from repro.objcache import ObjCache
from repro.vm import MigrationEngine, VirtualMemory

ROWS, ROW_WORDS = 48, 64
GET_BATCH, SET_BATCH = 32, 16


def values_for(keys, span):
    return np.asarray(keys, np.uint32)[:, None] * \
        np.arange(1, span + 1, dtype=np.uint32)


def replay(cache, trace, span):
    pending = np.zeros(0, np.int64)
    g0, h0 = cache.stats.gets, cache.stats.hits
    for i in range(0, len(trace) - len(trace) % GET_BATCH, GET_BATCH):
        ks = trace[i:i + GET_BATCH]
        _, _, found = cache.get_many(ks)
        pending = np.unique(np.concatenate([pending, ks[~found]]))
        while len(pending) >= SET_BATCH:
            batch, pending = pending[:SET_BATCH], pending[SET_BATCH:]
            cache.set_many(batch, values_for(batch, span))
    gets, hits = cache.stats.gets - g0, cache.stats.hits - h0
    miss = gets - hits
    model_us = (miss * cache_sim.FAULT_PENALTY_US
                + hits * cache_sim.HIT_COST_US) / max(gets, 1)
    return hits / max(gets, 1), model_us


# 1) An all-SECDED DIMM under VM management, the cache as its tenant.
vm = VirtualMemory(row_words=ROW_WORDS)
vm.add_pool("dimm", ROWS, Layout.INTERWRAP, boundary=0)
cache = ObjCache(vm, "dimm", index_capacity=4 * ROWS, probe=16)
span = vm.page_words                     # full-page objects: pages = items

# 2) A handful of authoritative items contract for SECDED protection.
auth = np.arange(90_000, 90_004)
cache.set_many(auth, values_for(auth, span), reliability=Protection.SECDED)

# 3) Zipfian traffic against the baseline capacity.
trace = cache_sim.zipf_trace(np.random.default_rng(0), 4 * ROWS, 6000)
hit0, us0 = replay(cache, trace[:3000], span)
print(f"all-SECDED   : {vm.device_capacity_pages()} pages, "
      f"hit={hit0:.3f}, modeled {us0:8.1f} us/req")

# 4) Live demotion: the boundary register frees the code lane mid-run.
#    Cached values are untouched; the reclaimed frames join the free lists
#    and the very next slab reservation claims them.
MigrationEngine(vm).repartition_with_migration("dimm", ROWS)
cache.refresh_translation()
hit1, us1 = replay(cache, trace[3000:], span)
print(f"correction-free: {vm.device_capacity_pages()} pages, "
      f"hit={hit1:.3f}, modeled {us1:8.1f} us/req")
print(f"capacity +{vm.device_capacity_pages() - ROWS} pages -> "
      f"hit rate {hit0:.3f} -> {hit1:.3f}, "
      f"modeled latency x{us0 / max(us1, 1e-9):.2f} better")

# 5) The authoritative items lived through it all, bit for bit.
got, _, found = cache.get_many(auth)
assert found.all()
np.testing.assert_array_equal(got, values_for(auth, span))
print("authoritative SECDED items intact after the boundary move")

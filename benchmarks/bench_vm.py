"""CREAM-VM benchmark: multi-tenant traffic over SECDED vs. InterWrap pools.

Simulates the OS-level payoff of the paper's capacity reclaim:

  * **churn scenario** — two tenants with different reliability classes (a
    SECDED-contracted "secure" tenant and a protection-free "bulk" tenant)
    allocate, touch, and free pages through the VM while soft errors are
    injected; the policy bridge scrubs, monitors, and upgrades protection
    via repartition + live-migration transactions. Secure-tenant contents
    are verified every epoch (their contract); at the end the remaining
    CREAM span is force-upgraded and *all* live pages are verified against
    a pre-upgrade snapshot — migration loses nothing, whatever the soft
    errors did before;
  * **migration microbench** — relocation throughput of a fully mapped pool
    into a spare pool: the SECDED source decodes per row, the InterWrap
    source takes the fused Pallas gather/re-encode path;
  * **mixed-access microbench** — the jitted mixed-pool engine behind
    the unified ``pool.read`` / ``pool.write`` access API hammering a
    half-CREAM/half-SECDED pool with a random CREAM+SECDED+extra id mix:
    the hot path every VM read/write and migration batch now rides.

Emits the repo's ``name,us_per_call,derived`` CSV contract.

Env: ``REPRO_VM_ROWS`` (default 64) scales the pools; the default runs in
seconds on CPU interpret mode (CI smoke).
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pool as pool_lib
from repro.core.injection import inject_flips
from repro.core.layouts import Layout
from repro.core.monitor import MonitorConfig
from repro.core.protection import Protection
from repro.vm import MigrationEngine, VirtualMemory, VMPolicy

ROW_WORDS = 64


def _blob(rng, n, page_words):
    return jnp.asarray(rng.integers(0, 2**32, (n, page_words),
                                    dtype=np.uint32))


def churn_scenario(mode: str, rows: int, epochs: int = 4, seed: int = 0
                   ) -> dict:
    rng = np.random.default_rng(seed)
    vm = VirtualMemory(row_words=ROW_WORDS)
    vm.add_pool("p0", rows, Layout.INTERWRAP,
                boundary=0 if mode == "secded" else rows)
    vm.add_pool("spill", max(8, rows // 4), Layout.INTERWRAP, boundary=0)
    vm.create_tenant("secure", default_reliability=Protection.SECDED)
    vm.create_tenant("bulk", default_reliability=Protection.NONE)
    engine = MigrationEngine(vm)
    policy = VMPolicy(vm, engine,
                      MonitorConfig(window=2, upgrade_threshold=1e-9))

    pw = vm.page_words
    live: list[tuple[str, list[int], jnp.ndarray]] = []
    reads = 0
    t0 = time.perf_counter()
    for _ in range(epochs):
        for tenant, burst in (("secure", 2), ("bulk", 4)):
            vpns = vm.alloc(tenant, burst)
            data = _blob(rng, burst, pw)
            vm.write(tenant, vpns, data)
            live.append((tenant, vpns, data))
        if len(live) > 6:           # churn: free a random old allocation
            tenant, vpns, _ = live.pop(int(rng.integers(0, 3)))
            vm.free(tenant, vpns)
        for tenant, vpns, data in live:
            got = vm.read(tenant, vpns)
            reads += len(vpns)
            if tenant == "secure":   # the reliability contract
                assert (got == data).all(), "secure tenant corrupted"
        storage, _ = inject_flips(vm.pools["p0"].storage, rng, n_flips=2)
        vm.pools["p0"] = dataclasses.replace(vm.pools["p0"], storage=storage)
        policy.step()
    churn_s = time.perf_counter() - t0

    # force-upgrade whatever CREAM span remains; snapshot-verify zero loss
    snapshot = [(t, v, np.asarray(vm.read(t, v))) for t, v, _ in live]
    engine.repartition_with_migration("p0", 0)
    for tenant, vpns, before in snapshot:
        assert (np.asarray(vm.read(tenant, vpns)) == before).all(), \
            "pages lost in upgrade migration"

    return {
        "churn_s": churn_s,
        "reads": reads,
        "utilisation": vm.utilisation(),
        "fault_rate": vm.stats.fault_rate,
        "capacity_pages": vm.device_capacity_pages(),
        "transitions": len(policy.transitions),
        "host_pages": len(vm.swap),
    }


def migration_microbench(mode: str, rows: int, seed: int = 0) -> dict:
    """Relocation throughput, steady state: the identical transaction is run
    on two freshly built VMs and the *second* run is reported, so one-time
    trace/compile cost is excluded (both runs share jit caches)."""
    def build():
        rng = np.random.default_rng(seed)
        vm = VirtualMemory(row_words=ROW_WORDS)
        vm.add_pool("src", rows, Layout.INTERWRAP,
                    boundary=0 if mode == "secded" else rows)
        n = vm.pools["src"].num_pages
        vm.add_pool("dst", ((n + 7) // 8) * 8, Layout.INTERWRAP, boundary=0)
        vm.create_tenant("bulk", default_reliability=Protection.NONE)
        vpns = vm.alloc("bulk", n, allow_host=False)
        data = _blob(rng, n, vm.page_words)
        vm.write("bulk", vpns, data)
        return vm, vpns, data, n

    vm, vpns, data, n = build()
    MigrationEngine(vm).relocate(
        "bulk", vpns, avoid_pool="src")          # warm-up transaction
    vm, vpns, data, n = build()
    engine = MigrationEngine(vm)
    t0 = time.perf_counter()
    moved = engine.relocate("bulk", vpns, avoid_pool="src")
    dt = time.perf_counter() - t0
    assert moved == n
    assert (vm.read("bulk", vpns) == data).all(), "relocation lost pages"
    assert vm.used_device_pages("src") == 0
    return {"pages": moved, "seconds": dt,
            "pages_s": moved / dt if dt else 0.0,
            "mb_s": moved * vm.page_bytes / 2**20 / dt if dt else 0.0,
            "kernel_batches": engine.stats.kernel_batches}


def scrub_writeback_microbench(rows: int, seed: int = 0) -> dict:
    """Write-back scrub semantics, measured end to end: plant latent
    single-bit errors across a SECDED + DAEC-tier pool, drive one
    write-back read pass over every page, and verify storage is clean —
    latent errors killed in one tick, not merely counted."""
    rng = np.random.default_rng(seed)
    boundary = ((rows // 4) // 8) * 8
    daec = max(8, ((rows // 4) // 8) * 8)
    pool = pool_lib.make_pool(rows, Layout.INTERWRAP, boundary=boundary,
                              row_words=ROW_WORDS, daec_rows=daec)
    ids = jnp.arange(pool.num_pages, dtype=jnp.int32)
    data = _blob(rng, pool.num_pages, pool.page_words)
    pool = pool.write(ids, data)

    # plant latent single-bit errors only in correctable (protected) rows
    protected = np.arange(boundary, rows)
    rows_hit = rng.choice(protected, size=max(4, rows // 8), replace=False)
    storage = np.array(pool.storage)
    for r in rows_hit:
        lane = int(rng.integers(0, 9))
        word = int(rng.integers(0, ROW_WORDS))
        storage[r, lane, word] ^= np.uint32(1 << int(rng.integers(0, 32)))
    pool = dataclasses.replace(pool, storage=jnp.asarray(storage))

    _, _, warm = pool.read_writeback(ids)           # warm the trace
    del warm
    pool = dataclasses.replace(pool, storage=jnp.asarray(storage))
    t0 = time.perf_counter()
    out, status, pool = pool.read_writeback(ids)
    jax.block_until_ready((out, status, pool.storage))
    dt = time.perf_counter() - t0

    status = np.asarray(status)
    killed = int(np.count_nonzero((status == 1) | (status == 2)))
    assert (np.asarray(out) == np.asarray(data)).all(), \
        "write-back read returned corrupted data"
    # one campaign tick drove the planted latent errors to zero: a plain
    # follow-up read must come back all-clean from the repaired storage
    out2, status2 = pool.read(ids, status=True)
    assert (np.asarray(status2) == 0).all(), "latent errors survived"
    assert (np.asarray(out2) == np.asarray(data)).all()
    n = pool.num_pages
    return {"pages": n, "seconds": dt, "planted": len(rows_hit),
            "killed": killed, "pages_s": n / dt if dt else 0.0,
            "clean_after": int((np.asarray(status2) == 0).all())}


def mixed_access_microbench(rows: int, seed: int = 0, reps: int = 10) -> dict:
    """Steady-state throughput of the jitted mixed-pool access engine."""
    rng = np.random.default_rng(seed)
    pool = pool_lib.make_pool(rows, Layout.INTERWRAP, boundary=rows // 2,
                              row_words=ROW_WORDS)
    n = max(8, pool.num_pages // 2)
    ids = jnp.asarray(rng.choice(pool.num_pages, n, replace=False), jnp.int32)
    data = _blob(rng, n, pool.page_words)
    # warm the traces (one compile per pool mode)
    pool = pool.write(ids, data)
    jax.block_until_ready(pool.read(ids))
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        pool = pool.write(ids, data)
        out = pool.read(ids)
    jax.block_until_ready((pool.storage, out))
    dt = time.perf_counter() - t0
    pages = 2 * n * reps                      # one write + one read per rep
    ok = bool((out == data).all())
    return {"pages": pages, "seconds": dt, "batch": n,
            "pages_s": pages / dt if dt else 0.0,
            "mb_s": pages * pool.page_bytes / 2**20 / dt if dt else 0.0,
            "ok": ok}


def main():
    rows = int(os.environ.get("REPRO_VM_ROWS", "64"))
    for mode in ("secded", "interwrap"):
        c = churn_scenario(mode, rows)
        m = migration_microbench(mode, rows)
        prefix = f"vm_{mode}"
        yield (f"{prefix}_churn", c["churn_s"] * 1e6 / max(c["reads"], 1),
               f"us_per_page_read,faults={c['fault_rate']:.3f},"
               f"transitions={c['transitions']}")
        yield (f"{prefix}_capacity", float(c["capacity_pages"]),
               f"pages,util={c['utilisation']:.3f},host={c['host_pages']}")
        yield (f"{prefix}_migration", m["seconds"] * 1e6 / m["pages"],
               f"us_per_page,pages_s={m['pages_s']:.1f},"
               f"mb_s={m['mb_s']:.2f},kernel_batches={m['kernel_batches']}")
    x = mixed_access_microbench(rows)
    yield ("vm_mixed_access", x["seconds"] * 1e6 / x["pages"],
           f"us_per_page,pages_s={x['pages_s']:.1f},mb_s={x['mb_s']:.2f},"
           f"batch={x['batch']},roundtrip_ok={int(x['ok'])}")
    s = scrub_writeback_microbench(rows)
    yield ("vm_scrub_writeback", s["seconds"] * 1e6 / s["pages"],
           f"us_per_page,planted={s['planted']},killed={s['killed']},"
           f"clean_after={s['clean_after']}")


if __name__ == "__main__":
    for name, val, derived in main():
        print(f"{name},{val:.3f},{derived}")

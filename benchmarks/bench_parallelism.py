"""Figs. 9–11 reproduction: 40 multiprogrammed workloads on the DRAM model.

Sweeps the number of memory-intensive applications per 4-core workload from
0 to 4 (the paper's 0–100%), eight random mixes each = 40 workloads. Reports
per configuration:
  * weighted speedup, normalised to Baseline   (Fig. 9)
  * memory requests, normalised               (Fig. 10a)
  * average concurrent requests, normalised   (Fig. 10b)
  * row-buffer hit rate, normalised           (Fig. 11a)
  * average memory latency, normalised        (Fig. 11b)

Weighted speedup = Σ_c (T_alone_c / T_shared_c), with T_alone measured on
Baseline with the core running by itself (paper §5).
"""
from __future__ import annotations

import numpy as np

from benchmarks.dram_sim import Core, DRAMSim, make_core
from repro.core.layouts import Layout

CONFIGS = [
    ("Baseline", Layout.BASELINE_ECC),
    ("Packed", Layout.PACKED),
    ("Packed+RS", Layout.RANK_SUBSET),
    ("Inter-Wrap", Layout.INTERWRAP),
]

NUM_ROWS = 256
N_REQ = 700
N_MIXES = 8


def _cores_for(seed: int, n_intensive: int, layout: Layout) -> list[Core]:
    rng = np.random.default_rng(seed)
    return [make_core(rng, layout, NUM_ROWS, N_REQ,
                      memory_intensive=(i < n_intensive))
            for i in range(4)]


def _finish_times(cores: list[Core]) -> list[int]:
    return [getattr(c, "done_at", 0) for c in cores]


def run() -> dict:
    out: dict = {c[0]: {"ws": [], "reqs": [], "conc": [], "hits": [],
                        "lat": []} for c in CONFIGS}
    sweep = []
    for n_int in range(5):
        for mix in range(N_MIXES):
            seed = 1000 * n_int + mix
            # alone runs (Baseline, single core) for weighted speedup
            alone = []
            for i in range(4):
                cores = _cores_for(seed, n_int, Layout.BASELINE_ECC)
                solo = [cores[i]]
                DRAMSim(Layout.BASELINE_ECC, NUM_ROWS).run(solo)
                alone.append(max(getattr(solo[0], "done_at", 1), 1))
            for name, layout in CONFIGS:
                cores = _cores_for(seed, n_int, layout)
                stats = DRAMSim(layout, NUM_ROWS).run(cores)
                shared = _finish_times(cores)
                ws = sum(a / max(s, 1) for a, s in zip(alone, shared))
                out[name]["ws"].append(ws)
                out[name]["reqs"].append(stats.device_ops)
                out[name]["conc"].append(stats.blp)
                out[name]["hits"].append(stats.row_hit_rate)
                out[name]["lat"].append(stats.avg_latency)
            sweep.append((n_int, mix))

    base = out["Baseline"]
    summary = {}
    for name, _ in CONFIGS:
        r = out[name]
        summary[name] = {
            "weighted_speedup_norm": float(np.mean(np.asarray(r["ws"])
                                                   / np.asarray(base["ws"]))),
            "requests_norm": float(np.mean(np.asarray(r["reqs"])
                                           / np.asarray(base["reqs"]))),
            "concurrency_norm": float(np.mean(np.asarray(r["conc"])
                                              / np.asarray(base["conc"]))),
            "row_hit_norm": float(np.mean(np.asarray(r["hits"])
                                          / np.asarray(base["hits"]))),
            "latency_norm": float(np.mean(np.asarray(r["lat"])
                                          / np.asarray(base["lat"]))),
        }
    return summary


def main() -> list[tuple[str, float, str]]:
    rows = []
    paper = {"Packed": 0.701, "Packed+RS": 0.839, "Inter-Wrap": 1.024}
    for name, s in run().items():
        ref = f",paper={paper[name]:.3f}" if name in paper else ""
        rows.append((f"fig9_ws_{name}", s["weighted_speedup_norm"],
                     f"reqs={s['requests_norm']:.2f},conc="
                     f"{s['concurrency_norm']:.2f},hit={s['row_hit_norm']:.2f},"
                     f"lat={s['latency_norm']:.2f}{ref}"))
    return rows


if __name__ == "__main__":
    for name, val, derived in main():
        print(f"{name},{val:.3f},{derived}")

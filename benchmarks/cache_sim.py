"""Page-cache / page-fault model (paper §5: Linux-style active+inactive lists).

Drives the capacity-sensitive experiments: memcached (Fig. 8) and WebSearch
(Fig. 4). DRAM is a page cache over a larger dataset; a miss costs the
paper's 500µs fault penalty (300µs SSD + 200µs software). Replacement is a
2Q approximation of the Linux VM: pages enter the inactive list, promote to
active on re-reference, and eviction drains the inactive tail (refilling it
from the active tail to keep the ~2:1 ratio).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

FAULT_PENALTY_US = 500.0
HIT_COST_US = 0.1          # DRAM service incl. controller (order of magnitude)


@dataclass
class CacheResult:
    accesses: int
    faults: int
    total_us: float

    @property
    def fault_rate(self) -> float:
        return self.faults / max(self.accesses, 1)

    @property
    def avg_us(self) -> float:
        return self.total_us / max(self.accesses, 1)


class TwoQPageCache:
    """Active/inactive-list page cache (capacity in pages)."""

    def __init__(self, capacity: int, active_frac: float = 2 / 3):
        self.capacity = max(capacity, 2)
        self.active_cap = max(1, int(self.capacity * active_frac))
        self.active: OrderedDict[int, None] = OrderedDict()
        self.inactive: OrderedDict[int, None] = OrderedDict()

    def __contains__(self, page: int) -> bool:
        return page in self.active or page in self.inactive

    def access(self, page: int) -> bool:
        """Returns True on hit."""
        if page in self.active:
            self.active.move_to_end(page)
            return True
        if page in self.inactive:
            del self.inactive[page]
            self.active[page] = None
            self._balance()
            return True
        self.inactive[page] = None
        self._evict()
        return False

    def _balance(self) -> None:
        while len(self.active) > self.active_cap:
            pg, _ = self.active.popitem(last=False)
            self.inactive[pg] = None

    def _evict(self) -> None:
        while len(self.active) + len(self.inactive) > self.capacity:
            if self.inactive:
                self.inactive.popitem(last=False)
            else:
                self.active.popitem(last=False)


def run_trace(capacity_pages: int, trace: np.ndarray,
              fault_penalty_us: float = FAULT_PENALTY_US) -> CacheResult:
    cache = TwoQPageCache(capacity_pages)
    faults = 0
    for page in trace:
        if not cache.access(int(page)):
            faults += 1
    total = faults * fault_penalty_us + (len(trace) - faults) * HIT_COST_US
    return CacheResult(len(trace), faults, total)


def zipf_trace(rng: np.random.Generator, n_pages: int, n_accesses: int,
               alpha: float = 0.99) -> np.ndarray:
    """Zipfian page popularity (hot keys), shuffled page ids.

    The single shared trace generator: ``bench_capacity`` (Fig. 8),
    ``bench_websearch`` (Fig. 4, via :func:`websearch_trace`), and the
    ``bench_objcache`` replay driver all draw from here, so the abstract
    page-fault model and the real CREAM-Cache data plane see the same
    workload shape.
    """
    ranks = np.arange(1, n_pages + 1, dtype=np.float64)
    probs = ranks ** (-alpha)
    probs /= probs.sum()
    perm = rng.permutation(n_pages)
    return perm[rng.choice(n_pages, size=n_accesses, p=probs)]


def websearch_trace(rng: np.random.Generator, hot_pages: int,
                    cold_pages: int, n_accesses: int,
                    hot_frac: float = 0.95,
                    alpha: float = 0.99) -> np.ndarray:
    """WebSearch-style index traffic: a zipfian hot set over a uniform tail.

    ``hot_frac`` of accesses go to a :func:`zipf_trace` over the first
    ``hot_pages`` ids; the rest fall uniformly on the ``cold_pages`` above
    them — the paper's Fig. 4 regime (hot working set slightly larger than
    the smallest DRAM size).
    """
    hot = zipf_trace(rng, hot_pages, n_accesses, alpha)
    cold = hot_pages + rng.integers(0, cold_pages, size=n_accesses)
    return np.where(rng.random(n_accesses) < hot_frac, hot, cold)

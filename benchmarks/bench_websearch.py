"""Fig. 4 reproduction: WebSearch percentile latency vs load vs capacity.

An interactive index-serving queue: queries touch a DRAM-cached index whose
*hot working set is slightly larger than the smallest DRAM size* — the
regime the paper's WebSearch lives in (each +12.5% capacity step absorbs a
big slice of the residual hot-set misses, so the p95-vs-load curve crosses
the queue-saturation knee; paper: 67%/24% latency drops per step, 2× load
at iso-latency). Misses pay the 500µs fault penalty; a 4-server M/G/c-style
discrete simulation sweeps offered load for four sizes w < x < y < z.

The trace comes from the shared :func:`benchmarks.cache_sim
.websearch_trace` generator — the same workload definition the
``bench_objcache`` replay and the ``serving_websearch_*`` rows of
``bench_serving`` consume, so the model, the object-cache data plane, and
the live serving engine all see one WebSearch shape (see
``docs/paper-map.md``).
"""
from __future__ import annotations

import numpy as np

from benchmarks import cache_sim

HOT_PAGES = 2700               # hot index set: just above capacity "w"
COLD_PAGES = 40_000            # long-tail index pages
HOT_FRAC = 0.95
TOUCHES = 4                    # index pages per query
SERVICE_US = 120.0             # CPU cost per query
N_QUERIES = 12_000
SERVERS = 4
BASE_CAPACITY = 2048           # "w"
LOADS = [0.5, 0.7, 0.9, 1.0]


def _trace(rng: np.random.Generator, n: int) -> np.ndarray:
    # shared generator, same as fig8 and bench_objcache. alpha=0 keeps the
    # hot set uniform: Fig. 4's regime needs the *whole* hot working set in
    # play (slightly larger than the smallest DRAM size), not a zipf head.
    return cache_sim.websearch_trace(rng, HOT_PAGES, COLD_PAGES, n,
                                     hot_frac=HOT_FRAC, alpha=0.0)


def _steady_service(capacity: int, seed: int = 0) -> float:
    """Mean per-query service at steady state (for arrival calibration)."""
    rng = np.random.default_rng(seed)
    cache = cache_sim.TwoQPageCache(capacity)
    tr = _trace(rng, 30_000)
    misses = sum(0 if cache.access(int(p)) else 1 for p in tr[15_000:])
    frate = misses / 15_000
    return SERVICE_US + TOUCHES * frate * cache_sim.FAULT_PENALTY_US


def _percentile_latency(rng: np.random.Generator, capacity: int, load: float,
                        base_service: float) -> float:
    cache = cache_sim.TwoQPageCache(capacity)
    arrival_rate = load * SERVERS / base_service
    inter = rng.exponential(1.0 / arrival_rate, N_QUERIES)
    arrive = np.cumsum(inter)
    trace = _trace(rng, N_QUERIES * TOUCHES).reshape(N_QUERIES, TOUCHES)
    free = np.zeros(SERVERS)
    lat = np.empty(N_QUERIES)
    for i in range(N_QUERIES):
        svc = SERVICE_US
        for pg in trace[i]:
            if not cache.access(int(pg)):
                svc += cache_sim.FAULT_PENALTY_US
        k = int(np.argmin(free))
        start = max(arrive[i], free[k])
        free[k] = start + svc
        lat[i] = free[k] - arrive[i]
    return float(np.percentile(lat, 95))


def run(seed: int = 0) -> dict:
    sizes = {"w": BASE_CAPACITY,
             "x": int(BASE_CAPACITY * 1.125),
             "y": int(BASE_CAPACITY * 1.125 ** 2),
             "z": int(BASE_CAPACITY * 1.125 ** 3)}
    # arrival calibrated so the LARGEST size is near-critical at load 1.0 —
    # smaller sizes then sit past the knee, as in the paper's figure.
    base_service = _steady_service(sizes["z"]) * 1.05
    curves = {}
    for name, cap in sizes.items():
        curves[name] = [
            _percentile_latency(np.random.default_rng(seed + 17 * i), cap,
                                ld, base_service)
            for i, ld in enumerate(LOADS)]
    imps = []
    names = list(sizes)
    for a, b in zip(names[:-1], names[1:]):
        hi_a, hi_b = curves[a][-1], curves[b][-1]
        imps.append((hi_a - hi_b) / hi_a)
    thresh = 2.0 * min(min(c) for c in curves.values())

    def max_load(curve):
        ok = [ld for ld, l in zip(LOADS, curve) if l <= thresh]
        return max(ok) if ok else LOADS[0]

    load_gain = max_load(curves["x"]) / max_load(curves["w"])
    return {"loads": LOADS, "curves": curves,
            "p95_improvement_per_step": imps,
            "mean_p95_improvement": float(np.mean(imps)),
            "iso_latency_load_gain": load_gain}


def main(seed: int = 0) -> list[tuple[str, float, str]]:
    r = run(seed)
    rows = []
    for name, curve in r["curves"].items():
        rows.append((f"fig4_websearch_p95_{name}", curve[-1],
                     "p95_us_at_full_load"))
    steps = ",".join(f"{x*100:.0f}%" for x in r["p95_improvement_per_step"])
    rows.append(("fig4_websearch_p95_improvement",
                 r["mean_p95_improvement"] * 100,
                 f"pct_per_step=[{steps}](paper:67/24),load_gain="
                 f"{r['iso_latency_load_gain']:.2f}(paper:2.0)"))
    return rows


if __name__ == "__main__":
    for name, val, derived in main():
        print(f"{name},{val:.1f},{derived}")

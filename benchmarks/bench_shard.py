"""CREAM-Shard benchmark: Figs. 9–11 as a *measured* data-plane result.

The paper's bank-level-parallelism claim (rank subsetting, §4.1.2; Figs.
9–11) was reproduced so far only on the abstract DRAM timing model
(``bench_parallelism``). Here it is measured on the real sharded data plane:
``S`` independent request streams, one per bank of a
:class:`repro.shard.ShardedPool` over a ``banks`` mesh (8 virtual host
devices in CI), each stream hammering its own bank's pages through the
mixed-pool engine.

Per shard count S in {1, 2, 4, 8}:

  * ``fig9_real_read_us_sS`` / ``fig9_real_write_us_sS`` — aggregate
    us/page of one S-stream dispatch (read: gather + masked SECDED decode;
    write: scatter + encode), each stream ``STREAM_PAGES`` pages mixing
    CREAM and SECDED regions;
  * ``fig9_real_ws_sS`` — weighted speedup, the paper's Fig. 9 metric:
    ws(S) = Σ_streams t_alone / t_shared = S · t(1) / t(S), where t(1) is
    one stream alone on a single-bank pool. > 1 means the banks genuinely
    serve concurrent request streams faster than a serial pool would;
  * ``fig9_real_lat_sS`` — per-stream latency inflation t(S) / t(1)
    (Fig. 11b analogue: what each stream pays for sharing the machine);
  * ``fig9_real_router_us_sS`` — the general (non-aligned) path: random
    global page ids through the one-pass FUSED dispatch (router folded
    into the mixed kernel's scalar-prefetch index map, cross-bank psum
    assembly — no owner-select pass, no stacked ``(S, n)`` intermediate);
  * ``fig9_real_planned_us_sS`` — the same ids through the concrete-id
    PLANNED dispatch (host stream planning + one jitted per-bank gather
    of ~n/S pages + device-side inverse permute), timed end to end
    including the planning pass — the shape serve decode gathers ride;
  * ``fig9_real_migrate_us_s{max}`` — cross-shard live migration through
    the explicit ppermute ring exchange.

Every per-S row carries a ``shards=S`` label, so speedup, router cost and
the CREAM-Lens bank profile join on one key. With the memory profiler on
(``benchmarks/run.py --memprof``), each shard count additionally captures
one aligned-streams round trip and one routed read through
:mod:`repro.obs.memprof`, publishes the replayed bank profiles as
``s{S}/streams`` and ``s{S}/router``, and emits the headline stats as
``fig9_memprof_*_sS`` rows (achieved BLP, row-hit/conflict rate,
tFAW-stall cycles, queue p99, extra-chip fraction). Capture is suspended
during the timed loops so the profiler never perturbs the measured rows.

Env: ``REPRO_SHARD_ROWS`` (global rows, default 128), ``REPRO_SHARD_STREAM``
(pages per stream per dispatch, default 64), ``REPRO_SHARD_ROW_WORDS``
(default 64 -> 2KB pages), ``REPRO_SHARD_REPS`` (default 30). Shard counts
above ``jax.device_count()`` are skipped with a note — no silent
truncation.
"""
from __future__ import annotations

import os
import time

import numpy as np

SHARD_COUNTS = (1, 2, 4, 8)


def _bench(fn, reps: int, windows: int = 5) -> float:
    """Best-of-windows mean (timeit-style): robust to scheduler noise."""
    import jax
    jax.block_until_ready(fn())          # warm / compile
    per = max(1, reps // windows)
    best = float("inf")
    for _ in range(windows):
        t0 = time.perf_counter()
        out = None
        for _ in range(per):
            out = fn()
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / per)
    return best


def _memprof_capture(S: int, pool, streams, data, gids, out: list) -> None:
    """One profiled round trip per path, published + emitted as rows.

    Two captures per shard count, kept separate so the attribution can
    contrast them: ``s{S}/streams`` (the bank-aligned hot path — one
    ``read_streams`` + one ``write_streams``) and ``s{S}/router`` (the
    fused planned read of random global ids).
    """
    import jax

    from repro import shard
    from repro.obs import memprof

    memprof.enable()
    memprof.reset()
    jax.block_until_ready(shard.read_streams(pool, streams))
    jax.block_until_ready(shard.write_streams(pool, streams, data).storage)
    prof_s = memprof.profile()
    memprof.publish(f"s{S}/streams", prof_s)
    memprof.reset()
    jax.block_until_ready(pool.read(gids))
    prof_r = memprof.profile()
    memprof.publish(f"s{S}/router", prof_r)
    memprof.reset()
    memprof.disable()
    o, r = prof_s["overall"], prof_r["overall"]
    lab = f"shards={S},path=streams"
    out.append((f"fig9_memprof_blp_s{S}", o["achieved_blp"], lab))
    out.append((f"fig9_memprof_row_hit_rate_s{S}", o["row_hit_rate"], lab))
    out.append((f"fig9_memprof_conflict_rate_s{S}", o["conflict_rate"], lab))
    out.append((f"fig9_memprof_tfaw_stall_cycles_s{S}",
                o["tfaw_stall_cycles"], lab))
    out.append((f"fig9_memprof_queue_p99_s{S}", o["queue_p99"], lab))
    out.append((f"fig9_memprof_extra_chip_frac_s{S}",
                o["extra_chip_frac"], lab))
    rlab = f"shards={S},path=fused"
    out.append((f"fig9_memprof_router_blp_s{S}", r["achieved_blp"], rlab))
    out.append((f"fig9_memprof_router_conflict_rate_s{S}",
                r["conflict_rate"], rlab))


def main(seed: int = 0):
    import jax
    import jax.numpy as jnp

    from repro import shard
    from repro.core.layouts import Layout
    from repro.obs import memprof

    rows = int(os.environ.get("REPRO_SHARD_ROWS", 128))
    stream_pages = int(os.environ.get("REPRO_SHARD_STREAM", 64))
    row_words = int(os.environ.get("REPRO_SHARD_ROW_WORDS", 64))
    reps = int(os.environ.get("REPRO_SHARD_REPS", 30))
    rng = np.random.default_rng(seed)
    ndev = jax.device_count()

    out = []
    read_t: dict[int, float] = {}
    counts = [s for s in SHARD_COUNTS if s <= ndev]
    for s in SHARD_COUNTS:
        if s not in counts:
            print(f"# bench_shard: skipping {s} shards "
                  f"(only {ndev} devices)", flush=True)
    last_pool = None
    # suspend capture during the timed loops: the hooks' host-side copy
    # would perturb exactly the rows this suite baselines
    profiling = memprof.enabled()
    if profiling:
        memprof.disable()
    try:
        for S in counts:
            pool = shard.make_sharded_pool(rows, Layout.INTERWRAP,
                                           boundary=rows // 2, num_shards=S,
                                           row_words=row_words)
            r_local = rows // S
            # bank-aligned streams: stream s draws its own bank's pages
            # across both regions (CREAM rows *and* SECDED rows -> decode)
            local = rng.integers(0, r_local, (S, stream_pages))
            streams = jnp.asarray(local * S + np.arange(S)[:, None],
                                  jnp.int32)
            data = jnp.asarray(rng.integers(
                0, 2**32, (S, stream_pages, pool.page_words),
                dtype=np.uint32))
            pool = shard.write_streams(pool, streams, data)
            total = S * stream_pages

            t_read = _bench(lambda: shard.read_streams(pool, streams), reps)
            read_t[S] = t_read
            out.append((f"fig9_real_read_us_s{S}", t_read * 1e6 / total,
                        f"shards={S},pages={total},rows={rows}"))

            t_write = _bench(
                lambda: shard.write_streams(pool, streams, data).storage,
                reps)
            out.append((f"fig9_real_write_us_s{S}", t_write * 1e6 / total,
                        f"shards={S},pages={total}"))

            # the general router path: unaligned random global ids.
            # Two dispatch shapes, timed separately:
            #  * fused — router folded into the mixed kernel's scalar-
            #    prefetch index map, cross-bank psum assembly (the traced
            #    in-jit path; ids stay on device);
            #  * planned — host stream planning + ONE jitted per-bank
            #    gather + device inverse permute (the concrete-id path
            #    serve/objcache ride), timed end to end incl. planning.
            gids = jnp.asarray(
                rng.permutation(pool.num_pages)[:stream_pages], jnp.int32)
            read_fused = jax.jit(shard.read_any)
            t_router = _bench(lambda: read_fused(pool, gids), reps)
            out.append((f"fig9_real_router_us_s{S}",
                        t_router * 1e6 / stream_pages,
                        f"shards={S},pages={stream_pages},path=fused"))
            gids_np = np.asarray(gids)
            t_planned = _bench(lambda: pool.read(gids_np), reps)
            out.append((f"fig9_real_planned_us_s{S}",
                        t_planned * 1e6 / stream_pages,
                        f"shards={S},pages={stream_pages},path=planned"))
            if profiling:
                _memprof_capture(S, pool, streams, data, gids, out)
            last_pool = pool
    finally:
        if profiling:
            memprof.enable()

    # paper metrics, normalised to the single-bank pool
    paper = {2: None, 4: None, 8: 1.024}   # Fig. 9 Inter-Wrap reference
    for S in counts:
        ws = S * read_t[counts[0]] / read_t[S]
        lat = read_t[S] / read_t[counts[0]]
        ref = f",paper_interwrap={paper[S]:.3f}" if paper.get(S) else ""
        out.append((f"fig9_real_ws_s{S}", ws,
                    f"shards={S},streams={S},t_us={read_t[S]*1e6:.0f}{ref}"))
        out.append((f"fig9_real_lat_s{S}", lat, f"shards={S},streams={S}"))

    # cross-shard migration through the ppermute ring (largest mesh)
    if last_pool is not None and last_pool.num_shards > 1:
        S = last_pool.num_shards
        n = min(stream_pages, rows // 2)
        src = rng.permutation(rows // 2)[:n].astype(np.int32)
        dst = (rows // 2 + rng.permutation(rows // 2)[:n]).astype(np.int32)
        src_d, dst_d = jnp.asarray(src), jnp.asarray(dst)
        pool = last_pool
        t_mig = _bench(
            lambda: shard.migrate_pages(pool, src_d, dst_d,
                                        donate=False).storage,
            reps=max(5, reps // 4))
        out.append((f"fig9_real_migrate_us_s{S}", t_mig * 1e6 / n,
                    f"shards={S},pages={n},path=ppermute-ring"))
    return out


if __name__ == "__main__":
    for name, val, derived in main():
        print(f"{name},{val:.3f},{derived}")

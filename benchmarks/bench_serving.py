"""Fig. 8's serving claim on the real stack: CREAM-Serve vs SECDED pools.

The paper's headline end-to-end numbers are serving-shaped: +23.0 % for a
memory-caching workload and +37.3 % for WebSearch (Fig. 8), both pure
capacity effects. This suite measures the same effect on the paged-KV
serving engine: a small LM serves multi-turn sessions whose KV blocks
live in CREAM pool pages, the session working set slightly overflows the
SECDED-mode pool, and the CREAM mode's +12.5 % reclaimed pages keep more
sessions device-resident — fewer preempt/restore host round-trips and a
fuller decode batch, so higher token throughput and lower p50/p99 request
latency. Measured wall-clock on the real data plane, not modelled.

Session popularity comes from the shared workload generators in
:mod:`benchmarks.cache_sim` — the same ``zipf_trace`` that drives the
Fig. 8 memcached rows and the ``websearch_trace`` hot-set/cold-tail shape
behind Fig. 4 — so the serving, objcache, and page-fault-model benchmarks
all see one workload definition.

Env: ``REPRO_SERVE_ROWS`` (default 56) scales the pool,
``REPRO_SERVE_TURNS`` (default 48) the trace length. The committed
baselines (``benchmarks/baselines/BENCH_serving.json``) are snapshotted
at the CI smoke config — ``REPRO_SERVE_TURNS=32`` — so gate fresh runs
at that trace length (latency rows scale with it).
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks import cache_sim
from repro.configs.base import ModelConfig
from repro.serve import Engine, ServeRequest

DEFAULT_ROWS = int(os.environ.get("REPRO_SERVE_ROWS", "56"))
DEFAULT_TURNS = int(os.environ.get("REPRO_SERVE_TURNS", "48"))

CFG = ModelConfig(name="serve-bench", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=256, head_dim=16, dtype="float32")

PROMPT_LEN = 12
TURN_TOKENS = 6
MAX_LEN = 48
ROW_WORDS = 64          # 512-word pages -> 8-token KV blocks for this model


def _requests(kind: str, n_sessions: int, n_turns: int, seed: int,
              paid_frac: float) -> list[ServeRequest]:
    """Turn sequence over sessions from the shared trace generators."""
    rng = np.random.default_rng(seed)
    if kind == "zipf":
        visits = cache_sim.zipf_trace(rng, n_sessions, n_turns)
    elif kind == "websearch":
        # Fig. 4 regime: the HOT session set alone slightly overflows the
        # SECDED pool (and fits CREAM); a same-sized cold tail of one-off
        # sessions churns the parking pool on both modes equally
        visits = cache_sim.websearch_trace(rng, n_sessions, n_sessions,
                                           n_turns, hot_frac=0.85,
                                           alpha=0.4)
    else:
        raise ValueError(kind)
    prompts = {s: rng.integers(0, CFG.vocab_size,
                               size=PROMPT_LEN).astype(np.int32)
               for s in set(int(v) for v in visits)}
    n_paid = int(paid_frac * n_sessions)
    return [ServeRequest(f"s{int(s)}", prompts[int(s)], TURN_TOKENS,
                         tier="paid" if int(s) < n_paid else "batch")
            for s in visits]


def run(num_rows: int = DEFAULT_ROWS, n_turns: int = DEFAULT_TURNS,
        kind: str = "zipf", seed: int = 0,
        paid_frac: float = 0.0) -> dict[str, dict]:
    """Serve the same turn trace under both pool modes.

    ``num_rows`` is sized so the session working set overflows the SECDED
    pool (``num_rows`` pages) but mostly fits the CREAM pool
    (``1.125 * num_rows``): the capacity delta is the whole effect.
    """
    # sessions sized to ~the CREAM capacity: one session at full depth is
    # ceil(MAX_LEN / block_tokens) * n_layers pages (here 6*2 = 12... at
    # steady state most sit at 3 blocks * 2 layers = 6 pages)
    n_sessions = max(4, int(num_rows * 1.125) // 6)
    out: dict[str, dict] = {}
    for mode in ("secded", "cream"):
        reqs = _requests(kind, n_sessions, n_turns, seed, paid_frac)
        eng = Engine(CFG, max_batch=4, max_len=MAX_LEN, mode=mode,
                     num_rows=num_rows, row_words=ROW_WORDS,
                     max_sessions=8 * n_sessions)
        out[mode] = eng.serve(reqs)
        out[mode]["n_sessions"] = n_sessions
    out["cream"]["speedup_vs_secded"] = (
        out["cream"]["tokens_per_s"] / out["secded"]["tokens_per_s"])
    out["cream"]["capacity_gain"] = (
        out["cream"]["device_pages"] / out["secded"]["device_pages"] - 1)
    return out


def main(seed: int = 0) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    for kind in ("zipf", "websearch"):
        r = run(kind=kind, seed=seed)
        for mode in ("secded", "cream"):
            s = r[mode]
            rows.append((
                f"serving_{kind}_{mode}_tokens_per_s", s["tokens_per_s"],
                f"p50={s['p50_latency_ms']:.0f}ms,"
                f"p99={s['p99_latency_ms']:.0f}ms,"
                f"restores={s['restores']},pages={s['device_pages']}"))
            rows.append((f"serving_{kind}_{mode}_p99_ms",
                         s["p99_latency_ms"],
                         f"p50={s['p50_latency_ms']:.0f}ms"))
        rows.append((
            f"serving_{kind}_cream_speedup",
            r["cream"]["speedup_vs_secded"],
            f"capacity_gain={r['cream']['capacity_gain']:.3f},"
            f"paper_fig8=+23.0%/+37.3%"))
    return rows


if __name__ == "__main__":
    for name, val, derived in main():
        print(f"{name},{val:.3f},{derived}")

"""Beyond-paper: the JAX serving engine under CREAM vs SECDED pool modes.

The end-to-end analogue of Fig. 8 on the real stack: a small LM serves
multi-turn requests whose parked decode states overflow the device pool.
CREAM mode (+12.5% pages) keeps more sequences device-resident -> fewer
host round-trips -> higher token throughput. Measured, not modelled.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig
from repro.serve.engine import Engine, Request
from repro.serve.kv_cache import SequenceCache


def run(num_rows: int = 48, n_requests: int = 10, max_new: int = 10,
        seed: int = 0) -> dict[str, dict]:
    cfg = ModelConfig(name="serve-bench", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256, head_dim=16, dtype="float32")
    out = {}
    for mode in ("secded", "cream"):
        rng = np.random.default_rng(seed)
        reqs = [Request(f"s{i}", rng.integers(0, 256, size=24).astype(
            np.int32), max_new) for i in range(n_requests)]
        cache = SequenceCache(num_rows=num_rows, mode=mode)
        eng = Engine(cfg, batch_size=4, max_len=64, cache=cache)
        out[mode] = eng.serve(reqs, steps_per_turn=4)
    out["cream"]["speedup_vs_secded"] = (
        out["secded"]["wall_s"] / out["cream"]["wall_s"])
    out["cream"]["capacity_gain"] = (
        out["cream"]["device_pages"] / out["secded"]["device_pages"] - 1)
    return out


def main() -> list[tuple[str, float, str]]:
    r = run()
    rows = []
    for mode in ("secded", "cream"):
        s = r[mode]
        rows.append((f"serving_{mode}", s["tokens_per_s"],
                     f"faults={s['fault_rate']:.3f},pages={s['device_pages']}"))
    rows.append(("serving_cream_speedup", r["cream"]["speedup_vs_secded"],
                 f"capacity_gain={r['cream']['capacity_gain']:.3f}"))
    return rows


if __name__ == "__main__":
    for name, val, derived in main():
        print(f"{name},{val:.3f},{derived}")

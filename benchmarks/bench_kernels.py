"""Kernel shape sweeps: Pallas vs oracle wall time + allclose verification.

Interpret-mode timings are for regression tracking; the allclose checks are
the correctness payload (mirrored by tests/test_kernels_sweep.py).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(f, *args, reps: int = 2) -> float:
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def main() -> list[tuple[str, float, str]]:
    from repro.kernels.secded import kernel as sk, ref as sr
    from repro.kernels.ecc_matmul import kernel as mk, ref as mr
    from repro.kernels.flash_attention import kernel as fk, ref as fr

    rng = np.random.default_rng(0)
    rows = []

    for n, d in [(16, 1024), (64, 2048), (128, 4096)]:
        data = jnp.asarray(rng.integers(0, 2**32, size=(n, d),
                                        dtype=np.uint32))
        ck = sk.encode(data)
        assert (ck == sr.encode(data)).all()
        rows.append((f"secded_encode_{n}x{d}", _time(sk.encode, data),
                     f"ref_us={_time(sr.encode, data):.1f},allclose=1"))

    for m, k, n in [(128, 256, 128), (256, 512, 256)]:
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.bfloat16)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.bfloat16)
        bits, codes = mr.protect(a)
        yk = mk.ecc_matmul(bits, codes, b)
        yr = mr.ecc_matmul(bits, codes, b)
        ok = bool(jnp.allclose(yk, yr, rtol=1e-5, atol=1e-5))
        rows.append((f"ecc_matmul_{m}x{k}x{n}",
                     _time(mk.ecc_matmul, bits, codes, b),
                     f"ref_us={_time(mr.ecc_matmul, bits, codes, b):.1f},"
                     f"allclose={int(ok)}"))

    for b, hq, hkv, s, d in [(1, 4, 2, 128, 64), (2, 8, 2, 256, 64)]:
        q = jnp.asarray(rng.standard_normal((b, hq, s, d)), jnp.float32)
        kk = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
        yk = fk.attention(q, kk, v)
        yr = fr.attention(q, kk, v)
        ok = bool(jnp.allclose(yk, yr, rtol=2e-5, atol=2e-5))
        rows.append((f"flash_attn_b{b}h{hq}s{s}",
                     _time(fk.attention, q, kk, v),
                     f"ref_us={_time(fr.attention, q, kk, v):.1f},"
                     f"allclose={int(ok)}"))
    return rows


if __name__ == "__main__":
    for name, val, derived in main():
        print(f"{name},{val:.1f},{derived}")

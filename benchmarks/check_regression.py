"""Bench-regression gate: diff fresh ``BENCH_*.json`` against baselines.

``benchmarks/baselines/`` holds committed ``BENCH_<suite>.json`` snapshots
(the CI smoke config). After ``benchmarks/run.py --json .`` writes fresh
files, this script compares every baselined metric:

  * time-like metrics (the default; us/call): fail when
    ``fresh > baseline * tolerance``;
  * higher-is-better metrics (weighted speedups, hit rates — matched by
    name, see ``HIGHER_IS_BETTER``): fail when
    ``fresh < baseline / tolerance``.

A missing fresh file or metric fails too — a suite silently dropping rows
is itself a regression. Metrics present only in the fresh output are
reported but never fail (they gate once baselined). Partial-suite files
(``BENCH_*.partial.json``) are ignored on both sides.

Usage::

    python benchmarks/check_regression.py [--baseline benchmarks/baselines]
        [--fresh .] [--tolerance 1.5] [--suites vm,kernels]
        [--require-rows 'fig9_.*_blp']   # presence gate, no baseline needed
        [--require-min 'fig9_real_ws_s8>1.0']  # hard floor, repeatable
        [--update]        # rewrite baselines from fresh (rebaselining)

Exit status 0 = within tolerance, 1 = regression (every violation listed).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

#: Substrings marking metrics where *larger* is better. Everything else is
#: treated as a cost (us/call, latency ms) where smaller is better. Covers
#: the current suites: weighted speedups (`fig9_real_ws_*`), reclaimed-
#: capacity page counts (`vm_*_capacity`), the objcache demotion hit-rate
#: gain (`objcache_demotion`), the serving suite's token throughput
#: (`serving_*_tokens_per_s`) and CREAM speedups (`serving_*_speedup`),
#: and the CREAM-Lens achieved bank-level parallelism (`fig9_memprof_*blp`;
#: its conflict/stall/queue companions default to lower-is-better).
HIGHER_IS_BETTER = ("_ws_", "hit_rate", "hitrate", "speedup", "_gain",
                    "_capacity", "demotion", "_per_s", "_blp")

#: Substrings marking metrics where *smaller* is better — checked FIRST,
#: so a rate row can never be mis-read through a HIGHER_IS_BETTER tag it
#: happens to contain. Covers the fault-campaign suite: per-class
#: corrected/detected/silent read rates, objcache value-corruption rates,
#: and ticks-to-escalation (`faults_*_escalation_steps`). A zero baseline
#: here is a hard gate: `0 * tolerance = 0`, so e.g. the SECDED class's
#: silent-corruption rate must STAY zero.
LOWER_IS_BETTER = ("_corrected_rate", "_detected_rate", "_silent_rate",
                   "_corrupt_rate", "_error_rate", "_escalation_steps")


def is_higher_better(name: str) -> bool:
    if any(tag in name for tag in LOWER_IS_BETTER):
        return False
    return any(tag in name for tag in HIGHER_IS_BETTER)


def _load(path: str) -> dict[str, float]:
    """Load one BENCH file, keeping only gateable numeric metrics.

    ``--profile`` runs embed non-scalar rows (the ``_metrics`` telemetry
    blob); a rebaselined file may therefore carry them too. Those are
    warned about and skipped on both sides — never a format crash, never
    a spurious violation; only real metric regressions exit nonzero.
    """
    with open(path) as f:
        raw = json.load(f)
    out: dict[str, float] = {}
    skipped: list[str] = []
    for name, val in raw.items():
        if name.startswith("_") or isinstance(val, bool) \
                or not isinstance(val, (int, float)):
            skipped.append(name)
            continue
        out[name] = float(val)
    if skipped:
        print(f"# {os.path.basename(path)}: skipping "
              f"{len(skipped)} non-metric entr(y/ies): "
              + ", ".join(skipped[:8])
              + (" ..." if len(skipped) > 8 else ""))
    return out


def _suite_of(path: str) -> str | None:
    base = os.path.basename(path)
    if not base.startswith("BENCH_") or not base.endswith(".json") \
            or base.endswith(".partial.json"):
        return None
    return base[len("BENCH_"):-len(".json")]


def check(baseline_dir: str, fresh_dir: str, tolerance: float,
          suites: set[str] | None = None) -> list[str]:
    """Returns the list of violations (empty = gate passes)."""
    violations: list[str] = []
    seen_any = False
    for bpath in sorted(glob.glob(os.path.join(baseline_dir,
                                               "BENCH_*.json"))):
        suite = _suite_of(bpath)
        if suite is None or (suites is not None and suite not in suites):
            continue
        seen_any = True
        fpath = os.path.join(fresh_dir, f"BENCH_{suite}.json")
        if not os.path.exists(fpath):
            violations.append(
                f"{suite}: fresh file {fpath} missing "
                "(suite failed or was not run)")
            continue
        base, fresh = _load(bpath), _load(fpath)
        for name, bval in sorted(base.items()):
            if name not in fresh:
                violations.append(f"{suite}/{name}: metric disappeared "
                                  f"(baseline {bval:.3f})")
                continue
            fval = fresh[name]
            if is_higher_better(name):
                limit = bval / tolerance
                ok = fval >= limit
                verdict = f"{fval:.3f} < {limit:.3f} (baseline {bval:.3f} " \
                          f"/ {tolerance}x)"
            else:
                limit = bval * tolerance
                ok = fval <= limit
                verdict = f"{fval:.3f} > {limit:.3f} (baseline {bval:.3f} " \
                          f"* {tolerance}x)"
            if not ok:
                violations.append(f"{suite}/{name}: {verdict}")
        new = sorted(set(fresh) - set(base))
        if new:
            print(f"# {suite}: {len(new)} unbaselined metric(s) "
                  f"(not gated): {', '.join(new[:8])}"
                  + (" ..." if len(new) > 8 else ""))
    if not seen_any:
        violations.append(f"no baselines found under {baseline_dir}")
    return violations


def check_required(fresh_dir: str, pattern: str,
                   suites: set[str] | None = None) -> list[str]:
    """Presence gate: >= 1 fresh row must match ``pattern``, all finite.

    Unlike the relative gate above, this needs no baseline: it asserts a
    row *family* exists at all (e.g. the CI ``--memprof`` run must emit
    ``fig9_.*_blp`` rows) and that none of the matches is NaN/inf — a
    profiler that silently captured nothing would otherwise pass.
    """
    import math
    import re
    rx = re.compile(pattern)
    matched = 0
    bad: list[str] = []
    for fpath in sorted(glob.glob(os.path.join(fresh_dir, "BENCH_*.json"))):
        suite = _suite_of(fpath)
        if suite is None or (suites is not None and suite not in suites):
            continue
        for name, val in sorted(_load(fpath).items()):
            if rx.search(name):
                matched += 1
                if math.isnan(val) or math.isinf(val):
                    bad.append(f"{suite}/{name}: required row is {val}")
    if not matched:
        bad.append(f"no fresh rows match required pattern {pattern!r}")
    else:
        print(f"# required-rows gate: {matched} row(s) match "
              f"{pattern!r}, all finite" if not bad else
              f"# required-rows gate: {matched} row(s) match {pattern!r}")
    return bad


def check_min(fresh_dir: str, spec: str,
              suites: set[str] | None = None) -> list[str]:
    """Hard min-value gate: every fresh row matching ``REGEX`` in a
    ``'REGEX>VALUE'`` spec must be finite and strictly above ``VALUE``.

    Unlike the relative gate, this is an *absolute* floor that no
    rebaselining can erode — e.g. ``'fig9_real_ws_s8>1.0'`` pins the
    Fig. 9 weighted speedup above parity forever. At least one row must
    match (a suite silently dropping the gated row family fails).
    """
    import math
    import re
    if ">" not in spec:
        return [f"bad --require-min spec {spec!r} (expected 'REGEX>VALUE')"]
    pattern, _, floor_s = spec.rpartition(">")
    try:
        floor = float(floor_s)
    except ValueError:
        return [f"bad --require-min floor {floor_s!r} in {spec!r}"]
    rx = re.compile(pattern)
    matched = 0
    bad: list[str] = []
    for fpath in sorted(glob.glob(os.path.join(fresh_dir, "BENCH_*.json"))):
        suite = _suite_of(fpath)
        if suite is None or (suites is not None and suite not in suites):
            continue
        for name, val in sorted(_load(fpath).items()):
            if rx.search(name):
                matched += 1
                if math.isnan(val) or math.isinf(val):
                    bad.append(f"{suite}/{name}: gated row is {val} "
                               f"(must be > {floor})")
                elif val <= floor:
                    bad.append(f"{suite}/{name}: {val:.3f} <= {floor} "
                               f"(hard floor)")
    if not matched:
        bad.append(f"no fresh rows match min-gate pattern {pattern!r}")
    elif not bad:
        print(f"# min-value gate: {matched} row(s) match {pattern!r}, "
              f"all > {floor}")
    return bad


def update(baseline_dir: str, fresh_dir: str,
           suites: set[str] | None = None) -> None:
    os.makedirs(baseline_dir, exist_ok=True)
    for fpath in sorted(glob.glob(os.path.join(fresh_dir, "BENCH_*.json"))):
        suite = _suite_of(fpath)
        if suite is None or (suites is not None and suite not in suites):
            continue
        out = os.path.join(baseline_dir, f"BENCH_{suite}.json")
        with open(out, "w") as f:
            json.dump(_load(fpath), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# rebaselined {out}")


def main() -> None:
    here = os.path.dirname(os.path.abspath(__file__))
    ap = argparse.ArgumentParser(
        description="diff fresh BENCH_*.json against committed baselines")
    ap.add_argument("--baseline", default=os.path.join(here, "baselines"))
    ap.add_argument("--fresh", default=".")
    ap.add_argument("--tolerance", type=float, default=1.5,
                    help="allowed slowdown/shrink factor (default 1.5x)")
    ap.add_argument("--suites", default=None,
                    help="comma-separated subset (default: every baseline)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite baselines from the fresh files and exit")
    ap.add_argument("--require-rows", default=None, metavar="REGEX",
                    help="additionally require >= 1 fresh row matching REGEX"
                         ", all finite (presence gate, no baseline needed)")
    ap.add_argument("--require-min", action="append", default=[],
                    metavar="'REGEX>VALUE'",
                    help="hard floor: every fresh row matching REGEX must be"
                         " finite and > VALUE (repeatable; immune to"
                         " rebaselining)")
    args = ap.parse_args()
    suites = set(args.suites.split(",")) if args.suites else None
    if args.update:
        update(args.baseline, args.fresh, suites)
        return
    violations = check(args.baseline, args.fresh, args.tolerance, suites)
    if args.require_rows:
        violations += check_required(args.fresh, args.require_rows, suites)
    for spec in args.require_min:
        violations += check_min(args.fresh, spec, suites)
    if violations:
        print(f"BENCH REGRESSION ({len(violations)} violation(s), "
              f"tolerance {args.tolerance}x):", file=sys.stderr)
        for v in violations:
            print(f"  {v}", file=sys.stderr)
        raise SystemExit(1)
    print(f"# bench regression gate passed (tolerance {args.tolerance}x)")


if __name__ == "__main__":
    main()

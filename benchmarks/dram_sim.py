"""Compact DRAM timing model — the paper's Ramulator substitute (Figs. 9–11).

Event-driven over 72 bank-slices (8 banks × 9 chips) of one DDR3-1333H rank:

  * every logical line access expands to DRAM operations via
    ``repro.core.layouts.plan_line_access`` — the SAME address translation
    the JAX pool and Pallas kernels use, so layout behaviour (packed RMWs,
    rank-subset op counts, inter-wrap single-op access) is identical by
    construction;
  * each op occupies its (row, lane) slices in lockstep: row miss pays
    tRP+tRCD+tCL, hit pays tCL, +1 bridge cycle for CREAM layouts
    (paper §5); the shared 72-bit data bus serialises transfers (tBL);
  * FR-FCFS: a lookahead window prefers row-buffer hits (paper's scheduler);
  * cores: 4-wide issue with a bounded MLP window — a request issues only
    when a slot frees, which is what couples memory latency back to IPC.

Faithfulness targets (checked in EXPERIMENTS.md §Benchmarks): the op-count
ratios (Fig. 10a: Packed ≈ 2.0×, Packed+RS ≈ 1.77×, InterWrap 1.0×) are
exact; concurrency/latency/weighted-speedup reproduce the paper's ordering
Packed < Packed+RS < Baseline < InterWrap.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

import numpy as np

from repro.core.layouts import (LANES, Layout,
                                plan_line_access, total_pages)


@dataclass(frozen=True)
class Timing:
    """DRAM timing parameters, in memory-clock cycles (nCK).

    Defaults are the JEDEC **DDR4-2400** speed bin, CL-nRCD-nRP = 16-16-16
    (JESD79-4; the same parameter set Ramulator ships as ``DDR4_2400R`` and
    Micron documents for MT40A-083E parts): tCK = 0.833 ns, tCAS/tRCD/tRP =
    13.32 ns = 16 nCK, burst BL8 over a DDR bus = 4 nCK, tRRD_S = 4 nCK
    (≥ 3.3 ns, x8 parts / 1 KB pages), tFAW = 26 nCK (≥ 21 ns).

    ``bridge`` is CREAM's +1-cycle bridge-chip translation (paper §4.4) and
    is the one parameter not drawn from the JEDEC bin.
    """
    tCK_ns: float = 0.833
    tRCD: int = 16
    tRP: int = 16
    tCL: int = 16
    tBL: int = 4          # 8 beats, DDR
    tRRD: int = 4         # ACT->ACT, different banks, same rank (tRRD_S)
    tFAW: int = 26        # rolling four-ACT window per rank
    bridge: int = 1       # CREAM bridge-chip translation (paper §4.4)


NUM_BANKS = 8


def bank_of(row: int) -> tuple[int, int]:
    """Pool row -> (bank, dram_row): consecutive rows hit different banks
    (paper Fig. 3's page->bank interleaving). Shared by the timing model
    below and the bank-attribution path in :mod:`repro.obs.memprof`."""
    return row % NUM_BANKS, row // NUM_BANKS


@dataclass
class Slice:
    open_row: int = -1
    free_at: int = 0


@dataclass
class SimStats:
    requests: int = 0
    device_ops: int = 0
    row_hits: int = 0
    row_misses: int = 0
    total_latency: int = 0
    finish_cycle: int = 0
    concurrent_sum: float = 0.0
    concurrent_samples: int = 0
    service_cycles: int = 0      # Σ op occupancy — drives the BLP metric

    @property
    def row_hit_rate(self) -> float:
        """Row-buffer hit fraction; 0.0 (not NaN) for a zero-access run."""
        t = self.row_hits + self.row_misses
        return self.row_hits / t if t > 0 else 0.0

    @property
    def avg_latency(self) -> float:
        return self.total_latency / self.requests if self.requests > 0 else 0.0

    @property
    def avg_concurrent(self) -> float:
        if self.concurrent_samples <= 0:
            return 0.0
        return self.concurrent_sum / self.concurrent_samples

    @property
    def blp(self) -> float:
        """Average concurrently-serviced requests (paper Fig. 10b): total
        op occupancy over the makespan — low when expansions serialise on a
        bank, high when 9 independent slice groups overlap. 0.0 (not NaN
        or a bogus ratio) when the run issued nothing."""
        return self.service_cycles / self.finish_cycle \
            if self.finish_cycle > 0 else 0.0


# Backwards-compatible alias (pre-profiler name).
_bank_of = bank_of


@dataclass
class Core:
    """A request stream with an MLP window; gaps model non-memory work."""
    requests: list            # [(page, write, gap_cycles), ...]
    window: int = 8
    next_idx: int = 0
    inflight: list = field(default_factory=list)   # completion cycles (heap)
    ready_at: int = 0         # when the next request may issue


class DRAMSim:
    def __init__(self, layout: Layout, num_rows: int, timing: Timing = Timing(),
                 window: int = 16):
        self.layout = layout
        self.num_rows = num_rows
        self.t = timing
        self.window = window
        self.slices = [[Slice() for _ in range(LANES)]
                       for _ in range(NUM_BANKS)]
        self.bus_free = 0
        self.stats = SimStats()
        self._bridge = 0 if layout == Layout.BASELINE_ECC else timing.bridge

    # -- single op ------------------------------------------------------------
    def _op_time(self, access, now: int) -> int:
        """Issue one lockstep op at/after `now`; returns completion cycle."""
        t = self.t
        start = now
        slice_objs = []
        for lane, row in access.slices:
            bank, drow = _bank_of(row)
            s = self.slices[bank][lane]
            slice_objs.append((s, drow))
            start = max(start, s.free_at)
        # row hit iff every touched slice has the row open
        hit = all(s.open_row == drow for s, drow in slice_objs)
        lat = t.tCL + (0 if hit else t.tRP + t.tRCD) + self._bridge
        self.stats.row_hits += 1 if hit else 0
        self.stats.row_misses += 0 if hit else 1
        # data bus: serialise the burst
        burst_start = max(start + lat, self.bus_free)
        done = burst_start + t.tBL
        self.bus_free = done
        for s, drow in slice_objs:
            s.open_row = drow
            s.free_at = done
        n_ops = 2 if access.rmw else 1
        service = lat + t.tBL
        if access.rmw:      # read-before-write: second pass on the bus
            done += t.tCL + t.tBL
            service += t.tCL + t.tBL
            self.bus_free = done
            for s, _ in slice_objs:
                s.free_at = done
        self.stats.device_ops += n_ops
        self.stats.service_cycles += service
        return done

    def _request_time(self, page: int, write: bool, now: int) -> int:
        ops = plan_line_access(self.layout, self.num_rows, page, write)
        done = now
        for i, acc in enumerate(ops):
            done = self._op_time(acc, now if i == 0 else done)
        return done

    # -- multiprogrammed run -----------------------------------------------------
    def run(self, cores: list[Core]) -> SimStats:
        """Interleave core request streams FR-FCFS-ish; returns stats."""
        now = 0
        active = [c for c in cores if c.next_idx < len(c.requests)]
        while active:
            # sample concurrency
            inflight = sum(len(c.inflight) for c in cores)
            self.stats.concurrent_sum += inflight
            self.stats.concurrent_samples += 1

            # pick among issuable heads: prefer row-hit requests (FR-FCFS)
            candidates = []
            for c in active:
                while c.inflight and c.inflight[0] <= now:
                    heapq.heappop(c.inflight)
                if len(c.inflight) >= c.window:
                    continue
                if c.ready_at > now:
                    continue
                page, write, gap = c.requests[c.next_idx]
                first = plan_line_access(self.layout, self.num_rows, page,
                                         write)[0]
                hit = True
                for lane, row in first.slices:
                    bank, drow = _bank_of(row)
                    if self.slices[bank][lane].open_row != drow:
                        hit = False
                        break
                candidates.append((0 if hit else 1, c.ready_at, id(c), c))
            if not candidates:
                # advance time to the next event
                nxt = []
                for c in active:
                    if c.inflight:
                        nxt.append(c.inflight[0])
                    if c.ready_at > now:
                        nxt.append(c.ready_at)
                now = min(nxt) if nxt else now + 1
                active = [c for c in cores if c.next_idx < len(c.requests)]
                continue
            candidates.sort()
            _, _, _, c = candidates[0]
            page, write, gap = c.requests[c.next_idx]
            done = self._request_time(page, write, now)
            heapq.heappush(c.inflight, done)
            self.stats.requests += 1
            self.stats.total_latency += done - now
            c.next_idx += 1
            c.ready_at = now + max(gap, 1)
            if c.next_idx >= len(c.requests):
                c.done_at = done
            active = [c for c in cores if c.next_idx < len(c.requests)]
            now += 2  # command-bus arbitration: one issue per 2 cycles
        finish = max((getattr(c, "done_at", 0) for c in cores), default=0)
        for c in cores:
            if c.inflight:
                finish = max(finish, max(c.inflight))
        self.stats.finish_cycle = finish
        return self.stats


# ---------------------------------------------------------------------------
# Gram-style per-bank state machines (trace replay for repro.obs.memprof)
#
# The event loop above couples a synthetic core model to the layout's op
# expansion. The classes below are the opposite cut: no cores, no layout —
# just the DRAM itself, one explicit state machine per (chip, bank) slice
# in the style of a real controller's bank machines (gram/LiteDRAM: open-row
# register, precharge/activate timing, per-rank tRRD/tFAW activation
# windows, a request queue per bank). ``repro.obs.memprof`` replays page
# access streams captured from the *real* data plane through a
# :class:`BankArray` to get per-bank row hit/miss/conflict counts,
# achieved bank-level parallelism, tFAW/tRRD stall cycles and queue-depth
# percentiles — the measurement behind the ``fig9_memprof_*`` rows.
# ---------------------------------------------------------------------------


@dataclass
class BankCounters:
    """Per-(chip, bank) census a :class:`BankMachine` accumulates."""
    accesses: int = 0
    row_hits: int = 0
    row_empty: int = 0        # miss with no row open (cold activate)
    row_conflicts: int = 0    # miss with a different row open (PRE + ACT)
    busy_cycles: int = 0      # Σ per-access service occupancy
    act_stall_cycles: int = 0  # cycles this bank waited on tRRD + tFAW
    faw_stall_cycles: int = 0  # the tFAW share of act_stall_cycles


@dataclass
class BankMachine:
    """Row-buffer state machine for one (chip, bank) slice."""
    open_row: int = -1
    free_at: int = 0
    counters: BankCounters = field(default_factory=BankCounters)


class RankTimers:
    """Per-chip (rank-subset) activation bookkeeping: tRRD + tFAW.

    A chip under rank subsetting is independently addressable, so each chip
    carries its own four-ACT window — the paper's §4.1.2 concurrency
    argument is exactly that these windows stop being shared.
    """

    def __init__(self, t: Timing):
        self.t = t
        self.last_act = -10**9
        self.act_times: list[int] = []     # up to the last 4 ACT cycles

    def earliest_act(self, ready: int) -> tuple[int, int]:
        """Earliest cycle an ACT may issue at/after ``ready``.

        Returns ``(act_at, faw_stall)`` where ``faw_stall`` is the share of
        the delay imposed by the four-ACT window alone (on top of tRRD)."""
        rrd_at = max(ready, self.last_act + self.t.tRRD)
        faw_at = rrd_at
        if len(self.act_times) >= 4:
            faw_at = max(rrd_at, self.act_times[-4] + self.t.tFAW)
        return faw_at, faw_at - rrd_at

    def commit_act(self, cycle: int) -> None:
        self.last_act = cycle
        self.act_times.append(cycle)
        if len(self.act_times) > 4:
            del self.act_times[0]


class BankArray:
    """All bank machines of one module: ``chips`` ranks × NUM_BANKS banks.

    ``access(slices, now)`` issues one lockstep page-slice access —
    ``slices`` is ``[(chip, bank, dram_row), ...]`` — applying per-bank
    row-buffer state, per-chip tRRD/tFAW activation limits and per-bank
    serialisation (a busy bank queues the access). Returns the completion
    cycle. ``bridge_cycles`` models CREAM's bridge-chip translation.
    """

    def __init__(self, timing: Timing | None = None, chips: int = LANES,
                 banks: int = NUM_BANKS, bridge_cycles: int = 0):
        self.t = timing or Timing()
        self.chips = chips
        self.banks = banks
        self.bridge = bridge_cycles
        self.machines = [[BankMachine() for _ in range(banks)]
                         for _ in range(chips)]
        self.ranks = [RankTimers(self.t) for _ in range(chips)]
        self.finish_cycle = 0
        self.blp_samples: list[float] = []   # per-access overlap snapshots
        self.queue_depths: list[int] = []    # per-access waiting depth
        self.sample_times: list[int] = []    # issue cycle of each snapshot

    def machine(self, chip: int, bank: int) -> BankMachine:
        return self.machines[chip][bank]

    def access(self, slices, now: int) -> int:
        t = self.t
        done_max = now
        waiting = 0
        for chip, bank, drow in slices:
            m = self.machines[chip][bank]
            if m.free_at > now:
                waiting += 1
            start = max(now, m.free_at)
            if m.open_row == drow:
                m.counters.row_hits += 1
                lat = t.tCL
            else:
                act_ready = start + (0 if m.open_row < 0 else t.tRP)
                act_at, faw_stall = self.ranks[chip].earliest_act(act_ready)
                m.counters.act_stall_cycles += act_at - act_ready
                m.counters.faw_stall_cycles += faw_stall
                self.ranks[chip].commit_act(act_at)
                if m.open_row < 0:
                    m.counters.row_empty += 1
                else:
                    m.counters.row_conflicts += 1
                lat = (act_at - start) + t.tRCD + t.tCL
            done = start + lat + t.tBL + self.bridge
            m.counters.accesses += 1
            m.counters.busy_cycles += done - start
            m.open_row = drow
            m.free_at = done
            done_max = max(done_max, done)
        # overlap snapshot: banks still busy after this access issued
        busy = sum(1 for row in self.machines for m in row
                   if m.free_at > now)
        self.blp_samples.append(float(busy))
        self.queue_depths.append(waiting)
        self.sample_times.append(now)
        self.finish_cycle = max(self.finish_cycle, done_max)
        return done_max

    # -- aggregate census ----------------------------------------------------
    def totals(self) -> BankCounters:
        tot = BankCounters()
        for row in self.machines:
            for m in row:
                c = m.counters
                tot.accesses += c.accesses
                tot.row_hits += c.row_hits
                tot.row_empty += c.row_empty
                tot.row_conflicts += c.row_conflicts
                tot.busy_cycles += c.busy_cycles
                tot.act_stall_cycles += c.act_stall_cycles
                tot.faw_stall_cycles += c.faw_stall_cycles
        return tot

    @property
    def row_hit_rate(self) -> float:
        tot = self.totals()
        return tot.row_hits / tot.accesses if tot.accesses > 0 else 0.0

    @property
    def achieved_blp(self) -> float:
        """Busy-bank cycles over the makespan — banks genuinely overlapping
        service. 0.0 for an empty replay (division guard)."""
        tot = self.totals()
        return tot.busy_cycles / self.finish_cycle \
            if self.finish_cycle > 0 else 0.0

    def blp_histogram(self, bins: int = 8) -> list[int]:
        """Histogram of per-access busy-bank snapshots (overlap levels)."""
        counts = [0] * bins
        for v in self.blp_samples:
            counts[min(int(v), bins - 1)] += 1
        return counts

    def queue_depth_percentile(self, q: float) -> float:
        if not self.queue_depths:
            return 0.0
        return float(np.percentile(np.asarray(self.queue_depths), q))


# ---------------------------------------------------------------------------
# Workload generation (paper §5: SPEC/TPC-like mixes by MPKI class)
# ---------------------------------------------------------------------------


def make_core(rng: np.random.Generator, layout: Layout, num_rows: int,
              n_requests: int, memory_intensive: bool,
              use_extra_pages: bool = True, window: int = 8) -> Core:
    """Synthetic request stream with page- and line-level locality.

    A core walks pages randomly but issues a *run* of sequential line
    accesses within each page (geometric, mean ~8), the standard locality
    structure row-buffer policies are designed around. Memory-intensive
    cores (MPKI>10 class) have short compute gaps; others long.
    """
    n_pages = total_pages(layout, num_rows) if use_extra_pages else num_rows
    gap = 4 if memory_intensive else 60          # cycles of non-mem work
    reqs = []
    while len(reqs) < n_requests:
        page = int(rng.integers(0, n_pages))
        run = min(1 + rng.geometric(1.0 / 8.0), n_requests - len(reqs))
        write = rng.random() < 0.3
        for _ in range(run):
            reqs.append((page, write, gap))
    return Core(requests=reqs, window=window)


def run_workload(layout: Layout, num_rows: int, rng_seed: int,
                 n_mem_intensive: int, n_cores: int = 4,
                 n_requests: int = 1500) -> SimStats:
    rng = np.random.default_rng(rng_seed)
    cores = [make_core(rng, layout, num_rows, n_requests,
                       memory_intensive=(i < n_mem_intensive))
             for i in range(n_cores)]
    return DRAMSim(layout, num_rows).run(cores)

"""Compact DRAM timing model — the paper's Ramulator substitute (Figs. 9–11).

Event-driven over 72 bank-slices (8 banks × 9 chips) of one DDR3-1333H rank:

  * every logical line access expands to DRAM operations via
    ``repro.core.layouts.plan_line_access`` — the SAME address translation
    the JAX pool and Pallas kernels use, so layout behaviour (packed RMWs,
    rank-subset op counts, inter-wrap single-op access) is identical by
    construction;
  * each op occupies its (row, lane) slices in lockstep: row miss pays
    tRP+tRCD+tCL, hit pays tCL, +1 bridge cycle for CREAM layouts
    (paper §5); the shared 72-bit data bus serialises transfers (tBL);
  * FR-FCFS: a lookahead window prefers row-buffer hits (paper's scheduler);
  * cores: 4-wide issue with a bounded MLP window — a request issues only
    when a slot frees, which is what couples memory latency back to IPC.

Faithfulness targets (checked in EXPERIMENTS.md §Benchmarks): the op-count
ratios (Fig. 10a: Packed ≈ 2.0×, Packed+RS ≈ 1.77×, InterWrap 1.0×) are
exact; concurrency/latency/weighted-speedup reproduce the paper's ordering
Packed < Packed+RS < Baseline < InterWrap.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

import numpy as np

from repro.core.layouts import (LANES, Layout,
                                plan_line_access, total_pages)


@dataclass(frozen=True)
class Timing:
    tCK_ns: float = 1.5
    tRCD: int = 9
    tRP: int = 9
    tCL: int = 9
    tBL: int = 4          # 8 beats, DDR
    bridge: int = 1       # CREAM bridge-chip translation (paper §4.4)


NUM_BANKS = 8


@dataclass
class Slice:
    open_row: int = -1
    free_at: int = 0


@dataclass
class SimStats:
    requests: int = 0
    device_ops: int = 0
    row_hits: int = 0
    row_misses: int = 0
    total_latency: int = 0
    finish_cycle: int = 0
    concurrent_sum: float = 0.0
    concurrent_samples: int = 0
    service_cycles: int = 0      # Σ op occupancy — drives the BLP metric

    @property
    def row_hit_rate(self) -> float:
        t = self.row_hits + self.row_misses
        return self.row_hits / t if t else 0.0

    @property
    def avg_latency(self) -> float:
        return self.total_latency / max(self.requests, 1)

    @property
    def avg_concurrent(self) -> float:
        return self.concurrent_sum / max(self.concurrent_samples, 1)

    @property
    def blp(self) -> float:
        """Average concurrently-serviced requests (paper Fig. 10b): total
        op occupancy over the makespan — low when expansions serialise on a
        bank, high when 9 independent slice groups overlap."""
        return self.service_cycles / max(self.finish_cycle, 1)


def _bank_of(row: int) -> tuple[int, int]:
    """Pool row -> (bank, dram_row): consecutive rows hit different banks
    (paper Fig. 3's page->bank interleaving)."""
    return row % NUM_BANKS, row // NUM_BANKS


@dataclass
class Core:
    """A request stream with an MLP window; gaps model non-memory work."""
    requests: list            # [(page, write, gap_cycles), ...]
    window: int = 8
    next_idx: int = 0
    inflight: list = field(default_factory=list)   # completion cycles (heap)
    ready_at: int = 0         # when the next request may issue


class DRAMSim:
    def __init__(self, layout: Layout, num_rows: int, timing: Timing = Timing(),
                 window: int = 16):
        self.layout = layout
        self.num_rows = num_rows
        self.t = timing
        self.window = window
        self.slices = [[Slice() for _ in range(LANES)]
                       for _ in range(NUM_BANKS)]
        self.bus_free = 0
        self.stats = SimStats()
        self._bridge = 0 if layout == Layout.BASELINE_ECC else timing.bridge

    # -- single op ------------------------------------------------------------
    def _op_time(self, access, now: int) -> int:
        """Issue one lockstep op at/after `now`; returns completion cycle."""
        t = self.t
        start = now
        slice_objs = []
        for lane, row in access.slices:
            bank, drow = _bank_of(row)
            s = self.slices[bank][lane]
            slice_objs.append((s, drow))
            start = max(start, s.free_at)
        # row hit iff every touched slice has the row open
        hit = all(s.open_row == drow for s, drow in slice_objs)
        lat = t.tCL + (0 if hit else t.tRP + t.tRCD) + self._bridge
        self.stats.row_hits += 1 if hit else 0
        self.stats.row_misses += 0 if hit else 1
        # data bus: serialise the burst
        burst_start = max(start + lat, self.bus_free)
        done = burst_start + t.tBL
        self.bus_free = done
        for s, drow in slice_objs:
            s.open_row = drow
            s.free_at = done
        n_ops = 2 if access.rmw else 1
        service = lat + t.tBL
        if access.rmw:      # read-before-write: second pass on the bus
            done += t.tCL + t.tBL
            service += t.tCL + t.tBL
            self.bus_free = done
            for s, _ in slice_objs:
                s.free_at = done
        self.stats.device_ops += n_ops
        self.stats.service_cycles += service
        return done

    def _request_time(self, page: int, write: bool, now: int) -> int:
        ops = plan_line_access(self.layout, self.num_rows, page, write)
        done = now
        for i, acc in enumerate(ops):
            done = self._op_time(acc, now if i == 0 else done)
        return done

    # -- multiprogrammed run -----------------------------------------------------
    def run(self, cores: list[Core]) -> SimStats:
        """Interleave core request streams FR-FCFS-ish; returns stats."""
        now = 0
        active = [c for c in cores if c.next_idx < len(c.requests)]
        while active:
            # sample concurrency
            inflight = sum(len(c.inflight) for c in cores)
            self.stats.concurrent_sum += inflight
            self.stats.concurrent_samples += 1

            # pick among issuable heads: prefer row-hit requests (FR-FCFS)
            candidates = []
            for c in active:
                while c.inflight and c.inflight[0] <= now:
                    heapq.heappop(c.inflight)
                if len(c.inflight) >= c.window:
                    continue
                if c.ready_at > now:
                    continue
                page, write, gap = c.requests[c.next_idx]
                first = plan_line_access(self.layout, self.num_rows, page,
                                         write)[0]
                hit = True
                for lane, row in first.slices:
                    bank, drow = _bank_of(row)
                    if self.slices[bank][lane].open_row != drow:
                        hit = False
                        break
                candidates.append((0 if hit else 1, c.ready_at, id(c), c))
            if not candidates:
                # advance time to the next event
                nxt = []
                for c in active:
                    if c.inflight:
                        nxt.append(c.inflight[0])
                    if c.ready_at > now:
                        nxt.append(c.ready_at)
                now = min(nxt) if nxt else now + 1
                active = [c for c in cores if c.next_idx < len(c.requests)]
                continue
            candidates.sort()
            _, _, _, c = candidates[0]
            page, write, gap = c.requests[c.next_idx]
            done = self._request_time(page, write, now)
            heapq.heappush(c.inflight, done)
            self.stats.requests += 1
            self.stats.total_latency += done - now
            c.next_idx += 1
            c.ready_at = now + max(gap, 1)
            if c.next_idx >= len(c.requests):
                c.done_at = done
            active = [c for c in cores if c.next_idx < len(c.requests)]
            now += 2  # command-bus arbitration: one issue per 2 cycles
        finish = max((getattr(c, "done_at", 0) for c in cores), default=0)
        for c in cores:
            if c.inflight:
                finish = max(finish, max(c.inflight))
        self.stats.finish_cycle = finish
        return self.stats


# ---------------------------------------------------------------------------
# Workload generation (paper §5: SPEC/TPC-like mixes by MPKI class)
# ---------------------------------------------------------------------------


def make_core(rng: np.random.Generator, layout: Layout, num_rows: int,
              n_requests: int, memory_intensive: bool,
              use_extra_pages: bool = True, window: int = 8) -> Core:
    """Synthetic request stream with page- and line-level locality.

    A core walks pages randomly but issues a *run* of sequential line
    accesses within each page (geometric, mean ~8), the standard locality
    structure row-buffer policies are designed around. Memory-intensive
    cores (MPKI>10 class) have short compute gaps; others long.
    """
    n_pages = total_pages(layout, num_rows) if use_extra_pages else num_rows
    gap = 4 if memory_intensive else 60          # cycles of non-mem work
    reqs = []
    while len(reqs) < n_requests:
        page = int(rng.integers(0, n_pages))
        run = min(1 + rng.geometric(1.0 / 8.0), n_requests - len(reqs))
        write = rng.random() < 0.3
        for _ in range(run):
            reqs.append((page, write, gap))
    return Core(requests=reqs, window=window)


def run_workload(layout: Layout, num_rows: int, rng_seed: int,
                 n_mem_intensive: int, n_cores: int = 4,
                 n_requests: int = 1500) -> SimStats:
    rng = np.random.default_rng(rng_seed)
    cores = [make_core(rng, layout, num_rows, n_requests,
                       memory_intensive=(i < n_mem_intensive))
             for i in range(n_cores)]
    return DRAMSim(layout, num_rows).run(cores)

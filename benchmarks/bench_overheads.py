"""§4.4-style overhead table: CREAM software costs per layout + kernel rates.

The paper synthesises its bridge-chip logic (493µm², 198ps); our software
analogue reports (a) the per-layout device-op counts straight from the
shared address translation, and (b) wall-clock throughput of the CREAM
kernels (interpret mode on CPU — for relative comparison and regression
tracking, not absolute TPU numbers).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layouts import Layout, count_device_ops, extra_page_count


def op_count_table(num_rows: int = 1024) -> dict[str, dict[str, float]]:
    out = {}
    for layout in (Layout.BASELINE_ECC, Layout.PACKED, Layout.RANK_SUBSET,
                   Layout.INTERWRAP, Layout.PARITY):
        extra = extra_page_count(layout, num_rows)
        total = num_rows + extra
        reads = sum(count_device_ops(layout, num_rows, p, False)
                    for p in range(total))
        writes = sum(count_device_ops(layout, num_rows, p, True)
                     for p in range(total))
        out[layout.value] = {
            "read_ops_per_access": reads / total,
            "write_ops_per_access": writes / total,
            "capacity_gain": extra / num_rows,
        }
    return out


def _time(f, *args, reps: int = 3) -> float:
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def kernel_rates() -> dict[str, float]:
    from repro.kernels.secded import ops as se
    from repro.kernels.parity8 import ops as pa
    from repro.kernels.interwrap import ops as iw
    from repro.kernels.scrub import ops as sc

    rng = np.random.default_rng(0)
    data = jnp.asarray(rng.integers(0, 2**32, size=(64, 2048), dtype=np.uint32))
    codes = se.encode(data)
    storage = jnp.asarray(rng.integers(0, 2**32, size=(64, 9, 256),
                                       dtype=np.uint32))
    pages = jnp.arange(16, dtype=jnp.int32)
    out = {
        "secded_encode_us": _time(lambda d: se.encode(d), data),
        "secded_decode_us": _time(lambda d, c: se.decode(d, c), data, codes),
        "parity_encode_us": _time(lambda d: pa.encode(d), data),
        "interwrap_gather_us": _time(
            lambda s, p: iw.gather(s, p, 64), storage, pages),
        "scrub_row_us": _time(lambda s: sc.scrub_rows(s), storage),
    }
    mb = data.nbytes / 1e6
    out["secded_encode_MBps"] = mb / (out["secded_encode_us"] / 1e6)
    return out


def main() -> list[tuple[str, float, str]]:
    rows = []
    for layout, t in op_count_table().items():
        rows.append((f"ops_{layout}", t["read_ops_per_access"],
                     f"write={t['write_ops_per_access']:.2f},"
                     f"gain={t['capacity_gain']:.3f}"))
    for name, us in kernel_rates().items():
        rows.append((f"kernel_{name}", us, "interpret-mode"))
    return rows


if __name__ == "__main__":
    for name, val, derived in main():
        print(f"{name},{val:.3f},{derived}")

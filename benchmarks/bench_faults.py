"""CREAM-Campaign: live fault injection under load, per class and FIT rate.

The paper's premise — weaker protection is *safe enough* for the right
data — asserted nowhere else in this repo, measured here: a
FIT-rate-scaled error process (:mod:`repro.faults.fit`; Schroeder et
al.'s memcached-fleet 70k FIT/Mbit as the hot anchor) is injected into
the **live** serving pool while the paged-KV engine decodes, and every
page read is classified against the ground-truth shadow oracle
(:mod:`repro.faults.shadow`) as corrected / detected / silently
corrupted, per reliability class. The closed loop runs too: a batch/NONE
tenant whose observed error rate crosses its
:class:`~repro.vm.policy.TenantSLO` is auto-upgraded through the zero-loss
migration mid-serve, and the time-to-escalation is reported.

Row families (all rates are lower-is-better; see
``check_regression.LOWER_IS_BETTER``):

  faults_{local,shard}_fit{F}_{cls}_{corrected,detected,silent}_rate
  faults_{local,shard}_fit{F}_tokens_per_s      serving throughput under fire
  faults_{local,shard}_fit{F}_escalation_steps  campaign ticks to first
                                                SLO escalation (= total
                                                ticks when none fired)
  faults_objcache_fit{F}_{cls}_value_corrupt_rate   objcache value oracle
  faults_scrub_{clean,injected}_us              scrub latency impact

The hard invariant — enforced here AND by the CI reliability gate: the
SECDED class NEVER silently corrupts (Hsiao detects all double-beat
errors; a detected read is flagged, a silent one is not). Rates are
deterministic for a fixed seed: the injector is host-side numpy and the
read schedule is trace-driven, independent of decoded token values.

Env: ``REPRO_FAULTS_ROWS`` (default 64) pool rows, ``REPRO_FAULTS_TURNS``
(default 24) trace turns, ``REPRO_FAULTS_FLIPS`` (default 6) expected
error events per campaign tick at the memcached FIT anchor (the
time-acceleration knob — tiny pools, compressed hours). Committed
baselines are snapshotted at the CI smoke config (TURNS=16).
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.bench_serving import CFG, MAX_LEN, ROW_WORDS, _requests
from repro.core.injection import FIELD_MIX, FaultModel, inject_flips
from repro.core.layouts import GROUP_ROWS, Layout
from repro.core.pool import make_pool
from repro.core.protection import Protection
from repro.faults import (CI_SMOKE_FIT, FaultCampaign, MEMCACHED_FIT,
                          hours_for_expected_flips,
                          soft_rate_per_gb_per_step)
from repro.objcache import ObjCache
from repro.serve import Engine
from repro.vm.address_space import VirtualMemory
from repro.vm.policy import TenantSLO, VMPolicy

DEFAULT_ROWS = int(os.environ.get("REPRO_FAULTS_ROWS", "64"))
DEFAULT_TURNS = int(os.environ.get("REPRO_FAULTS_TURNS", "24"))
DEFAULT_FLIPS = float(os.environ.get("REPRO_FAULTS_FLIPS", "6"))

N_SESSIONS = 4          # 1 paid (SECDED segment) + 3 batch (NONE segment)
PAID_FRAC = 0.25
CLASSES = ("secded", "parity", "none")


def _fit_label(fit: float) -> str:
    return f"fit{int(fit / 1000)}k"


def _shards() -> int:
    """Largest shard count the boundary geometry and devices allow."""
    for s in (2, 1):
        if jax.device_count() >= s and DEFAULT_ROWS % (s * GROUP_ROWS) == 0:
            return s
    return 1


def _campaign_serving(fit: float, shards: int, n_turns: int, seed: int
                      ) -> tuple[dict, float, int, int]:
    """One serving run under injection. Returns (census rates, tokens/s,
    ticks-to-first-escalation, total ticks). ``shards > 0`` forces the
    CREAM-Shard plane (a 1-device ``banks`` mesh when that's all we have);
    ``shards == 0`` is the local pool."""
    num_rows = DEFAULT_ROWS
    step = max(1, shards) * GROUP_ROWS
    # 3/4 of rows stay SECDED: room for the paid tier from the start AND
    # for every batch page the SLO escalation relocates mid-run
    boundary = (num_rows // 4 // step) * step or step
    vm = VirtualMemory(row_words=ROW_WORDS)
    if shards > 0:
        from repro.launch.mesh import make_banks_mesh
        vm.add_pool("kv", num_rows, Layout.INTERWRAP, boundary=boundary,
                    shards=shards, mesh=make_banks_mesh(shards))
    else:
        vm.add_pool("kv", num_rows, Layout.INTERWRAP, boundary=boundary)
    eng = Engine(CFG, max_batch=4, max_len=MAX_LEN, vm=vm, pool="kv",
                 mode="cream", row_words=ROW_WORDS,
                 max_sessions=8 * N_SESSIONS)
    policy = VMPolicy(vm)
    policy.set_tenant_slo("serve", "batch",
                          TenantSLO(max_error_rate=1e-3, min_reads=128,
                                    ceiling=Protection.SECDED))
    hours = hours_for_expected_flips(
        MEMCACHED_FIT, int(np.asarray(vm.pools["kv"].storage).nbytes),
        DEFAULT_FLIPS)
    campaign = FaultCampaign(vm, "kv", policy=policy, engine=eng,
                             fit_per_mbit=fit, hours_per_step=hours,
                             mix=FIELD_MIX, n_hard=2, seed=seed)
    reqs = _requests("zipf", N_SESSIONS, n_turns, seed, PAID_FRAC)
    for r in reqs:
        eng.submit(r)
    done = []
    t0 = time.perf_counter()
    while eng.sched.has_work():
        done.extend(eng.poll())
        campaign.tick()
        if campaign.steps % 4 == 0:
            policy.scrub_all()          # periodic repair sweep, under fire
    wall = time.perf_counter() - t0
    campaign.observe()                  # drain the tail
    tokens = sum(len(r.generated) for r in done)
    rep = campaign.report()
    first = campaign.first_escalation_step
    campaign.detach()
    return (rep.rates(), tokens / wall if wall else 0.0,
            first if first is not None else campaign.steps, campaign.steps)


def _serving_rows(fit: float, shards: int, n_turns: int, seed: int
                  ) -> list[tuple[str, float, str]]:
    plane = "shard" if shards > 0 else "local"
    rates, tok_s, esc, ticks = _campaign_serving(fit, shards, n_turns, seed)
    tag = f"faults_{plane}_{_fit_label(fit)}"
    rows = []
    for cls in CLASSES:
        if cls not in rates:
            continue
        r = rates[cls]
        for kind in ("corrected", "detected", "silent"):
            rows.append((f"{tag}_{cls}_{kind}_rate", r[kind],
                         f"plane={plane},fit={fit:.0f}"))
        if cls == "secded" and r["silent"] > 0:
            raise AssertionError(
                f"SECDED silently corrupted ({r['silent']:.2e}) — "
                "the Hsiao never-miscorrect invariant is broken")
    rows.append((f"{tag}_tokens_per_s", tok_s,
                 f"ticks={ticks},shards={shards}"))
    rows.append((f"{tag}_escalation_steps", float(esc),
                 f"escalated={'yes' if esc < ticks else 'no'},"
                 f"ticks={ticks}"))
    return rows


def _objcache_rows(fit: float, seed: int) -> list[tuple[str, float, str]]:
    """Value-level oracle for the objcache plane (its get path is jitted
    with the pool traced, so the shadow wrapper can't interpose — the
    expected key->value map is the ground truth instead)."""
    num_rows = DEFAULT_ROWS
    vm = VirtualMemory(row_words=ROW_WORDS)
    vm.add_pool("oc", num_rows, Layout.INTERWRAP, boundary=num_rows // 2)
    oc = ObjCache(vm, "oc", index_capacity=256, max_value_words=32)
    rng = np.random.default_rng(seed)
    span = 32
    per_class = 24
    expected: dict[str, tuple[np.ndarray, np.ndarray]] = {}
    for i, cls in enumerate((Protection.SECDED, Protection.NONE)):
        keys = np.arange(per_class, dtype=np.uint64) + 1 + i * per_class
        vals = rng.integers(0, 2**32, size=(per_class, span),
                            dtype=np.uint32)
        oc.set_many(keys, vals, reliability=cls)
        expected[cls.value] = (keys, vals)
    hours = hours_for_expected_flips(
        MEMCACHED_FIT, int(np.asarray(vm.pools["oc"].storage).nbytes),
        DEFAULT_FLIPS)
    model = FaultModel.make(
        seed + 17, soft_rate=soft_rate_per_gb_per_step(fit, hours),
        n_hard=0, shape=np.asarray(vm.pools["oc"].storage).shape,
        mix=FIELD_MIX)
    steps = 16
    lookups = {cls: 0 for cls in expected}
    corrupt = {cls: 0 for cls in expected}
    for _ in range(steps):
        vm.pools["oc"], _ = model.step_pool(vm.pools["oc"])
        for cls, (keys, vals) in expected.items():
            got, lens, found = oc.get_many(keys)
            lookups[cls] += len(keys)
            ok = found & (np.asarray(got)[:, :span] == vals).all(axis=1)
            corrupt[cls] += int(len(keys) - ok.sum())
    rows = []
    for cls in expected:
        rows.append((
            f"faults_objcache_{_fit_label(fit)}_{cls}_value_corrupt_rate",
            corrupt[cls] / lookups[cls],
            f"lookups={lookups[cls]},steps={steps}"))
    return rows


def _scrub_rows(seed: int) -> list[tuple[str, float, str]]:
    """Scrub sweep latency, clean vs under heavy injected corruption."""
    pool = make_pool(DEFAULT_ROWS, Layout.INTERWRAP,
                     boundary=DEFAULT_ROWS // 2)
    pool, _ = pool.scrub()              # warm the compile cache
    t0 = time.perf_counter()
    pool, _ = pool.scrub()
    clean_us = (time.perf_counter() - t0) * 1e6
    rng = np.random.default_rng(seed)
    storage, _ = inject_flips(pool.storage, rng, 2000)
    import dataclasses
    dirty = dataclasses.replace(pool, storage=storage)
    t0 = time.perf_counter()
    dirty, stats = dirty.scrub()
    injected_us = (time.perf_counter() - t0) * 1e6
    return [("faults_scrub_clean_us", clean_us, "rows=%d" % DEFAULT_ROWS),
            ("faults_scrub_injected_us", injected_us,
             f"flips=2000,corrected={stats.corrected},"
             f"uncorrectable={stats.detected_uncorrectable}")]


def main(seed: int = 0) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    for fit in (CI_SMOKE_FIT, MEMCACHED_FIT):
        rows.extend(_serving_rows(fit, shards=0, n_turns=DEFAULT_TURNS,
                                  seed=seed))
    rows.extend(_serving_rows(MEMCACHED_FIT, shards=_shards(),
                              n_turns=DEFAULT_TURNS, seed=seed))
    rows.extend(_objcache_rows(MEMCACHED_FIT, seed))
    rows.extend(_scrub_rows(seed))
    return rows


if __name__ == "__main__":
    for name, val, derived in main():
        print(f"{name},{val:.6f},{derived}")

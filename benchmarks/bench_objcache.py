"""CREAM-Cache benchmark: the paper's memcached experiment on the real data plane.

Where ``bench_capacity`` models Fig. 8 with an abstract page-fault cache,
this suite drives the actual :class:`repro.objcache.ObjCache`: values live
in CREAM pool pages, gets ride the fused probe+gather dispatch, sets ride
the batched RMW write path, and capacity differences between protection
configs show up as *measured* hit rate and us/op on the same zipfian trace:

  * ``objcache_zipf_*``      — zipfian replay per config (Fig. 8 shape);
  * ``objcache_websearch_*`` — WebSearch-style hot/cold replay (Fig. 4 shape);
  * ``objcache_demotion``    — live SECDED -> correction-free demotion
    mid-replay: the freed frames are claimed online and the hit rate rises.

Configs (per the paper's evaluation): Baseline (all-SECDED), Parity
(detection-only, +10.7% pages), correction-free InterWrap (+12.5% pages).
Misses are refilled through a fixed-size pending queue so the set path
keeps a constant batch shape (one compile per config).

Env: ``REPRO_OBJCACHE_ROWS`` (default 64) scales the pool,
``REPRO_OBJCACHE_ACCESSES`` (default 6144) the trace length.
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks import cache_sim
from repro.core.layouts import Layout
from repro.vm import MigrationEngine, VirtualMemory
from repro.objcache import ObjCache

ROW_WORDS = 64
DEFAULT_ROWS = int(os.environ.get("REPRO_OBJCACHE_ROWS", "64"))
DEFAULT_ACCESSES = int(os.environ.get("REPRO_OBJCACHE_ACCESSES", "6144"))
GET_BATCH = 128
SET_BATCH = 32

#: (name, layout, boundary) — boundary None = whole pool in CREAM mode.
CONFIGS = [
    ("baseline", Layout.INTERWRAP, 0),
    ("parity", Layout.PARITY, None),
    ("correction_free", Layout.INTERWRAP, None),
]


def values_for(keys: np.ndarray, span: int) -> np.ndarray:
    """Deterministic value per key (verifiable replay)."""
    keys = np.asarray(keys, np.uint32)
    return keys[:, None] * np.arange(1, span + 1, dtype=np.uint32)


def build_cache(layout: Layout, boundary: int | None, rows: int
                ) -> tuple[VirtualMemory, ObjCache]:
    vm = VirtualMemory(row_words=ROW_WORDS)
    vm.add_pool("dimm", rows, layout, boundary=boundary)
    cache = ObjCache(vm, "dimm", index_capacity=4 * rows, probe=16)
    return vm, cache


def replay(cache: ObjCache, trace: np.ndarray, span: int,
           get_batch: int = GET_BATCH, set_batch: int = SET_BATCH,
           verify: bool = False, warmup: bool = True) -> float:
    """Drive the cache through a key trace; returns wall seconds.

    Misses queue up and are admitted ``set_batch`` at a time (values are
    full chunks of ``span`` words), so every dispatch reuses one compiled
    shape. ``warmup`` runs one get/set round first and resets the stats, so
    the reported wall time is near-steady-state (the bulk of trace/compile
    cost excluded).
    """
    if warmup:
        ks = trace[:get_batch]
        _, _, found = cache.get_many(ks)
        miss = np.unique(ks[~found])[:set_batch]
        # pad to exactly set_batch unique keys (throwaway ids far outside
        # the trace's keyspace) so the set path compiles at the shape every
        # timed dispatch reuses, then retire the padding
        pad = np.arange(2**30, 2**30 + set_batch - len(miss), dtype=np.int64)
        batch = np.concatenate([miss, pad])
        cache.set_many(batch, values_for(batch, span))
        if len(pad):
            cache.delete_many(pad)
        cache.stats = type(cache.stats)()
    t0 = time.perf_counter()
    pending: np.ndarray = np.zeros(0, np.int64)
    n = len(trace) - len(trace) % get_batch
    for i in range(0, n, get_batch):
        ks = trace[i:i + get_batch]
        vals, _, found = cache.get_many(ks)
        if verify and found.any():
            expect = values_for(ks[found], span)
            assert (vals[found, :span] == expect).all(), "corrupted value"
        miss = ks[~found]
        pending = np.unique(np.concatenate([pending, miss]))
        while len(pending) >= set_batch:
            batch, pending = pending[:set_batch], pending[set_batch:]
            cache.set_many(batch, values_for(batch, span))
    # trailing sub-batch misses stay queued: admitting them would compile a
    # fresh (variable) shape per replay for no measurable hit-rate change
    return time.perf_counter() - t0


def _summary(cache: ObjCache, seconds: float) -> dict:
    s = cache.stats
    ops = s.gets + s.sets
    model_us = s.misses * cache_sim.FAULT_PENALTY_US \
        + s.hits * cache_sim.HIT_COST_US
    return {
        "hit_rate": s.hit_rate,
        "us_per_op": seconds * 1e6 / ops if ops else 0.0,
        "model_total_us": model_us,
        "capacity_pages": cache.pool.num_pages,
        "gets": s.gets,
        "sets": s.sets,
        "evictions": s.evictions,
        "host_hits": s.host_hits,
    }


def run(seed: int = 0, rows: int = DEFAULT_ROWS,
        n_accesses: int = DEFAULT_ACCESSES,
        kinds: tuple[str, ...] = ("zipf", "websearch", "demotion")) -> dict:
    span = 8 * ROW_WORDS                     # full-page values: pages = items
    keyspace = 4 * rows
    get_batch = min(GET_BATCH, max(16, keyspace // 4))
    ztrace = cache_sim.zipf_trace(np.random.default_rng(seed), keyspace,
                                  n_accesses)
    out: dict = {}
    traces = {"zipf": ztrace}
    if "websearch" in kinds:
        traces["websearch"] = cache_sim.websearch_trace(
            np.random.default_rng(seed + 1), int(1.25 * rows), 8 * rows,
            n_accesses)
    for kind in [k for k in kinds if k in traces]:
        out[kind] = {}
        for name, layout, boundary in CONFIGS:
            _, cache = build_cache(layout, boundary, rows)
            dt = replay(cache, traces[kind], span, get_batch=get_batch)
            out[kind][name] = _summary(cache, dt)
        base = out[kind]["baseline"]["model_total_us"]
        for name in out[kind]:
            cur = out[kind][name]["model_total_us"]
            out[kind][name]["model_speedup"] = base / cur if cur else 0.0

    if "demotion" in kinds:
        # live demotion: all-SECDED first half, correction-free second half
        vm, cache = build_cache(Layout.INTERWRAP, 0, rows)
        half = n_accesses // 2
        replay(cache, ztrace[:half], span, get_batch=get_batch)
        before = cache.stats.hit_rate
        g0, h0 = cache.stats.gets, cache.stats.hits
        MigrationEngine(vm).repartition_with_migration("dimm", rows)
        cache.refresh_translation()
        replay(cache, ztrace[half:], span, get_batch=get_batch, warmup=False)
        after = (cache.stats.hits - h0) / max(cache.stats.gets - g0, 1)
        out["demotion"] = {"hit_before": before, "hit_after": after,
                           "capacity_pages": cache.pool.num_pages}
    return out


def main(seed: int = 0):
    r = run(seed=seed)
    for kind in ("zipf", "websearch"):
        for name, s in r[kind].items():
            yield (f"objcache_{kind}_{name}", s["us_per_op"],
                   f"hit={s['hit_rate']:.4f},capacity={s['capacity_pages']},"
                   f"model_speedup={s['model_speedup']:.3f},"
                   f"evictions={s['evictions']},host_hits={s['host_hits']}")
    d = r["demotion"]
    yield ("objcache_demotion", (d["hit_after"] - d["hit_before"]) * 100,
           f"hit_gain_pct,before={d['hit_before']:.4f},"
           f"after={d['hit_after']:.4f},capacity={d['capacity_pages']}")


if __name__ == "__main__":
    for name, val, derived in main():
        print(f"{name},{val:.3f},{derived}")

"""Benchmark harness: one entry per paper table/figure + beyond-paper runs.

Prints ``name,us_per_call,derived`` CSV (scaffold contract). Figure map:
  fig4_*   WebSearch latency vs capacity        (paper Fig. 4)
  fig8_*   memcached speedups                   (paper Fig. 8)
  fig9_*   multiprogrammed weighted speedup     (paper Figs. 9, 10a/b, 11a/b)
  fig12_*  SECDED-fraction sensitivity vs SoftECC (paper Fig. 12)
  ops_* / kernel_*  layout + kernel overheads   (paper §4.4 analogue)
  serving_*         CREAM-Serve paged-KV engine — the real Fig. 8 serving
                    analogue (CREAM vs SECDED throughput + p50/p99)
  vm_*              CREAM-VM multi-tenant sim   (beyond paper)
  objcache_*        CREAM-Cache real-data-plane memcached (beyond paper)
  fig9_real_*       CREAM-Shard measured bank parallelism (shard suite)

``--only NAME[,NAME...]`` runs a subset of suites (CI smoke uses
``--only vm,kernels,objcache,shard``). ``--json [DIR]`` additionally writes
one machine-readable ``BENCH_<suite>.json`` per suite
(``{name: us_per_call}``), flushed *as each suite finishes* — a suite that
fails later never discards the files (or rows) already earned; a failing
suite's partial rows land in ``BENCH_<suite>.partial.json`` so the
trajectory survives without poisoning the regression gate
(``benchmarks/check_regression.py`` reads only the non-partial files).
``--seed N`` is forwarded to every suite whose entry point accepts a
``seed`` keyword. ``--memprof`` attaches CREAM-Lens
(:mod:`repro.obs.memprof`): each suite's captured page-access streams are
replayed through the per-bank DRAM state machines and the resulting bank
profile is embedded as ``_memprof`` + written to ``MEMPROF_<suite>.json``.
"""
import argparse
import inspect
import json
import os
import sys
import time
import traceback

# self-bootstrap: `python benchmarks/run.py` puts benchmarks/ (not the repo
# root) on sys.path, so `from benchmarks import ...` needs this
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)


def main() -> None:
    from benchmarks import (bench_capacity, bench_faults, bench_kernels,
                            bench_objcache, bench_overheads,
                            bench_parallelism, bench_sensitivity,
                            bench_serving, bench_shard, bench_vm,
                            bench_websearch)
    suites = [
        ("fig4", bench_websearch.main),
        ("fig8", bench_capacity.main),
        ("fig9-11", bench_parallelism.main),
        ("fig12", bench_sensitivity.main),
        ("overheads", bench_overheads.main),
        ("kernels", bench_kernels.main),
        ("serving", bench_serving.main),
        ("vm", bench_vm.main),
        ("objcache", bench_objcache.main),
        ("shard", bench_shard.main),
        ("faults", bench_faults.main),
    ]
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names to run")
    ap.add_argument("--json", nargs="?", const=".", default=None,
                    metavar="DIR",
                    help="also write BENCH_<suite>.json (name -> us_per_call)"
                         " into DIR (default: current directory)")
    ap.add_argument("--seed", type=int, default=0,
                    help="base RNG seed, forwarded to suites that take one")
    ap.add_argument("--profile", action="store_true",
                    help="attach the CREAM-Scope telemetry plane: embed a "
                         "metrics snapshot (_metrics) into each "
                         "BENCH_<suite>.json and write TRACE_<suite>.json "
                         "(Perfetto) + METRICS_<suite>.prom next to them")
    ap.add_argument("--memprof", action="store_true",
                    help="attach CREAM-Lens: capture the data plane's page-"
                         "access streams, replay them through the per-bank "
                         "DRAM state machines, embed the bank profile "
                         "(_memprof) into each BENCH_<suite>.json, write "
                         "MEMPROF_<suite>.json, and (with --profile) add "
                         "Perfetto counter tracks to TRACE_<suite>.json")
    args = ap.parse_args()
    if args.profile or args.memprof:
        from repro.obs import metrics as obs_metrics
        from repro.obs import slo as obs_slo
        from repro.obs import tracing as obs_tracing
    if args.profile:
        obs_metrics.enable()
        obs_tracing.enable()
    if args.memprof:
        from repro.obs import memprof as obs_memprof
        obs_memprof.enable()
    if args.only:
        wanted = set(args.only.split(","))
        unknown = wanted - {s for s, _ in suites}
        if unknown:
            raise SystemExit(f"unknown suites: {sorted(unknown)}")
        suites = [(s, fn) for s, fn in suites if s in wanted]
    if args.json is not None:
        os.makedirs(args.json, exist_ok=True)
    failed = 0
    for suite, fn in suites:
        t0 = time.time()
        results = {}
        suite_ok = True
        kwargs = {"seed": args.seed} \
            if "seed" in inspect.signature(fn).parameters else {}
        if args.profile:
            # fresh telemetry per suite: each BENCH json's _metrics blob and
            # TRACE file describe that suite alone
            obs_metrics.reset()
            obs_tracing.reset()
            obs_slo.TRACKER.reset()
        if args.memprof:
            obs_memprof.clear()         # records AND published profiles
        try:
            for name, val, derived in fn(**kwargs):
                print(f"{name},{val:.3f},{derived}", flush=True)
                results[name] = val
        except Exception as e:  # noqa: BLE001
            failed += 1
            suite_ok = False
            print(f"{suite},nan,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        if args.memprof:
            blob = obs_memprof.collect()    # also exports cream_dram_* gauges
            if blob["profiles"] or blob["records"]:
                results["_memprof"] = blob
                outdir = args.json if args.json is not None else "."
                os.makedirs(outdir, exist_ok=True)
                mp_path = os.path.join(outdir, f"MEMPROF_{suite}.json")
                with open(mp_path, "w") as f:
                    json.dump(blob, f, indent=2, sort_keys=True)
                print(f"# wrote MEMPROF_{suite}.json", flush=True)
                if args.profile:
                    # bank-occupancy counter lanes next to the spans
                    obs_tracing.TRACER.extend(obs_memprof.counter_events(blob))
        if args.profile:
            outdir = args.json if args.json is not None else "."
            os.makedirs(outdir, exist_ok=True)
            results["_metrics"] = obs_metrics.collect()
            obs_tracing.export(os.path.join(outdir, f"TRACE_{suite}.json"))
            with open(os.path.join(outdir, f"METRICS_{suite}.prom"),
                      "w") as f:
                f.write(obs_metrics.snapshot())
            print(f"# wrote TRACE_{suite}.json, METRICS_{suite}.prom",
                  flush=True)
        if args.json is not None:
            # flush per suite, immediately: a crash in a later suite (or in
            # this one) must never discard trajectory already earned
            if suite_ok:
                path = os.path.join(args.json, f"BENCH_{suite}.json")
            else:
                # quarantine partial rows under a name the regression gate
                # ignores — a trajectory diff would read a partial suite as
                # a valid (regressed) measurement
                path = os.path.join(args.json, f"BENCH_{suite}.partial.json")
            with open(path, "w") as f:
                json.dump(results, f, indent=2, sort_keys=True)
            print(f"# wrote {path}" + ("" if suite_ok else " (suite failed)"),
                  flush=True)
        print(f"# {suite} done in {time.time()-t0:.1f}s", flush=True)
    if failed:
        raise SystemExit(f"{failed} suites failed")


if __name__ == '__main__':
    main()

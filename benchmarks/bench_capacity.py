"""Fig. 8 reproduction: memcached speedups under CREAM configurations.

Two workload configs, as in §6.1:
  * 8GB resident (no paging anywhere) — isolates pure CREAM access overhead;
  * 10GB on an 8GB machine (thrash) — capacity benefits with all overheads.

Per config we combine (a) the page-fault model at that config's effective
capacity (+12.5% correction-free, +10.7% parity, 0% baseline) and (b) the
DRAM-sim access-cost multiplier for the layout's extra operations.

The model rows are cross-checked against the *real* data plane: the
``fig8_memcached_real_*`` rows replay the same zipfian workload shape
through :class:`repro.objcache.ObjCache` (values in actual CREAM pool
pages, capacity set by the boundary register) via the shared
``bench_objcache`` driver at reduced scale.
"""
from __future__ import annotations

import numpy as np

from benchmarks import bench_objcache, cache_sim
from benchmarks.dram_sim import run_workload
from repro.core.layouts import CAPACITY_GAIN, Layout

CONFIGS = [
    ("Baseline", Layout.BASELINE_ECC),
    ("Packed", Layout.PACKED),
    ("Packed+RS", Layout.RANK_SUBSET),
    ("Inter-Wrap", Layout.INTERWRAP),
    ("Parity", Layout.PARITY),
]

BASE_CAPACITY_PAGES = 2048            # "8GB" in model pages
DATASET_FACTOR_THRASH = 1.25          # "10GB" working set
N_ACCESSES = 60_000


def _dram_cost_multiplier(layout: Layout, seed: int = 1) -> float:
    """Mean DRAM time per request vs baseline (uniform traffic, all pages)."""
    base = run_workload(Layout.BASELINE_ECC, 256, seed, n_mem_intensive=4,
                        n_requests=600)
    cur = run_workload(layout, 256, seed, n_mem_intensive=4, n_requests=600)
    return (cur.finish_cycle / cur.requests) / (base.finish_cycle
                                                / base.requests)


def run(seed: int = 0) -> dict[str, dict[str, float]]:
    rng = np.random.default_rng(seed)
    results: dict[str, dict[str, float]] = {}
    base_us = {}
    for resident, dataset in (("8GB", 1.0), ("10GB", DATASET_FACTOR_THRASH)):
        n_pages = int(BASE_CAPACITY_PAGES * dataset)
        trace = cache_sim.zipf_trace(rng, n_pages, N_ACCESSES)
        for name, layout in CONFIGS:
            cap = int(BASE_CAPACITY_PAGES * (1 + CAPACITY_GAIN[layout]))
            cache_res = cache_sim.run_trace(cap, trace)
            mult = _dram_cost_multiplier(layout)
            # DRAM access cost scales with the layout's op overhead; faults
            # dominate when present.
            total_us = cache_res.faults * cache_sim.FAULT_PENALTY_US + \
                (cache_res.accesses - cache_res.faults) * \
                cache_sim.HIT_COST_US * mult
            key = f"{name}@{resident}"
            results[key] = {
                "total_us": total_us,
                "fault_rate": cache_res.fault_rate,
                "dram_mult": mult,
                "capacity_pages": cap,
            }
            if name == "Baseline":
                base_us[resident] = total_us
        for name, _ in CONFIGS:
            key = f"{name}@{resident}"
            results[key]["speedup"] = base_us[resident] / \
                results[key]["total_us"]
    return results


def main(seed: int = 0) -> list[tuple[str, float, str]]:
    rows = []
    for key, r in run(seed).items():
        rows.append((f"fig8_memcached_{key}", r["total_us"],
                     f"speedup={r['speedup']:.3f},faults={r['fault_rate']:.4f}"))
    # real-data-plane cross-check: same workload shape, actual CREAM pools
    real = bench_objcache.run(seed=seed, rows=32, n_accesses=2048,
                              kinds=("zipf",))
    for name, s in real["zipf"].items():
        rows.append((f"fig8_memcached_real_{name}", s["model_total_us"],
                     f"speedup={s['model_speedup']:.3f},"
                     f"hit={s['hit_rate']:.4f},"
                     f"capacity={s['capacity_pages']}pages"))
    return rows


if __name__ == "__main__":
    for name, val, derived in main():
        print(f"{name},{val:.1f},{derived}")

"""Fig. 12 reproduction: CREAM vs SoftECC across the SECDED-covered fraction.

Sweeps the fraction of memory under SECDED protection (the paper's 0–100%)
and compares:

  * **CREAM (Inter-Wrap)** — protected rows use the conventional ECC layout
    (zero extra ops: codes ride the 9th lane), unprotected rows use
    Inter-Wrap; the only costs are the bridge cycle and the row-locality
    seam at the boundary.
  * **SoftECC (Virtualized ECC)** — protected accesses need a second access
    for in-band codes, partially hidden by an LLC code cache whose capacity
    is *stolen from the application* — modelled as an elevated app miss
    rate, the paper's cache-contention effect.

Output: weighted-speedup proxy (inverse mean access cost) normalised to
Baseline, per coverage point, per memory-intensity level.
"""
from __future__ import annotations

import numpy as np

from repro.core.layouts import Layout
from repro.core.softecc import CodeCache, plan_line_ops
from benchmarks.dram_sim import DRAMSim, make_core

NUM_ROWS = 256
N_REQ = 600
LLC_LINES = 512                 # LLC lines available to code caching
COVERAGES = [0.0, 0.2, 0.4, 0.6, 0.8, 1.0]


def _cream_cost(coverage: float, seed: int, n_intensive: int) -> float:
    """Mean cycles/request with `coverage` of rows under SECDED."""
    boundary = int(NUM_ROWS * (1 - coverage)) // 8 * 8
    rng = np.random.default_rng(seed)
    # CREAM region = interwrap rows [0, boundary); SECDED = rest. Model as
    # two sims in proportion (the seam effect adds one bridge cycle to all).
    costs = []
    for layout, rows, frac in ((Layout.INTERWRAP, max(boundary, 8),
                                1 - coverage),
                               (Layout.BASELINE_ECC,
                                max(NUM_ROWS - boundary, 8), coverage)):
        if frac <= 0.0:
            continue
        cores = [make_core(rng, layout, rows, N_REQ,
                           memory_intensive=(i < n_intensive))
                 for i in range(4)]
        st = DRAMSim(layout, rows).run(cores)
        costs.append((st.finish_cycle / st.requests, frac))
    return sum(c * f for c, f in costs) / sum(f for _, f in costs)


def _softecc_cost(coverage: float, seed: int, n_intensive: int) -> float:
    """SoftECC: op multiplier from code fetches + LLC contention penalty."""
    rng = np.random.default_rng(seed)
    cache = CodeCache(int(LLC_LINES * 0.5))
    # ops per access for protected pages
    ops = []
    for _ in range(4000):
        page = int(rng.integers(0, NUM_ROWS * 8 // 9 * 8 // 8))
        line = int(rng.integers(0, 128))
        write = rng.random() < 0.3
        if rng.random() < coverage:
            ops.append(plan_line_ops(page, line, write, cache))
        else:
            ops.append(1)
    mult = float(np.mean(ops))
    # LLC contention: stolen code-cache lines raise the app's DRAM traffic
    contention = 1.0 + 0.25 * coverage * (n_intensive / 4)
    cores = [make_core(rng, Layout.BASELINE_ECC, NUM_ROWS, N_REQ,
                       memory_intensive=(i < n_intensive))
             for i in range(4)]
    st = DRAMSim(Layout.BASELINE_ECC, NUM_ROWS).run(cores)
    return (st.finish_cycle / st.requests) * mult * contention


def run() -> dict:
    out = {"coverages": COVERAGES, "cream": {}, "softecc": {}}
    for n_int in (1, 2, 4):
        base = _cream_cost(1.0, 7, n_int)  # all-SECDED == Baseline
        out["cream"][n_int] = [base / _cream_cost(c, 7, n_int)
                               for c in COVERAGES]
        out["softecc"][n_int] = [base / _softecc_cost(c, 7, n_int)
                                 for c in COVERAGES]
    return out


def main() -> list[tuple[str, float, str]]:
    r = run()
    rows = []
    for n_int in (1, 2, 4):
        cream_min = min(r["cream"][n_int])
        soft_min = min(r["softecc"][n_int])
        rows.append((f"fig12_sensitivity_mi{n_int}", cream_min,
                     f"cream_worst={cream_min:.3f}(paper>=0.96),"
                     f"softecc_worst={soft_min:.3f}(paper~0.75),"
                     f"curve_cream={[round(x, 3) for x in r['cream'][n_int]]}"))
    return rows


if __name__ == "__main__":
    for name, val, derived in main():
        print(f"{name},{val:.3f},{derived}")

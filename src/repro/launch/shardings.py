"""Input / state / parameter shardings for every dry-run cell.

One place that decides, per (arch × shape × mesh), where every tensor lives:

  * tokens/labels: batch over ('pod','data')
  * params & optimizer moments: FSDP over 'data' × TP over 'model'
    (repro.distributed.sharding.PARAM_RULES)
  * decode state: batch over ('pod','data'); the long dimension of each
    state kind over 'model' (KV sequence, mamba d_inner, xLSTM head dim);
    for global_batch == 1 (long_500k) the KV sequence takes both axes.

Divisibility is checked and degraded per-tensor (an axis that doesn't divide
is dropped) so every assigned cell lowers cleanly — including granite's
kv_heads=1 MQA cache.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import BlockKind, InputShape, ModelConfig
from repro.distributed.sharding import param_shardings as _param_shardings
from repro.models import transformer


def dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _fit(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop spec entries that don't divide the dim (graceful degrade)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= sizes[a]
        out.append(entry if dim % n == 0 else None)
    return P(*out)


def shard(mesh: Mesh, spec: P, shape: tuple) -> NamedSharding:
    return NamedSharding(mesh, _fit(spec, shape, mesh))


# -- inputs -------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                with_labels: bool) -> dict[str, jax.ShapeDtypeStruct]:
    dp = dp_axes(mesh)
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32,
                               sharding=shard(mesh, P(dp), (b, s)))
    out = {"tokens": tok}
    if with_labels:
        out["labels"] = jax.ShapeDtypeStruct(
            (b, s), jnp.int32, sharding=shard(mesh, P(dp), (b, s)))
    return out


def decode_token_spec(shape: InputShape, mesh: Mesh) -> jax.ShapeDtypeStruct:
    b = shape.global_batch
    return jax.ShapeDtypeStruct((b,), jnp.int32,
                                sharding=shard(mesh, P(dp_axes(mesh)), (b,)))


# -- parameters / optimizer ----------------------------------------------------


def param_specs(cfg: ModelConfig, mesh: Mesh) -> Any:
    """ShapeDtypeStructs with NamedShardings for the full parameter tree."""
    shapes = jax.eval_shape(
        lambda key: transformer.init_params(cfg, key),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    shardings = _param_shardings(shapes, mesh)
    return jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype,
            sharding=NamedSharding(mesh, _fit(sh.spec, sds.shape, mesh))),
        shapes, shardings)


def opt_specs(param_sds: Any, mesh: Mesh) -> Any:
    """AdamW moments mirror parameter shardings, in f32 (ZeRO-3)."""
    from repro.optim.adamw import AdamWState
    moments = jax.tree.map(
        lambda sds: jax.ShapeDtypeStruct(sds.shape, jnp.float32,
                                         sharding=sds.sharding), param_sds)
    step = jax.ShapeDtypeStruct((), jnp.int32,
                                sharding=NamedSharding(mesh, P()))
    return AdamWState(step=step, m=moments,
                      v=jax.tree.map(lambda x: x, moments))


# -- decode state ---------------------------------------------------------------


def decode_state_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> Any:
    """ShapeDtypeStructs + shardings for init_decode_state's pytree."""
    b, smax = shape.global_batch, shape.seq_len
    dp = dp_axes(mesh)
    seq_ax = ("data", "model") if b == 1 else "model"
    state_shapes = jax.eval_shape(
        lambda: transformer.init_decode_state(cfg, b, smax))

    def spec_for(pos_kind: BlockKind | None, key: str, ndim: int) -> P:
        if key == "cache_len":
            return P()
        if pos_kind == BlockKind.ATTN:            # k/v (ns,B,S,hkv,dh)
            return P(None, dp, seq_ax, None, None)
        if pos_kind == BlockKind.MAMBA:
            if key == "h":                        # (ns,B,di,n)
                return P(None, dp, "model", None)
            return P(None, dp, None, "model")     # conv (ns,B,K-1,di)
        if pos_kind == BlockKind.MLSTM:
            if key == "c":                        # (ns,B,H,dh,dh)
                return P(None, dp, None, "model", None)
            if key in ("n",):                     # (ns,B,H,dh)
                return P(None, dp, None, "model")
            if key == "conv":                     # (ns,B,3,dc)
                return P(None, dp, None, "model")
            return P(None, dp, None)              # m (ns,B,H)
        if pos_kind == BlockKind.SLSTM:           # c/n/h/m (ns,B,H,dh)
            return P(None, dp, None, "model")
        return P()

    out: dict[str, Any] = {}
    for key, sub in state_shapes.items():
        if key == "cache_len":
            out[key] = jax.ShapeDtypeStruct(
                sub.shape, sub.dtype, sharding=shard(mesh, P(dp), sub.shape))
            continue
        pos = int(key[3:])
        kind = cfg.pattern[pos][0]
        out[key] = {
            k: jax.ShapeDtypeStruct(
                sds.shape, sds.dtype,
                sharding=shard(mesh, spec_for(kind, k, sds.ndim), sds.shape))
            for k, sds in sub.items()
        }
    return out


def sds_shardings(tree: Any) -> Any:
    """Extract the shardings pytree from ShapeDtypeStructs."""
    return jax.tree.map(lambda sds: sds.sharding, tree)

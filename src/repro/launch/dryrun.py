import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede every other import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: the production
mesh is built from 512 host placeholder devices, inputs are ShapeDtypeStructs
(no allocation), and success criterion is ``.lower().compile()`` plus the
memory/cost/collective numbers dumped to JSON for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k \
      --mesh single --out experiments/dryrun
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (SHAPES, InputShape, ModelConfig, TrainConfig,
                           get_config, iter_cells, shape_applicable)
from repro.launch import shardings as sh
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.roofline.hlo_parse import parse_collectives


def _step_and_specs(cfg: ModelConfig, shape: InputShape, mesh, *,
                    remat: str = "block", grad_compression: str = "none",
                    logits_last: bool = True):
    """Returns (fn, arg_specs tuple) for the cell's step kind."""
    if shape.kind == "train":
        tcfg = TrainConfig(remat=remat, grad_compression=grad_compression,
                           microbatch=None)
        from repro.train.train_step import make_train_step
        fn = make_train_step(cfg, tcfg)
        p = sh.param_specs(cfg, mesh)
        o = sh.opt_specs(p, mesh)
        b = sh.batch_specs(cfg, shape, mesh, with_labels=True)
        return fn, (p, o, b)
    if shape.kind == "prefill":
        def fn(params, tokens):
            return transformer.forward(
                params, cfg, tokens, remat=remat,
                logits_mode="last" if logits_last else "all")
        p = sh.param_specs(cfg, mesh)
        b = sh.batch_specs(cfg, shape, mesh, with_labels=False)
        return fn, (p, b["tokens"])
    if shape.kind == "decode":
        def fn(params, state, tokens):
            return transformer.decode_step(params, cfg, state, tokens)
        p = sh.param_specs(cfg, mesh)
        st = sh.decode_state_specs(cfg, shape, mesh)
        tok = sh.decode_token_spec(shape, mesh)
        return fn, (p, st, tok)
    raise ValueError(shape.kind)


def _unstack_specs(tree):
    """Drop the leading (stage-stack) dim from ShapeDtypeStructs + shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def one(sds):
        sh_ = sds.sharding
        spec = tuple(sh_.spec) + (None,) * (len(sds.shape) - len(sh_.spec))
        return jax.ShapeDtypeStruct(
            sds.shape[1:], sds.dtype,
            sharding=NamedSharding(sh_.mesh, P(*spec[1:])))

    return jax.tree.map(one, tree)


def stage_cost_probe(cfg: ModelConfig, shape: InputShape, mesh, *,
                     remat: str = "block") -> dict:
    """Compile one super-block alone to get per-stage HLO cost.

    XLA's cost analysis counts while-loop (scan) bodies ONCE, so the full
    model's raw numbers undercount by the trip count. The §Roofline analysis
    scales:  total = raw_full + (num_stages - 1) × stage_cost.
    For train the probe differentiates through the stage (fwd+bwd+remat);
    for prefill it's the forward body; for decode the decode stage.
    """
    import functools

    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models.transformer import _stage_fn, decode_stage

    dp = sh.dp_axes(mesh)
    b, s = shape.global_batch, shape.seq_len
    dt = cfg.activation_dtype
    p_full = sh.param_specs(cfg, mesh)
    sp = _unstack_specs(p_full["stages"])

    def xspec(seq):
        return jax.ShapeDtypeStruct(
            (b, seq, cfg.d_model), dt,
            sharding=sh.shard(mesh, P(dp, None, None),
                              (b, seq, cfg.d_model)))

    if shape.kind == "train":
        stage = functools.partial(_stage_fn, cfg, "xla")
        if remat in ("block", "full"):
            stage = jax.checkpoint(stage)

        def fn(spar, x, cot):
            def loss(spar, x):
                (y, aux), _ = stage((x, jnp.zeros((), jnp.float32)), spar)
                return jnp.sum(y.astype(jnp.float32) * cot) + aux
            return jax.value_and_grad(loss, argnums=(0, 1))(spar, x)

        cot = jax.ShapeDtypeStruct(
            (b, s, cfg.d_model), jnp.float32,
            sharding=sh.shard(mesh, P(dp, None, None), (b, s, cfg.d_model)))
        args = (sp, xspec(s), cot)
    elif shape.kind == "prefill":
        def fn(spar, x):
            (y, _), _ = _stage_fn(cfg, "xla", (x, jnp.zeros((), jnp.float32)),
                                  spar)
            return y
        args = (sp, xspec(s))
    else:  # decode
        st_full = sh.decode_state_specs(cfg, shape, mesh)
        st = _unstack_specs({k: v for k, v in st_full.items()
                             if k != "cache_len"})
        clen = st_full["cache_len"]

        def fn(spar, stg, x, clen_):
            return decode_stage(cfg, spar, stg, x, clen_)
        args = (sp, st, xspec(1), clen)

    compiled = jax.jit(fn).lower(*args).compile()
    cost = compiled.cost_analysis() or {}
    coll = parse_collectives(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "collectives": coll.as_dict(),
            "num_stages": cfg.num_stages}


def run_cell(cfg: ModelConfig, shape: InputShape, multi_pod: bool,
             out_dir: str | None = None, save_hlo: bool = False,
             **step_kw) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{cfg.name}__{shape.name}__{mesh_name}"
    rec: dict = {"arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
                 "kind": shape.kind, "ok": False}
    t0 = time.perf_counter()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        from repro.distributed.sharding import use_mesh
        with use_mesh(mesh):
            fn, specs = _step_and_specs(cfg, shape, mesh, **step_kw)
            lowered = jax.jit(fn).lower(*specs)
            rec["lower_s"] = time.perf_counter() - t0
            t1 = time.perf_counter()
            compiled = lowered.compile()
            rec["compile_s"] = time.perf_counter() - t1

        mem = compiled.memory_analysis()
        if mem is not None:
            for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                         "output_size_in_bytes", "alias_size_in_bytes",
                         "generated_code_size_in_bytes"):
                v = getattr(mem, attr, None)
                if v is not None:
                    rec[attr] = int(v)
        cost = compiled.cost_analysis() or {}
        rec["hlo_flops"] = float(cost.get("flops", 0.0))
        rec["hlo_bytes"] = float(cost.get("bytes accessed", 0.0))
        rec["cost_keys"] = sorted(k for k in cost if "bytes accessed" in k
                                  or k in ("flops", "transcendentals"))

        hlo = compiled.as_text()
        rec["collectives"] = parse_collectives(hlo).as_dict()
        with use_mesh(mesh):
            rec["stage"] = stage_cost_probe(
                cfg, shape, mesh, remat=step_kw.get("remat", "block"))
        ns = rec["stage"]["num_stages"]
        rec["hlo_flops_scaled"] = rec["hlo_flops"] + \
            (ns - 1) * rec["stage"]["flops"]
        rec["hlo_bytes_scaled"] = rec["hlo_bytes"] + \
            (ns - 1) * rec["stage"]["bytes"]
        rec["collective_wire_bytes_scaled"] = \
            rec["collectives"]["wire_bytes"] + \
            (ns - 1) * rec["stage"]["collectives"]["wire_bytes"]
        rec["ok"] = True
        if save_hlo and out_dir:
            with open(os.path.join(out_dir, cell + ".hlo.txt"), "w") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = time.perf_counter() - t0

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, cell + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    status = "OK " if rec["ok"] else "FAIL"
    print(f"[{status}] {cell}  lower={rec.get('lower_s', 0):.1f}s "
          f"compile={rec.get('compile_s', 0):.1f}s "
          f"flops={rec.get('hlo_flops', 0):.3e} "
          f"coll={rec.get('collectives', {}).get('wire_bytes', 0):.3e}B"
          + ("" if rec["ok"] else f"  err={rec.get('error')}"), flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="sweep every applicable (arch x shape)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--remat", default="block")
    ap.add_argument("--grad-compression", default="none")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh]
    cells: list[tuple[ModelConfig, InputShape]] = []
    if args.all:
        cells = list(iter_cells())
    else:
        cfg = get_config(args.arch)
        shp = SHAPES[args.shape]
        if not shape_applicable(cfg, shp):
            print(f"[SKIP] {cfg.name} x {shp.name}: full-attention arch, "
                  f"long-context cell skipped per DESIGN.md §4")
            return
        cells = [(cfg, shp)]

    failures = 0
    for cfg, shp in cells:
        for mp in meshes:
            rec = run_cell(cfg, shp, mp, out_dir=args.out,
                           save_hlo=args.save_hlo, remat=args.remat,
                           grad_compression=args.grad_compression)
            failures += 0 if rec["ok"] else 1
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()

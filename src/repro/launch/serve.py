"""Serving launcher: ``python -m repro.launch.serve --arch <id> [--smoke]``.

Boots the CREAM-Serve paged-KV engine and serves a synthetic request mix;
``--pool-mode`` flips the device tier between conventional SECDED and
CREAM (+12.5 % pages) to show the capacity effect, ``--paid-frac``
controls the share of requests on the SECDED-backed paid tier.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import get_config
from repro.serve import Engine, ServeRequest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--pool-mode", choices=["cream", "secded"],
                    default="cream")
    ap.add_argument("--pool-rows", type=int, default=64)
    ap.add_argument("--row-words", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--paid-frac", type=float, default=0.25,
                    help="share of requests on the SECDED paid tier")
    ap.add_argument("--secded-rows", type=int, default=16,
                    help="rows kept SECDED in cream mode (the paid tier's "
                         "frames; multiple of 8)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    rng = np.random.default_rng(0)
    reqs = [ServeRequest(
        f"s{i}",
        rng.integers(0, cfg.vocab_size,
                     size=args.prompt_len).astype(np.int32),
        args.max_new,
        tier="paid" if i < args.paid_frac * args.requests else "batch")
        for i in range(args.requests)]
    eng = Engine(cfg, max_batch=args.batch, max_len=args.max_len,
                 mode=args.pool_mode, num_rows=args.pool_rows,
                 row_words=args.row_words,
                 secded_rows=args.secded_rows if args.paid_frac else 0)
    out = eng.serve(reqs)
    print(json.dumps({k: (round(v, 4) if isinstance(v, float) else v)
                      for k, v in out.items()}, indent=1))


if __name__ == "__main__":
    main()

"""Serving launcher: ``python -m repro.launch.serve --arch <id> [--smoke]``.

Boots the engine with a CREAM-tiered sequence cache and serves a synthetic
multi-turn request mix; ``--pool-mode`` flips the device tier between
conventional SECDED and CREAM (+12.5% pages) to show the capacity effect.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import get_config
from repro.serve.engine import Engine, Request
from repro.serve.kv_cache import SequenceCache


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--pool-mode", choices=["cream", "secded"],
                    default="cream")
    ap.add_argument("--pool-rows", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    rng = np.random.default_rng(0)
    reqs = [Request(f"s{i}",
                    rng.integers(0, cfg.vocab_size,
                                 size=args.prompt_len).astype(np.int32),
                    args.max_new)
            for i in range(args.requests)]
    cache = SequenceCache(num_rows=args.pool_rows, mode=args.pool_mode)
    eng = Engine(cfg, batch_size=4, max_len=args.max_len, cache=cache)
    out = eng.serve(reqs)
    print(json.dumps({k: (round(v, 4) if isinstance(v, float) else v)
                      for k, v in out.items()}, indent=1))


if __name__ == "__main__":
    main()

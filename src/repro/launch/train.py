"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

On TPU pods this drives the full config over the production mesh; on CPU
(this container) ``--smoke`` selects the reduced same-family config so every
architecture's training loop is runnable anywhere. Mesh axes come from
``--mesh-data/--mesh-model`` (defaults: whatever the host offers).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import TrainConfig, get_config
from repro.distributed.sharding import use_mesh
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train.trainer import make_trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the (data=16, model=16) pod mesh (TPU)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    tcfg = TrainConfig(total_steps=max(args.steps, 100),
                       microbatch=args.microbatch,
                       grad_compression=args.grad_compression,
                       scrub_every=10, checkpoint_every=max(args.steps // 2, 1))

    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    elif len(jax.devices()) > 1:
        mesh = make_host_mesh()
    else:
        mesh = None

    def run():
        tr = make_trainer(cfg, tcfg, ckpt_dir=args.ckpt_dir,
                          seq_len=args.seq_len,
                          global_batch=args.global_batch)
        if args.ckpt_dir and tr.restore():
            print(f"resumed at step {tr.step}")
        log = tr.run(args.steps)
        print(f"{cfg.name}: loss {log[0]['loss']:.4f} -> "
              f"{log[-1]['loss']:.4f} over {args.steps} steps")

    if mesh is not None:
        with use_mesh(mesh):
            run()
    else:
        run()


if __name__ == "__main__":
    main()

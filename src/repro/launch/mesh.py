"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
``xla_force_host_platform_device_count`` *before* first jax init.

Mesh axes:
  * single pod: (data=16, model=16) — 256 chips (one v5e pod slice)
  * multi-pod:  (pod=2, data=16, model=16) — 512 chips; the 'pod' axis is
    pure data parallelism across pods (gradient all-reduce crosses DCN).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """A mesh over whatever devices exist (tests / single host)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def make_banks_mesh(num_banks: int):
    """1-D ``banks`` mesh for the sharded CREAM data plane (CREAM-Shard).

    Uses the first ``num_banks`` devices. On CPU, export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (before first jax
    init) to expose N virtual devices — CI and the repo conftest do.
    """
    devices = jax.devices()
    if len(devices) < num_banks:
        raise ValueError(
            f"need {num_banks} devices for a {num_banks}-bank mesh, have "
            f"{len(devices)}; on CPU set "
            "XLA_FLAGS=--xla_force_host_platform_device_count")
    return jax.make_mesh((num_banks,), ("banks",),
                         devices=devices[:num_banks])


# TPU v5e hardware constants (roofline denominators; see EXPERIMENTS.md)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW_PER_LINK = 50e9            # bytes/s per link (~ per-axis effective)

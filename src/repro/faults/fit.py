"""FIT-rate arithmetic: field failure rates → injector step rates.

DRAM reliability is quoted in **FIT/Mbit** — failures per 10⁹
device-hours per megabit. Field studies of production fleets (Schroeder
et al., SIGMETRICS'09 — the memcached-class machines the paper targets)
measure 25,000–75,000 FIT/Mbit of correctable errors, orders of magnitude
above vendor datasheets. The campaign drives the injector from these
numbers:

    errors = FIT/Mbit × Mbits × hours / 10⁹
    Mbit/GB = 8 × 1024
    soft_rate_per_gb_per_step = FIT/Mbit × 8192 × hours_per_step / 10⁹

The pools in this repo are tiny (tens–hundreds of KB), so a campaign
compresses time instead of capacity: one injector step models
``hours_per_step`` wall-clock hours of a full-size node. Pick it with
:func:`hours_for_expected_flips` to target a workable expected flip count
per step, and report results *per FIT rate* — the acceleration factor
cancels out of the corrected/detected/silent ratios.
"""
from __future__ import annotations

MBIT_PER_GB = 8 * 1024

#: Field-measured correctable-error rate, upper band (Schroeder et al.) —
#: "memcached-scale": what a large cache fleet actually sees per Mbit.
MEMCACHED_FIT = 70_000.0
#: Lower band of the same study — a healthy fleet.
HEALTHY_FIT = 25_000.0
#: Reduced-scale rate for CI smoke campaigns (deterministic, fast).
CI_SMOKE_FIT = 5_000.0


def soft_rate_per_gb_per_step(fit_per_mbit: float,
                              hours_per_step: float) -> float:
    """Expected soft-error events per resident GB per injector step."""
    return fit_per_mbit * MBIT_PER_GB * hours_per_step / 1e9


def hours_for_expected_flips(fit_per_mbit: float, resident_bytes: int,
                             flips_per_step: float) -> float:
    """Time-acceleration: hours one step must model so that a pool of
    ``resident_bytes`` sees ``flips_per_step`` expected events per step."""
    gb = resident_bytes / 2**30
    per_hour = fit_per_mbit * MBIT_PER_GB * gb / 1e9
    if per_hour <= 0:
        raise ValueError("FIT rate and resident bytes must be positive")
    return flips_per_step / per_hour


def expected_flips(fit_per_mbit: float, resident_bytes: int,
                   hours: float) -> float:
    """Expected error events for ``resident_bytes`` over ``hours``."""
    gb = resident_bytes / 2**30
    return fit_per_mbit * MBIT_PER_GB * gb * hours / 1e9

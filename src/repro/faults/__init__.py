"""CREAM-Campaign: FIT-driven live fault injection with a closed
reliability-SLO loop (see :mod:`repro.faults.campaign`)."""
from repro.faults.campaign import CampaignReport, FaultCampaign
from repro.faults.fit import (CI_SMOKE_FIT, HEALTHY_FIT, MEMCACHED_FIT,
                              expected_flips, hours_for_expected_flips,
                              soft_rate_per_gb_per_step)
from repro.faults.shadow import PageCensus, ShadowedPool

__all__ = [
    "CampaignReport", "FaultCampaign", "ShadowedPool", "PageCensus",
    "MEMCACHED_FIT", "HEALTHY_FIT", "CI_SMOKE_FIT",
    "soft_rate_per_gb_per_step", "hours_for_expected_flips",
    "expected_flips",
]

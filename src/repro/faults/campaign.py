"""The fault campaign: FIT-driven injection against a *live* pool, with
the observation loop closed through monitor → SLO → policy escalation.

One :class:`FaultCampaign` owns one VM pool. On attach it swaps the pool
for a :class:`~repro.faults.shadow.ShadowedPool` (the data plane keeps
running — engine decode steps, objcache batches, migrations all route
through the wrapper untouched) and builds a
:class:`~repro.core.injection.FaultModel` whose Poisson soft-error rate
comes from a FIT figure via :mod:`repro.faults.fit`. Each campaign tick:

  1. **inject** one step of faults into the live storage (soft events per
     the :class:`~repro.core.injection.ErrorMix`, plus sticky hard cells);
  2. the workload runs — every read is classified against the shadow
     oracle as clean / corrected / detected / **silent**;
  3. **observe**: per-page outcome deltas are attributed to the owning
     ``(tenant, segment)`` through the frame allocator's reverse map and
     fed to :class:`~repro.vm.policy.VMPolicy.observe_reads`, the global
     :data:`~repro.obs.slo.TRACKER`, and
     :meth:`~repro.core.monitor.ErrorMonitor.record_observation`;
  4. **escalate**: :meth:`~repro.vm.policy.VMPolicy.auto_escalate`
     upgrades any tenant segment whose observed error rate crossed its
     SLO — realised as the existing zero-loss migration — and the
     campaign re-syncs the serving engine's tier map and translations.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.injection import ErrorMix, FaultModel, FIELD_MIX
from repro.faults.fit import MEMCACHED_FIT, soft_rate_per_gb_per_step
from repro.faults.shadow import PageCensus, ShadowedPool
from repro.vm.address_space import VirtualMemory, frame_class
from repro.vm.policy import VMPolicy


@dataclass
class CampaignReport:
    """What one campaign measured, per reliability class."""
    steps: int = 0
    injected: int = 0
    census: dict[str, PageCensus] = field(default_factory=dict)
    escalations: list[dict] = field(default_factory=list)

    def rates(self) -> dict[str, dict[str, float]]:
        return {cls: {k: cen.rate(k)
                      for k in ("corrected", "detected", "silent")}
                for cls, cen in sorted(self.census.items())}


class FaultCampaign:
    """Drive a FIT-scaled error process against one live VM pool."""

    def __init__(self, vm: VirtualMemory, pool_name: str, *,
                 policy: VMPolicy | None = None, engine=None,
                 fit_per_mbit: float = MEMCACHED_FIT,
                 hours_per_step: float = 1.0,
                 mix: ErrorMix = FIELD_MIX, n_hard: int = 0,
                 seed: int = 0, adopt: bool = True):
        self.vm = vm
        self.pool_name = pool_name
        self.policy = policy
        self.engine = engine
        inner = vm.pools[pool_name]
        if isinstance(inner, ShadowedPool):
            raise ValueError(f"pool {pool_name!r} is already shadowed")
        self.shadow = ShadowedPool(inner)
        vm.pools[pool_name] = self.shadow
        if adopt:
            self._adopt_contents()
        storage = inner.storage
        if storage.ndim == 4:           # sharded: global rows across shards
            S, R_local, L, W = storage.shape
            shape = (S * R_local, L, W)
        else:
            shape = storage.shape
        self.model = FaultModel.make(
            seed,
            soft_rate=soft_rate_per_gb_per_step(fit_per_mbit, hours_per_step),
            n_hard=n_hard, shape=shape, mix=mix)
        self.fit_per_mbit = fit_per_mbit
        self.hours_per_step = hours_per_step
        self.steps = 0
        self.injected = 0
        self.first_escalation_step: int | None = None

    def _adopt_contents(self) -> None:
        """Bless the pool's current contents as believed ground truth, so
        pages written before the campaign attached classify correctly."""
        pages = np.arange(self.shadow.num_pages)
        data, _ = self.shadow.inner.read(pages, status=True)
        self.shadow._shadow[pages] = np.asarray(data)
        self.shadow._valid[pages] = True
        self.shadow.drain()             # attach noise must not attribute

    # -- the loop ------------------------------------------------------------
    def inject(self) -> int:
        """One injector step against the live pool. Returns flips applied."""
        n = self.shadow.inject(self.model)
        self.steps += 1
        self.injected += n
        return n

    def observe(self) -> dict[str, tuple[int, int, int, int]]:
        """Drain read outcomes since the last call and close the loop.

        Per-page deltas are attributed to the owning (tenant, segment) via
        the allocator's reverse map, then fed to the policy accumulator,
        the SLO tracker, and the error monitor. Returns the per-class
        aggregate ``{class: (reads, corrected, detected, silent)}``.
        """
        from repro.obs import slo
        owner = self.vm.allocators[self.pool_name].owner
        by_class: dict[str, list[int]] = {}
        total = [0, 0, 0, 0]
        for phys, (reads, corrected, detected, silent) in \
                self.shadow.drain().items():
            cls = frame_class(self.shadow.inner, phys).value
            acc = by_class.setdefault(cls, [0, 0, 0, 0])
            for i, v in enumerate((reads, corrected, detected, silent)):
                acc[i] += v
                total[i] += v
            slo.TRACKER.record_read_status(
                cls, corrected=corrected, uncorrectable=detected,
                silent=silent)
            who = owner.get(phys)
            if who is None or self.policy is None:
                continue
            tenant, vpn = who
            pte = self.vm.tenants[tenant].entries[vpn]
            self.policy.observe_reads(tenant, pte.segment, reads=reads,
                                      corrected=corrected,
                                      detected=detected, silent=silent)
        if self.policy is not None and total[0]:
            self.policy.monitor.record_observation(
                self.pool_name, checked=total[0], corrected=total[1],
                uncorrectable=total[2], silent=total[3])
        return {cls: tuple(acc) for cls, acc in by_class.items()}

    def escalate(self) -> list[dict]:
        """Run the policy's SLO check; sync the engine after any upgrade."""
        if self.policy is None:
            return []
        done = self.policy.auto_escalate()
        if done and self.first_escalation_step is None:
            self.first_escalation_step = self.steps
        if done and self.engine is not None:
            kv = getattr(self.engine, "kv", None)
            for esc in done:
                if kv is not None and esc["segment"] in kv.tiers:
                    kv.tiers[esc["segment"]] = esc["to"]
            if kv is not None:
                kv.refresh()            # phys mirror moved under us
            self.engine.refresh_translation()
        return done

    def tick(self) -> list[dict]:
        """inject → observe → escalate (the workload runs in between the
        caller's ticks). Returns any escalations performed."""
        self.inject()
        self.observe()
        return self.escalate()

    # -- teardown / results --------------------------------------------------
    def detach(self) -> None:
        """Restore the unwrapped pool (campaign over)."""
        if self.vm.pools.get(self.pool_name) is self.shadow:
            self.vm.pools[self.pool_name] = self.shadow.inner

    def report(self) -> CampaignReport:
        return CampaignReport(
            steps=self.steps, injected=self.injected,
            census=dict(self.shadow.census),
            escalations=list(self.policy.escalations)
            if self.policy is not None else [])

"""Ground-truth shadow tracking: classify every read as corrected /
detected / silently-corrupted.

:class:`ShadowedPool` wraps any :class:`~repro.core.pool.PoolLike` and
keeps a host-side *shadow copy* of every page the system has written —
the content the data plane **believes** is stored. Reads go through the
wrapped pool's status path; each returned page is compared against the
shadow:

  ============================  ==========================  ============
  hardware status               data == shadow              verdict
  ============================  ==========================  ============
  DETECTED_UNCORRECTABLE        (any)                       detected
  CORRECTED_*                   yes                         corrected
  CORRECTED_*                   no                          **silent** (miscorrection)
  CLEAN                         yes                         clean
  CLEAN                         no                          **silent**
  ============================  ==========================  ============

"Silent" is the class the paper's contract cares about: wrong bits
surfaced with no flag. SECDED's Hsiao code never miscorrects a double
(it detects all 2-bit beat errors), PARITY misses only even numbers of
flips in one congruence class, NONE misses everything — the shadow
oracle measures all three, per reliability class, while the system runs.

The wrapper is deliberately **not** a pytree: it must never be traced.
It presents the full PoolLike surface, is *mutable* (``write`` replaces
``self.inner`` and returns ``self``), and therefore survives the data
plane's ``vm.pools[name] = pool.write(...)`` reassignment idiom
unchanged — the engine, VM, migration and policy layers run unmodified
over a shadowed pool. The fused in-jit gather (``PoolState`` fast path)
is bypassed by construction: ``isinstance(wrapper, PoolState)`` is
False, so engines fall back to the host-side ``read`` route the oracle
can observe. One caveat is inherent: a migration *re-writes* what
it read, so corruption that slips through a migration read is counted as
silent **at that read** (attributed to the class it occurred under) and
then becomes the new believed content.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

from repro.core import pool as pool_lib
from repro.core import secded
from repro.core.layouts import extra_page_count
from repro.vm.address_space import frame_class


@dataclass
class PageCensus:
    """Cumulative read-outcome counts for one reliability class."""
    reads: int = 0
    clean: int = 0
    corrected: int = 0
    detected: int = 0
    silent: int = 0

    def rate(self, kind: str) -> float:
        return getattr(self, kind) / self.reads if self.reads else 0.0


class ShadowedPool:
    """PoolLike wrapper adding a ground-truth oracle to every batched read."""

    def __init__(self, inner):
        self.inner = inner
        S = getattr(inner, "num_shards", 1)
        cap = inner.num_rows + S * extra_page_count(
            inner.layout, inner.num_rows // S, inner.row_words)
        self._shadow = np.zeros((cap, inner.page_words), np.uint32)
        self._valid = np.zeros(cap, bool)
        # per-page outcome counters (for tenant attribution via drain())
        self._reads = np.zeros(cap, np.int64)
        self._corrected = np.zeros(cap, np.int64)
        self._detected = np.zeros(cap, np.int64)
        self._silent = np.zeros(cap, np.int64)
        self._drained = np.zeros((4, cap), np.int64)   # snapshot at last drain
        self.census: dict[str, PageCensus] = {}

    # -- forwarded geometry --------------------------------------------------
    @property
    def layout(self):
        return self.inner.layout

    @property
    def row_words(self) -> int:
        return self.inner.row_words

    @property
    def boundary(self) -> int:
        return self.inner.boundary

    @property
    def num_rows(self) -> int:
        return self.inner.num_rows

    @property
    def num_pages(self) -> int:
        return self.inner.num_pages

    @property
    def num_extra_pages(self) -> int:
        return self.inner.num_extra_pages

    @property
    def page_words(self) -> int:
        return self.inner.page_words

    @property
    def boundary_step(self) -> int:
        return self.inner.boundary_step

    @property
    def daec_rows(self) -> int:
        return self.inner.daec_rows

    @property
    def daec_start(self) -> int:
        return self.inner.daec_start

    @property
    def storage(self):
        return self.inner.storage

    def capacity_gain(self) -> float:
        return self.inner.capacity_gain()

    # -- the oracle ----------------------------------------------------------
    def _classify(self, pages, data, status) -> None:
        pages = np.asarray(pages).reshape(-1)
        data = np.asarray(data).reshape(pages.size, -1)
        status = np.asarray(status).reshape(-1)
        valid = self._valid[pages]
        match = np.zeros(pages.size, bool)
        if valid.any():
            match[valid] = (data[valid] ==
                            self._shadow[pages[valid]]).all(axis=1)
        detected = status == secded.DETECTED_UNCORRECTABLE
        corrected = ((status == secded.CORRECTED_DATA) |
                     (status == secded.CORRECTED_CODE)) & ~detected
        # wrong bits with no flag — incl. SECDED miscorrections (status
        # says corrected but the data disagrees with the ground truth)
        silent = valid & ~detected & ~match
        corrected &= match
        np.add.at(self._reads, pages[valid], 1)   # only believed pages count
        np.add.at(self._detected, pages[detected & valid], 1)
        np.add.at(self._corrected, pages[corrected & valid], 1)
        np.add.at(self._silent, pages[silent], 1)
        # per-class census, attributed at read time under the live boundary
        for p, v, d, c, s in zip(pages, valid, detected & valid,
                                 corrected & valid, silent):
            if not v:
                continue
            cls = frame_class(self.inner, int(p)).value
            cen = self.census.setdefault(cls, PageCensus())
            cen.reads += 1
            if d:
                cen.detected += 1
            elif s:
                cen.silent += 1
            elif c:
                cen.corrected += 1
            else:
                cen.clean += 1

    def drain(self) -> dict[int, tuple[int, int, int, int]]:
        """Per-page (reads, corrected, detected, silent) since last drain."""
        cur = np.stack([self._reads, self._corrected,
                        self._detected, self._silent])
        delta = cur - self._drained
        self._drained = cur
        pages = np.nonzero(delta.any(axis=0))[0]
        return {int(p): tuple(int(x) for x in delta[:, p]) for p in pages}

    # -- PoolLike data plane (unified access API) ----------------------------
    # classification always works because the wrapper is never passed into
    # jit — any call landing here is host-side by design
    def read(self, pages, *, status=False):
        data, st = self.inner.read(pages, status=True)
        self._classify(pages, data, st)
        return (data, st) if status else data

    def read_writeback(self, pages):
        # storage repairs toward the stored codewords; logical truth — and
        # therefore the shadow — is unchanged, so the oracle still applies
        data, st, self.inner = self.inner.read_writeback(pages)
        self._classify(pages, data, st)
        return data, st, self

    def write(self, pages, data, *, valid=None) -> "ShadowedPool":
        self.inner = self.inner.write(pages, data, valid=valid)
        p = np.asarray(pages).reshape(-1)
        d = np.asarray(data).reshape(p.size, -1)
        if valid is not None:
            keep = np.asarray(valid, bool).reshape(-1)
            p, d = p[keep], d[keep]
        self._shadow[p] = d
        self._valid[p] = True
        return self

    def migrate(self, src_pages, dst_pages, *,
                donate: bool = True) -> "ShadowedPool":
        # through the classified read + write, not the inner fused migrate:
        # migration reads must hit the oracle (and what they surface becomes
        # the new believed content — the documented caveat above)
        return self.write(dst_pages, self.read(src_pages))

    def streams(self, pages, data=None, *, valid=None):
        if data is None:
            return self.read(np.asarray(pages).reshape(-1)) \
                .reshape(*np.shape(pages), -1)
        flat = np.asarray(pages).reshape(-1)
        vf = None if valid is None else np.asarray(valid).reshape(-1)
        return self.write(flat, np.asarray(data).reshape(flat.size, -1),
                          valid=vf)

    # -- deprecated access surface (thin shims over the unified API) --------
    def read_pages(self, pages) -> jax.Array:
        pool_lib._warn_deprecated("read_pages", "read(pages)")
        return self.read(pages)

    def read_pages_status(self, pages) -> tuple[jax.Array, jax.Array]:
        pool_lib._warn_deprecated("read_pages_status", "read(pages, status=True)")
        return self.read(pages, status=True)

    def write_pages(self, pages, data) -> "ShadowedPool":
        pool_lib._warn_deprecated("write_pages", "write(pages, data)")
        return self.write(pages, data)

    def read_any(self, pages) -> jax.Array:
        pool_lib._warn_deprecated("read_any", "read(pages)")
        return self.read(pages)

    def read_any_status(self, pages) -> tuple[jax.Array, jax.Array]:
        pool_lib._warn_deprecated("read_any_status", "read(pages, status=True)")
        return self.read(pages, status=True)

    def write_any(self, pages, data) -> "ShadowedPool":
        pool_lib._warn_deprecated("write_any", "write(pages, data)")
        return self.write(pages, data)

    # -- control plane -------------------------------------------------------
    def evict_prediction(self, new_boundary: int) -> list[int]:
        return self.inner.evict_prediction(new_boundary)

    def move_boundary(self, new_boundary: int) -> tuple["ShadowedPool", dict]:
        self.inner, info = self.inner.move_boundary(new_boundary)
        # pages beyond the new geometry no longer exist; boundary-shrink
        # re-encoding also re-blesses surviving contents as believed truth
        self._valid[self.inner.num_pages:] = False
        return self, info

    def scrub(self, use_kernel: bool = False) -> tuple["ShadowedPool", object]:
        # scrub repairs toward the stored codewords; the logical truth
        # (what the system wrote) is unchanged, so the shadow stays put
        self.inner, stats = self.inner.scrub(use_kernel=use_kernel)
        return self, stats

    def set_daec_rows(self, daec_rows: int) -> "ShadowedPool":
        # re-encoding preserves logical contents, so the shadow stays put
        self.inner = self.inner.set_daec_rows(daec_rows)
        return self

    # -- injection -----------------------------------------------------------
    def inject(self, fault_model) -> int:
        """One injector step against the wrapped pool (shadow untouched —
        injected corruption is exactly what the oracle must catch)."""
        self.inner, count = fault_model.step_pool(self.inner)
        return count

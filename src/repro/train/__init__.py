"""repro.train subpackage."""

"""The jitted training step: loss -> grads -> (compressed) update.

Gradient accumulation uses a lax.scan over microbatches (activation memory
bound by one microbatch; essential for the 34B+ configs). Under the
production mesh the grads inherit the parameter shardings, so the gradient
reduction is a reduce-scatter/all-gather pair inserted by GSPMD (ZeRO), and
``grad_compression='int8'`` quantises before the reduction to cut the
collective term (visible in the dry-run HLO).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import transformer
from repro.optim import adamw


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    attn_impl: str = "xla"):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def loss_fn(params, tokens, labels):
        return transformer.loss_fn(params, cfg, tokens, labels,
                                   attn_impl=attn_impl, remat=tcfg.remat)

    grad_fn = jax.value_and_grad(loss_fn)

    def whole_batch_grads(params, batch):
        return grad_fn(params, batch["tokens"], batch["labels"])

    def microbatched_grads(params, batch, n_micro: int):
        b = batch["tokens"].shape[0]
        assert b % n_micro == 0
        mb = b // n_micro
        toks = batch["tokens"].reshape(n_micro, mb, -1)
        labs = batch["labels"].reshape(n_micro, mb, -1)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, xs):
            loss_acc, g_acc = acc
            loss, g = grad_fn(params, xs[0], xs[1])
            g_acc = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32),
                                 g_acc, g)
            return (loss_acc + loss, g_acc), None

        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero),
                                        (toks, labs))
        scale = 1.0 / n_micro
        return loss * scale, jax.tree.map(lambda g: g * scale, grads)

    def train_step(params, opt_state, batch):
        if tcfg.microbatch and tcfg.microbatch > 1:
            loss, grads = microbatched_grads(params, batch, tcfg.microbatch)
        else:
            loss, grads = whole_batch_grads(params, batch)
        grads = adamw.maybe_compress_grads(grads, tcfg.grad_compression)
        gnorm = adamw.global_norm(grads)
        params, opt_state = adamw.update(grads, opt_state, params, tcfg)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                   "step": opt_state.step}
        return params, opt_state, metrics

    return train_step

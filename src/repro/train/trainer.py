"""Trainer: the fault-tolerant training loop with CREAM integration.

Per step: deterministic batch -> jitted train_step. Periodically:

  * **scrub** — the SECDED pool holding the optimizer-moment snapshot is
    swept; single-bit SDC is repaired in place, rates feed the monitor
    (paper §3.1 health loop);
  * **snapshot** — moments are re-stored into the pool (warm-restart tier)
    and a full SECDED-protected checkpoint goes to disk;
  * **restart** — ``Trainer.restore()`` resumes from the latest disk
    checkpoint; ``warm_restore()`` rebuilds moments from the pool after a
    simulated in-memory crash, repairing any injected bit flips on the way.

The loop is deliberately host-driven and simple: all heavy lifting is inside
the single jitted step, so the same loop drives 1 CPU or a 512-chip mesh.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import poolstore
from repro.core.layouts import Layout
from repro.core.monitor import ErrorMonitor
from repro.core.pool import make_pool
from repro.core.scrubber import scrub
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.models import transformer
from repro.optim import adamw
from repro.train.train_step import make_train_step


@dataclass
class Trainer:
    cfg: ModelConfig
    tcfg: TrainConfig
    data: SyntheticStream
    checkpointer: Checkpointer | None = None
    attn_impl: str = "xla"
    # runtime state
    params: Any = None
    opt_state: Any = None
    step: int = 0
    metrics_log: list = field(default_factory=list)
    # CREAM: SECDED pool snapshot of the optimizer moments
    moment_pool: Any = None
    moment_toc: Any = None
    monitor: ErrorMonitor = field(default_factory=ErrorMonitor)

    def initialize(self, seed: int | None = None) -> None:
        key = jax.random.key(seed if seed is not None else self.tcfg.seed)
        self.params = transformer.init_params(self.cfg, key)
        self.opt_state = adamw.init(self.params)
        self.step = 0
        self._step_fn = jax.jit(make_train_step(self.cfg, self.tcfg,
                                                self.attn_impl))
        if self.tcfg.protect_opt_state:
            self._init_moment_pool()

    def _init_moment_pool(self) -> None:
        moments = {"m": self.opt_state.m, "v": self.opt_state.v}
        rows = poolstore.required_rows(moments)
        self.moment_pool = make_pool(rows, Layout.INTERWRAP, boundary=0)
        self.snapshot_moments()

    # -- CREAM integration ----------------------------------------------------
    def snapshot_moments(self) -> None:
        if self.moment_pool is None:
            return
        moments = {"m": self.opt_state.m, "v": self.opt_state.v}
        self.moment_pool, self.moment_toc = poolstore.store_tree(
            self.moment_pool, moments)

    def scrub_pools(self) -> dict:
        if self.moment_pool is None:
            return {}
        self.moment_pool, stats = scrub(self.moment_pool)
        self.monitor.record("opt_moments", stats)
        return {"corrected": stats.corrected,
                "uncorrectable": stats.detected_uncorrectable,
                "rate": stats.error_rate}

    def warm_restore(self) -> int:
        """Rebuild optimizer moments from the SECDED pool (in-memory crash
        recovery without touching disk). Returns worst decode status seen."""
        moments_like = {"m": self.opt_state.m, "v": self.opt_state.v}
        restored, worst = poolstore.load_tree(self.moment_pool,
                                              self.moment_toc, moments_like)
        self.opt_state = dataclasses.replace(
            self.opt_state, m=restored["m"], v=restored["v"])
        return worst

    # -- checkpoint/restart ----------------------------------------------------
    def _ckpt_tree(self) -> dict:
        return {"params": self.params,
                "opt": {"step": self.opt_state.step, "m": self.opt_state.m,
                        "v": self.opt_state.v},
                "meta": {"step": np.int64(self.step)}}

    def save(self) -> None:
        if self.checkpointer:
            self.checkpointer.save(self.step, self._ckpt_tree())

    def restore(self, step: int | None = None) -> bool:
        if not self.checkpointer:
            return False
        step = step if step is not None else self.checkpointer.latest_step()
        if step is None:
            return False
        tree, report = self.checkpointer.restore(step, like=self._ckpt_tree())
        if report.corrupt_leaves:
            raise RuntimeError(
                f"uncorrectable checkpoint leaves: {report.corrupt_leaves}")
        self.params = tree["params"]
        self.opt_state = adamw.AdamWState(
            step=tree["opt"]["step"], m=tree["opt"]["m"], v=tree["opt"]["v"])
        self.step = int(tree["meta"]["step"])
        return True

    # -- the loop ---------------------------------------------------------------
    def run(self, num_steps: int) -> list[dict]:
        for _ in range(num_steps):
            batch = self.data.batch(self.step)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch)
            dt = time.perf_counter() - t0
            rec = {k: float(v) for k, v in metrics.items()}
            rec["wall_s"] = dt
            rec["step"] = self.step
            self.metrics_log.append(rec)
            self.step += 1
            if self.tcfg.scrub_every and self.step % self.tcfg.scrub_every == 0:
                rec["scrub"] = self.scrub_pools()
            if self.tcfg.checkpoint_every and \
                    self.step % self.tcfg.checkpoint_every == 0:
                self.snapshot_moments()
                self.save()
        return self.metrics_log


def make_trainer(cfg: ModelConfig, tcfg: TrainConfig,
                 ckpt_dir: str | None = None, seed: int = 0,
                 num_shards: int = 1, shard_id: int = 0,
                 seq_len: int = 128, global_batch: int = 8) -> Trainer:
    data = SyntheticStream(
        DataConfig(cfg.vocab_size, seq_len, global_batch, seed=seed),
        num_shards=num_shards, shard_id=shard_id)
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    tr = Trainer(cfg, tcfg, data, ckpt)
    tr.initialize(seed)
    return tr

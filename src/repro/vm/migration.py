"""Live page migration across pools, protection modes, and the host tier.

The engine turns two events into zero-loss relocations:

  * **protection upgrade** (boundary shrinks, SECDED region grows): the
    paper's repartition *evicts* the extra pages whose storage lived in the
    reclaimed code lanes. :meth:`MigrationEngine.repartition_with_migration`
    predicts the doomed frames (:func:`repro.core.pool.evicted_extra_pages`),
    reads them out in one fused Pallas gather/re-encode batch
    (:mod:`repro.kernels.migrate`), repartitions, then lands them in new
    frames — same-or-stronger class, any pool, host swap for overflow;
  * **protection downgrade** (boundary grows, capacity reclaimed): frames in
    the surrendered SECDED span weaken to the CREAM layout's class, so pages
    whose tenants contracted for stronger protection are relocated first —
    the HARP-style "move hot data away from weakening rows" motion.

Destination writes for SECDED frames reuse the codes the kernel already
computed (no second encode pass); everything else goes through the jitted
mixed-pool engine (the unified ``pool.write``), which maintains codes per
layout. Every step that touches pool storage — source gather, decode,
re-encode, destination scatter — is a single traced dispatch per pool, so a
migration transaction's data plane is jitted end-to-end; only the page-table
and free-list bookkeeping stays host-side.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layouts import CODE_LANE, DATA_LANES, Layout
from repro.core.pool import PoolState
from repro.core.protection import at_least
from repro.kernels.migrate import ops as migrate_ops
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.vm.address_space import PTE, VirtualMemory, cream_protection


@dataclass
class MigrationStats:
    pages_moved: int = 0
    bytes_moved: int = 0
    to_host: int = 0
    transactions: int = 0
    kernel_batches: int = 0
    seconds: float = 0.0

    @property
    def throughput_pages_s(self) -> float:
        return self.pages_moved / self.seconds if self.seconds else 0.0

    @property
    def throughput_mb_s(self) -> float:
        return self.bytes_moved / 2**20 / self.seconds if self.seconds else 0.0


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_coded_rows(storage: jax.Array, rows: jax.Array,
                        data: jnp.ndarray, codes: jnp.ndarray) -> jax.Array:
    """Land pages in SECDED rows reusing precomputed codes — one dispatch."""
    n = rows.shape[0]
    storage = storage.at[rows, :DATA_LANES, :].set(
        data.reshape(n, DATA_LANES, -1))
    return storage.at[rows, CODE_LANE, :].set(codes)


class MigrationEngine:
    """Relocates mapped pages between frames without losing contents.

    ``use_kernel=None`` auto-selects the fused Pallas path on TPU and the
    vectorised jnp oracle under interpret mode (see
    :mod:`repro.kernels.migrate.ops`).
    """

    def __init__(self, vm: VirtualMemory, use_kernel: bool | None = None):
        self.vm = vm
        self.use_kernel = use_kernel
        self.stats = MigrationStats()

    # -- building blocks -----------------------------------------------------
    def _read_frames(self, state, phys: list[int]
                     ) -> tuple[jnp.ndarray, jnp.ndarray | None]:
        """Batch-read frames -> (data, precomputed SECDED codes or None).

        Pure-CREAM InterWrap batches on a *local* pool take the fused Pallas
        gather/re-encode (codes for the destination come free); every other
        mix — including any sharded pool, whose per-shard reads are already
        fused dispatches — goes through the pool's jitted engine in one
        decode-corrected gather.
        """
        if isinstance(state, PoolState) \
                and state.layout == Layout.INTERWRAP and all(
                p < state.boundary or p >= state.num_rows for p in phys):
            data, codes = migrate_ops.gather_encode(
                state.storage, jnp.asarray(phys, jnp.int32), state.num_rows,
                use_kernel=self.use_kernel)
            self.stats.kernel_batches += 1
            return data, codes
        return state.read(phys), None

    def _write_frames(self, pool_name: str, phys: list[int],
                      data: jnp.ndarray, codes: jnp.ndarray | None) -> None:
        """Batch-write frames, reusing precomputed codes where they apply."""
        vm = self.vm
        state = vm.pools[pool_name]
        # precomputed codes are SECDED — the DAEC tier re-encodes via write()
        if codes is not None and isinstance(state, PoolState) and all(
                state.boundary <= p < state.num_rows - state.daec_rows
                for p in phys):
            storage = _scatter_coded_rows(
                state.storage, jnp.asarray(phys, jnp.int32), data, codes)
            vm.pools[pool_name] = dataclasses.replace(state, storage=storage)
        else:
            vm.pools[pool_name] = state.write(phys, data)

    def _place(self, data: jnp.ndarray, codes: jnp.ndarray | None,
               victims: list[tuple[str, int, PTE]],
               exclude: dict[str, set[int]],
               avoid_pool: str | None = None) -> None:
        """Land read-out pages in fresh frames (or host) and remap PTEs.

        Destination pools are tried in registration order, except that a
        victim's own source pool is tried last and ``avoid_pool`` is never a
        destination — migration should move data *away* unless nowhere else
        has room. Victims are placed in batches grouped by (source pool,
        reliability class): one free-list peek per (group, destination pool)
        instead of one walk per page, so the control plane scales with the
        number of groups, not pages.
        """
        vm = self.vm
        _host_before_place = self.stats.to_host
        by_pool: dict[str, list[tuple[int, int]]] = {}
        host = None                   # D2H copy made lazily, on first overflow
        groups: dict[tuple[str | None, object], list[int]] = {}
        for i, (_, _, pte) in enumerate(victims):
            groups.setdefault((pte.pool, pte.reliability), []).append(i)
        for (src_pool, rel), idxs in groups.items():
            ordered = sorted(
                (kv for kv in vm.allocators.items() if kv[0] != avoid_pool),
                key=lambda kv: kv[0] == src_pool)
            remaining = list(idxs)
            for pool_name, alloc in ordered:
                if not remaining:
                    break
                picks = alloc.peek(rel, len(remaining),
                                   exclude=exclude.get(pool_name))
                for phys, i in zip(picks, remaining[:len(picks)]):
                    tenant, vpn, pte = victims[i]
                    alloc.claim(phys, tenant, vpn)
                    vm.tenants[tenant].entries[vpn] = PTE(
                        pool_name, phys, pte.reliability, pte.segment)
                    by_pool.setdefault(pool_name, []).append((i, phys))
                remaining = remaining[len(picks):]
            for i in remaining:       # overflow -> host swap tier
                tenant, vpn, pte = victims[i]
                if host is None:
                    host = np.asarray(data, np.uint32)
                slot = vm._new_slot()
                vm.swap[slot] = host[i].copy()
                vm.tenants[tenant].entries[vpn] = PTE(
                    None, slot, pte.reliability, pte.segment)
                self.stats.to_host += 1
        for pool_name, items in by_pool.items():
            idx = jnp.asarray([i for i, _ in items])
            sub_codes = codes[idx] if codes is not None else None
            self._write_frames(pool_name, [p for _, p in items],
                               data[idx], sub_codes)
        self.stats.pages_moved += len(victims)
        self.stats.bytes_moved += len(victims) * vm.page_bytes
        if obs_metrics.enabled():
            c = obs_metrics.counter(
                obs_metrics.NAME_PAGES_MIGRATED,
                "pages relocated by the migration engine",
                labels=("cls",))
            per_cls: dict[str, int] = {}
            for _, _, pte in victims:
                key = pte.reliability.value
                per_cls[key] = per_cls.get(key, 0) + 1
            for cls, n in per_cls.items():
                c.labels(cls=cls).inc(n)
            overflow = self.stats.to_host - _host_before_place
            if overflow:
                obs_metrics.counter(
                    obs_metrics.NAME_MIGRATION_TO_HOST,
                    "migrated pages that overflowed to the host swap tier"
                ).labels().inc(overflow)

    # -- ad-hoc migration ----------------------------------------------------
    def relocate(self, tenant: str, vpns, avoid_pool: str | None = None
                 ) -> int:
        """Move pages off their current frames (e.g. away from a weakening
        pool), preferring other pools; host swap on overflow."""
        vpns = list(vpns)
        with obs_tracing.span("vm.migration.relocate", tenant=tenant,
                              pages=len(vpns)):
            return self._relocate(tenant, vpns, avoid_pool)

    def _relocate(self, tenant: str, vpns, avoid_pool: str | None) -> int:
        vm = self.vm
        t0 = time.perf_counter()
        space = vm.tenants[tenant]
        victims = []
        by_pool: dict[str, list[int]] = {}
        for vpn in vpns:
            pte = space.entries[vpn]
            if pte.pool is None:
                continue
            victims.append((tenant, vpn, pte))
            by_pool.setdefault(pte.pool, []).append(len(victims) - 1)
        if not victims:
            return 0
        # one gather per source pool, scattered straight into victim order —
        # no per-page slicing on the host
        n = len(victims)
        data_all = jnp.zeros((n, vm.page_words), jnp.uint32)
        codes_all = jnp.zeros((n, vm.row_words), jnp.uint32)
        have_codes = True
        for pool_name, idxs in by_pool.items():
            phys = [victims[i][2].phys for i in idxs]
            data, codes = self._read_frames(vm.pools[pool_name], phys)
            idx = jnp.asarray(idxs, jnp.int32)
            data_all = data_all.at[idx].set(data)
            if codes is None:
                have_codes = False
            else:
                codes_all = codes_all.at[idx].set(codes)
        # free the source frames, but bar them (and the avoided pool) as
        # destinations for this transaction — relocation must actually move
        exclude: dict[str, set[int]] = {}
        for tenant_, vpn, pte in victims:
            vm.allocators[pte.pool].release(vm.pools[pte.pool], pte.phys)
            exclude.setdefault(pte.pool, set()).add(pte.phys)
        self._place(data_all, codes_all if have_codes else None,
                    victims, exclude, avoid_pool=avoid_pool)
        self.stats.transactions += 1
        self.stats.seconds += time.perf_counter() - t0
        return len(victims)

    # -- the transaction -----------------------------------------------------
    def repartition_with_migration(self, pool_name: str, new_boundary: int
                                   ) -> dict:
        """Move a pool's boundary without losing a single mapped page.

        Upgrade (shrink): doomed extra pages are read out (fused Pallas
        gather/re-encode batch), the boundary moves, and the pages land in
        fresh frames / host swap. Downgrade (grow): mapped pages whose
        reliability contract exceeds the weakened class are relocated out of
        the surrendered span first; the new extra pages join the free lists.
        """
        vm = self.vm
        state = vm.pools[pool_name]
        alloc = vm.allocators[pool_name]
        old = state.boundary
        with obs_tracing.span("vm.migration.repartition", pool=pool_name,
                              old_boundary=old, new_boundary=new_boundary):
            return self._repartition(pool_name, new_boundary, state, alloc,
                                     old)

    def _repartition(self, pool_name: str, new_boundary: int, state, alloc,
                     old: int) -> dict:
        vm = self.vm
        # validate before touching any mapping: a bad boundary must not
        # leave half-unmapped victims behind (sharded pools move their
        # boundary in shard lockstep, so their step is S * GROUP_ROWS)
        if new_boundary % state.boundary_step \
                or not 0 <= new_boundary <= state.num_rows:
            raise ValueError(f"bad boundary {new_boundary}")
        t0 = time.perf_counter()
        info = {"pool": pool_name, "old_boundary": old,
                "new_boundary": new_boundary, "migrated": 0, "to_host": 0,
                "evicted_unmapped": 0}
        if new_boundary == old:
            return info
        host_before = self.stats.to_host

        if new_boundary < old:      # upgrade: SECDED region grows
            doomed = state.evict_prediction(new_boundary)
            victims = []
            for phys in doomed:
                if phys in alloc.owner:
                    tenant, vpn = alloc.owner[phys]
                    victims.append((tenant, vpn,
                                    vm.tenants[tenant].entries[vpn]))
                else:       # free frame: simply vanishes in the rebuild
                    info["evicted_unmapped"] += 1
            data = codes = None
            if victims:
                data, codes = self._read_frames(
                    state, [pte.phys for _, _, pte in victims])
                for _, _, pte in victims:     # unmap before the frame dies
                    del alloc.owner[pte.phys]
            new_state, _ = state.move_boundary(new_boundary)
            vm.pools[pool_name] = new_state
            alloc.rebuild(new_state)
            if victims:
                # surviving frames of this pool are fair game as destinations
                self._place(data, codes, victims, exclude={})
            info["migrated"] = len(victims)
        else:                       # downgrade: capacity reclaimed
            weak = cream_protection(state.layout)
            victims = []
            for phys in range(old, new_boundary):
                if phys in alloc.owner:
                    tenant, vpn = alloc.owner[phys]
                    pte = vm.tenants[tenant].entries[vpn]
                    if not at_least(weak, pte.reliability):
                        victims.append((tenant, vpn, pte))
            data = codes = None
            if victims:
                data, codes = self._read_frames(
                    state, [pte.phys for _, _, pte in victims])
                for _, _, pte in victims:
                    del alloc.owner[pte.phys]
            new_state, _ = state.move_boundary(new_boundary)
            vm.pools[pool_name] = new_state
            alloc.rebuild(new_state)
            if victims:
                # the surrendered span is now weak-class: excluded by the
                # reliability check in peek(), nothing extra to mask
                self._place(data, None, victims, exclude={})
            info["migrated"] = len(victims)

        info["to_host"] = self.stats.to_host - host_before
        self.stats.transactions += 1
        self.stats.seconds += time.perf_counter() - t0
        obs_metrics.record_pool_capacity(pool_name, vm.pools[pool_name])
        return info

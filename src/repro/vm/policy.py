"""Policy bridge: the scrub → monitor → recommend loop, acting on the VM.

:class:`repro.core.regions.RegionManager` closes the paper's §3.3 loop by
repartitioning raw pools and *dropping* the evicted extra pages on the
owner's lap. :class:`VMPolicy` closes the same loop one layer up: the
recommendation is realised as a VM transaction
(:meth:`~repro.vm.migration.MigrationEngine.repartition_with_migration`)
so every mapped page survives the boundary move.

A pool's realisable protection levels are its CREAM layout's class
(boundary = R: NONE for InterWrap/rank-subset/packed, PARITY for the parity
layout) and SECDED (boundary = 0). Monitor recommendations in between (e.g.
PARITY for an InterWrap pool) are snapped in the direction of the
recommendation — upgrades round up to SECDED, downgrades round down to the
layout's class — so the loop never under-protects relative to the monitor.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.monitor import ErrorMonitor, MonitorConfig
from repro.core.pool import PoolLike
from repro.core.protection import _ORDER, Protection, at_least, stronger
from repro.core.scrubber import ScrubStats
from repro.vm.address_space import (VirtualMemory, cream_protection,
                                    frame_class)
from repro.vm.migration import MigrationEngine


def pool_protection(state: PoolLike) -> Protection:
    """The protection level a pool currently *guarantees* (its weakest part)."""
    if state.boundary == 0:
        return Protection.SECDED
    return cream_protection(state.layout)


@dataclass
class PoolPolicy:
    """Per-pool knobs: how far the adaptation loop may swing the boundary."""
    floor: Protection = Protection.NONE       # weakest allowed
    ceiling: Protection = Protection.SECDED   # strongest allowed


@dataclass
class TenantSLO:
    """Per-(tenant, segment) reliability contract the campaign enforces.

    ``max_error_rate`` bounds (detected + silent) / reads as observed by
    the ground-truth shadow oracle; crossing it (after ``min_reads``
    observations, so one unlucky page can't trigger a migration storm)
    escalates the segment one protection level, up to ``ceiling``.
    """
    max_error_rate: float = 1e-3
    min_reads: int = 64
    ceiling: Protection = Protection.SECDED


class VMPolicy:
    """Owns the adaptation loop over every pool the VM manages."""

    def __init__(self, vm: VirtualMemory, engine: MigrationEngine | None = None,
                 config: MonitorConfig | None = None,
                 pool_policies: dict[str, PoolPolicy] | None = None):
        self.vm = vm
        self.engine = engine or MigrationEngine(vm)
        self.monitor = ErrorMonitor(config)
        self.pool_policies = pool_policies or {}
        self.transitions: list[tuple[str, Protection, Protection]] = []
        # per-(tenant, segment) SLOs + observed read-outcome accumulators
        self.tenant_slos: dict[tuple[str, str], TenantSLO] = {}
        self._observed: dict[tuple[str, str], list[int]] = {}
        self.escalations: list[dict] = []

    def policy_for(self, pool_name: str) -> PoolPolicy:
        return self.pool_policies.get(pool_name, PoolPolicy())

    # -- tenant reliability SLOs (the campaign's closed loop) ----------------
    def set_tenant_slo(self, tenant: str, segment: str,
                       slo: TenantSLO) -> None:
        self.tenant_slos[(tenant, segment)] = slo
        from repro.obs import slo as obs_slo
        obs_slo.TRACKER.set_tenant_slo(f"{tenant}/{segment}",
                                       slo.max_error_rate)

    def observe_reads(self, tenant: str, segment: str, reads: int,
                      corrected: int = 0, detected: int = 0,
                      silent: int = 0) -> None:
        """Fold shadow-oracle read outcomes for one tenant segment."""
        acc = self._observed.setdefault((tenant, segment), [0, 0, 0, 0])
        for i, v in enumerate((reads, corrected, detected, silent)):
            acc[i] += int(v)
        from repro.obs import slo as obs_slo
        obs_slo.TRACKER.record_tenant_reads(
            f"{tenant}/{segment}", reads, corrected=corrected,
            detected=detected, silent=silent)

    def observed_error_rate(self, tenant: str, segment: str) -> float:
        acc = self._observed.get((tenant, segment))
        if not acc or not acc[0]:
            return 0.0
        return (acc[2] + acc[3]) / acc[0]

    def escalate_tenant(self, tenant: str, segment: str,
                        target: Protection) -> dict:
        """Upgrade a segment's reliability class via zero-loss migration.

        The segment default and every PTE's contract move to ``target``
        (host-resident pages too, so a later swap-in honours it); pages on
        frames weaker than ``target`` are relocated through the existing
        migration engine — no data loss, no downtime.
        """
        space = self.vm.tenants[tenant]
        before = space.segments.get(segment, Protection.NONE)
        space.segments[segment] = target
        if target == Protection.DAEC:
            # DAEC frames exist only where a pool has carved its tier; make
            # sure there is somewhere to land before computing the move set
            # (carving may upgrade some of this segment's frames in place).
            demand = sum(1 for pte in space.entries.values()
                         if pte.segment == segment and pte.pool is not None)
            self.ensure_daec_frames(demand)
        move: list[int] = []
        for vpn, pte in space.entries.items():
            if pte.segment != segment:
                continue
            pte.reliability = target
            if pte.pool is not None and not at_least(
                    frame_class(self.vm.pools[pte.pool], pte.phys), target):
                move.append(vpn)
        moved = self.engine.relocate(tenant, move) if move else 0
        esc = {"tenant": tenant, "segment": segment, "from": before,
               "to": target, "moved": moved}
        self.escalations.append(esc)
        self._observed.pop((tenant, segment), None)   # fresh window
        return esc

    def ensure_daec_frames(self, count: int) -> int:
        """Grow pools' SEC-DAEC tiers until ``count`` free DAEC frames exist.

        Carving converts the top of a pool's SECDED span in place
        (``set_daec_rows`` re-encodes contents, so mapped frames simply
        *upgrade* — never a contract violation) and rebuilds the free
        lists. Best effort: returns the free-DAEC-frame count afterwards,
        which may fall short if no pool has SECDED rows left to convert.
        """
        def free_daec() -> int:
            return sum(len(a.free.get(Protection.DAEC, {}))
                       for a in self.vm.allocators.values())

        free = free_daec()
        for name, state in list(self.vm.pools.items()):
            if free >= count:
                break
            step = state.boundary_step
            avail = (state.num_rows - state.daec_rows) - state.boundary
            if avail <= 0:
                continue
            want = min(avail, -((free - count) // step) * step)
            new_state = state.set_daec_rows(state.daec_rows + want)
            self.vm.pools[name] = new_state
            self.vm.allocators[name].rebuild(new_state)
            free = free_daec()
        return free

    def auto_escalate(self) -> list[dict]:
        """Escalate every tenant segment whose observed rate crossed its SLO."""
        done = []
        for (tenant, segment), slo in list(self.tenant_slos.items()):
            acc = self._observed.get((tenant, segment))
            if not acc or acc[0] < slo.min_reads:
                continue
            rate = (acc[2] + acc[3]) / acc[0]
            if rate <= slo.max_error_rate:
                continue
            current = self.vm.tenants[tenant].segments.get(
                segment, Protection.NONE)
            target = stronger(current)
            hi = _ORDER.index(slo.ceiling)
            target = _ORDER[min(_ORDER.index(target), hi)]
            if target == current:
                # already at the ceiling: reset the window so the breach
                # is re-evaluated on fresh evidence, not compounded
                self._observed.pop((tenant, segment), None)
                continue
            done.append(self.escalate_tenant(tenant, segment, target))
        return done

    # -- the loop ------------------------------------------------------------
    def scrub_all(self, use_kernel: bool = False) -> dict[str, ScrubStats]:
        """Sweep every pool, repairing SECDED rows and feeding the monitor."""
        stats = {}
        for name in list(self.vm.pools):
            self.vm.pools[name], s = self.vm.pools[name].scrub(
                use_kernel=use_kernel)
            self.monitor.record(name, s)
            stats[name] = s
        return stats

    def adapt(self) -> list[dict]:
        """Realise monitor recommendations as repartition+migrate transactions.

        Returns the transaction infos (one per pool whose boundary moved).
        """
        performed = []
        for name, state in list(self.vm.pools.items()):
            cur = pool_protection(state)
            pp = self.policy_for(name)
            rec = self.monitor.recommend(name, cur, floor=pp.floor,
                                         ceiling=pp.ceiling)
            if rec == cur:
                continue
            weak = cream_protection(state.layout)
            if at_least(rec, cur) and rec != cur:     # upgrade
                target = rec if rec in (Protection.SECDED, weak) \
                    else Protection.SECDED
            else:                                     # downgrade
                target = rec if rec in (Protection.SECDED, weak) else weak
            if target == cur:
                continue
            new_boundary = 0 if target == Protection.SECDED \
                else state.num_rows
            info = self.engine.repartition_with_migration(name, new_boundary)
            self.monitor.acknowledge_transition(name)
            self.transitions.append((name, cur, target))
            performed.append(info)
        return performed

    def step(self, use_kernel: bool = False
             ) -> tuple[dict[str, ScrubStats], list[dict]]:
        """One full adaptation epoch: scrub → monitor → repartition+migrate."""
        stats = self.scrub_all(use_kernel=use_kernel)
        return stats, self.adapt()

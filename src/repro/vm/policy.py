"""Policy bridge: the scrub → monitor → recommend loop, acting on the VM.

:class:`repro.core.regions.RegionManager` closes the paper's §3.3 loop by
repartitioning raw pools and *dropping* the evicted extra pages on the
owner's lap. :class:`VMPolicy` closes the same loop one layer up: the
recommendation is realised as a VM transaction
(:meth:`~repro.vm.migration.MigrationEngine.repartition_with_migration`)
so every mapped page survives the boundary move.

A pool's realisable protection levels are its CREAM layout's class
(boundary = R: NONE for InterWrap/rank-subset/packed, PARITY for the parity
layout) and SECDED (boundary = 0). Monitor recommendations in between (e.g.
PARITY for an InterWrap pool) are snapped in the direction of the
recommendation — upgrades round up to SECDED, downgrades round down to the
layout's class — so the loop never under-protects relative to the monitor.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.monitor import ErrorMonitor, MonitorConfig
from repro.core.pool import PoolLike
from repro.core.protection import Protection, at_least
from repro.core.scrubber import ScrubStats
from repro.vm.address_space import VirtualMemory, cream_protection
from repro.vm.migration import MigrationEngine


def pool_protection(state: PoolLike) -> Protection:
    """The protection level a pool currently *guarantees* (its weakest part)."""
    if state.boundary == 0:
        return Protection.SECDED
    return cream_protection(state.layout)


@dataclass
class PoolPolicy:
    """Per-pool knobs: how far the adaptation loop may swing the boundary."""
    floor: Protection = Protection.NONE       # weakest allowed
    ceiling: Protection = Protection.SECDED   # strongest allowed


class VMPolicy:
    """Owns the adaptation loop over every pool the VM manages."""

    def __init__(self, vm: VirtualMemory, engine: MigrationEngine | None = None,
                 config: MonitorConfig | None = None,
                 pool_policies: dict[str, PoolPolicy] | None = None):
        self.vm = vm
        self.engine = engine or MigrationEngine(vm)
        self.monitor = ErrorMonitor(config)
        self.pool_policies = pool_policies or {}
        self.transitions: list[tuple[str, Protection, Protection]] = []

    def policy_for(self, pool_name: str) -> PoolPolicy:
        return self.pool_policies.get(pool_name, PoolPolicy())

    # -- the loop ------------------------------------------------------------
    def scrub_all(self, use_kernel: bool = False) -> dict[str, ScrubStats]:
        """Sweep every pool, repairing SECDED rows and feeding the monitor."""
        stats = {}
        for name in list(self.vm.pools):
            self.vm.pools[name], s = self.vm.pools[name].scrub(
                use_kernel=use_kernel)
            self.monitor.record(name, s)
            stats[name] = s
        return stats

    def adapt(self) -> list[dict]:
        """Realise monitor recommendations as repartition+migrate transactions.

        Returns the transaction infos (one per pool whose boundary moved).
        """
        performed = []
        for name, state in list(self.vm.pools.items()):
            cur = pool_protection(state)
            pp = self.policy_for(name)
            rec = self.monitor.recommend(name, cur, floor=pp.floor,
                                         ceiling=pp.ceiling)
            if rec == cur:
                continue
            weak = cream_protection(state.layout)
            if at_least(rec, cur) and rec != cur:     # upgrade
                target = rec if rec in (Protection.SECDED, weak) \
                    else Protection.SECDED
            else:                                     # downgrade
                target = rec if rec in (Protection.SECDED, weak) else weak
            if target == cur:
                continue
            new_boundary = 0 if target == Protection.SECDED \
                else state.num_rows
            info = self.engine.repartition_with_migration(name, new_boundary)
            self.monitor.acknowledge_transition(name)
            self.transitions.append((name, cur, target))
            performed.append(info)
        return performed

    def step(self, use_kernel: bool = False
             ) -> tuple[dict[str, ScrubStats], list[dict]]:
        """One full adaptation epoch: scrub → monitor → repartition+migrate."""
        stats = self.scrub_all(use_kernel=use_kernel)
        return stats, self.adapt()

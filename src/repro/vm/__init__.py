"""CREAM-VM — a multi-tenant virtual memory subsystem over CREAM pools.

The paper's capacity story only pays off when an OS-like layer hands the
reclaimed pages to applications and reclaims them back when protection is
upgraded (§3.3, §4.3.1). This package is that layer:

  * :mod:`repro.vm.address_space` — per-tenant page tables (virtual page id →
    (pool, physical page)), HRM-style reliability classes per tenant/segment
    (SECDED / PARITY / NONE), and a mode-aware frame allocator whose free
    lists track extra-page capacity as pool boundaries move;
  * :mod:`repro.vm.migration`     — a live migration engine that relocates
    pages across pools and protection modes (batched Pallas gather/re-encode
    via :mod:`repro.kernels.migrate`), with a host swap tier for overflow;
    its :meth:`~repro.vm.migration.MigrationEngine.repartition_with_migration`
    turns a boundary upgrade's eviction into a zero-loss relocation;
  * :mod:`repro.vm.policy`        — the bridge from the scrub → monitor →
    recommend loop (:mod:`repro.core.monitor`) to VM-level repartition +
    migrate transactions.

The serving stack (:mod:`repro.serve.kv_cache`) allocates through this layer
instead of raw pool page ids.
"""
from repro.vm.address_space import (PTE, AddressSpace, FrameAllocator,
                                    VirtualMemory, VMStats, frame_class)
from repro.vm.migration import MigrationEngine, MigrationStats
from repro.vm.policy import VMPolicy

__all__ = [
    "PTE", "AddressSpace", "FrameAllocator", "VirtualMemory", "VMStats",
    "frame_class", "MigrationEngine", "MigrationStats", "VMPolicy",
]

"""Address spaces, page tables, and the mode-aware frame allocator.

Terminology (OS analogue over the paper's hardware):

  * **frame** — one physical pool page: ``(pool_name, phys)`` where ``phys``
    follows the pool's page-id convention (regular pages ``[0, R)``, extra
    pages ``[R, R + extra)``);
  * **storage class** — the protection a frame provides *today*, derived from
    its pool's boundary register: SECDED for rows in ``[boundary, R)``, the
    CREAM layout's protection (PARITY or NONE) elsewhere. Classes shift when
    the boundary moves — the allocator's free lists are rebuilt in lockstep;
  * **reliability class** — what a tenant *requested* for a segment
    (Heterogeneous-Reliability-Memory style: per-data-region choice). A frame
    may serve a request iff its storage class is at least as strong, so a
    protection upgrade never violates a mapping while a downgrade forces the
    migration engine to relocate stricter tenants first;
  * **host swap tier** — overflow residency: page contents parked in host
    memory (``PTE.pool is None``). Reads from it are the page faults whose
    frequency the capacity mode controls.

All data-plane traffic goes through :meth:`VirtualMemory.read` /
:meth:`VirtualMemory.write`, which batch per pool through the
:class:`repro.core.pool.PoolLike` engine methods — the pre-jitted
``read_pages`` / ``write_pages`` (one ``page_coords`` gather/scatter +
masked batched codecs per pool, donation-friendly on the write side).
Pools may be single-device :class:`~repro.core.pool.PoolState`\\ s or
multi-device :class:`repro.shard.ShardedPool`\\ s — the VM never branches
on the concrete type. Page-table walks stay host-side (they are dict
lookups); everything that touches pool storage is one traced dispatch per
pool.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layouts import DEFAULT_ROW_WORDS, Layout
from repro.core.pool import PoolLike, make_pool
from repro.core.protection import _ORDER, Protection
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing


def cream_protection(layout: Layout) -> Protection:
    """Protection a CREAM-region frame provides under ``layout``."""
    if layout == Layout.BASELINE_ECC:
        return Protection.SECDED
    return Protection.PARITY if layout == Layout.PARITY else Protection.NONE


def frame_class(state: PoolLike, phys: int) -> Protection:
    """Storage class of frame ``phys`` under the pool's current boundary."""
    if state.boundary <= phys < state.num_rows:
        if phys >= state.num_rows - state.daec_rows:
            return Protection.DAEC
        return Protection.SECDED
    return cream_protection(state.layout)


@dataclass
class PTE:
    """Page-table entry: where one virtual page lives right now."""
    pool: str | None            # None -> host swap tier
    phys: int                   # physical page id, or host swap slot
    reliability: Protection     # requested class (the contract)
    segment: str = "default"


class AddressSpace:
    """Per-tenant page table + segment reliability defaults."""

    def __init__(self, tenant: str,
                 default_reliability: Protection = Protection.NONE):
        self.tenant = tenant
        self.entries: dict[int, PTE] = {}
        self.segments: dict[str, Protection] = {
            "default": default_reliability}
        self._next_vpn = 0

    def add_segment(self, name: str, reliability: Protection) -> None:
        self.segments[name] = reliability

    def new_vpn(self) -> int:
        vpn = self._next_vpn
        self._next_vpn += 1
        return vpn

    @property
    def num_pages(self) -> int:
        return len(self.entries)


class FrameAllocator:
    """Free lists over one pool's frames, keyed by storage class.

    Free lists are insertion-ordered dicts (page-id order after a rebuild)
    with a frame -> class side map, so ``claim`` is O(1) instead of a scan
    over every free frame. ``owner`` maps a mapped frame to its
    ``(tenant, vpn)`` — the reverse translation the migration engine walks
    when a boundary move dooms frames.
    """

    def __init__(self, state: PoolLike):
        self.free: dict[Protection, dict[int, None]] = {}
        self.owner: dict[int, tuple[str, int]] = {}
        self._class: dict[int, Protection] = {}
        self.rebuild(state)

    def rebuild(self, state: PoolLike) -> None:
        """Recompute free lists after a boundary move.

        Every surviving frame keeps its page id across repartitions (regular
        pages by row, extra pages by group), so ownership carries over; a
        still-owned frame that no longer exists means the caller forgot to
        migrate it first — refuse, that would silently lose data.
        """
        lost = [p for p in self.owner if p >= state.num_pages]
        if lost:
            raise RuntimeError(
                f"frames {lost} are mapped but no longer exist; "
                "relocate them before repartitioning")
        self.free = {p: {} for p in _ORDER}
        self._class = {}
        for phys in range(state.num_pages):
            if phys not in self.owner:
                cls = frame_class(state, phys)
                self.free[cls][phys] = None
                self._class[phys] = cls

    def peek(self, reliability: Protection, count: int,
             exclude: set[int] | None = None) -> list[int]:
        """Up to ``count`` free frames of class >= ``reliability`` (no pop).

        Exact class first, then stronger — over-protecting is allowed,
        under-protecting never is.
        """
        exclude = exclude or set()
        picks: list[int] = []
        for cls in _ORDER[_ORDER.index(reliability):]:
            for phys in self.free[cls]:
                if phys in exclude:
                    continue
                picks.append(phys)
                if len(picks) == count:
                    return picks
        return picks

    def claim(self, phys: int, tenant: str, vpn: int) -> None:
        cls = self._class.get(phys)
        if cls is None:
            raise KeyError(f"frame {phys} is not free")
        del self.free[cls][phys]
        del self._class[phys]
        self.owner[phys] = (tenant, vpn)

    def release(self, state: PoolLike, phys: int) -> None:
        del self.owner[phys]
        cls = frame_class(state, phys)
        self.free[cls][phys] = None
        self._class[phys] = cls

    @property
    def used(self) -> int:
        return len(self.owner)


@dataclass
class VMStats:
    """Data-plane traffic census (host reads are the page faults)."""
    device_reads: int = 0
    host_reads: int = 0
    device_writes: int = 0
    host_writes: int = 0

    @property
    def fault_rate(self) -> float:
        total = self.device_reads + self.host_reads
        return self.host_reads / total if total else 0.0


class VirtualMemory:
    """Multi-tenant virtual memory over a set of CREAM pools + host swap."""

    def __init__(self, row_words: int = DEFAULT_ROW_WORDS):
        self.row_words = row_words
        self.pools: dict[str, PoolLike] = {}
        self.allocators: dict[str, FrameAllocator] = {}
        self.tenants: dict[str, AddressSpace] = {}
        self.swap: dict[int, np.ndarray] = {}
        self._next_slot = 0
        self.stats = VMStats()

    # -- setup ---------------------------------------------------------------
    def add_pool(self, name: str, num_rows: int,
                 layout: Layout = Layout.INTERWRAP,
                 boundary: int | None = None, shards: int = 1,
                 mesh=None, daec_rows: int = 0) -> PoolLike:
        """Create a pool under VM management.

        ``shards > 1`` builds a :class:`repro.shard.ShardedPool` over a
        ``banks`` mesh (CREAM-Shard) instead of a local pool; everything
        above the pool — tenants, allocator, data plane, migration — is
        oblivious to the difference. ``daec_rows`` carves that many top
        rows of the protected region into the SEC-DAEC tier.
        """
        if name in self.pools:
            raise ValueError(f"pool {name!r} exists")
        if shards > 1 or mesh is not None:
            from repro.shard import make_sharded_pool
            state = make_sharded_pool(num_rows, layout, boundary,
                                      num_shards=shards,
                                      row_words=self.row_words, mesh=mesh,
                                      daec_rows=daec_rows)
        else:
            state = make_pool(num_rows, layout, boundary=boundary,
                              row_words=self.row_words, daec_rows=daec_rows)
        self.pools[name] = state
        self.allocators[name] = FrameAllocator(state)
        obs_metrics.record_pool_capacity(name, state)
        return state

    def adopt_pool(self, name: str, state: PoolLike) -> None:
        """Bring an existing pool under VM management (frames all free)."""
        if state.row_words != self.row_words:
            raise ValueError("row_words mismatch")
        self.pools[name] = state
        self.allocators[name] = FrameAllocator(state)
        obs_metrics.record_pool_capacity(name, state)

    def create_tenant(self, name: str,
                      default_reliability: Protection = Protection.NONE,
                      segments: dict[str, Protection] | None = None
                      ) -> AddressSpace:
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} exists")
        space = AddressSpace(name, default_reliability)
        for seg, rel in (segments or {}).items():
            space.add_segment(seg, rel)
        self.tenants[name] = space
        return space

    # -- geometry ------------------------------------------------------------
    @property
    def page_words(self) -> int:
        return 8 * self.row_words

    @property
    def page_bytes(self) -> int:
        return 4 * self.page_words

    def device_capacity_pages(self, pool: str | None = None) -> int:
        names = [pool] if pool else list(self.pools)
        return sum(self.pools[n].num_pages for n in names)

    def used_device_pages(self, pool: str | None = None) -> int:
        names = [pool] if pool else list(self.pools)
        return sum(self.allocators[n].used for n in names)

    def utilisation(self, pool: str | None = None) -> float:
        cap = self.device_capacity_pages(pool)
        return self.used_device_pages(pool) / cap if cap else 0.0

    def capacity_report(self) -> dict[str, dict]:
        out = {}
        for name, state in self.pools.items():
            alloc = self.allocators[name]
            out[name] = {
                "layout": state.layout.value,
                "rows": state.num_rows,
                "boundary": state.boundary,
                "daec_rows": state.daec_rows,
                "pages": state.num_pages,
                "extra_pages": state.num_extra_pages,
                "used": alloc.used,
                "free": {p.value: len(lst) for p, lst in alloc.free.items()},
                "gain": state.capacity_gain(),
            }
        out["host_swap_pages"] = len(self.swap)
        return out

    # -- translation ---------------------------------------------------------
    def translate(self, tenant: str, vpn: int) -> PTE:
        return self.tenants[tenant].entries[vpn]

    def effective_protection(self, tenant: str, vpn: int) -> Protection | None:
        """Storage class actually backing a page (None = host tier)."""
        pte = self.translate(tenant, vpn)
        if pte.pool is None:
            return None
        return frame_class(self.pools[pte.pool], pte.phys)

    def residency(self, tenant: str, vpns) -> str:
        tiers = {"host" if self.translate(tenant, v).pool is None else "device"
                 for v in vpns}
        return tiers.pop() if len(tiers) == 1 else "mixed"

    # -- allocation ----------------------------------------------------------
    def alloc(self, tenant: str, n: int, segment: str = "default",
              reliability: Protection | None = None,
              allow_host: bool = True, zero: bool = True,
              pool: str | None = None) -> list[int] | None:
        """Allocate ``n`` virtual pages; returns their vpns.

        Frames come from any pool with storage class >= the segment's
        reliability class (exact class preferred, then stronger); ``pool``
        restricts the search to one pool (callers like the object cache pin
        their data plane to a single pool's storage). Overflow lands in the
        host swap tier unless ``allow_host=False``, in which case the
        allocation either fits on device or returns None untouched.

        ``zero=False`` skips scrubbing the claimed device frames — only for
        callers that overwrite every page before any read (the frames may
        still hold a previous tenant's bits until then).
        """
        space = self.tenants[tenant]
        rel = reliability if reliability is not None \
            else space.segments[segment]
        picks: list[tuple[str, int]] = []
        candidates = [(pool, self.allocators[pool])] if pool is not None \
            else list(self.allocators.items())
        for pool_name, alloc in candidates:
            for phys in alloc.peek(rel, n - len(picks)):
                picks.append((pool_name, phys))
            if len(picks) == n:
                break
        if len(picks) < n and not allow_host:
            return None
        vpns = []
        for i in range(n):
            vpn = space.new_vpn()
            if i < len(picks):
                pool_name, phys = picks[i]
                self.allocators[pool_name].claim(phys, tenant, vpn)
                space.entries[vpn] = PTE(pool_name, phys, rel, segment)
            else:
                slot = self._new_slot()
                self.swap[slot] = np.zeros(self.page_words, np.uint32)
                space.entries[vpn] = PTE(None, slot, rel, segment)
            vpns.append(vpn)
        # zero the claimed device frames: a fresh mapping must never expose
        # another tenant's freed contents (host slots are zeroed above)
        if zero:
            by_pool: dict[str, list[int]] = {}
            for pool_name, phys in picks:
                by_pool.setdefault(pool_name, []).append(phys)
            for pool_name, phys_list in by_pool.items():
                self.pools[pool_name] = self.pools[pool_name].write(
                    phys_list,
                    jnp.zeros((len(phys_list), self.page_words), jnp.uint32))
        return vpns

    def free(self, tenant: str, vpns) -> None:
        space = self.tenants[tenant]
        for vpn in vpns:
            pte = space.entries.pop(vpn)
            if pte.pool is None:
                self.swap.pop(pte.phys, None)
            else:
                self.allocators[pte.pool].release(self.pools[pte.pool],
                                                  pte.phys)

    def _new_slot(self) -> int:
        slot = self._next_slot
        self._next_slot += 1
        return slot

    # -- data plane ----------------------------------------------------------
    def write(self, tenant: str, vpns, data: jax.Array | np.ndarray) -> None:
        """Write ``(n, page_words)`` uint32 through the page tables."""
        vpns = list(vpns)
        data = jnp.asarray(data, jnp.uint32).reshape(len(vpns), -1)
        if data.shape[1] != self.page_words:
            raise ValueError(f"expected (n, {self.page_words}) words")
        space = self.tenants[tenant]
        by_pool: dict[str, list[tuple[int, int]]] = {}
        host_view = None          # one D2H view for all host-resident pages
        for i, vpn in enumerate(vpns):
            pte = space.entries[vpn]
            if pte.pool is None:
                if host_view is None:
                    host_view = np.asarray(data, np.uint32)
                self.swap[pte.phys] = host_view[i].copy()
                self.stats.host_writes += 1
            else:
                by_pool.setdefault(pte.pool, []).append((i, pte.phys))
        for pool_name, items in by_pool.items():
            idx = jnp.asarray([i for i, _ in items], jnp.int32)
            # page ids stay host-side: the engine wrapper validates and
            # uploads them once (no device round-trip before dispatch)
            with obs_tracing.span("vm.write", pool=pool_name,
                                  pages=len(items)):
                self.pools[pool_name] = self.pools[pool_name].write(
                    [p for _, p in items], data[idx])
            self.stats.device_writes += len(items)
        if obs_metrics.enabled():
            device_n = sum(len(items) for items in by_pool.values())
            c = obs_metrics.counter(
                obs_metrics.NAME_VM_WRITES,
                "pages written through the VM data plane", labels=("tier",))
            if device_n:
                c.labels(tier="device").inc(device_n)
            if len(vpns) - device_n:
                c.labels(tier="host").inc(len(vpns) - device_n)

    def read(self, tenant: str, vpns) -> jax.Array:
        """Read ``(n, page_words)`` uint32 through the page tables.

        Host-resident pages are served from the swap tier (counted as
        faults in :attr:`stats`); device pages are decode-corrected batch
        gathers per pool.
        """
        vpns = list(vpns)
        n = len(vpns)
        space = self.tenants[tenant]
        out = jnp.zeros((n, self.page_words), jnp.uint32)
        by_pool: dict[str, list[tuple[int, int]]] = {}
        host_items: list[tuple[int, int]] = []
        for i, vpn in enumerate(vpns):
            pte = space.entries[vpn]
            if pte.pool is None:
                host_items.append((i, pte.phys))
                self.stats.host_reads += 1
            else:
                by_pool.setdefault(pte.pool, []).append((i, pte.phys))
        if host_items:
            # the "page fault": host -> device transfer charged here
            blob = np.stack([self.swap[slot] for _, slot in host_items])
            out = out.at[jnp.asarray([i for i, _ in host_items])].set(
                jnp.asarray(blob))
        for pool_name, items in by_pool.items():
            idx = jnp.asarray([i for i, _ in items], jnp.int32)
            with obs_tracing.span("vm.read", pool=pool_name,
                                  pages=len(items)):
                data = self.pools[pool_name].read([p for _, p in items])
            out = out.at[idx].set(data)
            self.stats.device_reads += len(items)
        if obs_metrics.enabled():
            device_n = sum(len(items) for items in by_pool.values())
            c = obs_metrics.counter(
                obs_metrics.NAME_VM_READS,
                "pages read through the VM data plane (host = faults)",
                labels=("tier",))
            if device_n:
                c.labels(tier="device").inc(device_n)
            if host_items:
                c.labels(tier="host").inc(len(host_items))
        return out

    # -- swap tier -----------------------------------------------------------
    def swap_out(self, tenant: str, vpns) -> int:
        """Demote device-resident pages to the host tier; returns count."""
        space = self.tenants[tenant]
        device = [v for v in vpns if space.entries[v].pool is not None]
        if not device:
            return 0
        data = np.asarray(self.read(tenant, device), np.uint32)
        self.stats.device_reads -= len(device)   # internal move, not traffic
        for j, vpn in enumerate(device):
            pte = space.entries[vpn]
            self.allocators[pte.pool].release(self.pools[pte.pool], pte.phys)
            slot = self._new_slot()
            self.swap[slot] = data[j].copy()
            space.entries[vpn] = PTE(None, slot, pte.reliability, pte.segment)
        return len(device)

    def swap_in(self, tenant: str, vpns) -> int:
        """Promote host-resident pages back to device frames (best effort)."""
        space = self.tenants[tenant]
        promoted = 0
        for vpn in vpns:
            pte = space.entries[vpn]
            if pte.pool is not None:
                continue
            home = None
            for pool_name, alloc in self.allocators.items():
                picks = alloc.peek(pte.reliability, 1)
                if picks:
                    home = (pool_name, picks[0])
                    break
            if home is None:
                continue
            pool_name, phys = home
            self.allocators[pool_name].claim(phys, tenant, vpn)
            blob = self.swap.pop(pte.phys)
            self.pools[pool_name] = self.pools[pool_name].write(
                [phys], jnp.asarray(blob)[None, :])
            space.entries[vpn] = PTE(pool_name, phys, pte.reliability,
                                     pte.segment)
            promoted += 1
        return promoted

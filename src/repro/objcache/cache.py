"""CREAM-Cache: the capacity-adaptive key-value object cache.

Values live in CREAM pool pages allocated through the VM; the device-side
hash index (:mod:`repro.objcache.hash_index`) resolves keys straight to
physical pages, so the batched get path is **one traced dispatch**: fused
probe + mixed-pool gather (:mod:`repro.kernels.hash`) + per-value slice.
The batched set path is one RMW transaction: a single
``read_pages_any`` gather of the touched pages, one vectorised chunk
scatter, one code-maintaining ``write_pages_any``, and one vectorised index
insert. No per-key Python loops anywhere on either hot path — host-side
work is numpy-vectorised policy bookkeeping, in the same spirit as the VM's
host-side page-table walks.

Per-item reliability classes (Heterogeneous-Reliability-Memory style): each
``set_many`` batch carries a :class:`~repro.core.protection.Protection`
class, and its chunks come from a slab whose VM pages were allocated under
that class's segment — hot/authoritative items land on SECDED frames, cold
bulk on PARITY/NONE frames (over-protection allowed, under-protection
never).

Capacity adapts live in both directions:

  * **demotion** (boundary grows): the freed weak-class frames are claimed
    by the very next slab reservation instead of forcing an eviction — the
    cache's item capacity, and therefore hit rate, rises online;
  * **upgrade** (boundary shrinks):
    :meth:`~repro.vm.migration.MigrationEngine.repartition_with_migration`
    relocates the cache's doomed frames (other pools or the host swap
    tier); :meth:`ObjCache.refresh_translation` then rebuilds the
    slot->page translation, and values parked off the home pool stay
    readable through a batched VM-read patch — a protection upgrade loses
    zero cached values.

Replacement is a 2Q approximation (probation + main queues, numpy
recency/queue arrays): new items enter probation, a re-referenced item
promotes to main, and eviction drains probation-oldest first — the same
shape as ``benchmarks.cache_sim.TwoQPageCache``, vectorised.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pool import PoolLike, PoolState
from repro.core.protection import _ORDER, Protection
from repro.kernels.hash import ops as hash_ops
from repro.objcache import hash_index as hix
from repro.objcache.hash_index import HashIndex
from repro.objcache.slab import SlabAllocator
from repro.obs import memprof as obs_memprof
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.vm.address_space import VirtualMemory


# ---------------------------------------------------------------------------
# Jitted data plane (module-level: the jit cache is shared across instances)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("max_len", "use_kernel"))
def _get_batch(state, index: HashIndex, queries: jax.Array,
               max_len: int, use_kernel: bool | None
               ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused batched get: probe + gather + per-value slice, one dispatch.

    On a local pool the probe rides the fused hash kernel
    (:mod:`repro.kernels.hash`); on a sharded pool the probe stays global
    and the resolved pages take the per-shard fused mixed read
    (``PoolLike.read_any``). Returns ``(values (n, max_len) uint32,
    lens (n,), slot (n,), found (n,))`` with not-found / beyond-length
    words zeroed.
    """
    page, off, length, slot, found = hix.lookup(index, queries)
    if isinstance(state, PoolState):
        data = hash_ops.lookup_read(
            state.storage, index.key, index.page, queries, state.layout,
            state.num_rows, state.boundary, index.probe,
            use_kernel=use_kernel)
    else:
        data = state.read(page)
    idx = jnp.minimum(off[:, None] + jnp.arange(max_len), data.shape[1] - 1)
    vals = jnp.take_along_axis(data, idx, axis=1)
    mask = (jnp.arange(max_len)[None, :] < length[:, None]) & found[:, None]
    return jnp.where(mask, vals, 0), length, slot, found


@jax.jit
def _write_values(state, upages: jax.Array, inv: jax.Array,
                  offs: jax.Array, lens: jax.Array, values: jax.Array):
    """Batched chunk write: RMW the touched pages in one gather/scatter.

    ``upages`` are unique page ids, ``inv[i]`` the row of value ``i``'s page
    within them; distinct values sharing a page scatter into disjoint chunk
    spans of the same RMW image, so nothing clobbers. Codes (SECDED/parity)
    are maintained by the pool's engine on the write-back — local or
    sharded alike (``PoolLike.read`` / ``write``).
    """
    imgs = state.read(upages)
    w = imgs.shape[1]
    span = values.shape[1]
    col = offs[:, None] + jnp.arange(span)
    col = jnp.where(jnp.arange(span)[None, :] < lens[:, None], col, w)
    imgs = imgs.at[inv[:, None], col].set(values.astype(jnp.uint32),
                                          mode="drop")
    return state.write(upages, imgs)


_find_jit = jax.jit(hix.find)
_insert_jit = jax.jit(hix.insert)
_delete_slots_jit = jax.jit(hix.delete_slots)


@dataclass
class ObjCacheStats:
    gets: int = 0
    hits: int = 0
    misses: int = 0
    host_hits: int = 0          # values served off the home pool (faults)
    sets: int = 0
    updates: int = 0
    evictions: int = 0
    rejected: int = 0           # values that could not be admitted
    get_s: float = 0.0
    set_s: float = 0.0

    @property
    def hit_rate(self) -> float:
        return self.hits / self.gets if self.gets else 0.0

    @property
    def us_per_get(self) -> float:
        return self.get_s * 1e6 / self.gets if self.gets else 0.0

    @property
    def us_per_op(self) -> float:
        ops = self.gets + self.sets + self.rejected
        return (self.get_s + self.set_s) * 1e6 / ops if ops else 0.0


class ObjCache:
    """Key-value cache over one home pool of a :class:`VirtualMemory`."""

    def __init__(self, vm: VirtualMemory, pool: str,
                 tenant: str = "objcache", index_capacity: int = 1024,
                 probe: int = 16, max_value_words: int | None = None,
                 chunk_words: tuple[int, ...] | None = None,
                 use_kernel: bool | None = None):
        if pool not in vm.pools:
            raise ValueError(f"pool {pool!r} not under VM management")
        self.vm = vm
        self.pool_name = pool
        self.tenant = tenant
        vm.create_tenant(tenant, default_reliability=Protection.NONE,
                         segments={p.value: p for p in _ORDER})
        self.index = hix.make_index(index_capacity, probe)
        self.max_value_words = int(max_value_words or vm.page_words)
        if self.max_value_words > vm.page_words:
            raise ValueError("values larger than one page are not supported")
        self.use_kernel = use_kernel
        self._chunk_words = chunk_words
        self.slabs: dict[Protection, SlabAllocator] = {}
        self.stats = ObjCacheStats()
        c = index_capacity
        # per-slot policy/translation mirrors (host-side, numpy-vectorised)
        self._vpn = np.full(c, -1, np.int64)
        self._off = np.zeros(c, np.int32)
        self._len = np.zeros(c, np.int32)
        self._cls = np.zeros(c, np.int32)
        self._relidx = np.zeros(c, np.int8)
        self._queue = np.zeros(c, np.int8)       # 0 probation, 1 main
        self._last = np.zeros(c, np.int64)
        self._live = np.zeros(c, bool)
        self._clock = 0
        # per-vpn translation mirrors (vpn -> home-pool phys page, or away)
        self._phys = np.full(64, -1, np.int64)
        self._away = np.zeros(64, bool)          # host swap or another pool

    # -- plumbing ------------------------------------------------------------
    @property
    def pool(self) -> PoolLike:
        return self.vm.pools[self.pool_name]

    @property
    def live_items(self) -> int:
        return int(self._live.sum())

    def capacity_report(self) -> dict:
        state = self.pool
        return {
            "pool_pages": state.num_pages,
            "boundary": state.boundary,
            "pages_claimed": sum(s.pages_claimed for s in self.slabs.values()),
            "live_items": self.live_items,
            "away_items": int(self._away[
                self._vpn[self._live]].sum()) if self._live.any() else 0,
        }

    def _slab(self, reliability: Protection) -> SlabAllocator:
        slab = self.slabs.get(reliability)
        if slab is None:
            slab = SlabAllocator(self.vm, self.tenant, reliability.value,
                                 reliability, self.pool_name,
                                 chunk_words=self._chunk_words)
            self.slabs[reliability] = slab
        return slab

    def _grow_vpn_mirrors(self, vmax: int) -> None:
        if vmax < len(self._phys):
            return
        new = max(vmax + 1, 2 * len(self._phys))
        phys = np.full(new, -1, np.int64)
        away = np.zeros(new, bool)
        phys[:len(self._phys)] = self._phys
        away[:len(self._away)] = self._away
        self._phys, self._away = phys, away

    def _note_vpns(self, vpns: np.ndarray) -> None:
        """Record home-pool phys ids for newly seen vpns (control plane)."""
        if not len(vpns):
            return
        self._grow_vpn_mirrors(int(vpns.max()))
        unknown = np.unique(vpns[(self._phys[vpns] < 0) & ~self._away[vpns]])
        space = self.vm.tenants[self.tenant]
        for v in unknown:                # new pages only, never keys
            pte = space.entries[int(v)]
            if pte.pool == self.pool_name:
                self._phys[v] = pte.phys
            else:
                self._away[v] = True

    @staticmethod
    def _check_keys(keys) -> np.ndarray:
        keys = np.asarray(keys, np.int64).reshape(-1)
        if keys.size and (int(keys.min()) < 0
                          or int(keys.max()) > hix.MAX_KEY):
            raise ValueError(f"keys must be in [0, {hix.MAX_KEY}]")
        return keys

    # -- policy --------------------------------------------------------------
    def _drop_slots(self, slots: np.ndarray, evicted: bool) -> None:
        slots = np.asarray(slots)
        live = slots[self._live[slots]]
        if not len(live):
            return
        for ridx in np.unique(self._relidx[live]):
            sel = live[self._relidx[live] == ridx]
            self._slab(_ORDER[int(ridx)]).release(
                self._vpn[sel], self._off[sel], self._cls[sel])
        # pad to a power of two (duplicate tombstones are idempotent) so the
        # device delete compiles a handful of shapes, not one per batch size
        pad = 1 << (len(live) - 1).bit_length()
        padded = np.concatenate([live, np.full(pad - len(live), live[0])])
        self.index = _delete_slots_jit(self.index,
                                       jnp.asarray(padded, jnp.int32))
        self._live[live] = False
        if evicted:
            self.stats.evictions += len(live)

    def _evict(self, count: int, reliability: Protection | None) -> bool:
        """Drop up to ``count`` victims: probation-oldest first, then main."""
        mask = self._live if reliability is None else \
            self._live & (self._relidx == _ORDER.index(reliability))
        cand = np.flatnonzero(mask)
        if not len(cand):
            return False
        order = np.lexsort((self._last[cand], self._queue[cand]))
        self._drop_slots(cand[order[:count]], evicted=True)
        return True

    # -- set -----------------------------------------------------------------
    def set_many(self, keys, values, lens=None,
                 reliability: Protection = Protection.NONE) -> np.ndarray:
        """Store a batch -> (n,) bool "admitted" mask (aligned to input).

        ``values`` is ``(n, span)`` uint32 with ``span <= max_value_words``;
        ``lens`` (words, default: full span) sets each value's true length.
        Duplicate keys within a batch resolve to the last occurrence.
        Existing keys are overwritten. A batch carries one reliability class.
        """
        t0 = time.perf_counter()
        keys = self._check_keys(keys)
        n = len(keys)
        values = np.asarray(values, np.uint32)
        if values.shape[0] != n or values.ndim != 2 \
                or values.shape[1] > self.max_value_words:
            raise ValueError(
                f"values must be (n, <= {self.max_value_words}) words")
        lens = np.full(n, values.shape[1], np.int32) if lens is None \
            else np.asarray(lens, np.int32)
        if lens.size and (int(lens.min()) < 1
                          or int(lens.max()) > values.shape[1]):
            raise ValueError("lens must be in [1, values.shape[1]]")
        # keep the LAST occurrence of each duplicated key
        _, ridx = np.unique(keys[::-1], return_index=True)
        take = np.sort(n - 1 - ridx)
        before = (self.stats.sets, self.stats.rejected, self.stats.evictions)
        with obs_tracing.span("objcache.set", n=n,
                              cls=reliability.value):
            ok_u = self._set_unique(keys[take], values[take], lens[take],
                                    reliability)
        order = np.argsort(keys[take], kind="stable")
        stored = ok_u[order][np.searchsorted(keys[take][order], keys)]
        self.stats.set_s += time.perf_counter() - t0
        if obs_metrics.enabled():
            c = obs_metrics.counter(
                obs_metrics.NAME_OBJCACHE_OPS,
                "object-cache operations by outcome", labels=("op",))
            for op, delta in zip(
                    ("set", "rejected", "evicted"),
                    (self.stats.sets - before[0],
                     self.stats.rejected - before[1],
                     self.stats.evictions - before[2])):
                if delta:
                    c.labels(op=op).inc(delta)
        return stored

    def _set_unique(self, keys: np.ndarray, values: np.ndarray,
                    lens: np.ndarray, reliability: Protection) -> np.ndarray:
        n = len(keys)
        if n == 0:
            return np.zeros(0, bool)
        qdev = jnp.asarray(keys.astype(np.uint32))
        # 1) overwrite semantics: retire existing versions first
        slot, found = jax.device_get(_find_jit(self.index, qdev))
        if found.any():
            self._drop_slots(slot[found], evicted=False)
            self.stats.updates += int(found.sum())
        # 2) reserve chunks; under pressure, evict this class's LRU and
        #    retry, degrading to partial admission when nothing evictable
        #    is left (a batch larger than the whole cache stores what fits)
        slab = self._slab(reliability)
        vpn = np.zeros(n, np.int64)
        off = np.zeros(n, np.int32)
        cls = np.zeros(n, np.int32)
        admitted = np.zeros(n, bool)
        while True:
            rem = np.flatnonzero(~admitted)
            v, o, c, taken = slab.reserve(lens[rem], partial=True)
            if taken.any():
                sel = rem[taken]
                vpn[sel], off[sel], cls[sel] = v[taken], o[taken], c[taken]
                admitted[sel] = True
            if admitted.all():
                break
            if not self._evict(int((~admitted).sum()), reliability):
                break
        if not admitted.any():
            self.stats.rejected += n
            return admitted
        sub = np.flatnonzero(admitted)
        self._note_vpns(vpn[sub])
        pages = np.where(admitted, self._phys[vpn], 0)
        # 3) data plane: one RMW gather + chunk scatter + coded write-back
        upages, inv = np.unique(pages[sub], return_inverse=True)
        # the fused RMW bypasses the pool wrappers: feed CREAM-Lens here
        self.pool.memprof_record("scatter", upages, stream="objcache")
        self.vm.pools[self.pool_name] = _write_values(
            self.pool, jnp.asarray(upages, jnp.int32),
            jnp.asarray(inv, jnp.int32), jnp.asarray(off[sub], jnp.int32),
            jnp.asarray(lens[sub], jnp.int32), jnp.asarray(values[sub]))
        self.vm.stats.device_writes += len(upages)
        # 4) index insert; a full probe window evicts-and-retries (rare)
        qsub = jnp.asarray(keys[sub].astype(np.uint32))
        pages_d = jnp.asarray(pages[sub], jnp.int32)
        off_d = jnp.asarray(off[sub], jnp.int32)
        lens_d = jnp.asarray(lens[sub], jnp.int32)
        self.index, slots_d, ok_d = _insert_jit(self.index, qsub, pages_d,
                                                off_d, lens_d)
        slots, ok = jax.device_get((slots_d, ok_d))
        for _ in range(3):
            if ok.all():
                break
            if not self._evict(int((~ok).sum()) * 4, None):
                break
            self.index, slots_d, ok_d = _insert_jit(self.index, qsub,
                                                    pages_d, off_d, lens_d)
            slots, ok = jax.device_get((slots_d, ok_d))
        # 5) mirrors for the admitted, chunk release for the rejected
        s = slots[ok]
        self._vpn[s] = vpn[sub][ok]
        self._off[s] = off[sub][ok]
        self._len[s] = lens[sub][ok]
        self._cls[s] = cls[sub][ok]
        self._relidx[s] = _ORDER.index(reliability)
        self._queue[s] = 0
        self._clock += 1
        self._last[s] = self._clock
        self._live[s] = True
        if not ok.all():
            bad = sub[~ok]
            slab.release(vpn[bad], off[bad], cls[bad])
        stored = np.zeros(n, bool)
        stored[sub[ok]] = True
        self.stats.rejected += n - int(stored.sum())
        self.stats.sets += int(stored.sum())
        return stored

    # -- get -----------------------------------------------------------------
    def get_many(self, keys) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batched lookup -> ``(values (n, max_value_words), lens, found)``.

        One fused probe+gather dispatch serves every device-resident value;
        values migrated off the home pool (protection upgrade overflow) are
        patched in through a single batched VM read — the cache's page
        faults.
        """
        t0 = time.perf_counter()
        keys = self._check_keys(keys)
        n = len(keys)
        if n == 0:
            return (np.zeros((0, self.max_value_words), np.uint32),
                    np.zeros(0, np.int32), np.zeros(0, bool))
        qdev = jnp.asarray(keys.astype(np.uint32))
        with obs_tracing.span("objcache.get", n=n):
            vals_d, lens_d, slot_d, found_d = _get_batch(
                self.pool, self.index, qdev, self.max_value_words,
                self.use_kernel)
        vals = np.array(vals_d, np.uint32)     # writable: host patch below
        lens, slot, found = jax.device_get((lens_d, slot_d, found_d))
        hs = slot[found]
        if len(hs):
            if obs_memprof.enabled():   # fused probe+gather bypasses wrappers
                self.pool.memprof_record("gather", self._phys[self._vpn[hs]],
                                         stream="objcache")
            # 2Q: a re-referenced item promotes probation -> main
            self._clock += 1
            self._last[hs] = self._clock
            self._queue[hs] = 1
            # patch values whose pages migrated off the home pool
            away = self._away[self._vpn[hs]]
            if away.any():
                rows = np.flatnonzero(found)[away]
                data = np.asarray(self.vm.read(
                    self.tenant, self._vpn[slot[rows]].tolist()), np.uint32)
                offs = self._off[slot[rows]]
                span = self.max_value_words
                col = np.minimum(offs[:, None] + np.arange(span),
                                 data.shape[1] - 1)
                got = np.take_along_axis(data, col, axis=1)
                mask = np.arange(span)[None, :] < self._len[slot[rows],
                                                            None]
                vals[rows] = np.where(mask, got, 0)
                self.stats.host_hits += len(rows)
        self.stats.gets += n
        self.stats.hits += int(found.sum())
        self.stats.misses += n - int(found.sum())
        self.stats.get_s += time.perf_counter() - t0
        if obs_metrics.enabled():
            c = obs_metrics.counter(
                obs_metrics.NAME_OBJCACHE_OPS,
                "object-cache operations by outcome", labels=("op",))
            c.labels(op="get").inc(n)
            if found.any():
                c.labels(op="hit").inc(int(found.sum()))
            if n - int(found.sum()):
                c.labels(op="miss").inc(n - int(found.sum()))
        return vals, lens.astype(np.int32), found

    # -- delete --------------------------------------------------------------
    def delete_many(self, keys) -> np.ndarray:
        """Batched delete -> (n,) bool "was present"."""
        keys = self._check_keys(keys)
        if not len(keys):
            return np.zeros(0, bool)
        qdev = jnp.asarray(keys.astype(np.uint32))
        slot, found = jax.device_get(_find_jit(self.index, qdev))
        self._drop_slots(slot[found], evicted=False)
        return found

    # -- the migration bridge ------------------------------------------------
    def refresh_translation(self) -> dict:
        """Rebuild slot->page translation from the VM page tables.

        Call after any repartition/migration touching the cache's frames:
        surviving frames keep serving from the fused device path, frames
        that moved to the host tier (or another pool) flip to the batched
        VM-read patch path, and their free chunks are quarantined so new
        values never land out of device reach. No cached value is lost.
        """
        space = self.vm.tenants[self.tenant]
        away_vpns = []
        if space.entries:
            self._grow_vpn_mirrors(max(space.entries))
        for vpn, pte in space.entries.items():   # pages, never keys
            if pte.pool == self.pool_name:
                self._phys[vpn] = pte.phys
                self._away[vpn] = False
            else:
                self._phys[vpn] = -1
                self._away[vpn] = True
                away_vpns.append(vpn)
        for slab in self.slabs.values():
            slab.drop_vpns(away_vpns)
        pages = np.zeros(self.index.capacity, np.int32)
        lv = np.flatnonzero(self._live)
        if len(lv):
            ph = self._phys[self._vpn[lv]]
            pages[lv] = np.where(ph >= 0, ph, 0).astype(np.int32)
        self.index = hix.replace_pages(self.index, pages)
        return {"away_pages": len(away_vpns),
                "device_pages": int((self._phys >= 0).sum())}

"""CREAM-Cache: a key-value object cache living on the CREAM data plane.

The paper's memcached experiment (Fig. 8), made real: cached values are
stored in CREAM pool pages allocated through :class:`repro.vm.VirtualMemory`,
per-item reliability classes map hot/authoritative items onto SECDED frames
and cold bulk onto PARITY/NONE frames, and the batched get/set hot path is
one traced dispatch over the mixed-pool access engine — so capacity gains,
reliability demotions, and repartition-driven migrations show up as measured
hit rate and latency on actual data-plane traffic.
"""
from repro.objcache.cache import ObjCache, ObjCacheStats
from repro.objcache.hash_index import HashIndex, make_index
from repro.objcache.slab import SlabAllocator

__all__ = ["ObjCache", "ObjCacheStats", "HashIndex", "make_index",
           "SlabAllocator"]

"""Vectorised open-addressing hash index over jnp arrays.

The index is the device-resident half of the object cache's translation: it
maps a uint32 key to the *physical pool page* (plus word offset and length)
holding its value, so the batched get path resolves keys straight against
pool storage with no host-side page-table walk. Everything here is pure
functional jnp — traced key batches compose under jit, and the probe
sequence below is the single definition shared with the fused Pallas probe
kernel (:mod:`repro.kernels.hash`), which must match it slot for slot.

Collision policy is bounded linear probing: a key lives in the first
matching slot of its ``probe``-long candidate window; lookups scan the whole
window (no early exit on empties, so tombstones need no special casing) and
inserts claim the first EMPTY/TOMB slot via a first-writer-wins scatter —
``probe`` rounds of pure vector work, never a per-key host loop.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

#: Slot-state sentinels in the key array. User keys must be < TOMB.
EMPTY = 0xFFFFFFFF
TOMB = 0xFFFFFFFE
MAX_KEY = TOMB - 1

#: Knuth's multiplicative constant (2^32 / golden ratio).
_KNUTH = 2654435761


def hash_u32(keys: jax.Array) -> jax.Array:
    """Multiplicative hash with an xor-shift finaliser (uint32 -> uint32)."""
    k = keys.astype(jnp.uint32) * jnp.uint32(_KNUTH)
    return k ^ (k >> 16)


def probe_slots(queries: jax.Array, capacity: int, probe: int) -> jax.Array:
    """(n,) keys -> (n, probe) int32 candidate slots (linear window, mod C)."""
    h = hash_u32(queries) % jnp.uint32(capacity)
    r = jnp.arange(probe, dtype=jnp.uint32)
    return ((h[:, None] + r[None, :]) % jnp.uint32(capacity)).astype(jnp.int32)


@jax.tree_util.register_dataclass
@dataclass
class HashIndex:
    """Functional index state. ``probe`` is static; arrays are the leaves."""
    key: jax.Array        # (C,) uint32 — stored key, or EMPTY / TOMB
    page: jax.Array       # (C,) int32  — physical pool page of the value
    off: jax.Array        # (C,) int32  — word offset within the page
    length: jax.Array     # (C,) int32  — value length in words
    probe: int = dataclasses.field(metadata=dict(static=True))

    @property
    def capacity(self) -> int:
        return self.key.shape[0]

    @property
    def live(self) -> jax.Array:
        return self.key < jnp.uint32(TOMB)


def make_index(capacity: int, probe: int = 16) -> HashIndex:
    """Create an empty index. ``probe`` bounds the displacement of any key."""
    if probe < 1 or probe > capacity:
        raise ValueError(f"bad probe window {probe} for capacity {capacity}")
    return HashIndex(
        key=jnp.full((capacity,), EMPTY, jnp.uint32),
        page=jnp.zeros((capacity,), jnp.int32),
        off=jnp.zeros((capacity,), jnp.int32),
        length=jnp.zeros((capacity,), jnp.int32),
        probe=probe)


def find(index: HashIndex, queries: jax.Array
         ) -> tuple[jax.Array, jax.Array]:
    """Batched probe: (n,) keys -> (slot (n,) int32, found (n,) bool).

    ``slot[i] == capacity`` when absent. One gather over the whole candidate
    window per key; fully traceable.
    """
    c = index.capacity
    q = queries.astype(jnp.uint32)
    cand = probe_slots(q, c, index.probe)               # (n, P)
    hit = index.key[cand] == q[:, None]
    first = jnp.argmax(hit, axis=1)
    found = jnp.any(hit, axis=1)
    slot = jnp.take_along_axis(cand, first[:, None], axis=1)[:, 0]
    return jnp.where(found, slot, c).astype(jnp.int32), found


def lookup(index: HashIndex, queries: jax.Array
           ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Resolve keys -> ``(page, off, length, slot, found)``, all (n,).

    Values for absent keys are zeroed (page 0 / off 0 / length 0) — callers
    mask on ``found``.
    """
    slot, found = find(index, queries)
    cs = jnp.minimum(slot, index.capacity - 1)
    page = jnp.where(found, index.page[cs], 0)
    off = jnp.where(found, index.off[cs], 0)
    length = jnp.where(found, index.length[cs], 0)
    return page, off, length, slot, found


def insert(index: HashIndex, queries: jax.Array, pages: jax.Array,
           offs: jax.Array, lens: jax.Array
           ) -> tuple[HashIndex, jax.Array, jax.Array]:
    """Batched insert/update -> ``(index', slot (n,), ok (n,))``.

    Present keys update their slot in place; absent keys claim the first
    EMPTY/TOMB slot of their window over ``probe`` first-writer-wins rounds
    (in-batch conflicts on a slot resolve to the lowest batch position —
    callers must deduplicate keys within a batch). ``ok[i]`` is False when
    key ``i``'s whole window is occupied by *other* live keys; the caller
    evicts and retries.
    """
    c, p = index.capacity, index.probe
    q = queries.astype(jnp.uint32)
    n = q.shape[0]
    batch = jnp.arange(n, dtype=jnp.int32)
    slot, found = find(index, q)
    placed = found
    slots = jnp.where(found, slot, c)
    key = index.key
    cand_all = probe_slots(q, c, p)                     # (n, P)
    for r in range(p):
        cand = cand_all[:, r]
        state = key[cand]
        want = (~placed) & ((state == jnp.uint32(EMPTY))
                            | (state == jnp.uint32(TOMB)))
        # first-writer-wins: lowest batch index claims a contested slot
        claim = jnp.full((c + 1,), n, jnp.int32).at[
            jnp.where(want, cand, c)].min(batch)
        win = want & (claim[cand] == batch)
        key = key.at[jnp.where(win, cand, c)].set(q, mode="drop")
        slots = jnp.where(win, cand, slots)
        placed = placed | win
    tgt = jnp.where(placed, slots, c)
    new = dataclasses.replace(
        index, key=key,
        page=index.page.at[tgt].set(pages.astype(jnp.int32), mode="drop"),
        off=index.off.at[tgt].set(offs.astype(jnp.int32), mode="drop"),
        length=index.length.at[tgt].set(lens.astype(jnp.int32), mode="drop"))
    return new, slots.astype(jnp.int32), placed


def delete(index: HashIndex, queries: jax.Array
           ) -> tuple[HashIndex, jax.Array]:
    """Batched delete -> ``(index', found (n,))``. Slots become tombstones."""
    slot, found = find(index, queries)
    tgt = jnp.where(found, slot, index.capacity)
    key = index.key.at[tgt].set(jnp.uint32(TOMB), mode="drop")
    return dataclasses.replace(index, key=key), found


def delete_slots(index: HashIndex, slots: jax.Array) -> HashIndex:
    """Tombstone concrete slot ids (the eviction path — no probe needed)."""
    key = index.key.at[slots].set(jnp.uint32(TOMB), mode="drop")
    return dataclasses.replace(index, key=key)


def replace_pages(index: HashIndex, pages: jax.Array) -> HashIndex:
    """Swap in a rebuilt slot->page translation (post-migration refresh)."""
    return dataclasses.replace(index,
                               page=jnp.asarray(pages, jnp.int32))

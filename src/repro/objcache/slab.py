"""Slab/extent allocator: variable-size values onto VM pages.

One allocator instance manages the chunks of a single reliability class:
its pages are allocated from the VM under that class's segment (so the
frames' storage class honours the contract), each page is cut into
fixed-size chunks of one size class, and a value occupies the smallest
chunk that fits it. The control plane is vectorised numpy — free lists are
LIFO arrays popped/pushed a batch at a time, never one chunk per Python
iteration — and growing is on-demand: when a reservation outruns the free
chunks, pages are claimed from the VM (``allow_host=False``; capacity the
VM cannot provide surfaces as a failed reservation the cache answers with
eviction). That on-demand growth *is* the live-capacity bridge: a protection
demotion frees weaker-class frames, the very next reservation claims them,
and the cache's effective capacity (and hit rate) rises online.

Pages whose frames migrate to the host swap tier (a protection upgrade
shrank the pool) are quarantined via :meth:`SlabAllocator.drop_vpns`: their
free chunks leave the lists so new values never land somewhere the batched
device get path cannot reach. Fully-free pages are not returned to the VM
(slab pages are sticky, as in memcached); ``drop_vpns`` is the one exception.
"""
from __future__ import annotations

import numpy as np

from repro.core.protection import Protection
from repro.vm.address_space import VirtualMemory


def default_chunk_words(page_words: int) -> tuple[int, ...]:
    """Size classes: powers of two from an eighth of a page up to a page."""
    return (page_words // 8, page_words // 4, page_words // 2, page_words)


class SlabAllocator:
    """Chunked value storage of one reliability class over VM pages."""

    def __init__(self, vm: VirtualMemory, tenant: str, segment: str,
                 reliability: Protection, pool: str,
                 chunk_words: tuple[int, ...] | None = None):
        self.vm = vm
        self.tenant = tenant
        self.segment = segment
        self.reliability = reliability
        self.pool = pool
        pw = vm.page_words
        self.chunk_words = tuple(chunk_words or default_chunk_words(pw))
        if any(pw % c for c in self.chunk_words):
            raise ValueError(f"chunk sizes {self.chunk_words} must divide "
                             f"the page ({pw} words)")
        ncls = len(self.chunk_words)
        self._free_vpn = [np.zeros(0, np.int64) for _ in range(ncls)]
        self._free_off = [np.zeros(0, np.int32) for _ in range(ncls)]
        self.vpns: set[int] = set()          # every page this slab owns
        self.pages_claimed = 0

    # -- geometry ------------------------------------------------------------
    def size_class(self, lens: np.ndarray) -> np.ndarray:
        """(n,) value lengths (words) -> (n,) smallest fitting class index."""
        lens = np.asarray(lens)
        if lens.size and int(lens.max()) > self.chunk_words[-1]:
            raise ValueError(
                f"value of {int(lens.max())} words exceeds the largest "
                f"chunk ({self.chunk_words[-1]} words)")
        if lens.size and int(lens.min()) < 1:
            raise ValueError("values must be at least one word long")
        return np.searchsorted(np.asarray(self.chunk_words), lens,
                               side="left").astype(np.int32)

    def free_chunks(self, cls: int) -> int:
        return len(self._free_vpn[cls])

    # -- grow ----------------------------------------------------------------
    def _grow(self, cls: int, n_chunks: int) -> int:
        """Claim VM pages and cut them into class-``cls`` chunks; returns the
        number of chunks actually added (the VM may be short on frames)."""
        chunk = self.chunk_words[cls]
        per_page = self.vm.page_words // chunk
        want_pages = -(-n_chunks // per_page)
        avail = len(self.vm.allocators[self.pool].peek(self.reliability,
                                                       want_pages))
        pages = min(want_pages, avail)
        if pages == 0:
            return 0
        # zero=False: chunks are always fully written before first read
        vpns = self.vm.alloc(self.tenant, pages, segment=self.segment,
                             allow_host=False, zero=False, pool=self.pool)
        if vpns is None:
            return 0
        self.vpns.update(vpns)
        self.pages_claimed += pages
        offs = np.arange(per_page, dtype=np.int32) * chunk
        self._free_vpn[cls] = np.concatenate(
            [self._free_vpn[cls], np.repeat(np.asarray(vpns, np.int64),
                                            per_page)])
        self._free_off[cls] = np.concatenate(
            [self._free_off[cls], np.tile(offs, pages)])
        return pages * per_page

    # -- reserve / release ---------------------------------------------------
    def reserve(self, lens: np.ndarray, partial: bool = False
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Reserve one chunk per value -> ``(vpn, off, cls, taken)``.

        Grows from the VM on shortfall. With ``partial=False`` the
        reservation is atomic: when the VM cannot provide enough frames,
        nothing is taken (``taken`` all False) — the caller evicts and
        retries. With ``partial=True`` whatever fits is taken, earliest
        values first within each size class.
        """
        cls = self.size_class(lens)
        n = len(cls)
        counts = np.bincount(cls, minlength=len(self.chunk_words))
        short_somewhere = False
        for c, need in enumerate(counts):
            short = int(need) - self.free_chunks(c)
            if short > 0:
                self._grow(c, short)
            if self.free_chunks(c) < int(need):
                short_somewhere = True
        vpn = np.zeros(n, np.int64)
        off = np.zeros(n, np.int32)
        taken = np.zeros(n, bool)
        if short_somewhere and not partial:
            return vpn, off, cls, taken
        for c in range(len(self.chunk_words)):     # ~4 classes, not n keys
            idxs = np.flatnonzero(cls == c)
            k = min(len(idxs), self.free_chunks(c))
            if not k:
                continue
            sel = idxs[:k]
            vpn[sel] = self._free_vpn[c][-k:]
            off[sel] = self._free_off[c][-k:]
            self._free_vpn[c] = self._free_vpn[c][:-k]
            self._free_off[c] = self._free_off[c][:-k]
            taken[sel] = True
        return vpn, off, cls, taken

    def release(self, vpn: np.ndarray, off: np.ndarray, cls: np.ndarray
                ) -> None:
        """Return chunks to their free lists (batched push)."""
        vpn, off, cls = (np.asarray(vpn, np.int64), np.asarray(off, np.int32),
                        np.asarray(cls))
        for c in range(len(self.chunk_words)):
            sel = cls == c
            if not sel.any():
                continue
            keep = np.isin(vpn[sel], np.fromiter(self.vpns, np.int64,
                                                 len(self.vpns)))
            self._free_vpn[c] = np.concatenate([self._free_vpn[c],
                                                vpn[sel][keep]])
            self._free_off[c] = np.concatenate([self._free_off[c],
                                                off[sel][keep]])

    def drop_vpns(self, vpns) -> None:
        """Quarantine pages (e.g. migrated to host swap): purge their free
        chunks and forget them, so no new value lands out of device reach."""
        gone = set(int(v) for v in vpns) & self.vpns
        if not gone:
            return
        self.vpns -= gone
        garr = np.fromiter(gone, np.int64, len(gone))
        for c in range(len(self.chunk_words)):
            keep = ~np.isin(self._free_vpn[c], garr)
            self._free_vpn[c] = self._free_vpn[c][keep]
            self._free_off[c] = self._free_off[c][keep]

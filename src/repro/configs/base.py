"""Model/run configuration dataclasses and the input-shape registry."""
from __future__ import annotations

import enum
from dataclasses import dataclass, replace

import jax.numpy as jnp


class BlockKind(enum.Enum):
    ATTN = "attn"
    MAMBA = "mamba"
    MLSTM = "mlstm"
    SLSTM = "slstm"


class MixerKind(enum.Enum):
    MLP = "mlp"      # dense SwiGLU
    MOE = "moe"      # top-k mixture of experts
    NONE = "none"    # block has no separate channel mixer (xLSTM)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    qk_norm: bool = False
    mlp_variant: str = "swiglu"        # swiglu (llama-family) | gelu (bigcode)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # Layer pattern: one period of (block, mixer) pairs, tiled depth/period
    # times and scanned. Homogeneous transformers use a period of 1.
    pattern: tuple[tuple[BlockKind, MixerKind], ...] = (
        (BlockKind.ATTN, MixerKind.MLP),)
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # Mamba
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int | None = None
    # Long context: sub-quadratic support (SSM/hybrid archs); pure
    # full-attention archs must skip the long_500k shape (DESIGN.md §4).
    subquadratic: bool = False
    # Modality frontend stub: 'token' (LM) | 'frame' (audio) | 'patch' (vlm).
    # Non-token frontends are STUBS per the assignment: input_specs() hands
    # the backbone precomputed token ids in the modality vocab.
    frontend: str = "token"
    dtype: str = "bfloat16"

    # -- derived -------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def num_stages(self) -> int:
        assert self.num_layers % self.period == 0, \
            f"{self.name}: layers {self.num_layers} % period {self.period}"
        return self.num_layers // self.period

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.ssm_dt_rank or max(16, self.d_model // 16)

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Total parameters (counted exactly from the layer shapes)."""
        from repro.models.model import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        from repro.models.model import count_params
        return count_params(self, active_only=True)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        period = self.period
        n_layers = max(period, 2 if period == 1 else period)
        return replace(
            self,
            name=self.name + "-smoke",
            num_layers=n_layers,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(4, self.num_kv_heads)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            moe_d_ff=64 if self.num_experts else 0,
            ssm_state_dim=8,
            ssm_dt_rank=8,
            dtype="float32",
        )


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # 'train' | 'prefill' | 'decode'


# The assigned LM shape set (same four for every arch; long_500k applies
# only to sub-quadratic archs).
SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    if shape.name == "long_500k":
        return cfg.subquadratic
    return True


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    microbatch: int | None = None          # gradient accumulation
    remat: str = "none"                    # none | block | full
    seed: int = 0
    # CREAM integration
    protect_opt_state: bool = True         # SECDED pool for optimizer moments
    scrub_every: int = 50
    checkpoint_every: int = 200
    # distributed-optimization tricks
    grad_compression: str = "none"         # none | int8 | topk
    zero_sharding: bool = True             # shard opt state over 'data'

"""chameleon-34b — early-fusion VLM over VQ image tokens [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 (text + VQ image
codes in one vocabulary). QK-norm per the paper's stability recipe. The
image tokenizer frontend is a STUB per the assignment: input_specs() feeds
precomputed token ids (early fusion makes the backbone token-uniform).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    frontend="patch",
)

"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304. xLSTM[7:1]: seven mLSTM
blocks per sLSTM block; no separate FFN (d_ff=0 — blocks carry their own
2x up-projection). Recurrent O(1) state -> runs long_500k.
"""
from repro.configs.base import BlockKind, MixerKind, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pattern=((BlockKind.MLSTM, MixerKind.NONE),) * 7
            + ((BlockKind.SLSTM, MixerKind.NONE),),
    subquadratic=True,
)

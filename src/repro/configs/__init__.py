"""Assigned architecture configs + shape registry."""
from repro.configs.base import (SHAPES, BlockKind, InputShape, MixerKind,
                                ModelConfig, TrainConfig, shape_applicable)
from repro.configs.registry import ARCH_IDS, get_config, get_shape, iter_cells

__all__ = ["SHAPES", "BlockKind", "InputShape", "MixerKind", "ModelConfig",
           "TrainConfig", "shape_applicable", "ARCH_IDS", "get_config",
           "get_shape", "iter_cells"]

"""qwen3-0.6b — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936; head_dim=128
(Qwen3 heads are wider than d_model/num_heads).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=3072,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    tie_embeddings=True,
)

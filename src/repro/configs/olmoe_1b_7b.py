"""olmoe-1b-7b — 64 experts top-8 [arXiv:2409.02060; hf].

16L d_model=2048 16H (kv=16) per-expert d_ff=1024 vocab=50304,
MoE 64e top-8; QK-norm per the OLMoE recipe. ~7B total, ~1B active.
"""
from repro.configs.base import BlockKind, MixerKind, ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    qk_norm=True,
    pattern=((BlockKind.ATTN, MixerKind.MOE),),
    num_experts=64,
    experts_per_token=8,
    moe_d_ff=1024,
)

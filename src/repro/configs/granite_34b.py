"""granite-34b — llama-arch code model, MQA [arXiv:2405.04324; hf].

88L d_model=6144 48H (GQA kv=1 — multi-query) d_ff=24576 vocab=49152.
The kv=1 cache is tiny; CREAM's capacity win for this arch concentrates in
the optimizer-state pool (DESIGN.md SS4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    mlp_variant="gelu",
)

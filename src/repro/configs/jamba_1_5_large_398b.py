"""jamba-1.5-large-398b — Mamba+attention 1:7 interleave, MoE [arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Period-8 super-block: 1 attention + 7 Mamba layers; MoE replaces the MLP on
every other layer (4 MoE / 4 dense-MLP per period). SSM state is O(1) ->
runs long_500k (the 9 attention layers use a sequence-sharded KV cache).
"""
from repro.configs.base import BlockKind, MixerKind, ModelConfig

_PERIOD = (
    (BlockKind.ATTN, MixerKind.MOE),
    (BlockKind.MAMBA, MixerKind.MLP),
    (BlockKind.MAMBA, MixerKind.MOE),
    (BlockKind.MAMBA, MixerKind.MLP),
    (BlockKind.MAMBA, MixerKind.MOE),
    (BlockKind.MAMBA, MixerKind.MLP),
    (BlockKind.MAMBA, MixerKind.MOE),
    (BlockKind.MAMBA, MixerKind.MLP),
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    pattern=_PERIOD,
    num_experts=16,
    experts_per_token=2,
    moe_d_ff=24576,
    ssm_state_dim=16,
    ssm_expand=2,
    subquadratic=True,
)

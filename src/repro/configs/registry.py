"""Architecture registry: ``--arch <id>`` lookup for all assigned configs."""
from __future__ import annotations

import importlib

from repro.configs.base import (SHAPES, InputShape, ModelConfig,
                                shape_applicable)

_MODULES = {
    "xlstm-1.3b": "xlstm_1_3b",
    "chameleon-34b": "chameleon_34b",
    "qwen3-0.6b": "qwen3_0_6b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "starcoder2-7b": "starcoder2_7b",
    "granite-34b": "granite_34b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "musicgen-large": "musicgen_large",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {', '.join(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_shape(name: str) -> InputShape:
    return SHAPES[name]


def iter_cells(include_inapplicable: bool = False):
    """Yield every assigned (arch, shape) dry-run cell."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if include_inapplicable or shape_applicable(cfg, shape):
                yield cfg, shape

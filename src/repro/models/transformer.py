"""Decoder-LM assembly: scan-over-stages with heterogeneous block patterns.

A config's ``pattern`` is one *period* of (block, mixer) pairs — e.g. jamba's
(attn, mamba×7) with interleaved MoE — and the model is ``num_layers/period``
repetitions. Parameters for each pattern position are stacked across
repetitions on a leading axis and the depth loop is a single ``lax.scan``
(compile time stays flat in depth — essential at 512 devices), with the
period unrolled inside the scan body.

Three entry points:
  * :func:`forward`       — full-sequence activations (train / prefill)
  * :func:`prefill`       — forward + extraction of every block's decode state
  * :func:`decode_step`   — one token against stacked decode states
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import BlockKind, MixerKind, ModelConfig
from repro.distributed.sharding import constraint
from repro.models import attention, moe, ssm, xlstm
from repro.models.common import (apply_embed, apply_lm_head, apply_mlp,
                                 cross_entropy, init_embed, init_lm_head,
                                 init_mlp, init_rms, rms_norm)

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(key, kind: BlockKind, cfg: ModelConfig, dtype) -> Params:
    if kind == BlockKind.ATTN:
        return attention.init_attn(key, cfg, dtype)
    if kind == BlockKind.MAMBA:
        return ssm.init_ssm(key, cfg, dtype)
    if kind == BlockKind.MLSTM:
        return xlstm.init_mlstm(key, cfg, dtype)
    if kind == BlockKind.SLSTM:
        return xlstm.init_slstm(key, cfg, dtype)
    raise ValueError(kind)


def _init_mixer(key, kind: MixerKind, cfg: ModelConfig, dtype) -> Params | None:
    if kind == MixerKind.MLP:
        return init_mlp(key, cfg.d_model, cfg.d_ff, dtype,
                        variant=cfg.mlp_variant)
    if kind == MixerKind.MOE:
        return moe.init_moe(key, cfg, dtype)
    return None


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = cfg.activation_dtype
    k_embed, k_head, k_stages = jax.random.split(key, 3)
    params: Params = {
        "embed": init_embed(k_embed, cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_rms(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_lm_head(k_head, cfg.d_model, cfg.vocab_size,
                                         dtype)

    def init_stage(key):
        stage: Params = {}
        pos_keys = jax.random.split(key, cfg.period)
        for i, (bk, mk) in enumerate(cfg.pattern):
            kb, km = jax.random.split(pos_keys[i])
            entry: Params = {
                "norm1": init_rms(cfg.d_model),
                "block": _init_block(kb, bk, cfg, dtype),
            }
            mixer = _init_mixer(km, mk, cfg, dtype)
            if mixer is not None:
                entry["norm2"] = init_rms(cfg.d_model)
                entry["mixer"] = mixer
            stage[f"pos{i}"] = entry
        return stage

    stage_keys = jax.random.split(k_stages, cfg.num_stages)
    params["stages"] = jax.vmap(init_stage)(stage_keys)
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _apply_block(bp: Params, kind: BlockKind, cfg: ModelConfig, x: jax.Array,
                 attn_impl: str) -> jax.Array:
    if kind == BlockKind.ATTN:
        return attention.apply_attn(bp, cfg, x, impl=attn_impl)
    if kind == BlockKind.MAMBA:
        return ssm.apply_ssm(bp, cfg, x)
    if kind == BlockKind.MLSTM:
        return xlstm.apply_mlstm(bp, cfg, x)
    if kind == BlockKind.SLSTM:
        return xlstm.apply_slstm(bp, cfg, x)[0]
    raise ValueError(kind)


def _stage_fn(cfg: ModelConfig, attn_impl: str, carry, stage_params):
    x, aux = carry
    for i, (bk, mk) in enumerate(cfg.pattern):
        entry = stage_params[f"pos{i}"]
        h = rms_norm(x, entry["norm1"], cfg.norm_eps)
        x = x + _apply_block(entry["block"], bk, cfg, h, attn_impl)
        if mk != MixerKind.NONE:
            h2 = rms_norm(x, entry["norm2"], cfg.norm_eps)
            if mk == MixerKind.MLP:
                x = x + apply_mlp(entry["mixer"], h2)
            else:
                y, a = moe.apply_moe(entry["mixer"], cfg, h2)
                x = x + y
                aux = aux + a
        x = constraint(x, "data", None, None)
    return (x, aux), None


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array,
            attn_impl: str = "xla", remat: str = "none",
            logits_mode: str = "all") -> tuple[jax.Array, jax.Array]:
    """tokens (B, S) int32 -> (logits, moe aux loss).

    ``logits_mode='all'`` gives (B, S, V) (training); ``'last'`` gives (B, V)
    for the final position only (inference prefill — avoids materialising a
    seq-length vocab tensor).
    """
    x = apply_embed(params["embed"], tokens)
    x = constraint(x, "data", None, None)
    aux = jnp.zeros((), jnp.float32)

    stage = functools.partial(_stage_fn, cfg, attn_impl)
    if remat in ("block", "full"):
        stage = jax.checkpoint(stage)
    (x, aux), _ = jax.lax.scan(stage, (x, aux), params["stages"])

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if logits_mode == "last":
        x = x[:, -1, :]
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
        logits = constraint(logits, "data", *(((None,) if logits_mode == "all"
                                               else ()) + ("model",)))
    else:
        logits = apply_lm_head(params["lm_head"], x)
    return logits, aux


def loss_fn(params: Params, cfg: ModelConfig, tokens: jax.Array,
            labels: jax.Array, aux_weight: float = 0.01,
            attn_impl: str = "xla", remat: str = "none") -> jax.Array:
    logits, aux = forward(params, cfg, tokens, attn_impl, remat)
    return cross_entropy(logits, labels) + aux_weight * aux


# ---------------------------------------------------------------------------
# Decode state
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """Stacked per-stage decode states, keyed like the stage params."""
    dtype = cfg.activation_dtype
    ns = cfg.num_stages

    def stack(tree):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (ns,) + a.shape).copy(), tree)

    state: Params = {"cache_len": jnp.zeros((batch,), jnp.int32)}
    for i, (bk, _) in enumerate(cfg.pattern):
        if bk == BlockKind.ATTN:
            shape = (ns, batch, max_len, cfg.num_kv_heads, cfg.head_dim_)
            state[f"pos{i}"] = {"k": jnp.zeros(shape, dtype),
                                "v": jnp.zeros(shape, dtype)}
        elif bk == BlockKind.MAMBA:
            state[f"pos{i}"] = stack(ssm.init_ssm_state(cfg, batch, dtype))
        elif bk == BlockKind.MLSTM:
            state[f"pos{i}"] = stack(xlstm.init_mlstm_state(cfg, batch))
        elif bk == BlockKind.SLSTM:
            state[f"pos{i}"] = stack(xlstm.init_slstm_state(cfg, batch))
    return state


def decode_stage(cfg: ModelConfig, sp: Params, st: Params, x: jax.Array,
                 cache_len: jax.Array) -> tuple[jax.Array, Params]:
    """One super-block of decode: (stage params, stage state, x) -> (x, st')."""
    new_st = {}
    for i, (bk, mk) in enumerate(cfg.pattern):
        entry = sp[f"pos{i}"]
        h = rms_norm(x, entry["norm1"], cfg.norm_eps)
        if bk == BlockKind.ATTN:
            kv = (st[f"pos{i}"]["k"], st[f"pos{i}"]["v"])
            y, (ck, cv) = attention.apply_attn_decode(
                entry["block"], cfg, h, kv, cache_len)
            new_st[f"pos{i}"] = {"k": ck, "v": cv}
        elif bk == BlockKind.MAMBA:
            y, s2 = ssm.apply_ssm_decode(entry["block"], cfg, h,
                                         st[f"pos{i}"])
            new_st[f"pos{i}"] = s2
        elif bk == BlockKind.MLSTM:
            y, s2 = xlstm.apply_mlstm_decode(entry["block"], cfg, h,
                                             st[f"pos{i}"])
            new_st[f"pos{i}"] = s2
        else:
            y, s2 = xlstm.apply_slstm_decode(entry["block"], cfg, h,
                                             st[f"pos{i}"])
            new_st[f"pos{i}"] = s2
        x = x + y
        if mk != MixerKind.NONE:
            h2 = rms_norm(x, entry["norm2"], cfg.norm_eps)
            if mk == MixerKind.MLP:
                x = x + apply_mlp(entry["mixer"], h2)
            else:
                y2, _ = moe.apply_moe(entry["mixer"], cfg, h2)
                x = x + y2
    return x, new_st


def decode_step(params: Params, cfg: ModelConfig, state: Params,
                tokens: jax.Array) -> tuple[jax.Array, Params]:
    """One decode step. tokens (B,) int32 -> (logits (B, V), new state)."""
    x = apply_embed(params["embed"], tokens[:, None])
    cache_len = state["cache_len"]

    def stage(carry, scanned):
        sp, st = scanned
        return decode_stage(cfg, sp, st, carry, cache_len)

    per_stage_state = {k: v for k, v in state.items() if k != "cache_len"}
    x, new_state = jax.lax.scan(stage, x, (params["stages"], per_stage_state))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = apply_lm_head(params["lm_head"], x)
    new_state["cache_len"] = cache_len + 1
    return logits[:, 0], new_state


def attn_pattern_positions(cfg: ModelConfig) -> list[int]:
    """Pattern indices whose block is attention (= has a KV cache)."""
    return [i for i, (bk, _) in enumerate(cfg.pattern)
            if bk == BlockKind.ATTN]


def num_attn_layers(cfg: ModelConfig) -> int:
    """Total attention layers = stages x attention positions per period.

    This is the leading ``n_attn`` axis of the paged-KV tensors consumed by
    :func:`decode_step_paged`; layers are ordered stage-major (stage 0's
    attention positions first, in pattern order).
    """
    return cfg.num_stages * len(attn_pattern_positions(cfg))


def decode_step_paged(params: Params, cfg: ModelConfig, state: Params,
                      tokens: jax.Array, kv: tuple[jax.Array, jax.Array]
                      ) -> tuple[jax.Array, Params,
                                 tuple[jax.Array, jax.Array]]:
    """One decode step against externally gathered paged KV (CREAM-Serve).

    ``kv`` = (k, v), each ``(n_attn, B, S_pad, Hkv, D)`` — dense views of
    every sequence's KV blocks, gathered from CREAM pool pages by the
    serving tier in ONE batched mixed-pool dispatch per step (the block
    table is the gather's index map); ``n_attn`` is stage-major (see
    :func:`num_attn_layers`). ``state`` carries only ``cache_len``: paged
    serving supports attention-only patterns, whose entire per-sequence
    state lives in pool pages (recurrent-state blocks for hybrid patterns
    are future work — we raise rather than silently keep dense state).

    Returns ``(logits (B, V), new_state, (k_new, v_new))`` where
    k_new/v_new are ``(n_attn, B, Hkv, D)`` — the one token of KV this step
    produced, for the caller to scatter into its current blocks (one
    batched pool scatter per step).
    """
    apos = attn_pattern_positions(cfg)
    if len(apos) != len(cfg.pattern):
        raise ValueError(
            f"{cfg.name}: paged decode supports attention-only patterns; "
            f"pattern has non-attention blocks at "
            f"{[i for i in range(len(cfg.pattern)) if i not in apos]}")
    x = apply_embed(params["embed"], tokens[:, None])
    cache_len = state["cache_len"]
    ns, na = cfg.num_stages, len(apos)
    k_all, v_all = kv
    k_all = k_all.reshape((ns, na) + k_all.shape[1:])
    v_all = v_all.reshape((ns, na) + v_all.shape[1:])

    def stage(carry, scanned):
        sp, ks, vs = scanned                     # ks/vs: (na, B, S_pad, h, d)
        x = carry
        news_k, news_v = [], []
        for a, i in enumerate(apos):
            entry = sp[f"pos{i}"]
            _, mk = cfg.pattern[i]
            h = rms_norm(x, entry["norm1"], cfg.norm_eps)
            y, (kn, vn) = attention.apply_attn_decode_paged(
                entry["block"], cfg, h, (ks[a], vs[a]), cache_len)
            news_k.append(kn)
            news_v.append(vn)
            x = x + y
            if mk != MixerKind.NONE:
                h2 = rms_norm(x, entry["norm2"], cfg.norm_eps)
                if mk == MixerKind.MLP:
                    x = x + apply_mlp(entry["mixer"], h2)
                else:
                    y2, _ = moe.apply_moe(entry["mixer"], cfg, h2)
                    x = x + y2
        return x, (jnp.stack(news_k), jnp.stack(news_v))

    x, (k_new, v_new) = jax.lax.scan(stage, x,
                                     (params["stages"], k_all, v_all))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = apply_lm_head(params["lm_head"], x)
    b = tokens.shape[0]
    sh = (ns * na, b) + k_new.shape[3:]
    return (logits[:, 0], {"cache_len": cache_len + 1},
            (k_new.reshape(sh), v_new.reshape(sh)))


# ---------------------------------------------------------------------------
# Prefill: forward + decode-state extraction
# ---------------------------------------------------------------------------


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
            max_len: int, attn_impl: str = "xla"
            ) -> tuple[jax.Array, Params]:
    """tokens (B, S) -> (logits (B, S, V), decode state at position S)."""
    b, s = tokens.shape
    x = apply_embed(params["embed"], tokens)
    dtype = cfg.activation_dtype

    def stage(carry, sp):
        x = carry
        st = {}
        for i, (bk, mk) in enumerate(cfg.pattern):
            entry = sp[f"pos{i}"]
            h = rms_norm(x, entry["norm1"], cfg.norm_eps)
            if bk == BlockKind.ATTN:
                y, (k, v) = attention.apply_attn(entry["block"], cfg, h,
                                                 impl=attn_impl,
                                                 return_kv=True)
                pad = [(0, 0), (0, max_len - s), (0, 0), (0, 0)]
                st[f"pos{i}"] = {"k": jnp.pad(k.astype(dtype), pad),
                                 "v": jnp.pad(v.astype(dtype), pad)}
            elif bk == BlockKind.MAMBA:
                y, s2 = ssm.apply_ssm_prefill(entry["block"], cfg, h)
                st[f"pos{i}"] = s2
            elif bk == BlockKind.MLSTM:
                y, s2 = xlstm.apply_mlstm_prefill(entry["block"], cfg, h)
                st[f"pos{i}"] = s2
            else:
                y, s2 = xlstm.apply_slstm(entry["block"], cfg, h)
                st[f"pos{i}"] = s2
            x = x + y
            if mk != MixerKind.NONE:
                h2 = rms_norm(x, entry["norm2"], cfg.norm_eps)
                if mk == MixerKind.MLP:
                    x = x + apply_mlp(entry["mixer"], h2)
                else:
                    y2, _ = moe.apply_moe(entry["mixer"], cfg, h2)
                    x = x + y2
        return x, st

    x, states = jax.lax.scan(stage, x, params["stages"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = apply_lm_head(params["lm_head"], x)
    states["cache_len"] = jnp.full((b,), s, jnp.int32)
    return logits, states

"""GQA attention (RoPE, optional qk-norm) with full-seq and decode paths.

The full-sequence path is XLA-native einsum attention by default — the dry
run derives its roofline from the compiled HLO, which custom calls would
hide — with the Pallas flash kernel selectable for TPU execution
(``impl='flash'``). The decode path works against a (externally managed)
KV cache so the serving layer can place it in a CREAM pool.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constraint
from repro.models.common import apply_rope, dense_init, init_rms, rms_norm


def init_attn(key, cfg: ModelConfig, dtype) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, hkv * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, hkv * hd), dtype=dtype),
        "wo": dense_init(ks[3], (hq * hd, d), fan_in=hq * hd, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rms(hd)
        p["k_norm"] = init_rms(hd)
    return p


def _project_qkv(p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constraint(q, "data", None, "model", None)
    k = constraint(k, "data", None, "model", None)
    v = constraint(v, "data", None, "model", None)
    return q, k, v


def _sdpa(q, k, v, causal: bool) -> jax.Array:
    """einsum attention; q (B,S,Hq,D), k/v (B,S,Hkv,D) -> (B,S,Hq,D).

    Megatron-style GQA under TP: when Hkv doesn't divide the model axis but
    Hq does (e.g. chameleon 64q/8kv on model=16), K/V are repeated to Hq
    heads *first* so every attention tensor shards cleanly over 'model' —
    otherwise GSPMD keeps K/V (and the (B,Hkv,g,S,S) logits) partially
    replicated (§Perf iteration 9).
    """
    from repro.distributed.sharding import axis_size
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    tp = axis_size("model")
    if g > 1 and hkv % tp and hq % tp == 0:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        k = constraint(k, "data", None, "model", None)
        v = constraint(v, "data", None, "model", None)
        hkv, g = hq, 1
    qg = q.reshape(b, s, hkv, g, hd)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / (hd ** 0.5)
    if causal:
        ii = jnp.arange(s)
        mask = ii[:, None] >= ii[None, :]
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, hq, hd).astype(q.dtype)


def apply_attn(p: dict, cfg: ModelConfig, x: jax.Array,
               positions: jax.Array | None = None, causal: bool = True,
               impl: str = "xla", return_kv: bool = False):
    """Full-sequence attention (train / prefill). x: (B, S, d_model)."""
    b, s, _ = x.shape
    positions = positions if positions is not None else jnp.arange(s)
    q, k, v = _project_qkv(p, cfg, x, positions)
    if impl == "flash":
        from repro.kernels.flash_attention import ops as fa
        out = fa.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                           v.transpose(0, 2, 1, 3), causal=causal)
        out = out.transpose(0, 2, 1, 3)
    else:
        out = _sdpa(q, k, v, causal)
    out = constraint(out, "data", None, "model", None)
    y = out.reshape(b, s, -1) @ p["wo"]
    y = constraint(y, "data", None, None)
    if return_kv:
        return y, (k, v)
    return y


def apply_attn_decode(p: dict, cfg: ModelConfig, x: jax.Array,
                      kv_cache: tuple[jax.Array, jax.Array],
                      cache_len: jax.Array
                      ) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """One-token decode. x: (B, 1, d_model); cache k/v: (B, S_max, Hkv, D).

    The KV cache is sharded over 'data' on S_max for long-context decode
    (sequence parallelism): each shard computes partial attention and the
    softmax combines via the standard max/denominator trick — here expressed
    as a single masked full-length attention which GSPMD partitions along k.
    """
    b = x.shape[0]
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    pos = cache_len  # (B,) current lengths
    q = (x @ p["wq"]).reshape(b, 1, hq, hd)
    k_new = (x @ p["wk"]).reshape(b, 1, hkv, hd)
    v_new = (x @ p["wv"]).reshape(b, 1, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k_new = rms_norm(k_new, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)

    ck, cv = kv_cache
    smax = ck.shape[1]
    # Sequence-parallel KV: the cache shards S_max over 'model' (both axes
    # when B == 1 — the long_500k cell). The update mask must carry the SAME
    # sharding, else SPMD "involuntarily rematerialises" (replicates!) the
    # whole cache per step — a ~400x HBM-traffic blowup measured in §Perf
    # iteration 4.
    seq_ax = ("data", "model") if b == 1 else "model"
    at_pos = (jnp.arange(smax)[None, :] == pos[:, None])  # (B, S_max)
    at_pos = constraint(at_pos, None if b == 1 else "data", seq_ax)
    ck = jnp.where(at_pos[:, :, None, None], k_new.astype(ck.dtype), ck)
    cv = jnp.where(at_pos[:, :, None, None], v_new.astype(cv.dtype), cv)
    ck = constraint(ck, None if b == 1 else "data", seq_ax, None, None)
    cv = constraint(cv, None if b == 1 else "data", seq_ax, None, None)

    g = hq // hkv
    qg = q.reshape(b, hkv, g, hd)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        ck.astype(jnp.float32)) / (hd ** 0.5)
    valid = jnp.arange(smax)[None, :] <= pos[:, None]    # (B, S_max)
    valid = constraint(valid, None if b == 1 else "data", seq_ax)
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, cv.astype(jnp.float32))
    out = out.reshape(b, 1, hq * hd).astype(x.dtype)
    y = out @ p["wo"]
    return y, (ck, cv)


def apply_attn_decode_paged(p: dict, cfg: ModelConfig, x: jax.Array,
                            kv: tuple[jax.Array, jax.Array],
                            cache_len: jax.Array
                            ) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """One-token decode against a paged-KV *view* (CREAM-Serve read path).

    ``kv`` is (k, v), each ``(B, S_pad, Hkv, D)`` — not a cache this layer
    owns, but a dense view the serving tier gathered from CREAM pool pages
    in one batched mixed-pool dispatch (the per-sequence block table is the
    gather's index map, the paged-attention pattern of
    :mod:`repro.kernels.mixed`). Unlike :func:`apply_attn_decode` the cache
    is NOT updated in place: the new token's (k, v) are inserted at
    ``cache_len`` for this attention computation only and returned as
    ``(B, Hkv, D)`` pairs so the block-table owner can scatter the updated
    block back to its pool page (one batched scatter per decode step).

    Positions at and beyond ``cache_len`` in the gathered view may hold
    arbitrary pool bytes (partially-filled or freshly-allocated blocks);
    they are masked out of the softmax here, so garbage never attends.
    """
    b = x.shape[0]
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    pos = cache_len                                    # (B,) current lengths
    q = (x @ p["wq"]).reshape(b, 1, hq, hd)
    k_new = (x @ p["wk"]).reshape(b, 1, hkv, hd)
    v_new = (x @ p["wv"]).reshape(b, 1, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k_new = rms_norm(k_new, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)

    ck, cv = kv
    smax = ck.shape[1]
    at_pos = (jnp.arange(smax)[None, :] == pos[:, None])       # (B, S_pad)
    ck = jnp.where(at_pos[:, :, None, None], k_new.astype(ck.dtype), ck)
    cv = jnp.where(at_pos[:, :, None, None], v_new.astype(cv.dtype), cv)

    g = hq // hkv
    qg = q.reshape(b, hkv, g, hd)
    valid = jnp.arange(smax)[None, :] <= pos[:, None]          # (B, S_pad)
    # pool garbage can bit-cast to NaN/Inf; a NaN value would survive the
    # softmax mask as 0 * NaN, so zero the masked positions outright
    cv = jnp.where(valid[:, :, None, None], cv, 0)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        ck.astype(jnp.float32)) / (hd ** 0.5)
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, cv.astype(jnp.float32))
    out = out.reshape(b, 1, hq * hd).astype(x.dtype)
    y = out @ p["wo"]
    return y, (k_new.reshape(b, hkv, hd), v_new.reshape(b, hkv, hd))


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_attn: int,
                  dtype) -> tuple[jax.Array, jax.Array]:
    """Stacked (n_attn_layers, B, S_max, Hkv, D) cache pair."""
    shape = (n_attn, batch, max_len, cfg.num_kv_heads, cfg.head_dim_)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)

"""Model substrate: attention, MoE, Mamba, xLSTM, and the LM assembly."""
from repro.models.model import Model, build_model, count_params

__all__ = ["Model", "build_model", "count_params"]

"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory w/ mixing).

Follows the xLSTM paper's formulations:

  * mLSTM — exponential input gate + sigmoid forget gate over a matrix
    memory C_t = f_t C_{t-1} + i_t v_t k_tᵀ. Training/prefill uses the
    *parallel* form (attention-like, with the stabilised log-gate matrix
    D_ij = exp(F_i − F_j + ĩ_j − m_i)); decode uses the O(1) recurrent form
    carrying (C, n, m). The two are verified equivalent in tests — a strong
    property check on the gating algebra.
  * sLSTM — scalar memory with per-head recurrent mixing R·h_{t-1}; inherently
    sequential, implemented as lax.scan over time (1 of every 8 layers).

Projections q/k/v are block-diagonal per head (H · dh² params), matching the
published 1.3B configuration; the cell runs at 2× up-projected width
(pf = 2) since the assigned config has d_ff = 0 (no separate FFN).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constraint
from repro.models.common import dense_init, init_rms, rms_norm

PF = 2  # mLSTM up-projection factor


def _cell_dims(cfg: ModelConfig) -> tuple[int, int]:
    dc = PF * cfg.d_model
    return dc, dc // cfg.num_heads


def _headwise(key, h: int, dh: int, dtype) -> jax.Array:
    return dense_init(key, (h, dh, dh), fan_in=dh, dtype=dtype)


def _apply_headwise(w: jax.Array, x: jax.Array) -> jax.Array:
    """x (B, S, H, dh) @ w (H, dh, dh) -> (B, S, H, dh)."""
    return jnp.einsum("bshd,hde->bshe", x, w)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    dc, dh = _cell_dims(cfg)
    ks = jax.random.split(key, 9)
    return {
        "w_up": dense_init(ks[0], (d, 2 * dc), dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (4, dc), jnp.float32) * 0.1
                   ).astype(dtype),
        "wq": _headwise(ks[2], h, dh, dtype),
        "wk": _headwise(ks[3], h, dh, dtype),
        "wv": _headwise(ks[4], h, dh, dtype),
        "wi": dense_init(ks[5], (dc, h), dtype=jnp.float32),
        "wf": dense_init(ks[6], (dc, h), dtype=jnp.float32),
        "gn": init_rms(dh),
        "w_down": dense_init(ks[7], (dc, d), fan_in=dc, dtype=dtype),
    }


def _mlstm_qkv(p: dict, cfg: ModelConfig, u: jax.Array):
    """u (B, S, dc) -> q, k, v (B, S, H, dh) + gate preacts (B, S, H)."""
    from repro.models.ssm import _causal_conv
    b, s, dc = u.shape
    h = cfg.num_heads
    dh = dc // h
    conv_u, _ = _causal_conv(u, p["conv_w"])
    conv_u = jax.nn.silu(conv_u)
    heads = conv_u.reshape(b, s, h, dh)
    q = _apply_headwise(p["wq"], heads)
    k = _apply_headwise(p["wk"], heads) / (dh ** 0.5)
    v = _apply_headwise(p["wv"], u.reshape(b, s, h, dh))
    i_pre = (u @ p["wi"]).astype(jnp.float32)            # (B, S, H)
    f_pre = (u @ p["wf"]).astype(jnp.float32)
    return q, k, v, i_pre, f_pre


def apply_mlstm(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Parallel-form mLSTM block. x: (B, S, d_model)."""
    b, s, d = x.shape
    u2 = x @ p["w_up"]
    u, z = jnp.split(u2, 2, axis=-1)                     # (B, S, dc) each
    u = constraint(u, "data", None, "model")
    q, k, v, i_pre, f_pre = _mlstm_qkv(p, cfg, u)

    log_f = jax.nn.log_sigmoid(f_pre)                    # (B, S, H)
    cum_f = jnp.cumsum(log_f, axis=1)
    # D̃_ij = F_i − F_j + ĩ_j  (j ≤ i)
    dmat = (cum_f[:, :, None, :] - cum_f[:, None, :, :]
            + i_pre[:, None, :, :])                      # (B, Si, Sj, H)
    ii = jnp.arange(s)
    causal = ii[:, None] >= ii[None, :]
    dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2, keepdims=True)             # (B, S, 1, H)
    dexp = jnp.exp(dmat - m)

    qk = jnp.einsum("bihd,bjhd->bijh", q.astype(jnp.float32),
                    k.astype(jnp.float32))
    smat = qk * dexp                                     # (B, Si, Sj, H)
    norm = jnp.sum(smat, axis=2)                         # (B, S, H)
    denom = jnp.maximum(jnp.abs(norm), jnp.exp(-m[:, :, 0, :]))
    hout = jnp.einsum("bijh,bjhd->bihd", smat, v.astype(jnp.float32))
    hout = hout / denom[..., None]

    hout = rms_norm(hout, p["gn"], cfg.norm_eps).astype(x.dtype)
    dc = u.shape[-1]
    out = hout.reshape(b, s, dc) * jax.nn.silu(z)
    out = constraint(out, "data", None, "model")
    return out @ p["w_down"]


def apply_mlstm_prefill(p: dict, cfg: ModelConfig, x: jax.Array
                        ) -> tuple[jax.Array, dict]:
    """Parallel forward + recurrent-equivalent state at position S.

    The recurrent state after S tokens unrolls to
      m_S = max_j (F_S − F_j + ĩ_j),
      C̃_S = Σ_j exp(F_S − F_j + ĩ_j − m_S) v_j k_jᵀ,   ñ_S likewise,
    which we evaluate directly from the parallel cumulative gates.
    """
    b, s, d = x.shape
    u2 = x @ p["w_up"]
    u, z = jnp.split(u2, 2, axis=-1)
    dc = u.shape[-1]
    q, k, v, i_pre, f_pre = _mlstm_qkv(p, cfg, u)

    log_f = jax.nn.log_sigmoid(f_pre)
    cum_f = jnp.cumsum(log_f, axis=1)
    # --- forward output (same math as apply_mlstm) ---
    dmat = (cum_f[:, :, None, :] - cum_f[:, None, :, :]
            + i_pre[:, None, :, :])
    ii = jnp.arange(s)
    causal = ii[:, None] >= ii[None, :]
    dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2, keepdims=True)
    dexp = jnp.exp(dmat - m)
    qk = jnp.einsum("bihd,bjhd->bijh", q.astype(jnp.float32),
                    k.astype(jnp.float32))
    smat = qk * dexp
    norm = jnp.sum(smat, axis=2)
    denom = jnp.maximum(jnp.abs(norm), jnp.exp(-m[:, :, 0, :]))
    hout = jnp.einsum("bijh,bjhd->bihd", smat, v.astype(jnp.float32))
    hout = hout / denom[..., None]
    hout = rms_norm(hout, p["gn"], cfg.norm_eps).astype(x.dtype)
    out = (hout.reshape(b, s, dc) * jax.nn.silu(z)) @ p["w_down"]

    # --- recurrent-equivalent state at S ---
    w_last = cum_f[:, -1:, :] - cum_f + i_pre            # (B, S, H)
    m_s = jnp.max(w_last, axis=1)                        # (B, H)
    wexp = jnp.exp(w_last - m_s[:, None, :])             # (B, S, H)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    c_s = jnp.einsum("bjh,bjhd,bjhe->bhde", wexp, vf, kf)
    n_s = jnp.einsum("bjh,bjhd->bhd", wexp, kf)
    conv_carry = u.astype(jnp.float32)[:, -3:, :]
    return out, {"c": c_s, "n": n_s, "m": m_s, "conv": conv_carry}


def init_mlstm_state(cfg: ModelConfig, batch: int) -> dict[str, jax.Array]:
    h = cfg.num_heads
    dc, dh = _cell_dims(cfg)
    return {
        "c": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, 3, dc), jnp.float32),
    }


def apply_mlstm_decode(p: dict, cfg: ModelConfig, x: jax.Array,
                       state: dict) -> tuple[jax.Array, dict]:
    """Recurrent mLSTM step. x: (B, 1, d_model)."""
    from repro.models.ssm import _causal_conv
    b = x.shape[0]
    h_heads = cfg.num_heads
    u2 = x @ p["w_up"]
    u, z = jnp.split(u2, 2, axis=-1)
    dc = u.shape[-1]
    dh = dc // h_heads

    conv_u, conv_carry = _causal_conv(u, p["conv_w"],
                                      state["conv"].astype(u.dtype))
    conv_u = jax.nn.silu(conv_u)
    heads = conv_u.reshape(b, 1, h_heads, dh)
    q = _apply_headwise(p["wq"], heads)[:, 0].astype(jnp.float32)
    k = (_apply_headwise(p["wk"], heads)[:, 0] / (dh ** 0.5)
         ).astype(jnp.float32)
    v = _apply_headwise(p["wv"], u.reshape(b, 1, h_heads, dh)
                        )[:, 0].astype(jnp.float32)
    i_pre = (u @ p["wi"]).astype(jnp.float32)[:, 0]      # (B, H)
    f_pre = (u @ p["wf"]).astype(jnp.float32)[:, 0]

    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + state["m"], i_pre)
    i_g = jnp.exp(i_pre - m_new)[..., None]              # (B, H, 1)
    f_g = jnp.exp(log_f + state["m"] - m_new)[..., None]
    c = f_g[..., None] * state["c"] + i_g[..., None] * \
        (v[..., :, None] * k[..., None, :])              # (B,H,dh,dh)
    n = f_g * state["n"] + i_g * k
    num = jnp.einsum("bhde,bhe->bhd", c, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)),
                      jnp.exp(-m_new))[..., None]
    hout = num / den
    hout = rms_norm(hout, p["gn"], cfg.norm_eps)[:, None].astype(x.dtype)
    out = hout.reshape(b, 1, dc) * jax.nn.silu(z)
    y = out @ p["w_down"]
    return y, {"c": c, "n": n, "m": m_new, "conv": conv_carry.astype(
        jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    ks = jax.random.split(key, 10)
    p = {"gn": init_rms(dh),
         "w_out": dense_init(ks[8], (d, d), dtype=dtype)}
    for i, g in enumerate(("z", "i", "f", "o")):
        p[f"w_{g}gate"] = dense_init(ks[i], (d, d), dtype=dtype)
        p[f"r_{g}"] = _headwise(ks[4 + i], h, dh, jnp.float32)
    return p


def _slstm_step(p: dict, cfg: ModelConfig, carry, wx):
    """One time step. wx: dict of gate preacts (B, H, dh) from W x_t."""
    c, n, h, m = carry
    h_heads = h  # (B, H, dh)

    def mix(g):
        return wx[g] + jnp.einsum("bhd,hde->bhe", h_heads, p[f"r_{g}"])

    z = jnp.tanh(mix("z"))
    o = jax.nn.sigmoid(mix("o"))
    i_pre = mix("i")
    f_pre = mix("f")
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    c_new = f_g * c + i_g * z
    n_new = jnp.maximum(f_g * n + i_g, 1e-6)
    h_new = o * c_new / n_new
    return (c_new, n_new, h_new, m_new)


def apply_slstm(p: dict, cfg: ModelConfig, x: jax.Array,
                state: dict | None = None
                ) -> tuple[jax.Array, dict]:
    """Sequential sLSTM block. x: (B, S, d_model)."""
    b, s, d = x.shape
    hh = cfg.num_heads
    dh = d // hh
    wx = {g: (x @ p[f"w_{g}gate"]).astype(jnp.float32).reshape(b, s, hh, dh)
          for g in ("z", "i", "f", "o")}
    if state is None:
        zeros = jnp.zeros((b, hh, dh), jnp.float32)
        carry = (zeros, zeros, zeros, jnp.full((b, hh), -1e30, jnp.float32
                                               )[..., None] * jnp.ones(dh))
    else:
        carry = (state["c"], state["n"], state["h"], state["m"])

    def step(carry, wx_t):
        new = _slstm_step(p, cfg, carry, wx_t)
        return new, new[2]

    wx_t = {g: jnp.moveaxis(v, 1, 0) for g, v in wx.items()}
    carry, hs = jax.lax.scan(lambda c_, w_: step(c_, w_), carry, wx_t)
    hs = jnp.moveaxis(hs, 0, 1)                          # (B, S, H, dh)
    hs = rms_norm(hs, p["gn"], cfg.norm_eps).astype(x.dtype)
    y = hs.reshape(b, s, d) @ p["w_out"]
    new_state = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
    return y, new_state


def init_slstm_state(cfg: ModelConfig, batch: int) -> dict[str, jax.Array]:
    hh = cfg.num_heads
    dh = cfg.d_model // hh
    zeros = jnp.zeros((batch, hh, dh), jnp.float32)
    return {"c": zeros, "n": zeros, "h": zeros,
            "m": jnp.full((batch, hh, dh), -1e30, jnp.float32)}


def apply_slstm_decode(p: dict, cfg: ModelConfig, x: jax.Array,
                       state: dict) -> tuple[jax.Array, dict]:
    y, new_state = apply_slstm(p, cfg, x, state)
    return y, new_state

"""Mamba (selective SSM) block — chunked parallel scan + O(1) decode.

Training/prefill uses an associative scan over time *within chunks* and a
sequential lax.scan across chunks: the (B, L, d_inner, d_state) discretised
tensors only ever materialise one chunk at a time (with remat around the
chunk body), bounding activation memory at B·CHUNK·d_inner·d_state while
keeping the cross-chunk dependency exact. Decode is the standard O(1)
recurrent update carrying (conv window, ssm state).

This is the hardware adaptation of Mamba's fused CUDA scan to TPU/XLA:
the chunk body is a pure associative_scan (lowers to log-depth compute),
and chunk boundaries are where XLA pipelines HBM traffic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constraint
from repro.models.common import dense_init

CHUNK = 256


def init_ssm(key, cfg: ModelConfig, dtype) -> dict:
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state_dim, cfg.dt_rank_
    ks = jax.random.split(key, 7)
    # S4D-real initialisation for A; dt bias for softplus ~ [1e-3, 1e-1]
    a_init = jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                                      (di, n)))
    dt = jnp.exp(jax.random.uniform(ks[0], (di,), jnp.float32) *
                 (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": dense_init(ks[1], (d, 2 * di), dtype=dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv_dim, di),
                                     jnp.float32) * 0.1).astype(dtype),
        "x_bc": dense_init(ks[3], (di, 2 * n), dtype=dtype),
        "x_dt": dense_init(ks[4], (di, r), dtype=dtype),
        "dt_proj": dense_init(ks[5], (r, di), fan_in=r, dtype=dtype),
        "dt_bias": dt_bias,
        "a_log": a_init,
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[6], (di, d), fan_in=di, dtype=dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, carry: jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over time. x (B,L,di); w (K,di).

    Returns (y, new_carry) where carry is the trailing K-1 inputs.
    """
    k = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)            # (B, L+K-1, di)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return y, xp[:, -(k - 1):, :]


def _ssm_params(p: dict, cfg: ModelConfig, xc: jax.Array):
    """Input-dependent (dt, B, C) for a chunk xc (B, L, di)."""
    n = cfg.ssm_state_dim
    bc = xc @ p["x_bc"]                                  # (B, L, 2n)
    b_in, c_out = jnp.split(bc.astype(jnp.float32), 2, axis=-1)
    dt = (xc @ p["x_dt"]) @ p["dt_proj"]                 # (B, L, di)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    return dt, b_in, c_out


def _scan_chunk(p: dict, cfg: ModelConfig, xc: jax.Array, h0: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
    """Exact selective scan over one chunk. xc (B,L,di); h0 (B,di,n)."""
    a = -jnp.exp(p["a_log"])                             # (di, n)
    dt, b_in, c_out = _ssm_params(p, cfg, xc)
    xf = xc.astype(jnp.float32)
    abar = jnp.exp(dt[..., None] * a)                    # (B,L,di,n)
    bx = (dt * xf)[..., None] * b_in[:, :, None, :]      # (B,L,di,n)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    # Fold the incoming state into the first element.
    bx = bx.at[:, 0].add(abar[:, 0] * h0)
    acc_a, acc_b = jax.lax.associative_scan(combine, (abar, bx), axis=1)
    y = jnp.einsum("bldn,bln->bld", acc_b, c_out)        # (B,L,di)
    y = y + xf * p["d_skip"]
    return y.astype(xc.dtype), acc_b[:, -1]


def apply_ssm(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Full-sequence Mamba block. x: (B, S, d_model)."""
    y, _ = apply_ssm_prefill(p, cfg, x)
    return y


def apply_ssm_prefill(p: dict, cfg: ModelConfig, x: jax.Array
                      ) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Full-sequence forward that also returns the decode state at S."""
    b, s, _ = x.shape
    di = cfg.d_inner
    xz = x @ p["in_proj"]
    xs_raw, z = jnp.split(xz, 2, axis=-1)
    xs_raw = constraint(xs_raw, "data", None, "model")
    xs, conv_carry = _causal_conv(xs_raw, p["conv_w"])
    xs = jax.nn.silu(xs)

    chunk = min(CHUNK, s)
    assert s % chunk == 0
    h0 = jnp.zeros((b, di, cfg.ssm_state_dim), jnp.float32)

    def body(h, xc):
        xc = jnp.moveaxis(xc, 0, 1)
        y, h1 = _scan_chunk(p, cfg, xc, h)
        return h1, jnp.moveaxis(y, 0, 1)

    xcs = xs.reshape(b, s // chunk, chunk, di).transpose(1, 2, 0, 3)
    h_final, ys = jax.lax.scan(jax.checkpoint(body), h0, xcs)
    y = ys.transpose(2, 0, 1, 3).reshape(b, s, di)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], {"conv": conv_carry, "h": h_final}


def init_ssm_state(cfg: ModelConfig, batch: int, dtype
                   ) -> dict[str, jax.Array]:
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_dim - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state_dim), jnp.float32),
    }


def apply_ssm_decode(p: dict, cfg: ModelConfig, x: jax.Array,
                     state: dict[str, jax.Array]
                     ) -> tuple[jax.Array, dict[str, jax.Array]]:
    """One-token decode. x: (B, 1, d_model)."""
    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, conv_carry = _causal_conv(xs, p["conv_w"], state["conv"])
    xs = jax.nn.silu(xs)

    a = -jnp.exp(p["a_log"])
    dt, b_in, c_out = _ssm_params(p, cfg, xs)
    xf = xs.astype(jnp.float32)[:, 0]                    # (B, di)
    dt0, b0, c0 = dt[:, 0], b_in[:, 0], c_out[:, 0]
    abar = jnp.exp(dt0[..., None] * a)                   # (B, di, n)
    h = abar * state["h"] + (dt0 * xf)[..., None] * b0[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, c0) + xf * p["d_skip"]
    y = (y[:, None, :].astype(x.dtype)) * jax.nn.silu(z)
    return y @ p["out_proj"], {"conv": conv_carry, "h": h}

"""Model facade: build/init/apply for any assigned architecture config."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import MixerKind, ModelConfig
from repro.models import transformer


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    forward: Callable[..., tuple[jax.Array, jax.Array]]
    loss: Callable[..., jax.Array]
    prefill: Callable[..., tuple[jax.Array, Any]]
    decode_step: Callable[..., tuple[jax.Array, Any]]
    init_decode_state: Callable[[int, int], Any]


def build_model(cfg: ModelConfig, attn_impl: str = "xla",
                remat: str = "none") -> Model:
    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_params(cfg, key),
        forward=lambda p, tokens: transformer.forward(
            p, cfg, tokens, attn_impl=attn_impl, remat=remat),
        loss=lambda p, tokens, labels: transformer.loss_fn(
            p, cfg, tokens, labels, attn_impl=attn_impl, remat=remat),
        prefill=lambda p, tokens, max_len: transformer.prefill(
            p, cfg, tokens, max_len, attn_impl=attn_impl),
        decode_step=lambda p, state, tokens: transformer.decode_step(
            p, cfg, state, tokens),
        init_decode_state=lambda batch, max_len: transformer.init_decode_state(
            cfg, batch, max_len),
    )


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count via eval_shape (no allocation)."""
    shapes = jax.eval_shape(
        lambda key: transformer.init_params(cfg, key),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))
    if not active_only or not cfg.num_experts:
        return total
    # MoE: only top-k of E experts fire per token.
    moe_layers = sum(1 for _, mk in cfg.pattern if mk == MixerKind.MOE)
    moe_layers *= cfg.num_stages
    expert_params = 3 * cfg.d_model * cfg.moe_d_ff * cfg.num_experts
    active_expert = 3 * cfg.d_model * cfg.moe_d_ff * cfg.experts_per_token
    return total - moe_layers * (expert_params - active_expert)


def model_flops_per_token(cfg: ModelConfig, active_only: bool = True) -> float:
    """The roofline's MODEL_FLOPS: 6·N per token (N = active params)."""
    n = count_params(cfg, active_only=active_only)
    return 6.0 * n

"""Top-k mixture-of-experts with capacity-based scatter dispatch (EP over GSPMD).

Dispatch avoids the classic GShard one-hot tensor — (T, E, C) is infeasible
at kimi-k2 scale (1M tokens × 384 experts) — and instead computes each
(token, choice)'s *slot* = expert·C + position-in-expert-queue directly
(cumsum over the flattened choice order), then scatter-adds token activations
into the (E·C, d) expert buffer and gathers back weighted by the router
gates. Work and memory are O(T·k·d + E·C·d) with E·C = cf·T·k — i.e. the
MoE's true *active* compute, which keeps MODEL_FLOPS/HLO_FLOPs honest in the
roofline. Experts are sharded over 'model' (EP); the scatter/gather lower to
all-to-all-style collectives under GSPMD.

Routing is f32; a Switch-style load-balance loss is returned for the trainer.
Overflow beyond capacity falls through to the residual stream.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constraint
from repro.models.common import dense_init


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, f), fan_in=d, dtype=dtype),
        "w_up": dense_init(ks[2], (e, d, f), fan_in=d, dtype=dtype),
        "w_down": dense_init(ks[3], (e, f, d), fan_in=f, dtype=dtype),
    }


def moe_capacity(cfg: ModelConfig, tokens: int) -> int:
    cap = int(cfg.capacity_factor * tokens * cfg.experts_per_token
              / cfg.num_experts)
    return max(1, min(cap, tokens))


def apply_moe(p: dict, cfg: ModelConfig, x: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    NOTE (§Perf iteration 3, refuted): a GShard-style *grouped* dispatch
    (groups over 'data' × experts over 'model', per-group capacity) was
    tried to eliminate dispatch resharding; under pure-GSPMD lowering the
    per-group scatter/take_along_axis compiled to ~5x MORE collective and
    ~3x more HBM traffic than this flat formulation (grouping pays off only
    with an explicit shard_map all-to-all). Kept flat.
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    xt = x.reshape(t, d)

    logits = xt.astype(jnp.float32) @ p["router"]             # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                  # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Switch-style load-balance aux loss: E * sum_e f_e * P_e.
    # (bincount, not a (T, E) one-hot — see §Perf iteration 1)
    me = jnp.mean(probs, axis=0)
    fe = jnp.bincount(idx[:, 0], length=e).astype(jnp.float32) / t
    aux = e * jnp.sum(fe * me)

    c = moe_capacity(cfg, t)

    # Queue position of each (token, choice) within its expert. Sort-based
    # ranking: the naive one-hot cumsum over (T·k, E) lowers to a
    # reduce-window XLA costs quadratically (§Perf iteration 1 measured a
    # ~15x HLO-flop blowup at kimi scale); a stable sort by expert plus an
    # E-length exclusive prefix gives the same first-come positions in
    # O(n log n).
    flat_idx = idx.reshape(t * k)                             # (T*k,)
    order = jnp.argsort(flat_idx, stable=True)
    counts = jnp.bincount(flat_idx, length=e)                 # (E,)
    starts = jnp.cumsum(counts) - counts                      # tiny cumsum
    pos_sorted = jnp.arange(t * k) - starts[flat_idx[order]]
    pos = jnp.zeros_like(flat_idx).at[order].set(
        pos_sorted.astype(flat_idx.dtype))                    # (T*k,)
    keep = pos < c
    slot = jnp.where(keep, flat_idx * c + pos, e * c)         # overflow -> pad

    # Scatter tokens into the expert buffer (pad slot e*c absorbs overflow).
    xk = jnp.repeat(xt, k, axis=0)                            # (T*k, d)
    expert_in = jnp.zeros((e * c + 1, d), x.dtype).at[slot].add(xk)[:-1]
    expert_in = expert_in.reshape(e, c, d)
    # EP over 'model' x capacity over 'data' (iteration 2: without 'data'
    # the expert FFN replicates across the data axis, 16x compute waste).
    expert_in = constraint(expert_in, "model", "data", None)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"])
    h = constraint(h, "model", "data", None)
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])   # (E, C, d)

    # Gather back, weighted by gates; dropped tokens contribute zero.
    flat_out = expert_out.reshape(e * c, d)
    safe_slot = jnp.where(keep, slot, 0)
    picked = flat_out[safe_slot] * (gate_vals.reshape(t * k, 1)
                                    * keep[:, None]).astype(x.dtype)
    out = jnp.sum(picked.reshape(t, k, d), axis=1)
    return out.reshape(b, s, d), aux

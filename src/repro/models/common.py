"""Shared model components: norms, RoPE, SwiGLU MLP, initialisation."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constraint


def dense_init(key, shape, fan_in: int | None = None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = (1.0 / fan_in) ** 0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * scale * gamma.astype(jnp.float32)).astype(dt)


def init_rms(d: int, dtype=jnp.float32) -> jax.Array:
    return jnp.ones((d,), dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                     # (D/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype,
             variant: str = "swiglu") -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k2, (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(k3, (d_ff, d_model), fan_in=d_ff, dtype=dtype),
    }
    if variant == "swiglu":
        p["w_gate"] = dense_init(k1, (d_model, d_ff), dtype=dtype)
    return p


def apply_mlp(p: dict, x: jax.Array) -> jax.Array:
    if "w_gate" in p:
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    h = constraint(h, "data", None, "model")
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def init_embed(key, vocab: int, d_model: int, dtype) -> dict:
    return {"table": dense_init(key, (vocab, d_model), fan_in=d_model,
                                dtype=dtype)}


def apply_embed(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def init_lm_head(key, d_model: int, vocab: int, dtype) -> dict:
    return {"w": dense_init(key, (d_model, vocab), dtype=dtype)}


def apply_lm_head(p: dict, x: jax.Array) -> jax.Array:
    logits = x @ p["w"]
    spec = ("data",) + (None,) * (logits.ndim - 2) + ("model",)
    return constraint(logits, *spec)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL in f32. logits (B,S,V); labels (B,S) int32.

    The gold-logit pick is a masked reduce (iota==label -> select -> sum),
    not take_along_axis: a gather over the vocab axis forces GSPMD to
    all-gather the TP-sharded logits (§Perf iteration 6), while the masked
    reduce partitions cleanly (per-shard partial + psum) and fuses.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                   axis=-1)
    return jnp.mean(logz - gold)

"""PoolStore — keep an arbitrary pytree inside a CREAM pool.

Bridges the framework's tensors and the pool's page world: leaves are
bitcast to uint32 words, concatenated, and written page-by-page. The table
of contents records each leaf's page span so single leaves can be reloaded
(targeted restore) without touching the rest. Used by the trainer to keep a
SECDED-protected warm snapshot of optimizer moments, and by tests to prove
end-to-end repair of injected bit flips.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core import pool as pool_lib
from repro.core.pool import PoolState
from repro.distributed.sharding import tree_paths


@dataclass(frozen=True)
class LeafEntry:
    word_offset: int
    num_words: int
    pad_bytes: int
    shape: tuple
    dtype: str


@dataclass(frozen=True)
class TableOfContents:
    entries: dict[str, LeafEntry]
    total_pages: int


def _leaf_words(arr: np.ndarray) -> tuple[np.ndarray, int]:
    raw = arr.tobytes()
    pad = (-len(raw)) % 4
    return np.frombuffer(raw + b"\0" * pad, dtype=np.uint32), pad


def required_rows(tree, row_words: int = 256) -> int:
    """Pool rows needed to store ``tree`` (SECDED region sizing helper)."""
    total_bytes = sum(np.asarray(l).nbytes for l in tree_paths(tree).values())
    words = math.ceil(total_bytes / 4)
    page_words = 8 * row_words
    rows = math.ceil(words / page_words)
    return math.ceil(rows / 8) * 8  # group-aligned


def store_tree(state: PoolState, tree, first_page: int = 0
               ) -> tuple[PoolState, TableOfContents]:
    """Write all leaves into consecutive pages starting at ``first_page``."""
    flat = {p: np.asarray(l) for p, l in tree_paths(tree).items()}
    entries: dict[str, LeafEntry] = {}
    chunks: list[np.ndarray] = []
    offset = 0
    for path, arr in flat.items():
        words, pad = _leaf_words(arr)
        entries[path] = LeafEntry(offset, len(words), pad, tuple(arr.shape),
                                  str(arr.dtype))
        chunks.append(words)
        offset += len(words)

    blob = np.concatenate(chunks) if chunks else np.zeros(0, np.uint32)
    pw = state.page_words
    n_pages = math.ceil(len(blob) / pw)
    if first_page + n_pages > state.num_pages:
        raise ValueError(
            f"tree needs {n_pages} pages at offset {first_page}, pool has "
            f"{state.num_pages}")
    padded = np.zeros(n_pages * pw, np.uint32)
    padded[:len(blob)] = blob
    # Batched write: one traced scatter instead of n_pages separate
    # static-index writes (each of which would re-trace — a 110M-param
    # moment snapshot is ~10^5 pages). The mixed-pool engine handles any
    # boundary, so no per-page fallback is needed.
    state = pool_lib.write_pages_any(
        state, jnp.arange(first_page, first_page + n_pages, dtype=jnp.int32),
        jnp.asarray(padded.reshape(n_pages, pw)))
    return state, TableOfContents(entries, n_pages)


def load_tree(state: PoolState, toc: TableOfContents, like,
              first_page: int = 0) -> tuple[object, int]:
    """Read the tree back. Returns (tree, worst_status)."""
    pw = state.page_words
    n = toc.total_pages
    idx = jnp.arange(first_page, first_page + n, dtype=jnp.int32)
    data, status = pool_lib.read_pages_any_status(state, idx)
    blob = np.asarray(data).reshape(-1)
    worst = int(jnp.max(status)) if n else 0

    def rebuild(prefix, node):
        if isinstance(node, dict):
            return {k: rebuild(f"{prefix}/{k}" if prefix else k, v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [rebuild(f"{prefix}/{i}", v) for i, v in enumerate(node)]
            return type(node)(t)
        e = toc.entries[prefix]
        words = blob[e.word_offset:e.word_offset + e.num_words]
        raw = words.tobytes()
        if e.pad_bytes:
            raw = raw[:-e.pad_bytes]
        arr = np.frombuffer(raw, dtype=np.dtype(e.dtype)).reshape(e.shape)
        return jnp.asarray(arr.copy())

    return rebuild("", like), worst

"""CREAMPool — the ECC-DRAM module analogue, with the paper's boundary register.

A pool is a single uint32 buffer of shape ``(R, 9, W)`` (rows × lanes × words;
DESIGN.md §2.1). Rows ``[0, boundary)`` form the CREAM region (layout = one of
PACKED / RANK_SUBSET / INTERWRAP / PARITY); rows ``[boundary, R)`` keep the
conventional SECDED layout — the paper's §4.3.1 partitioning, with the same
page-id convention:

    pages [0, boundary)        CREAM-region regular pages (lanes 0–7 / wrap)
    pages [boundary, R)        SECDED-protected pages
    pages [R, R + extra)       extra pages reclaimed from the code lane

All state transforms are functional (old state in, new state out). Page-level
reads/writes with *static* page ids compose under jit; the hot paths are the
batched engines: :func:`read_pages_batch` / :func:`write_pages_batch` for
single-mode pools, and the universal mixed-pool engine
:func:`read_pages_any` / :func:`write_pages_any` — one
:func:`repro.core.layouts.page_coords` translation, one gather/scatter, and
masked batched SECDED / packed-parity codecs, jittable with *traced* page-id
arrays for any boundary (``read_pages_any_jit`` etc. are the pre-jitted,
donation-friendly entry points).
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from functools import partial
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import daec, parity8, secded
from repro.obs import memprof
from repro.core.layouts import (CODE_LANE, DATA_LANES, DEFAULT_ROW_WORDS,
                                GROUP_ROWS, LANES, REGION_SECDED, Layout,
                                PagePlacement, extra_page_count, page_coords,
                                parity_coords, place_page,
                                _parity_row_of_page)


def _warn_deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"PoolLike.{old} is deprecated; use the unified access API: "
        f"pool.{new}", DeprecationWarning, stacklevel=3)


@jax.tree_util.register_dataclass
@dataclass
class PoolState:
    """Functional pool state. ``storage`` is the only traced leaf.

    ``daec_rows`` carves the TOP of the protected region into the SEC-DAEC
    tier: pages ``[num_rows - daec_rows, num_rows)`` store
    ``repro.core.daec`` 16-bit superbeat code fields in the same code lane
    the SECDED rows use (identical shapes — see ``core/daec.py``), so the
    ladder rung changes codec selection only, never placement. Invariant:
    ``boundary <= num_rows - daec_rows``.
    """
    storage: jax.Array  # (R, 9, W) uint32
    boundary: int = dataclasses.field(metadata=dict(static=True))
    layout: Layout = dataclasses.field(metadata=dict(static=True))
    row_words: int = dataclasses.field(metadata=dict(static=True))
    daec_rows: int = dataclasses.field(default=0, metadata=dict(static=True))

    @property
    def num_rows(self) -> int:
        return self.storage.shape[0]

    @property
    def daec_start(self) -> int:
        """First DAEC-tier page id (== num_rows when the tier is empty)."""
        return self.num_rows - self.daec_rows

    @property
    def page_words(self) -> int:
        return DATA_LANES * self.row_words

    @property
    def page_bytes(self) -> int:
        return 4 * self.page_words

    @property
    def num_extra_pages(self) -> int:
        return extra_page_count(self.layout, self.boundary, self.row_words)

    @property
    def num_pages(self) -> int:
        """Effective page capacity = R regular + reclaimed extras."""
        return self.num_rows + self.num_extra_pages

    @property
    def raw_bytes(self) -> int:
        return self.storage.size * 4

    @property
    def effective_bytes(self) -> int:
        return self.num_pages * self.page_bytes

    def capacity_gain(self) -> float:
        """Fraction of baseline (all-SECDED) capacity reclaimed."""
        return self.num_extra_pages / self.num_rows

    # -- PoolLike surface (the local data plane) ----------------------------
    # ONE coherent access API — ``read`` / ``write`` / ``migrate`` /
    # ``streams`` — so owners (the VM, the object cache, the serving tier)
    # run unchanged on any PoolLike implementation (this local pool or
    # ``repro.shard.ShardedPool``). Each entry point auto-selects its
    # dispatch shape: traced operands (we are inside someone's jit) compose
    # straight into the enclosing trace; concrete ids are range-validated
    # host-side and take the pre-jitted hot path. The historical
    # ``read_any`` / ``read_pages`` split (traceable vs jitted, times three
    # operations, times a ``_status`` axis) survives only as deprecation
    # shims below.

    @property
    def boundary_step(self) -> int:
        """Boundary-register granularity (rows)."""
        return GROUP_ROWS

    def _traced(self, *operands) -> bool:
        return any(isinstance(x, jax.core.Tracer)
                   for x in (self.storage, *operands))

    def read(self, pages, *, status=False):
        """Batch read for an arbitrary page-id vector.

        Returns ``(n, page_words)`` uint32, or with ``status=True`` a
        ``(data, status (n,) int32)`` pair (worst per-beat decode status:
        0 clean, 1/2 corrected, 3 detected-uncorrectable). Traceable with
        traced ids; concrete ids validate host-side and dispatch jitted.
        """
        if self._traced(pages):
            return read_pages_any_status(self, pages) if status \
                else read_pages_any(self, pages)
        arr = _as_page_array(self, pages)
        self.memprof_record("gather", arr)
        fn = _read_pages_any_status_jitted if status \
            else _read_pages_any_jitted
        return fn(self, arr)

    def read_writeback(self, pages):
        """Write-back read: like ``read(pages, status=True)`` but corrected
        beats are persisted back to storage (latent errors killed in the
        same pass). Returns ``(data, status, new_state)``."""
        if self._traced(pages):
            return read_pages_any_writeback(self, pages)
        arr = _as_page_array(self, pages)
        self.memprof_record("gather", arr)
        return _read_pages_any_writeback_jitted(self, arr)

    def write(self, pages, data: jax.Array, *, valid=None) -> "PoolState":
        """Code-maintaining batch write; returns the new pool state.

        ``valid`` (optional ``(n,)`` bool) drops masked rows entirely —
        the SPMD building block of the sharded dispatch. On the concrete
        (jitted) path the input state's storage is donated: drop the old
        state immediately, as every internal owner does.
        """
        if self._traced(pages, data, valid):
            return write_pages_any(self, pages, data, valid=valid)
        arr = _as_page_array(self, pages)
        self.memprof_record("scatter", arr)
        if valid is None:
            return _write_pages_any_jitted(self, arr, data)
        return _write_pages_any_valid_jitted(
            self, arr, data, jnp.asarray(valid, bool).reshape(-1))

    def migrate(self, src_pages, dst_pages, *,
                donate: bool = True) -> "PoolState":
        """In-pool page relocation ``src -> dst``: one fused dispatch
        (decode-corrected read + code-maintaining write under one jit).
        ``donate=False`` keeps the input state's storage valid (callers
        that may roll back)."""
        src = _as_page_array(self, src_pages)
        dst = _as_page_array(self, dst_pages)
        self.memprof_record("gather", src)
        self.memprof_record("scatter", dst)
        fn = _migrate_within_jitted if donate \
            else _migrate_within_jitted_nodonate
        return fn(self, src, dst)

    def streams(self, pages, data=None, *, valid=None):
        """Bank-aligned stream access: ``(S, n)`` ids, one dispatch.

        With ``data=None`` reads and returns ``(S, n, page_words)``;
        with ``data`` ``(S, n, page_words)`` writes (``valid`` optionally
        masks entries) and returns the new state. On a local pool the
        stream axis is a pure batching convention — the sharded pool
        (:class:`repro.shard.ShardedPool`) serves each stream on its own
        bank, which is where the Figs. 9–11 concurrency lives.
        """
        shape = pages.shape
        flat = jnp.asarray(pages, jnp.int32).reshape(-1)
        if data is None:
            return self.read(flat).reshape(*shape, self.page_words)
        vf = None if valid is None else jnp.asarray(valid).reshape(-1)
        return self.write(flat, jnp.asarray(data).reshape(flat.shape[0], -1),
                          valid=vf)

    # -- deprecated access surface (thin shims over the unified API) --------

    def read_any(self, pages) -> jax.Array:
        _warn_deprecated("read_any", "read(pages)")
        return read_pages_any(self, pages)

    def read_any_status(self, pages) -> tuple[jax.Array, jax.Array]:
        _warn_deprecated("read_any_status", "read(pages, status=True)")
        return read_pages_any_status(self, pages)

    def write_any(self, pages, data: jax.Array) -> "PoolState":
        _warn_deprecated("write_any", "write(pages, data)")
        return write_pages_any(self, pages, data)

    def read_pages(self, pages) -> jax.Array:
        _warn_deprecated("read_pages", "read(pages)")
        return self.read(pages)

    def read_pages_status(self, pages) -> tuple[jax.Array, jax.Array]:
        _warn_deprecated("read_pages_status", "read(pages, status=True)")
        return self.read(pages, status=True)

    def write_pages(self, pages, data: jax.Array) -> "PoolState":
        _warn_deprecated("write_pages", "write(pages, data)")
        return self.write(pages, data)

    def evict_prediction(self, new_boundary: int) -> list[int]:
        """Extra-page ids a move to ``new_boundary`` would evict."""
        return evicted_extra_pages(self, new_boundary)

    def move_boundary(self, new_boundary: int) -> tuple["PoolState", dict]:
        """Repartition (see :func:`repartition`)."""
        return repartition(self, new_boundary)

    def set_daec_rows(self, daec_rows: int) -> "PoolState":
        """Resize the SEC-DAEC tier in place (see :func:`set_daec_rows`)."""
        return set_daec_rows(self, daec_rows)

    def scrub(self, use_kernel: bool = False):
        """Sweep + repair in place; returns ``(new_state, ScrubStats)``."""
        from repro.core.scrubber import scrub as _scrub
        return _scrub(self, use_kernel=use_kernel)

    def memprof_record(self, op: str, pages, stream: str = "main") -> None:
        """Feed one dispatch to CREAM-Lens (no-op unless memprof enabled).

        Owners with context the pool can't see (the serving engine's fused
        decode gather, the object cache) call this directly; the jit
        wrappers below call it implicitly. Traced ``pages`` (or traced
        storage, i.e. *we* are inside someone's jit) are skipped — capture
        records execution, not tracing.
        """
        if not memprof.enabled() or isinstance(pages, jax.core.Tracer) \
                or isinstance(self.storage, jax.core.Tracer):
            return
        memprof.record(op, np.asarray(pages), layout=self.layout,
                       num_rows=self.num_rows, boundary=self.boundary,
                       row_words=self.row_words, stream=stream)


@runtime_checkable
class PoolLike(Protocol):
    """The pool data-plane contract the VM / object-cache / serving layers
    program against.

    Implementations: :class:`PoolState` (single device) and
    :class:`repro.shard.ShardedPool` (multi-device, ``banks`` mesh axis).
    Both share the page-id convention (regular pages ``[0, num_rows)``,
    reclaimed extras above) and the region semantics derived from
    ``boundary`` / ``num_rows`` / ``layout``, so owners never branch on the
    concrete type for translation, allocation, or capacity accounting.
    """

    layout: Layout
    row_words: int
    boundary: int
    num_rows: int
    num_pages: int
    num_extra_pages: int
    page_words: int
    boundary_step: int
    daec_rows: int

    def read(self, pages, *, status=False): ...                     # noqa: E704
    def write(self, pages, data, *, valid=None) -> "PoolLike": ...  # noqa: E704
    def migrate(self, src_pages, dst_pages, *,
                donate: bool = True) -> "PoolLike": ...             # noqa: E704
    def streams(self, pages, data=None, *, valid=None): ...         # noqa: E704
    def evict_prediction(self, new_boundary) -> list[int]: ...      # noqa: E704
    def move_boundary(self, new_boundary) -> tuple: ...             # noqa: E704
    def scrub(self, use_kernel: bool = False) -> tuple: ...         # noqa: E704
    def memprof_record(self, op, pages, stream="main") -> None: ... # noqa: E704


def make_pool(num_rows: int, layout: Layout = Layout.INTERWRAP,
              boundary: int | None = None,
              row_words: int = DEFAULT_ROW_WORDS,
              daec_rows: int = 0) -> PoolState:
    """Create a zeroed pool. ``boundary=None`` puts the whole pool in CREAM
    mode; ``daec_rows`` carves the top of the protected region into the
    SEC-DAEC tier (requires ``boundary <= num_rows - daec_rows``)."""
    if num_rows % GROUP_ROWS:
        raise ValueError(f"num_rows must be a multiple of {GROUP_ROWS}")
    boundary = num_rows if boundary is None else boundary
    if boundary % GROUP_ROWS or not 0 <= boundary <= num_rows:
        raise ValueError(f"bad boundary {boundary}")
    if layout == Layout.BASELINE_ECC and boundary != 0:
        boundary = 0  # whole pool SECDED
    if not 0 <= daec_rows <= num_rows - boundary:
        raise ValueError(
            f"daec_rows ({daec_rows}) must fit the protected region "
            f"[{boundary}, {num_rows})")
    storage = jnp.zeros((num_rows, LANES, row_words), dtype=jnp.uint32)
    return PoolState(storage, boundary, layout, row_words, daec_rows)


# ---------------------------------------------------------------------------
# Placement → jnp gather/scatter
# ---------------------------------------------------------------------------


def _placement(state: PoolState, page: int) -> PagePlacement:
    if page < state.boundary:
        return place_page(state.layout, state.boundary, page, state.row_words)
    if page < state.num_rows:
        return PagePlacement("rows", page)  # SECDED region
    # extra page: ids relative to the CREAM region
    rel = state.boundary + (page - state.num_rows)
    return place_page(state.layout, state.boundary, rel, state.row_words)


def _gather(state: PoolState, pl: PagePlacement) -> jax.Array:
    if pl.kind == "rows":
        return state.storage[pl.row0, :DATA_LANES, :].reshape(-1)
    if pl.kind == "codelane":
        return state.storage[pl.row0:pl.row0 + GROUP_ROWS, CODE_LANE, :].reshape(-1)
    if pl.kind == "wrap":
        parts = [state.storage[row, lane, :] for lane, row in pl.slices]
        return jnp.concatenate(parts)
    raise ValueError(pl.kind)


def _scatter(state: PoolState, pl: PagePlacement, data: jax.Array) -> jax.Array:
    s = state.storage
    if pl.kind == "rows":
        return s.at[pl.row0, :DATA_LANES, :].set(
            data.reshape(DATA_LANES, state.row_words))
    if pl.kind == "codelane":
        return s.at[pl.row0:pl.row0 + GROUP_ROWS, CODE_LANE, :].set(
            data.reshape(GROUP_ROWS, state.row_words))
    if pl.kind == "wrap":
        chunks = data.reshape(DATA_LANES, state.row_words)
        for k, (lane, row) in enumerate(pl.slices):
            s = s.at[row, lane, :].set(chunks[k])
        return s
    raise ValueError(pl.kind)


# ---------------------------------------------------------------------------
# Page read / write (static page id)
# ---------------------------------------------------------------------------


def read_page(state: PoolState, page: int) -> tuple[jax.Array, jax.Array]:
    """Read one 8KB page. Returns (data[8W], status[int32 scalar]).

    status: max SECDED/parity status over the page (0 clean, 1/2 corrected,
    3 detected-uncorrectable). Corrections are *reported*, not persisted —
    use :func:`scrub` to repair storage in place.
    """
    pl = _placement(state, page)
    data = _gather(state, pl)
    if page >= state.boundary and page < state.num_rows:
        codes = state.storage[pl.row0, CODE_LANE, :]
        codec = daec if page >= state.daec_start else secded
        data, _, st = codec.decode_block(data, codes)
        return data, jnp.max(st)
    if state.layout == Layout.PARITY and page < state.num_rows:
        prow = _parity_row_of_page(state.layout, state.boundary, page,
                                   state.row_words)
        off = (page % 8) * (state.row_words // 8)
        packed = jax.lax.dynamic_slice(
            state.storage[prow, CODE_LANE, :], (off,), (state.row_words // 8,))
        st = parity8.check_lines_packed(data, packed)
        return data, jnp.max(st) * 3  # corrupt -> DETECTED_UNCORRECTABLE
    if state.layout == Layout.PARITY and page >= state.num_rows:
        rel = state.boundary + (page - state.num_rows)
        prow = _parity_row_of_page(state.layout, state.boundary, rel,
                                   state.row_words)
        off = (rel % 8) * (state.row_words // 8)
        packed = jax.lax.dynamic_slice(
            state.storage[prow, CODE_LANE, :], (off,), (state.row_words // 8,))
        st = parity8.check_lines_packed(data, packed)
        return data, jnp.max(st) * 3
    return data, jnp.zeros((), jnp.int32)


def write_page(state: PoolState, page: int, data: jax.Array) -> PoolState:
    """Write one 8KB page, maintaining codes for protected pages."""
    data = data.astype(jnp.uint32).reshape(-1)
    if data.shape[0] != state.page_words:
        raise ValueError(f"page data must be {state.page_words} words")
    pl = _placement(state, page)
    storage = _scatter(state, pl, data)
    if page >= state.boundary and page < state.num_rows:
        codec = daec if page >= state.daec_start else secded
        storage = storage.at[pl.row0, CODE_LANE, :].set(codec.encode_block(data))
    elif state.layout == Layout.PARITY:
        rel = page if page < state.num_rows else \
            state.boundary + (page - state.num_rows)
        prow = _parity_row_of_page(state.layout, state.boundary, rel,
                                   state.row_words)
        off = (rel % 8) * (state.row_words // 8)
        packed = parity8.encode_lines_packed(data)
        storage = jax.lax.dynamic_update_slice(
            storage, packed[None, None, :],
            (prow, CODE_LANE, off))[..., :]  # update within the code lane
    return dataclasses.replace(state, storage=storage)


# ---------------------------------------------------------------------------
# Batched dynamic access (hot path: paged KV cache).
# Restricted to pools whose CREAM region covers everything and whose layout
# gives uniform single-op placement (INTERWRAP) or uniform row placement.
# ---------------------------------------------------------------------------


def _single_mode(state: PoolState) -> bool:
    return state.boundary == 0 or (state.layout == Layout.INTERWRAP
                                   and state.boundary == state.num_rows)


def read_pages_batch(state: PoolState, pages: jax.Array) -> jax.Array:
    """Gather a batch of pages -> (n, 8W) uint32.

    Fast paths: whole-pool INTERWRAP (the Pallas ``interwrap`` kernel's
    access; this jnp version is its oracle and the CPU path) and whole-pool
    SECDED (decode+correct on load). Mixed pools go through
    :func:`read_pages_any`, which handles every boundary.
    """
    if not _single_mode(state):
        raise ValueError("batched access requires a single-mode pool")
    return read_pages_any(state, pages)


def read_pages_batch_status(state: PoolState, pages: jax.Array
                            ) -> tuple[jax.Array, jax.Array]:
    """Batched read + per-page worst decode status.

    Contract: returns ``(data (n, page_words) uint32, status (n,) int32)``
    on *both* branches — ``status[i]`` is the worst per-beat decode status of
    page ``i`` (0 clean, 1/2 corrected, 3 detected-uncorrectable) for SECDED
    pools and all-zeros for unprotected single-mode pools.
    """
    if not _single_mode(state):
        raise ValueError("batched access requires a single-mode pool")
    return read_pages_any_status(state, pages)


def write_pages_batch(state: PoolState, pages: jax.Array,
                      data: jax.Array) -> PoolState:
    """Scatter a batch of pages (n, 8W). Single-mode pools only."""
    if not _single_mode(state):
        raise ValueError("batched access requires a single-mode pool")
    return write_pages_any(state, pages, data)


# ---------------------------------------------------------------------------
# Mixed-pool batched access engine — any boundary, any page-id mix.
#
# One `layouts.page_coords` translation turns an arbitrary page-id vector
# into (rows, lanes, region); data then moves in a single advanced-indexing
# gather/scatter and the codecs run batched + masked: SECDED decode/encode
# over every page with the non-SECDED lanes masked out, and (for PARITY
# pools) one packed-parity gather/scatter with `mode="drop"` routing. No
# Python per-page loops — everything traces, so the VM data plane
# (``repro.vm``) and serving engine jit straight through with *dynamic*
# page-id arrays.
# ---------------------------------------------------------------------------


def _as_page_array(state: PoolState, pages) -> jax.Array:
    """Coerce page ids to int32; range-validate only when they are concrete.

    Traced ids (inside jit) skip host validation — out-of-range ids then
    clamp, as standard for jnp indexing.
    """
    if isinstance(pages, jax.core.Tracer):
        return pages.astype(jnp.int32).reshape(-1)
    arr = np.asarray(pages, dtype=np.int64).reshape(-1)
    bad = arr[(arr < 0) | (arr >= state.num_pages)]
    if bad.size:
        raise ValueError(
            f"pages {bad.tolist()} out of range [0, {state.num_pages})")
    if isinstance(pages, jax.Array) and pages.dtype == jnp.int32 \
            and pages.ndim == 1:
        return pages          # already device-resident: don't rebuild
    return jnp.asarray(arr, jnp.int32)


def read_pages_any_status(state: PoolState, pages
                          ) -> tuple[jax.Array, jax.Array]:
    """Batch read with per-page status for an arbitrary page-id vector.

    Handles every pool mode (``0 <= boundary <= num_rows``) and page-id mix
    (CREAM regular / SECDED / extra) in one gather + masked batched codecs.
    Returns ``(data (n, page_words) uint32, status (n,) int32)`` where
    ``status[i]`` is the page's worst beat/line status: SECDED pages report
    decode status (corrections applied to the returned data, *not*
    persisted — see :func:`scrub`), PARITY-layout CREAM/extra pages report
    0 or DETECTED_UNCORRECTABLE, unprotected pages report 0.
    """
    pages = _as_page_array(state, pages)
    state.memprof_record("gather", pages)   # no-op when traced or disabled
    n = pages.shape[0]
    if n == 0:
        return (jnp.zeros((0, state.page_words), jnp.uint32),
                jnp.zeros((0,), jnp.int32))
    rows, lanes, region = page_coords(state.layout, state.num_rows,
                                      state.boundary, pages, state.row_words)
    data = state.storage[rows, lanes, :].reshape(n, -1)
    is_sec = region == REGION_SECDED
    status = jnp.zeros((n,), jnp.int32)
    if state.boundary < state.num_rows:       # pool has SECDED rows
        crow = jnp.clip(pages, state.boundary, state.num_rows - 1)
        codes = state.storage[crow, CODE_LANE, :]
        fixed, _, st = secded.decode_block(data, codes)
        pst = jnp.max(st, axis=-1)
        if state.daec_rows > 0:               # DAEC tier atop the region
            dfixed, _, dst = daec.decode_block(data, codes)
            is_daec = is_sec & (pages >= state.daec_start)
            fixed = jnp.where(is_daec[:, None], dfixed, fixed)
            pst = jnp.where(is_daec, jnp.max(dst, axis=-1), pst)
        data = jnp.where(is_sec[:, None], fixed, data)
        status = jnp.where(is_sec, pst, 0).astype(jnp.int32)
    if state.layout == Layout.PARITY and state.boundary > 0:
        prow, off = parity_coords(state.num_rows, state.boundary, pages,
                                  state.row_words)
        idx = off[:, None] + jnp.arange(state.row_words // 8)
        packed = state.storage[jnp.clip(prow, 0, state.num_rows - 1)[:, None],
                               CODE_LANE, idx]
        pst = jnp.max(parity8.check_lines_packed(data, packed), axis=-1) * 3
        status = jnp.where(is_sec, status, pst.astype(jnp.int32))
    return data, status


def read_pages_any(state: PoolState, pages) -> jax.Array:
    """Decode-corrected batch read for an arbitrary page-id vector.

    Mixed-pool engine entry point: any boundary, any mix of CREAM / SECDED /
    extra ids, fully traceable. Returns ``(n, page_words)`` uint32.
    """
    return read_pages_any_status(state, pages)[0]


def write_pages_any(state: PoolState, pages, data: jax.Array,
                    valid: jax.Array | None = None) -> PoolState:
    """Batch write for an arbitrary page-id vector, maintaining codes.

    One data scatter over the ``page_coords`` translation, one masked SECDED
    encode scatter (``mode="drop"`` routes non-SECDED pages off the code
    lane), and — for PARITY pools — one packed-parity scatter. Duplicate ids
    within a batch leave that page's contents unspecified (scatter order).
    ``data`` is ``(n, page_words)``.

    ``valid`` (optional ``(n,)`` bool) masks rows out of the write entirely —
    their data, code, and parity scatters are routed out of range and
    dropped. This is the SPMD building block the sharded pool's per-shard
    dispatch uses: every shard traces the same program over the full batch
    and lands only the pages it owns.
    """
    pages = _as_page_array(state, pages)
    state.memprof_record("scatter", pages)  # no-op when traced or disabled
    n = pages.shape[0]
    if n == 0:
        return state
    data = data.astype(jnp.uint32).reshape(n, -1)
    if data.shape[1] != state.page_words:
        raise ValueError(f"page data must be {state.page_words} words")
    rows, lanes, region = page_coords(state.layout, state.num_rows,
                                      state.boundary, pages, state.row_words)
    is_sec = region == REGION_SECDED
    if valid is None:
        storage = state.storage.at[rows, lanes, :].set(
            data.reshape(n, DATA_LANES, state.row_words))
    else:
        valid = jnp.asarray(valid, bool).reshape(-1)
        rows = jnp.where(valid[:, None], rows, state.num_rows)  # OOB -> drop
        is_sec = is_sec & valid
        storage = state.storage.at[rows, lanes, :].set(
            data.reshape(n, DATA_LANES, state.row_words), mode="drop")
    if state.boundary < state.num_rows:       # pool has SECDED rows
        codes = secded.encode_block(data)
        if state.daec_rows > 0:               # DAEC tier atop the region
            is_daec = is_sec & (pages >= state.daec_start)
            codes = jnp.where(is_daec[:, None], daec.encode_block(data), codes)
        crow = jnp.where(is_sec, pages, state.num_rows)   # OOB -> dropped
        storage = storage.at[crow, CODE_LANE, :].set(codes, mode="drop")
    if state.layout == Layout.PARITY and state.boundary > 0:
        prow, off = parity_coords(state.num_rows, state.boundary, pages,
                                  state.row_words)
        prow = jnp.where(is_sec, state.num_rows, prow)    # OOB -> dropped
        if valid is not None:
            prow = jnp.where(valid, prow, state.num_rows)
        packed = parity8.encode_lines_packed(data)        # (n, W/8)
        idx = off[:, None] + jnp.arange(state.row_words // 8)
        storage = storage.at[prow[:, None], CODE_LANE, idx].set(
            packed, mode="drop")
    return dataclasses.replace(state, storage=storage)


def read_pages_any_writeback(state: PoolState, pages
                             ) -> tuple[jax.Array, jax.Array, PoolState]:
    """Write-back read: the fused read pass that *kills latent errors*.

    Same gather + masked codecs as :func:`read_pages_any_status`, but
    protected pages whose decode corrected a bit get their corrected data
    AND corrected code scattered back to storage — the memory-controller
    write-back scrub semantic ("correct on read, persist the fix") instead
    of correct-and-forget. Returns ``(data, status, new_state)``; pages
    that were clean or uncorrectable leave storage untouched, so the pass
    is idempotent and a follow-up read of the same pages reports CLEAN for
    everything it corrected.
    """
    pages = _as_page_array(state, pages)
    state.memprof_record("gather", pages)
    n = pages.shape[0]
    if n == 0:
        return (jnp.zeros((0, state.page_words), jnp.uint32),
                jnp.zeros((0,), jnp.int32), state)
    rows, lanes, region = page_coords(state.layout, state.num_rows,
                                      state.boundary, pages, state.row_words)
    data = state.storage[rows, lanes, :].reshape(n, -1)
    is_sec = region == REGION_SECDED
    status = jnp.zeros((n,), jnp.int32)
    storage = state.storage
    if state.boundary < state.num_rows:       # pool has protected rows
        crow = jnp.clip(pages, state.boundary, state.num_rows - 1)
        codes = storage[crow, CODE_LANE, :]
        fixed, fcodes, st = secded.decode_block(data, codes)
        pst = jnp.max(st, axis=-1)
        if state.daec_rows > 0:
            dfixed, dcodes, dst = daec.decode_block(data, codes)
            is_daec = is_sec & (pages >= state.daec_start)
            fixed = jnp.where(is_daec[:, None], dfixed, fixed)
            fcodes = jnp.where(is_daec[:, None], dcodes, fcodes)
            pst = jnp.where(is_daec, jnp.max(dst, axis=-1), pst)
        data = jnp.where(is_sec[:, None], fixed, data)
        status = jnp.where(is_sec, pst, 0).astype(jnp.int32)
        # scatter the fix: only protected pages with a corrected beat
        # (uncorrectable pages must keep their evidence for the monitor)
        wb = is_sec & ((status == secded.CORRECTED_DATA)
                       | (status == secded.CORRECTED_CODE))
        wrow = jnp.where(wb, pages, state.num_rows)       # OOB -> dropped
        storage = storage.at[wrow, :DATA_LANES, :].set(
            data.reshape(n, DATA_LANES, state.row_words), mode="drop")
        storage = storage.at[wrow, CODE_LANE, :].set(fcodes, mode="drop")
    if state.layout == Layout.PARITY and state.boundary > 0:
        prow, off = parity_coords(state.num_rows, state.boundary, pages,
                                  state.row_words)
        idx = off[:, None] + jnp.arange(state.row_words // 8)
        packed = storage[jnp.clip(prow, 0, state.num_rows - 1)[:, None],
                         CODE_LANE, idx]
        pst = jnp.max(parity8.check_lines_packed(data, packed), axis=-1) * 3
        status = jnp.where(is_sec, status, pst.astype(jnp.int32))
    return data, status, dataclasses.replace(state, storage=storage)


def set_daec_rows(state: PoolState, daec_rows: int) -> PoolState:
    """Re-tier the top of the protected region to/from SEC-DAEC.

    Converts the code lane of every affected row in place: decode with the
    outgoing codec (last chance to correct), re-encode with the incoming
    one. Data survives bit-exact — safe on occupied frames — because both
    codecs share storage shapes and the decode corrects before re-encoding.
    """
    n = int(daec_rows)
    R = state.num_rows
    if not 0 <= n <= R - state.boundary:
        raise ValueError(
            f"daec_rows ({n}) must fit the protected region "
            f"[{state.boundary}, {R})")
    old = state.daec_rows
    if n == old:
        return state
    rows = jnp.arange(R - max(old, n), R - min(old, n), dtype=jnp.int32)
    data = state.storage[rows, :DATA_LANES, :].reshape(rows.shape[0], -1)
    codes = state.storage[rows, CODE_LANE, :]
    if n > old:   # SECDED -> DAEC
        fixed, _, _ = secded.decode_block(data, codes)
        new_codes = daec.encode_block(fixed)
    else:         # DAEC -> SECDED
        fixed, _, _ = daec.decode_block(data, codes)
        new_codes = secded.encode_block(fixed)
    storage = state.storage.at[rows, :DATA_LANES, :].set(
        fixed.reshape(-1, DATA_LANES, state.row_words))
    storage = storage.at[rows, CODE_LANE, :].set(new_codes)
    return dataclasses.replace(state, storage=storage, daec_rows=n)


# Pre-jitted engine entry points for the hot paths (the VM data plane).
# ``boundary`` / ``layout`` / ``row_words`` are static pytree metadata, so
# each pool mode compiles once; page ids and data stay dynamic. Each wrapper
# range-validates concrete page ids *before* dispatch (inside the trace they
# are tracers and would silently clamp), so the pre-engine ValueError
# behaviour is preserved on the jitted paths too.
_read_pages_any_jitted = jax.jit(read_pages_any)
_read_pages_any_status_jitted = jax.jit(read_pages_any_status)
_read_pages_any_writeback_jitted = jax.jit(read_pages_any_writeback)
_write_pages_any_jitted = jax.jit(write_pages_any, donate_argnums=(0,))
_write_pages_any_valid_jitted = jax.jit(
    lambda state, pages, data, valid: write_pages_any(state, pages, data,
                                                      valid=valid),
    donate_argnums=(0,))


def _migrate_within(state: PoolState, src_pages, dst_pages) -> PoolState:
    return write_pages_any(state, dst_pages,
                           read_pages_any(state, src_pages))


_migrate_within_jitted = jax.jit(_migrate_within, donate_argnums=(0,))
_migrate_within_jitted_nodonate = jax.jit(_migrate_within)


def read_pages_any_jit(state: PoolState, pages) -> jax.Array:
    """Jitted :func:`read_pages_any` (validates concrete ids host-side)."""
    arr = _as_page_array(state, pages)
    state.memprof_record("gather", arr)
    return _read_pages_any_jitted(state, arr)


def read_pages_any_status_jit(state: PoolState, pages
                              ) -> tuple[jax.Array, jax.Array]:
    """Jitted :func:`read_pages_any_status` (validates concrete ids)."""
    arr = _as_page_array(state, pages)
    state.memprof_record("gather", arr)
    return _read_pages_any_status_jitted(state, arr)


def write_pages_any_jit(state: PoolState, pages, data: jax.Array
                        ) -> PoolState:
    """Jitted, donating :func:`write_pages_any` (validates concrete ids).

    The donation invalidates the *input* pool's storage on backends with
    buffer donation — only use it when the old state is dropped immediately
    (as ``repro.vm`` does).
    """
    arr = _as_page_array(state, pages)
    state.memprof_record("scatter", arr)
    return _write_pages_any_jitted(state, arr, data)


@partial(jax.jit, donate_argnums=(2,))
def _migrate_pages(src: PoolState, src_pages, dst: PoolState,
                   dst_pages) -> PoolState:
    return write_pages_any(dst, dst_pages, read_pages_any(src, src_pages))


def migrate_pages(src: PoolState, src_pages, dst: PoolState,
                  dst_pages) -> PoolState:
    """One-program live migration: decode-corrected read from ``src`` and
    code-maintaining write into ``dst`` (whose storage is donated), fused
    under a single jit so the whole transaction's data plane is one dispatch.
    """
    return _migrate_pages(src, _as_page_array(src, src_pages),
                          dst, _as_page_array(dst, dst_pages))


# ---------------------------------------------------------------------------
# Repartitioning — the paper's dynamic boundary moves (§3.3, §4.3.1)
# ---------------------------------------------------------------------------


def evicted_extra_pages(state: PoolState, new_boundary: int) -> list[int]:
    """Extra-page ids a boundary move to ``new_boundary`` would evict.

    Pure prediction — lets an owner (the VM's migration engine) relocate the
    pages *before* calling :func:`repartition`, turning the paper's
    OS-visible capacity loss into a live migration instead of a drop.
    """
    if new_boundary >= state.boundary:
        return []
    new_extra = extra_page_count(state.layout, new_boundary, state.row_words)
    return list(range(state.num_rows + new_extra,
                      state.num_rows + state.num_extra_pages))


def repartition(state: PoolState, new_boundary: int
                ) -> tuple[PoolState, dict]:
    """Move the CREAM/SECDED boundary, re-encoding affected rows.

    Growing the SECDED region (boundary shrinks) evicts extra pages whose
    storage lived in reclaimed code lanes — their ids are returned so the
    owner (e.g. the KV-cache) can refetch/drop them, mirroring the OS-visible
    capacity change in the paper. Growing the CREAM region re-purposes code
    lanes into extra-page storage (zeroed).

    Page *contents* of regular pages are preserved across the move: rows
    entering the SECDED region get fresh codes; rows leaving it keep data and
    (for PARITY) get parity entries. Surviving *extra* pages are preserved
    too: PACKED / RANK_SUBSET / INTERWRAP extras have boundary-independent
    storage, and PARITY extras — whose physical home sits above the
    boundary-sized parity tables — are read out and re-homed under the new
    boundary, so every surviving page id keeps its contents.
    """
    if new_boundary % GROUP_ROWS or not 0 <= new_boundary <= state.num_rows:
        raise ValueError(f"bad boundary {new_boundary}")
    if new_boundary > state.daec_start:
        raise ValueError(
            f"boundary {new_boundary} would overlap the DAEC tier "
            f"[{state.daec_start}, {state.num_rows}) — shrink it first "
            "(set_daec_rows)")
    old = state.boundary
    info = {"old_boundary": old, "new_boundary": new_boundary,
            "evicted_extra_pages": [], "pages_reencoded": 0}
    if new_boundary == old:
        return state, info

    storage = state.storage

    # PARITY extra-page storage moves with the parity tables: snapshot the
    # survivors now (reads are functional — `state` never mutates) and
    # re-home them after the boundary move.
    extra_ids = None
    if state.layout == Layout.PARITY:
        new_extra = extra_page_count(state.layout, new_boundary,
                                     state.row_words)
        surviving = min(state.num_extra_pages, new_extra)
        if surviving:
            extra_ids = jnp.arange(state.num_rows,
                                   state.num_rows + surviving,
                                   dtype=jnp.int32)
            extra_data = read_pages_any(state, extra_ids)

    if new_boundary < old:  # CREAM region shrinks -> protect more rows
        # 1) All extra pages with storage above the new CREAM span are lost.
        info["evicted_extra_pages"] = evicted_extra_pages(state, new_boundary)
        # 2) Rows [new_boundary, old) need SECDED codes over their current
        #    data. Under INTERWRAP that data may be wrap-striped, so this is
        #    one batched logical read of the affected span, one batched
        #    encode, and two scatters (data rows + code lane).
        affected = jnp.arange(new_boundary, old, dtype=jnp.int32)
        data = read_pages_any(state, affected)
        storage = storage.at[affected, :DATA_LANES, :].set(
            data.reshape(-1, DATA_LANES, state.row_words))
        storage = storage.at[affected, CODE_LANE, :].set(
            secded.encode_block(data))
        info["pages_reencoded"] = old - new_boundary
        new_state = PoolState(storage, new_boundary, state.layout,
                              state.row_words, state.daec_rows)
    else:  # CREAM region grows -> reclaim code lanes
        # One batched decode of the surrendered span with its outgoing codes
        # (last chance to correct), then one batched re-place under the CREAM
        # layout (data scatter + code-lane scatter inside write_pages_any).
        tmp = PoolState(storage, new_boundary, state.layout, state.row_words,
                        state.daec_rows)
        affected = jnp.arange(old, new_boundary, dtype=jnp.int32)
        block = state.storage[affected, :DATA_LANES, :].reshape(
            affected.shape[0], -1)
        fixed, _, _ = secded.decode_block(
            block, state.storage[affected, CODE_LANE, :])
        new_state = write_pages_any(tmp, affected, fixed)
        info["pages_reencoded"] = new_boundary - old
    if extra_ids is not None:      # re-home surviving PARITY extras
        new_state = write_pages_any(new_state, extra_ids, extra_data)
    return new_state, info

"""CREAMPool — the ECC-DRAM module analogue, with the paper's boundary register.

A pool is a single uint32 buffer of shape ``(R, 9, W)`` (rows × lanes × words;
DESIGN.md §2.1). Rows ``[0, boundary)`` form the CREAM region (layout = one of
PACKED / RANK_SUBSET / INTERWRAP / PARITY); rows ``[boundary, R)`` keep the
conventional SECDED layout — the paper's §4.3.1 partitioning, with the same
page-id convention:

    pages [0, boundary)        CREAM-region regular pages (lanes 0–7 / wrap)
    pages [boundary, R)        SECDED-protected pages
    pages [R, R + extra)       extra pages reclaimed from the code lane

All state transforms are functional (old state in, new state out). Page-level
reads/writes with *static* page ids compose under jit; batched dynamic access
for hot paths (KV cache) is in :func:`read_pages_batch` /
:func:`write_pages_batch`, restricted to single-mode pools.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import parity8, secded
from repro.core.layouts import (CODE_LANE, DATA_LANES, DEFAULT_ROW_WORDS,
                                GROUP_ROWS, LANES, Layout, PagePlacement,
                                extra_page_count, place_page,
                                _parity_row_of_page)


@jax.tree_util.register_dataclass
@dataclass
class PoolState:
    """Functional pool state. ``storage`` is the only traced leaf."""
    storage: jax.Array  # (R, 9, W) uint32
    boundary: int = dataclasses.field(metadata=dict(static=True))
    layout: Layout = dataclasses.field(metadata=dict(static=True))
    row_words: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_rows(self) -> int:
        return self.storage.shape[0]

    @property
    def page_words(self) -> int:
        return DATA_LANES * self.row_words

    @property
    def page_bytes(self) -> int:
        return 4 * self.page_words

    @property
    def num_extra_pages(self) -> int:
        return extra_page_count(self.layout, self.boundary, self.row_words)

    @property
    def num_pages(self) -> int:
        """Effective page capacity = R regular + reclaimed extras."""
        return self.num_rows + self.num_extra_pages

    @property
    def raw_bytes(self) -> int:
        return self.storage.size * 4

    @property
    def effective_bytes(self) -> int:
        return self.num_pages * self.page_bytes

    def capacity_gain(self) -> float:
        """Fraction of baseline (all-SECDED) capacity reclaimed."""
        return self.num_extra_pages / self.num_rows


def make_pool(num_rows: int, layout: Layout = Layout.INTERWRAP,
              boundary: int | None = None,
              row_words: int = DEFAULT_ROW_WORDS) -> PoolState:
    """Create a zeroed pool. ``boundary=None`` puts the whole pool in CREAM mode."""
    if num_rows % GROUP_ROWS:
        raise ValueError(f"num_rows must be a multiple of {GROUP_ROWS}")
    boundary = num_rows if boundary is None else boundary
    if boundary % GROUP_ROWS or not 0 <= boundary <= num_rows:
        raise ValueError(f"bad boundary {boundary}")
    if layout == Layout.BASELINE_ECC and boundary != 0:
        boundary = 0  # whole pool SECDED
    storage = jnp.zeros((num_rows, LANES, row_words), dtype=jnp.uint32)
    return PoolState(storage, boundary, layout, row_words)


# ---------------------------------------------------------------------------
# Placement → jnp gather/scatter
# ---------------------------------------------------------------------------


def _placement(state: PoolState, page: int) -> PagePlacement:
    if page < state.boundary:
        return place_page(state.layout, state.boundary, page, state.row_words)
    if page < state.num_rows:
        return PagePlacement("rows", page)  # SECDED region
    # extra page: ids relative to the CREAM region
    rel = state.boundary + (page - state.num_rows)
    return place_page(state.layout, state.boundary, rel, state.row_words)


def _gather(state: PoolState, pl: PagePlacement) -> jax.Array:
    W = state.row_words
    if pl.kind == "rows":
        return state.storage[pl.row0, :DATA_LANES, :].reshape(-1)
    if pl.kind == "codelane":
        return state.storage[pl.row0:pl.row0 + GROUP_ROWS, CODE_LANE, :].reshape(-1)
    if pl.kind == "wrap":
        parts = [state.storage[row, lane, :] for lane, row in pl.slices]
        return jnp.concatenate(parts)
    raise ValueError(pl.kind)


def _scatter(state: PoolState, pl: PagePlacement, data: jax.Array) -> jax.Array:
    W = state.row_words
    s = state.storage
    if pl.kind == "rows":
        return s.at[pl.row0, :DATA_LANES, :].set(data.reshape(DATA_LANES, W))
    if pl.kind == "codelane":
        return s.at[pl.row0:pl.row0 + GROUP_ROWS, CODE_LANE, :].set(
            data.reshape(GROUP_ROWS, W))
    if pl.kind == "wrap":
        chunks = data.reshape(DATA_LANES, W)
        for k, (lane, row) in enumerate(pl.slices):
            s = s.at[row, lane, :].set(chunks[k])
        return s
    raise ValueError(pl.kind)


# ---------------------------------------------------------------------------
# Page read / write (static page id)
# ---------------------------------------------------------------------------


def read_page(state: PoolState, page: int) -> tuple[jax.Array, jax.Array]:
    """Read one 8KB page. Returns (data[8W], status[int32 scalar]).

    status: max SECDED/parity status over the page (0 clean, 1/2 corrected,
    3 detected-uncorrectable). Corrections are *reported*, not persisted —
    use :func:`scrub` to repair storage in place.
    """
    pl = _placement(state, page)
    data = _gather(state, pl)
    if page >= state.boundary and page < state.num_rows:
        codes = state.storage[pl.row0, CODE_LANE, :]
        data, _, st = secded.decode_block(data, codes)
        return data, jnp.max(st)
    if state.layout == Layout.PARITY and page < state.num_rows:
        prow = _parity_row_of_page(state.layout, state.boundary, page,
                                   state.row_words)
        off = (page % 8) * (state.row_words // 8)
        packed = jax.lax.dynamic_slice(
            state.storage[prow, CODE_LANE, :], (off,), (state.row_words // 8,))
        st = parity8.check_lines_packed(data, packed)
        return data, jnp.max(st) * 3  # corrupt -> DETECTED_UNCORRECTABLE
    if state.layout == Layout.PARITY and page >= state.num_rows:
        rel = state.boundary + (page - state.num_rows)
        prow = _parity_row_of_page(state.layout, state.boundary, rel,
                                   state.row_words)
        off = (rel % 8) * (state.row_words // 8)
        packed = jax.lax.dynamic_slice(
            state.storage[prow, CODE_LANE, :], (off,), (state.row_words // 8,))
        st = parity8.check_lines_packed(data, packed)
        return data, jnp.max(st) * 3
    return data, jnp.zeros((), jnp.int32)


def write_page(state: PoolState, page: int, data: jax.Array) -> PoolState:
    """Write one 8KB page, maintaining codes for protected pages."""
    data = data.astype(jnp.uint32).reshape(-1)
    if data.shape[0] != state.page_words:
        raise ValueError(f"page data must be {state.page_words} words")
    pl = _placement(state, page)
    storage = _scatter(state, pl, data)
    if page >= state.boundary and page < state.num_rows:
        codes = secded.encode_block(data)
        storage = storage.at[pl.row0, CODE_LANE, :].set(codes)
    elif state.layout == Layout.PARITY:
        rel = page if page < state.num_rows else \
            state.boundary + (page - state.num_rows)
        prow = _parity_row_of_page(state.layout, state.boundary, rel,
                                   state.row_words)
        off = (rel % 8) * (state.row_words // 8)
        packed = parity8.encode_lines_packed(data)
        storage = jax.lax.dynamic_update_slice(
            storage, packed[None, None, :],
            (prow, CODE_LANE, off))[..., :]  # update within the code lane
    return dataclasses.replace(state, storage=storage)


# ---------------------------------------------------------------------------
# Batched dynamic access (hot path: paged KV cache).
# Restricted to pools whose CREAM region covers everything and whose layout
# gives uniform single-op placement (INTERWRAP) or uniform row placement.
# ---------------------------------------------------------------------------


def _wrap_index_tables(boundary: int) -> tuple[np.ndarray, np.ndarray]:
    """lane/row tables: for slot s (0..8), the 8 (lane, rel_row) slices."""
    lanes = np.empty((9, 8), np.int32)
    rows = np.empty((9, 8), np.int32)
    for s in range(9):
        for k in range(8):
            linear = 8 * s + k
            lanes[s, k] = linear % LANES
            rows[s, k] = linear // LANES
    return lanes, rows


_WRAP_LANES, _WRAP_ROWS = _wrap_index_tables(0)


def page_to_wrap_coords(state: PoolState, pages: jax.Array
                        ) -> tuple[jax.Array, jax.Array]:
    """Vectorised (group, slot) -> (rows[n,8], lanes[n,8]) for INTERWRAP pools."""
    nr = state.num_rows
    is_extra = pages >= nr
    e = pages - nr
    group = jnp.where(is_extra, e, pages // GROUP_ROWS)
    slot = jnp.where(is_extra, GROUP_ROWS, pages % GROUP_ROWS)
    lanes = jnp.asarray(_WRAP_LANES)[slot]                  # (n, 8)
    rows = GROUP_ROWS * group[:, None] + jnp.asarray(_WRAP_ROWS)[slot]
    return rows, lanes


def read_pages_batch(state: PoolState, pages: jax.Array) -> jax.Array:
    """Gather a batch of pages -> (n, 8W) uint32.

    Fast paths: whole-pool INTERWRAP (the Pallas ``interwrap`` kernel's
    access; this jnp version is its oracle and the CPU path) and whole-pool
    SECDED (decode+correct on load).
    """
    if state.layout == Layout.INTERWRAP and state.boundary == state.num_rows:
        rows, lanes = page_to_wrap_coords(state, pages)
        return state.storage[rows, lanes, :].reshape(pages.shape[0], -1)
    if state.boundary == 0:  # whole pool conventional SECDED
        data = state.storage[pages, :DATA_LANES, :].reshape(
            pages.shape[0], -1)
        codes = state.storage[pages, CODE_LANE, :]
        fixed, _, _ = secded.decode_block(data, codes)
        return fixed
    raise ValueError("batched access requires a single-mode pool")


def read_pages_batch_status(state: PoolState, pages: jax.Array
                            ) -> tuple[jax.Array, jax.Array]:
    """Batched read + worst decode status (0 clean .. 3 uncorrectable)."""
    if state.boundary == 0:
        data = state.storage[pages, :DATA_LANES, :].reshape(
            pages.shape[0], -1)
        codes = state.storage[pages, CODE_LANE, :]
        fixed, _, status = secded.decode_block(data, codes)
        return fixed, jnp.max(status)
    return read_pages_batch(state, pages), jnp.zeros((), jnp.int32)


def write_pages_batch(state: PoolState, pages: jax.Array,
                      data: jax.Array) -> PoolState:
    """Scatter a batch of pages (n, 8W). Single-mode pools only."""
    data = data.astype(jnp.uint32)
    if state.layout == Layout.INTERWRAP and state.boundary == state.num_rows:
        rows, lanes = page_to_wrap_coords(state, pages)
        chunks = data.reshape(pages.shape[0], DATA_LANES, -1)
        storage = state.storage.at[rows, lanes, :].set(chunks)
        return dataclasses.replace(state, storage=storage)
    if state.boundary == 0:
        chunks = data.reshape(pages.shape[0], DATA_LANES, state.row_words)
        storage = state.storage.at[pages, :DATA_LANES, :].set(chunks)
        codes = secded.encode_block(data.reshape(pages.shape[0], -1))
        storage = storage.at[pages, CODE_LANE, :].set(codes)
        return dataclasses.replace(state, storage=storage)
    raise ValueError("batched access requires a single-mode pool")


# ---------------------------------------------------------------------------
# Mixed-pool batched access — any boundary, any page-id mix.
# SECDED rows and (for INTERWRAP) CREAM/extra pages take vectorised paths;
# other layouts fall back to per-page gather/scatter. Used by the VM layer
# (``repro.vm``) whose pools are routinely mixed-mode.
# ---------------------------------------------------------------------------


def read_pages_any(state: PoolState, pages) -> jax.Array:
    """Decode-corrected batch read for an arbitrary list of page ids.

    Unlike :func:`read_pages_batch` this handles mixed pools
    (``0 < boundary < num_rows``). Returns ``(n, page_words)`` uint32.
    """
    pages = [int(p) for p in pages]
    n = len(pages)
    bad = [p for p in pages if not 0 <= p < state.num_pages]
    if bad:
        raise ValueError(f"pages {bad} out of range [0, {state.num_pages})")
    if not n:
        return jnp.zeros((0, state.page_words), jnp.uint32)
    out: list = [None] * n
    sec = [i for i, p in enumerate(pages)
           if state.boundary <= p < state.num_rows]
    other = [i for i in range(n) if state.boundary > pages[i]
             or pages[i] >= state.num_rows]
    if sec:
        rows = jnp.asarray([pages[i] for i in sec], jnp.int32)
        data = state.storage[rows, :DATA_LANES, :].reshape(len(sec), -1)
        codes = state.storage[rows, CODE_LANE, :]
        fixed, _, _ = secded.decode_block(data, codes)
        for j, i in enumerate(sec):
            out[i] = fixed[j]
    if other:
        if state.layout == Layout.INTERWRAP:
            ids = jnp.asarray([pages[i] for i in other], jnp.int32)
            rows, lanes = page_to_wrap_coords(state, ids)
            data = state.storage[rows, lanes, :].reshape(len(other), -1)
            for j, i in enumerate(other):
                out[i] = data[j]
        else:
            for i in other:
                out[i], _ = read_page(state, pages[i])
    return jnp.stack(out)


def write_pages_any(state: PoolState, pages, data: jax.Array) -> PoolState:
    """Batch write for an arbitrary list of page ids, maintaining codes.

    Mixed-pool counterpart of :func:`write_pages_batch`; ``data`` is
    ``(n, page_words)``.
    """
    pages = [int(p) for p in pages]
    n = len(pages)
    bad = [p for p in pages if not 0 <= p < state.num_pages]
    if bad:
        raise ValueError(f"pages {bad} out of range [0, {state.num_pages})")
    if not n:
        return state
    data = data.astype(jnp.uint32).reshape(n, -1)
    if data.shape[1] != state.page_words:
        raise ValueError(f"page data must be {state.page_words} words")
    sec = [i for i, p in enumerate(pages)
           if state.boundary <= p < state.num_rows]
    other = [i for i in range(n) if state.boundary > pages[i]
             or pages[i] >= state.num_rows]
    if other:
        if state.layout == Layout.INTERWRAP:
            ids = jnp.asarray([pages[i] for i in other], jnp.int32)
            rows, lanes = page_to_wrap_coords(state, ids)
            chunks = data[jnp.asarray(other)].reshape(
                len(other), DATA_LANES, state.row_words)
            state = dataclasses.replace(
                state, storage=state.storage.at[rows, lanes, :].set(chunks))
        else:
            for i in other:
                state = write_page(state, pages[i], data[i])
    if sec:
        rows = jnp.asarray([pages[i] for i in sec], jnp.int32)
        block = data[jnp.asarray(sec)]
        storage = state.storage.at[rows, :DATA_LANES, :].set(
            block.reshape(len(sec), DATA_LANES, state.row_words))
        storage = storage.at[rows, CODE_LANE, :].set(secded.encode_block(block))
        state = dataclasses.replace(state, storage=storage)
    return state


# ---------------------------------------------------------------------------
# Repartitioning — the paper's dynamic boundary moves (§3.3, §4.3.1)
# ---------------------------------------------------------------------------


def evicted_extra_pages(state: PoolState, new_boundary: int) -> list[int]:
    """Extra-page ids a boundary move to ``new_boundary`` would evict.

    Pure prediction — lets an owner (the VM's migration engine) relocate the
    pages *before* calling :func:`repartition`, turning the paper's
    OS-visible capacity loss into a live migration instead of a drop.
    """
    if new_boundary >= state.boundary:
        return []
    new_extra = extra_page_count(state.layout, new_boundary, state.row_words)
    return list(range(state.num_rows + new_extra,
                      state.num_rows + state.num_extra_pages))


def repartition(state: PoolState, new_boundary: int
                ) -> tuple[PoolState, dict]:
    """Move the CREAM/SECDED boundary, re-encoding affected rows.

    Growing the SECDED region (boundary shrinks) evicts extra pages whose
    storage lived in reclaimed code lanes — their ids are returned so the
    owner (e.g. the KV-cache) can refetch/drop them, mirroring the OS-visible
    capacity change in the paper. Growing the CREAM region re-purposes code
    lanes into extra-page storage (zeroed).

    Page *contents* of regular pages are preserved across the move: rows
    entering the SECDED region get fresh codes; rows leaving it keep data and
    (for PARITY) get parity entries.
    """
    if new_boundary % GROUP_ROWS or not 0 <= new_boundary <= state.num_rows:
        raise ValueError(f"bad boundary {new_boundary}")
    old = state.boundary
    info = {"old_boundary": old, "new_boundary": new_boundary,
            "evicted_extra_pages": [], "pages_reencoded": 0}
    if new_boundary == old:
        return state, info

    storage = state.storage

    if new_boundary < old:  # CREAM region shrinks -> protect more rows
        # 1) All extra pages with storage above the new CREAM span are lost.
        info["evicted_extra_pages"] = evicted_extra_pages(state, new_boundary)
        # 2) Rows [new_boundary, old) need SECDED codes over their current data.
        for row in range(new_boundary, old):
            # Under INTERWRAP the row's data may be wrap-striped: read the
            # logical page first, then rewrite in conventional layout.
            data, _ = read_page(state, row)
            storage = storage.at[row, :DATA_LANES, :].set(
                data.reshape(DATA_LANES, state.row_words))
            storage = storage.at[row, CODE_LANE, :].set(secded.encode_block(data))
            info["pages_reencoded"] += 1
        new_state = PoolState(storage, new_boundary, state.layout,
                              state.row_words)
    else:  # CREAM region grows -> reclaim code lanes
        tmp = PoolState(storage, new_boundary, state.layout, state.row_words)
        for row in range(old, new_boundary):
            data = state.storage[row, :DATA_LANES, :].reshape(-1)
            # decode once with the outgoing codes (last chance to correct)
            data, _, _ = secded.decode_block(data, state.storage[row, CODE_LANE, :])
            tmp = write_page(tmp, row, data)   # re-place under CREAM layout
            info["pages_reencoded"] += 1
        # zero reclaimed code lanes that are now extra-page storage
        new_state = tmp
    return new_state, info

"""RegionManager — named reliability domains over CREAM pools (paper Fig. 1).

Each region ("weights", "opt_state", "kv_cache", ...) owns a pool whose
boundary register splits it into a SECDED part and a CREAM part. The adaptive
controller closes the loop the paper envisions in §3.3:

    scrub -> monitor -> recommend -> repartition (move the boundary)

Protection levels map to boundary positions:
    SECDED -> boundary = 0          (whole pool conventional ECC layout)
    PARITY -> boundary = num_rows   with Layout.PARITY
    NONE   -> boundary = num_rows   with a correction-free layout

Mixed within one region is also supported (fractional boundary), which is
what the Fig.12-style sensitivity sweep exercises.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.layouts import GROUP_ROWS, Layout
from repro.core.monitor import ErrorMonitor, MonitorConfig
from repro.core.pool import PoolState, make_pool, repartition
from repro.core.protection import (Protection, RegionSpec, default_layout)
from repro.core.scrubber import ScrubStats, scrub


@dataclass
class Region:
    spec: RegionSpec
    pool: PoolState
    evictions: list[int] = field(default_factory=list)  # pending owner action

    @property
    def protection(self) -> Protection:
        return self.spec.protection

    @property
    def capacity_pages(self) -> int:
        return self.pool.num_pages


def _boundary_for(protection: Protection, rows: int) -> int:
    return 0 if protection == Protection.SECDED else rows


def _layout_for(protection: Protection, rows_layout: Layout | None) -> Layout:
    if protection == Protection.SECDED:
        # Layout choice is irrelevant when boundary==0, but keep a CREAM
        # layout on the state so future downgrades don't re-create the pool.
        return rows_layout or Layout.INTERWRAP
    return rows_layout or default_layout(protection)


class RegionManager:
    """Owns regions, runs the scrub/monitor/repartition loop."""

    def __init__(self, monitor_config: MonitorConfig | None = None):
        self.regions: dict[str, Region] = {}
        self.monitor = ErrorMonitor(monitor_config)
        self.transitions: list[tuple[str, Protection, Protection]] = []

    # -- setup -------------------------------------------------------------
    def add_region(self, spec: RegionSpec) -> Region:
        if spec.rows % GROUP_ROWS:
            raise ValueError("region rows must be group-aligned")
        layout = _layout_for(spec.protection, spec.layout
                             if spec.protection != Protection.SECDED else None)
        pool = make_pool(spec.rows, layout,
                         boundary=_boundary_for(spec.protection, spec.rows))
        region = Region(spec, pool)
        self.regions[spec.name] = region
        return region

    # -- accounting ---------------------------------------------------------
    def total_capacity_pages(self) -> int:
        return sum(r.capacity_pages for r in self.regions.values())

    def capacity_report(self) -> dict[str, dict]:
        out = {}
        for name, r in self.regions.items():
            out[name] = {
                "protection": r.protection.value,
                "layout": r.pool.layout.value,
                "rows": r.pool.num_rows,
                "boundary": r.pool.boundary,
                "pages": r.capacity_pages,
                "gain": r.pool.capacity_gain(),
            }
        return out

    # -- adaptation loop ----------------------------------------------------
    def scrub_all(self, use_kernel: bool = False) -> dict[str, ScrubStats]:
        stats = {}
        for name, region in self.regions.items():
            region.pool, s = scrub(region.pool, use_kernel=use_kernel)
            self.monitor.record(name, s)
            stats[name] = s
        return stats

    def adapt(self) -> list[tuple[str, Protection, Protection]]:
        """Apply monitor recommendations; returns performed transitions."""
        performed = []
        for name, region in self.regions.items():
            cur = region.protection
            rec = self.monitor.recommend(
                name, cur, floor=region.spec.min_protection,
                ceiling=region.spec.max_protection)
            if rec == cur:
                continue
            self._transition(region, rec)
            self.monitor.acknowledge_transition(name)
            performed.append((name, cur, rec))
            self.transitions.append((name, cur, rec))
        return performed

    def set_protection(self, name: str, protection: Protection) -> None:
        """Operator-forced transition (e.g. SLA change for a tenant)."""
        region = self.regions[name]
        if region.protection != protection:
            self._transition(region, protection)

    def _transition(self, region: Region, protection: Protection) -> None:
        """Repartition the region's pool to realise ``protection``.

        SECDED<->CREAM uses the boundary register (cheap, data-preserving).
        Changing the CREAM *layout* (e.g. NONE/interwrap -> PARITY) re-creates
        the CREAM part through the boundary: shrink to 0 (conventional
        layout), swap the layout tag, grow back — contents preserved.
        """
        pool = region.pool
        target_layout = default_layout(protection) \
            if protection != Protection.SECDED else pool.layout
        if protection == Protection.SECDED:
            pool, info = repartition(pool, 0)
            region.evictions += info["evicted_extra_pages"]
        else:
            if pool.layout != target_layout and pool.boundary > 0:
                pool, info = repartition(pool, 0)
                region.evictions += info["evicted_extra_pages"]
            if pool.layout != target_layout:
                import dataclasses
                pool = dataclasses.replace(pool, layout=target_layout)
            pool, info = repartition(pool, pool.num_rows)
        region.pool = pool
        spec_layout = Layout.BASELINE_ECC if protection == Protection.SECDED \
            else pool.layout
        region.spec = RegionSpec(
            region.spec.name, protection, spec_layout, region.spec.rows,
            min_protection=region.spec.min_protection,
            max_protection=region.spec.max_protection)

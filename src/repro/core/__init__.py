"""CREAM core — Capacity- and Reliability-Adaptive Memory in JAX.

The paper's contribution as a composable library:

  * :mod:`repro.core.secded`    — Hsiao SECDED(72,64), vectorised jnp
  * :mod:`repro.core.parity8`   — 8-bit-per-line detection code
  * :mod:`repro.core.layouts`   — Solutions 1–3 + parity address translation
  * :mod:`repro.core.pool`      — the ECC-DRAM-module analogue w/ boundary register
  * :mod:`repro.core.scrubber`  — in-place repair sweeps
  * :mod:`repro.core.monitor`   — health tracking + protection recommendations
  * :mod:`repro.core.regions`   — named reliability domains, adaptation loop
  * :mod:`repro.core.softecc`   — the Virtualized-ECC comparison baseline
  * :mod:`repro.core.injection` — fault models for tests/experiments
"""
from repro.core.layouts import Layout, page_coords
from repro.core.pool import (PoolState, evicted_extra_pages, make_pool,
                             migrate_pages, read_page, read_pages_any,
                             read_pages_any_status, read_pages_batch,
                             repartition, write_page, write_pages_any,
                             write_pages_batch)
from repro.core.protection import Protection, RegionSpec
from repro.core.regions import Region, RegionManager
from repro.core.scrubber import ScrubStats, scrub

__all__ = [
    "Layout", "page_coords", "PoolState", "make_pool", "read_page",
    "write_page", "read_pages_batch", "write_pages_batch", "read_pages_any",
    "read_pages_any_status", "write_pages_any", "migrate_pages",
    "evicted_extra_pages", "repartition", "Protection",
    "RegionSpec", "Region", "RegionManager", "ScrubStats", "scrub",
]

"""SEC-DAEC(144,128) code — the ladder rung above SECDED.

Dutta & Touba's SEC-DAEC class corrects any single-bit error AND any
*adjacent* double-bit error (the dominant multi-bit upset shape in DRAM:
two physically neighbouring cells of one word, `core.injection`'s
``adjacent_double``). We realise it as **two bit-interleaved Hsiao(72,64)
codewords per 128-bit superbeat** — the construction memory controllers
actually ship, because interleaving turns adjacency into independence:

  * A *superbeat* is 4 consecutive uint32 words (128 data bits). Even
    physical bits (0, 2, 4, …) form codeword **A**, odd bits codeword
    **B**; each codeword is a plain Hsiao(72,64) over its 64 bits.
  * Any adjacent double-bit error hits one even and one odd bit — a
    *single* error in each codeword — so both bits are corrected and the
    data survives exact. (A direct (72,64) code cannot deliver this with
    zero miscorrection: with odd-weight 8-bit columns every even-weight
    syndrome is reachable by ≥16 distinct column pairs, so some double
    would miscorrect. Doubling the syndrome space removes the collision.)
  * A random double in the *same* codeword (two even bits, or two odd
    bits) is Hsiao-detected — never silent, never miscorrected. A random
    double split across codewords is corrected outright. Either way the
    never-silent contract holds.
  * The two 8-bit Hsiao codes bit-interleave into one 16-bit code field
    (bit 2i = code-A bit i, bit 2i+1 = code-B bit i), two fields per
    uint32 — so 128 data bits carry 16 code bits and the packed code
    plane has EXACTLY the shapes of :mod:`repro.core.secded`
    (``(..., D) -> (..., D//8)``). DAEC rows drop into the same code
    lane, the same gathers, and the same kernels' tiling; the price is
    compute (two Hsiao passes), not capacity.

Everything here is pure jnp (usable inside Pallas kernels and as the
oracle for ``repro.kernels.daec``). Status codes are shared with
:mod:`repro.core.secded`; ``decode_block`` reports per-64-bit-beat status
(the superbeat verdict broadcast to both constituent beats) so callers
treat SECDED and DAEC blocks interchangeably.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import secded
from repro.core.secded import (CLEAN, CORRECTED_CODE,  # noqa: F401
                               CORRECTED_DATA, DETECTED_UNCORRECTABLE)

NUM_DATA_BITS = 128
NUM_CODE_BITS = 16
SUPERBEAT_WORDS = 4        # uint32 words per superbeat


def _compact_even(x: jax.Array) -> jax.Array:
    """Gather the 16 even bits of a uint32 into its low half (Morton)."""
    x = x & jnp.uint32(0x55555555)
    x = (x | (x >> 1)) & jnp.uint32(0x33333333)
    x = (x | (x >> 2)) & jnp.uint32(0x0F0F0F0F)
    x = (x | (x >> 4)) & jnp.uint32(0x00FF00FF)
    x = (x | (x >> 8)) & jnp.uint32(0x0000FFFF)
    return x


def _spread_even(x: jax.Array) -> jax.Array:
    """Inverse of :func:`_compact_even`: low 16 bits -> even positions."""
    x = x & jnp.uint32(0x0000FFFF)
    x = (x | (x << 8)) & jnp.uint32(0x00FF00FF)
    x = (x | (x << 4)) & jnp.uint32(0x0F0F0F0F)
    x = (x | (x << 2)) & jnp.uint32(0x33333333)
    x = (x | (x << 1)) & jnp.uint32(0x55555555)
    return x


def _spread16(v: int) -> int:
    """Host-side 8->16 even-bit spread (H-matrix construction)."""
    v &= 0xFF
    v = (v | (v << 4)) & 0x0F0F
    v = (v | (v << 2)) & 0x3333
    v = (v | (v << 1)) & 0x5555
    return v


def _build_daec_columns() -> np.ndarray:
    """The 144 H-matrix columns in the 16-bit interleaved-syndrome view.

    Column ``p < 128`` is the syndrome of an error in data bit ``p`` of the
    superbeat (Hsiao column ``p >> 1`` of codeword A or B, spread to the
    even or odd syndrome bits); columns ``128 + q`` are the 16 check bits
    (unit vectors). Invariants property-tested in
    ``tests/test_codec_conformance.py``: all columns distinct and nonzero,
    and every adjacent-column pair XORs to a value that is distinct across
    pairs and collides with no single column — the defining SEC-DAEC
    condition.
    """
    cols = [_spread16(int(secded._COLUMNS[p >> 1])) << (p & 1)
            for p in range(NUM_DATA_BITS)]
    cols += [1 << q for q in range(NUM_CODE_BITS)]
    return np.asarray(cols, dtype=np.uint32)


_COLUMNS = _build_daec_columns()
H_COLUMNS = jnp.asarray(_COLUMNS.astype(np.int32))


def split_superbeats(data: jax.Array
                     ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """(..., 4k) uint32 -> (w0, w1, w2, w3) each (..., k): superbeat j =
    words (4j, 4j+1, 4j+2, 4j+3)."""
    if data.shape[-1] % SUPERBEAT_WORDS:
        raise ValueError(f"last dim must be a multiple of 4, got {data.shape}")
    g = data.reshape(*data.shape[:-1], data.shape[-1] // SUPERBEAT_WORDS,
                     SUPERBEAT_WORDS)
    return g[..., 0], g[..., 1], g[..., 2], g[..., 3]


def merge_superbeats(w0, w1, w2, w3) -> jax.Array:
    """Inverse of :func:`split_superbeats`."""
    return jnp.stack([w0, w1, w2, w3], axis=-1).reshape(
        *w0.shape[:-1], w0.shape[-1] * SUPERBEAT_WORDS)


def _deinterleave(w0, w1, w2, w3):
    """Superbeat words -> ((a_lo, a_hi), (b_lo, b_hi)) codeword planes."""
    e = [_compact_even(w.astype(jnp.uint32)) for w in (w0, w1, w2, w3)]
    o = [_compact_even(w.astype(jnp.uint32) >> 1) for w in (w0, w1, w2, w3)]
    a_lo = e[0] | (e[1] << 16)
    a_hi = e[2] | (e[3] << 16)
    b_lo = o[0] | (o[1] << 16)
    b_hi = o[2] | (o[3] << 16)
    return (a_lo, a_hi), (b_lo, b_hi)


def _interleave(a_lo, a_hi, b_lo, b_hi):
    """Codeword planes -> superbeat words (inverse of :func:`_deinterleave`)."""
    mask = jnp.uint32(0xFFFF)
    w0 = _spread_even(a_lo & mask) | (_spread_even(b_lo & mask) << 1)
    w1 = _spread_even(a_lo >> 16) | (_spread_even(b_lo >> 16) << 1)
    w2 = _spread_even(a_hi & mask) | (_spread_even(b_hi & mask) << 1)
    w3 = _spread_even(a_hi >> 16) | (_spread_even(b_hi >> 16) << 1)
    return w0, w1, w2, w3


def encode_words(w0, w1, w2, w3) -> jax.Array:
    """16-bit DAEC code field for 128-bit superbeats given as 4 word planes.

    Returns a uint32 array (same shape as each plane) with values in
    [0, 65536): bit 2i = codeword-A Hsiao bit i, bit 2i+1 = codeword-B.
    """
    (a_lo, a_hi), (b_lo, b_hi) = _deinterleave(w0, w1, w2, w3)
    code_a = secded.encode_words(a_lo, a_hi)
    code_b = secded.encode_words(b_lo, b_hi)
    return _spread_even(code_a) | (_spread_even(code_b) << 1)


def decode_words(w0, w1, w2, w3, field) -> tuple[
        jax.Array, jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Check + correct 128-bit superbeats against stored 16-bit code fields.

    Returns ``(w0', w1', w2', w3', field', status)`` with ``status`` one
    per superbeat: the worse of the two constituent Hsiao verdicts
    (CLEAN / CORRECTED_DATA / CORRECTED_CODE / DETECTED_UNCORRECTABLE).
    """
    field = field.astype(jnp.uint32) & jnp.uint32(0xFFFF)
    (a_lo, a_hi), (b_lo, b_hi) = _deinterleave(w0, w1, w2, w3)
    code_a = _compact_even(field)
    code_b = _compact_even(field >> 1)
    a_lo, a_hi, code_a, st_a = secded.decode_words(a_lo, a_hi, code_a)
    b_lo, b_hi, code_b, st_b = secded.decode_words(b_lo, b_hi, code_b)
    w0, w1, w2, w3 = _interleave(a_lo, a_hi, b_lo, b_hi)
    field = _spread_even(code_a) | (_spread_even(code_b) << 1)
    return w0, w1, w2, w3, field, jnp.maximum(st_a, st_b)


# ---------------------------------------------------------------------------
# Block-level helpers — shape-identical to repro.core.secded so DAEC rows
# share the SECDED code lane, gathers, and kernel tiling unchanged.
# ---------------------------------------------------------------------------


def pack_fields(fields: jax.Array) -> jax.Array:
    """(..., k) uint32 16-bit values -> (..., k//2) uint32, 2 per word."""
    if fields.shape[-1] % 2:
        raise ValueError(f"field count must be even, got {fields.shape}")
    g = fields.reshape(*fields.shape[:-1], fields.shape[-1] // 2, 2).astype(
        jnp.uint32)
    return (g[..., 0] | (g[..., 1] << 16)).astype(jnp.uint32)


def unpack_fields(packed: jax.Array) -> jax.Array:
    """(..., m) uint32 -> (..., 2m) uint32 16-bit values."""
    shifts = jnp.asarray([0, 16], dtype=jnp.uint32)
    fields = (packed[..., None] >> shifts) & jnp.uint32(0xFFFF)
    return fields.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


def encode_block(data: jax.Array) -> jax.Array:
    """Encode a data block into its packed DAEC code plane.

    Args:
      data: uint32 (..., D) with D % 8 == 0 — same contract as
            :func:`repro.core.secded.encode_block`.
    Returns:
      uint32 (..., D//8) packed 16-bit code fields (2 per word) — the same
      shape SECDED packs, so the pool's code lane holds either.
    """
    w0, w1, w2, w3 = split_superbeats(data.astype(jnp.uint32))
    return pack_fields(encode_words(w0, w1, w2, w3))


def decode_block(data: jax.Array, packed_fields: jax.Array
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Check + correct a data block against its packed DAEC code plane.

    Returns ``(data', packed_fields', status)`` — status is per 64-bit beat
    (..., D//2) int32 (each superbeat's verdict broadcast to its two
    beats), matching :func:`repro.core.secded.decode_block`'s shape.
    """
    w0, w1, w2, w3 = split_superbeats(data.astype(jnp.uint32))
    fields = unpack_fields(packed_fields)
    w0, w1, w2, w3, fields, st = decode_words(w0, w1, w2, w3, fields)
    status = jnp.stack([st, st], axis=-1).reshape(
        *st.shape[:-1], st.shape[-1] * 2)
    return merge_superbeats(w0, w1, w2, w3), pack_fields(fields), status

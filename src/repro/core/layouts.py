"""CREAM data layouts — address translation for the paper's Solutions 1–3 + parity.

This module is the single source of truth for *where bytes live* under each
CREAM layout. It is consumed by:

  * ``repro.core.pool``       — page-granularity jnp gather/scatter,
  * ``repro.kernels.interwrap`` — the Pallas S3 re-striping kernel,
  * ``benchmarks.dram_sim``   — line-granularity access plans for the
                                 Ramulator-style timing model (Figs. 9–12).

Geometry (DESIGN.md §2.1): a pool region is ``(R, 9, W)`` uint32 — R rows,
9 lanes (8 data + 1 code, the DIMM's chips), W words per lane per row
(default 256 → 8KB data + 1KB code per row, one "OS page" per row as in the
paper's simplified figures). A cache line is 64B = 16 words; each row holds
``8W/16 = W/2`` lines (128 for W=256).

Layout catalogue
----------------
BASELINE_ECC   paper Fig. 3 — data lanes 0–7, SECDED codes in lane 8.
PACKED         paper §4.1.1 (Solution 1) — extra pages packed into lane 8
               across 8 consecutive rows; every write is a read-modify-write.
RANK_SUBSET    paper §4.1.2 (Solution 2) — same placement, but lane 8 is an
               independently addressable plane: no RMWs, extra reads still 8 ops.
INTERWRAP      paper §4.1.3 (Solution 3) — within each 8-row group the
               72 (row×lane) slices are linearised ℓ = row·9 + lane and page
               p ∈ [0,9) owns slices [8p, 8p+8): every access is one operation
               touching 8 lanes (skipping lane (8−p) mod 9 — the paper's bridge
               formula) and 9 pages are independently accessible.
PARITY         paper §4.2 — lane 8 holds an 8-bit-parity table (1B per 64B
               line; one code row covers 8 pages) plus packed extra pages.
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field

LANES = 9
DATA_LANES = 8
CODE_LANE = 8
DEFAULT_ROW_WORDS = 256          # uint32 words per lane per row (1KB)
WORDS_PER_LINE = 16              # 64-byte cache line
GROUP_ROWS = 8                   # packing / wrap-around group (paper's 8 banks)


class Layout(enum.Enum):
    BASELINE_ECC = "baseline_ecc"
    PACKED = "packed"
    RANK_SUBSET = "rank_subset"
    INTERWRAP = "interwrap"
    PARITY = "parity"


#: Extra effective capacity per layout, as a fraction of the 8-lane data
#: capacity (paper: +12.5% correction-free, +10.7% detection-only).
CAPACITY_GAIN = {
    Layout.BASELINE_ECC: 0.0,
    Layout.PACKED: 1.0 / 8.0,
    Layout.RANK_SUBSET: 1.0 / 8.0,
    Layout.INTERWRAP: 1.0 / 8.0,
    Layout.PARITY: (9.0 / 8.0) / (1.0 + 1.0 / 64.0) - 1.0,  # ≈ 10.77%
}


def lines_per_row(row_words: int = DEFAULT_ROW_WORDS) -> int:
    return DATA_LANES * row_words // WORDS_PER_LINE


# ---------------------------------------------------------------------------
# Capacity accounting
# ---------------------------------------------------------------------------


def parity_table_rows(num_rows: int, extra_pages: int, row_words: int) -> int:
    """Code-lane rows reserved for parity tables (regular + extra pages).

    One code-lane row (``row_words`` words) holds parity for
    ``row_words / (row_words // 8)`` = 8 pages (W/8 words per page) — the
    paper's "each row of parity in Chip 8 contains the parity data for eight
    pages".
    """
    pages_per_parity_row = 8
    return math.ceil(num_rows / pages_per_parity_row) + math.ceil(
        extra_pages / pages_per_parity_row
    )


def extra_page_count(layout: Layout, num_rows: int,
                     row_words: int = DEFAULT_ROW_WORDS) -> int:
    """Number of extra (reclaimed-capacity) pages a region of `num_rows` offers."""
    if layout == Layout.BASELINE_ECC:
        return 0
    if layout in (Layout.PACKED, Layout.RANK_SUBSET, Layout.INTERWRAP):
        return num_rows // GROUP_ROWS
    if layout == Layout.PARITY:
        # Iterate: extra pages consume 8 code rows each, plus parity tables.
        extra = 0
        while True:
            used = parity_table_rows(num_rows, extra + 1, row_words)
            if used + (extra + 1) * GROUP_ROWS > num_rows:
                return extra
            extra += 1
    raise ValueError(layout)


def total_pages(layout: Layout, num_rows: int,
                row_words: int = DEFAULT_ROW_WORDS) -> int:
    return num_rows + extra_page_count(layout, num_rows, row_words)


# ---------------------------------------------------------------------------
# Physical access plans (line granularity — DRAM-sim / overhead accounting)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Access:
    """One DRAM operation: a lockstep fetch/store of ≤9 (row, lane) slices.

    ``slices`` maps lane -> row. For all layouts except INTERWRAP every touched
    lane reads the same row; INTERWRAP ops may straddle two adjacent rows
    (the paper's two banks opened by the bridge chip).
    """
    slices: tuple[tuple[int, int], ...]  # ((lane, row), ...)
    write: bool = False
    rmw: bool = False                    # requires read-before-write

    @property
    def rows(self) -> tuple[int, ...]:
        return tuple(sorted({r for _, r in self.slices}))

    @property
    def lanes(self) -> tuple[int, ...]:
        return tuple(sorted({l for l, _ in self.slices}))

    def num_device_ops(self) -> int:
        """DRAM command count this access expands to (RMW = read + write)."""
        return 2 if self.rmw else 1


def _full_row(row: int, lanes: range | tuple, write: bool, rmw: bool = False
              ) -> Access:
    return Access(tuple((l, row) for l in lanes), write=write, rmw=rmw)


def interwrap_slices(page_slot: int) -> tuple[tuple[int, int], ...]:
    """(lane, group-relative row) slices owned by page slot s ∈ [0, 9).

    Paper §4.1.3: linear slice ℓ = row·9 + lane; slot s owns ℓ ∈ [8s, 8s+8).
    The skipped lane is (8 − s) mod 9.
    """
    if not 0 <= page_slot < 9:
        raise ValueError(page_slot)
    out = []
    for k in range(8):
        linear = 8 * page_slot + k
        out.append((linear % LANES, linear // LANES))
    return tuple(out)


def plan_line_access(layout: Layout, num_rows: int, page: int, write: bool,
                     row_words: int = DEFAULT_ROW_WORDS) -> list[Access]:
    """Access plan for one 64B line of logical ``page`` in a CREAM region.

    Page id space: [0, num_rows) are regular pages; [num_rows, total) are
    extra pages. Line index within the page does not change op structure
    (only column addresses), so it is not a parameter.
    """
    n_extra = extra_page_count(layout, num_rows, row_words)
    if not 0 <= page < num_rows + n_extra:
        raise ValueError(f"page {page} out of range for {layout} x {num_rows}")
    is_extra = page >= num_rows
    e = page - num_rows

    if layout == Layout.BASELINE_ECC:
        # One lockstep op across all 9 chips, for reads and writes alike.
        return [_full_row(page, range(LANES), write)]

    if layout == Layout.PACKED:
        if not is_extra:
            # Reads fetch all 9 lanes (lane-8 data ignored); writes must RMW
            # because lane 8 holds another page's data (paper §4.1.1).
            return [_full_row(page, range(LANES), write, rmw=write)]
        # Extra page: line lives in lane 8 of one row, split over 8 column
        # segments -> 8 back-to-back ops, same row (≤1 row miss).
        row = GROUP_ROWS * e + 0  # part index affects the row; one line maps
        # to part (line // 16); callers that care pass per-line rows via
        # plan_extra_line_row(). For op counting the row is representative.
        return [_full_row(row, range(LANES), write, rmw=write)
                for _ in range(8)]

    if layout == Layout.RANK_SUBSET:
        if not is_extra:
            return [_full_row(page, range(DATA_LANES), write)]
        row = GROUP_ROWS * e
        return [_full_row(row, (CODE_LANE,), write) for _ in range(8)]

    if layout == Layout.INTERWRAP:
        group, slot = (page // GROUP_ROWS, page % GROUP_ROWS) if not is_extra \
            else (e, GROUP_ROWS)
        rel = interwrap_slices(slot)
        slices = tuple((lane, GROUP_ROWS * group + r) for lane, r in rel)
        return [Access(slices, write=write)]

    if layout == Layout.PARITY:
        # Rank-subset base + parity ops on lane 8 (paper §4.2).
        parity_row = _parity_row_of_page(layout, num_rows, page, row_words)
        parity_op = Access(((CODE_LANE, parity_row),), write=write, rmw=write)
        if not is_extra:
            return [_full_row(page, range(DATA_LANES), write), parity_op]
        data_row0 = _parity_extra_data_row0(num_rows, n_extra, e, row_words)
        ops = [_full_row(data_row0, (CODE_LANE,), write) for _ in range(8)]
        return ops + [parity_op]

    raise ValueError(layout)


def _parity_row_of_page(layout: Layout, num_rows: int, page: int,
                        row_words: int) -> int:
    """Code-lane row holding ``page``'s parity. Regular table first, then extra.

    Note: the paper additionally stores the parity for bank i in bank
    (i+4) mod 8 to dodge row-buffer conflicts — a *timing* placement detail.
    The pool keeps tables contiguous; ``benchmarks.dram_sim`` applies the
    bank swap when mapping rows to banks.
    """
    if page < num_rows:
        return page // 8
    return math.ceil(num_rows / 8) + (page - num_rows) // 8


def _parity_extra_data_row0(num_rows: int, n_extra: int, e: int,
                            row_words: int) -> int:
    tables = parity_table_rows(num_rows, n_extra, row_words)
    return tables + GROUP_ROWS * e


def count_device_ops(layout: Layout, num_rows: int, page: int, write: bool,
                     row_words: int = DEFAULT_ROW_WORDS) -> int:
    """Total DRAM commands for one line access (the paper's Fig. 10a metric)."""
    return sum(a.num_device_ops()
               for a in plan_line_access(layout, num_rows, page, write, row_words))


def parallelism_groups(layout: Layout) -> int:
    """Independently accessible page groups per 8-row group (Fig. 10b driver).

    Baseline/packed: the 8 rows (banks). Rank-subset: 8 + the lane-8 subset.
    Interwrap: 9 — all 72 lane-slices form nine independent groups (paper
    §4.1.3 "we are able to sustain nine concurrent requests at any time").
    """
    return {Layout.BASELINE_ECC: 8, Layout.PACKED: 8, Layout.RANK_SUBSET: 9,
            Layout.INTERWRAP: 9, Layout.PARITY: 9}[layout]


# ---------------------------------------------------------------------------
# Page-granularity placement (jnp pool gather/scatter)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PagePlacement:
    """Where a logical page's 8KB of data lives, as dense slice descriptors.

    kind:
      'rows'      data = region[row, 0:8, :]               (one row, 8 lanes)
      'codelane'  data = region[row0:row0+8, 8, :]          (8 rows of lane 8)
      'wrap'      data = 8 (lane, row) slices, lane-rotated (interwrap)
    """
    kind: str
    row0: int
    slices: tuple[tuple[int, int], ...] = field(default=())


def place_page(layout: Layout, num_rows: int, page: int,
               row_words: int = DEFAULT_ROW_WORDS) -> PagePlacement:
    n_extra = extra_page_count(layout, num_rows, row_words)
    if not 0 <= page < num_rows + n_extra:
        raise ValueError(f"page {page} out of range")
    is_extra = page >= num_rows
    e = page - num_rows

    if layout == Layout.BASELINE_ECC:
        return PagePlacement("rows", page)
    if layout in (Layout.PACKED, Layout.RANK_SUBSET):
        if not is_extra:
            return PagePlacement("rows", page)
        return PagePlacement("codelane", GROUP_ROWS * e)
    if layout == Layout.INTERWRAP:
        group, slot = (page // GROUP_ROWS, page % GROUP_ROWS) if not is_extra \
            else (e, GROUP_ROWS)
        rel = interwrap_slices(slot)
        return PagePlacement(
            "wrap", GROUP_ROWS * group,
            tuple((lane, GROUP_ROWS * group + r) for lane, r in rel))
    if layout == Layout.PARITY:
        if not is_extra:
            return PagePlacement("rows", page)
        return PagePlacement(
            "codelane", _parity_extra_data_row0(num_rows, n_extra, e, row_words))
    raise ValueError(layout)

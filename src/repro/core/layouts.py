"""CREAM data layouts — address translation for the paper's Solutions 1–3 + parity.

This module is the single source of truth for *where bytes live* under each
CREAM layout. It is consumed by:

  * ``repro.core.pool``       — page-granularity jnp gather/scatter,
  * ``repro.kernels.interwrap`` — the Pallas S3 re-striping kernel,
  * ``benchmarks.dram_sim``   — line-granularity access plans for the
                                 Ramulator-style timing model (Figs. 9–12).

Geometry (DESIGN.md §2.1): a pool region is ``(R, 9, W)`` uint32 — R rows,
9 lanes (8 data + 1 code, the DIMM's chips), W words per lane per row
(default 256 → 8KB data + 1KB code per row, one "OS page" per row as in the
paper's simplified figures). A cache line is 64B = 16 words; each row holds
``8W/16 = W/2`` lines (128 for W=256).

Layout catalogue
----------------
BASELINE_ECC   paper Fig. 3 — data lanes 0–7, SECDED codes in lane 8.
PACKED         paper §4.1.1 (Solution 1) — extra pages packed into lane 8
               across 8 consecutive rows; every write is a read-modify-write.
RANK_SUBSET    paper §4.1.2 (Solution 2) — same placement, but lane 8 is an
               independently addressable plane: no RMWs, extra reads still 8 ops.
INTERWRAP      paper §4.1.3 (Solution 3) — within each 8-row group the
               72 (row×lane) slices are linearised ℓ = row·9 + lane and page
               p ∈ [0,9) owns slices [8p, 8p+8): every access is one operation
               touching 8 lanes (skipping lane (8−p) mod 9 — the paper's bridge
               formula) and 9 pages are independently accessible.
PARITY         paper §4.2 — lane 8 holds an 8-bit-parity table (1B per 64B
               line; one code row covers 8 pages) plus packed extra pages.
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

LANES = 9
DATA_LANES = 8
CODE_LANE = 8
DEFAULT_ROW_WORDS = 256          # uint32 words per lane per row (1KB)
WORDS_PER_LINE = 16              # 64-byte cache line
GROUP_ROWS = 8                   # packing / wrap-around group (paper's 8 banks)


class Layout(enum.Enum):
    BASELINE_ECC = "baseline_ecc"
    PACKED = "packed"
    RANK_SUBSET = "rank_subset"
    INTERWRAP = "interwrap"
    PARITY = "parity"


#: Extra effective capacity per layout, as a fraction of the 8-lane data
#: capacity (paper: +12.5% correction-free, +10.7% detection-only).
CAPACITY_GAIN = {
    Layout.BASELINE_ECC: 0.0,
    Layout.PACKED: 1.0 / 8.0,
    Layout.RANK_SUBSET: 1.0 / 8.0,
    Layout.INTERWRAP: 1.0 / 8.0,
    Layout.PARITY: (9.0 / 8.0) / (1.0 + 1.0 / 64.0) - 1.0,  # ≈ 10.77%
}


def lines_per_row(row_words: int = DEFAULT_ROW_WORDS) -> int:
    return DATA_LANES * row_words // WORDS_PER_LINE


# ---------------------------------------------------------------------------
# Capacity accounting
# ---------------------------------------------------------------------------


def parity_table_rows(num_rows: int, extra_pages: int, row_words: int) -> int:
    """Code-lane rows reserved for parity tables (regular + extra pages).

    One code-lane row (``row_words`` words) holds parity for
    ``row_words / (row_words // 8)`` = 8 pages (W/8 words per page) — the
    paper's "each row of parity in Chip 8 contains the parity data for eight
    pages".
    """
    pages_per_parity_row = 8
    return math.ceil(num_rows / pages_per_parity_row) + math.ceil(
        extra_pages / pages_per_parity_row
    )


def extra_page_count(layout: Layout, num_rows: int,
                     row_words: int = DEFAULT_ROW_WORDS) -> int:
    """Number of extra (reclaimed-capacity) pages a region of `num_rows` offers."""
    if layout == Layout.BASELINE_ECC:
        return 0
    if layout in (Layout.PACKED, Layout.RANK_SUBSET, Layout.INTERWRAP):
        return num_rows // GROUP_ROWS
    if layout == Layout.PARITY:
        # Iterate: extra pages consume 8 code rows each, plus parity tables.
        extra = 0
        while True:
            used = parity_table_rows(num_rows, extra + 1, row_words)
            if used + (extra + 1) * GROUP_ROWS > num_rows:
                return extra
            extra += 1
    raise ValueError(layout)


def total_pages(layout: Layout, num_rows: int,
                row_words: int = DEFAULT_ROW_WORDS) -> int:
    return num_rows + extra_page_count(layout, num_rows, row_words)


# ---------------------------------------------------------------------------
# Physical access plans (line granularity — DRAM-sim / overhead accounting)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Access:
    """One DRAM operation: a lockstep fetch/store of ≤9 (row, lane) slices.

    ``slices`` maps lane -> row. For all layouts except INTERWRAP every touched
    lane reads the same row; INTERWRAP ops may straddle two adjacent rows
    (the paper's two banks opened by the bridge chip).
    """
    slices: tuple[tuple[int, int], ...]  # ((lane, row), ...)
    write: bool = False
    rmw: bool = False                    # requires read-before-write

    @property
    def rows(self) -> tuple[int, ...]:
        return tuple(sorted({r for _, r in self.slices}))

    @property
    def lanes(self) -> tuple[int, ...]:
        return tuple(sorted({l for l, _ in self.slices}))

    def num_device_ops(self) -> int:
        """DRAM command count this access expands to (RMW = read + write)."""
        return 2 if self.rmw else 1


def _full_row(row: int, lanes: range | tuple, write: bool, rmw: bool = False
              ) -> Access:
    return Access(tuple((l, row) for l in lanes), write=write, rmw=rmw)


def interwrap_slices(page_slot: int) -> tuple[tuple[int, int], ...]:
    """(lane, group-relative row) slices owned by page slot s ∈ [0, 9).

    Paper §4.1.3: linear slice ℓ = row·9 + lane; slot s owns ℓ ∈ [8s, 8s+8).
    The skipped lane is (8 − s) mod 9.
    """
    if not 0 <= page_slot < 9:
        raise ValueError(page_slot)
    out = []
    for k in range(8):
        linear = 8 * page_slot + k
        out.append((linear % LANES, linear // LANES))
    return tuple(out)


def plan_line_access(layout: Layout, num_rows: int, page: int, write: bool,
                     row_words: int = DEFAULT_ROW_WORDS) -> list[Access]:
    """Access plan for one 64B line of logical ``page`` in a CREAM region.

    Page id space: [0, num_rows) are regular pages; [num_rows, total) are
    extra pages. Line index within the page does not change op structure
    (only column addresses), so it is not a parameter.
    """
    n_extra = extra_page_count(layout, num_rows, row_words)
    if not 0 <= page < num_rows + n_extra:
        raise ValueError(f"page {page} out of range for {layout} x {num_rows}")
    is_extra = page >= num_rows
    e = page - num_rows

    if layout == Layout.BASELINE_ECC:
        # One lockstep op across all 9 chips, for reads and writes alike.
        return [_full_row(page, range(LANES), write)]

    if layout == Layout.PACKED:
        if not is_extra:
            # Reads fetch all 9 lanes (lane-8 data ignored); writes must RMW
            # because lane 8 holds another page's data (paper §4.1.1).
            return [_full_row(page, range(LANES), write, rmw=write)]
        # Extra page: line lives in lane 8 of one row, split over 8 column
        # segments -> 8 back-to-back ops, same row (≤1 row miss).
        row = GROUP_ROWS * e + 0  # part index affects the row; one line maps
        # to part (line // 16); callers that care pass per-line rows via
        # plan_extra_line_row(). For op counting the row is representative.
        return [_full_row(row, range(LANES), write, rmw=write)
                for _ in range(8)]

    if layout == Layout.RANK_SUBSET:
        if not is_extra:
            return [_full_row(page, range(DATA_LANES), write)]
        row = GROUP_ROWS * e
        return [_full_row(row, (CODE_LANE,), write) for _ in range(8)]

    if layout == Layout.INTERWRAP:
        group, slot = (page // GROUP_ROWS, page % GROUP_ROWS) if not is_extra \
            else (e, GROUP_ROWS)
        rel = interwrap_slices(slot)
        slices = tuple((lane, GROUP_ROWS * group + r) for lane, r in rel)
        return [Access(slices, write=write)]

    if layout == Layout.PARITY:
        # Rank-subset base + parity ops on lane 8 (paper §4.2).
        parity_row = _parity_row_of_page(layout, num_rows, page, row_words)
        parity_op = Access(((CODE_LANE, parity_row),), write=write, rmw=write)
        if not is_extra:
            return [_full_row(page, range(DATA_LANES), write), parity_op]
        data_row0 = _parity_extra_data_row0(num_rows, n_extra, e, row_words)
        ops = [_full_row(data_row0, (CODE_LANE,), write) for _ in range(8)]
        return ops + [parity_op]

    raise ValueError(layout)


def _parity_row_of_page(layout: Layout, num_rows: int, page: int,
                        row_words: int) -> int:
    """Code-lane row holding ``page``'s parity. Regular table first, then extra.

    Note: the paper additionally stores the parity for bank i in bank
    (i+4) mod 8 to dodge row-buffer conflicts — a *timing* placement detail.
    The pool keeps tables contiguous; ``benchmarks.dram_sim`` applies the
    bank swap when mapping rows to banks.
    """
    if page < num_rows:
        return page // 8
    return math.ceil(num_rows / 8) + (page - num_rows) // 8


def _parity_extra_data_row0(num_rows: int, n_extra: int, e: int,
                            row_words: int) -> int:
    tables = parity_table_rows(num_rows, n_extra, row_words)
    return tables + GROUP_ROWS * e


def count_device_ops(layout: Layout, num_rows: int, page: int, write: bool,
                     row_words: int = DEFAULT_ROW_WORDS) -> int:
    """Total DRAM commands for one line access (the paper's Fig. 10a metric)."""
    return sum(a.num_device_ops()
               for a in plan_line_access(layout, num_rows, page, write, row_words))


def parallelism_groups(layout: Layout) -> int:
    """Independently accessible page groups per 8-row group (Fig. 10b driver).

    Baseline/packed: the 8 rows (banks). Rank-subset: 8 + the lane-8 subset.
    Interwrap: 9 — all 72 lane-slices form nine independent groups (paper
    §4.1.3 "we are able to sustain nine concurrent requests at any time").
    """
    return {Layout.BASELINE_ECC: 8, Layout.PACKED: 8, Layout.RANK_SUBSET: 9,
            Layout.INTERWRAP: 9, Layout.PARITY: 9}[layout]


# ---------------------------------------------------------------------------
# Page-granularity placement (jnp pool gather/scatter)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PagePlacement:
    """Where a logical page's 8KB of data lives, as dense slice descriptors.

    kind:
      'rows'      data = region[row, 0:8, :]               (one row, 8 lanes)
      'codelane'  data = region[row0:row0+8, 8, :]          (8 rows of lane 8)
      'wrap'      data = 8 (lane, row) slices, lane-rotated (interwrap)
    """
    kind: str
    row0: int
    slices: tuple[tuple[int, int], ...] = field(default=())


def place_page(layout: Layout, num_rows: int, page: int,
               row_words: int = DEFAULT_ROW_WORDS) -> PagePlacement:
    n_extra = extra_page_count(layout, num_rows, row_words)
    if not 0 <= page < num_rows + n_extra:
        raise ValueError(f"page {page} out of range")
    is_extra = page >= num_rows
    e = page - num_rows

    if layout == Layout.BASELINE_ECC:
        return PagePlacement("rows", page)
    if layout in (Layout.PACKED, Layout.RANK_SUBSET):
        if not is_extra:
            return PagePlacement("rows", page)
        return PagePlacement("codelane", GROUP_ROWS * e)
    if layout == Layout.INTERWRAP:
        group, slot = (page // GROUP_ROWS, page % GROUP_ROWS) if not is_extra \
            else (e, GROUP_ROWS)
        rel = interwrap_slices(slot)
        return PagePlacement(
            "wrap", GROUP_ROWS * group,
            tuple((lane, GROUP_ROWS * group + r) for lane, r in rel))
    if layout == Layout.PARITY:
        if not is_extra:
            return PagePlacement("rows", page)
        return PagePlacement(
            "codelane", _parity_extra_data_row0(num_rows, n_extra, e, row_words))
    raise ValueError(layout)


# ---------------------------------------------------------------------------
# Universal vectorised coordinate translation (the "bridge chip" as an index
# map). This is the single translation the whole mixed-pool access engine is
# built on: ``repro.core.pool`` turns it into one-gather/one-scatter batched
# access, ``repro.kernels.mixed`` turns it into a Pallas BlockSpec index map.
# ---------------------------------------------------------------------------

#: Region codes returned by :func:`page_coords`.
REGION_CREAM = 0    # CREAM-region regular page (layout's unprotected/parity class)
REGION_SECDED = 1   # conventional SECDED row
REGION_EXTRA = 2    # reclaimed extra page (code-lane / wrap-slot-8 storage)


def _build_wrap_tables() -> tuple[np.ndarray, np.ndarray]:
    """Slot tables for the InterWrap linearisation ℓ = 8·slot + k.

    For slot s ∈ [0, 9): ``WRAP_LANES[s, k] = ℓ mod 9`` and
    ``WRAP_ROWS[s, k] = ℓ div 9`` (group-relative row) — the paper's §4.1.3
    bridge formula, tabulated once so batched lookups are a single gather.
    """
    lanes = np.empty((LANES, DATA_LANES), np.int32)
    rows = np.empty((LANES, DATA_LANES), np.int32)
    for s in range(LANES):
        for k in range(DATA_LANES):
            linear = DATA_LANES * s + k
            lanes[s, k] = linear % LANES
            rows[s, k] = linear // LANES
    return lanes, rows


WRAP_LANES, WRAP_ROWS = _build_wrap_tables()


def page_region(num_rows: int, boundary: int, pages: jax.Array) -> jax.Array:
    """Vectorised region classification: (n,) page ids -> (n,) REGION_* codes."""
    pages = jnp.asarray(pages, jnp.int32)
    is_secded = (pages >= boundary) & (pages < num_rows)
    is_extra = pages >= num_rows
    return jnp.where(is_secded, REGION_SECDED,
                     jnp.where(is_extra, REGION_EXTRA,
                               REGION_CREAM)).astype(jnp.int32)


def parity_coords(num_rows: int, boundary: int, pages: jax.Array,
                  row_words: int = DEFAULT_ROW_WORDS
                  ) -> tuple[jax.Array, jax.Array]:
    """Vectorised parity-table lookup for PARITY-layout CREAM/extra pages.

    Returns ``(prow (n,), off (n,))``: the code-lane row holding each page's
    packed parity entry and the word offset of its ``row_words // 8``-word
    slot within that row. Values for SECDED-region ids are meaningless (the
    caller masks them); callers must clamp/drop before indexing storage.
    """
    pages = jnp.asarray(pages, jnp.int32)
    rel = jnp.where(pages >= num_rows, boundary + (pages - num_rows), pages)
    tables = math.ceil(boundary / 8) if boundary else 0
    prow = jnp.where(rel < boundary, rel // 8,
                     tables + jnp.maximum(rel - boundary, 0) // 8)
    off = (rel % 8) * (row_words // 8)
    return prow.astype(jnp.int32), off.astype(jnp.int32)


def extra_base_row(layout: Layout, boundary: int,
                   row_words: int = DEFAULT_ROW_WORDS) -> int:
    """First code-lane row used for extra-page storage in a CREAM region.

    PACKED / RANK_SUBSET / INTERWRAP pack extras from row 0 of their group;
    PARITY reserves the parity tables first (paper §4.2).
    """
    if layout != Layout.PARITY:
        return 0
    n_extra = extra_page_count(layout, boundary, row_words)
    return parity_table_rows(boundary, n_extra, row_words)


def page_coords(layout: Layout, num_rows: int, boundary: int,
                pages: jax.Array, row_words: int = DEFAULT_ROW_WORDS
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Universal page -> physical-slice translation, for *any* boundary.

    Every logical page — SECDED row, CREAM regular page under any layout, or
    reclaimed extra page — occupies exactly 8 ``(row, lane)`` slices of
    ``row_words`` words. This computes all of them in one vectorised pass:

    Args:
      layout, num_rows, boundary, row_words: static pool geometry
        (``boundary`` is the CREAM-region size; rows ``[boundary, num_rows)``
        are SECDED).
      pages: (n,) int page ids, traced or concrete — page-id convention of
        ``repro.core.pool`` (regular ``[0, num_rows)``, extras above).

    Returns:
      ``(rows (n, 8) int32, lanes (n, 8) int32, region (n,) int32)`` such
      that page ``i``'s data is ``storage[rows[i], lanes[i], :]`` flattened,
      and ``region[i]`` is a REGION_* code. Out-of-range ids produce
      undefined (but in-range-clamped by jnp) coordinates — validate ids
      host-side when they are concrete.
    """
    pages = jnp.asarray(pages, jnp.int32)
    n = pages.shape[0]
    k = jnp.arange(DATA_LANES, dtype=jnp.int32)
    region = page_region(num_rows, boundary, pages)
    is_extra = pages >= num_rows
    e = pages - num_rows
    row_rows = jnp.broadcast_to(pages[:, None], (n, DATA_LANES))
    row_lanes = jnp.broadcast_to(k[None, :], (n, DATA_LANES))

    if layout == Layout.INTERWRAP:
        # CREAM + extra pages are wrap-striped; SECDED rows are conventional.
        group = jnp.where(is_extra, e, pages // GROUP_ROWS)
        slot = jnp.where(is_extra, GROUP_ROWS, pages % GROUP_ROWS)
        w_lanes = jnp.asarray(WRAP_LANES)[slot]
        w_rows = GROUP_ROWS * group[:, None] + jnp.asarray(WRAP_ROWS)[slot]
        in_sec = (region == REGION_SECDED)[:, None]
        rows = jnp.where(in_sec, row_rows, w_rows)
        lanes = jnp.where(in_sec, row_lanes, w_lanes)
        return rows.astype(jnp.int32), lanes.astype(jnp.int32), region

    # BASELINE_ECC / PACKED / RANK_SUBSET / PARITY: regular pages (either
    # region) are row-wise; extras live in code-lane rows of their group.
    ebase = extra_base_row(layout, boundary, row_words)
    ex_rows = ebase + GROUP_ROWS * e[:, None] + k[None, :]
    rows = jnp.where(is_extra[:, None], ex_rows, row_rows)
    lanes = jnp.where(is_extra[:, None], CODE_LANE, row_lanes)
    return rows.astype(jnp.int32), lanes.astype(jnp.int32), region

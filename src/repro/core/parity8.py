"""8-bit interleaved parity per 64-byte line — the paper's detection-only code.

Paper §4.2: detection-only regions store an 8-bit parity code per 64B cache
line (bit *i* of the parity byte = XOR of all data bits congruent to *i* mod 8),
detecting one error per bit-lane — "up to eight errors per cache line" — at a
1/64 storage cost, which is what leaves +10.7% of reclaimable capacity.

A line here is 16 consecutive uint32 (64 bytes). The parity byte is the XOR of
the line's 64 bytes, computed by XOR-folding the 16 words to a single byte.
Pure jnp; oracle for ``repro.kernels.parity8``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

WORDS_PER_LINE = 16  # 64 bytes
LINE_OK = 0
LINE_CORRUPT = 1


def _fold_byte(word: jax.Array) -> jax.Array:
    """XOR-fold a uint32 to its byte-wise XOR (one byte)."""
    word = word ^ (word >> 16)
    word = word ^ (word >> 8)
    return word & jnp.uint32(0xFF)


def encode_lines(data: jax.Array) -> jax.Array:
    """Parity bytes for lines of 16 words.

    Args:
      data: uint32 (..., 16k).
    Returns:
      uint32 (..., k) parity bytes.
    """
    if data.shape[-1] % WORDS_PER_LINE:
        raise ValueError(f"last dim must be a multiple of 16, got {data.shape}")
    lines = data.reshape(*data.shape[:-1], data.shape[-1] // WORDS_PER_LINE,
                         WORDS_PER_LINE)
    folded = jax.lax.reduce_xor(
        lines.astype(jnp.uint32), axes=(lines.ndim - 1,)
    ) if hasattr(jax.lax, "reduce_xor") else None
    if folded is None:  # pragma: no cover - fallback for older jax
        folded = lines[..., 0]
        for i in range(1, WORDS_PER_LINE):
            folded = folded ^ lines[..., i]
    return _fold_byte(folded)


def check_lines(data: jax.Array, parity: jax.Array) -> jax.Array:
    """Per-line status: LINE_OK or LINE_CORRUPT (detection only — no repair).

    Args:
      data:   uint32 (..., 16k).
      parity: uint32 (..., k) stored parity bytes.
    Returns:
      int32 (..., k).
    """
    expected = encode_lines(data)
    return jnp.where(
        (expected ^ (parity.astype(jnp.uint32) & 0xFF)) == 0, LINE_OK, LINE_CORRUPT
    ).astype(jnp.int32)


def encode_lines_packed(data: jax.Array) -> jax.Array:
    """Parity bytes packed 4-per-uint32 (chip-8 storage format).

    (..., 16k) -> (..., k//4); requires k % 4 == 0. A pool row's 2048 data
    words (128 lines) pack to 32 code-lane words — 1/64 of the data, the
    paper's detection-mode overhead.
    """
    from repro.core.secded import pack_codes

    return pack_codes(encode_lines(data))


def check_lines_packed(data: jax.Array, packed_parity: jax.Array) -> jax.Array:
    """Per-line status against packed parity; (..., 16k), (..., k//4) -> (..., k)."""
    from repro.core.secded import unpack_codes

    return check_lines(data, unpack_codes(packed_parity))

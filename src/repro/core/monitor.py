"""Health monitor + adaptive protection policy (paper §3.1, §3.3).

Consumes scrub statistics per region, keeps windowed error-rate estimates,
and recommends protection transitions:

  * rate above ``upgrade_threshold``  -> strengthen (NONE -> PARITY -> SECDED)
    ("As the health of the memory degrades, the protection can be upgraded")
  * rate below ``downgrade_threshold`` for ``downgrade_patience`` consecutive
    windows -> weaken, reclaiming capacity ("healthy DIMMs may initially be
    provisioned with parity protection")

Pure-python control plane: decisions happen between steps, never in jit.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

from repro.core.protection import Protection, stronger, weaker
from repro.core.scrubber import ScrubStats


@dataclass
class MonitorConfig:
    window: int = 8                      # scrub sweeps per estimate
    upgrade_threshold: float = 1e-7      # errors per beat per sweep
    downgrade_threshold: float = 1e-9
    downgrade_patience: int = 4


@dataclass
class RegionHealth:
    rates: deque = field(default_factory=lambda: deque(maxlen=64))
    quiet_windows: int = 0
    uncorrectable_seen: int = 0

    def rate(self, window: int) -> float:
        recent = list(self.rates)[-window:]
        return sum(recent) / len(recent) if recent else 0.0


class ErrorMonitor:
    """Tracks per-region error rates and recommends protection levels."""

    def __init__(self, config: MonitorConfig | None = None):
        self.config = config or MonitorConfig()
        self._health: dict[str, RegionHealth] = {}

    def record(self, region: str, stats: ScrubStats) -> None:
        h = self._health.get(region)
        if h is None:
            # size the rate history from the configured window (a fixed
            # maxlen would silently truncate estimates for window > 64)
            h = RegionHealth(rates=deque(maxlen=max(1, self.config.window)))
            self._health[region] = h
        h.rates.append(stats.error_rate)
        h.uncorrectable_seen += stats.detected_uncorrectable + \
            stats.parity_corrupt_lines
        if stats.error_rate <= self.config.downgrade_threshold:
            h.quiet_windows += 1
        else:
            h.quiet_windows = 0
        self._emit(region, stats, h)

    def _emit(self, region: str, stats: ScrubStats,
              h: RegionHealth) -> None:
        """Feed the telemetry plane: SLO tracker always, metrics when on."""
        from repro.obs import metrics, slo
        slo.TRACKER.record_scrub(region, stats)
        if not metrics.enabled():
            return
        metrics.counter(metrics.NAME_SCRUB_SWEEPS,
                        "scrub sweeps recorded per region",
                        labels=("region",)).labels(region=region).inc()
        metrics.counter(metrics.NAME_SCRUB_BEATS,
                        "beats + parity lines checked by scrub",
                        labels=("region",)).labels(region=region).inc(
            stats.beats_checked + stats.parity_lines_checked)
        c = metrics.counter(metrics.NAME_SCRUB_CORRECTED,
                            "errors repaired in place by scrub",
                            labels=("region", "kind"))
        if stats.corrected_data:
            c.labels(region=region, kind="data").inc(stats.corrected_data)
        if stats.corrected_code:
            c.labels(region=region, kind="code").inc(stats.corrected_code)
        if stats.detected_uncorrectable or stats.parity_corrupt_lines:
            metrics.counter(
                metrics.NAME_SCRUB_UNCORRECTABLE,
                "detected-uncorrectable beats + corrupt parity lines",
                labels=("region",)).labels(region=region).inc(
                stats.detected_uncorrectable + stats.parity_corrupt_lines)
        metrics.gauge(metrics.NAME_REGION_ERROR_RATE,
                      "windowed error-rate estimate per region",
                      labels=("region",)).labels(region=region).set(
            h.rate(self.config.window))

    def record_observation(self, region: str, checked: int,
                           corrected: int = 0, uncorrectable: int = 0,
                           silent: int = 0) -> None:
        """Fold a live read-outcome census (the fault campaign's feed).

        Scrub sweeps aren't the only error source any more: campaign reads
        classified against the ground-truth shadow enter the same windowed
        rate estimate, so ``recommend`` reacts to in-flight corruption
        between sweeps. Silent corruption counts as uncorrectable here —
        it is strictly worse (wrong bits with no flag), so it must trip
        the same upgrade path.
        """
        h = self._health.get(region)
        if h is None:
            h = RegionHealth(rates=deque(maxlen=max(1, self.config.window)))
            self._health[region] = h
        rate = (corrected + uncorrectable + silent) / max(checked, 1)
        h.rates.append(rate)
        h.uncorrectable_seen += uncorrectable + silent
        if rate <= self.config.downgrade_threshold:
            h.quiet_windows += 1
        else:
            h.quiet_windows = 0

    def rate(self, region: str) -> float:
        h = self._health.get(region)
        return h.rate(self.config.window) if h else 0.0

    def recommend(self, region: str, current: Protection,
                  floor: Protection = Protection.NONE,
                  ceiling: Protection = Protection.SECDED) -> Protection:
        """Next protection level for ``region`` (clamped to [floor, ceiling])."""
        from repro.core.protection import _ORDER  # stable ordering
        h = self._health.get(region)
        if h is None:
            return current
        rate = h.rate(self.config.window)
        target = current
        if rate > self.config.upgrade_threshold or h.uncorrectable_seen:
            target = stronger(current)
        elif h.quiet_windows >= self.config.downgrade_patience:
            target = weaker(current)
        lo, hi = _ORDER.index(floor), _ORDER.index(ceiling)
        return _ORDER[min(max(_ORDER.index(target), lo), hi)]

    def acknowledge_transition(self, region: str) -> None:
        """Reset hysteresis after a repartition takes effect."""
        h = self._health.get(region)
        if h:
            h.quiet_windows = 0
            h.uncorrectable_seen = 0

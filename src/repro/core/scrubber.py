"""Memory scrubbing — periodic sweep that repairs single-bit errors in place.

Data centers scrub DRAM in the background; CREAM's health monitor (paper
§3.1) consumes the per-sweep error statistics to drive protection upgrades/
downgrades. Here the sweep is a vectorised jnp pass (oracle) with a Pallas
fast path (``repro.kernels.scrub``) selectable via ``use_kernel=True``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import daec, parity8, secded
from repro.core.layouts import CODE_LANE, DATA_LANES, Layout
from repro.core.pool import PoolState


@dataclass(frozen=True)
class ScrubStats:
    """Per-sweep error census (python ints; host-side control plane)."""
    beats_checked: int = 0
    corrected_data: int = 0
    corrected_code: int = 0
    detected_uncorrectable: int = 0
    parity_lines_checked: int = 0
    parity_corrupt_lines: int = 0
    corrupt_rows: tuple[int, ...] = ()
    #: Corrections persisted back to storage this sweep — latent errors that
    #: can no longer pair up with a future flip into an uncorrectable double.
    latent_errors_killed: int = 0

    @property
    def corrected(self) -> int:
        return self.corrected_data + self.corrected_code

    @property
    def error_rate(self) -> float:
        checked = self.beats_checked + self.parity_lines_checked
        errors = self.corrected + self.detected_uncorrectable + \
            self.parity_corrupt_lines
        return errors / checked if checked else 0.0


def _scrub_secded_rows(storage: jax.Array, start: int,
                       stop: int | None = None) -> tuple[
        jax.Array, jax.Array, jax.Array]:
    """Decode+correct rows [start, stop). Returns (storage', status, row_bad)."""
    if stop is None:
        stop = storage.shape[0]
    data = storage[start:stop, :DATA_LANES, :].reshape(stop - start, -1)
    codes = storage[start:stop, CODE_LANE, :]
    data2, codes2, status = secded.decode_block(data, codes)
    storage = storage.at[start:stop, :DATA_LANES, :].set(
        data2.reshape(-1, DATA_LANES, storage.shape[2]))
    storage = storage.at[start:stop, CODE_LANE, :].set(codes2)
    row_bad = jnp.max(status, axis=-1) == secded.DETECTED_UNCORRECTABLE
    return storage, status, row_bad


@jax.jit
def _scrub_secded_jit(storage: jax.Array, start: int):
    return _scrub_secded_rows(storage, start)


def _scrub_daec_rows(storage: jax.Array, start: int, use_kernel: bool
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Decode+correct the DAEC tier rows [start, R).

    Per-row code volume is W words (= D//8 for D = 8W data words), so the
    DAEC block codec consumes the rows' code lane directly — no dedicated
    scrub kernel needed; the fused ``kernels/daec`` decode IS the kernel
    path.
    """
    n = storage.shape[0] - start
    data = storage[start:, :DATA_LANES, :].reshape(n, -1)
    codes = storage[start:, CODE_LANE, :]
    if use_kernel:
        from repro.kernels.daec import ops as daec_ops
        data2, codes2, status = daec_ops.decode(data, codes)
    else:
        data2, codes2, status = daec.decode_block(data, codes)
    storage = storage.at[start:, :DATA_LANES, :].set(
        data2.reshape(-1, DATA_LANES, storage.shape[2]))
    storage = storage.at[start:, CODE_LANE, :].set(codes2)
    row_bad = jnp.max(status, axis=-1) == daec.DETECTED_UNCORRECTABLE
    return storage, status, row_bad


def scrub(state: PoolState, use_kernel: bool = False
          ) -> tuple[PoolState, ScrubStats]:
    """One full scrub sweep. SECDED rows are repaired in place; parity rows
    are checked (detection only) and reported via ``corrupt_rows`` so the
    owner can restore them from a checkpoint (targeted recovery, DESIGN §2.4).
    """
    from repro.obs import tracing
    with tracing.span("scrub.sweep", rows=state.num_rows,
                      boundary=state.boundary, layout=state.layout.value):
        return _scrub_impl(state, use_kernel)


def _scrub_impl(state: PoolState, use_kernel: bool
                ) -> tuple[PoolState, ScrubStats]:
    storage = state.storage
    B, R = state.boundary, state.num_rows
    D = state.daec_start            # SECDED span ends where the DAEC tier begins

    corrected_data = corrected_code = detected = 0
    beats = 0
    corrupt_rows: list[int] = []

    if B < D:  # SECDED region
        if use_kernel:
            from repro.kernels.scrub import ops as scrub_ops
            storage, status, row_bad = scrub_ops.scrub_secded(storage, B, D)
        else:
            storage, status, row_bad = _scrub_secded_rows(storage, B, D)
        beats = int(status.size)
        corrected_data = int(jnp.sum(status == secded.CORRECTED_DATA))
        corrected_code = int(jnp.sum(status == secded.CORRECTED_CODE))
        detected = int(jnp.sum(status == secded.DETECTED_UNCORRECTABLE))
        corrupt_rows += [B + i for i in jnp.where(row_bad)[0].tolist()]

    if D < R:  # DAEC tier (top rows) — stronger codec, same sweep semantics
        storage, status, row_bad = _scrub_daec_rows(storage, D, use_kernel)
        beats += int(status.size)
        corrected_data += int(jnp.sum(status == secded.CORRECTED_DATA))
        corrected_code += int(jnp.sum(status == secded.CORRECTED_CODE))
        detected += int(jnp.sum(status == secded.DETECTED_UNCORRECTABLE))
        corrupt_rows += [D + i for i in jnp.where(row_bad)[0].tolist()]

    parity_lines = parity_corrupt = 0
    if state.layout == Layout.PARITY and B > 0:
        # Check regular CREAM pages against the parity table (vectorised).
        W = state.row_words
        data = storage[:B, :DATA_LANES, :].reshape(B, -1)
        table_rows = (B + 7) // 8
        table = storage[:table_rows, CODE_LANE, :].reshape(-1)[: B * (W // 8)]
        packed = table.reshape(B, W // 8)
        st = parity8.check_lines_packed(data, packed)
        parity_lines = int(st.size)
        parity_corrupt = int(jnp.sum(st))
        bad = jnp.max(st, axis=-1) == parity8.LINE_CORRUPT
        corrupt_rows += [int(i) for i in jnp.where(bad)[0].tolist()]

    new_state = dataclasses.replace(state, storage=storage)
    return new_state, ScrubStats(
        beats_checked=beats,
        corrected_data=corrected_data,
        corrected_code=corrected_code,
        detected_uncorrectable=detected,
        parity_lines_checked=parity_lines,
        parity_corrupt_lines=parity_corrupt,
        corrupt_rows=tuple(corrupt_rows),
        latent_errors_killed=corrected_data + corrected_code,
    )

"""SoftECC — the Virtualized-ECC baseline the paper compares against (§6.3).

Virtualized ECC [Yoon & Erez, ASPLOS'10] provides SECDED on *non-ECC* DRAM by
storing the codes inside ordinary physical pages: every group of 9 rows holds
8 data pages + 1 code page (8 pages × 1KB codes = one 8KB page), lowering
effective capacity by 1/9 ≈ 11.1%. Each protected access needs a second
access for the code page, partially hidden by caching recently-used code
lines in the LLC — which the paper shows *increases cache contention* and
costs up to 25.1% performance at high memory intensity.

We model both faces:
  * functional jnp pool (read/write/scrub) used by the comparison tests, and
  * access accounting (`plan_line_access`) incl. the code cache, consumed by
    ``benchmarks/bench_sensitivity.py`` to reproduce Fig. 12.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import secded

GROUP = 9  # 8 data pages + 1 code page


@jax.tree_util.register_dataclass
@dataclass
class SoftECCState:
    """Non-ECC pool (R, 8, W) with in-band code pages."""
    storage: jax.Array  # (R, 8, W) uint32 — NOTE: 8 lanes, no ECC chip
    row_words: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_rows(self) -> int:
        return self.storage.shape[0]

    @property
    def num_pages(self) -> int:
        """Effective data capacity: 8 of every 9 rows."""
        return self.num_rows - self.num_code_rows

    @property
    def num_code_rows(self) -> int:
        return self.num_rows // GROUP

    @property
    def page_words(self) -> int:
        return 8 * self.row_words


def make_softecc(num_rows: int, row_words: int = 256) -> SoftECCState:
    if num_rows % GROUP:
        raise ValueError(f"num_rows must be a multiple of {GROUP}")
    return SoftECCState(jnp.zeros((num_rows, 8, row_words), jnp.uint32),
                        row_words)


def _locate(state: SoftECCState, page: int) -> tuple[int, int, int]:
    """logical page -> (data_row, code_row, code_word_offset).

    Group g occupies rows [9g, 9g+9): rows 9g..9g+7 are data pages, row 9g+8
    is the code page; page p's codes fill words [(p%8)·W/8·... ] — one data
    page (8W words = 4W beats) needs W code words, i.e. 1/8 of the code page.
    """
    g, k = divmod(page, 8)
    data_row = GROUP * g + k
    code_row = GROUP * g + 8
    return data_row, code_row, k * state.row_words // 8


def read_page(state: SoftECCState, page: int) -> tuple[jax.Array, jax.Array]:
    data_row, code_row, off = _locate(state, page)
    data = state.storage[data_row].reshape(-1)
    # Codes for one page (8W words = 4W beats = 4W bytes) pack into W words;
    # page k of the group owns lane k of the code page (8 × W = full page).
    codes = _code_slice(state, page)
    data2, _, status = secded.decode_block(data, codes)
    return data2, jnp.max(status)


def _code_slice(state: SoftECCState, page: int) -> jax.Array:
    g, k = divmod(page, 8)
    code_row = GROUP * g + 8
    W = state.row_words
    # one page's packed codes = page_words/8 = W words... (8W words data ->
    # 4W beats -> 4W bytes -> W words packed). Page k's codes live in lane k.
    return state.storage[code_row, k, :]


def write_page(state: SoftECCState, page: int, data: jax.Array) -> SoftECCState:
    data = data.astype(jnp.uint32).reshape(-1)
    if data.shape[0] != state.page_words:
        raise ValueError("bad page size")
    data_row, code_row, _ = _locate(state, page)
    g, k = divmod(page, 8)
    storage = state.storage.at[data_row].set(
        data.reshape(8, state.row_words))
    storage = storage.at[code_row, k, :].set(secded.encode_block(data))
    return dataclasses.replace(state, storage=storage)


def scrub(state: SoftECCState) -> tuple[SoftECCState, dict]:
    """Decode+correct every data page; returns stats like the CREAM scrubber."""
    st = state
    corrected = detected = 0
    for page in range(state.num_pages):
        data, status = read_page(st, page)
        s = int(status)
        if s in (secded.CORRECTED_DATA, secded.CORRECTED_CODE):
            st = write_page(st, page, data)
            corrected += 1
        elif s == secded.DETECTED_UNCORRECTABLE:
            detected += 1
    return st, {"corrected_pages": corrected, "uncorrectable_pages": detected}


# ---------------------------------------------------------------------------
# Access accounting with an LLC-resident code cache (Fig. 12 driver)
# ---------------------------------------------------------------------------


class CodeCache:
    """LRU over (code_row, line) entries — the LLC space VECC borrows.

    ``capacity_lines`` models how many 64B code lines fit in the borrowed LLC
    space; the sensitivity benchmark charges the displaced cache capacity to
    the application, reproducing the paper's contention effect.
    """

    def __init__(self, capacity_lines: int):
        self.capacity = capacity_lines
        self._lru: OrderedDict[tuple[int, int], None] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def access(self, key: tuple[int, int]) -> bool:
        if self.capacity <= 0:
            self.misses += 1
            return False
        if key in self._lru:
            self._lru.move_to_end(key)
            self.hits += 1
            return True
        self.misses += 1
        self._lru[key] = None
        if len(self._lru) > self.capacity:
            self._lru.popitem(last=False)
        return False


def plan_line_ops(page: int, line: int, write: bool,
                  cache: CodeCache | None) -> int:
    """DRAM operations for one 64B line access under SoftECC.

    Data op + code op; the code op is elided on a code-cache hit. Writes
    must read-modify-write the code line (codes for 8 lines share one 64B).
    """
    g, k = divmod(page, 8)
    code_row = GROUP * g + 8
    code_line = (k * 64 + line // 8) % 128  # which 64B of the code page
    ops = 1  # the data access itself
    hit = cache.access((code_row, code_line)) if cache else False
    if not hit:
        ops += 1          # fetch code line
    if write and not hit:
        ops += 1          # RMW write-back of the merged code line
    elif write:
        ops += 1          # dirty write-back eventually; charge one op
    return ops

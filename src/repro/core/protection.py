"""Protection levels and region descriptors — the paper's Fig. 1 quadrants.

A *region* is a contiguous span of pool rows with one protection level and one
CREAM layout. The memory controller analogue (``repro.core.pool``) keeps a
boundary between CREAM-layout rows and conventional SECDED rows, exactly as
the paper's boundary register (§4.3.1).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass

from repro.core.layouts import CAPACITY_GAIN, Layout


class Protection(enum.Enum):
    DAEC = "daec"        # correct 1 + any adjacent 2 per 128-bit superbeat — 0%
    SECDED = "secded"    # correct 1 / detect 2 per 64-bit beat — 0% extra capacity
    PARITY = "parity"    # detect only, 8-bit parity per 64B line — +10.7%
    NONE = "none"        # no protection — +12.5%


#: Layouts admissible for each protection level. The first entry is the
#: default (best-performing per the paper's evaluation: InterWrap for
#: correction-free, rank-subset-based packing for parity). DAEC shares
#: SECDED's physical layout — its 16-bit superbeat code fields pack into
#: the same code lane (see ``repro.core.daec``), so the rung costs extra
#: decode compute, not capacity.
ADMISSIBLE_LAYOUTS = {
    Protection.DAEC: (Layout.BASELINE_ECC,),
    Protection.SECDED: (Layout.BASELINE_ECC,),
    Protection.PARITY: (Layout.PARITY,),
    Protection.NONE: (Layout.INTERWRAP, Layout.RANK_SUBSET, Layout.PACKED),
}


def default_layout(protection: Protection) -> Layout:
    return ADMISSIBLE_LAYOUTS[protection][0]


def capacity_gain(protection: Protection, layout: Layout | None = None) -> float:
    layout = layout or default_layout(protection)
    if layout not in ADMISSIBLE_LAYOUTS[protection]:
        raise ValueError(f"layout {layout} invalid for {protection}")
    return CAPACITY_GAIN[layout]


@dataclass(frozen=True)
class RegionSpec:
    """A named reliability domain (e.g. 'weights', 'kv_cache', 'opt_state')."""
    name: str
    protection: Protection
    layout: Layout
    rows: int                      # pool rows assigned to the region
    # Adaptive-policy hints (paper §3.1): how tolerant the consumer is.
    min_protection: Protection = Protection.NONE
    max_protection: Protection = Protection.SECDED

    def __post_init__(self):
        if self.layout not in ADMISSIBLE_LAYOUTS[self.protection]:
            raise ValueError(
                f"{self.name}: layout {self.layout} invalid for {self.protection}")

    @staticmethod
    def make(name: str, protection: Protection, rows: int,
             layout: Layout | None = None, **kw) -> "RegionSpec":
        return RegionSpec(name, protection, layout or default_layout(protection),
                          rows, **kw)


_ORDER = [Protection.NONE, Protection.PARITY, Protection.SECDED,
          Protection.DAEC]


def ladder() -> tuple[Protection, ...]:
    """The full code ladder, strongest first — the single source of truth
    for per-class plumbing (obs fold matrices, SLO class maps, dashboards).
    Derive from this, never hardcode the class count."""
    return tuple(reversed(_ORDER))


def stronger(p: Protection) -> Protection:
    i = _ORDER.index(p)
    return _ORDER[min(i + 1, len(_ORDER) - 1)]


def weaker(p: Protection) -> Protection:
    i = _ORDER.index(p)
    return _ORDER[max(i - 1, 0)]


def at_least(a: Protection, b: Protection) -> bool:
    return _ORDER.index(a) >= _ORDER.index(b)

"""Bit-flip fault injection — drives reliability tests and the health monitor.

Models DRAM soft/hard errors (paper §2.2): soft = uniform random single-bit
flips at a configurable rate; hard = a sticky set of (row, lane, word, bit)
cells that re-flip after every scrub, concentrated in a few rows (matching
field studies [1,8]: errors cluster within a small fraction of devices).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class FlipRecord:
    row: int
    lane: int
    word: int
    bit: int


def inject_flips(storage: jnp.ndarray, rng: np.random.Generator, n_flips: int,
                 row_range: tuple[int, int] | None = None,
                 lanes: tuple[int, ...] | None = None,
                 ) -> tuple[jnp.ndarray, list[FlipRecord]]:
    """Flip ``n_flips`` uniformly random bits. Returns (storage', ground truth).

    Distinct (row, lane, word, bit) cells are guaranteed, so the flip count is
    exact (needed when asserting corrected==injected).
    """
    R, L, W = storage.shape
    r0, r1 = row_range or (0, R)
    lanes = lanes or tuple(range(L))
    arr = np.asarray(storage).copy()
    seen: set[tuple[int, int, int, int]] = set()
    records: list[FlipRecord] = []
    while len(records) < n_flips:
        cell = (int(rng.integers(r0, r1)), int(rng.choice(lanes)),
                int(rng.integers(0, W)), int(rng.integers(0, 32)))
        if cell in seen:
            continue
        seen.add(cell)
        row, lane, word, bit = cell
        arr[row, lane, word] ^= np.uint32(1 << bit)
        records.append(FlipRecord(row, lane, word, bit))
    return jnp.asarray(arr), records


@dataclass
class FaultModel:
    """Stateful injector: soft error rate + sticky hard-fault cells."""
    rng: np.random.Generator
    soft_rate_per_gb_per_step: float = 0.0
    hard_cells: list[FlipRecord] = field(default_factory=list)

    @staticmethod
    def make(seed: int, soft_rate: float = 0.0, n_hard: int = 0,
             shape: tuple[int, int, int] | None = None,
             hard_row_fraction: float = 0.05) -> "FaultModel":
        rng = np.random.default_rng(seed)
        hard: list[FlipRecord] = []
        if n_hard:
            R, L, W = shape
            # hard faults cluster in a few rows (field-study behaviour)
            bad_rows = rng.choice(R, size=max(1, int(R * hard_row_fraction)),
                                  replace=False)
            for _ in range(n_hard):
                hard.append(FlipRecord(int(rng.choice(bad_rows)),
                                       int(rng.integers(0, L)),
                                       int(rng.integers(0, W)),
                                       int(rng.integers(0, 32))))
        return FaultModel(rng, soft_rate, hard)

    def step(self, storage: jnp.ndarray) -> tuple[jnp.ndarray, int]:
        """Apply one step of faults; returns (storage', flips applied)."""
        arr = np.asarray(storage).copy()
        count = 0
        gb = arr.nbytes / 2**30
        n_soft = self.rng.poisson(self.soft_rate_per_gb_per_step * gb)
        R, L, W = arr.shape
        for _ in range(int(n_soft)):
            arr[self.rng.integers(0, R), self.rng.integers(0, L),
                self.rng.integers(0, W)] ^= np.uint32(
                    1 << self.rng.integers(0, 32))
            count += 1
        for c in self.hard_cells:
            arr[c.row, c.lane, c.word] |= np.uint32(1 << c.bit)  # stuck-at-1
            count += 1
        return jnp.asarray(arr), count

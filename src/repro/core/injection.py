"""Bit-flip fault injection — drives reliability tests and the fault campaign.

Models DRAM soft/hard errors (paper §2.2):

  * **soft errors** arrive as a Poisson process whose rate scales with the
    resident capacity (errors per GB per step — see
    :mod:`repro.faults.fit` for the FIT-rate conversion). Each arrival is
    one *event* drawn from an :class:`ErrorMix` of realistic shapes:
    ``single`` (one flipped bit), ``adjacent_double`` (two neighbouring
    bits of one word — one SECDED beat, the classic multi-bit upset), and
    ``random_double`` (two independent uniform bits — almost always two
    separate beats);
  * **hard errors** are a sticky set of (row, lane, word, bit) cells that
    re-assert after every scrub (stuck-at-1), concentrated in a few rows
    (matching field studies [1,8]: errors cluster within a small fraction
    of devices).

Everything is numpy-vectorised: campaign-scale injection (10⁴+ flips per
step) is one batched draw + dedupe + one ``bitwise_xor.at`` scatter, not a
Python loop. :meth:`FaultModel.step_pool` injects into either pool kind —
a local :class:`~repro.core.pool.PoolState` or a multi-device
``repro.shard.ShardedPool`` (per-shard storage views, global row ``r`` ↔
shard ``r % S``, local row ``r // S`` — the router's convention).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class FlipRecord:
    row: int        # global row (sharded pools: shard = row % S)
    lane: int
    word: int
    bit: int


def _one(bits: np.ndarray) -> np.ndarray:
    """``1 << bits`` as uint32 (numpy promotes plain ``1 <<`` to int64)."""
    return np.left_shift(np.uint32(1), bits.astype(np.uint32),
                         dtype=np.uint32)


def inject_flips(storage: jnp.ndarray, rng: np.random.Generator, n_flips: int,
                 row_range: tuple[int, int] | None = None,
                 lanes: tuple[int, ...] | None = None,
                 ) -> tuple[jnp.ndarray, list[FlipRecord]]:
    """Flip ``n_flips`` uniformly random bits. Returns (storage', ground truth).

    Distinct (row, lane, word, bit) cells are guaranteed, so the flip count
    is exact (needed when asserting corrected==injected). Vectorised:
    oversampled batch draws deduped on a linear cell code until the exact
    count is reached — no per-flip Python loop, so campaign-scale batches
    (10⁴+) stay injector-cheap.
    """
    R, L, W = storage.shape
    r0, r1 = row_range or (0, R)
    lane_pool = np.asarray(lanes if lanes is not None else range(L),
                           dtype=np.int64)
    arr = np.asarray(storage).copy()
    chosen = np.empty(0, np.int64)      # linear cell codes, draw order kept
    while chosen.size < n_flips:
        m = 2 * max(n_flips - chosen.size, 16)
        rows = rng.integers(r0, r1, size=m)
        lns = lane_pool[rng.integers(0, lane_pool.size, size=m)]
        words = rng.integers(0, W, size=m)
        bits = rng.integers(0, 32, size=m)
        lin = ((rows * L + lns) * W + words) * 32 + bits
        cat = np.concatenate([chosen, lin])
        _, first = np.unique(cat, return_index=True)
        chosen = cat[np.sort(first)]    # dedupe, preserving draw order
    chosen = chosen[:n_flips]
    bits = chosen % 32
    words = (chosen // 32) % W
    lns = (chosen // (32 * W)) % L
    rows = chosen // (32 * W * L)
    np.bitwise_xor.at(arr, (rows, lns, words), _one(bits))
    records = [FlipRecord(int(r), int(ln), int(w), int(b))
               for r, ln, w, b in zip(rows, lns, words, bits)]
    return jnp.asarray(arr), records


def apply_flips(storage: jnp.ndarray,
                records: list[FlipRecord]) -> jnp.ndarray:
    """XOR a known set of cells (targeted injection for tests/replays)."""
    arr = np.asarray(storage).copy()
    if records:
        rows = np.asarray([c.row for c in records])
        lns = np.asarray([c.lane for c in records])
        words = np.asarray([c.word for c in records])
        bits = np.asarray([c.bit for c in records])
        np.bitwise_xor.at(arr, (rows, lns, words), _one(bits))
    return jnp.asarray(arr)


@dataclass(frozen=True)
class ErrorMix:
    """Relative weights of the soft-error event shapes.

    ``single`` flips one bit; ``adjacent_double`` flips two neighbouring
    bits of one uint32 word (one SECDED beat → detected-uncorrectable by
    the Hsiao code, never miscorrected; *corrected* outright in the
    SEC-DAEC tier, whose bit-interleaving splits the pair across two
    codewords — see :mod:`repro.core.daec`); ``random_double`` flips two
    independent uniform bits (distinct beats with overwhelming probability
    → each corrected; a same-beat pair under DAEC is detected, never
    silent). Weights need not sum to 1.
    """
    single: float = 1.0
    adjacent_double: float = 0.0
    random_double: float = 0.0

    def probs(self) -> np.ndarray:
        w = np.asarray([self.single, self.adjacent_double,
                        self.random_double], float)
        total = w.sum()
        if total <= 0:
            raise ValueError("ErrorMix weights must sum to > 0")
        return w / total


#: Single-bit upsets only — the pre-campaign behaviour.
SINGLES = ErrorMix()
#: Field-shaped mix: mostly singles, a tail of multi-bit upsets
#: (Sridharan et al. find multi-bit faults are a small but steady
#: fraction of DRAM error events).
FIELD_MIX = ErrorMix(single=0.88, adjacent_double=0.08, random_double=0.04)


@dataclass
class FaultModel:
    """Stateful injector: soft error process + sticky hard-fault cells."""
    rng: np.random.Generator
    soft_rate_per_gb_per_step: float = 0.0
    hard_cells: list[FlipRecord] = field(default_factory=list)
    mix: ErrorMix = SINGLES

    @staticmethod
    def make(seed: int, soft_rate: float = 0.0, n_hard: int = 0,
             shape: tuple[int, int, int] | None = None,
             hard_row_fraction: float = 0.05,
             mix: ErrorMix = SINGLES) -> "FaultModel":
        """``shape`` is the *global* geometry ``(R, L, W)`` (sharded pools:
        R = total rows across shards)."""
        rng = np.random.default_rng(seed)
        hard: list[FlipRecord] = []
        if n_hard:
            R, L, W = shape
            # hard faults cluster in a few rows (field-study behaviour)
            bad_rows = rng.choice(R, size=max(1, int(R * hard_row_fraction)),
                                  replace=False)
            for _ in range(n_hard):
                hard.append(FlipRecord(int(rng.choice(bad_rows)),
                                       int(rng.integers(0, L)),
                                       int(rng.integers(0, W)),
                                       int(rng.integers(0, 32))))
        return FaultModel(rng, soft_rate, hard, mix)

    # -- soft-error event generation (vectorised) ---------------------------
    def _draw_soft(self, R: int, L: int, W: int, nbytes: int
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """One step's soft flips as (rows, lanes, words, bits) arrays.

        The Poisson draw counts *events*; each event contributes 1 or 2 bit
        flips per the mix. Rows are global.
        """
        gb = nbytes / 2**30
        n_events = int(self.rng.poisson(self.soft_rate_per_gb_per_step * gb))
        if not n_events:
            z = np.empty(0, np.int64)
            return z, z, z, z
        n1, n_adj, n_rnd = self.rng.multinomial(n_events, self.mix.probs())
        parts = []
        # singles + random doubles: independent uniform cells
        n_uni = int(n1) + 2 * int(n_rnd)
        if n_uni:
            parts.append((self.rng.integers(0, R, n_uni),
                          self.rng.integers(0, L, n_uni),
                          self.rng.integers(0, W, n_uni),
                          self.rng.integers(0, 32, n_uni)))
        # adjacent doubles: bits (b, b+1) of one word — one SECDED beat
        if n_adj:
            rows = self.rng.integers(0, R, n_adj)
            lns = self.rng.integers(0, L, n_adj)
            words = self.rng.integers(0, W, n_adj)
            b0 = self.rng.integers(0, 31, n_adj)
            parts.append((np.repeat(rows, 2), np.repeat(lns, 2),
                          np.repeat(words, 2),
                          np.stack([b0, b0 + 1], axis=1).reshape(-1)))
        rows = np.concatenate([p[0] for p in parts])
        lns = np.concatenate([p[1] for p in parts])
        words = np.concatenate([p[2] for p in parts])
        bits = np.concatenate([p[3] for p in parts])
        return rows, lns, words, bits

    # -- injection ----------------------------------------------------------
    def step(self, storage: jnp.ndarray) -> tuple[jnp.ndarray, int]:
        """Apply one step of faults; returns (storage', flips applied)."""
        arr = np.asarray(storage).copy()
        R, L, W = arr.shape
        count = self._apply(arr, R, lambda r: (r,))
        return jnp.asarray(arr), count

    def step_pool(self, pool) -> tuple[object, int]:
        """Inject one step of faults into a live pool — local or sharded.

        Local pools (3-D storage) are flipped in place and rebuilt; sharded
        pools (4-D ``(S, R_local, 9, W)`` storage) map each global row
        ``r`` to ``(shard r % S, local r // S)`` — the shard router's
        round-robin convention — and the flipped host image is re-placed on
        the ``banks`` mesh. Returns ``(pool', flips applied)``.
        """
        storage = pool.storage
        if storage.ndim == 3:
            new_storage, count = self.step(storage)
            return dataclasses.replace(pool, storage=new_storage), count
        if storage.ndim != 4:
            raise ValueError(f"unsupported storage rank {storage.ndim}")
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        S, R_local, L, W = storage.shape
        arr = np.asarray(storage).copy()
        count = self._apply(arr, S * R_local, lambda r: (r % S, r // S))
        new_storage = jax.device_put(
            jnp.asarray(arr), NamedSharding(pool.mesh, P("banks")))
        return dataclasses.replace(pool, storage=new_storage), count

    def _apply(self, arr: np.ndarray, num_rows: int, split) -> int:
        """XOR soft flips + OR hard cells into ``arr`` via ``split``, which
        maps a global-row vector to the leading index tuple."""
        L, W = arr.shape[-2], arr.shape[-1]
        rows, lns, words, bits = self._draw_soft(num_rows, L, W, arr.nbytes)
        count = int(rows.size)
        if count:
            np.bitwise_xor.at(arr, (*split(rows), lns, words), _one(bits))
        for c in self.hard_cells:
            idx = tuple(int(i) for i in split(np.asarray(c.row))) \
                + (c.lane, c.word)
            arr[idx] |= np.uint32(1 << c.bit)   # stuck-at-1
            count += 1
        return count

"""Hsiao SECDED(72,64) code — the error-correcting code stored on "chip 8".

The paper's ECC DRAM stores an 8-bit SECDED code for every 64-bit data burst
(Hsiao, "A Class of Optimal Minimum Odd-Weight-Column SEC-DED Codes", 1970).
We implement the same (72,64) code in vectorised JAX:

  * 64 data bits are carried as a pair of uint32 words ``(lo, hi)`` — our
    TPU-adapted "beat" (see DESIGN.md §2.1: the bit-interleaved DDR burst is
    re-bound to two consecutive uint32 within one lane; the 64:8 ratio and all
    SECDED guarantees are unchanged).
  * The 8 parity bits are each the XOR of an odd-weight subset of data bits.
    Columns of the parity-check matrix H are distinct odd-weight 8-bit vectors
    (56 of weight 3 + 8 of weight 5), so any single-bit error yields a syndrome
    equal to that bit's (odd-weight) column — correctable — while any double
    error yields a nonzero even-weight syndrome — detected, never miscorrected.

Everything here is pure jnp (usable inside Pallas kernels and as the oracle
for ``repro.kernels.secded``).
"""
from __future__ import annotations

from itertools import combinations

import jax
import jax.numpy as jnp
import numpy as np

NUM_DATA_BITS = 64
NUM_CODE_BITS = 8

# Per-beat decode status codes (also used by the scrubber / monitor).
CLEAN = 0                     # syndrome zero — no error
CORRECTED_DATA = 1            # single-bit error in the data bits, corrected
CORRECTED_CODE = 2            # single-bit error in the code bits, corrected
DETECTED_UNCORRECTABLE = 3    # even-weight / unmatched syndrome — ≥2 bit errors


def _build_hsiao_code() -> tuple[np.ndarray, np.ndarray]:
    """Construct H-matrix data columns and the 256-entry syndrome action table.

    Returns:
      columns: (64,) uint16 — syndrome value produced by an error in data bit i.
      table:   (256,) int32 — action per syndrome:
                 -1        -> clean
                 0..63     -> flip data bit
                 64..71    -> flip code bit (value - 64)
                 -2        -> detected uncorrectable
    """
    cols: list[int] = []
    for weight in (3, 5):
        for combo in combinations(range(NUM_CODE_BITS), weight):
            col = 0
            for b in combo:
                col |= 1 << b
            cols.append(col)
            if len(cols) == NUM_DATA_BITS:
                break
        if len(cols) == NUM_DATA_BITS:
            break
    assert len(cols) == NUM_DATA_BITS and len(set(cols)) == NUM_DATA_BITS

    table = np.full(256, -2, dtype=np.int32)
    table[0] = -1
    for i, col in enumerate(cols):
        table[col] = i
    for p in range(NUM_CODE_BITS):
        table[1 << p] = 64 + p
    return np.asarray(cols, dtype=np.uint16), table


_COLUMNS, _SYNDROME_TABLE = _build_hsiao_code()

# Per-parity-bit masks over the 64 data bits, split into the (lo, hi) words.
_MASK_LO = np.zeros(NUM_CODE_BITS, dtype=np.uint32)
_MASK_HI = np.zeros(NUM_CODE_BITS, dtype=np.uint32)
for _i, _col in enumerate(_COLUMNS):
    for _p in range(NUM_CODE_BITS):
        if (_col >> _p) & 1:
            if _i < 32:
                _MASK_LO[_p] |= np.uint32(1 << _i)
            else:
                _MASK_HI[_p] |= np.uint32(1 << (_i - 32))

# jnp constants (captured as literals inside jit/pallas traces).
MASK_LO = jnp.asarray(_MASK_LO)
MASK_HI = jnp.asarray(_MASK_HI)
SYNDROME_TABLE = jnp.asarray(_SYNDROME_TABLE)
H_COLUMNS = jnp.asarray(_COLUMNS.astype(np.int32))


def encode_words(lo: jax.Array, hi: jax.Array) -> jax.Array:
    """SECDED code for 64-bit beats given as two uint32 planes.

    Args:
      lo, hi: uint32 arrays of identical shape (bits 0..31 / 32..63).
    Returns:
      uint32 array, same shape, values in [0, 256): the 8-bit Hsiao code.
    """
    lo = lo.astype(jnp.uint32)
    hi = hi.astype(jnp.uint32)
    code = jnp.zeros_like(lo)
    for p in range(NUM_CODE_BITS):
        ones = jax.lax.population_count(lo & MASK_LO[p]) + jax.lax.population_count(
            hi & MASK_HI[p]
        )
        code = code | ((ones & jnp.uint32(1)) << p)
    return code


def decode_words(
    lo: jax.Array, hi: jax.Array, code: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Check + correct 64-bit beats against their stored SECDED codes.

    Args:
      lo, hi: uint32 data planes (any shape).
      code:   uint32 stored codes in [0, 256), same shape.
    Returns:
      (lo', hi', code', status) — corrected planes/codes and a per-beat status
      in {CLEAN, CORRECTED_DATA, CORRECTED_CODE, DETECTED_UNCORRECTABLE}.
    """
    lo = lo.astype(jnp.uint32)
    hi = hi.astype(jnp.uint32)
    code = code.astype(jnp.uint32) & jnp.uint32(0xFF)
    syndrome = (encode_words(lo, hi) ^ code) & jnp.uint32(0xFF)
    action = jnp.take(SYNDROME_TABLE, syndrome.astype(jnp.int32), axis=0)

    is_data = (action >= 0) & (action < 64)
    is_code_bit = action >= 64
    bit = jnp.where(action >= 0, action, 0).astype(jnp.uint32)

    flip_lo = jnp.where(is_data & (bit < 32), jnp.uint32(1) << (bit & 31), 0)
    flip_hi = jnp.where(is_data & (bit >= 32), jnp.uint32(1) << (bit & 31), 0)
    flip_code = jnp.where(is_code_bit, jnp.uint32(1) << ((bit - 64) & 7), 0)

    status = jnp.where(
        action == -1,
        CLEAN,
        jnp.where(
            is_data,
            CORRECTED_DATA,
            jnp.where(is_code_bit, CORRECTED_CODE, DETECTED_UNCORRECTABLE),
        ),
    ).astype(jnp.int32)
    return lo ^ flip_lo, hi ^ flip_hi, code ^ flip_code, status


# ---------------------------------------------------------------------------
# Block-level helpers: pool rows move 64-bit beats as pairs of consecutive
# uint32 words; codes are packed 4-per-uint32 ("chip 8" storage format).
# ---------------------------------------------------------------------------


def split_beats(data: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(..., 2k) uint32 -> (lo, hi) each (..., k): beat j = words (2j, 2j+1)."""
    if data.shape[-1] % 2:
        raise ValueError(f"last dim must be even, got {data.shape}")
    pairs = data.reshape(*data.shape[:-1], data.shape[-1] // 2, 2)
    return pairs[..., 0], pairs[..., 1]


def merge_beats(lo: jax.Array, hi: jax.Array) -> jax.Array:
    """Inverse of :func:`split_beats`."""
    return jnp.stack([lo, hi], axis=-1).reshape(*lo.shape[:-1], lo.shape[-1] * 2)


def pack_codes(codes: jax.Array) -> jax.Array:
    """(..., k) uint32 byte values -> (..., k//4) uint32, 4 codes per word."""
    if codes.shape[-1] % 4:
        raise ValueError(f"code count must be divisible by 4, got {codes.shape}")
    grouped = codes.reshape(*codes.shape[:-1], codes.shape[-1] // 4, 4).astype(
        jnp.uint32
    )
    shifts = jnp.asarray([0, 8, 16, 24], dtype=jnp.uint32)
    return jnp.sum(grouped << shifts, axis=-1).astype(jnp.uint32)


def unpack_codes(packed: jax.Array) -> jax.Array:
    """(..., m) uint32 -> (..., 4m) uint32 byte values."""
    shifts = jnp.asarray([0, 8, 16, 24], dtype=jnp.uint32)
    codes = (packed[..., None] >> shifts) & jnp.uint32(0xFF)
    return codes.reshape(*packed.shape[:-1], packed.shape[-1] * 4)


def encode_block(data: jax.Array) -> jax.Array:
    """Encode a data block into its packed SECDED code plane.

    Args:
      data: uint32 (..., 2k) with k % 4 == 0 — e.g. a pool row's 8 data lanes
            flattened to 2048 words encodes to a 256-word code lane (8KB:1KB,
            the paper's chip-8 ratio).
    Returns:
      uint32 (..., k//4) packed codes.
    """
    lo, hi = split_beats(data)
    return pack_codes(encode_words(lo, hi))


def decode_block(
    data: jax.Array, packed_codes: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Check + correct a data block against its packed code plane.

    Returns:
      (data', packed_codes', status) — status is per-beat (..., k) int32.
    """
    lo, hi = split_beats(data)
    codes = unpack_codes(packed_codes)
    lo2, hi2, codes2, status = decode_words(lo, hi, codes)
    return merge_beats(lo2, hi2), pack_codes(codes2), status

"""Reliability / capacity SLO tracking per storage class and pool.

The paper's contract, stated as objectives a dashboard can go red on:

  * **reliability** — data on SECDED frames must never surface a
    detected-uncorrectable read: the SECDED class's uncorrectable budget
    is 0 (HRM's "paid tier" guarantee). PARITY/NONE classes *tolerate*
    errors by contract — their counts are tracked (HARP's profiling
    prerequisite) but do not breach;
  * **capacity** — the reclaimed-page gain per pool rides the boundary
    register; a pool may declare a minimum gain (e.g. the paper's +12.5 %
    InterWrap figure) below which the capacity SLO goes amber.

Fed by :class:`repro.core.monitor.ErrorMonitor` (scrub sweeps), the
serving engine's per-class read-status fold, and
:func:`repro.obs.metrics.record_pool_capacity` (boundary moves). The
tracker itself is a handful of dicts — always on, no jit interaction.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class SLOStatus:
    """One objective's current verdict."""
    name: str
    scope: str
    ok: bool
    value: float
    objective: str
    detail: str = ""


@dataclass
class _ClassState:
    corrected: int = 0
    uncorrectable: int = 0
    silent: int = 0                # wrong bits surfaced with no flag
    budget: int | None = None      # max uncorrectable (None = unbounded)
    silent_budget: int | None = None   # max silent (None = unbounded)


@dataclass
class _TenantState:
    """Per-tenant read-outcome census (fed by the fault campaign)."""
    reads: int = 0
    corrected: int = 0
    detected: int = 0
    silent: int = 0
    max_error_rate: float | None = None   # (detected+silent)/reads budget

    @property
    def error_rate(self) -> float:
        return (self.detected + self.silent) / self.reads \
            if self.reads else 0.0


@dataclass
class _RegionState:
    sweeps: int = 0
    corrected: int = 0
    uncorrectable: int = 0
    last_rate: float = 0.0


@dataclass
class _CapacityState:
    total_rows: int = 0
    reclaimed_pages: int = 0
    boundary: int = 0
    min_gain: float | None = None


@dataclass
class SLOTracker:
    """The process-global SLO state (see :data:`TRACKER`)."""

    classes: dict[str, _ClassState] = field(default_factory=dict)
    regions: dict[str, _RegionState] = field(default_factory=dict)
    capacity: dict[str, _CapacityState] = field(default_factory=dict)
    tenants: dict[str, _TenantState] = field(default_factory=dict)

    def __post_init__(self):
        self._default_classes()

    def _default_classes(self) -> None:
        # the contract: DAEC/SECDED reads must never be uncorrectable and
        # never silently wrong; weaker classes tolerate errors (tracked,
        # never breaching on their own — the per-tenant SLO escalates
        # instead). Every Protection-ladder rung gets a class here — the
        # conformance suite asserts the two stay in sync.
        self.classes.setdefault("daec",
                                _ClassState(budget=0, silent_budget=0))
        self.classes.setdefault("secded",
                                _ClassState(budget=0, silent_budget=0))
        self.classes.setdefault("parity", _ClassState(budget=None))
        self.classes.setdefault("none", _ClassState(budget=None))

    # -- feeds ---------------------------------------------------------------
    def set_budget(self, cls: str, budget: int | None) -> None:
        self.classes.setdefault(cls, _ClassState()).budget = budget

    def record_read_status(self, cls: str, corrected: int = 0,
                           uncorrectable: int = 0, silent: int = 0) -> None:
        st = self.classes.setdefault(cls, _ClassState())
        st.corrected += int(corrected)
        st.uncorrectable += int(uncorrectable)
        st.silent += int(silent)

    def set_tenant_slo(self, tenant: str,
                       max_error_rate: float | None) -> None:
        self.tenants.setdefault(tenant, _TenantState()) \
            .max_error_rate = max_error_rate

    def record_tenant_reads(self, tenant: str, reads: int,
                            corrected: int = 0, detected: int = 0,
                            silent: int = 0) -> None:
        st = self.tenants.setdefault(tenant, _TenantState())
        st.reads += int(reads)
        st.corrected += int(corrected)
        st.detected += int(detected)
        st.silent += int(silent)

    def record_scrub(self, region: str, stats) -> None:
        """Fold one scrub sweep's census (a ``ScrubStats``-shaped object)."""
        st = self.regions.setdefault(region, _RegionState())
        st.sweeps += 1
        st.corrected += stats.corrected
        st.uncorrectable += (stats.detected_uncorrectable
                             + stats.parity_corrupt_lines)
        st.last_rate = stats.error_rate

    def record_capacity(self, pool_name: str, pool,
                        min_gain: float | None = None) -> None:
        st = self.capacity.setdefault(pool_name, _CapacityState())
        st.total_rows = pool.num_rows
        st.reclaimed_pages = pool.num_extra_pages
        st.boundary = pool.boundary
        if min_gain is not None:
            st.min_gain = min_gain

    def set_capacity_target(self, pool_name: str, min_gain: float) -> None:
        self.capacity.setdefault(pool_name, _CapacityState()) \
            .min_gain = min_gain

    # -- verdicts ------------------------------------------------------------
    def report(self) -> list[SLOStatus]:
        out: list[SLOStatus] = []
        for cls, st in sorted(self.classes.items()):
            if st.budget is None and st.silent_budget is None:
                ok = True
                objective = "errors tolerated by contract"
            else:
                ok = (st.budget is None or st.uncorrectable <= st.budget) \
                    and (st.silent_budget is None
                         or st.silent <= st.silent_budget)
                parts = []
                if st.budget is not None:
                    parts.append(f"uncorrectable <= {st.budget}")
                if st.silent_budget is not None:
                    parts.append(f"silent <= {st.silent_budget}")
                objective = ", ".join(parts)
            out.append(SLOStatus(
                name="reliability", scope=f"class/{cls}", ok=ok,
                value=float(st.uncorrectable + st.silent),
                objective=objective,
                detail=f"corrected={st.corrected} silent={st.silent}"))
        for tenant, st in sorted(self.tenants.items()):
            ok = st.max_error_rate is None \
                or st.error_rate <= st.max_error_rate
            objective = "observed error rate (informational)" \
                if st.max_error_rate is None \
                else f"error rate <= {st.max_error_rate:g}"
            out.append(SLOStatus(
                name="tenant-reliability", scope=f"tenant/{tenant}", ok=ok,
                value=st.error_rate, objective=objective,
                detail=f"reads={st.reads} corrected={st.corrected} "
                       f"detected={st.detected} silent={st.silent}"))
        for region, st in sorted(self.regions.items()):
            out.append(SLOStatus(
                name="scrub", scope=f"region/{region}", ok=True,
                value=st.last_rate,
                objective="error-rate census (informational)",
                detail=f"sweeps={st.sweeps} corrected={st.corrected} "
                       f"uncorrectable={st.uncorrectable}"))
        for pool, st in sorted(self.capacity.items()):
            gain = st.reclaimed_pages / st.total_rows if st.total_rows else 0.0
            ok = st.min_gain is None or gain >= st.min_gain
            objective = "reclaimed gain (informational)" \
                if st.min_gain is None else f"gain >= {st.min_gain:.3f}"
            out.append(SLOStatus(
                name="capacity", scope=f"pool/{pool}", ok=ok, value=gain,
                objective=objective,
                detail=f"extra_pages={st.reclaimed_pages} "
                       f"boundary={st.boundary}/{st.total_rows}"))
        return out

    def breached(self) -> list[SLOStatus]:
        return [s for s in self.report() if not s.ok]

    def reset(self) -> None:
        self.classes.clear()
        self.regions.clear()
        self.capacity.clear()
        self.tenants.clear()
        self._default_classes()


#: Process-global tracker (always on — a handful of dict updates).
TRACKER = SLOTracker()

"""Process-global metrics registry: counters, gauges, histograms.

Design constraints (the reason this is not a Prometheus client import):

  * **jit-safe by construction** — series hold python floats only. Device
    code never touches the registry; the fused kernels' status outputs are
    carried through jit as small jnp arrays and folded here *between*
    steps (:func:`fold_read_status`), so the hot path stays
    one-gather/one-scatter.
  * **near-zero cost when disabled** — every instrumentation site guards
    on :func:`enabled` (one module-level boolean read); handle methods
    check it again so even un-guarded call sites stay cheap.
  * **labelled series** — ``metric.labels(pool="kv", cls="secded")``
    returns a cached handle; label values become part of the series key.

The canonical metric names the repo emits are declared at the bottom
(``NAME_*`` constants) and catalogued in ``docs/observability.md``.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field

import numpy as np

_LOCK = threading.Lock()

#: Default histogram bucket upper bounds, in microseconds (latency-shaped).
DEFAULT_BUCKETS = (10.0, 50.0, 100.0, 500.0, 1000.0, 5000.0, 10000.0,
                   50000.0, 100000.0, float("inf"))


@dataclass
class _Series:
    """One (metric, label-values) time series."""
    value: float = 0.0                       # counter / gauge
    count: int = 0                           # histogram observations
    sum: float = 0.0
    buckets: list[int] = field(default_factory=list)


class Handle:
    """A series bound to concrete label values; the object call sites hold."""

    __slots__ = ("_metric", "_series")

    def __init__(self, metric: "Metric", series: _Series):
        self._metric = metric
        self._series = series

    def inc(self, n: float = 1.0) -> None:
        if not self._metric.registry.enabled:
            return
        if self._metric.kind != "counter":
            raise TypeError(f"{self._metric.name} is a {self._metric.kind}")
        if n < 0:
            raise ValueError("counters only go up")
        self._series.value += float(n)

    def set(self, v: float) -> None:
        if not self._metric.registry.enabled:
            return
        if self._metric.kind != "gauge":
            raise TypeError(f"{self._metric.name} is a {self._metric.kind}")
        self._series.value = float(v)

    def observe(self, v: float) -> None:
        if not self._metric.registry.enabled:
            return
        if self._metric.kind != "histogram":
            raise TypeError(f"{self._metric.name} is a {self._metric.kind}")
        v = float(v)
        s = self._series
        s.count += 1
        s.sum += v
        for i, ub in enumerate(self._metric.buckets):
            if v <= ub:
                s.buckets[i] += 1
                break

    @property
    def value(self) -> float:
        return self._series.value


class Metric:
    """A named metric family; concrete series come from :meth:`labels`."""

    def __init__(self, registry: "Registry", name: str, kind: str,
                 help: str = "", labelnames: tuple[str, ...] = (),
                 buckets: tuple[float, ...] = DEFAULT_BUCKETS):
        self.registry = registry
        self.name = name
        self.kind = kind
        self.help = help
        self.labelnames = tuple(labelnames)
        self.buckets = tuple(buckets)
        self._series: dict[tuple[str, ...], _Series] = {}
        self._handles: dict[tuple[str, ...], Handle] = {}

    def labels(self, **kv: str) -> Handle:
        if set(kv) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: labels {sorted(kv)} != "
                f"declared {sorted(self.labelnames)}")
        key = tuple(str(kv[ln]) for ln in self.labelnames)
        h = self._handles.get(key)
        if h is None:
            with _LOCK:
                s = self._series.get(key)
                if s is None:
                    s = _Series(buckets=[0] * len(self.buckets))
                    self._series[key] = s
                h = self._handles.setdefault(key, Handle(self, s))
        return h

    # unlabelled convenience (metrics declared with no label names)
    def inc(self, n: float = 1.0) -> None:
        self.labels().inc(n)

    def set(self, v: float) -> None:
        self.labels().set(v)

    def observe(self, v: float) -> None:
        self.labels().observe(v)

    @property
    def series(self) -> dict[tuple[str, ...], _Series]:
        return self._series


class Registry:
    """A metric namespace. The process-global one is :data:`REGISTRY`."""

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self._metrics: dict[str, Metric] = {}

    # -- declaration ---------------------------------------------------------
    def _declare(self, name: str, kind: str, help: str,
                 labels: tuple[str, ...],
                 buckets: tuple[float, ...] = DEFAULT_BUCKETS) -> Metric:
        with _LOCK:
            m = self._metrics.get(name)
            if m is not None:
                if m.kind != kind or m.labelnames != tuple(labels):
                    raise ValueError(
                        f"metric {name!r} re-declared as {kind}{labels} "
                        f"(was {m.kind}{m.labelnames})")
                return m
            m = Metric(self, name, kind, help, labels, buckets)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labels: tuple[str, ...] = ()) -> Metric:
        return self._declare(name, "counter", help, tuple(labels))

    def gauge(self, name: str, help: str = "",
              labels: tuple[str, ...] = ()) -> Metric:
        return self._declare(name, "gauge", help, tuple(labels))

    def histogram(self, name: str, help: str = "",
                  labels: tuple[str, ...] = (),
                  buckets: tuple[float, ...] = DEFAULT_BUCKETS) -> Metric:
        return self._declare(name, "histogram", help, tuple(labels), buckets)

    # -- lifecycle -----------------------------------------------------------
    def reset(self) -> None:
        """Zero every series (registrations and label sets survive)."""
        with _LOCK:
            for m in self._metrics.values():
                for s in m.series.values():
                    s.value = 0.0
                    s.count = 0
                    s.sum = 0.0
                    s.buckets = [0] * len(m.buckets)

    def clear(self) -> None:
        """Drop every metric (a fresh namespace)."""
        with _LOCK:
            self._metrics.clear()

    # -- export --------------------------------------------------------------
    def collect(self) -> dict:
        """JSON-friendly snapshot: {name: {kind, help, series: [...]}}."""
        out: dict = {}
        for name, m in sorted(self._metrics.items()):
            rows = []
            for key, s in sorted(m.series.items()):
                labels = dict(zip(m.labelnames, key))
                if m.kind == "histogram":
                    rows.append({"labels": labels, "count": s.count,
                                 "sum": s.sum,
                                 "buckets": dict(zip(
                                     (str(b) for b in m.buckets),
                                     s.buckets))})
                else:
                    rows.append({"labels": labels, "value": s.value})
            out[name] = {"kind": m.kind, "help": m.help, "series": rows}
        return out

    def snapshot(self) -> str:
        """Prometheus-style text exposition (the testable wire format)."""
        lines: list[str] = []
        for name, m in sorted(self._metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for key, s in sorted(m.series.items()):
                lab = ",".join(f'{ln}="{lv}"'
                               for ln, lv in zip(m.labelnames, key))
                suffix = "{" + lab + "}" if lab else ""
                if m.kind == "histogram":
                    cum = 0
                    for ub, b in zip(m.buckets, s.buckets):
                        cum += b
                        le = "+Inf" if ub == float("inf") else f"{ub:g}"
                        blab = (lab + "," if lab else "") + f'le="{le}"'
                        lines.append(f"{name}_bucket{{{blab}}} {cum}")
                    lines.append(f"{name}_sum{suffix} {s.sum:g}")
                    lines.append(f"{name}_count{suffix} {s.count}")
                else:
                    lines.append(f"{name}{suffix} {s.value:g}")
        return "\n".join(lines) + "\n"

    def get(self, name: str) -> Metric | None:
        return self._metrics.get(name)

    def value(self, name: str, **labels: str) -> float:
        """Current value of one series (0.0 if it does not exist yet)."""
        m = self._metrics.get(name)
        if m is None:
            return 0.0
        s = m.series.get(tuple(str(labels[ln]) for ln in m.labelnames))
        return s.value if s else 0.0


#: The process-global registry every subsystem emits into.
REGISTRY = Registry(enabled=False)


def enabled() -> bool:
    return REGISTRY.enabled


def enable(on: bool = True) -> None:
    REGISTRY.enabled = on


def disable() -> None:
    REGISTRY.enabled = False


def counter(name: str, help: str = "",
            labels: tuple[str, ...] = ()) -> Metric:
    return REGISTRY.counter(name, help, labels)


def gauge(name: str, help: str = "", labels: tuple[str, ...] = ()) -> Metric:
    return REGISTRY.gauge(name, help, labels)


def histogram(name: str, help: str = "", labels: tuple[str, ...] = (),
              buckets: tuple[float, ...] = DEFAULT_BUCKETS) -> Metric:
    return REGISTRY.histogram(name, help, labels, buckets)


def snapshot() -> str:
    return REGISTRY.snapshot()


def collect() -> dict:
    return REGISTRY.collect()


def reset() -> None:
    REGISTRY.reset()


# ---------------------------------------------------------------------------
# Canonical metric names (catalogued in docs/observability.md)
# ---------------------------------------------------------------------------

NAME_READ_STATUS = "cream_read_status_total"
NAME_SCRUB_SWEEPS = "cream_scrub_sweeps_total"
NAME_SCRUB_BEATS = "cream_scrub_beats_total"
NAME_SCRUB_CORRECTED = "cream_scrub_corrected_total"
NAME_SCRUB_UNCORRECTABLE = "cream_scrub_uncorrectable_total"
NAME_REGION_ERROR_RATE = "cream_region_error_rate"
NAME_CAPACITY_PAGES = "cream_capacity_pages"
NAME_CAPACITY_RECLAIMED = "cream_capacity_reclaimed_pages"
NAME_PAGES_MIGRATED = "cream_pages_migrated_total"
NAME_MIGRATION_TO_HOST = "cream_migration_to_host_total"
NAME_VM_READS = "cream_vm_reads_total"
NAME_VM_WRITES = "cream_vm_writes_total"
NAME_TOKENS_DECODED = "cream_tokens_decoded_total"
NAME_DECODE_STEPS = "cream_decode_steps_total"
NAME_PREFILLS = "cream_prefills_total"
NAME_PREEMPTIONS = "cream_preemptions_total"
NAME_RESTORES = "cream_restores_total"
NAME_OBJCACHE_OPS = "cream_objcache_ops_total"
NAME_SHARD_DISPATCH = "cream_shard_dispatch_total"
NAME_SHARD_RING_PAGES = "cream_shard_ring_pages_total"
# CREAM-Lens (repro.obs.memprof) replayed bank-profile series
NAME_DRAM_ROW_HIT_RATE = "cream_dram_bank_row_hit_rate"
NAME_DRAM_CONFLICT_RATE = "cream_dram_bank_conflict_rate"
NAME_DRAM_BLP = "cream_dram_bank_blp"
NAME_DRAM_TFAW_STALL = "cream_dram_bank_tfaw_stall_cycles"
NAME_DRAM_QUEUE_P99 = "cream_dram_bank_queue_p99"
NAME_DRAM_EXTRA_CHIP = "cream_dram_bank_extra_chip_frac"
NAME_DRAM_ACCESSES = "cream_dram_bank_accesses_total"

def _fold_classes() -> tuple[str, ...]:
    from repro.core import protection
    return tuple(p.value for p in protection.ladder())


#: Storage classes in fold order (index into the device-side count matrix).
#: Derived from the Protection ladder (strongest first) — NEVER hardcode the
#: class count; adding a rung must widen every consumer in lockstep.
FOLD_CLASSES = _fold_classes()


def read_status_counter() -> Metric:
    return counter(NAME_READ_STATUS,
                   "per-page decode outcomes on the serving read path",
                   labels=("cls", "status"))


def touch_read_status() -> None:
    """Pre-create the per-class read-status series at zero, so snapshots
    always carry the full (cls, status) matrix even before any error."""
    m = read_status_counter()
    for cls in FOLD_CLASSES:
        for status in ("corrected", "uncorrectable"):
            m.labels(cls=cls, status=status)


def fold_read_status(counts) -> None:
    """Fold a device-side status-count accumulator into the registry.

    ``counts`` is ``(len(FOLD_CLASSES), 2)`` — column 0 corrected, column 1
    detected-uncorrectable — produced inside the step's fused gather (see
    ``repro.serve.engine``). One tiny D2H transfer per step, outside jit.
    Also feeds the per-class reliability SLO (:mod:`repro.obs.slo`), so a
    SECDED uncorrectable surfacing on the read path breaches immediately.
    """
    from repro.obs import slo
    c = np.asarray(counts)
    for i, cls in enumerate(FOLD_CLASSES):
        if c[i, 0] or c[i, 1]:
            slo.TRACKER.record_read_status(cls, corrected=int(c[i, 0]),
                                           uncorrectable=int(c[i, 1]))
    if not REGISTRY.enabled:
        return
    m = read_status_counter()
    for i, cls in enumerate(FOLD_CLASSES):
        if c[i, 0]:
            m.labels(cls=cls, status="corrected").inc(int(c[i, 0]))
        if c[i, 1]:
            m.labels(cls=cls, status="uncorrectable").inc(int(c[i, 1]))


def record_pool_capacity(pool_name: str, pool) -> None:
    """Publish a pool's boundary-register capacity split as gauges.

    Called whenever a boundary is created or moved; the per-class page
    gauges are the "capacity reclaimed rides the boundary register" story.
    Also feeds the capacity SLO (:mod:`repro.obs.slo`).
    """
    from repro.obs import slo
    slo.TRACKER.record_capacity(pool_name, pool)
    if not REGISTRY.enabled:
        return
    from repro.core.layouts import Layout
    g = gauge(NAME_CAPACITY_PAGES,
              "device pages by storage class (rides the boundary register)",
              labels=("pool", "cls"))
    if pool.layout == Layout.BASELINE_ECC:
        cream_cls = "secded"
    elif pool.layout == Layout.PARITY:
        cream_cls = "parity"
    else:
        cream_cls = "none"
    daec_pages = getattr(pool, "daec_rows", 0)
    secded_pages = pool.num_rows - pool.boundary - daec_pages
    cream_pages = pool.boundary + pool.num_extra_pages
    if cream_cls == "secded":
        g.labels(pool=pool_name, cls="secded").set(secded_pages + cream_pages)
    else:
        g.labels(pool=pool_name, cls="secded").set(secded_pages)
        g.labels(pool=pool_name, cls=cream_cls).set(cream_pages)
    if daec_pages:
        g.labels(pool=pool_name, cls="daec").set(daec_pages)
    gauge(NAME_CAPACITY_RECLAIMED,
          "extra pages reclaimed from code lanes",
          labels=("pool",)).labels(pool=pool_name).set(pool.num_extra_pages)

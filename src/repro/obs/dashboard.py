"""Terminal snapshot dashboard over the metrics registry + SLO tracker.

Renders a fixed-width text report — the same thing ``tools/creamtop.py``
prints — either live (from the process-global registry/tracker) or from a
previously collected snapshot dict (e.g. the ``_metrics`` blob
``benchmarks/run.py --profile`` embeds into ``BENCH_<suite>.json``).
"""
from __future__ import annotations

from repro.obs import metrics as _metrics
from repro.obs import slo as _slo

_W = 78


def _rule(ch: str = "-") -> str:
    return ch * _W


def _fmt_labels(labels: dict) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(labels.items())) or "-"


def _counter_rows(snap: dict, name: str) -> list[tuple[str, float]]:
    m = snap.get(name)
    if not m:
        return []
    return [(_fmt_labels(r["labels"]), r.get("value", 0.0))
            for r in m["series"]]


def render_slo(statuses: list[_slo.SLOStatus]) -> str:
    lines = [_rule("="), "SLO".center(_W), _rule("=")]
    lines.append(f"{'scope':<22}{'objective':<30}{'value':>12}  state")
    lines.append(_rule())
    for s in statuses:
        state = "OK " if s.ok else "BREACH"
        lines.append(f"{s.scope:<22}{s.objective:<30}{s.value:>12.4g}  "
                     f"{state}  {s.detail}")
    if not statuses:
        lines.append("(no objectives recorded)")
    return "\n".join(lines)


def render_metrics(snap: dict) -> str:
    lines = [_rule("="), "METRICS".center(_W), _rule("=")]
    interesting = (
        ("capacity", (_metrics.NAME_CAPACITY_PAGES,
                      _metrics.NAME_CAPACITY_RECLAIMED)),
        ("reliability", (_metrics.NAME_READ_STATUS,
                         _metrics.NAME_SCRUB_CORRECTED,
                         _metrics.NAME_SCRUB_UNCORRECTABLE,
                         _metrics.NAME_SCRUB_SWEEPS)),
        ("data plane", (_metrics.NAME_VM_READS, _metrics.NAME_VM_WRITES,
                        _metrics.NAME_PAGES_MIGRATED,
                        _metrics.NAME_MIGRATION_TO_HOST,
                        _metrics.NAME_SHARD_DISPATCH,
                        _metrics.NAME_SHARD_RING_PAGES)),
        ("serving", (_metrics.NAME_TOKENS_DECODED,
                     _metrics.NAME_DECODE_STEPS, _metrics.NAME_PREFILLS,
                     _metrics.NAME_PREEMPTIONS, _metrics.NAME_RESTORES)),
        ("objcache", (_metrics.NAME_OBJCACHE_OPS,)),
    )
    shown: set[str] = set()
    for section, names in interesting:
        rows = []
        for name in names:
            shown.add(name)
            for lab, val in _counter_rows(snap, name):
                rows.append((f"{name}{{{lab}}}" if lab != "-" else name, val))
        if not rows:
            continue
        lines.append(f"[{section}]")
        for label, val in rows:
            lines.append(f"  {label:<62}{val:>14g}")
    other = sorted(set(snap) - shown)
    leftovers = []
    for name in other:
        if snap[name]["kind"] == "histogram":
            for r in snap[name]["series"]:
                c, s = r.get("count", 0), r.get("sum", 0.0)
                if c:
                    leftovers.append(
                        (f"{name}{{{_fmt_labels(r['labels'])}}}",
                         f"n={c} mean={s / c:.1f}us"))
        else:
            for lab, val in _counter_rows(snap, name):
                leftovers.append(
                    (f"{name}{{{lab}}}" if lab != "-" else name, f"{val:g}"))
    if leftovers:
        lines.append("[other]")
        for label, val in leftovers:
            lines.append(f"  {label:<58}{val:>18}")
    return "\n".join(lines)


#: Heat ramp for the bank heatmap, coolest -> hottest.
_HEAT = " .:-=+*#@"


def render_bank_heatmap(memprof_blob: dict) -> str:
    """Render CREAM-Lens bank heatmaps from a ``_memprof`` blob.

    One 9-chip x 8-bank panel per replayed profile (rows = chips, with
    chip 8 the code/extra chip; columns = banks), each cell shaded by its
    share of the profile's hottest bank, plus the headline stats the
    profile carries (achieved BLP, row hit rate, tFAW stalls). This is
    the ``tools/creamtop.py --bench`` view of where bank-level
    parallelism actually lands.
    """
    lines = [_rule("="), "DRAM BANK PROFILE (CREAM-Lens)".center(_W),
             _rule("=")]
    profiles = memprof_blob.get("profiles", {})
    if not profiles:
        lines.append("(no bank profiles captured — run with --memprof)")
        return "\n".join(lines)
    for pname, prof in sorted(profiles.items()):
        o = prof.get("overall", {})
        lines.append(f"[{pname}]  streams={o.get('streams', 0)} "
                     f"accesses={o.get('accesses', 0)} "
                     f"blp={o.get('achieved_blp', 0.0):.2f} "
                     f"row_hit={o.get('row_hit_rate', 0.0):.1%} "
                     f"conflict={o.get('conflict_rate', 0.0):.1%} "
                     f"tfaw_stall={o.get('tfaw_stall_cycles', 0)}cy "
                     f"extra_chip={o.get('extra_chip_frac', 0.0):.1%}")
        heat = o.get("heatmap") or []
        peak = max((n for row in heat for n in row), default=0)
        lines.append("        " + " ".join(f"b{b}" for b in
                                           range(len(heat[0]) if heat else 0)))
        for chip, row in enumerate(heat):
            tag = "code" if chip == 8 else f"  c{chip}"
            cells = " ".join(
                (_HEAT[min(len(_HEAT) - 1,
                           (n * (len(_HEAT) - 1) + peak - 1) // peak)]
                 if peak else " ") * 2 for n in row)
            lines.append(f"  {tag}  {cells}")
        lines.append(_rule())
    return "\n".join(lines)


def render(snap: dict | None = None,
           statuses: list[_slo.SLOStatus] | None = None) -> str:
    """The full dashboard: SLO verdicts on top, metric sections below.

    With no arguments, reads the live process-global registry and tracker.
    """
    if snap is None:
        snap = _metrics.collect()
    if statuses is None:
        statuses = _slo.TRACKER.report()
    return render_slo(statuses) + "\n\n" + render_metrics(snap) + "\n"

"""Hot-path tracing: nestable spans with a Perfetto/chrome-tracing export.

Spans are recorded as chrome-tracing *complete events* (``"ph": "X"``)
with microsecond timestamps, so the export loads directly in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``. Nesting comes for free:
chrome's trace viewer stacks events on the same tid by containment, and a
thread-local depth counter is recorded in ``args.depth`` for tools that
want it explicitly.

Disabled (the default), :func:`span` returns a shared null context — one
boolean read per call site, no allocation, no clock reads — so tracing can
stay compiled into every hot path.

The compile-vs-execute helper :func:`traced_call` wraps a jitted callable
in two spans: ``<name>.dispatch`` (tracing + compilation on first call,
then just dispatch) and ``<name>.block_until_ready`` (device execution),
which is how the benchmarks' ``--profile`` mode attributes kernel time.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time

import jax


class _NullSpan:
    """Shared do-nothing context for the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class _Span:
    __slots__ = ("tracer", "name", "args", "t0")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self.tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self):
        tl = self.tracer._tls
        tl.depth = getattr(tl, "depth", 0) + 1
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        dur_us = (time.perf_counter_ns() - self.t0) / 1e3
        tl = self.tracer._tls
        depth = getattr(tl, "depth", 1)
        tl.depth = depth - 1
        args = dict(self.args)
        args["depth"] = depth - 1
        self.tracer._events.append({
            "name": self.name, "ph": "X", "cat": "cream",
            "ts": self.t0 / 1e3, "dur": dur_us,
            "pid": os.getpid(), "tid": threading.get_ident(),
            "args": args,
        })
        return False


class Tracer:
    """An event buffer. The process-global one is :data:`TRACER`."""

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self._events: list[dict] = []
        self._tls = threading.local()

    def span(self, name: str, **args):
        if not self.enabled:
            return _NULL
        return _Span(self, name, args)

    def instant(self, name: str, **args) -> None:
        """A zero-duration marker event."""
        if not self.enabled:
            return
        self._events.append({
            "name": name, "ph": "i", "cat": "cream", "s": "t",
            "ts": time.perf_counter_ns() / 1e3,
            "pid": os.getpid(), "tid": threading.get_ident(),
            "args": args,
        })

    @property
    def events(self) -> list[dict]:
        return self._events

    def extend(self, events: list[dict]) -> None:
        """Append pre-built chrome-tracing events (e.g. CREAM-Lens counter
        tracks, ``"ph": "C"``) so they export alongside the spans.
        Unconditional: exporters inject into a buffer they already own."""
        self._events.extend(events)

    def reset(self) -> None:
        self._events = []

    def to_dict(self) -> dict:
        return {"traceEvents": list(self._events),
                "displayTimeUnit": "ms"}

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)

    def span_names(self) -> set[str]:
        return {e["name"] for e in self._events}


#: The process-global tracer every subsystem emits into.
TRACER = Tracer(enabled=False)


def enabled() -> bool:
    return TRACER.enabled


def enable(on: bool = True) -> None:
    TRACER.enabled = on


def disable() -> None:
    TRACER.enabled = False


def span(name: str, **args):
    """Open a span on the global tracer (null context when disabled)."""
    if not TRACER.enabled:
        return _NULL
    return _Span(TRACER, name, args)


def instant(name: str, **args) -> None:
    TRACER.instant(name, **args)


def reset() -> None:
    TRACER.reset()


def export(path: str) -> None:
    TRACER.export(path)


def traced_call(name: str, fn, *args, **kwargs):
    """Run ``fn`` under dispatch / block_until_ready spans.

    ``<name>.dispatch`` covers tracing+compilation (dominant on the first
    call for a given shape) plus async dispatch; ``<name>.block_until_ready``
    covers device execution. With tracing disabled this is a plain call —
    no blocking, no spans — so it is safe on hot paths.
    """
    if not TRACER.enabled:
        return fn(*args, **kwargs)
    with span(f"{name}.dispatch"):
        out = fn(*args, **kwargs)
    with span(f"{name}.block_until_ready"):
        jax.block_until_ready(out)
    return out


@contextlib.contextmanager
def blocked_span(name: str, **args):
    """Span that blocks on the values the body hands back via ``hold``.

    Usage::

        with blocked_span("engine.step.gather") as hold:
            pages = pool.read(phys)
            hold(pages)

    ensures the span's duration covers device execution, not just async
    dispatch. When tracing is disabled the body still runs; ``hold`` is a
    no-op and nothing blocks.
    """
    if not TRACER.enabled:
        yield lambda *_: None
        return
    with span(name, **args):
        held = []
        yield held.append
        if held:
            jax.block_until_ready(held)

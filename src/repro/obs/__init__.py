"""CREAM-Scope — the unified telemetry plane.

Three cooperating pieces, all host-side control plane (nothing here ever
runs inside jit; device-side accumulators are tiny status arrays produced
by the existing fused reads and *folded* into the registry between steps):

  * :mod:`repro.obs.metrics` — a process-global registry of counters /
    gauges / histograms with labelled series (pool, reliability class,
    tier, region), a Prometheus-style text exposition, and fold helpers
    for device-side status accumulators;
  * :mod:`repro.obs.tracing` — nestable spans with a Perfetto /
    chrome-tracing JSON exporter, instrumenting the named hot paths
    (``Engine.step`` gather/compute/scatter, the shard router dispatch
    and ``ppermute`` migration ring, ``repartition_with_migration``,
    scrub sweeps, objcache batched get/set);
  * :mod:`repro.obs.slo` + :mod:`repro.obs.dashboard` — per-reliability-
    class SLO tracking (uncorrectable reads on SECDED frames must be 0;
    capacity reclaimed rides the boundary register) and a terminal
    snapshot dashboard (``tools/creamtop.py``);
  * :mod:`repro.obs.memprof` — CREAM-Lens, the bank-level memory-system
    profiler: captures the data plane's page-access streams, attributes
    them to (chip, bank, row) via the layout translation, and replays
    them through the per-bank state machines in ``benchmarks/dram_sim``
    (row-buffer hits/conflicts, achieved BLP, tRRD/tFAW stalls).

Everything is opt-in: with all planes disabled (the default) every
instrumentation site reduces to one boolean check, so the hot paths stay
one-gather/one-scatter with no extra dispatches.
"""
from repro.obs import dashboard, memprof, metrics, slo, tracing

__all__ = ["metrics", "tracing", "slo", "dashboard", "memprof"]

"""CREAM-Lens — the bank-level memory-system profiler.

CREAM-Scope (:mod:`repro.obs.metrics` / :mod:`repro.obs.tracing`) sees the
stack down to the page dispatch; this module sees *below* the page. It
answers the question the flat ``fig9_real_ws_*`` rows left open: when the
sharded data plane fails to turn bank-level parallelism into speedup,
where does the concurrency actually go — router serialization, row-buffer
conflicts, or activation-window (tRRD/tFAW) stalls?

Three stages, mirroring a hardware profiler:

  1. **Capture** — :func:`record` appends :class:`AccessRecord`\\ s (step,
     op, page ids, pool geometry, stream label) from cheap opt-in hooks on
     the pool engines (``repro.core.pool`` gather/scatter wrappers, the
     sharded pool's routed/stream dispatches, the serving engine's decode
     gather, the object cache). Disabled (the default) every hook is one
     module-boolean read; nothing allocates.
  2. **Attribute** — :func:`page_coords_np` + :func:`code_rows_np` are
     numpy mirrors of :func:`repro.core.layouts.page_coords` (property-
     tested bit-exact against the jnp oracle): every page id becomes its 8
     physical ``(row, lane)`` slices plus the layout's extra-chip traffic
     (SECDED code reads, packed-parity rows).
  3. **Replay** — :func:`replay` runs each stream's records through the
     gram-style per-bank state machines of ``benchmarks.dram_sim``
     (``BankArray``: row-buffer state, tRCD/tRP/tCAS, per-chip tRRD/tFAW
     activation windows, per-bank queues), yielding per-bank row
     hit/miss/conflict counts, achieved-BLP histograms, tFAW-stall cycles
     and queue-depth percentiles.

:func:`collect` snapshots everything for ``benchmarks/run.py --memprof``
(embedded as the ``_memprof`` blob in ``BENCH_<suite>.json``); with
metrics enabled the profile is also exported as ``cream_dram_bank_*``
gauges, and :func:`counter_events` turns stream timelines into Perfetto
counter tracks ("ph": "C") that sit next to the gather/compute/scatter
spans. See docs/observability.md § Memory-system profiling.
"""
from __future__ import annotations

import math
import os
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.layouts import (CODE_LANE, DATA_LANES, DEFAULT_ROW_WORDS,
                                GROUP_ROWS, LANES, REGION_CREAM,
                                REGION_EXTRA, REGION_SECDED, WRAP_LANES,
                                WRAP_ROWS, Layout, extra_base_row)

#: Capture cap: one record per engine dispatch, so this bounds *dispatches*,
#: not pages. Overflow increments ``dropped`` (reported, never silent).
MAX_RECORDS = 4096

_LOCK = threading.Lock()


@dataclass
class AccessRecord:
    """One captured data-plane dispatch (a batch gather or scatter)."""
    step: int
    t_us: float                  # perf_counter_ns/1e3 — same clock as spans
    op: str                      # "gather" | "scatter"
    pages: np.ndarray            # (n,) page ids in the *pool's own* id space
    layout: Layout
    num_rows: int                # pool (or shard-local) regular-page count
    boundary: int                # CREAM/SECDED split of that id space
    row_words: int
    pool: str = "pool"
    stream: str = "main"         # replay lane: one BankArray per stream


class MemProfiler:
    """Capture buffer + published-profile store. Global instance: PROFILER."""

    def __init__(self) -> None:
        self.enabled = False
        self.records: list[AccessRecord] = []
        self.dropped = 0
        self.step = 0
        self.published: dict[str, dict] = {}

    def record(self, op: str, pages, *, layout: Layout, num_rows: int,
               boundary: int, row_words: int = DEFAULT_ROW_WORDS,
               pool: str = "pool", stream: str = "main") -> None:
        if not self.enabled:
            return
        if op not in ("gather", "scatter"):
            raise ValueError(f"op must be gather|scatter, got {op!r}")
        arr = np.asarray(pages, dtype=np.int64).reshape(-1)
        with _LOCK:
            if len(self.records) >= MAX_RECORDS:
                self.dropped += 1
                return
            self.records.append(AccessRecord(
                self.step, time.perf_counter_ns() / 1e3, op, arr, layout,
                int(num_rows), int(boundary), int(row_words), pool, stream))

    def next_step(self) -> None:
        self.step += 1

    def reset(self) -> None:
        """Drop captured records (published profiles survive)."""
        with _LOCK:
            self.records = []
            self.dropped = 0
            self.step = 0

    def clear(self) -> None:
        """Full reset: records AND published profiles."""
        self.reset()
        self.published = {}

    def publish(self, name: str, profile: dict) -> None:
        """Stash a replayed profile under ``name`` (survives reset())."""
        self.published[str(name)] = profile


#: The process-global profiler every hook records into.
PROFILER = MemProfiler()


def enabled() -> bool:
    return PROFILER.enabled


def enable(on: bool = True) -> None:
    PROFILER.enabled = on


def disable() -> None:
    PROFILER.enabled = False


def record(op: str, pages, **kw) -> None:
    PROFILER.record(op, pages, **kw)


def next_step() -> None:
    PROFILER.next_step()


def reset() -> None:
    PROFILER.reset()


def clear() -> None:
    PROFILER.clear()


def publish(name: str, profile: dict) -> None:
    PROFILER.publish(name, profile)


def records() -> list[AccessRecord]:
    return list(PROFILER.records)


# ---------------------------------------------------------------------------
# Attribution: numpy mirror of layouts.page_coords (+ extra-chip traffic).
# Host-side replay must not touch the device, so the jnp translation is
# mirrored in numpy; tests/test_memprof.py proves the mirror bit-exact
# against the jnp oracle for every layout × boundary.
# ---------------------------------------------------------------------------


def page_coords_np(layout: Layout, num_rows: int, boundary: int,
                   pages: np.ndarray, row_words: int = DEFAULT_ROW_WORDS
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Numpy twin of :func:`repro.core.layouts.page_coords`.

    Returns ``(rows (n, 8), lanes (n, 8), region (n,))`` int32 — the 8
    physical (row, lane) slices holding each page's data, and its REGION_*
    code. Same contract as the jnp original, including the INTERWRAP wrap
    tables and the extra-page code-lane packing.
    """
    pages = np.asarray(pages, dtype=np.int64).reshape(-1)
    n = pages.shape[0]
    k = np.arange(DATA_LANES, dtype=np.int64)
    is_secded = (pages >= boundary) & (pages < num_rows)
    is_extra = pages >= num_rows
    region = np.where(is_secded, REGION_SECDED,
                      np.where(is_extra, REGION_EXTRA,
                               REGION_CREAM)).astype(np.int32)
    e = pages - num_rows
    row_rows = np.broadcast_to(pages[:, None], (n, DATA_LANES))
    row_lanes = np.broadcast_to(k[None, :], (n, DATA_LANES))

    if layout == Layout.INTERWRAP:
        group = np.where(is_extra, e, pages // GROUP_ROWS)
        slot = np.where(is_extra, GROUP_ROWS, pages % GROUP_ROWS)
        w_lanes = WRAP_LANES[slot]
        w_rows = GROUP_ROWS * group[:, None] + WRAP_ROWS[slot]
        in_sec = is_secded[:, None]
        rows = np.where(in_sec, row_rows, w_rows)
        lanes = np.where(in_sec, row_lanes, w_lanes)
        return rows.astype(np.int32), lanes.astype(np.int32), region

    ebase = extra_base_row(layout, boundary, row_words)
    ex_rows = ebase + GROUP_ROWS * e[:, None] + k[None, :]
    rows = np.where(is_extra[:, None], ex_rows, row_rows)
    lanes = np.where(is_extra[:, None], CODE_LANE, row_lanes)
    return rows.astype(np.int32), lanes.astype(np.int32), region


def code_rows_np(layout: Layout, num_rows: int, boundary: int,
                 pages: np.ndarray, row_words: int = DEFAULT_ROW_WORDS
                 ) -> np.ndarray:
    """Extra-chip (lane 8) row each page's access additionally touches.

    -1 = none. SECDED-region pages read their code row (same row, lane 8);
    PARITY-layout CREAM/extra pages read their packed-parity row (mirrors
    :func:`repro.core.layouts.parity_coords`). This is exactly the traffic
    CREAM's layouts add to chip 8 — the paper's §4.4 overhead source.
    """
    pages = np.asarray(pages, dtype=np.int64).reshape(-1)
    is_secded = (pages >= boundary) & (pages < num_rows)
    out = np.full(pages.shape, -1, dtype=np.int64)
    out[is_secded] = pages[is_secded]
    if layout == Layout.PARITY and boundary > 0:
        rel = np.where(pages >= num_rows, boundary + (pages - num_rows),
                       pages)
        tables = math.ceil(boundary / 8)
        prow = np.where(rel < boundary, rel // 8,
                        tables + np.maximum(rel - boundary, 0) // 8)
        out = np.where(is_secded, out, prow)
    return out.astype(np.int32)


# ---------------------------------------------------------------------------
# Replay: captured streams -> per-bank state machines (benchmarks.dram_sim)
# ---------------------------------------------------------------------------


def _dram_sim():
    """Lazy import: src/ never hard-depends on benchmarks/ at import time."""
    try:
        from benchmarks import dram_sim
    except ImportError as e:   # pragma: no cover - environment-specific
        raise ImportError(
            "memprof.replay needs benchmarks/dram_sim.py on sys.path "
            "(run with PYTHONPATH=src:. from the repo root)") from e
    return dram_sim


@dataclass
class _StreamReplay:
    array: object                       # dram_sim.BankArray
    timeline: list[dict] = field(default_factory=list)
    pages: int = 0
    slice_accesses: int = 0
    extra_chip_accesses: int = 0


def replay(recs: list[AccessRecord] | None = None,
           timing=None) -> dict[str, _StreamReplay]:
    """Run captured records through per-bank state machines, per stream.

    Each ``(pool, stream)`` pair gets its own :class:`BankArray` — its own
    rank-subset hardware, matching the sharded pool's model where every
    shard is an independent module. Within one record (one engine
    dispatch) all page accesses issue simultaneously; banks serialize via
    their own occupancy. Consecutive records on a stream issue
    back-to-back (dispatch N+1 starts when N's slowest bank finishes).
    """
    ds = _dram_sim()
    t = timing or ds.Timing()
    recs = PROFILER.records if recs is None else recs
    streams: dict[str, _StreamReplay] = {}
    for rec in recs:
        key = rec.stream if rec.pool == "pool" else \
            f"{rec.pool}/{rec.stream}"
        sr = streams.get(key)
        if sr is None:
            bridge = 0 if rec.layout == Layout.BASELINE_ECC else t.bridge
            sr = streams[key] = _StreamReplay(
                ds.BankArray(t, chips=LANES, banks=ds.NUM_BANKS,
                             bridge_cycles=bridge))
        arr = sr.array
        rows, lanes, _ = page_coords_np(rec.layout, rec.num_rows,
                                        rec.boundary, rec.pages,
                                        rec.row_words)
        crow = code_rows_np(rec.layout, rec.num_rows, rec.boundary,
                            rec.pages, rec.row_words)
        now = arr.finish_cycle
        for i in range(rec.pages.shape[0]):
            slices = [(int(lanes[i, j]),) + ds.bank_of(int(rows[i, j]))
                      for j in range(DATA_LANES)]
            if crow[i] >= 0:
                slices.append((CODE_LANE,) + ds.bank_of(int(crow[i])))
            arr.access(slices, now)
            sr.slice_accesses += len(slices)
            sr.extra_chip_accesses += sum(1 for c, _, _ in slices
                                          if c == CODE_LANE)
        sr.pages += int(rec.pages.shape[0])
        tot = arr.totals()
        sr.timeline.append({
            "t_us": rec.t_us, "op": rec.op, "pages": int(rec.pages.shape[0]),
            "blp": round(arr.achieved_blp, 4),
            "row_hit_rate": round(arr.row_hit_rate, 4),
            "queue_depth": int(arr.queue_depths[-1])
            if arr.queue_depths else 0,
            "tfaw_stall_cycles": int(tot.faw_stall_cycles),
        })
    return streams


def _stream_stats(sr: _StreamReplay) -> dict:
    arr = sr.array
    tot = arr.totals()
    acc = tot.accesses
    heat = [[arr.machine(c, b).counters.accesses
             for b in range(arr.banks)] for c in range(arr.chips)]
    return {
        "pages": sr.pages,
        "accesses": acc,
        "row_hits": tot.row_hits,
        "row_empty": tot.row_empty,
        "row_conflicts": tot.row_conflicts,
        "row_hit_rate": round(arr.row_hit_rate, 4),
        "conflict_rate": round(tot.row_conflicts / acc, 4) if acc else 0.0,
        "achieved_blp": round(arr.achieved_blp, 4),
        "busy_cycles": tot.busy_cycles,
        "finish_cycle": arr.finish_cycle,
        "act_stall_cycles": tot.act_stall_cycles,
        "tfaw_stall_cycles": tot.faw_stall_cycles,
        "queue_p50": arr.queue_depth_percentile(50),
        "queue_p99": arr.queue_depth_percentile(99),
        "blp_hist": arr.blp_histogram(),
        "extra_chip_frac": round(
            sr.extra_chip_accesses / sr.slice_accesses, 4)
        if sr.slice_accesses else 0.0,
        "heatmap": heat,
        "timeline": sr.timeline,
    }


def profile(recs: list[AccessRecord] | None = None, timing=None) -> dict:
    """Replay + aggregate: the JSON-ready per-bank profile.

    ``overall`` treats the streams as concurrent hardware (the sharded
    pool's model): busy-bank cycles sum across streams while the makespan
    is the slowest stream's — so overall achieved-BLP grows with shard
    count only if the per-shard replays genuinely overlap.
    """
    ds = _dram_sim()
    t = timing or ds.Timing()
    streams = replay(recs, t)
    out_streams = {k: _stream_stats(v) for k, v in sorted(streams.items())}
    busy = sum(s["busy_cycles"] for s in out_streams.values())
    makespan = max((s["finish_cycle"] for s in out_streams.values()),
                   default=0)
    acc = sum(s["accesses"] for s in out_streams.values())
    hits = sum(s["row_hits"] for s in out_streams.values())
    confl = sum(s["row_conflicts"] for s in out_streams.values())
    sl = sum(v.slice_accesses for v in streams.values())
    xc = sum(v.extra_chip_accesses for v in streams.values())
    heat = np.zeros((LANES, ds.NUM_BANKS), dtype=np.int64)
    for s in out_streams.values():
        heat += np.asarray(s["heatmap"], dtype=np.int64)
    overall = {
        "streams": len(out_streams),
        "pages": sum(s["pages"] for s in out_streams.values()),
        "accesses": acc,
        "row_hit_rate": round(hits / acc, 4) if acc else 0.0,
        "conflict_rate": round(confl / acc, 4) if acc else 0.0,
        "achieved_blp": round(busy / makespan, 4) if makespan else 0.0,
        "act_stall_cycles": sum(s["act_stall_cycles"]
                                for s in out_streams.values()),
        "tfaw_stall_cycles": sum(s["tfaw_stall_cycles"]
                                 for s in out_streams.values()),
        "queue_p99": max((s["queue_p99"] for s in out_streams.values()),
                         default=0.0),
        "extra_chip_frac": round(xc / sl, 4) if sl else 0.0,
        "heatmap": heat.tolist(),
    }
    return {
        "timing": {"tCK_ns": t.tCK_ns, "tRCD": t.tRCD, "tRP": t.tRP,
                   "tCL": t.tCL, "tBL": t.tBL, "tRRD": t.tRRD,
                   "tFAW": t.tFAW, "bridge": t.bridge},
        "streams": out_streams,
        "overall": overall,
        "records": len(PROFILER.records if recs is None else recs),
        "dropped": PROFILER.dropped,
    }


# ---------------------------------------------------------------------------
# Export: metrics gauges, Perfetto counter tracks, run.py blob
# ---------------------------------------------------------------------------


def emit_metrics(prof: dict, suite: str = "pool") -> None:
    """Export one profile's stats as ``cream_dram_bank_*`` labelled gauges."""
    from repro.obs import metrics
    if not metrics.enabled():
        return
    lab = ("suite", "stream")
    g_hit = metrics.gauge(metrics.NAME_DRAM_ROW_HIT_RATE,
                          "replayed per-bank row-buffer hit fraction", lab)
    g_con = metrics.gauge(metrics.NAME_DRAM_CONFLICT_RATE,
                          "replayed row-buffer conflict fraction", lab)
    g_blp = metrics.gauge(metrics.NAME_DRAM_BLP,
                          "achieved bank-level parallelism (busy/makespan)",
                          lab)
    g_faw = metrics.gauge(metrics.NAME_DRAM_TFAW_STALL,
                          "cycles stalled on the four-ACT tFAW window", lab)
    g_q99 = metrics.gauge(metrics.NAME_DRAM_QUEUE_P99,
                          "p99 per-bank request queue depth", lab)
    g_xtr = metrics.gauge(metrics.NAME_DRAM_EXTRA_CHIP,
                          "fraction of slice accesses on the code chip", lab)
    items = [("overall", prof["overall"])] + list(prof["streams"].items())
    for stream, s in items:
        kv = dict(suite=suite, stream=stream)
        g_hit.labels(**kv).set(s["row_hit_rate"])
        g_con.labels(**kv).set(s["conflict_rate"])
        g_blp.labels(**kv).set(s["achieved_blp"])
        g_faw.labels(**kv).set(s["tfaw_stall_cycles"])
        g_q99.labels(**kv).set(s["queue_p99"])
        g_xtr.labels(**kv).set(s["extra_chip_frac"])
    c_acc = metrics.counter(metrics.NAME_DRAM_ACCESSES,
                            "replayed accesses per (chip, bank)",
                            ("suite", "chip", "bank"))
    for chip, row in enumerate(prof["overall"]["heatmap"]):
        for bank, n in enumerate(row):
            if n:
                c_acc.labels(suite=suite, chip=str(chip),
                             bank=str(bank)).inc(n)


def counter_events(blob: dict) -> list[dict]:
    """Perfetto counter tracks ("ph": "C") from profile timelines.

    One ``dram.bank[<profile>/<stream>]`` track per replayed stream, with
    ``blp`` / ``row_hit_rate_pct`` / ``queue`` series, timestamped with the
    capture clock so the lanes line up with the gather/compute/scatter
    spans in the same trace.
    """
    profiles = blob.get("profiles", {}) if "profiles" in blob \
        else {"profile": blob}
    pid = os.getpid()
    events: list[dict] = []
    for pname, prof in profiles.items():
        for stream, s in prof.get("streams", {}).items():
            track = f"dram.bank[{pname}/{stream}]"
            for pt in s.get("timeline", []):
                events.append({
                    "name": track, "ph": "C", "cat": "cream",
                    "ts": pt["t_us"], "pid": pid,
                    "args": {"blp": pt["blp"],
                             "row_hit_rate_pct": 100 * pt["row_hit_rate"],
                             "queue": pt["queue_depth"]},
                })
    return events


def collect(timing=None) -> dict:
    """Snapshot for ``run.py --memprof``: published profiles + a replay of
    any records still in the buffer (suites without explicit publishing
    still get a ``live`` profile). Also exports metrics gauges when the
    metrics plane is on."""
    profiles = dict(PROFILER.published)
    if PROFILER.records:
        profiles.setdefault("live", profile(timing=timing))
    for name, prof in profiles.items():
        emit_metrics(prof, suite=name)
    return {
        "records": len(PROFILER.records),
        "dropped": PROFILER.dropped,
        "profiles": profiles,
    }

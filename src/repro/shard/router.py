"""Shard router — global page ids -> (shard, local page), vectorised.

The sharded pool stripes the global page-id space round-robin over the
``banks`` mesh axis, the software analogue of DRAM bank interleaving and of
the paper's rank subsetting (§4.1.2): every shard is an independent,
identically-shaped CREAM mini-pool, and consecutive global pages land on
consecutive shards so any dense access naturally fans out across all banks.

Global convention (identical to :mod:`repro.core.pool`'s single-pool one):

    pages [0, boundary)            CREAM-region regular pages
    pages [boundary, num_rows)     SECDED-protected pages
    pages [num_rows, num_pages)    reclaimed extra pages

With ``S`` shards of ``R_local`` rows and local boundary ``b_local``:

  * regular page ``p``  -> shard ``p % S``,  local page ``p // S``;
  * extra page ``num_rows + e`` -> shard ``e % S``,
    local page ``R_local + e // S``.

Because ``boundary = S * b_local`` and ``p < S*b_local  <=>  p//S < b_local``,
the *global* region of a page (CREAM / SECDED / extra) is exactly the *local*
region of its routed id — the router never has to know where the boundary
is, and a page's physical home never moves when the boundary does (the same
invariant the local pool's repartition relies on for id stability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layouts import GROUP_ROWS


def route(pages: jax.Array, num_rows: int, num_shards: int
          ) -> tuple[jax.Array, jax.Array]:
    """Translate global page ids -> ``(shard (n,), local (n,))`` int32.

    ``num_rows`` is the *global* regular-page count (``S * R_local``); ids
    follow the global convention above. Fully traceable.
    """
    pages = jnp.asarray(pages, jnp.int32).reshape(-1)
    rows_local = num_rows // num_shards
    is_extra = pages >= num_rows
    e = pages - num_rows
    shard = jnp.where(is_extra, e % num_shards, pages % num_shards)
    local = jnp.where(is_extra, rows_local + e // num_shards,
                      pages // num_shards)
    return shard.astype(jnp.int32), local.astype(jnp.int32)


def unroute(shard, local, num_rows: int, num_shards: int) -> jax.Array:
    """Inverse of :func:`route`: (shard, local) -> global page ids."""
    shard = jnp.asarray(shard, jnp.int32)
    local = jnp.asarray(local, jnp.int32)
    rows_local = num_rows // num_shards
    is_extra = local >= rows_local
    e_local = local - rows_local
    return jnp.where(is_extra, num_rows + e_local * num_shards + shard,
                     local * num_shards + shard).astype(jnp.int32)


def route_np(pages: np.ndarray, num_rows: int, num_shards: int
             ) -> tuple[np.ndarray, np.ndarray]:
    """Host-side (numpy) :func:`route` for concrete page-id vectors."""
    p = np.asarray(pages, np.int64).reshape(-1)
    rows_local = num_rows // num_shards
    is_extra = p >= num_rows
    e = p - num_rows
    shard = np.where(is_extra, e % num_shards, p % num_shards)
    local = np.where(is_extra, rows_local + e // num_shards, p // num_shards)
    return shard, local


def plan_streams(pages: np.ndarray, num_rows: int, num_shards: int
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Regroup concrete global ids into bank-aligned padded streams.

    Returns ``(spages (S, m) int32, valid (S, m) bool, inv (n,) int64)``:
    stream ``s`` holds exactly the batch entries shard ``s`` owns (original
    order preserved within the stream), padded to a power-of-two width
    ``m`` with shard ``s``'s own page id ``s`` (``valid`` False) so every
    stream keeps the alignment invariant and pad reads are harmless.
    ``inv[i] = s * m + pos`` recovers entry ``i`` from the flattened
    ``(S * m, ...)`` stream output — the one device-side permute that
    replaces the owner-select chain. This is the host half of the fused
    dispatch: one numpy pass over ids the caller already holds, then ONE
    jitted device program (see :meth:`repro.shard.ShardedPool.read`).
    """
    S = num_shards
    p = np.asarray(pages, np.int64).reshape(-1)
    shard, _ = route_np(p, num_rows, S)
    counts = np.bincount(shard, minlength=S)
    m = 1 << max(0, int(counts.max(initial=1) - 1)).bit_length()
    order = np.argsort(shard, kind="stable")
    starts = np.zeros(S, np.int64)
    starts[1:] = np.cumsum(counts)[:-1]
    within = np.arange(p.size) - np.repeat(starts, counts)
    spages = np.broadcast_to(np.arange(S, dtype=np.int64)[:, None],
                             (S, m)).copy()
    valid = np.zeros((S, m), bool)
    spages[shard[order], within] = p[order]
    valid[shard[order], within] = True
    inv = np.empty(p.size, np.int64)
    inv[order] = shard[order] * m + within
    return spages.astype(np.int32), valid, inv


def owned_mask(shard: jax.Array, num_shards: int) -> jax.Array:
    """``(S, n)`` bool: row ``s`` flags the batch entries shard ``s`` owns.

    Laid out shard-major so it can enter a ``shard_map`` with
    ``P('banks')`` — each shard sees exactly its own ``(1, n)`` slice.
    """
    return shard[None, :] == jnp.arange(num_shards, dtype=jnp.int32)[:, None]


def check_geometry(num_rows: int, boundary: int, num_shards: int) -> None:
    """Validate that a (rows, boundary) pair shards evenly over S banks."""
    step = num_shards * GROUP_ROWS
    if num_shards < 1:
        raise ValueError(f"need at least one shard, got {num_shards}")
    if num_rows % step:
        raise ValueError(
            f"num_rows ({num_rows}) must be a multiple of shards*group "
            f"({step})")
    if boundary % step or not 0 <= boundary <= num_rows:
        raise ValueError(
            f"boundary ({boundary}) must be a multiple of {step} in "
            f"[0, {num_rows}]")

"""CREAM-Shard: the CREAM data plane partitioned over a ``banks`` mesh axis.

See :mod:`repro.shard.pool` for the sharded pool and its dispatch shapes,
and :mod:`repro.shard.router` for the global-id -> (shard, local)
translation.
"""
from repro.shard.pool import (ShardedPool, evicted_extra_pages,
                              make_sharded_pool, migrate_pages, read_any,
                              read_any_status, read_any_writeback,
                              read_streams, repartition, scrub,
                              set_daec_rows, write_any, write_streams)
from repro.shard.router import plan_streams, route, unroute

__all__ = [
    "ShardedPool", "make_sharded_pool", "read_any", "read_any_status",
    "read_any_writeback", "write_any", "read_streams", "write_streams",
    "migrate_pages", "repartition", "evicted_extra_pages", "scrub",
    "set_daec_rows", "route", "unroute", "plan_streams",
]

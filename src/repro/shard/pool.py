"""CREAM-Shard — the CREAM pool partitioned across a ``banks`` mesh axis.

The paper's second headline claim is that CREAM *increases bank-level
parallelism*: rank subsetting (§4.1.2) splits the DIMM into independently
addressable subsets, and Figs. 9–11 measure the resulting concurrency win.
This module is that mechanism on the real data plane: the pool's rows are
striped round-robin over ``S`` devices of a 1-D ``banks`` mesh
(:func:`repro.launch.mesh.make_banks_mesh`), every shard holds an
identically-shaped mini CREAM pool ``(R_local, 9, W)`` with the same
boundary register, and the whole mixed-pool access engine of
:mod:`repro.core.pool` — one ``page_coords`` translation, one
gather/scatter, masked batched codecs — runs unchanged *inside each shard*
under ``shard_map``. On TPU the per-shard read is the fused Pallas mixed
kernel; on CPU it is the vectorised engine (the kernel's oracle).

Three dispatch shapes, by locality:

  * :func:`read_any` / :func:`write_any` — arbitrary global page-id vectors.
    The router (:mod:`repro.shard.router`) translates ids to (shard, local);
    every shard traces the same program over the full batch and keeps only
    the pages it owns (reads: owner-select on the stacked output; writes:
    the engine's ``valid`` mask drops foreign pages). **No cross-shard
    collectives** — the only inter-device motion is the final owner-select
    gather that assembles the replicated result.
  * :func:`read_streams` / :func:`write_streams` — bank-parallel hot path:
    ``(S, n)`` page ids, stream ``s`` touching only shard ``s``'s pages
    (``page % S == s``). Each bank serves its stream fully independently —
    the measured Figs. 9–11 concurrency story (``benchmarks/bench_shard.py``).
  * :func:`migrate_pages` — cross-shard relocation as an explicit
    ``ppermute`` ring exchange: each shard reads its owned source pages,
    the batch circulates around the ring, and every shard lands the pages
    addressed to it with a masked code-maintaining write.

:func:`repartition` moves every shard's boundary in lockstep (one
``shard_map`` over the local repartition, which re-encodes in place), so
the global page-id convention — and therefore every owner's bookkeeping —
is preserved exactly as for the local pool.

:class:`ShardedPool` implements :class:`repro.core.pool.PoolLike`; the VM
(:mod:`repro.vm`), object cache (:mod:`repro.objcache`) and serving tier
(:mod:`repro.serve`) run on it unchanged.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

try:                                    # jax >= 0.6 moved it to the top level
    from jax import shard_map           # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map

from repro.core import pool as pool_lib
from repro.core.layouts import (GROUP_ROWS, LANES, Layout, extra_page_count)
from repro.core.pool import PoolState
from repro.obs import memprof as obs_memprof
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.shard import router


def _note_dispatch(op: str, pages: int) -> None:
    """Count one routed host-side dispatch through the shard data plane."""
    if not obs_metrics.enabled():
        return
    obs_metrics.counter(
        obs_metrics.NAME_SHARD_DISPATCH,
        "routed dispatches through the sharded data plane",
        labels=("op",)).labels(op=op).inc()


def _memprof_routed(state: "ShardedPool", op: str, pages,
                    stream: str = "main") -> None:
    """Feed one routed dispatch to CREAM-Lens, split per shard.

    Mirrors :func:`repro.shard.router.route` in numpy and records each
    shard's local id set against the shard's *local* geometry (its own
    module: ``rows_local`` rows, ``boundary_local``), stream ``bank<s>``
    — so replay models ``S`` independent BankArrays, exactly the
    rank-subset hardware the sharding claims to be.
    """
    if not obs_memprof.enabled() or isinstance(pages, jax.core.Tracer) \
            or isinstance(state.storage, jax.core.Tracer):
        return
    p = np.asarray(pages, dtype=np.int64).reshape(-1)
    S = state.num_shards
    is_extra = p >= state.num_rows
    e = p - state.num_rows
    shard = np.where(is_extra, e % S, p % S)
    local = np.where(is_extra, state.rows_local + e // S, p // S)
    prefix = "" if stream == "main" else f"{stream}/"
    for s in range(S):
        loc = local[shard == s]
        if loc.size == 0:
            continue
        obs_memprof.record(
            op, loc, layout=state.layout,
            num_rows=state.rows_local,
            boundary=state.boundary_local,
            row_words=state.row_words, stream=f"{prefix}bank{s}")


@jax.tree_util.register_dataclass
@dataclass
class ShardedPool:
    """Functional sharded pool state. ``storage`` is the only traced leaf.

    ``storage`` is ``(S, R_local, 9, W)`` uint32, laid out over the mesh's
    ``banks`` axis (leading dim). All other fields are static pytree
    metadata, so each (geometry, mesh) compiles once — exactly like the
    local pool's (boundary, layout, row_words) treatment.
    """
    storage: jax.Array                  # (S, R_local, 9, W) uint32
    boundary_local: int = dataclasses.field(metadata=dict(static=True))
    layout: Layout = dataclasses.field(metadata=dict(static=True))
    row_words: int = dataclasses.field(metadata=dict(static=True))
    mesh: jax.sharding.Mesh = dataclasses.field(metadata=dict(static=True))
    use_kernel: bool | None = dataclasses.field(
        default=None, metadata=dict(static=True))

    # -- geometry (global page-id convention, same as PoolState) ------------
    @property
    def num_shards(self) -> int:
        return self.storage.shape[0]

    @property
    def rows_local(self) -> int:
        return self.storage.shape[1]

    @property
    def num_rows(self) -> int:
        return self.num_shards * self.rows_local

    @property
    def boundary(self) -> int:
        return self.num_shards * self.boundary_local

    @property
    def boundary_step(self) -> int:
        """Boundary moves in lockstep across shards: S * GROUP_ROWS rows."""
        return self.num_shards * GROUP_ROWS

    @property
    def extra_pages_local(self) -> int:
        return extra_page_count(self.layout, self.boundary_local,
                                self.row_words)

    @property
    def num_extra_pages(self) -> int:
        return self.num_shards * self.extra_pages_local

    @property
    def num_pages(self) -> int:
        return self.num_rows + self.num_extra_pages

    @property
    def page_words(self) -> int:
        return 8 * self.row_words

    @property
    def page_bytes(self) -> int:
        return 4 * self.page_words

    @property
    def raw_bytes(self) -> int:
        return self.storage.size * 4

    @property
    def effective_bytes(self) -> int:
        return self.num_pages * self.page_bytes

    def capacity_gain(self) -> float:
        return self.num_extra_pages / self.num_rows

    # -- PoolLike surface ---------------------------------------------------
    def read_any(self, pages) -> jax.Array:
        return read_any(self, pages)

    def read_any_status(self, pages) -> tuple[jax.Array, jax.Array]:
        return read_any_status(self, pages)

    def write_any(self, pages, data: jax.Array) -> "ShardedPool":
        return write_any(self, pages, data)

    def read_pages(self, pages) -> jax.Array:
        arr = pool_lib._as_page_array(self, pages)
        _note_dispatch("read", arr.shape[0])
        _memprof_routed(self, "gather", arr)
        with obs_tracing.span("shard.router.dispatch", op="read",
                              pages=arr.shape[0], shards=self.num_shards):
            return _read_any_jitted(self, arr)

    def read_pages_status(self, pages) -> tuple[jax.Array, jax.Array]:
        arr = pool_lib._as_page_array(self, pages)
        _note_dispatch("read_status", arr.shape[0])
        _memprof_routed(self, "gather", arr)
        with obs_tracing.span("shard.router.dispatch", op="read_status",
                              pages=arr.shape[0], shards=self.num_shards):
            return _read_any_status_jitted(self, arr)

    def write_pages(self, pages, data: jax.Array) -> "ShardedPool":
        arr = pool_lib._as_page_array(self, pages)
        _note_dispatch("write", arr.shape[0])
        _memprof_routed(self, "scatter", arr)
        with obs_tracing.span("shard.router.dispatch", op="write",
                              pages=arr.shape[0], shards=self.num_shards):
            return _write_any_jitted(self, arr, data)

    def evict_prediction(self, new_boundary: int) -> list[int]:
        return evicted_extra_pages(self, new_boundary)

    def move_boundary(self, new_boundary: int) -> tuple["ShardedPool", dict]:
        return repartition(self, new_boundary)

    def scrub(self, use_kernel: bool = False):
        return scrub(self, use_kernel=use_kernel)

    def memprof_record(self, op: str, pages, stream: str = "main") -> None:
        """Feed one dispatch to CREAM-Lens, routed per shard (PoolLike)."""
        _memprof_routed(self, op, pages, stream)


def make_sharded_pool(num_rows: int, layout: Layout = Layout.INTERWRAP,
                      boundary: int | None = None, *, num_shards: int,
                      row_words: int = 64,
                      mesh: jax.sharding.Mesh | None = None,
                      use_kernel: bool | None = None) -> ShardedPool:
    """Create a zeroed sharded pool of ``num_rows`` *global* rows.

    ``boundary`` is the global CREAM-region size (default: whole pool in
    CREAM mode); both must shard evenly (multiples of
    ``num_shards * GROUP_ROWS``). ``mesh`` defaults to a fresh 1-D
    ``banks`` mesh over the first ``num_shards`` devices.
    """
    boundary = num_rows if boundary is None else boundary
    if layout == Layout.BASELINE_ECC:
        boundary = 0
    router.check_geometry(num_rows, boundary, num_shards)
    if mesh is None:
        from repro.launch.mesh import make_banks_mesh
        mesh = make_banks_mesh(num_shards)
    if mesh.devices.size != num_shards or "banks" not in mesh.axis_names:
        raise ValueError(
            f"mesh must be a 1-D 'banks' mesh of {num_shards} devices")
    storage = jax.device_put(
        jnp.zeros((num_shards, num_rows // num_shards, LANES, row_words),
                  jnp.uint32),
        NamedSharding(mesh, P("banks")))
    return ShardedPool(storage, boundary // num_shards, layout, row_words,
                       mesh, use_kernel)


def _local_state(state: ShardedPool, block: jax.Array) -> PoolState:
    """Per-shard view: ``block`` is the shard's ``(1, R_local, 9, W)`` slice."""
    return PoolState(block[0], state.boundary_local, state.layout,
                     state.row_words)


# ---------------------------------------------------------------------------
# General dispatch: arbitrary global page-id vectors
# ---------------------------------------------------------------------------


def read_any_status(state: ShardedPool, pages
                    ) -> tuple[jax.Array, jax.Array]:
    """Batch read + per-page status for arbitrary global page ids.

    Every shard runs the mixed-pool engine over the routed local ids (same
    trace on every device — pages it does not own read harmless garbage),
    and the owner's rows are selected from the stacked per-shard output.
    Traceable; returns ``(data (n, page_words) uint32, status (n,) int32)``.
    """
    pages = jnp.asarray(pages, jnp.int32).reshape(-1)
    n = pages.shape[0]
    if n == 0:
        return (jnp.zeros((0, state.page_words), jnp.uint32),
                jnp.zeros((0,), jnp.int32))
    shard, local = router.route(pages, state.num_rows, state.num_shards)

    def body(block, loc):
        data, status = pool_lib.read_pages_any_status(
            _local_state(state, block), loc)
        return data[None], status[None]

    data_s, st_s = shard_map(
        body, mesh=state.mesh, in_specs=(P("banks"), P(None)),
        out_specs=(P("banks"), P("banks")))(state.storage, local)
    pick = jnp.arange(n)
    return data_s[shard, pick, :], st_s[shard, pick]


def read_any(state: ShardedPool, pages) -> jax.Array:
    """Decode-corrected batch read (owner-selected per-shard fused read).

    The per-shard read dispatches :mod:`repro.kernels.mixed` — the fused
    Pallas mixed-pool kernel on TPU, its vectorised oracle elsewhere —
    honouring ``state.use_kernel``.
    """
    from repro.kernels.mixed import ops as mixed_ops
    pages = jnp.asarray(pages, jnp.int32).reshape(-1)
    n = pages.shape[0]
    if n == 0:
        return jnp.zeros((0, state.page_words), jnp.uint32)
    shard, local = router.route(pages, state.num_rows, state.num_shards)

    def body(block, loc):
        st = _local_state(state, block)
        data = mixed_ops.read_correct(st.storage, loc, st.layout, st.num_rows,
                                      st.boundary, use_kernel=state.use_kernel)
        return data[None]

    data_s = shard_map(
        body, mesh=state.mesh, in_specs=(P("banks"), P(None)),
        out_specs=P("banks"))(state.storage, local)
    return data_s[shard, jnp.arange(n), :]


def write_any(state: ShardedPool, pages, data: jax.Array) -> ShardedPool:
    """Code-maintaining batch write for arbitrary global page ids.

    Each shard traces the same masked engine write over the full batch; the
    ``valid`` mask routes foreign pages' scatters out of range (dropped), so
    no collectives are needed — each shard's storage slice is written purely
    locally from the replicated data.
    """
    pages = jnp.asarray(pages, jnp.int32).reshape(-1)
    n = pages.shape[0]
    if n == 0:
        return state
    data = data.astype(jnp.uint32).reshape(n, -1)
    if data.shape[1] != state.page_words:
        raise ValueError(f"page data must be {state.page_words} words")
    shard, local = router.route(pages, state.num_rows, state.num_shards)
    owned = router.owned_mask(shard, state.num_shards)

    def body(block, loc, dat, own):
        st = pool_lib.write_pages_any(_local_state(state, block), loc, dat,
                                      valid=own[0])
        return st.storage[None]

    storage = shard_map(
        body, mesh=state.mesh,
        in_specs=(P("banks"), P(None), P(None), P("banks")),
        out_specs=P("banks"))(state.storage, local, data, owned)
    return dataclasses.replace(state, storage=storage)


_read_any_jitted = jax.jit(read_any)
_read_any_status_jitted = jax.jit(read_any_status)
_write_any_jitted = jax.jit(write_any, donate_argnums=(0,))


# ---------------------------------------------------------------------------
# Bank-parallel streams: the measured Figs. 9–11 hot path
# ---------------------------------------------------------------------------


def _read_streams_impl(state: ShardedPool, pages: jax.Array) -> jax.Array:
    S = state.num_shards
    _, local = router.route(pages.reshape(-1), state.num_rows, S)
    local = local.reshape(S, -1)

    def body(block, loc):
        data, _ = pool_lib.read_pages_any_status(
            _local_state(state, block), loc[0])
        return data[None]

    return shard_map(
        body, mesh=state.mesh, in_specs=(P("banks"), P("banks")),
        out_specs=P("banks"))(state.storage, local)


def _write_streams_impl(state: ShardedPool, pages: jax.Array,
                        data: jax.Array) -> ShardedPool:
    S = state.num_shards
    _, local = router.route(pages.reshape(-1), state.num_rows, S)
    local = local.reshape(S, -1)

    def body(block, loc, dat):
        st = pool_lib.write_pages_any(_local_state(state, block), loc[0],
                                      dat[0].astype(jnp.uint32))
        return st.storage[None]

    storage = shard_map(
        body, mesh=state.mesh, in_specs=(P("banks"), P("banks"), P("banks")),
        out_specs=P("banks"))(state.storage, local, data)
    return dataclasses.replace(state, storage=storage)


_read_streams_jitted = jax.jit(_read_streams_impl)
_write_streams_jitted = jax.jit(_write_streams_impl)


def read_streams(state: ShardedPool, pages: jax.Array) -> jax.Array:
    """Serve ``S`` independent request streams, one per bank, concurrently.

    ``pages`` is ``(S, n)`` *global* ids with stream ``s`` touching only
    shard ``s``'s pages (``page % S == s`` for regular pages) — the caller
    owns that alignment, mirroring how a bank-aware allocator hands each
    client its own rank subset. Each shard gathers only its own ``n`` pages
    (no masking, no replication, no collectives): per-bank work is ``n``
    pages regardless of ``S``, which is exactly the paper's bank-level
    parallelism claim. Returns ``(S, n, page_words)``, still sharded over
    ``banks``.

    Host wrapper around the jitted dispatch so CREAM-Lens can capture the
    aligned streams (stream ``bank<s>`` per shard); composes under an
    enclosing jit unchanged (the hook skips traced operands).
    """
    _memprof_routed(state, "gather", pages, stream="streams")
    return _read_streams_jitted(state, pages)


def write_streams(state: ShardedPool, pages: jax.Array,
                  data: jax.Array) -> ShardedPool:
    """Per-bank scatter of ``S`` aligned streams (see :func:`read_streams`).

    ``pages`` is ``(S, n)`` shard-aligned global ids, ``data`` is
    ``(S, n, page_words)``.
    """
    _memprof_routed(state, "scatter", pages, stream="streams")
    return _write_streams_jitted(state, pages, data)


# ---------------------------------------------------------------------------
# Cross-shard migration: explicit ppermute ring exchange
# ---------------------------------------------------------------------------


def _migrate_impl(state: ShardedPool, src: jax.Array, dst: jax.Array
                  ) -> ShardedPool:
    S = state.num_shards
    src_sh, src_lo = router.route(src, state.num_rows, S)
    dst_sh, dst_lo = router.route(dst, state.num_rows, S)
    ring = [(i, (i + 1) % S) for i in range(S)]

    def body(block, s_sh, s_lo, d_sh, d_lo):
        me = jax.lax.axis_index("banks")
        st = _local_state(state, block)
        data, _ = pool_lib.read_pages_any_status(st, s_lo)
        buf = jnp.where((s_sh == me)[:, None], data, 0)
        for step in range(S):
            if step:
                buf = jax.lax.ppermute(buf, "banks", ring)
            deliver = (s_sh == (me - step) % S) & (d_sh == me)
            st = pool_lib.write_pages_any(st, d_lo, buf, valid=deliver)
        return st.storage[None]

    storage = shard_map(
        body, mesh=state.mesh,
        in_specs=(P("banks"), P(None), P(None), P(None), P(None)),
        out_specs=P("banks"))(state.storage, src_sh, src_lo, dst_sh, dst_lo)
    return dataclasses.replace(state, storage=storage)


_migrate_jitted = jax.jit(_migrate_impl, donate_argnums=(0,))
_migrate_jitted_nodonate = jax.jit(_migrate_impl)


def migrate_pages(state: ShardedPool, src_pages, dst_pages,
                  donate: bool = True) -> ShardedPool:
    """Live in-pool migration ``src -> dst`` across shard boundaries.

    One fused dispatch: every shard decode-reads the source pages it owns,
    the page batch circulates the ``banks`` ring via ``S`` explicit
    ``ppermute`` steps (the rank-subset interconnect made visible), and at
    each step every shard lands the pages addressed to it with a masked
    code-maintaining write. Same-shard moves complete at step 0 without
    touching the ring. ``donate=False`` keeps the input pool's storage
    valid (benchmarks; callers that roll back).
    """
    src = pool_lib._as_page_array(state, src_pages)
    dst = pool_lib._as_page_array(state, dst_pages)
    fn = _migrate_jitted if donate else _migrate_jitted_nodonate
    if obs_metrics.enabled():
        obs_metrics.counter(
            obs_metrics.NAME_SHARD_RING_PAGES,
            "pages exchanged over the ppermute migration ring"
        ).inc(int(src.shape[0]))
    with obs_tracing.span("shard.migrate.ring", pages=int(src.shape[0]),
                          shards=state.num_shards):
        return fn(state, src, dst)


# ---------------------------------------------------------------------------
# Repartitioning: all shards move their boundary register in lockstep
# ---------------------------------------------------------------------------


def evicted_extra_pages(state: ShardedPool, new_boundary: int) -> list[int]:
    """Global extra-page ids a move to ``new_boundary`` would evict.

    Round-robin extra striping makes the surviving set a contiguous global
    prefix, so — exactly as for the local pool — the evicted ids are the
    trailing range.
    """
    if new_boundary >= state.boundary:
        return []
    x_new = extra_page_count(state.layout,
                             new_boundary // state.num_shards,
                             state.row_words)
    return list(range(state.num_rows + state.num_shards * x_new,
                      state.num_rows + state.num_extra_pages))


def repartition(state: ShardedPool, new_boundary: int
                ) -> tuple[ShardedPool, dict]:
    """Move every shard's CREAM/SECDED boundary in lockstep.

    Semantics mirror :func:`repro.core.pool.repartition` (page contents of
    surviving ids preserved, codes re-established, evicted extras reported);
    the data plane is one ``shard_map`` over the local repartition, so each
    bank re-encodes its own span independently — no cross-shard traffic.
    """
    router.check_geometry(state.num_rows, new_boundary, state.num_shards)
    old = state.boundary
    info = {"old_boundary": old, "new_boundary": new_boundary,
            "evicted_extra_pages": [], "pages_reencoded": 0}
    if new_boundary == old:
        return state, info
    info["evicted_extra_pages"] = evicted_extra_pages(state, new_boundary)
    info["pages_reencoded"] = abs(new_boundary - old)
    nb_local = new_boundary // state.num_shards

    def body(block):
        new_st, _ = pool_lib.repartition(_local_state(state, block), nb_local)
        return new_st.storage[None]

    with obs_tracing.span("shard.repartition", old_boundary=old,
                          new_boundary=new_boundary,
                          shards=state.num_shards):
        storage = jax.jit(shard_map(
            body, mesh=state.mesh, in_specs=P("banks"),
            out_specs=P("banks")))(state.storage)
    return dataclasses.replace(state, storage=storage,
                               boundary_local=nb_local), info


# ---------------------------------------------------------------------------
# Scrubbing (background sweep; per-shard, host-driven)
# ---------------------------------------------------------------------------


def scrub(state: ShardedPool, use_kernel: bool = False):
    """Sweep every shard, repairing in place; returns (state', ScrubStats).

    Background path (not latency-critical): shards are swept sequentially
    host-side and the per-shard censuses merged, with corrupt row ids mapped
    back to global rows (``global = local * S + shard``).
    """
    from repro.core.scrubber import ScrubStats
    from repro.core.scrubber import scrub as _scrub
    S = state.num_shards
    blocks, merged, corrupt = [], {}, []
    for s in range(S):
        st = PoolState(state.storage[s], state.boundary_local, state.layout,
                       state.row_words)
        new_st, stats = _scrub(st, use_kernel=use_kernel)
        blocks.append(new_st.storage)
        for f in ("beats_checked", "corrected_data", "corrected_code",
                  "detected_uncorrectable", "parity_lines_checked",
                  "parity_corrupt_lines"):
            merged[f] = merged.get(f, 0) + getattr(stats, f)
        corrupt.extend(r * S + s for r in stats.corrupt_rows)
    storage = jax.device_put(jnp.stack(blocks),
                             NamedSharding(state.mesh, P("banks")))
    return (dataclasses.replace(state, storage=storage),
            ScrubStats(corrupt_rows=tuple(sorted(corrupt)), **merged))

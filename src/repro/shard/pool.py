"""CREAM-Shard — the CREAM pool partitioned across a ``banks`` mesh axis.

The paper's second headline claim is that CREAM *increases bank-level
parallelism*: rank subsetting (§4.1.2) splits the DIMM into independently
addressable subsets, and Figs. 9–11 measure the resulting concurrency win.
This module is that mechanism on the real data plane: the pool's rows are
striped round-robin over ``S`` devices of a 1-D ``banks`` mesh
(:func:`repro.launch.mesh.make_banks_mesh`), every shard holds an
identically-shaped mini CREAM pool ``(R_local, 9, W)`` with the same
boundary register, and the whole mixed-pool access engine of
:mod:`repro.core.pool` — one ``page_coords`` translation, one
gather/scatter, masked batched codecs — runs unchanged *inside each shard*
under ``shard_map``. On TPU the per-shard read is the fused Pallas mixed
kernel; on CPU it is the vectorised engine (the kernel's oracle).

Every access is ONE device dispatch, in one of two shapes by id locality:

  * **Fused traced dispatch** — :func:`read_any` / :func:`write_any`,
    arbitrary (possibly traced) global page-id vectors. The router's
    global-id -> (shard, local) translation is *fused into the access
    itself*: reads dispatch the router-aware mixed kernel
    (:func:`repro.kernels.mixed.ops.read_correct_routed`, whose
    scalar-prefetch index map composes routing with the layout
    translation), each shard zeroes the rows it does not own, and a single
    ``psum`` over ``banks`` assembles the replicated batch. Writes compute
    ownership in-body from ``axis_index`` and let the engine's ``valid``
    mask drop foreign pages — no routed operands, no stacked outputs, no
    owner-select chain.
  * **Planned bank-aligned dispatch** — the concrete-id hot path behind
    :meth:`ShardedPool.read` / :meth:`ShardedPool.write`. A host-side
    numpy pass (:func:`repro.shard.router.plan_streams`) regroups the
    batch into ``S`` padded per-bank streams plus one inverse permutation;
    the single jitted program then does a per-bank gather of ~``n/S``
    pages and the device-side permute back to batch order. Per-bank work
    *shrinks* with ``S`` — the measured Figs. 9–11 concurrency story
    (``benchmarks/bench_shard.py``). :func:`read_streams` /
    :func:`write_streams` expose the aligned ``(S, n)`` form directly for
    callers that already hold per-bank streams.

:func:`migrate_pages` relocates pages across shard boundaries as an
explicit ``ppermute`` ring exchange: each shard reads its owned source
pages, the batch circulates around the ring, and every shard lands the
pages addressed to it with a masked code-maintaining write.

:func:`repartition` moves every shard's boundary in lockstep (one
``shard_map`` over the local repartition, which re-encodes in place), so
the global page-id convention — and therefore every owner's bookkeeping —
is preserved exactly as for the local pool.

:class:`ShardedPool` implements :class:`repro.core.pool.PoolLike`; the VM
(:mod:`repro.vm`), object cache (:mod:`repro.objcache`) and serving tier
(:mod:`repro.serve`) run on it unchanged.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

try:                                    # jax >= 0.6 moved it to the top level
    from jax import shard_map           # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map

from repro.core import pool as pool_lib
from repro.core.layouts import (GROUP_ROWS, LANES, Layout, extra_page_count)
from repro.core.pool import PoolState
from repro.obs import memprof as obs_memprof
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.shard import router


def _note_dispatch(op: str, pages: int) -> None:
    """Count one routed host-side dispatch through the shard data plane."""
    if not obs_metrics.enabled():
        return
    obs_metrics.counter(
        obs_metrics.NAME_SHARD_DISPATCH,
        "routed dispatches through the sharded data plane",
        labels=("op",)).labels(op=op).inc()


def _memprof_routed(state: "ShardedPool", op: str, pages,
                    stream: str = "main") -> None:
    """Feed one routed dispatch to CREAM-Lens, split per shard.

    Mirrors :func:`repro.shard.router.route` in numpy and records each
    shard's local id set against the shard's *local* geometry (its own
    module: ``rows_local`` rows, ``boundary_local``), stream ``bank<s>``
    — so replay models ``S`` independent BankArrays, exactly the
    rank-subset hardware the sharding claims to be.
    """
    if not obs_memprof.enabled() or isinstance(pages, jax.core.Tracer) \
            or isinstance(state.storage, jax.core.Tracer):
        return
    p = np.asarray(pages, dtype=np.int64).reshape(-1)
    S = state.num_shards
    is_extra = p >= state.num_rows
    e = p - state.num_rows
    shard = np.where(is_extra, e % S, p % S)
    local = np.where(is_extra, state.rows_local + e // S, p // S)
    prefix = "" if stream == "main" else f"{stream}/"
    for s in range(S):
        loc = local[shard == s]
        if loc.size == 0:
            continue
        obs_memprof.record(
            op, loc, layout=state.layout,
            num_rows=state.rows_local,
            boundary=state.boundary_local,
            row_words=state.row_words, stream=f"{prefix}bank{s}")


@jax.tree_util.register_dataclass
@dataclass
class ShardedPool:
    """Functional sharded pool state. ``storage`` is the only traced leaf.

    ``storage`` is ``(S, R_local, 9, W)`` uint32, laid out over the mesh's
    ``banks`` axis (leading dim). All other fields are static pytree
    metadata, so each (geometry, mesh) compiles once — exactly like the
    local pool's (boundary, layout, row_words) treatment.
    """
    storage: jax.Array                  # (S, R_local, 9, W) uint32
    boundary_local: int = dataclasses.field(metadata=dict(static=True))
    layout: Layout = dataclasses.field(metadata=dict(static=True))
    row_words: int = dataclasses.field(metadata=dict(static=True))
    mesh: jax.sharding.Mesh = dataclasses.field(metadata=dict(static=True))
    use_kernel: bool | None = dataclasses.field(
        default=None, metadata=dict(static=True))
    #: Per-shard DAEC-tier depth. Global DAEC rows stripe round-robin like
    #: everything else, so the tier is the top ``daec_rows_local`` rows of
    #: EVERY shard and global ``daec_rows = S * daec_rows_local`` — the
    #: tier boundary needs no per-shard adjustment.
    daec_rows_local: int = dataclasses.field(
        default=0, metadata=dict(static=True))

    # -- geometry (global page-id convention, same as PoolState) ------------
    @property
    def num_shards(self) -> int:
        return self.storage.shape[0]

    @property
    def rows_local(self) -> int:
        return self.storage.shape[1]

    @property
    def num_rows(self) -> int:
        return self.num_shards * self.rows_local

    @property
    def boundary(self) -> int:
        return self.num_shards * self.boundary_local

    @property
    def boundary_step(self) -> int:
        """Boundary moves in lockstep across shards: S * GROUP_ROWS rows."""
        return self.num_shards * GROUP_ROWS

    @property
    def daec_rows(self) -> int:
        return self.num_shards * self.daec_rows_local

    @property
    def daec_start(self) -> int:
        """First global row of the SEC-DAEC tier (= num_rows - daec_rows)."""
        return self.num_rows - self.daec_rows

    @property
    def extra_pages_local(self) -> int:
        return extra_page_count(self.layout, self.boundary_local,
                                self.row_words)

    @property
    def num_extra_pages(self) -> int:
        return self.num_shards * self.extra_pages_local

    @property
    def num_pages(self) -> int:
        return self.num_rows + self.num_extra_pages

    @property
    def page_words(self) -> int:
        return 8 * self.row_words

    @property
    def page_bytes(self) -> int:
        return 4 * self.page_words

    @property
    def raw_bytes(self) -> int:
        return self.storage.size * 4

    @property
    def effective_bytes(self) -> int:
        return self.num_pages * self.page_bytes

    def capacity_gain(self) -> float:
        return self.num_extra_pages / self.num_rows

    # -- PoolLike surface (unified access API) ------------------------------
    def _traced(self, *operands) -> bool:
        return any(isinstance(x, jax.core.Tracer)
                   for x in (self.storage, *operands))

    def read(self, pages, *, status=False):
        """Batch read for arbitrary global page ids — ONE device dispatch.

        Traced ids compose into the enclosing trace via the fused
        router-in-kernel path (:func:`read_any`). Concrete ids take the
        planned bank-aligned path: host-side stream planning, then one
        jitted program whose per-bank gather touches only ~``n/S`` pages.
        """
        if self._traced(pages):
            return read_any_status(self, pages) if status \
                else read_any(self, pages)
        arr = pool_lib._as_page_array(self, pages)
        op = "read_status" if status else "read"
        _note_dispatch(op, arr.shape[0])
        _memprof_routed(self, "gather", arr)
        spages, _, inv = router.plan_streams(arr, self.num_rows,
                                             self.num_shards)
        fn = _read_planned_status_jitted if status else _read_planned_jitted
        with obs_tracing.span("shard.fused.dispatch", op=op,
                              pages=arr.shape[0], shards=self.num_shards):
            return fn(self, jnp.asarray(spages), jnp.asarray(inv, jnp.int32))

    def write(self, pages, data: jax.Array, *, valid=None) -> "ShardedPool":
        """Code-maintaining batch write — ONE device dispatch.

        ``valid`` optionally drops masked entries. Traced operands use the
        fused in-body-ownership path (:func:`write_any`); concrete ids use
        the planned bank-aligned path (pads and masked entries share the
        engine's ``valid`` drop). The concrete path donates this pool's
        storage — drop the old state immediately.
        """
        if self._traced(pages, data, valid):
            return write_any(self, pages, data, valid=valid)
        arr = pool_lib._as_page_array(self, pages)
        n = arr.shape[0]
        data = jnp.asarray(data).astype(jnp.uint32).reshape(n, -1)
        if data.shape[1] != self.page_words:
            raise ValueError(f"page data must be {self.page_words} words")
        _note_dispatch("write", n)
        _memprof_routed(self, "scatter", arr)
        spages, svalid, inv = router.plan_streams(arr, self.num_rows,
                                                  self.num_shards)
        if valid is not None:
            v = np.asarray(valid, bool).reshape(-1)
            flat = svalid.reshape(-1)
            flat[inv] &= v
        with obs_tracing.span("shard.fused.dispatch", op="write",
                              pages=n, shards=self.num_shards):
            return _write_planned_jitted(self, jnp.asarray(spages),
                                         jnp.asarray(svalid),
                                         jnp.asarray(inv, jnp.int32), data)

    def migrate(self, src_pages, dst_pages, *,
                donate: bool = True) -> "ShardedPool":
        """Cross-shard relocation over the ``ppermute`` ring
        (see :func:`migrate_pages`)."""
        return migrate_pages(self, src_pages, dst_pages, donate=donate)

    def streams(self, pages, data=None, *, valid=None):
        """Bank-aligned ``(S, n)`` stream access (see :func:`read_streams`).

        With ``data=None`` reads, returning ``(S, n, page_words)`` still
        sharded over ``banks``; with ``data`` writes (``valid`` optionally
        masking entries) and returns the new pool.
        """
        if data is None:
            return read_streams(self, pages)
        return write_streams(self, pages, data, valid=valid)

    # -- deprecated access surface (thin shims over the unified API) --------

    def read_any(self, pages) -> jax.Array:
        pool_lib._warn_deprecated("read_any", "read(pages)")
        return read_any(self, pages)

    def read_any_status(self, pages) -> tuple[jax.Array, jax.Array]:
        pool_lib._warn_deprecated("read_any_status", "read(pages, status=True)")
        return read_any_status(self, pages)

    def write_any(self, pages, data: jax.Array) -> "ShardedPool":
        pool_lib._warn_deprecated("write_any", "write(pages, data)")
        return write_any(self, pages, data)

    def read_pages(self, pages) -> jax.Array:
        pool_lib._warn_deprecated("read_pages", "read(pages)")
        return self.read(pages)

    def read_pages_status(self, pages) -> tuple[jax.Array, jax.Array]:
        pool_lib._warn_deprecated("read_pages_status", "read(pages, status=True)")
        return self.read(pages, status=True)

    def write_pages(self, pages, data: jax.Array) -> "ShardedPool":
        pool_lib._warn_deprecated("write_pages", "write(pages, data)")
        return self.write(pages, data)

    def evict_prediction(self, new_boundary: int) -> list[int]:
        return evicted_extra_pages(self, new_boundary)

    def move_boundary(self, new_boundary: int) -> tuple["ShardedPool", dict]:
        return repartition(self, new_boundary)

    def set_daec_rows(self, daec_rows: int) -> "ShardedPool":
        return set_daec_rows(self, daec_rows)

    def read_writeback(self, pages):
        """Write-back read (see :meth:`repro.core.pool.PoolState.read_writeback`):
        corrected beats are persisted to the owning shard in the same pass.
        Returns ``(data, status, new_pool)``."""
        arr = pool_lib._as_page_array(self, pages)
        _note_dispatch("read_writeback", arr.shape[0])
        _memprof_routed(self, "gather", arr)
        return _read_writeback_jitted(self, arr)

    def scrub(self, use_kernel: bool = False):
        return scrub(self, use_kernel=use_kernel)

    def memprof_record(self, op: str, pages, stream: str = "main") -> None:
        """Feed one dispatch to CREAM-Lens, routed per shard (PoolLike)."""
        _memprof_routed(self, op, pages, stream)


def make_sharded_pool(num_rows: int, layout: Layout = Layout.INTERWRAP,
                      boundary: int | None = None, *, num_shards: int,
                      row_words: int = 64,
                      mesh: jax.sharding.Mesh | None = None,
                      use_kernel: bool | None = None,
                      daec_rows: int = 0) -> ShardedPool:
    """Create a zeroed sharded pool of ``num_rows`` *global* rows.

    ``boundary`` is the global CREAM-region size (default: whole pool in
    CREAM mode); both must shard evenly (multiples of
    ``num_shards * GROUP_ROWS``). ``daec_rows`` carves that many *global*
    top rows into the SEC-DAEC tier (must be a multiple of ``num_shards``
    and fit the protected region). ``mesh`` defaults to a fresh 1-D
    ``banks`` mesh over the first ``num_shards`` devices.
    """
    boundary = num_rows if boundary is None else boundary
    if layout == Layout.BASELINE_ECC:
        boundary = 0
    router.check_geometry(num_rows, boundary, num_shards)
    if daec_rows % num_shards:
        raise ValueError(
            f"daec_rows ({daec_rows}) must shard evenly over {num_shards}")
    if not 0 <= daec_rows <= num_rows - boundary:
        raise ValueError(
            f"daec_rows ({daec_rows}) must fit the protected region "
            f"[{boundary}, {num_rows})")
    if mesh is None:
        from repro.launch.mesh import make_banks_mesh
        mesh = make_banks_mesh(num_shards)
    if mesh.devices.size != num_shards or "banks" not in mesh.axis_names:
        raise ValueError(
            f"mesh must be a 1-D 'banks' mesh of {num_shards} devices")
    storage = jax.device_put(
        jnp.zeros((num_shards, num_rows // num_shards, LANES, row_words),
                  jnp.uint32),
        NamedSharding(mesh, P("banks")))
    return ShardedPool(storage, boundary // num_shards, layout, row_words,
                       mesh, use_kernel, daec_rows // num_shards)


def _local_state(state: ShardedPool, block: jax.Array) -> PoolState:
    """Per-shard view: ``block`` is the shard's ``(1, R_local, 9, W)`` slice."""
    return PoolState(block[0], state.boundary_local, state.layout,
                     state.row_words, state.daec_rows_local)


# ---------------------------------------------------------------------------
# General dispatch: arbitrary global page-id vectors
# ---------------------------------------------------------------------------


def read_any_status(state: ShardedPool, pages
                    ) -> tuple[jax.Array, jax.Array]:
    """Batch read + per-page status for arbitrary global page ids, fused.

    Every shard routes in-body (``axis_index`` ownership), reads its owned
    local ids through the mixed-pool engine, zeroes foreign rows, and one
    ``psum`` pair over ``banks`` assembles the replicated result — no
    stacked per-shard output, no owner-select chain. Traceable; returns
    ``(data (n, page_words) uint32, status (n,) int32)``.
    """
    pages = jnp.asarray(pages, jnp.int32).reshape(-1)
    n = pages.shape[0]
    if n == 0:
        return (jnp.zeros((0, state.page_words), jnp.uint32),
                jnp.zeros((0,), jnp.int32))

    def body(block, pg):
        me = jax.lax.axis_index("banks")
        shard, local = router.route(pg, state.num_rows, state.num_shards)
        own = shard == me
        data, status = pool_lib.read_pages_any_status(
            _local_state(state, block), jnp.where(own, local, 0))
        return (jax.lax.psum(jnp.where(own[:, None], data, 0), "banks"),
                jax.lax.psum(jnp.where(own, status, 0), "banks"))

    return shard_map(
        body, mesh=state.mesh, in_specs=(P("banks"), P(None)),
        out_specs=(P(None), P(None)))(state.storage, pages)


def read_any(state: ShardedPool, pages) -> jax.Array:
    """Decode-corrected batch read: router fused into the kernel, one pass.

    Each shard dispatches the router-aware mixed kernel
    (:func:`repro.kernels.mixed.ops.read_correct_routed` — the Pallas
    scalar-prefetch index map composes the global-id -> (shard, local)
    translation with the layout translation; the jnp oracle elsewhere),
    zeroing rows it does not own, and a single ``psum`` over ``banks``
    assembles the replicated batch. Honours ``state.use_kernel``.
    """
    from repro.kernels.mixed import ops as mixed_ops
    pages = jnp.asarray(pages, jnp.int32).reshape(-1)
    n = pages.shape[0]
    if n == 0:
        return jnp.zeros((0, state.page_words), jnp.uint32)

    if state.daec_rows_local > 0:
        # The fused mixed kernel corrects with SECDED only — a DAEC tier
        # would be mis-decoded. Route through the dual-codec engine instead.
        return read_any_status(state, pages)[0]

    def body(block, pg):
        me = jax.lax.axis_index("banks")
        data = mixed_ops.read_correct_routed(
            block[0], pg, state.layout, state.num_rows, state.boundary,
            state.num_shards, me, use_kernel=state.use_kernel)
        return jax.lax.psum(data, "banks")

    return shard_map(
        body, mesh=state.mesh, in_specs=(P("banks"), P(None)),
        out_specs=P(None))(state.storage, pages)


def write_any(state: ShardedPool, pages, data: jax.Array,
              valid=None) -> ShardedPool:
    """Code-maintaining batch write for arbitrary global page ids, fused.

    Each shard routes in-body and computes ownership from ``axis_index``;
    the engine's ``valid`` mask routes foreign (and caller-masked) pages'
    scatters out of range (dropped), so no collectives are needed — each
    shard's storage slice is written purely locally from the replicated
    data.
    """
    pages = jnp.asarray(pages, jnp.int32).reshape(-1)
    n = pages.shape[0]
    if n == 0:
        return state
    data = data.astype(jnp.uint32).reshape(n, -1)
    if data.shape[1] != state.page_words:
        raise ValueError(f"page data must be {state.page_words} words")

    def body(block, pg, dat, *vld):
        me = jax.lax.axis_index("banks")
        shard, local = router.route(pg, state.num_rows, state.num_shards)
        own = shard == me
        if vld:
            own = own & vld[0]
        st = pool_lib.write_pages_any(_local_state(state, block), local, dat,
                                      valid=own)
        return st.storage[None]

    operands = (state.storage, pages, data)
    in_specs = [P("banks"), P(None), P(None)]
    if valid is not None:
        operands += (jnp.asarray(valid, bool).reshape(-1),)
        in_specs.append(P(None))
    storage = shard_map(
        body, mesh=state.mesh, in_specs=tuple(in_specs),
        out_specs=P("banks"))(*operands)
    return dataclasses.replace(state, storage=storage)


def read_any_writeback(state: ShardedPool, pages
                       ) -> tuple[jax.Array, jax.Array, ShardedPool]:
    """Write-back batch read for arbitrary global page ids, fused.

    Like :func:`read_any_status`, but each shard persists corrected beats
    of the pages it owns back into its own storage slice in the same pass
    (:func:`repro.core.pool.read_pages_any_writeback`); foreign pages are
    masked out of range so only the owner writes. Returns
    ``(data, status, new_pool)``.
    """
    pages = jnp.asarray(pages, jnp.int32).reshape(-1)
    n = pages.shape[0]
    if n == 0:
        return (jnp.zeros((0, state.page_words), jnp.uint32),
                jnp.zeros((0,), jnp.int32), state)

    def body(block, pg):
        me = jax.lax.axis_index("banks")
        shard, local = router.route(pg, state.num_rows, state.num_shards)
        own = shard == me
        st = _local_state(state, block)
        data, status, st = pool_lib.read_pages_any_writeback(
            st, jnp.where(own, local, st.num_pages))
        return (jax.lax.psum(jnp.where(own[:, None], data, 0), "banks"),
                jax.lax.psum(jnp.where(own, status, 0), "banks"),
                st.storage[None])

    data, status, storage = shard_map(
        body, mesh=state.mesh, in_specs=(P("banks"), P(None)),
        out_specs=(P(None), P(None), P("banks")))(state.storage, pages)
    return data, status, dataclasses.replace(state, storage=storage)


_read_any_jitted = jax.jit(read_any)
_read_any_status_jitted = jax.jit(read_any_status)
_write_any_jitted = jax.jit(write_any, donate_argnums=(0,))
_read_writeback_jitted = jax.jit(read_any_writeback)


# ---------------------------------------------------------------------------
# Bank-parallel streams: the measured Figs. 9–11 hot path
# ---------------------------------------------------------------------------


def _read_streams_impl(state: ShardedPool, pages: jax.Array) -> jax.Array:
    # Local translation happens in-body on each shard's own (1, n) slice —
    # stream alignment guarantees ownership, so no shard id is needed.
    from repro.kernels.mixed import ops as mixed_ops

    if state.daec_rows_local > 0:
        # SECDED-only fused kernel would mis-decode the DAEC tier; fall
        # back to the dual-codec engine (same dispatch shape, jnp body).
        return _read_streams_status_impl(state, pages)[0]

    def body(block, pg):
        _, local = router.route(pg[0], state.num_rows, state.num_shards)
        data = mixed_ops.read_correct(
            block[0], local, state.layout, state.rows_local,
            state.boundary_local, use_kernel=state.use_kernel)
        return data[None]

    return shard_map(
        body, mesh=state.mesh, in_specs=(P("banks"), P("banks")),
        out_specs=P("banks"))(state.storage, pages)


def _read_streams_status_impl(state: ShardedPool, pages: jax.Array
                              ) -> tuple[jax.Array, jax.Array]:
    def body(block, pg):
        _, local = router.route(pg[0], state.num_rows, state.num_shards)
        data, status = pool_lib.read_pages_any_status(
            _local_state(state, block), local)
        return data[None], status[None]

    return shard_map(
        body, mesh=state.mesh, in_specs=(P("banks"), P("banks")),
        out_specs=(P("banks"), P("banks")))(state.storage, pages)


def _write_streams_impl(state: ShardedPool, pages: jax.Array,
                        data: jax.Array, valid=None) -> ShardedPool:
    def body(block, pg, dat, *vld):
        _, local = router.route(pg[0], state.num_rows, state.num_shards)
        st = pool_lib.write_pages_any(
            _local_state(state, block), local, dat[0].astype(jnp.uint32),
            valid=vld[0][0] if vld else None)
        return st.storage[None]

    operands = (state.storage, pages, data)
    in_specs = [P("banks"), P("banks"), P("banks")]
    if valid is not None:
        operands += (valid,)
        in_specs.append(P("banks"))
    storage = shard_map(
        body, mesh=state.mesh, in_specs=tuple(in_specs),
        out_specs=P("banks"))(*operands)
    return dataclasses.replace(state, storage=storage)


_read_streams_jitted = jax.jit(_read_streams_impl)
_write_streams_jitted = jax.jit(_write_streams_impl)


# The planned bank-aligned dispatch behind ShardedPool.read / .write:
# plan_streams (host numpy) regroups the batch into (S, m) per-bank streams
# + one inverse permutation; each program below is ONE jitted dispatch that
# gathers ~n/S pages per bank and permutes back to batch order on device.

def _read_planned_impl(state: ShardedPool, spages: jax.Array,
                       inv: jax.Array) -> jax.Array:
    data = _read_streams_impl(state, spages)
    return data.reshape(-1, state.page_words)[inv]


def _read_planned_status_impl(state: ShardedPool, spages: jax.Array,
                              inv: jax.Array
                              ) -> tuple[jax.Array, jax.Array]:
    data, status = _read_streams_status_impl(state, spages)
    return (data.reshape(-1, state.page_words)[inv],
            status.reshape(-1)[inv])


def _write_planned_impl(state: ShardedPool, spages: jax.Array,
                        svalid: jax.Array, inv: jax.Array,
                        data: jax.Array) -> ShardedPool:
    S, m = spages.shape
    sdata = jnp.zeros((S * m, state.page_words),
                      jnp.uint32).at[inv].set(data.astype(jnp.uint32))
    return _write_streams_impl(state, spages, sdata.reshape(S, m, -1),
                               valid=svalid)


_read_planned_jitted = jax.jit(_read_planned_impl)
_read_planned_status_jitted = jax.jit(_read_planned_status_impl)
_write_planned_jitted = jax.jit(_write_planned_impl, donate_argnums=(0,))


def read_streams(state: ShardedPool, pages: jax.Array) -> jax.Array:
    """Serve ``S`` independent request streams, one per bank, concurrently.

    ``pages`` is ``(S, n)`` *global* ids with stream ``s`` touching only
    shard ``s``'s pages (``page % S == s`` for regular pages) — the caller
    owns that alignment, mirroring how a bank-aware allocator hands each
    client its own rank subset. Each shard gathers only its own ``n`` pages
    (no masking, no replication, no collectives): per-bank work is ``n``
    pages regardless of ``S``, which is exactly the paper's bank-level
    parallelism claim. Returns ``(S, n, page_words)``, still sharded over
    ``banks``.

    Host wrapper around the jitted dispatch so CREAM-Lens can capture the
    aligned streams (stream ``bank<s>`` per shard); composes under an
    enclosing jit unchanged (the hook skips traced operands).
    """
    _memprof_routed(state, "gather", pages, stream="streams")
    return _read_streams_jitted(state, pages)


def write_streams(state: ShardedPool, pages: jax.Array,
                  data: jax.Array, valid=None) -> ShardedPool:
    """Per-bank scatter of ``S`` aligned streams (see :func:`read_streams`).

    ``pages`` is ``(S, n)`` shard-aligned global ids, ``data`` is
    ``(S, n, page_words)``; ``valid`` (optional ``(S, n)`` bool) drops
    masked entries via the engine's OOB-routing mask.
    """
    _memprof_routed(state, "scatter", pages, stream="streams")
    if valid is None:
        return _write_streams_jitted(state, pages, data)
    return _write_streams_jitted(state, pages, data,
                                 jnp.asarray(valid, bool))


# ---------------------------------------------------------------------------
# Cross-shard migration: explicit ppermute ring exchange
# ---------------------------------------------------------------------------


def _migrate_impl(state: ShardedPool, src: jax.Array, dst: jax.Array
                  ) -> ShardedPool:
    S = state.num_shards
    src_sh, src_lo = router.route(src, state.num_rows, S)
    dst_sh, dst_lo = router.route(dst, state.num_rows, S)
    ring = [(i, (i + 1) % S) for i in range(S)]

    def body(block, s_sh, s_lo, d_sh, d_lo):
        me = jax.lax.axis_index("banks")
        st = _local_state(state, block)
        data, _ = pool_lib.read_pages_any_status(st, s_lo)
        buf = jnp.where((s_sh == me)[:, None], data, 0)
        for step in range(S):
            if step:
                buf = jax.lax.ppermute(buf, "banks", ring)
            deliver = (s_sh == (me - step) % S) & (d_sh == me)
            st = pool_lib.write_pages_any(st, d_lo, buf, valid=deliver)
        return st.storage[None]

    storage = shard_map(
        body, mesh=state.mesh,
        in_specs=(P("banks"), P(None), P(None), P(None), P(None)),
        out_specs=P("banks"))(state.storage, src_sh, src_lo, dst_sh, dst_lo)
    return dataclasses.replace(state, storage=storage)


_migrate_jitted = jax.jit(_migrate_impl, donate_argnums=(0,))
_migrate_jitted_nodonate = jax.jit(_migrate_impl)


def migrate_pages(state: ShardedPool, src_pages, dst_pages,
                  donate: bool = True) -> ShardedPool:
    """Live in-pool migration ``src -> dst`` across shard boundaries.

    One fused dispatch: every shard decode-reads the source pages it owns,
    the page batch circulates the ``banks`` ring via ``S`` explicit
    ``ppermute`` steps (the rank-subset interconnect made visible), and at
    each step every shard lands the pages addressed to it with a masked
    code-maintaining write. Same-shard moves complete at step 0 without
    touching the ring. ``donate=False`` keeps the input pool's storage
    valid (benchmarks; callers that roll back).
    """
    src = pool_lib._as_page_array(state, src_pages)
    dst = pool_lib._as_page_array(state, dst_pages)
    fn = _migrate_jitted if donate else _migrate_jitted_nodonate
    if obs_metrics.enabled():
        obs_metrics.counter(
            obs_metrics.NAME_SHARD_RING_PAGES,
            "pages exchanged over the ppermute migration ring"
        ).inc(int(src.shape[0]))
    with obs_tracing.span("shard.migrate.ring", pages=int(src.shape[0]),
                          shards=state.num_shards):
        return fn(state, src, dst)


# ---------------------------------------------------------------------------
# Repartitioning: all shards move their boundary register in lockstep
# ---------------------------------------------------------------------------


def evicted_extra_pages(state: ShardedPool, new_boundary: int) -> list[int]:
    """Global extra-page ids a move to ``new_boundary`` would evict.

    Round-robin extra striping makes the surviving set a contiguous global
    prefix, so — exactly as for the local pool — the evicted ids are the
    trailing range.
    """
    if new_boundary >= state.boundary:
        return []
    x_new = extra_page_count(state.layout,
                             new_boundary // state.num_shards,
                             state.row_words)
    return list(range(state.num_rows + state.num_shards * x_new,
                      state.num_rows + state.num_extra_pages))


def repartition(state: ShardedPool, new_boundary: int
                ) -> tuple[ShardedPool, dict]:
    """Move every shard's CREAM/SECDED boundary in lockstep.

    Semantics mirror :func:`repro.core.pool.repartition` (page contents of
    surviving ids preserved, codes re-established, evicted extras reported);
    the data plane is one ``shard_map`` over the local repartition, so each
    bank re-encodes its own span independently — no cross-shard traffic.
    """
    router.check_geometry(state.num_rows, new_boundary, state.num_shards)
    old = state.boundary
    info = {"old_boundary": old, "new_boundary": new_boundary,
            "evicted_extra_pages": [], "pages_reencoded": 0}
    if new_boundary == old:
        return state, info
    info["evicted_extra_pages"] = evicted_extra_pages(state, new_boundary)
    info["pages_reencoded"] = abs(new_boundary - old)
    nb_local = new_boundary // state.num_shards

    def body(block):
        new_st, _ = pool_lib.repartition(_local_state(state, block), nb_local)
        return new_st.storage[None]

    with obs_tracing.span("shard.repartition", old_boundary=old,
                          new_boundary=new_boundary,
                          shards=state.num_shards):
        storage = jax.jit(shard_map(
            body, mesh=state.mesh, in_specs=P("banks"),
            out_specs=P("banks")))(state.storage)
    return dataclasses.replace(state, storage=storage,
                               boundary_local=nb_local), info


def set_daec_rows(state: ShardedPool, daec_rows: int) -> ShardedPool:
    """Resize the SEC-DAEC tier: every shard re-encodes its own top span.

    ``daec_rows`` is global and must shard evenly; semantics per shard
    mirror :func:`repro.core.pool.set_daec_rows` (contents preserved —
    decode under the old codec, re-encode under the new one).
    """
    S = state.num_shards
    if daec_rows % S:
        raise ValueError(
            f"daec_rows ({daec_rows}) must shard evenly over {S}")
    if not 0 <= daec_rows <= state.num_rows - state.boundary:
        raise ValueError(
            f"daec_rows ({daec_rows}) must fit the protected region "
            f"[{state.boundary}, {state.num_rows})")
    n_local = daec_rows // S
    if n_local == state.daec_rows_local:
        return state

    def body(block):
        st = pool_lib.set_daec_rows(_local_state(state, block), n_local)
        return st.storage[None]

    with obs_tracing.span("shard.set_daec_rows", old=state.daec_rows,
                          new=daec_rows, shards=S):
        storage = jax.jit(shard_map(
            body, mesh=state.mesh, in_specs=P("banks"),
            out_specs=P("banks")))(state.storage)
    return dataclasses.replace(state, storage=storage,
                               daec_rows_local=n_local)


# ---------------------------------------------------------------------------
# Scrubbing (background sweep; per-shard, host-driven)
# ---------------------------------------------------------------------------


def scrub(state: ShardedPool, use_kernel: bool = False):
    """Sweep every shard, repairing in place; returns (state', ScrubStats).

    Background path (not latency-critical): shards are swept sequentially
    host-side and the per-shard censuses merged, with corrupt row ids mapped
    back to global rows (``global = local * S + shard``).
    """
    from repro.core.scrubber import ScrubStats
    from repro.core.scrubber import scrub as _scrub
    S = state.num_shards
    blocks, merged, corrupt = [], {}, []
    for s in range(S):
        st = PoolState(state.storage[s], state.boundary_local, state.layout,
                       state.row_words, state.daec_rows_local)
        new_st, stats = _scrub(st, use_kernel=use_kernel)
        blocks.append(new_st.storage)
        for f in ("beats_checked", "corrected_data", "corrected_code",
                  "detected_uncorrectable", "parity_lines_checked",
                  "parity_corrupt_lines", "latent_errors_killed"):
            merged[f] = merged.get(f, 0) + getattr(stats, f)
        corrupt.extend(r * S + s for r in stats.corrupt_rows)
    storage = jax.device_put(jnp.stack(blocks),
                             NamedSharding(state.mesh, P("banks")))
    return (dataclasses.replace(state, storage=storage),
            ScrubStats(corrupt_rows=tuple(sorted(corrupt)), **merged))

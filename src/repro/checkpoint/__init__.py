"""repro.checkpoint subpackage."""

"""SECDED-protected sharded checkpointing with targeted restore.

Every leaf is serialised with a SECDED(72,64) code plane computed by the
CREAM core — the checkpoint *itself* is an ECC memory region at rest. On
load, single-bit corruption (disk/DRAM/transfer) is corrected transparently
and double-bit corruption is detected and reported per leaf, enabling the
targeted-restore path (re-fetch only the corrupt leaves from a replica)
instead of failing the whole restore — the paper's reliability asymmetry
applied to the checkpoint tier.

Layout on disk:
  <dir>/step_<N>/manifest.json        paths, shapes, dtypes, code lengths
  <dir>/step_<N>/<mangled-path>.npz   data words + SECDED codes per leaf
"""
from __future__ import annotations

import json
import os
import threading
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core import secded
from repro.distributed.sharding import tree_paths


def _mangle(path: str) -> str:
    return path.replace("/", "__")


def _to_words(arr: np.ndarray) -> tuple[np.ndarray, int]:
    """Any-dtype array -> (uint32 words padded to 8-word multiple, pad_bytes)."""
    raw = arr.tobytes()
    pad = (-len(raw)) % 32  # 8 words = 32 bytes
    words = np.frombuffer(raw + b"\0" * pad, dtype=np.uint32)
    return words, pad


def _from_words(words: np.ndarray, pad: int, shape, dtype) -> np.ndarray:
    raw = words.tobytes()
    if pad:
        raw = raw[:-pad]
    return np.frombuffer(raw, dtype=np.dtype(dtype)).reshape(shape).copy()


@dataclass
class RestoreReport:
    corrected_leaves: list[str]
    corrupt_leaves: list[str]      # detected-uncorrectable -> caller re-fetches

    @property
    def clean(self) -> bool:
        return not self.corrected_leaves and not self.corrupt_leaves


class Checkpointer:
    def __init__(self, directory: str, protect: bool = True,
                 async_save: bool = False):
        self.dir = directory
        self.protect = protect
        self.async_save = async_save
        self._pending: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree) -> str:
        flat = {p: np.asarray(l) for p, l in tree_paths(tree).items()}
        if self._pending is not None:
            self._pending.join()  # one outstanding async save max
            self._pending = None
        if self.async_save:
            self._pending = threading.Thread(
                target=self._write, args=(step, flat), daemon=True)
            self._pending.start()
        else:
            self._write(step, flat)
        return self.step_dir(step)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, flat: dict[str, np.ndarray]) -> None:
        d = self.step_dir(step)
        tmp = d + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {}
        for path, arr in flat.items():
            words, pad = _to_words(arr)
            entry = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                     "pad": pad}
            payload = {"data": words}
            if self.protect:
                codes = np.asarray(secded.encode_block(
                    jnp.asarray(words)[None, :]))[0]
                payload["codes"] = codes
            np.savez(os.path.join(tmp, _mangle(path) + ".npz"), **payload)
            manifest[path] = entry
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "protect": self.protect,
                       "leaves": manifest}, f)
        if os.path.exists(d):
            import shutil
            shutil.rmtree(d)
        os.rename(tmp, d)

    # -- load ---------------------------------------------------------------
    def step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def latest_step(self) -> int | None:
        steps = [int(n.split("_")[1]) for n in os.listdir(self.dir)
                 if n.startswith("step_") and not n.endswith(".tmp")]
        return max(steps) if steps else None

    def restore(self, step: int, like=None
                ) -> tuple[dict, RestoreReport]:
        """Returns (flat {path: np.ndarray}, report). Use ``unflatten_like``
        to rebuild the pytree structure."""
        d = self.step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        corrected, corrupt = [], []
        out: dict[str, np.ndarray] = {}
        for path, entry in manifest["leaves"].items():
            arr, status = self._load_leaf(d, path, entry, manifest["protect"])
            out[path] = arr
            if status == "corrected":
                corrected.append(path)
            elif status == "corrupt":
                corrupt.append(path)
        report = RestoreReport(corrected, corrupt)
        if like is not None:
            return unflatten_like(like, out), report
        return out, report

    def restore_leaves(self, step: int, paths: list[str]) -> dict[str, np.ndarray]:
        """Targeted restore of only the named leaves (corrupt-page recovery)."""
        d = self.step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        out = {}
        for path in paths:
            arr, _ = self._load_leaf(d, path, manifest["leaves"][path],
                                     manifest["protect"])
            out[path] = arr
        return out

    def _load_leaf(self, d: str, path: str, entry: dict, protected: bool
                   ) -> tuple[np.ndarray, str]:
        z = np.load(os.path.join(d, _mangle(path) + ".npz"))
        words = z["data"]
        status = "clean"
        if protected and "codes" in z:
            fixed, _, st = secded.decode_block(
                jnp.asarray(words)[None, :], jnp.asarray(z["codes"])[None, :])
            st = int(jnp.max(st))
            if st == secded.DETECTED_UNCORRECTABLE:
                status = "corrupt"
            elif st != secded.CLEAN:
                status = "corrected"
            words = np.asarray(fixed)[0]
        arr = _from_words(words, entry["pad"], entry["shape"], entry["dtype"])
        return arr, status


def unflatten_like(like, flat: dict[str, np.ndarray]):
    """Rebuild a pytree with ``like``'s structure from a flat path dict."""

    def rebuild(prefix, node):
        if isinstance(node, dict):
            return {k: rebuild(f"{prefix}/{k}" if prefix else k, v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [rebuild(f"{prefix}/{i}", v) for i, v in enumerate(node)]
            return type(node)(t)
        arr = flat[prefix]
        return jnp.asarray(arr).astype(node.dtype) if hasattr(node, "dtype") \
            else jnp.asarray(arr)

    return rebuild("", like)

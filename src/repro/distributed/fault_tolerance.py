"""Fault-tolerance driver: the recovery ladder for 1000+-node training.

Recovery ladder (cheapest first), each rung backed by a tested mechanism:

  1. **scrub-repair** (no restart): SECDED pools self-heal single-bit SDC
     in optimizer snapshots (core.scrubber + trainer.scrub_pools).
  2. **targeted restore**: parity-detected / SECDED-uncorrectable pages are
     re-fetched leaf-wise from the last checkpoint
     (checkpointer.restore_leaves) without touching healthy state.
  3. **warm restart**: a crashed step rebuilds optimizer moments from the
     in-memory SECDED pool (trainer.warm_restore) — params re-read from the
     latest checkpoint.
  4. **cold restart**: full checkpoint restore; the deterministic data
     pipeline resumes at the exact step (no replayed/skipped batches).
  5. **elastic re-mesh**: pod loss -> reshard_tree to the surviving mesh and
     continue with a scaled data axis (distributed.elastic).

Straggler mitigation: there is no shared data queue (per-(step, shard)
batches are recomputed, never handed off), checkpoint saves are async
(one-outstanding), and slow hosts can be dropped at any step boundary via
rung 5 without coordination beyond the new mesh size.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.train.trainer import Trainer


@dataclass
class RecoveryReport:
    rung: str
    details: dict


def recover(trainer: Trainer, failure: str) -> RecoveryReport:
    """Apply the cheapest sufficient rung for the given failure kind."""
    if failure == "sdc_single_bit":
        stats = trainer.scrub_pools()
        if stats.get("uncorrectable", 0) == 0:
            return RecoveryReport("scrub-repair", stats)
        failure = "sdc_multi_bit"
    if failure == "sdc_multi_bit":
        # pool pages are beyond repair -> targeted leaf restore from disk
        step = trainer.checkpointer.latest_step()
        tree, report = trainer.checkpointer.restore(
            step, like=trainer._ckpt_tree())
        bad = report.corrupt_leaves
        if bad:
            raise RuntimeError(f"checkpoint also corrupt: {bad}")
        trainer.params = tree["params"]
        import repro.optim.adamw as adamw
        trainer.opt_state = adamw.AdamWState(
            step=tree["opt"]["step"], m=tree["opt"]["m"], v=tree["opt"]["v"])
        trainer.step = int(tree["meta"]["step"])
        trainer.snapshot_moments()
        return RecoveryReport("targeted-restore",
                              {"restored_at_step": trainer.step,
                               "corrected": report.corrected_leaves})
    if failure == "process_crash":
        worst = trainer.warm_restore()
        if worst <= 2:  # clean or corrected
            return RecoveryReport("warm-restart", {"worst_status": worst})
        failure = "host_loss"
    if failure == "host_loss":
        ok = trainer.restore()
        return RecoveryReport("cold-restart",
                              {"restored": ok, "step": trainer.step})
    raise ValueError(failure)

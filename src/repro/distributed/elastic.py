"""Elastic scaling: re-shard checkpoints/trees across changing host counts.

At 1000+ nodes, pod loss is routine. The elastic path is: every host holds a
deterministic shard of each leaf (split on axis 0); on a re-mesh the new
host set re-slices from whatever shard granularity the checkpoint carries.
``reshard_tree`` is granularity-polymorphic: give it the original tree (one
shard) or a shard list, and a new shard count — it merges then re-splits.

Combined with the deterministic data pipeline (any shard's batch is
recomputable from (seed, step)), a re-meshed job resumes bit-exactly minus
the lost in-flight step.
"""
from __future__ import annotations

import numpy as np


def _merge(shards: list) -> dict:
    """Merge shard dicts back into full leaves (inverse of _split)."""
    if len(shards) == 1:
        return shards[0]
    out = {}
    for key in shards[0]:
        parts = [s[key] for s in shards]
        first = np.asarray(parts[0])
        if first.ndim == 0:
            out[key] = first
        else:
            out[key] = np.concatenate(parts, axis=0)
    return out


def _split(tree: dict, num_shards: int) -> list[dict]:
    shards = [dict() for _ in range(num_shards)]
    for key, leaf in tree.items():
        arr = np.asarray(leaf)
        if arr.ndim == 0 or arr.shape[0] % num_shards:
            for s in shards:            # replicate unsplittable leaves
                s[key] = arr
        else:
            for i, piece in enumerate(np.split(arr, num_shards, axis=0)):
                shards[i][key] = piece
    return shards


def reshard_tree(tree_or_shards, num_shards: int) -> list[dict]:
    """dict | list[dict] -> list of `num_shards` shard dicts."""
    if isinstance(tree_or_shards, dict):
        full = tree_or_shards
    else:
        full = _merge(list(tree_or_shards))
    return _split(full, num_shards)


def plan_remesh(old_devices: int, new_devices: int,
                model_axis: int) -> dict:
    """Axis plan when the device count changes (pod loss / grow).

    Keeps the model axis fixed (TP degree is baked into layer shapes at
    compile time) and absorbs the change on the data axis; if the new count
    doesn't divide, falls back to the largest feasible data axis and idles
    the remainder (reported so the scheduler can re-pack).
    """
    if new_devices % model_axis:
        usable = (new_devices // model_axis) * model_axis
    else:
        usable = new_devices
    return {
        "model_axis": model_axis,
        "data_axis": usable // model_axis,
        "usable_devices": usable,
        "idle_devices": new_devices - usable,
        "batch_scale": (usable // model_axis) / (old_devices // model_axis),
    }

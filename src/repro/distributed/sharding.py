"""Sharding rules: one place that knows how tensors map onto the mesh.

Mesh axes (DESIGN.md §6):
  * ``pod``   — across pods; extra data-parallel dimension (multi-pod mesh only)
  * ``data``  — batch / FSDP / sequence(-KV) parallelism
  * ``model`` — tensor parallelism: heads, FFN hidden, experts, vocab

Model code calls :func:`constraint` on activations; the rules here degrade
gracefully to no-ops when no mesh is active (single-device smoke tests) and
drop axis names the active mesh doesn't have (single-pod vs multi-pod).
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def active_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Activate a mesh for sharding constraints (and enter its jax context)."""
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _state.mesh = prev


def _filter_spec(spec: P, mesh: Mesh) -> P:
    """Drop axis names the mesh doesn't have; keep positions."""
    names = set(mesh.axis_names)

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(e for e in entry if e in names)
            return kept if kept else None
        return entry if entry in names else None

    return P(*(keep(e) for e in spec))


def axis_size(name: str) -> int:
    """Size of a mesh axis (1 when absent / no active mesh)."""
    mesh = active_mesh()
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.devices.shape[list(mesh.axis_names).index(name)]


def _fit_dims(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop spec entries that don't divide their dim.

    Non-divisible shardings make GSPMD pad — and in several measured cases
    (kv_heads=8 over model=16; MoE capacity 3 over data=16 in decode) fall
    back to full rematerialisation, replicating the tensor. Filtering here
    keeps every constraint a clean partition.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= sizes.get(a, 1)
        out.append(entry if n and dim % n == 0 else None)
    return P(*out)


def constraint(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint(x, P(*spec)) if a mesh is active, else x.

    Unknown axis names and non-divisible entries are dropped per-dim.
    """
    mesh = active_mesh()
    if mesh is None:
        return x
    p = _fit_dims(_filter_spec(P(*spec), mesh), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, p))


def named_sharding(*spec) -> NamedSharding:
    mesh = active_mesh()
    if mesh is None:
        raise RuntimeError("no active mesh")
    return NamedSharding(mesh, _filter_spec(P(*spec), mesh))


# ---------------------------------------------------------------------------
# Parameter sharding rules
# ---------------------------------------------------------------------------
# Structural dispatch on the actual parameter paths the model emits
# ('embed/table', 'lm_head/w', 'stages/posN/block/<name>',
# 'stages/posN/mixer/<name>', norms). §Perf iteration 5 note: an earlier
# regex table referenced module names ('attn/', 'mlp/', 'moe/') that never
# appear in real paths — every layer weight silently fell through to the
# replicated catch-all, which the kimi decode probe exposed as
# `sharding={replicated}` full expert weights. Rules are now matched against
# path *leaves* with shape-rank disambiguation and covered by tests.
#
# Philosophy: Megatron-style TP over 'model' + ZeRO-3/FSDP over 'data' on
# one other large dim; experts over 'model' (EP); norms/scalars replicated.

_COL_PARALLEL = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj",
                 "w_zgate", "w_igate", "w_fgate", "w_ogate"}
_ROW_PARALLEL = {"wo", "w_down", "out_proj", "w_out"}


def spec_for_param(path: str, stacked: bool, ndim: int | None = None) -> P:
    parts = path.split("/")
    name = parts[-1]
    parent = parts[-2] if len(parts) > 1 else ""
    spec = P()
    if name == "table":                       # vocab x d_model
        spec = P("model", "data")
    elif parent == "lm_head":                 # d_model x vocab
        spec = P("data", "model")
    elif parent == "mixer":
        if name == "router":
            spec = P("data", None)
        elif ndim == 3 or (ndim is None):     # MoE expert banks (E, ., .)
            # FSDP over the *d* dim. §Perf iteration 7 (refuted) moved FSDP
            # to the f dim hoping to keep contractions local; the resulting
            # (E,C,d) output partial-sum all-reduced 186GB/stage vs 97GB for
            # d-FSDP at kimi scale. d-FSDP + capacity-over-data stands.
            spec = P("model", "data", None) if name in ("w_gate", "w_up") \
                else P("model", None, "data")
        elif name in _COL_PARALLEL:
            spec = P("data", "model")
        elif name in _ROW_PARALLEL:
            spec = P("model", "data")
    elif parent == "block":
        if ndim == 3:                         # head-wise (H, dh, dh)
            spec = P(None, "model", None)
        elif name in _ROW_PARALLEL:
            spec = P("model", "data")
        elif name in _COL_PARALLEL:
            spec = P("data", "model")
        elif name in ("x_bc", "x_dt", "a_log"):
            spec = P("model", None)           # d_inner-major
        elif name == "dt_proj":
            spec = P(None, "model")
        elif name == "conv_w":
            spec = P(None, "model")
        elif name in ("dt_bias", "d_skip"):
            spec = P("model")
        elif name in ("wi", "wf"):            # mLSTM gate heads (dc, H)
            spec = P("data", None)
    # norms / scalars / anything else: replicated P()
    if ndim is not None:
        spec = P(*tuple(spec)[:ndim])
    return P(None, *spec) if stacked else spec


def tree_paths(tree) -> dict[str, jax.Array]:
    """Flatten a pytree of params to {'a/b/c': leaf}."""
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}/{k}" if prefix else k, v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}/{i}", v)
        else:
            flat[prefix] = node

    walk("", tree)
    return flat


def param_shardings(params, mesh: Mesh, stacked_prefixes: tuple[str, ...] = (
        "stages",)):
    """Pytree of NamedShardings matching ``params``' structure."""

    def one(path: str, leaf):
        stacked = any(path.startswith(p) for p in stacked_prefixes)
        ndim = getattr(leaf, "ndim", None)
        spec = spec_for_param(path, stacked,
                              ndim - 1 if stacked and ndim else ndim)
        spec = P(*spec[: ndim if ndim is not None else len(spec)])
        spec = _fit_dims(spec, leaf.shape, mesh) if ndim else spec
        return NamedSharding(mesh, _filter_spec(spec, mesh))

    flat = tree_paths(params)
    shardings = {p: one(p, l) for p, l in flat.items()}

    def rebuild(prefix, node):
        if isinstance(node, dict):
            return {k: rebuild(f"{prefix}/{k}" if prefix else k, v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [rebuild(f"{prefix}/{i}", v) for i, v in enumerate(node)]
            return type(node)(t)
        return shardings[prefix]

    return rebuild("", params)

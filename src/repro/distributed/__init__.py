"""repro.distributed subpackage."""

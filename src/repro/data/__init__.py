"""repro.data subpackage."""

"""Deterministic synthetic token pipeline with shard-aware skip/refill.

Production data loading is out of scope for a CPU container, but the
*contract* a 1000-node trainer needs is implemented exactly:

  * deterministic per-(step, shard) batches — any host can regenerate any
    shard's batch from (seed, step) alone, so restarts and elastic re-meshes
    never replay or skip data;
  * straggler mitigation by construction: there is no shared queue to drain —
    a failed host's shard is recomputed by its replacement from the step id;
  * a lightweight mixture model (Zipfian unigrams + periodic motifs) so
    losses move during integration tests instead of staying at log V.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1


def _zipf_logits(cfg: DataConfig) -> np.ndarray:
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    probs = ranks ** (-cfg.zipf_alpha)
    return np.log(probs / probs.sum())


class SyntheticStream:
    """Deterministic (step, shard) -> batch generator."""

    def __init__(self, cfg: DataConfig, num_shards: int = 1, shard_id: int = 0):
        if cfg.global_batch % num_shards:
            raise ValueError("global_batch must divide by num_shards")
        self.cfg = cfg
        self.num_shards = num_shards
        self.shard_id = shard_id
        self.local_batch = cfg.global_batch // num_shards
        self._logits = jnp.asarray(_zipf_logits(cfg), jnp.float32)

    def batch(self, step: int) -> dict[str, jax.Array]:
        """-> {'tokens': (local_batch, S), 'labels': (local_batch, S)} int32."""
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(self.cfg.seed), step),
            self.shard_id)
        k1, k2 = jax.random.split(key)
        b, s = self.local_batch, self.cfg.seq_len
        base = jax.random.categorical(k1, self._logits, shape=(b, s + 1))
        # periodic motif: every 8th position repeats the motif token, giving
        # the model a learnable structure
        motif = jax.random.randint(k2, (b, 1), 0, self.cfg.vocab_size)
        pos = jnp.arange(s + 1)[None, :]
        seq = jnp.where(pos % 8 == 0, motif, base).astype(jnp.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}

    def batches(self, start_step: int = 0):
        step = start_step
        while True:
            yield step, self.batch(step)
            step += 1

"""Roofline analysis: three terms per (arch × shape × mesh) from dry-run JSONs.

    compute    = HLO_FLOPs / (chips × 197 TF/s bf16)
    memory     = HLO_bytes / (chips × 819 GB/s)
    collective = collective_wire_bytes / (chips × 50 GB/s·link)

All numerators are per-device (the compiled module is the per-device SPMD
program), scaled for scan trip counts via the stage probe (dryrun.py), so
the denominators use per-chip rates directly. MODEL_FLOPS = 6·N_active·D
(per device) checks how much compiled compute is useful.

Usage: PYTHONPATH=src python -m repro.roofline.analysis [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from dataclasses import dataclass

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

CHIPS = {"pod16x16": 256, "pod2x16x16": 512}


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    kind: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_per_dev: float
    hlo_flops_per_dev: float
    bound: str
    step_s: float              # max of the three (no-overlap bound)
    roofline_frac: float       # compute term / step_s ("how close to ideal")
    useful_ratio: float        # MODEL_FLOPS / HLO_FLOPs

    def row(self) -> str:
        return (f"{self.arch:22s} {self.shape:12s} {self.mesh:10s} "
                f"{self.compute_s:9.2e} {self.memory_s:9.2e} "
                f"{self.collective_s:9.2e} {self.bound:10s} "
                f"{self.roofline_frac:5.2f} {self.useful_ratio:5.2f}")


def model_flops_for(arch: str, shape_name: str, kind: str,
                    global_batch: int, seq_len: int) -> float:
    """Total MODEL_FLOPS for the step (all devices together)."""
    from repro.configs import get_config
    from repro.models.model import count_params
    cfg = get_config(arch)
    n_active = count_params(cfg, active_only=True)
    if kind == "train":
        tokens = global_batch * seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = global_batch * seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * global_batch


def analyze_record(rec: dict) -> Roofline | None:
    if not rec.get("ok"):
        return None
    chips = CHIPS[rec["mesh"]]
    flops = rec.get("hlo_flops_scaled", rec.get("hlo_flops", 0.0))
    mem_bytes = rec.get("hlo_bytes_scaled", rec.get("hlo_bytes", 0.0))
    coll_bytes = rec.get("collective_wire_bytes_scaled",
                         rec.get("collectives", {}).get("wire_bytes", 0))

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = mem_bytes / HBM_BW
    collective_s = coll_bytes / ICI_BW_PER_LINK

    from repro.configs import SHAPES
    shp = SHAPES[rec["shape"]]
    mf_total = model_flops_for(rec["arch"], rec["shape"], rec["kind"],
                               shp.global_batch, shp.seq_len)
    mf_dev = mf_total / chips

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bound = max(terms, key=terms.get)
    step_s = max(terms.values())
    ideal_s = mf_dev / PEAK_FLOPS_BF16
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        kind=rec["kind"], compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, model_flops_per_dev=mf_dev,
        hlo_flops_per_dev=flops, bound=bound, step_s=step_s,
        roofline_frac=ideal_s / step_s if step_s else 0.0,
        useful_ratio=mf_dev / flops if flops else 0.0)


def load_all(directory: str) -> list[Roofline]:
    out = []
    for f in sorted(glob.glob(os.path.join(directory, "*.json"))):
        r = analyze_record(json.load(open(f)))
        if r is not None:
            out.append(r)
    return out


def print_table(rows: list[Roofline]) -> None:
    print(f"{'arch':22s} {'shape':12s} {'mesh':10s} {'compute_s':>9s} "
          f"{'memory_s':>9s} {'collect_s':>9s} {'bound':10s} "
          f"{'rfrac':>5s} {'usefl':>5s}")
    for r in rows:
        print(r.row())


def interesting_cells(rows: list[Roofline]) -> dict[str, Roofline]:
    """The three hillclimb candidates (§Perf)."""
    single = [r for r in rows if r.mesh == "pod16x16"]
    worst = min(single, key=lambda r: r.roofline_frac)
    coll = max(single, key=lambda r: (r.collective_s /
                                      max(r.step_s, 1e-30)))
    # most CREAM-representative: the serving-decode cell of the largest
    # KV-capacity-sensitive arch (decode = where pool capacity bites)
    decode = [r for r in single if r.kind == "decode"]
    rep = max(decode, key=lambda r: r.model_flops_per_dev)
    return {"worst_roofline": worst, "most_collective_bound": coll,
            "most_representative": rep}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--json-out", default="experiments/roofline.json")
    args = ap.parse_args()
    rows = load_all(args.dir)
    print_table(rows)
    picks = interesting_cells(rows)
    print("\nHillclimb candidates:")
    for why, r in picks.items():
        print(f"  {why:24s} -> {r.arch} x {r.shape} ({r.bound}-bound, "
              f"frac={r.roofline_frac:.3f})")
    with open(args.json_out, "w") as f:
        json.dump({"cells": [r.__dict__ for r in rows],
                   "picks": {k: v.__dict__ for k, v in picks.items()}},
                  f, indent=1)


if __name__ == "__main__":
    main()

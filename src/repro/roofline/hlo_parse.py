"""Parse collective traffic out of optimised (post-SPMD) HLO text.

``compiled.as_text()`` is the per-device module, so shapes are per-shard:
summing the result bytes of every collective op gives per-device collective
bytes directly (§Roofline's collective_bytes). All-reduce is charged 2×
(reduce-scatter + all-gather wire cost of a ring); others 1×.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute", "collective-broadcast")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^)=]*?\)?)\s*"
    r"(" + "|".join(COLLECTIVE_OPS) + r")(?:-start|-done)?\(",
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict[str, int] = field(default_factory=lambda: defaultdict(int))
    count_by_op: dict[str, int] = field(default_factory=lambda: defaultdict(int))

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def wire_bytes(self) -> int:
        """Ring-model wire traffic: all-reduce counted twice."""
        total = 0
        for op, b in self.bytes_by_op.items():
            total += 2 * b if op == "all-reduce" else b
        return total

    def as_dict(self) -> dict:
        return {"bytes_by_op": dict(self.bytes_by_op),
                "count_by_op": dict(self.count_by_op),
                "total_bytes": self.total_bytes,
                "wire_bytes": self.wire_bytes}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    seen_done: set[str] = set()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        # async pairs: count -start, skip -done (same transfer)
        if f"{op}-done(" in line:
            continue
        b = _shape_bytes(type_str)
        stats.bytes_by_op[op] += b
        stats.count_by_op[op] += 1
    return stats

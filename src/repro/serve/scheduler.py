"""CREAM-Serve scheduler: admission, interleaving, preempt-to-host.

Paper anchor: §3.3's dynamic capacity adjustment and §6.1's capacity-vs-
fault-rate tradeoff, acted out as serving policy. The scheduler is the
"OS" of the serving tier: it decides which sequences' KV occupies the
CREAM pool (device), which are parked on it between turns, and which are
preempted to the host swap tier when the boundary register takes capacity
away — the same decision the paper's kernel makes for page frames, with
HRM-style tiers (paid → SECDED frames, batch → NONE frames) deciding who
gets evicted first.

Mechanics:

  * requests are admitted FIFO into a fixed number of decode slots; a
    request for a session whose earlier turn is still decoding waits
    (per-session ordering), others may overtake it;
  * a session keeps its KV pages *after* a request finishes (parked on
    device) so the next turn resumes without prefill — parked
    sessions are the eviction pool: when frames run out, parked batch-tier
    sessions are preempted to host LRU-first (paid admissions may also
    preempt parked paid sessions, never running ones);
  * mid-decode, a bound sequence whose block table cannot grow (or whose
    pages a repartition pushed off-device — :meth:`sync_residency`) is
    preempted: its request re-queues as a continuation and resumes later
    with bit-exact KV.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.serve.paged_kv import PagedKV


@dataclass
class ServeRequest:
    """One turn of one session: decode ``max_new`` tokens onto its KV.

    ``prompt`` seeds the session's KV on first contact (and on a reset
    after the session's block table fills); continuation turns reuse the
    session's parked KV and decode straight away.
    """
    seq_id: str
    prompt: np.ndarray
    max_new: int
    tier: str = "batch"
    generated: list[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_done: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit


@dataclass
class Session:
    """A sequence with live KV (bound to a slot, parked, or on host)."""
    seq_id: str
    tier: str
    row: int                      # PagedKV block-table row
    cache_len: int = 0
    last_tok: int = 0
    slot: int | None = None
    req: ServeRequest | None = None
    last_use: int = 0


@dataclass
class Admission:
    slot: int
    req: ServeRequest
    session: Session
    is_prefill: bool


class Scheduler:
    """Continuous-batching admission control over a :class:`PagedKV`."""

    def __init__(self, kv: PagedKV, max_batch: int, token_limit: int):
        self.kv = kv
        self.max_batch = max_batch
        self.token_limit = min(token_limit,
                               kv.max_blocks * kv.block_tokens)
        self.waiting: list[ServeRequest] = []
        self.slots: list[Session | None] = [None] * max_batch
        self.sessions: dict[str, Session] = {}
        self.preemptions = 0
        self.restores = 0
        self.resets = 0
        self._clock = 0

    # -- public surface ------------------------------------------------------
    def submit(self, req: ServeRequest) -> None:
        # a fresh (or reset) session prefills the prompt then decodes
        # max_new - 1 more tokens, so its cache peaks at P + max_new - 1
        if len(req.prompt) + req.max_new - 1 > self.token_limit:
            raise ValueError(
                f"prompt {len(req.prompt)} + max_new {req.max_new} tokens "
                f"exceed the {self.token_limit}-token block table")
        req.t_submit = time.perf_counter()
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting) or any(s is not None for s in self.slots)

    def active_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def tick(self) -> list[Admission]:
        """One admission pass: bind as many waiting requests to free slots
        as device capacity allows. Returns the new bindings; the engine
        prefills the ``is_prefill`` ones."""
        self._clock += 1
        out: list[Admission] = []
        i = 0
        while i < len(self.waiting) and None in self.slots:
            req = self.waiting[i]
            sess = self.sessions.get(req.seq_id)
            if sess is not None and sess.slot is not None:
                i += 1          # session busy: later sessions may overtake
                continue
            act = self._activate(req)
            if act is None:     # out of device frames: head-of-line waits
                break
            sess, is_prefill = act
            slot = self.slots.index(None)
            self.slots[slot] = sess
            sess.slot = slot
            sess.req = req
            sess.last_use = self._clock
            self.waiting.pop(i)
            out.append(Admission(slot, req, sess, is_prefill))
        return out

    def ensure_step(self) -> list[int]:
        """Grow every bound session's block table for one more token,
        preempting (to host) the ones that cannot fit. Returns the slots
        dropped from this step."""
        dropped = []
        for slot, sess in enumerate(self.slots):
            if sess is None:
                continue
            need = self.kv.frames_needed(sess.row, sess.cache_len + 1)
            if need and not self._with_room(sess.tier, need, lambda:
                                            self.kv.ensure(
                                                sess.row,
                                                sess.cache_len + 1)):
                self._preempt_bound(slot)
                dropped.append(slot)
        return dropped

    def finish(self, slot: int) -> ServeRequest:
        """Request done: park the session (KV stays device-resident)."""
        sess = self.slots[slot]
        req = sess.req
        req.t_done = time.perf_counter()
        sess.slot = None
        sess.req = None
        sess.last_use = self._clock
        self.slots[slot] = None
        return req

    def close_session(self, seq_id: str) -> None:
        sess = self.sessions.pop(seq_id)
        if sess.slot is not None:
            raise RuntimeError(f"{seq_id} still bound to slot {sess.slot}")
        self.kv.close(sess.row)

    def sync_residency(self) -> list[int]:
        """After an external repartition/migration: refresh translations and
        preempt every bound session whose pages left the device — the
        mid-decode capacity loss the preemption test exercises. Returns the
        dropped slots."""
        self.kv.refresh()
        dropped = []
        for slot, sess in enumerate(self.slots):
            if sess is not None and not self.kv.resident(sess.row):
                self._preempt_bound(slot)
                dropped.append(slot)
        return dropped

    @property
    def stats(self) -> dict:
        return {"preemptions": self.preemptions, "restores": self.restores,
                "resets": self.resets, "parked": sum(
                    1 for s in self.sessions.values() if s.slot is None),
                "waiting": len(self.waiting)}

    # -- internals -----------------------------------------------------------
    def _activate(self, req: ServeRequest) -> tuple[Session, bool] | None:
        sess = self.sessions.get(req.seq_id)
        # tokens this request still has to decode — a preempted-and-requeued
        # continuation carries its partial `generated` and must NOT be
        # measured (or reset!) as if it were starting from scratch
        remaining = req.max_new - len(req.generated)
        if sess is not None and \
                self.token_limit - sess.cache_len < remaining:
            # block table full: reset the session (conversation truncation)
            self.close_session(req.seq_id)
            self.resets += 1
            sess = None
        if sess is None:
            need_tokens = len(req.prompt) + 1
            frames = self.kv.blocks_for(need_tokens) * self.kv.n_layers
            row = self.kv.open(req.tier)
            if not self._with_room(req.tier, frames,
                                   lambda: self.kv.ensure(row, need_tokens),
                                   keep=row):
                self.kv.close(row)
                return None
            sess = Session(req.seq_id, req.tier, row)
            self.sessions[req.seq_id] = sess
            return sess, True
        # continuation: bring pages home, then room for one more token
        if not self.kv.resident(sess.row):
            frames = self.kv.host_pages(sess.row)
            if not self._with_room(sess.tier, frames,
                                   lambda: self.kv.restore(sess.row),
                                   keep=sess.row):
                return None
            self.restores += 1
        need = self.kv.frames_needed(sess.row, sess.cache_len + 1)
        if need and not self._with_room(sess.tier, need, lambda:
                                        self.kv.ensure(sess.row,
                                                       sess.cache_len + 1),
                                        keep=sess.row):
            return None
        return sess, False

    def _with_room(self, tier: str, frames: int, attempt,
                   keep: int | None = None) -> bool:
        """Run ``attempt`` (an allocation), preempting parked sessions to
        host until it succeeds or no victims remain. ``keep`` protects the
        row the allocation is *for* from being its own victim."""
        rel = self.kv.tiers[tier]
        while True:
            if self.kv.free_frames(rel) >= frames and attempt():
                return True
            if not self._preempt_one_parked(rel, requester_tier=tier,
                                            keep=keep):
                # no victims left — one last try (classes may overlap)
                return attempt()

    def _preempt_one_parked(self, rel, requester_tier: str,
                            keep: int | None = None) -> bool:
        """Preempt the LRU parked session to host: batch tier first; parked
        paid sessions fall only to paid requesters. Running sequences are
        never victims, nor is the ``keep`` row, nor sessions whose frames
        could not serve a class-``rel`` allocation anyway (evicting them
        would be pure host traffic with zero usable frames freed)."""
        parked = [s for s in self.sessions.values()
                  if s.slot is None and s.row != keep
                  and self.kv.row_frames_of_class(s.row, rel) > 0]
        victims = sorted((s for s in parked if s.tier == "batch"
                          or requester_tier == "paid"),
                         key=lambda s: (s.tier != "batch", s.last_use))
        if not victims:
            return False
        self.kv.preempt(victims[0].row)
        self.preemptions += 1
        return True

    def _preempt_bound(self, slot: int) -> None:
        """Preempt a running sequence: KV to host, request re-queued as a
        continuation (front of the queue, preserving per-session order)."""
        sess = self.slots[slot]
        req = sess.req
        self.kv.preempt(sess.row)
        sess.slot = None
        sess.req = None
        self.slots[slot] = None
        self.waiting.insert(0, req)
        self.preemptions += 1

"""CREAM-pool-backed sequence-state cache: the paper's capacity story, served.

Serving keeps many more sequences than fit in one decode batch; parked
sequences' KV/recurrent state must live *somewhere*. The tier order is

    device CREAM pool  ->  host memory  ("page fault": device<->host copy)

and the pool's protection mode sets the device tier's capacity: flipping
SECDED -> InterWrap adds +12.5% device pages => higher hit rate => fewer
host round-trips. This is exactly the paper's memcached experiment with the
SSD replaced by host DRAM (same orders-of-magnitude penalty ratio on TPU).

KV pages are protection-free by policy (Fig. 1: caches tolerate loss — a
lost page is a prefill away), which is what frees the code lane for data.
"""
from __future__ import annotations

import math
import time
from collections import OrderedDict
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pool as pool_lib
from repro.core.layouts import Layout
from repro.core.pool import PoolState, make_pool


@dataclass
class CacheStats:
    device_hits: int = 0
    host_hits: int = 0          # page faults: state had been demoted to host
    misses: int = 0             # unknown sequence (needs prefill)
    evictions: int = 0
    device_fetch_s: float = 0.0
    host_fetch_s: float = 0.0

    @property
    def fault_rate(self) -> float:
        total = self.device_hits + self.host_hits
        return self.host_hits / total if total else 0.0


@dataclass
class _Entry:
    pages: list[int] | None     # device pages, or None if on host
    nbytes: int
    host_copy: np.ndarray | None = None


class SequenceCache:
    """LRU cache of per-sequence state blobs over (CREAM pool, host) tiers."""

    def __init__(self, num_rows: int, mode: str = "cream",
                 row_words: int = 256):
        """mode: 'cream' (InterWrap, +12.5% pages) | 'secded' (baseline ECC)."""
        if mode == "cream":
            self.pool = make_pool(num_rows, Layout.INTERWRAP,
                                  row_words=row_words)
        elif mode == "secded":
            self.pool = make_pool(num_rows, Layout.INTERWRAP, boundary=0,
                                  row_words=row_words)
        else:
            raise ValueError(mode)
        self.mode = mode
        self.free_pages = list(range(self.pool.num_pages))
        self.lru: OrderedDict[str, _Entry] = OrderedDict()
        self.stats = CacheStats()

    @property
    def device_capacity_pages(self) -> int:
        return self.pool.num_pages

    def pages_needed(self, nbytes: int) -> int:
        return math.ceil(nbytes / self.pool.page_bytes)

    # -- write ---------------------------------------------------------------
    def park(self, seq_id: str, blob: np.ndarray) -> None:
        """Store a sequence's state (uint8 blob). Evicts LRU to host if full."""
        if seq_id in self.lru:
            self._drop_device(self.lru.pop(seq_id))
        nbytes = blob.nbytes
        n = self.pages_needed(nbytes)
        while len(self.free_pages) < n and self._any_device_resident():
            self._evict_one()
        entry = _Entry(pages=None, nbytes=nbytes)
        if len(self.free_pages) >= n:
            pages = [self.free_pages.pop() for _ in range(n)]
            words = np.zeros(n * self.pool.page_words, np.uint32)
            padded = np.frombuffer(
                blob.tobytes() + b"\0" * ((-nbytes) % 4), dtype=np.uint32)
            words[:len(padded)] = padded
            self.pool = pool_lib.write_pages_batch(
                self.pool, jnp.asarray(pages, jnp.int32),
                jnp.asarray(words.reshape(n, -1)))
            entry.pages = pages
        else:
            entry.host_copy = blob.copy()
        self.lru[seq_id] = entry
        self.lru.move_to_end(seq_id)

    # -- read ----------------------------------------------------------------
    def resume(self, seq_id: str) -> np.ndarray | None:
        """Fetch a sequence's state; None if unknown (caller must prefill)."""
        entry = self.lru.get(seq_id)
        if entry is None:
            self.stats.misses += 1
            return None
        self.lru.move_to_end(seq_id)
        t0 = time.perf_counter()
        if entry.pages is not None:
            data = pool_lib.read_pages_batch(
                self.pool, jnp.asarray(entry.pages, jnp.int32))
            blob = np.asarray(data).view(np.uint8).reshape(-1)[:entry.nbytes]
            self.stats.device_hits += 1
            self.stats.device_fetch_s += time.perf_counter() - t0
        else:
            blob = entry.host_copy
            # charge a host->device transfer (the "page fault")
            _ = jax.device_put(blob).block_until_ready()
            self.stats.host_hits += 1
            self.stats.host_fetch_s += time.perf_counter() - t0
        return np.asarray(blob, np.uint8).copy()

    # -- internals -------------------------------------------------------------
    def _any_device_resident(self) -> bool:
        return any(e.pages is not None for e in self.lru.values())

    def _evict_one(self) -> None:
        for sid, e in self.lru.items():      # oldest first
            if e.pages is not None:
                data = pool_lib.read_pages_batch(
                    self.pool, jnp.asarray(e.pages, jnp.int32))
                e.host_copy = np.asarray(data).view(np.uint8).reshape(-1)[
                    :e.nbytes].copy()
                self._drop_device(e)
                self.stats.evictions += 1
                return
        raise RuntimeError("nothing to evict")

    def _drop_device(self, e: _Entry) -> None:
        if e.pages is not None:
            self.free_pages.extend(e.pages)
            e.pages = None


def pack_tree(tree) -> tuple[np.ndarray, list]:
    """Pytree -> (uint8 blob, spec) for SequenceCache storage."""
    leaves, treedef = jax.tree.flatten(tree)
    spec = [(l.shape, str(l.dtype)) for l in leaves]
    blob = np.concatenate([np.asarray(l).view(np.uint8).reshape(-1)
                           for l in leaves]) if leaves else np.zeros(0, np.uint8)
    return blob, (treedef, spec)


def unpack_tree(blob: np.ndarray, spec) -> object:
    treedef, shapes = spec
    leaves = []
    off = 0
    for shape, dtype in shapes:
        n = int(np.prod(shape)) * np.dtype(dtype).itemsize
        arr = blob[off:off + n].view(np.dtype(dtype)).reshape(shape)
        leaves.append(jnp.asarray(arr.copy()))
        off += n
    return jax.tree.unflatten(treedef, leaves)

"""CREAM-VM-backed sequence-state cache: the paper's capacity story, served.

Paper anchor: §6.1's memcached experiment (Fig. 8) with the SSD replaced
by host DRAM, and Fig. 1's loss-tolerant cache quadrant (KV pages run
protection-free by policy). Superseded on the serving hot path by the
paged-KV engine (:mod:`repro.serve.paged_kv`), which keeps KV blocks
natively in pool pages instead of packing/parking whole decode states;
kept as the whole-blob VM-tenant exemplar the VM acceptance tests drive.

Serving keeps many more sequences than fit in one decode batch; parked
sequences' KV/recurrent state must live *somewhere*. The tier order is

    device CREAM pool  ->  host memory  ("page fault": device<->host copy)

and the pool's protection mode sets the device tier's capacity: flipping
SECDED -> InterWrap adds +12.5% device pages => higher hit rate => fewer
host round-trips. This is exactly the paper's memcached experiment with the
SSD replaced by host DRAM (same orders-of-magnitude penalty ratio on TPU).

KV pages are protection-free by policy (Fig. 1: caches tolerate loss — a
lost page is a prefill away), which is what frees the code lane for data.

Storage goes through :class:`repro.vm.VirtualMemory` — the cache is just a
tenant with an LRU policy. It no longer owns raw pool page ids, so a
protection upgrade on the underlying pool (driven by
:class:`repro.vm.policy.VMPolicy`) live-migrates parked sequences instead of
dropping them, and the pool can be shared with other tenants. All device
traffic rides the VM's jitted mixed-pool access engine (one vectorised
gather/scatter per pool, any boundary); :meth:`SequenceCache.resume_many`
batches whole decode waves into a single engine dispatch.
"""
from __future__ import annotations

import math
import time
from collections import OrderedDict
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layouts import Layout
from repro.core.pool import PoolState
from repro.core.protection import Protection
from repro.vm.address_space import VirtualMemory


@dataclass
class CacheStats:
    device_hits: int = 0
    host_hits: int = 0          # page faults: state had been demoted to host
    misses: int = 0             # unknown sequence (needs prefill)
    evictions: int = 0
    device_fetch_s: float = 0.0
    host_fetch_s: float = 0.0

    @property
    def fault_rate(self) -> float:
        total = self.device_hits + self.host_hits
        return self.host_hits / total if total else 0.0


@dataclass
class _Entry:
    vpns: list[int]
    nbytes: int


class SequenceCache:
    """LRU cache of per-sequence state blobs, allocated through the VM."""

    POOL = "kv"

    def __init__(self, num_rows: int, mode: str = "cream",
                 row_words: int = 256, vm: VirtualMemory | None = None,
                 tenant: str = "kv"):
        """mode: 'cream' (InterWrap, +12.5% pages) | 'secded' (baseline ECC).

        Pass an existing ``vm`` (with a pool named ``"kv"``) to share pools
        with other tenants; otherwise a private one-pool VM is built.
        """
        if mode not in ("cream", "secded"):
            raise ValueError(mode)
        if vm is None:
            vm = VirtualMemory(row_words=row_words)
            vm.add_pool(self.POOL, num_rows, Layout.INTERWRAP,
                        boundary=None if mode == "cream" else 0)
        self.vm = vm
        self.tenant = tenant
        reliability = Protection.NONE if mode == "cream" \
            else Protection.SECDED
        vm.create_tenant(tenant, default_reliability=reliability)
        self.mode = mode
        self.lru: OrderedDict[str, _Entry] = OrderedDict()
        self.stats = CacheStats()

    @property
    def pool(self) -> PoolState:
        return self.vm.pools[self.POOL]

    @property
    def device_capacity_pages(self) -> int:
        return self.vm.device_capacity_pages()

    @property
    def device_utilisation(self) -> float:
        return self.vm.utilisation()

    def pages_needed(self, nbytes: int) -> int:
        return math.ceil(nbytes / self.vm.page_bytes)

    # -- write ---------------------------------------------------------------
    def park(self, seq_id: str, blob: np.ndarray) -> None:
        """Store a sequence's state (uint8 blob). Evicts LRU to host if full."""
        if seq_id in self.lru:
            self.vm.free(self.tenant, self.lru.pop(seq_id).vpns)
        nbytes = blob.nbytes
        n = self.pages_needed(nbytes)
        # zero=False: every allocated page is overwritten just below
        vpns = self.vm.alloc(self.tenant, n, allow_host=False, zero=False)
        while vpns is None and self._evict_one():
            vpns = self.vm.alloc(self.tenant, n, allow_host=False, zero=False)
        if vpns is None:             # device full of pinned pages -> host
            vpns = self.vm.alloc(self.tenant, n, allow_host=True, zero=False)
        words = np.zeros(n * self.vm.page_words, np.uint32)
        padded = np.frombuffer(
            blob.tobytes() + b"\0" * ((-nbytes) % 4), dtype=np.uint32)
        words[:len(padded)] = padded
        self.vm.write(self.tenant, vpns, words.reshape(n, -1))
        self.lru[seq_id] = _Entry(vpns, nbytes)
        self.lru.move_to_end(seq_id)

    # -- read ----------------------------------------------------------------
    def resume_many(self, seq_ids) -> dict[str, np.ndarray | None]:
        """Batched :meth:`resume`: one engine dispatch per backing pool.

        All device-resident pages of all known sequences are translated and
        gathered together through the VM's mixed-pool engine (a single
        ``page_coords`` gather + masked decode per pool) instead of one
        round-trip per sequence — the decode batch assembling several parked
        sequences is the serving hot path the engine exists for.
        """
        seq_ids = list(seq_ids)
        out: dict[str, np.ndarray | None] = {}
        known: list[tuple[str, _Entry, bool]] = []
        all_vpns: list[int] = []
        for sid in seq_ids:
            entry = self.lru.get(sid)
            if entry is None:
                self.stats.misses += 1
                out[sid] = None
                continue
            self.lru.move_to_end(sid)
            on_host = self.vm.residency(self.tenant, entry.vpns) != "device"
            known.append((sid, entry, on_host))
            all_vpns.extend(entry.vpns)
        if not known:
            return out
        t0 = time.perf_counter()
        data = np.asarray(self.vm.read(self.tenant, all_vpns), np.uint32)
        off = 0
        host_blobs = []
        for sid, entry, on_host in known:
            pages = data[off:off + len(entry.vpns)]
            off += len(entry.vpns)
            blob = pages.view(np.uint8).reshape(-1)[:entry.nbytes]
            out[sid] = np.asarray(blob, np.uint8).copy()
            if on_host:
                host_blobs.append(out[sid])
                self.stats.host_hits += 1
            else:
                self.stats.device_hits += 1
        if host_blobs:
            # charge the host->device transfer (the "page fault"), exactly
            # as the single-sequence resume() does — one batched upload
            _ = jax.device_put(np.concatenate(host_blobs)).block_until_ready()
        fetch_s = time.perf_counter() - t0
        # charge the batch's wall time to the slower tier it touched
        if host_blobs:
            self.stats.host_fetch_s += fetch_s
        else:
            self.stats.device_fetch_s += fetch_s
        return out

    def resume(self, seq_id: str) -> np.ndarray | None:
        """Fetch a sequence's state; None if unknown (caller must prefill)."""
        entry = self.lru.get(seq_id)
        if entry is None:
            self.stats.misses += 1
            return None
        self.lru.move_to_end(seq_id)
        t0 = time.perf_counter()
        on_host = self.vm.residency(self.tenant, entry.vpns) != "device"
        data = self.vm.read(self.tenant, entry.vpns)
        blob = np.asarray(data).view(np.uint8).reshape(-1)[:entry.nbytes]
        if on_host:
            # charge the host->device transfer (the "page fault")
            _ = jax.device_put(blob).block_until_ready()
            self.stats.host_hits += 1
            self.stats.host_fetch_s += time.perf_counter() - t0
        else:
            self.stats.device_hits += 1
            self.stats.device_fetch_s += time.perf_counter() - t0
        return np.asarray(blob, np.uint8).copy()

    # -- internals -----------------------------------------------------------
    def _evict_one(self) -> bool:
        """Demote the LRU device-resident entry to the host tier."""
        for sid, e in self.lru.items():      # oldest first
            if self.vm.residency(self.tenant, e.vpns) != "host":
                self.vm.swap_out(self.tenant, e.vpns)
                self.stats.evictions += 1
                return True
        return False


def pack_tree(tree) -> tuple[np.ndarray, list]:
    """Pytree -> (uint8 blob, spec) for SequenceCache storage."""
    leaves, treedef = jax.tree.flatten(tree)
    spec = [(l.shape, str(l.dtype)) for l in leaves]
    blob = np.concatenate([np.asarray(l).view(np.uint8).reshape(-1)
                           for l in leaves]) if leaves else np.zeros(0, np.uint8)
    return blob, (treedef, spec)


def unpack_tree(blob: np.ndarray, spec) -> object:
    treedef, shapes = spec
    leaves = []
    off = 0
    for shape, dtype in shapes:
        n = int(np.prod(shape)) * np.dtype(dtype).itemsize
        arr = blob[off:off + n].view(np.dtype(dtype)).reshape(shape)
        leaves.append(jnp.asarray(arr.copy()))
        off += n
    return jax.tree.unflatten(treedef, leaves)

"""CREAM-Serve: continuous batching with KV paged onto the CREAM pool.

Paper anchor: §6.1 / Fig. 8 — the end-to-end capacity claim (memcached
+23.0 %, WebSearch +37.3 %) restated for LLM serving: the KV cache IS the
capacity-sensitive working set, stored page-for-page in a CREAM pool, and
the boundary register's reclaimed code-lane pages are extra sequences
served without a host round-trip.

The engine is vLLM-shaped but the data plane is this repo's:

  * every (sequence, layer, KV block) lives in one CREAM pool page; the
    :class:`repro.serve.paged_kv.PagedKV` block table maps them and the
    :class:`repro.serve.scheduler.Scheduler` decides residency
    (admission, parking between turns, preempt-to-host under pressure);
  * a decode step is exactly three dispatches on any
    :class:`repro.core.pool.PoolLike` (local or CREAM-Shard): ONE batched
    page gather (``pool.read`` with the flattened block tables as index
    map — on a sharded pool the planned bank-aligned dispatch, ~``n/S``
    pages per bank), one fused model step
    (:func:`repro.models.transformer.decode_step_paged` over all slots,
    optionally fused with the ``ppermute`` migration ring so scheduled
    page moves overlap the attention compute), and ONE batched scatter of
    the updated current blocks (``pool.write``). No Python per-sequence
    loop touches KV;
  * prefill extracts the prompt's KV from the dense
    :func:`repro.models.transformer.prefill` state and packs it into the
    sequence's blocks with a single batched write.

All shapes are fixed by ``(max_batch, n_layers, max_blocks)``: unbound
slots read and write a scratch page and are masked by ``cache_len = 0``,
so the whole serving loop runs three compiled programs regardless of which
sequences are live.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import pool as pool_lib
from repro.core import secded
from repro.core.layouts import Layout
from repro.core.pool import PoolState
from repro.kernels.mixed import ops as mixed_ops
from repro.models import build_model
from repro.models import transformer
from repro.obs import memprof as obs_memprof
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.serve.paged_kv import PagedKV, token_words_for
from repro.serve.scheduler import Scheduler, ServeRequest
from repro.vm.address_space import VirtualMemory

# Re-export: the old engine's request type moved to the scheduler.
Request = ServeRequest


def _percentile(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def _cream_cls_index(layout: Layout) -> int:
    """Index into :data:`repro.obs.metrics.FOLD_CLASSES` for CREAM pages."""
    if layout == Layout.BASELINE_ECC:
        return obs_metrics.FOLD_CLASSES.index("secded")
    cls = "parity" if layout == Layout.PARITY else "none"
    return obs_metrics.FOLD_CLASSES.index(cls)


def _status_counts(pages: jax.Array, status: jax.Array, boundary: int,
                   num_rows: int, cream_idx: int,
                   daec_start: int) -> jax.Array:
    """Per-class (corrected, uncorrectable) counts — the device-side
    accumulator the registry folds between steps. Shape
    ``(len(FOLD_CLASSES), 2)`` int32, rows indexed by ``FOLD_CLASSES`` —
    derived from the Protection ladder, never a literal."""
    classes = obs_metrics.FOLD_CLASSES
    is_sec = (pages >= boundary) & (pages < num_rows)
    cls = jnp.where(is_sec, classes.index("secded"), cream_idx)
    cls = jnp.where(is_sec & (pages >= daec_start),
                    classes.index("daec"), cls)
    corrected = ((status == secded.CORRECTED_DATA)
                 | (status == secded.CORRECTED_CODE)).astype(jnp.int32)
    unc = (status == secded.DETECTED_UNCORRECTABLE).astype(jnp.int32)
    counts = jnp.zeros((len(classes), 2), jnp.int32)
    counts = counts.at[cls, 0].add(corrected)
    return counts.at[cls, 1].add(unc)


@jax.jit
def _read_correct_counts(state: PoolState, pages: jax.Array
                         ) -> tuple[jax.Array, jax.Array]:
    """Metrics-enabled gather for a local pool: the SAME fused mixed-pool
    read the plain path uses, except the per-page status it already
    computes is kept and reduced to the per-class count matrix inside
    the same compiled program — still one gather dispatch per step."""
    data, status = pool_lib.read_pages_any_status(state, pages)
    counts = _status_counts(pages, status, state.boundary, state.num_rows,
                            _cream_cls_index(state.layout),
                            state.daec_start)
    return data, counts


@functools.partial(jax.jit,
                   static_argnames=("boundary", "num_rows", "cream_idx",
                                    "daec_start"))
def _counts_only(pages: jax.Array, status: jax.Array, boundary: int,
                 num_rows: int, cream_idx: int,
                 daec_start: int) -> jax.Array:
    return _status_counts(pages, status, boundary, num_rows, cream_idx,
                          daec_start)


class Engine:
    """Paged-KV continuous-batching engine on a CREAM pool.

    ``mode='cream'`` runs the pool boundary-free (InterWrap, +12.5 %
    pages); ``'secded'`` pins ``boundary=0`` (all rows SECDED — the
    conventional-ECC baseline with the same arithmetic). Pass an existing
    ``vm`` (with pool ``pool`` already added, possibly sharded) to share
    the data plane with other tenants; the engine never branches on the
    pool's concrete type.
    """

    def __init__(self, cfg: ModelConfig, max_batch: int, max_len: int,
                 vm: VirtualMemory | None = None, pool: str = "kv",
                 mode: str = "cream", num_rows: int = 64,
                 row_words: int = 64, max_sessions: int = 128,
                 secded_rows: int = 0, seed: int = 0):
        if mode not in ("cream", "secded"):
            raise ValueError(mode)
        if len(transformer.attn_pattern_positions(cfg)) != len(cfg.pattern):
            raise ValueError(f"{cfg.name}: CREAM-Serve pages KV only; "
                             "attention-only patterns required")
        if vm is None:
            vm = VirtualMemory(row_words=row_words)
            # cream: boundary-free pool, except `secded_rows` kept in the
            # SECDED region so paid-tier requests have frames of their class
            vm.add_pool(pool, num_rows, Layout.INTERWRAP,
                        boundary=num_rows - secded_rows
                        if mode == "cream" else 0)
        self.cfg = cfg
        self.vm = vm
        self.pool_name = pool
        self.mode = mode
        self.max_batch = max_batch
        self.max_len = max_len
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.key(seed))
        self.n_layers = transformer.num_attn_layers(cfg)
        self.kv = PagedKV(
            vm, pool, n_layers=self.n_layers,
            token_words=token_words_for(cfg.num_kv_heads, cfg.head_dim_,
                                        cfg.activation_dtype),
            max_seqs=max_sessions, max_tokens=max_len)
        self.sched = Scheduler(self.kv, max_batch, token_limit=max_len)
        # host-side per-slot decode registers
        self._lens = np.zeros(max_batch, np.int32)
        self._toks = np.zeros(max_batch, np.int32)
        self.steps = 0
        self._prefill = jax.jit(
            lambda p, toks: self.model.prefill(p, toks, max_len))
        self._attend = jax.jit(self._attend_fn)
        # attend fused with the ppermute migration ring: ONE program, so
        # XLA overlaps the ring's collectives with the attention matmuls
        # (separate dispatches on the same devices would serialise)
        self._attend_ring = jax.jit(self._attend_ring_fn,
                                    donate_argnums=(4,))
        self._pending_migration: tuple[np.ndarray, np.ndarray] | None = None
        self._pack = jax.jit(self._pack_fn)
        # the paged-attention gather: the kernels/mixed fused read with the
        # flattened block table as its scalar-prefetched index map (geometry
        # is static → one compile per pool mode, page ids stay dynamic)
        self._mixed_read = jax.jit(
            mixed_ops.read_correct,
            static_argnames=("layout", "num_rows", "boundary",
                             "use_kernel"))
        if obs_metrics.enabled():
            # pre-create the acceptance-critical series at zero so every
            # snapshot carries the full per-class matrix, errors or not
            obs_metrics.touch_read_status()
            mig = obs_metrics.counter(
                obs_metrics.NAME_PAGES_MIGRATED,
                "pages relocated by the migration engine", labels=("cls",))
            for cls in obs_metrics.FOLD_CLASSES:
                mig.labels(cls=cls)
            obs_metrics.counter(
                obs_metrics.NAME_DECODE_STEPS,
                "batched decode steps executed")
            obs_metrics.counter(
                obs_metrics.NAME_TOKENS_DECODED,
                "tokens decoded, by request tier", labels=("tier",))
            obs_metrics.counter(
                obs_metrics.NAME_PREFILLS, "prompt prefills executed")
        obs_metrics.record_pool_capacity(pool, self.pool)

    # -- geometry shorthands -------------------------------------------------
    @property
    def pool(self):
        return self.vm.pools[self.pool_name]

    @property
    def _bt(self) -> int:
        return self.kv.block_tokens

    @property
    def _s_pad(self) -> int:
        return self.kv.max_blocks * self.kv.block_tokens

    # -- the fused per-step compute (one compiled program) -------------------
    def _attend_fn(self, params, pages_u32, lens, toks):
        """(B*L*maxB, page_words) gathered pages -> (logits, next token,
        updated current-block pages (B*L, page_words))."""
        cfg, kvw = self.cfg, self.kv.kv_words
        B, L, maxB, bt = (self.max_batch, self.n_layers,
                          self.kv.max_blocks, self._bt)
        hkv, hd = cfg.num_kv_heads, cfg.head_dim_
        pages = pages_u32.reshape(B, L, maxB, -1)
        used, tail = pages[..., :kvw], pages[..., kvw:]
        kvv = jax.lax.bitcast_convert_type(used, jnp.float32)
        kvv = kvv.reshape(B, L, maxB, 2, bt, hkv, hd)
        k = kvv[:, :, :, 0].transpose(1, 0, 2, 3, 4, 5) \
            .reshape(L, B, maxB * bt, hkv, hd)
        v = kvv[:, :, :, 1].transpose(1, 0, 2, 3, 4, 5) \
            .reshape(L, B, maxB * bt, hkv, hd)
        logits, _, (k_new, v_new) = transformer.decode_step_paged(
            params, cfg, {"cache_len": lens}, toks, (k, v))
        # write-back: insert the new token into each slot's current block
        blk = lens // bt
        off = lens - blk * bt
        idx = jnp.broadcast_to(blk.reshape(B, 1, 1, 1, 1, 1, 1),
                               (B, L, 1, 2, bt, hkv, hd))
        curr = jnp.take_along_axis(kvv, idx, axis=2)[:, :, 0]
        new_tok = jnp.stack([k_new.transpose(1, 0, 2, 3),
                             v_new.transpose(1, 0, 2, 3)], axis=2)
        onehot = jnp.arange(bt) == off[:, None]              # (B, bt)
        curr = jnp.where(onehot[:, None, None, :, None, None],
                         new_tok[:, :, :, None], curr)
        cur_used = jax.lax.bitcast_convert_type(curr, jnp.uint32) \
            .reshape(B, L, kvw)
        tidx = jnp.broadcast_to(blk.reshape(B, 1, 1, 1),
                                (B, L, 1, tail.shape[-1]))
        cur_tail = jnp.take_along_axis(tail, tidx, axis=2)[:, :, 0]
        cur_pages = jnp.concatenate([cur_used, cur_tail], axis=-1)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits, nxt, cur_pages.reshape(B * L, -1)

    def _attend_ring_fn(self, params, pages_u32, lens, toks, pool, src, dst):
        """:meth:`_attend_fn` fused with the sharded pool's ``ppermute``
        migration ring in ONE compiled program — the ring's cross-bank
        exchange overlaps the attention compute instead of serialising
        after it. ``pool``'s storage is donated (the caller installs the
        returned pool). Contract: ``src``/``dst`` must not touch pages of
        bound decode sequences (scheduled migrations are screened by
        :meth:`schedule_migration`'s caller)."""
        from repro.shard.pool import _migrate_impl
        logits, nxt, cur_pages = self._attend_fn(params, pages_u32, lens,
                                                 toks)
        return logits, nxt, cur_pages, _migrate_impl(pool, src, dst)

    def schedule_migration(self, src_pages, dst_pages) -> None:
        """Queue a page migration to run overlapped with the next decode
        step's compute (sharded pools: fused into the attend program so the
        ring's ``ppermute`` steps interleave with the matmuls; local pools:
        one fused migrate dispatch after compute). The pages must not
        belong to bound decode sequences — relocating a bound page would
        race the step's scatter; park or preempt the sequence first and
        call :meth:`refresh_translation` after the step."""
        src = np.asarray(src_pages, np.int32).reshape(-1)
        dst = np.asarray(dst_pages, np.int32).reshape(-1)
        if src.shape != dst.shape:
            raise ValueError("src/dst page lists must match")
        if self._pending_migration is not None:
            src = np.concatenate([self._pending_migration[0], src])
            dst = np.concatenate([self._pending_migration[1], dst])
        self._pending_migration = (src, dst)

    def _pack_fn(self, k, v):
        """Prefill KV (L, S, Hkv, D) pair -> (L*maxB, page_words) pages."""
        L, maxB, bt = self.n_layers, self.kv.max_blocks, self._bt
        pad = self._s_pad - k.shape[1]
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv = jnp.stack([k.reshape(L, maxB, bt, *k.shape[2:]),
                        v.reshape(L, maxB, bt, *v.shape[2:])], axis=2)
        used = jax.lax.bitcast_convert_type(kv, jnp.uint32) \
            .reshape(L, maxB, self.kv.kv_words)
        tail = jnp.zeros((L, maxB, self.kv.page_words - self.kv.kv_words),
                         jnp.uint32)
        return jnp.concatenate([used, tail], axis=-1) \
            .reshape(L * maxB, self.kv.page_words)

    def _gather_pages(self, phys: np.ndarray) -> jax.Array:
        """The decode step's ONE page gather. Local pools take the
        :mod:`repro.kernels.mixed` fused read — the Pallas scalar-prefetch
        kernel on TPU, its vectorised jnp oracle (= the mixed-pool engine's
        fast path) on CPU; sharded pools take the planned bank-aligned
        dispatch behind ``pool.read`` (host stream planning + ONE jitted
        per-bank gather, ~``n/S`` pages per bank)."""
        pool = self.pool
        if isinstance(pool, PoolState) and pool.daec_rows == 0:
            # the fused read bypasses the pool's wrappers, so feed
            # CREAM-Lens here (sharded pools record inside pool.read).
            # A DAEC tier falls through to pool.read — the mixed kernel
            # corrects with SECDED only and would mis-decode those rows.
            pool.memprof_record("gather", phys, stream="decode")
            return self._mixed_read(pool.storage,
                                    jnp.asarray(phys, jnp.int32),
                                    layout=pool.layout,
                                    num_rows=pool.num_rows,
                                    boundary=pool.boundary)
        return pool.read(phys)

    def _gather_pages_counts(self, phys: np.ndarray
                             ) -> tuple[jax.Array, jax.Array]:
        """Metrics-enabled gather: same dispatch shape, plus the (3, 2)
        per-class status-count matrix carried out of jit for the registry
        fold (see :func:`repro.obs.metrics.fold_read_status`)."""
        pool = self.pool
        pages = jnp.asarray(phys, jnp.int32)
        if isinstance(pool, PoolState):
            pool.memprof_record("gather", phys, stream="decode")
            return _read_correct_counts(pool, pages)
        data, status = pool.read(phys, status=True)
        counts = _counts_only(pages, status, boundary=pool.boundary,
                              num_rows=pool.num_rows,
                              cream_idx=_cream_cls_index(pool.layout),
                              daec_start=pool.daec_start)
        return data, counts

    # -- request intake ------------------------------------------------------
    def submit(self, req: ServeRequest) -> None:
        self.sched.submit(req)

    def refresh_translation(self) -> list[int]:
        """Call after an external repartition/migration on the serve pool:
        refreshes the block tables' physical mirror and preempts bound
        sequences whose pages left the device. Returns the dropped slots."""
        return self.sched.sync_residency()

    # -- the serving loop ------------------------------------------------------
    def _do_prefill(self, slot: int, req: ServeRequest, sess) -> None:
        with obs_tracing.span("engine.prefill", slot=slot,
                              prompt=len(req.prompt), tier=req.tier):
            self._do_prefill_impl(slot, req, sess)
        if obs_metrics.enabled():
            obs_metrics.counter(obs_metrics.NAME_PREFILLS,
                                "prompt prefills executed").inc()

    def _do_prefill_impl(self, slot: int, req: ServeRequest, sess) -> None:
        toks = jnp.asarray(np.asarray(req.prompt)[None, :], jnp.int32)
        logits, state = self._prefill(self.params, toks)
        apos = transformer.attn_pattern_positions(self.cfg)
        ks = jnp.stack([state[f"pos{i}"]["k"][:, 0] for i in apos], axis=1)
        vs = jnp.stack([state[f"pos{i}"]["v"][:, 0] for i in apos], axis=1)
        sh = (self.n_layers,) + ks.shape[2:]
        pages = self._pack(ks.reshape(sh).astype(jnp.float32),
                           vs.reshape(sh).astype(jnp.float32))
        p = len(req.prompt)
        nb = self.kv.blocks_for(p)
        phys = self.kv.gather_phys(np.asarray([sess.row]))[0]   # (L, maxB)
        ids = phys[:, :nb].reshape(-1)
        data = pages.reshape(self.n_layers, self.kv.max_blocks, -1)[:, :nb] \
            .reshape(len(ids), -1)
        self.vm.pools[self.pool_name] = self.pool.write(ids, data)
        sess.cache_len = p
        sess.last_tok = int(jnp.argmax(logits[0, -1]))
        req.generated.append(sess.last_tok)
        self._lens[slot] = sess.cache_len
        self._toks[slot] = sess.last_tok

    def step(self) -> list[ServeRequest]:
        """One decode step over every bound slot: one page gather, one
        model dispatch, one page scatter. Returns requests that finished."""
        self.sched.ensure_step()
        if obs_memprof.enabled():
            obs_memprof.next_step()     # one profiler step per decode step
        rows = np.asarray([s.row if s is not None else -1
                           for s in self.sched.slots])
        active = rows >= 0
        if not active.any():
            return []
        lens = np.where(active, self._lens, 0).astype(np.int32)
        toks = np.where(active, self._toks, 0).astype(np.int32)
        with obs_tracing.span("serve.router.dispatch",
                              slots=int(active.sum())):
            phys = self.kv.gather_phys(rows)                # (B, L, maxB)
        counts = None
        with obs_tracing.blocked_span("engine.step.gather",
                                      pages=int(phys.size)) as hold:
            if obs_metrics.enabled():
                pages, counts = self._gather_pages_counts(phys.reshape(-1))
            else:
                pages = self._gather_pages(phys.reshape(-1))  # ONE gather
            hold(pages)
        pending = self._pending_migration
        from repro.shard.pool import ShardedPool
        if pending is not None and isinstance(self.pool, ShardedPool):
            # ring overlapped with compute: ONE fused program
            src, dst = pending
            self._pending_migration = None
            if obs_metrics.enabled():
                obs_metrics.counter(
                    obs_metrics.NAME_SHARD_RING_PAGES,
                    "pages exchanged over the ppermute migration ring"
                ).inc(int(src.shape[0]))
            with obs_tracing.blocked_span("engine.step.compute_ring",
                                          ring_pages=int(src.shape[0])) \
                    as hold:
                _, nxt, cur_pages, new_pool = self._attend_ring(
                    self.params, pages, jnp.asarray(lens),
                    jnp.asarray(toks), self.pool,
                    jnp.asarray(src), jnp.asarray(dst))
                self.vm.pools[self.pool_name] = new_pool
                hold(nxt)
        else:
            with obs_tracing.blocked_span("engine.step.compute") as hold:
                _, nxt, cur_pages = self._attend(self.params, pages,
                                                 jnp.asarray(lens),
                                                 jnp.asarray(toks))
                hold(nxt)
            if pending is not None:
                self._pending_migration = None
                self.vm.pools[self.pool_name] = self.pool.migrate(
                    pending[0], pending[1])
        with obs_tracing.blocked_span("engine.step.scatter") as hold:
            cur_ids = self.kv.current_block_phys(rows, lens)  # (B, L)
            self.vm.pools[self.pool_name] = self.pool.write(
                cur_ids.reshape(-1), cur_pages)             # ONE scatter
            hold(self.pool.storage)
        nxt = np.asarray(nxt)
        self.steps += 1
        if counts is not None:
            obs_metrics.fold_read_status(counts)
        finished = []
        tokens_by_tier: dict[str, int] = {}
        for slot in np.flatnonzero(active):
            sess = self.sched.slots[slot]
            sess.cache_len += 1
            sess.last_tok = int(nxt[slot])
            sess.req.generated.append(sess.last_tok)
            self._lens[slot] = sess.cache_len
            self._toks[slot] = sess.last_tok
            tier = sess.req.tier
            tokens_by_tier[tier] = tokens_by_tier.get(tier, 0) + 1
            if len(sess.req.generated) >= sess.req.max_new:
                finished.append(self.sched.finish(slot))
        if obs_metrics.enabled():
            obs_metrics.counter(obs_metrics.NAME_DECODE_STEPS,
                                "batched decode steps executed").inc()
            tok = obs_metrics.counter(
                obs_metrics.NAME_TOKENS_DECODED,
                "tokens decoded, by request tier", labels=("tier",))
            for tier, n in tokens_by_tier.items():
                tok.labels(tier=tier).inc(n)
        return finished

    def poll(self) -> list[ServeRequest]:
        """One serving-loop iteration: an admission pass (prefilling the
        newly admitted sessions) followed by one batched decode step.
        Returns requests that completed; raises on an unserveable queue."""
        admitted = self.sched.tick()
        done: list[ServeRequest] = []
        for adm in admitted:
            if adm.is_prefill:
                self._do_prefill(adm.slot, adm.req, adm.session)
                if len(adm.req.generated) >= adm.req.max_new:
                    done.append(self.sched.finish(adm.slot))
            else:
                self._lens[adm.slot] = adm.session.cache_len
                self._toks[adm.slot] = adm.session.last_tok
        if self.sched.active_slots():
            done.extend(self.step())
        elif not admitted and self.sched.waiting:
            raise RuntimeError(
                "deadlock: waiting requests cannot be admitted "
                f"({self.sched.stats})")
        return done

    def serve(self, requests: list[ServeRequest]) -> dict:
        """Serve a request list to completion; returns the run's stats."""
        for req in requests:
            self.submit(req)
        done: list[ServeRequest] = []
        t0 = time.perf_counter()
        while self.sched.has_work():
            done.extend(self.poll())
        wall = time.perf_counter() - t0
        lats = [r.latency_s for r in done]
        tokens = sum(len(r.generated) for r in done)
        return {
            "wall_s": wall,
            "tokens": tokens,
            "tokens_per_s": tokens / wall if wall else 0.0,
            "requests": len(done),
            "p50_latency_ms": _percentile(lats, 50) * 1e3,
            "p99_latency_ms": _percentile(lats, 99) * 1e3,
            "decode_steps": self.steps,
            "device_pages": self.vm.device_capacity_pages(self.pool_name),
            "device_util": self.vm.utilisation(self.pool_name),
            "vm_fault_rate": self.vm.stats.fault_rate,
            "host_reads": self.vm.stats.host_reads,
            "mode": self.mode,
            **self.sched.stats,
        }

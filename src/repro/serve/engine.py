"""Serving engine: batched decode with CREAM-tiered sequence parking.

A deliberately compact continuous-batching engine:

  * requests (prompt, max_new) are admitted into decode slots;
  * when a request pauses (multi-turn think time) its per-sequence decode
    state is packed and parked in the :class:`SequenceCache`, which
    allocates through the CREAM-VM (:mod:`repro.vm`) — device pool tier
    first, host swap on overflow — so pool repartitions live-migrate
    parked state instead of dropping it;
  * on resume the state is fetched back — a host fetch is the page fault
    whose frequency the pool's capacity mode controls.

The decode batch itself is a dense jitted ``decode_step`` over B slots;
per-sequence state slices in/out of the batch via tree indexing.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.serve.kv_cache import SequenceCache, pack_tree, unpack_tree


@dataclass
class Request:
    seq_id: str
    prompt: np.ndarray
    max_new: int
    generated: list[int] = field(default_factory=list)
    latency_s: float = 0.0


class Engine:
    def __init__(self, cfg: ModelConfig, batch_size: int, max_len: int,
                 cache: SequenceCache, seed: int = 0):
        self.cfg = cfg
        self.batch = batch_size
        self.max_len = max_len
        self.cache = cache
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.key(seed))
        self._decode = jax.jit(self.model.decode_step)
        self._specs: dict = {}
        self._prefill = jax.jit(
            lambda p, toks: self.model.prefill(p, toks, max_len))

    # -- single-sequence building blocks -------------------------------------
    def prefill_one(self, req: Request):
        toks = jnp.asarray(req.prompt[None, :], jnp.int32)
        logits, state = self._prefill(self.params, toks)
        next_tok = int(jnp.argmax(logits[0, -1]))
        return next_tok, state

    def park(self, seq_id: str, state) -> None:
        blob, spec = pack_tree(state)
        self.cache.park(seq_id, blob)
        self._specs[seq_id] = spec

    def resume(self, req: Request, blob: np.ndarray | None = None,
               prefetched: bool = False):
        """Restore a request's decode state.

        ``prefetched=True`` means ``blob`` came from a batched
        :meth:`SequenceCache.resume_many` prefetch (possibly None on miss)
        and the cache must not be consulted again.
        """
        if not prefetched:
            blob = self.cache.resume(req.seq_id)
        if blob is None:
            tok, state = self.prefill_one(req)   # cache miss -> re-prefill
            if req.generated:
                # replay generated tokens to rebuild state
                for t in req.generated:
                    _, state = self._decode(self.params, state,
                                            jnp.asarray([t], jnp.int32))
                tok = req.generated[-1]
            return tok, state
        return None, unpack_tree(blob, self._specs[req.seq_id])

    # -- serving loop ----------------------------------------------------------
    def serve(self, requests: list[Request], steps_per_turn: int = 8
              ) -> dict:
        """Round-robin multi-turn serving: each request decodes
        ``steps_per_turn`` tokens per turn, parking between turns."""
        t_start = time.perf_counter()
        queue = list(requests)
        first = True
        while any(len(r.generated) < r.max_new for r in queue):
            active = [r for r in queue if len(r.generated) < r.max_new]
            # batched prefetch: one mixed-pool engine dispatch per backing
            # pool restores the whole turn's parked states together
            blobs = {} if first else self.cache.resume_many(
                [r.seq_id for r in active if r.seq_id in self._specs])
            for req in active:
                t0 = time.perf_counter()
                if first or req.seq_id not in self._specs:
                    tok, state = self.prefill_one(req)
                    req.generated.append(tok)
                else:
                    _, state = self.resume(req, blob=blobs.get(req.seq_id),
                                           prefetched=True)
                    tok = req.generated[-1]
                for _ in range(steps_per_turn):
                    if len(req.generated) >= req.max_new:
                        break
                    logits, state = self._decode(
                        self.params, state, jnp.asarray([tok], jnp.int32))
                    tok = int(jnp.argmax(logits[0]))
                    req.generated.append(tok)
                self.park(req.seq_id, state)
                req.latency_s += time.perf_counter() - t0
            first = False
        wall = time.perf_counter() - t_start
        total_tokens = sum(len(r.generated) for r in queue)
        return {
            "wall_s": wall,
            "tokens": total_tokens,
            "tokens_per_s": total_tokens / wall,
            "fault_rate": self.cache.stats.fault_rate,
            "device_hits": self.cache.stats.device_hits,
            "host_hits": self.cache.stats.host_hits,
            "evictions": self.cache.stats.evictions,
            "device_pages": self.cache.device_capacity_pages,
            "device_util": self.cache.device_utilisation,
            "vm_fault_rate": self.cache.vm.stats.fault_rate,
            "mode": self.cache.mode,
        }

"""Paged-KV block tables on the CREAM data plane (paper §3.1 + §6.1/Fig. 8).

Paper anchor: Fig. 1's "caches tolerate loss" quadrant and the §6.1
memcached/WebSearch capacity experiments, applied to the KV cache of a
serving engine. The KV cache is the serving tier's page cache: every
(sequence, layer, block) of KV lives in ONE CREAM pool page, so the
boundary register's +12.5 % (InterWrap) capacity gain is extra *sequences
kept device-resident* — the paper's fewer-page-faults story with decode
states instead of memcached values.

vLLM-style paged attention, mapped onto the repo's data plane:

  * a **block** holds ``block_tokens`` tokens of one attention layer's K and
    V, packed ``(2, block_tokens, Hkv, D)`` float32 and bit-cast to the
    pool's uint32 page words (tail-padded to ``page_words``);
  * the **block table** maps ``(seq row, layer, block index) -> vpn`` into a
    VM tenant; a cached vpn→physical-page mirror (refreshed after any
    repartition / migration, like :meth:`repro.objcache.ObjCache
    .refresh_translation`) turns a whole decode batch's tables into one
    int32 page-id array — the index map of the mixed-pool gather
    (:mod:`repro.kernels.mixed`), so a decode step's KV reads are ONE
    batched ``read_pages`` and its write-back ONE batched ``write_pages``
    on any :class:`repro.core.pool.PoolLike` (local or sharded);
  * **reliability tiers** (HRM-style, Luo et al.): each sequence's pages are
    allocated under a tenant segment — ``paid`` → SECDED frames, ``batch``
    → NONE/PARITY frames. A repartition that grows the CREAM region frees
    weak-class frames that admit more batch sequences *without* evicting
    paid ones (the live capacity bridge);
  * **preempt-to-host**: a sequence's pages swap to the VM's host tier
    (:meth:`preempt`) and return bit-exact (:meth:`restore`) — restore
    re-lands pages in this pool via fresh frames, and the host reads are
    the page faults :class:`repro.vm.address_space.VMStats` counts.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.protection import _ORDER, Protection
from repro.vm.address_space import VirtualMemory, frame_class

#: Default request tiers: who may land on which storage class. Over-
#: protection is allowed (a batch page may sit on a SECDED frame when the
#: pool is all-SECDED), under-protection never is.
DEFAULT_TIERS = {"paid": Protection.SECDED, "batch": Protection.NONE}


@dataclass
class _Row:
    """One sequence's block-table row."""
    tier: str
    blocks: int = 0          # allocated blocks per layer


class PagedKV:
    """(seq row, layer, block) -> CREAM page-id block tables over a VM pool.

    ``token_words`` is the uint32 words one token of one layer's K+V packs
    to (``2 * Hkv * D`` for float32). All pages come from the single pool
    ``pool`` of ``vm`` (callers share the VM with other tenants freely; the
    serve data plane stays pinned so a decode step is one gather on one
    pool). ``max_tokens`` bounds a sequence's KV; the block table reserves
    ``ceil(max_tokens / block_tokens)`` block slots per (row, layer).
    """

    def __init__(self, vm: VirtualMemory, pool: str, n_layers: int,
                 token_words: int, max_seqs: int, max_tokens: int,
                 tenant: str = "serve",
                 tiers: dict[str, Protection] | None = None):
        self.vm = vm
        self.pool_name = pool
        self.tenant = tenant
        self.n_layers = n_layers
        self.token_words = token_words
        self.block_tokens = vm.page_words // token_words
        if self.block_tokens < 1:
            raise ValueError(
                f"page ({vm.page_words} words) smaller than one KV token "
                f"({token_words} words); raise row_words")
        self.kv_words = self.block_tokens * token_words
        self.max_seqs = max_seqs
        self.max_blocks = math.ceil(max_tokens / self.block_tokens)
        self.tiers = dict(tiers or DEFAULT_TIERS)
        vm.create_tenant(tenant, default_reliability=Protection.NONE,
                         segments=self.tiers)
        # block tables: vpn per (row, layer, block); -1 = unallocated
        self._table = np.full((max_seqs, n_layers, self.max_blocks), -1,
                              np.int64)
        self._rows: dict[int, _Row] = {}
        self._free_rows = list(range(max_seqs - 1, -1, -1))
        # vpn -> home-pool physical page (-1 = host / foreign pool)
        self._phys = np.full(64, -1, np.int32)
        # one always-device scratch page: unbound decode slots read it and
        # park their (ignored) write-back there, so the per-step gather and
        # scatter keep a fixed shape with no host-side branching
        scratch = vm.alloc(tenant, 1, reliability=Protection.NONE,
                           allow_host=False, zero=True, pool=pool)
        if scratch is None:
            raise ValueError(f"pool {pool!r} has no free frame for scratch")
        self._scratch_vpn = scratch[0]
        self._sync(scratch)

    # -- geometry / accounting ----------------------------------------------
    @property
    def page_words(self) -> int:
        return self.vm.page_words

    @property
    def scratch_phys(self) -> int:
        return int(self._phys[self._scratch_vpn])

    def blocks_for(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.block_tokens)

    def frames_needed(self, row: int, n_tokens: int) -> int:
        """Device frames :meth:`ensure` would claim for ``n_tokens``."""
        need = self.blocks_for(n_tokens) - self._rows[row].blocks
        return max(need, 0) * self.n_layers

    def mapped_pages(self, row: int) -> int:
        """Pages the row currently maps (device- or host-resident)."""
        return self._rows[row].blocks * self.n_layers

    def row_frames_of_class(self, row: int,
                            reliability: Protection) -> int:
        """Device-resident pages of the row on frames of storage class
        >= ``reliability`` — what preempting the row would free for an
        allocation of that class. Lets the scheduler skip victims whose
        eviction cannot help (e.g. a batch session on NONE frames when a
        paid request needs SECDED)."""
        pool = self.vm.pools[self.pool_name]
        i = _ORDER.index(reliability)
        vpns = self._table[row][self._table[row] >= 0]
        return sum(1 for v in vpns
                   if self._phys[int(v)] >= 0
                   and _ORDER.index(frame_class(
                       pool, int(self._phys[int(v)]))) >= i)

    def free_frames(self, reliability: Protection) -> int:
        """Free home-pool frames with storage class >= ``reliability``."""
        alloc = self.vm.allocators[self.pool_name]
        i = _ORDER.index(reliability)
        return sum(len(alloc.free[cls]) for cls in _ORDER[i:])

    def used_pages(self) -> int:
        return int((self._table >= 0).sum()) + 1        # + scratch

    # -- row lifecycle -------------------------------------------------------
    def open(self, tier: str) -> int:
        """Claim a block-table row for a new sequence; no pages yet."""
        if tier not in self.tiers:
            raise KeyError(f"unknown tier {tier!r}")
        if not self._free_rows:
            raise RuntimeError(f"all {self.max_seqs} sequence rows in use")
        row = self._free_rows.pop()
        self._rows[row] = _Row(tier)
        return row

    def close(self, row: int) -> None:
        """Release a row and every page it maps."""
        vpns = self._table[row][self._table[row] >= 0]
        if len(vpns):
            self.vm.free(self.tenant, [int(v) for v in vpns])
        self._table[row] = -1
        del self._rows[row]
        self._free_rows.append(row)

    def ensure(self, row: int, n_tokens: int) -> bool:
        """Grow the row's block table to hold ``n_tokens``; False = pool
        full (no device frames of the row's class — caller preempts or
        defers; nothing is allocated on failure)."""
        r = self._rows[row]
        nb = self.blocks_for(n_tokens)
        if nb > self.max_blocks:
            raise ValueError(f"{n_tokens} tokens > {self.max_blocks} blocks")
        need = nb - r.blocks
        if need <= 0:
            return True
        vpns = self.vm.alloc(self.tenant, need * self.n_layers,
                             segment=r.tier, allow_host=False, zero=False,
                             pool=self.pool_name)
        if vpns is None:
            return False
        got = np.asarray(vpns, np.int64).reshape(self.n_layers, need)
        self._table[row, :, r.blocks:nb] = got
        r.blocks = nb
        self._sync(vpns)
        return True

    # -- residency -----------------------------------------------------------
    def resident(self, row: int) -> bool:
        """True iff every mapped page is home-pool device-resident."""
        vpns = self._table[row][self._table[row] >= 0]
        return bool((self._phys[vpns] >= 0).all()) if len(vpns) else True

    def host_pages(self, row: int) -> int:
        vpns = self._table[row][self._table[row] >= 0]
        return int((self._phys[vpns] < 0).sum()) if len(vpns) else 0

    def preempt(self, row: int) -> int:
        """Swap the row's device pages to the VM host tier (KV preserved
        bit-exact); returns pages moved."""
        from repro.obs import metrics, tracing
        tier = self._rows[row].tier
        vpns = [int(v) for v in self._table[row][self._table[row] >= 0]
                if self._phys[v] >= 0 or self.vm.translate(
                    self.tenant, int(v)).pool is not None]
        with tracing.span("migrate.preempt_to_host", row=row, tier=tier,
                          pages=len(vpns)):
            moved = self.vm.swap_out(self.tenant, vpns) if vpns else 0
        self._sync(vpns)
        if metrics.enabled():
            metrics.counter(
                metrics.NAME_PREEMPTIONS,
                "sequences preempted to the host swap tier",
                labels=("tier",)).labels(tier=tier).inc()
        return moved

    def restore(self, row: int) -> bool:
        """Bring a preempted row's pages back into the home pool.

        Re-lands every off-home page in a fresh home-pool frame through the
        VM data plane — the host reads are the page faults the capacity
        mode controls — then retires the old mappings. False = not enough
        free frames (nothing changes; caller makes room and retries).
        """
        from repro.obs import metrics, tracing
        r = self._rows[row]
        vpns = self._table[row]
        off = np.argwhere((vpns >= 0) & (self._phys[np.clip(vpns, 0, None)]
                                         < 0))
        if not len(off):
            return True
        old = [int(vpns[tuple(ix)]) for ix in off]
        new = self.vm.alloc(self.tenant, len(old), segment=r.tier,
                            allow_host=False, zero=False,
                            pool=self.pool_name)
        if new is None:
            return False
        with tracing.span("migrate.restore_from_host", row=row, tier=r.tier,
                          pages=len(old)):
            data = self.vm.read(self.tenant, old)       # the page fault(s)
            self.vm.write(self.tenant, new, data)
        self.vm.free(self.tenant, old)
        for ix, nv in zip(off, new):
            self._table[row][tuple(ix)] = nv
        self._sync(new)
        if metrics.enabled():
            metrics.counter(
                metrics.NAME_RESTORES,
                "preempted sequences restored to device frames",
                labels=("tier",)).labels(tier=r.tier).inc()
        return True

    def refresh(self) -> dict:
        """Rebuild the vpn→phys mirror from the VM page tables.

        Call after any repartition / migration touching the pool (the
        objcache's ``refresh_translation`` idiom): pages that moved to the
        host tier or a foreign pool flip to non-resident, and the scheduler
        preempts the sequences that own them before the next decode gather.
        """
        space = self.vm.tenants[self.tenant]
        if space.entries:
            self._grow(max(space.entries))
        away = device = 0
        for vpn, pte in space.entries.items():
            if pte.pool == self.pool_name:
                self._phys[vpn] = pte.phys
                device += 1
            else:
                self._phys[vpn] = -1
                away += 1
        return {"device_pages": device, "away_pages": away}

    # -- the decode-step index maps ------------------------------------------
    def gather_phys(self, rows: np.ndarray) -> np.ndarray:
        """Block tables of a decode batch as one page-id array.

        ``rows`` is ``(B,)`` int (-1 = unbound slot). Returns ``(B,
        n_layers, max_blocks)`` int32 physical page ids — the index map of
        the step's single mixed-pool gather. Unbound slots and unallocated
        block slots point at the scratch page (their data is masked by
        ``cache_len`` downstream); every mapped block of a bound row must
        be home-device-resident (the scheduler's invariant).
        """
        rows = np.asarray(rows)
        safe = np.clip(rows, 0, None)
        vpns = self._table[safe]                       # (B, L, maxB)
        vpns = np.where(rows[:, None, None] >= 0, vpns, -1)
        phys = np.where(vpns >= 0, self._phys[np.clip(vpns, 0, None)], -1)
        if (np.where(vpns >= 0, phys, 0) < 0).any():
            bad = sorted({int(r) for r in
                          rows[(np.where(vpns >= 0, phys, 0) < 0)
                               .any(axis=(1, 2))]})
            raise RuntimeError(
                f"rows {bad} have non-resident pages in the decode batch; "
                "preempt or restore them first")
        return np.where(phys >= 0, phys,
                        self.scratch_phys).astype(np.int32)

    def current_block_phys(self, rows: np.ndarray,
                           lens: np.ndarray) -> np.ndarray:
        """Physical page of each slot's *current* block (the one token
        ``lens`` lands in) — the index map of the step's single scatter.
        Returns ``(B, n_layers)`` int32; unbound slots write the scratch
        page."""
        rows = np.asarray(rows)
        lens = np.asarray(lens)
        safe = np.clip(rows, 0, None)
        blk = np.clip(lens // self.block_tokens, 0, self.max_blocks - 1)
        vpns = np.take_along_axis(self._table[safe],
                                  blk[:, None, None], axis=2)[:, :, 0]
        vpns = np.where(rows[:, None] >= 0, vpns, -1)
        phys = np.where(vpns >= 0, self._phys[np.clip(vpns, 0, None)], -1)
        return np.where(phys >= 0, phys,
                        self.scratch_phys).astype(np.int32)

    # -- internals -----------------------------------------------------------
    def _grow(self, vmax: int) -> None:
        if vmax < len(self._phys):
            return
        n = max(vmax + 1, 2 * len(self._phys))
        grown = np.full(n, -1, np.int32)
        grown[:len(self._phys)] = self._phys
        self._phys = grown

    def _sync(self, vpns) -> None:
        """Refresh the mirror for specific vpns from the page tables."""
        if not len(vpns):
            return
        self._grow(max(int(v) for v in vpns))
        space = self.vm.tenants[self.tenant]
        for v in vpns:
            pte = space.entries[int(v)]
            self._phys[int(v)] = pte.phys \
                if pte.pool == self.pool_name else -1


def token_words_for(num_kv_heads: int, head_dim: int,
                    dtype=jnp.float32) -> int:
    """uint32 words one token of one layer's K+V occupies in a pool page."""
    itemsize = jnp.dtype(dtype).itemsize
    if itemsize != 4:
        raise ValueError(
            f"paged KV packs 4-byte elements into uint32 pool words; "
            f"got {jnp.dtype(dtype)} (cast the cache to float32)")
    return 2 * num_kv_heads * head_dim

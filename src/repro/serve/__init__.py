"""repro.serve subpackage."""

"""CREAM-Serve: paged-KV continuous batching on the CREAM data plane.

Paper anchor: §6.1 / Fig. 8 (capacity → end-to-end serving speedups) and
Fig. 1's reliability-tolerance quadrants (KV blocks are the cache-class
data that trades protection for capacity).

Layers:

  * :mod:`repro.serve.paged_kv`  — block tables mapping (seq, layer,
    block) → CREAM page ids, with per-request reliability tiers;
  * :mod:`repro.serve.scheduler` — admission control, parking,
    preempt-to-host;
  * :mod:`repro.serve.engine`    — the continuous-batching engine: one
    pool gather + one pool scatter per decode step;
  * :mod:`repro.serve.kv_cache`  — the earlier whole-state
    :class:`~repro.serve.kv_cache.SequenceCache` park/resume tier, kept
    as the VM-tenant exemplar the VM test-suite exercises.
"""
from repro.serve.engine import Engine
from repro.serve.paged_kv import PagedKV, token_words_for
from repro.serve.scheduler import Scheduler, ServeRequest

__all__ = ["Engine", "PagedKV", "Scheduler", "ServeRequest",
           "token_words_for"]

"""Pallas TPU kernels for Hsiao SECDED(72,64) encode / decode-correct.

TPU mapping (DESIGN.md §2.2): SECDED is pure VPU work — per-beat popcounts
against 8 bit-masks, syndrome matching, and XOR fix-ups. Arithmetic intensity
is low (~30 VPU ops per 8 bytes), so the kernels are strictly memory-bound:
the BlockSpec tiling streams rows HBM→VMEM in large aligned tiles and fuses
encode/correct into a single pass (the paper's "performed entirely in
hardware as part of every memory request").

Two TPU-specific adaptations vs. the reference:
  * the per-parity bit-masks are baked in as scalar literals (VREG splats),
  * the 256-entry syndrome→action table becomes a 72-way compare/select
    chain — per-element gathers don't vectorise on the VPU, whereas a select
    tree is pure element-wise work.

Tiling: data rows are (N, D) uint32. Blocks are (BLOCK_ROWS, D): for
BLOCK_ROWS=32 and a pool row D=2048 (8 lanes × 256 words) the working set is
32×8KB data + codes + status ≈ 0.6MB of VMEM — comfortably double-buffered
on a v5e core, with 128-multiple minor dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.secded import _COLUMNS, _MASK_HI, _MASK_LO, NUM_CODE_BITS
from repro.kernels.common import pick_block, use_interpret

DEFAULT_BLOCK_ROWS = 32

# Python-int constants — splatted into VREGs at trace time.
MASKS = [(int(_MASK_LO[p]), int(_MASK_HI[p])) for p in range(NUM_CODE_BITS)]
COLUMNS = [int(c) for c in _COLUMNS]


def _encode_beats(lo: jax.Array, hi: jax.Array) -> jax.Array:
    code = jnp.zeros_like(lo)
    for p, (mlo, mhi) in enumerate(MASKS):
        ones = jax.lax.population_count(lo & jnp.uint32(mlo)) + \
            jax.lax.population_count(hi & jnp.uint32(mhi))
        code = code | ((ones & jnp.uint32(1)) << p)
    return code


def _syndrome_action(syn: jax.Array) -> jax.Array:
    """Syndrome -> action via select chain: -1 clean, 0..63 data bit,
    64..71 code bit, -2 detected-uncorrectable."""
    action = jnp.full(syn.shape, -2, jnp.int32)
    action = jnp.where(syn == 0, -1, action)
    for i, col in enumerate(COLUMNS):
        action = jnp.where(syn == jnp.uint32(col), i, action)
    for p in range(NUM_CODE_BITS):
        action = jnp.where(syn == jnp.uint32(1 << p), 64 + p, action)
    return action


def _split(data: jax.Array) -> tuple[jax.Array, jax.Array]:
    pairs = data.reshape(data.shape[0], data.shape[1] // 2, 2)
    return pairs[..., 0], pairs[..., 1]


def _pack4(codes: jax.Array) -> jax.Array:
    g = codes.reshape(codes.shape[0], codes.shape[1] // 4, 4)
    return (g[..., 0] | (g[..., 1] << 8) | (g[..., 2] << 16)
            | (g[..., 3] << 24)).astype(jnp.uint32)


def _unpack4(packed: jax.Array, beats: int) -> jax.Array:
    parts = [(packed >> (8 * j)) & jnp.uint32(0xFF) for j in range(4)]
    return jnp.stack(parts, axis=-1).reshape(packed.shape[0], beats)


def decode_correct_block(blk: jax.Array, packed_codes: jax.Array
                         ) -> jax.Array:
    """Fused Hsiao check+correct of one flattened block (VPU-only work).

    ``blk`` is any uint32 block whose flattened words pair into 64-bit
    beats; ``packed_codes`` holds the matching packed code bytes (one per
    beat, 4 per word). Returns the block with single-bit *data* errors
    corrected in place — code-bit and uncorrectable beats pass through
    unchanged. Shared by every kernel that fuses correction into a gather
    (``kernels.mixed``, ``kernels.hash``).
    """
    flat = blk.reshape(1, -1)
    pairs = flat.reshape(1, flat.shape[1] // 2, 2)
    lo, hi = pairs[..., 0], pairs[..., 1]
    stored = _unpack4(packed_codes.reshape(1, -1), lo.shape[1])
    syndrome = (_encode_beats(lo, hi) ^ stored) & jnp.uint32(0xFF)
    action = _syndrome_action(syndrome)
    is_data = (action >= 0) & (action < 64)
    bit = jnp.where(action >= 0, action, 0).astype(jnp.uint32)
    lo = lo ^ jnp.where(is_data & (bit < 32), jnp.uint32(1) << (bit & 31), 0)
    hi = hi ^ jnp.where(is_data & (bit >= 32), jnp.uint32(1) << (bit & 31), 0)
    return jnp.stack([lo, hi], axis=-1).reshape(blk.shape)


def _encode_kernel(data_ref, codes_ref):
    lo, hi = _split(data_ref[...])
    codes_ref[...] = _pack4(_encode_beats(lo, hi))


def _decode_kernel(data_ref, codes_ref, out_data_ref, out_codes_ref,
                   status_ref):
    lo, hi = _split(data_ref[...])
    stored = _unpack4(codes_ref[...], lo.shape[1])
    syndrome = (_encode_beats(lo, hi) ^ stored) & jnp.uint32(0xFF)
    action = _syndrome_action(syndrome)

    is_data = (action >= 0) & (action < 64)
    is_code = action >= 64
    bit = jnp.where(action >= 0, action, 0).astype(jnp.uint32)
    lo = lo ^ jnp.where(is_data & (bit < 32), jnp.uint32(1) << (bit & 31), 0)
    hi = hi ^ jnp.where(is_data & (bit >= 32), jnp.uint32(1) << (bit & 31), 0)
    stored = stored ^ jnp.where(is_code, jnp.uint32(1) << ((bit - 64) & 7), 0)

    out_data_ref[...] = jnp.stack([lo, hi], axis=-1).reshape(data_ref.shape)
    out_codes_ref[...] = _pack4(stored)
    status_ref[...] = jnp.where(
        action == -1, 0,
        jnp.where(is_data, 1, jnp.where(is_code, 2, 3))).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def encode(data: jax.Array, block_rows: int = DEFAULT_BLOCK_ROWS) -> jax.Array:
    """(N, D) uint32 -> (N, D//8) packed SECDED codes."""
    n, d = data.shape
    br = pick_block(n, block_rows)
    return pl.pallas_call(
        _encode_kernel,
        grid=(n // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d // 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d // 8), jnp.uint32),
        interpret=use_interpret(),
    )(data)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def decode(data: jax.Array, codes: jax.Array,
           block_rows: int = DEFAULT_BLOCK_ROWS
           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused check+correct. (N,D),(N,D//8) -> (data', codes', status (N,D//2))."""
    n, d = data.shape
    br = pick_block(n, block_rows)
    return pl.pallas_call(
        _decode_kernel,
        grid=(n // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((br, d // 8), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                   pl.BlockSpec((br, d // 8), lambda i: (i, 0)),
                   pl.BlockSpec((br, d // 2), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, d), jnp.uint32),
                   jax.ShapeDtypeStruct((n, d // 8), jnp.uint32),
                   jax.ShapeDtypeStruct((n, d // 2), jnp.int32)],
        interpret=use_interpret(),
    )(data, codes)

"""Pure-jnp oracle for the SECDED kernels — delegates to repro.core.secded."""
from __future__ import annotations

import jax

from repro.core import secded as _s


def encode(data: jax.Array) -> jax.Array:
    """(N, D) uint32, D % 8 == 0 -> (N, D//8) packed codes."""
    return _s.encode_block(data)


def decode(data: jax.Array, codes: jax.Array
           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(N, D), (N, D//8) -> (corrected data, corrected codes, status (N, D//2))."""
    return _s.decode_block(data, codes)

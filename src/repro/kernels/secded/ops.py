"""Public jit'd entry points for SECDED encode/decode with kernel/ref dispatch."""
from __future__ import annotations

import jax

from repro.kernels.secded import kernel, ref


def encode(data: jax.Array, use_kernel: bool = True) -> jax.Array:
    """(N, D) uint32 -> (N, D//8) packed codes."""
    if use_kernel:
        return kernel.encode(data)
    return ref.encode(data)


def decode(data: jax.Array, codes: jax.Array, use_kernel: bool = True
           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(N, D), (N, D//8) -> (corrected data, corrected codes, per-beat status)."""
    if use_kernel:
        return kernel.decode(data, codes)
    return ref.decode(data, codes)

"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package has three files:
  * ``kernel.py`` — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target),
  * ``ops.py``    — jit'd public wrapper with kernel/ref dispatch,
  * ``ref.py``    — pure-jnp oracle used by the allclose test sweeps.

Kernels run natively on TPU and in interpret mode elsewhere
(``repro.kernels.common.use_interpret``).

Catalogue:
  secded           Hsiao(72,64) encode / fused check+correct
  daec             SEC-DAEC(144,128) interleaved dual-Hsiao encode / correct
  parity8          8-bit-per-line detection code
  interwrap        Solution-3 wrap-around page gather/scatter (scalar prefetch)
  mixed            mixed-pool fused read: universal page_coords gather +
                   masked SECDED correction for any boundary
  migrate          live migration: wrap gather fused with SECDED re-encode
  scrub            fused scrub sweep: decode + correct + census, one pass
  ecc_matmul       beyond-paper: SECDED decode-on-load fused into a matmul
  flash_attention  causal GQA flash attention for long-context serving
"""
